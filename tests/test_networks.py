"""Cost/policy network properties: the sum/max reductions must make
predictions invariant to table order and (for the overall head) device
order -- the mechanism behind DreamShard's generalization (paper §3.2)."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import features as F
from repro.core import networks as N


def _setup(m=12, d=4, seed=0):
    rng = np.random.default_rng(seed)
    feats = rng.random((m, F.NUM_FEATURES)).astype(np.float32)
    assign = rng.integers(0, d, m)
    onehot = np.zeros((d, m), np.float32)
    onehot[assign, np.arange(m)] = 1.0
    params = N.cost_net_init(jax.random.PRNGKey(seed))
    return params, jnp.asarray(feats), jnp.asarray(onehot), assign


def test_cost_net_shapes():
    params, feats, onehot, _ = _setup()
    q, overall = N.cost_net_apply(params, feats, onehot)
    assert q.shape == (4, 3)
    assert overall.shape == ()


def test_table_permutation_invariance():
    params, feats, onehot, assign = _setup()
    q0, c0 = N.cost_net_apply(params, feats, onehot)
    perm = np.random.default_rng(1).permutation(feats.shape[0])
    onehot_p = np.zeros_like(np.asarray(onehot))
    onehot_p[assign[perm], np.arange(len(perm))] = 1.0
    q1, c1 = N.cost_net_apply(params, feats[perm], jnp.asarray(onehot_p))
    np.testing.assert_allclose(q0, q1, rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(c0, c1, rtol=1e-5, atol=1e-5)


def test_device_permutation_invariance_of_overall():
    params, feats, onehot, _ = _setup()
    _, c0 = N.cost_net_apply(params, feats, onehot)
    dperm = np.array([2, 0, 3, 1])
    _, c1 = N.cost_net_apply(params, feats, onehot[dperm])
    np.testing.assert_allclose(c0, c1, rtol=1e-5, atol=1e-5)


def test_generalizes_across_sizes():
    """Same params evaluate any (M, D) -- no shape-bound weights."""
    params = N.cost_net_init(jax.random.PRNGKey(0))
    for m, d in [(5, 2), (30, 8), (100, 16)]:
        rng = np.random.default_rng(m)
        feats = jnp.asarray(rng.random((m, F.NUM_FEATURES)), jnp.float32)
        assign = rng.integers(0, d, m)
        onehot = np.zeros((d, m), np.float32)
        onehot[assign, np.arange(m)] = 1.0
        q, c = N.cost_net_apply(params, feats, jnp.asarray(onehot))
        assert q.shape == (d, 3) and np.isfinite(float(c))


def test_policy_logits_any_device_count():
    params = N.policy_net_init(jax.random.PRNGKey(0))
    for d in (2, 4, 8, 16):
        dev = jnp.zeros((d, N.HIDDEN))
        q = jnp.zeros((d, 3))
        logits = N.policy_logits(params, dev, q)
        assert logits.shape == (d,)


def test_batched_cost_net():
    params, feats, onehot, _ = _setup()
    fb = jnp.stack([feats, feats])
    ob = jnp.stack([onehot, onehot])
    q, c = N.cost_net_apply(params, fb, ob)
    assert q.shape == (2, 4, 3) and c.shape == (2,)


def test_masking_ignores_padded_tables():
    params, feats, onehot, assign = _setup()
    m = feats.shape[0]
    feats_pad = jnp.concatenate([feats, jnp.ones((3, F.NUM_FEATURES))])
    onehot_pad = jnp.concatenate([onehot, jnp.zeros((4, 3))], axis=1)
    tmask = jnp.concatenate([jnp.ones(m), jnp.zeros(3)])
    q0, c0 = N.cost_net_apply(params, feats, onehot)
    q1, c1 = N.cost_net_apply(params, feats_pad, onehot_pad, table_mask=tmask)
    np.testing.assert_allclose(q0, q1, rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(c0, c1, rtol=1e-5, atol=1e-5)


def test_single_table_cost_sorting_finite():
    params = N.cost_net_init(jax.random.PRNGKey(0))
    feats = jnp.asarray(np.random.default_rng(0).random((20, F.NUM_FEATURES)),
                        jnp.float32)
    c = N.predict_single_table_costs(params, feats)
    assert c.shape == (20,) and np.isfinite(np.asarray(c)).all()
