"""Measured-cost profiling subsystem: calibration-table round-trip,
interpolation semantics, the fused multi-table model (v2), MeasuredOracle
protocol/monotonicity, comm model fitting, the calibrate CLI, the
KernelOracle adapter regression, and DreamShard end-to-end on a
MeasuredOracle."""

import os
import subprocess
import sys

import numpy as np
import pytest

from repro.api import CostOracle, KernelOracle, MeasuredOracle
from repro.core.trainer import DreamShard, DreamShardConfig
from repro.data.tasks import sample_tasks, split_pool
from repro.profiling import (CALIBRATION_VERSION, CalibrationTable,
                             CommModel, FusionModel, default_artifact_path,
                             fit_alpha_beta, load_or_none, synthetic_trace)
from repro.profiling.calibrate import main as calibrate_main
from repro.sim.hardware import PAPER_GPU


@pytest.fixture(scope="module")
def synth_table():
    """Deterministic analytic table (no kernels timed, no flakiness)."""
    return CalibrationTable.synthetic(
        dims=(16, 64, 256), rows=(256, 4096), batches=(32, 1024),
        poolings=(2, 8))


@pytest.fixture(scope="module")
def measured_table():
    """A real (tiny) measured table; reuses the CI-cached artifact from
    ``repro.profiling.calibrate --smoke`` when present so the sim-to-real
    tests don't re-measure."""
    cached = load_or_none(default_artifact_path())
    if cached is not None and cached.version == CALIBRATION_VERSION:
        return cached
    return CalibrationTable.measure(
        dims=(16, 64), rows=(128, 1024), batches=(8,), poolings=(2,),
        use_pallas=False, warmup=1, repeats=1)


@pytest.fixture(scope="module")
def tasks20(dlrm_pool):
    _, test_ids = split_pool(dlrm_pool, seed=0)
    return sample_tasks(dlrm_pool, test_ids, 20, 4, 3, seed=5, name="prof")


# ---- calibration table -------------------------------------------------------


def test_table_roundtrip_identical_interpolation(synth_table, tmp_path):
    path = synth_table.save(str(tmp_path / "cal.npz"))
    loaded = CalibrationTable.load(path)
    rng = np.random.default_rng(0)
    dim = rng.uniform(8, 512, 64)
    rows = rng.uniform(64, 1e6, 64)
    pool = rng.uniform(1, 32, 64)
    np.testing.assert_array_equal(
        synth_table.fwd_lookup_ms(dim, rows, 200, pool),
        loaded.fwd_lookup_ms(dim, rows, 200, pool))
    np.testing.assert_array_equal(
        synth_table.bwd_lookup_ms(dim, rows, 200, pool),
        loaded.bwd_lookup_ms(dim, rows, 200, pool))
    np.testing.assert_array_equal(
        synth_table.comm_ms([0.0, 0.5, 4.0]), loaded.comm_ms([0.0, 0.5, 4.0]))
    assert loaded.version == synth_table.version == CALIBRATION_VERSION
    assert loaded.fingerprint == synth_table.fingerprint
    assert loaded.comm.source == synth_table.comm.source


def test_table_rejects_future_version(synth_table, tmp_path):
    synth_table.version = CALIBRATION_VERSION + 1
    try:
        path = synth_table.save(str(tmp_path / "future.npz"))
    finally:
        synth_table.version = CALIBRATION_VERSION
    with pytest.raises(ValueError, match="version"):
        CalibrationTable.load(path)
    assert load_or_none(path) is None            # tolerant loader


def test_load_or_none_survives_corrupt_artifact(synth_table, tmp_path):
    """An interrupted calibration must read as 're-measure', not crash."""
    path = synth_table.save(str(tmp_path / "cal.npz"))
    with open(path, "r+b") as f:
        f.truncate(100)                          # corrupt the zip container
    assert load_or_none(path) is None
    assert load_or_none(str(tmp_path / "missing.npz")) is None


def test_interp_exact_on_grid_and_clamped_off_grid(synth_table):
    t = synth_table
    # exactly on a grid point -> the stored cell
    got = t.fwd_lookup_ms(64, 4096, 1024, 8)
    assert got == pytest.approx(t.fwd_ms[1, 1, 1, 1])
    # beyond the hull -> clamps to the edge cell
    lo = t.fwd_lookup_ms(1, 1, 1, 1)
    hi = t.fwd_lookup_ms(4096, 1e9, 1e9, 1e6)
    assert lo == pytest.approx(t.fwd_ms[0, 0, 0, 0])
    assert hi == pytest.approx(t.fwd_ms[-1, -1, -1, -1])
    # between grid points -> strictly between the bracketing cells
    mid = t.fwd_lookup_ms(128, 4096, 1024, 8)
    a, b = sorted([t.fwd_ms[1, 1, 1, 1], t.fwd_ms[2, 1, 1, 1]])
    assert a <= mid <= b


def test_table_validates_grids():
    with pytest.raises(ValueError, match="strictly"):
        CalibrationTable(dims=[64, 16], rows=[1], batches=[1], poolings=[1],
                         fwd_ms=np.zeros((2, 1, 1, 1)),
                         bwd_ms=np.zeros((2, 1, 1, 1)),
                         comm=CommModel.from_spec(), fingerprint={})
    with pytest.raises(ValueError, match="shape"):
        CalibrationTable(dims=[16, 64], rows=[1], batches=[1], poolings=[1],
                         fwd_ms=np.zeros((1, 1, 1, 1)),
                         bwd_ms=np.zeros((1, 1, 1, 1)),
                         comm=CommModel.from_spec(), fingerprint={})


# ---- fused multi-table model (v2) --------------------------------------------


def test_fusion_fit_recovers_clean_model():
    """On noise-free samples generated by a model inside the search grid,
    the fit reproduces it (c0 is closed-form; coef/cap grid-searched)."""
    true = FusionModel(overhead_ms=0.2, pipeline_coef=0.33962106564175104,
                       pipeline_cap=2.0, source="measured")
    rng = np.random.default_rng(0)
    singles = [rng.uniform(0.3, 5.0, size=k)
               for k in (2, 2, 3, 4, 4, 6, 8, 8)]
    fused = np.array([true.fused_ms(t) for t in singles])
    fit = FusionModel.fit(singles, fused)
    assert fit.fit_mape < 1e-6
    assert fit.overhead_ms == pytest.approx(true.overhead_ms, rel=1e-6)
    assert fit.pipeline_coef == pytest.approx(true.pipeline_coef, rel=1e-6)
    assert fit.pipeline_cap == true.pipeline_cap
    assert fit.additive_mape > fit.fit_mape
    assert fit.n_samples == len(singles)


def test_fusion_additive_identity(dlrm_pool):
    """The additive model is the exact per-table sum -- and drives the
    fast path in device pricing (bitwise the pre-v2 arithmetic)."""
    add = FusionModel.additive()
    assert add.is_additive
    ts = np.array([0.4, 0.1, 2.5])
    assert add.fused_ms(ts) == float(ts.sum())
    assert not FusionModel.from_spec(PAPER_GPU).is_additive


def test_v2_roundtrip_preserves_fusion(synth_table, tmp_path):
    path = synth_table.save(str(tmp_path / "v2.npz"))
    loaded = CalibrationTable.load(path)
    assert loaded.fusion_fwd == synth_table.fusion_fwd
    assert loaded.fusion_bwd == synth_table.fusion_bwd
    assert loaded.fusion_fwd.source == "synthetic"
    for k, v in synth_table.fusion_sweep.items():
        np.testing.assert_array_equal(loaded.fusion_sweep[k], v)


def test_v1_artifact_loads_additive_with_warning(synth_table, tmp_path,
                                                save_v1_calibration):
    path = str(tmp_path / "v1.npz")
    save_v1_calibration(synth_table, path)
    with pytest.warns(UserWarning, match="ADDITIVE"):
        v1 = CalibrationTable.load(path)
    assert v1.version == 1
    assert v1.fusion_fwd.is_additive and v1.fusion_bwd.is_additive
    assert v1.fusion_fwd.source == "v1-fallback"


def test_calibrate_cli_regenerates_v1_artifact(synth_table, tmp_path,
                                               capsys,
                                               save_v1_calibration):
    """An existing artifact that predates schema v2 is re-measured, not
    skipped -- and the refreshed artifact carries a measured fusion fit."""
    out = str(tmp_path / "cal.npz")
    save_v1_calibration(synth_table, out)
    argv = ["--out", out, "--dims", "16", "--rows", "128", "--batches", "8",
            "--poolings", "2", "--repeats", "1", "--fused-ks", "2",
            "--fused-per-k", "1", "--pallas", "off"]
    assert calibrate_main(argv) == 0
    assert "re-measuring" in capsys.readouterr().out
    table = CalibrationTable.load(out)
    assert table.version == CALIBRATION_VERSION
    assert table.fusion_fwd.source == "measured"
    # and a second run with the now-current artifact is a no-op
    assert calibrate_main(argv) == 0
    assert "up to date" in capsys.readouterr().out


def test_fusion_pricing_engaged_on_v2(synth_table, tasks20):
    """A v2 table's fusion model actually changes multi-table pricing:
    fused < additive whenever a device holds >= 2 tables (overhead
    amortization), identical on single-table devices."""
    t = tasks20[0]
    a = np.arange(t.n_tables) % t.n_devices
    fused = MeasuredOracle(synth_table, batch_size=1024).evaluate(
        t.raw_features, a, t.n_devices)
    additive = MeasuredOracle(synth_table, batch_size=1024,
                              fusion=False).evaluate(
        t.raw_features, a, t.n_devices)
    assert (fused.fwd_comp < additive.fwd_comp).all()
    assert fused.overall < additive.overall
    one = np.zeros(1, np.int64)
    f1 = MeasuredOracle(synth_table).evaluate(t.raw_features[:1], one, 1)
    a1 = MeasuredOracle(synth_table, fusion=False).evaluate(
        t.raw_features[:1], one, 1)
    np.testing.assert_array_equal(f1.fwd_comp, a1.fwd_comp)


def test_measure_placement_per_table_pooling(dlrm_pool):
    """pooling=None takes each table's own pooling factor from raw."""
    from repro.profiling import measure_placement
    raw = dlrm_pool[:3].copy()
    raw[:, 2] = [2.0, 5.0, 3.0]                      # F.POOLING
    res = measure_placement(raw, np.zeros(3, np.int64), 1, batch_size=4,
                            pooling=None, max_rows=64, repeats=1)
    assert np.isfinite(res.overall) and res.overall > 0
    assert res.fwd_comp[0] > 0 and res.bwd_comp[0] > 0


# ---- comm model --------------------------------------------------------------


def test_fit_alpha_beta_recovers_clean_model():
    p = np.array([0.5, 1.0, 2.0, 4.0, 8.0])
    alpha, beta = fit_alpha_beta(p, 0.3 + 0.25 * p)
    assert alpha == pytest.approx(0.3, abs=1e-9)
    assert beta == pytest.approx(0.25, abs=1e-9)


def test_synthetic_trace_seeded_and_fit_close_to_spec():
    p = np.array([0.25, 0.5, 1.0, 2.0, 4.0, 8.0])
    t1 = synthetic_trace(p, spec=PAPER_GPU, seed=3)
    t2 = synthetic_trace(p, spec=PAPER_GPU, seed=3)
    np.testing.assert_array_equal(t1, t2)
    alpha, beta = fit_alpha_beta(p, t1)
    assert alpha == pytest.approx(PAPER_GPU.comm_overhead_ms, rel=0.2)
    assert beta == pytest.approx(1.0 / PAPER_GPU.a2a_bw_gbs, rel=0.2)


def test_comm_model_zero_payload_is_free():
    m = CommModel.from_spec(PAPER_GPU)
    out = m.comm_ms([0.0, 1.0])
    assert out[0] == 0.0 and out[1] > m.alpha_ms


def test_measure_collapses_subpad_dims_under_pallas():
    """With the Pallas kernel, dims pad to 128 lanes -- sub-128 dims would
    all time the same compiled shape, so the stored dim axis must be the
    padded, deduplicated one (interpret mode stands in for TPU here)."""
    table = CalibrationTable.measure(
        dims=(16, 64, 128), rows=(64,), batches=(4,), poolings=(2,),
        use_pallas=True, warmup=1, repeats=1,
        comm=CommModel.from_spec(PAPER_GPU))
    np.testing.assert_array_equal(table.dims, [128.0])
    assert table.meta["use_pallas"] is True
    assert (table.fwd_ms > 0).all()


# ---- MeasuredOracle ----------------------------------------------------------


def test_measured_oracle_defaults_to_calibrated_batch(synth_table):
    """Default operating point = the table's largest calibrated batch, so
    compute interpolation and comm payload price the same workload."""
    assert MeasuredOracle(synth_table).batch_size == \
        int(synth_table.batches[-1])
    assert MeasuredOracle(synth_table, batch_size=32).batch_size == 32


def test_measured_oracle_protocol(synth_table, tasks20):
    oracle = MeasuredOracle(synth_table, batch_size=1024)
    assert isinstance(oracle, CostOracle)
    assert oracle.mem_capacity_gb == PAPER_GPU.mem_capacity_gb
    t = tasks20[0]
    a = np.arange(t.n_tables) % t.n_devices
    res = oracle.evaluate(t.raw_features, a, t.n_devices)
    assert oracle.num_evaluations == 1
    assert np.isfinite(res.overall) and res.overall > 0
    assert res.fwd_comp.shape == (t.n_devices,)
    assert (res.fwd_comp > 0).all() and (res.bwd_comp > 0).all()
    assert res.cost_features.shape == (t.n_devices, 3)
    # deterministic: same placement, same measurement
    res2 = MeasuredOracle(synth_table, batch_size=1024).evaluate(
        t.raw_features, a, t.n_devices)
    assert res2.overall == res.overall


def test_measured_oracle_from_path(synth_table, tmp_path):
    path = synth_table.save(str(tmp_path / "cal.npz"))
    oracle = MeasuredOracle(path)
    assert oracle.table.version == synth_table.version


def test_measured_oracle_missing_artifact(tmp_path, monkeypatch):
    monkeypatch.setenv("REPRO_CALIBRATION", str(tmp_path / "nope.npz"))
    with pytest.raises(FileNotFoundError, match="calibrate"):
        MeasuredOracle()


def test_measured_oracle_monotone_in_table_count(synth_table, tasks20):
    oracle = MeasuredOracle(synth_table, batch_size=1024)
    t = tasks20[0]
    a = np.arange(t.n_tables) % t.n_devices
    base = oracle.evaluate(t.raw_features[:-1], a[:-1], t.n_devices)
    more = oracle.evaluate(t.raw_features, a, t.n_devices)
    d = a[-1]                                    # device gaining the table
    assert more.fwd_comp[d] > base.fwd_comp[d]
    assert more.bwd_comp[d] > base.bwd_comp[d]
    assert more.overall >= base.overall


def test_measured_oracle_monotone_in_dim(synth_table, tasks20):
    oracle = MeasuredOracle(synth_table, batch_size=1024)
    t = tasks20[0]
    a = np.arange(t.n_tables) % t.n_devices
    small = oracle.evaluate(t.raw_features, a, t.n_devices)
    wide = t.raw_features.copy()
    wide[:, 0] *= 4.0                            # F.DIM
    big = oracle.evaluate(wide, a, t.n_devices)
    assert (big.fwd_comp >= small.fwd_comp).all()
    assert big.overall > small.overall           # comm payload grows too


def test_measured_oracle_single_device_no_comm(synth_table, tasks20):
    oracle = MeasuredOracle(synth_table, batch_size=1024)
    t = tasks20[0]
    res = oracle.evaluate(t.raw_features, np.zeros(t.n_tables, np.int64), 1)
    assert (res.bwd_comm == 0).all() and (res.fwd_comm == 0).all()
    assert res.overall == pytest.approx(res.fwd_comp[0] + res.bwd_comp[0])


def test_measured_oracle_legal(synth_table, tasks20):
    oracle = MeasuredOracle(synth_table)
    t = tasks20[0]
    assert oracle.legal(t.raw_features,
                        np.arange(t.n_tables) % t.n_devices, t.n_devices)
    assert not oracle.legal(t.raw_features * 1e3,
                            np.zeros(t.n_tables, np.int64), 1)


# ---- KernelOracle adapter ----------------------------------------------------


def test_kernel_adapter_matches_measured_oracle(measured_table, tasks20):
    """The adapter must be a pure delegation: same table, same numbers."""
    t = tasks20[0]
    a = np.arange(t.n_tables) % t.n_devices
    kern = KernelOracle(table=measured_table, batch_size=8)
    meas = MeasuredOracle(measured_table, batch_size=8)
    rk = kern.evaluate(t.raw_features, a, t.n_devices)
    rm = meas.evaluate(t.raw_features, a, t.n_devices)
    np.testing.assert_allclose(rk.fwd_comp, rm.fwd_comp, rtol=1e-12)
    np.testing.assert_allclose(rk.bwd_comp, rm.bwd_comp, rtol=1e-12)
    np.testing.assert_allclose(rk.bwd_comm, rm.bwd_comm, rtol=1e-12)
    assert rk.overall == pytest.approx(rm.overall, rel=1e-12)
    assert kern.num_evaluations == 1


def test_kernel_oracle_lazy_calibration_counts():
    oracle = KernelOracle(batch_size=8, pooling=2, max_rows=128, repeats=1)
    assert oracle.num_evaluations == 0           # nothing measured yet
    assert oracle._measured is None              # calibration is lazy


def test_kernel_oracle_grid_covers_widest_tables():
    """prod-pool dims go to 768: the lazy calibration grid must reach
    them, or interpolation edge-clamps and underprices the widest (most
    expensive) tables."""
    grid = KernelOracle()._calibration_grid()
    assert grid["dims"][-1] >= 768
    pallas_grid = KernelOracle(use_pallas=True)._calibration_grid()
    assert pallas_grid["dims"][-1] >= 768
    assert all(d % 128 == 0 for d in pallas_grid["dims"])
    assert KernelOracle(max_dim=256)._calibration_grid()["dims"][-1] == 256


def test_kernel_oracle_with_table_uses_calibrated_batch(synth_table):
    """A supplied table prices compute and comm at ITS operating point
    unless the caller pins one explicitly (mirrors MeasuredOracle)."""
    assert KernelOracle(table=synth_table).measured().batch_size == \
        int(synth_table.batches[-1])
    assert KernelOracle(table=synth_table,
                        batch_size=32).measured().batch_size == 32


# ---- CLI ---------------------------------------------------------------------


def test_calibrate_cli_smoke(tmp_path):
    out = str(tmp_path / "cli" / "cal.npz")
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src") \
        + os.pathsep + env.get("PYTHONPATH", "")
    cmd = [sys.executable, "-m", "repro.profiling.calibrate", "--smoke",
           "--out", out, "--repeats", "1",
           "--dims", "16,64", "--rows", "128", "--poolings", "2"]
    r = subprocess.run(cmd, capture_output=True, text=True, env=env,
                       timeout=300)
    assert r.returncode == 0, r.stderr
    table = CalibrationTable.load(out)
    assert table.version == CALIBRATION_VERSION
    assert (table.fwd_ms > 0).all() and (table.bwd_ms > 0).all()
    assert table.meta.get("cli") is True
    # second run: artifact matches version/fingerprint/grid -> no-op
    r2 = subprocess.run(cmd, capture_output=True, text=True, env=env,
                        timeout=300)
    assert r2.returncode == 0, r2.stderr
    assert "up to date" in r2.stdout


# ---- trainer end-to-end ------------------------------------------------------


def test_trainer_end_to_end_with_measured_oracle(synth_table, tasks20):
    oracle = MeasuredOracle(synth_table, batch_size=1024)
    agent = DreamShard(tasks20, oracle,
                       DreamShardConfig(n_iterations=2, n_collect=3,
                                        n_cost=4, n_rl=2))
    history = agent.train()
    assert len(history) == 2
    assert oracle.num_evaluations == 6           # n_iterations * n_collect
    assert np.isfinite(history[-1]["cost_loss"])
    t = tasks20[0]
    a = agent.place(t.raw_features, t.n_devices)
    assert a.shape == (t.n_tables,)
    assert oracle.legal(t.raw_features, a, t.n_devices)
    # placements decode hardware-free: no extra oracle evaluations
    assert oracle.num_evaluations == 6


def test_measured_oracle_beats_live_timing_throughput(measured_table,
                                                      tasks20):
    """The acceptance-criterion regression in miniature: interpolation
    must be orders of magnitude faster than one live kernel timing."""
    import time
    from repro.profiling import measure_placement
    t = tasks20[0]
    a = np.arange(t.n_tables) % t.n_devices
    oracle = MeasuredOracle(measured_table, batch_size=8)
    oracle.evaluate(t.raw_features, a, t.n_devices)          # warm numpy
    n = 50
    t0 = time.perf_counter()
    for _ in range(n):
        oracle.evaluate(t.raw_features, a, t.n_devices)
    interp = (time.perf_counter() - t0) / n
    t0 = time.perf_counter()
    measure_placement(t.raw_features, a, t.n_devices, batch_size=8,
                      pooling=2, max_rows=128, repeats=1)
    live = time.perf_counter() - t0
    assert live / interp > 20          # conservative floor for CI jitter
