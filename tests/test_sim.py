"""Simulator invariants: fusion band, comm congestion, determinism,
memory legality (paper App. A.3 phenomena)."""

import numpy as np
import pytest

from repro.core import baselines as B
from repro.core import features as F
from repro.sim.costsim import CostSimulator


def test_fusion_speedup_band(dlrm_pool, sim):
    """Fused multi-table cost vs sum of single-table costs: 1x-3x (Fig 12)."""
    rng = np.random.default_rng(0)
    for _ in range(20):
        sub = dlrm_pool[rng.choice(len(dlrm_pool), 10, replace=False)]
        fused_fwd, _ = sim.fused_op_ms(sub)
        singles = sim.single_table_ms(sub).sum()
        speedup = singles / fused_fwd
        assert 1.0 <= speedup <= 3.2, speedup


def test_comm_monotone_in_imbalance(sim):
    """Table 4: more dim imbalance -> higher max comm time."""
    maxes = []
    for sums in ([256, 256, 256, 256], [192, 256, 320, 256],
                 [128, 128, 384, 384], [64, 64, 64, 832]):
        comm = sim.comm_ms(np.array(sums, float), 4)
        maxes.append(comm.max())
    assert all(a <= b + 1e-9 for a, b in zip(maxes, maxes[1:])), maxes


def test_single_device_no_comm(dlrm_pool, sim):
    res = sim.evaluate(dlrm_pool[:5], np.zeros(5, np.int64), 1)
    assert res.bwd_comm.max() == 0.0
    assert res.overall > 0


def test_measurement_deterministic(dlrm_pool, sim):
    a = np.array([0, 1, 0, 1, 2, 3, 2, 3])
    r1 = sim.evaluate(dlrm_pool[:8], a, 4)
    r2 = CostSimulator(seed=0).evaluate(dlrm_pool[:8], a, 4)
    assert r1.overall == r2.overall
    np.testing.assert_array_equal(r1.cost_features, r2.cost_features)


def test_noise_seed_changes_measurement(dlrm_pool):
    a = np.array([0, 1, 0, 1, 2, 3, 2, 3])
    r1 = CostSimulator(seed=0).evaluate(dlrm_pool[:8], a, 4)
    r2 = CostSimulator(seed=7).evaluate(dlrm_pool[:8], a, 4)
    assert r1.overall != r2.overall


def test_overall_is_sum_of_stage_maxima(dlrm_pool):
    sim = CostSimulator(noise_std=0.0)
    a = np.array([0, 1, 2, 3] * 3)
    r = sim.evaluate(dlrm_pool[:12], a, 4)
    # fwd comm max == bwd comm max without noise
    assert r.overall == pytest.approx(
        r.fwd_comp.max() + 2 * r.bwd_comm.max() + r.bwd_comp.max(), rel=1e-6)


def test_cost_features_shape(dlrm_pool, sim):
    a = np.array([0, 1, 0, 1])
    r = sim.evaluate(dlrm_pool[:4], a, 2)
    assert r.cost_features.shape == (2, 3)
    assert (r.cost_features >= 0).all()


def test_legality(dlrm_pool, sim):
    big = dlrm_pool.copy()
    big[:, F.TABLE_SIZE_GB] = 12.0     # every table exceeds an 11 GB device
    assert not sim.legal(big[:2], np.array([0, 0]), 2)
    assert sim.legal(dlrm_pool[:2], np.array([0, 0]), 2)


def test_cache_hit_rate_bounds(dlrm_pool, prod_pool, sim):
    for pool in (dlrm_pool, prod_pool):
        hit = sim._cache_hit_rate(pool)
        assert (hit >= 0).all() and (hit <= sim.HIT_CAP + 1e-9).all()
        # contention: co-residence never increases hit rates
        shared = sim._cache_hit_rate(pool[:12], shared=True)
        alone = sim._cache_hit_rate(pool[:12], shared=False)
        assert (shared <= alone + 1e-9).all()


def test_expert_placements_legal(dlrm_pool, sim):
    rng = np.random.default_rng(0)
    sub = dlrm_pool[rng.choice(len(dlrm_pool), 40, replace=False)]
    for s in B.EXPERT_STRATEGIES:
        a = B.expert_place(sub, 4, sim.spec.mem_capacity_gb, s)
        assert a.shape == (40,)
        assert set(np.unique(a)) <= set(range(4))
        assert sim.legal(sub, a, 4)
