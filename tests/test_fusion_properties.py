"""Hypothesis property tests for the fused multi-table cost model:
monotone in fusion depth K and in total work, exact at K = 1, and the
v1-artifact additive fallback reproduces the pre-fusion oracle numbers
bitwise."""

import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.api import MeasuredOracle                      # noqa: E402
from repro.profiling.calibration import (CalibrationTable,  # noqa: E402
                                         FusionModel)
from repro.sim.costsim import per_device_sums             # noqa: E402

models = st.builds(
    FusionModel,
    overhead_ms=st.floats(0.0, 0.5),
    pipeline_coef=st.floats(0.0, 3.0),
    pipeline_cap=st.floats(1.0, 6.0),
)
# per-table single-op times (ms); positive, spanning several decades
times = st.lists(st.floats(1e-3, 1e3), min_size=1, max_size=12)


@settings(max_examples=100, deadline=None)
@given(model=models, ts=times, extra=st.floats(1e-3, 1e3))
def test_fused_monotone_in_k(model, ts, extra):
    """Adding a table to a fused op never lowers its cost (a table whose
    marginal clamps to zero adds exactly nothing)."""
    base = model.fused_ms(ts)
    more = model.fused_ms(ts + [extra])
    assert more >= base - 1e-12 * max(1.0, abs(base))


@settings(max_examples=100, deadline=None)
@given(model=models, ts=times, idx=st.integers(0, 11),
       factor=st.floats(1.0, 10.0))
def test_fused_monotone_in_total_work(model, ts, idx, factor):
    """Growing any single table's time never lowers the fused cost."""
    grown = list(ts)
    grown[idx % len(ts)] *= factor
    assert model.fused_ms(grown) >= \
        model.fused_ms(ts) - 1e-12 * max(1.0, model.fused_ms(ts))


@settings(max_examples=100, deadline=None)
@given(model=models, t=st.floats(1e-3, 1e3))
def test_fused_exact_at_k1(model, t):
    """A single-table 'fused' op IS the single-table grid value, bitwise:
    the correction must round-trip K = 1 exactly."""
    assert model.fused_ms([t]) == t


@settings(max_examples=50, deadline=None)
@given(model=models,
       seed=st.integers(0, 2**31 - 1),
       n_tables=st.integers(2, 12),
       n_devices=st.sampled_from([1, 2, 4]),
       p=st.integers(1, 6))
def test_device_ms_matches_scalar_fused(model, seed, n_tables, n_devices, p):
    """The batched (lexsort + segment-sum) pricing agrees with the scalar
    ``fused_ms`` on every (placement, device) group."""
    rng = np.random.default_rng(seed)
    per = rng.uniform(1e-3, 10.0, size=n_tables)
    A = rng.integers(0, n_devices, size=(p, n_tables))
    out = model.device_ms(per, A, n_devices)
    for pi in range(p):
        for d in range(n_devices):
            expect = model.fused_ms(per[A[pi] == d])
            assert out[pi, d] == pytest.approx(expect, rel=1e-12, abs=1e-15)


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), n_devices=st.sampled_from([1, 2, 4]))
def test_v1_fallback_is_bitwise_additive(tmp_path_factory, dlrm_pool,
                                         save_v1_calibration, seed,
                                         n_devices):
    """A v1 artifact (no fused sweep) must price placements exactly as the
    pre-fusion oracle did: the additive per-table segment sum, bit for
    bit, for every placement."""
    table = CalibrationTable.synthetic()
    path = str(tmp_path_factory.mktemp("v1") / "cal.npz")
    save_v1_calibration(table, path)
    with pytest.warns(UserWarning, match="ADDITIVE"):
        v1 = CalibrationTable.load(path)
    assert v1.fusion_fwd.is_additive and v1.fusion_bwd.is_additive

    rng = np.random.default_rng(seed)
    raw = dlrm_pool[:10]
    A = rng.integers(0, n_devices, size=(4, 10))
    oracle = MeasuredOracle(v1)
    per_fwd, per_bwd = oracle.per_table_ms(raw)
    results = oracle.evaluate_many(raw, A, n_devices)
    fwd = per_device_sums(A.astype(np.int64), n_devices, per_fwd)
    bwd = per_device_sums(A.astype(np.int64), n_devices, per_bwd)
    for i, res in enumerate(results):
        np.testing.assert_array_equal(res.fwd_comp, fwd[i])
        np.testing.assert_array_equal(res.bwd_comp, bwd[i])
