"""Column-wise sharding: ShardSpec canonicalization, the K = 1 bitwise
guarantee across every oracle, mixed-K batched pricing, digest/cache key
stability, sharded plans + output combination, and ShardingPlacer
feasibility on tasks no whole-table placer can hold."""

import dataclasses

import numpy as np
import pytest

from repro import telemetry as tele
from repro.api import (CachedOracle, KernelOracle, MeasuredOracle, SimOracle,
                       evaluate_many, evaluate_sharded, legal_batch,
                       legal_sharded, placement_key, placement_keys,
                       sharded_placement_key, sharded_placement_keys)
from repro.core import features as F
from repro.core.baselines import EXPERT_STRATEGIES, expert_place, random_place
from repro.data.tasks import Task
from repro.embedding import sharded as E
from repro.embedding.plan import build_plan
from repro.profiling.calibration import CalibrationTable
from repro.search.placer import SearchConfig, SearchPlacer
from repro.sharding import (ShardSpec, ShardingConfig, ShardingPlacer,
                            project_assignment, refine_sharded,
                            shard_features, shard_sizes_gb)
from repro.sharding.placer import pack_shards


@pytest.fixture(scope="module")
def raw8(dlrm_pool):
    return np.array(dlrm_pool[:8], dtype=np.float64)


@pytest.fixture(scope="module")
def mixed_spec(raw8):
    """K = (1, 3, 1, 2, 1, 1, 2, 1): a genuinely mixed split."""
    return ShardSpec.even(raw8, np.array([1, 3, 1, 2, 1, 1, 2, 1]))


def _oracles(table):
    return [SimOracle(seed=3), CachedOracle(SimOracle(seed=3)),
            MeasuredOracle(table)]


# ---- ShardSpec ----------------------------------------------------------------


def test_trivial_spec_expands_byte_identically(raw8):
    spec = ShardSpec.trivial(raw8)
    assert spec.is_trivial and spec.n_shards == spec.n_tables == 8
    assert shard_features(raw8, spec).tobytes() == raw8.tobytes()


def test_even_split_tiles_columns(raw8, mixed_spec):
    spec = mixed_spec
    dims = raw8[:, F.DIM].astype(np.int64)
    assert spec.n_shards == 12
    np.testing.assert_array_equal(spec.shard_counts,
                                  [1, 3, 1, 2, 1, 1, 2, 1])
    for t in range(8):
        rows = np.flatnonzero(spec.table == t)
        assert spec.col_start[rows[0]] == 0
        assert spec.col_end[rows[-1]] == dims[t]
        np.testing.assert_array_equal(spec.col_start[rows[1:]],
                                      spec.col_end[rows[:-1]])


def test_spec_validation_rejects_bad_tilings(raw8):
    dims = raw8[:, F.DIM].astype(np.int64)
    with pytest.raises(ValueError, match="start at col 0"):
        ShardSpec(table=np.array([0]), col_start=np.array([1]),
                  col_end=np.array([int(dims[0])]), dims=dims[:1])
    with pytest.raises(ValueError, match="end at its dim"):
        ShardSpec(table=np.array([0]), col_start=np.array([0]),
                  col_end=np.array([int(dims[0]) - 1]), dims=dims[:1])
    with pytest.raises(ValueError, match="positive column width"):
        ShardSpec(table=np.array([0, 0]), col_start=np.array([0, 0]),
                  col_end=np.array([int(dims[0]), 0]), dims=dims[:1])
    with pytest.raises(ValueError, match="cover"):
        ShardSpec(table=np.array([0]), col_start=np.array([0]),
                  col_end=np.array([int(dims[0])]), dims=dims[:2])


def test_split_merge_roundtrip(raw8):
    spec = ShardSpec.trivial(raw8)
    split = spec.split(2)
    assert split.shard_counts[2] == 2 and split.n_shards == 9
    back = split.merge(2)
    assert back.to_bytes() == spec.to_bytes()
    # split is clamped at the column count
    tiny = ShardSpec.even(raw8, raw8[:, F.DIM].astype(int))
    assert tiny.split(0).to_bytes() == tiny.to_bytes()


def test_shard_sizes_sum_to_table_sizes(raw8, mixed_spec):
    sizes = shard_sizes_gb(raw8, mixed_spec)
    per_table = np.bincount(mixed_spec.table, weights=sizes, minlength=8)
    np.testing.assert_allclose(per_table, raw8[:, F.TABLE_SIZE_GB],
                               rtol=1e-12)


def test_project_assignment_takes_first_shard(mixed_spec):
    a = np.arange(mixed_spec.n_shards) % 4
    proj = project_assignment(mixed_spec, a)
    np.testing.assert_array_equal(proj, a[mixed_spec.first_shard])
    # batched (P, S) -> (P, M)
    A = np.stack([a, a[::-1].copy()])
    assert project_assignment(mixed_spec, A).shape == (2, 8)


# ---- K = 1 bitwise guarantee --------------------------------------------------


def test_k1_costs_bitwise_across_oracles(raw8):
    spec = ShardSpec.trivial(raw8)
    rng = np.random.default_rng(0)
    A = rng.integers(0, 4, (6, 8))
    table = CalibrationTable.synthetic()
    for oracle in _oracles(table):
        legacy = evaluate_many(oracle, raw8, A, 4)
        sharded = evaluate_sharded(oracle, raw8, spec, A, 4)
        for r_leg, r_sh in zip(legacy, sharded):
            assert r_leg.overall == r_sh.overall        # bitwise, not approx
            np.testing.assert_array_equal(r_leg.fwd_comp, r_sh.fwd_comp)
        np.testing.assert_array_equal(
            legal_batch(oracle, raw8, A, 4),
            legal_sharded(oracle, raw8, spec, A, 4))


def test_k1_bitwise_kernel_oracle(raw8):
    oracle = KernelOracle(batch_size=8, pooling=2, max_rows=256, repeats=1)
    spec = ShardSpec.trivial(raw8)
    a = np.array([0, 1, 0, 1, 1, 0, 1, 0])
    # legal_sharded never triggers lazy calibration
    assert oracle._measured is None
    np.testing.assert_array_equal(
        legal_batch(oracle, raw8, a[None], 2),
        legal_sharded(oracle, raw8, spec, a[None], 2))
    assert oracle._measured is None
    legacy = evaluate_many(oracle, raw8, a[None], 2)
    sharded = evaluate_sharded(oracle, raw8, spec, a[None], 2)
    assert legacy[0].overall == sharded[0].overall


def test_k1_digests_equal_legacy(raw8):
    spec = ShardSpec.trivial(raw8)
    a = np.array([0, 1, 2, 3, 0, 1, 2, 3])
    assert sharded_placement_key(raw8, spec, a, 4) == \
        placement_key(raw8, a, 4)
    A = np.stack([a, a[::-1].copy()])
    assert sharded_placement_keys(raw8, spec, A, 4) == \
        placement_keys(raw8, A, 4)


def test_k1_shares_cache_entries_with_legacy(raw8):
    oracle = CachedOracle(SimOracle(seed=3))
    spec = ShardSpec.trivial(raw8)
    a = np.array([0, 1, 0, 1, 1, 0, 1, 0])
    evaluate_many(oracle, raw8, a[None], 2)
    assert (oracle.hits, oracle.misses) == (0, 1)
    evaluate_sharded(oracle, raw8, spec, a[None], 2)   # same key: pure hit
    assert (oracle.hits, oracle.misses) == (1, 1)


def test_k1_sharded_search_refine_matches_legacy(raw8):
    task = Task.of(raw8, 4)
    cfg = SearchConfig(strategy="lns", budget_ms=None, max_evals=120, seed=5)
    a0 = expert_place(raw8, 4, SimOracle(seed=3).mem_capacity_gb, "size")

    legacy_seed = SearchPlacer(SimOracle(seed=3), config=cfg)._wrap(task, a0)
    legacy = SearchPlacer(SimOracle(seed=3), config=cfg).refine(
        task, legacy_seed)

    spec = ShardSpec.trivial(raw8)
    placer = SearchPlacer(SimOracle(seed=3), config=cfg)
    sharded_seed = placer._wrap(task, a0, sharding=spec)
    sharded = placer.refine(task, sharded_seed)
    # trivial-spec search replays the legacy search bit-for-bit: same
    # digest seeds the rng, same costs rank the same proposals
    np.testing.assert_array_equal(legacy.assignment, sharded.assignment)
    assert legacy.est_cost_ms == sharded.est_cost_ms


# ---- mixed-K pricing ----------------------------------------------------------


def test_mixed_k_batch_matches_loop(raw8, mixed_spec):
    rng = np.random.default_rng(1)
    A = rng.integers(0, 4, (5, mixed_spec.n_shards))
    table = CalibrationTable.synthetic()
    for oracle in _oracles(table):
        batched = evaluate_sharded(oracle, raw8, mixed_spec, A, 4)
        for i in range(A.shape[0]):
            single = evaluate_sharded(oracle, raw8, mixed_spec,
                                      A[i][None], 4)[0]
            assert batched[i].overall == single.overall
        legal = legal_sharded(oracle, raw8, mixed_spec, A, 4)
        sizes = shard_sizes_gb(raw8, mixed_spec)
        for i in range(A.shape[0]):
            per_dev = np.bincount(A[i], weights=sizes, minlength=4)
            assert legal[i] == bool(
                (per_dev <= oracle.mem_capacity_gb).all())


def test_measured_oracle_shard_model_prices_sublinearly(raw8):
    """With a synthetic (overhead > 0) shard model, half a table costs
    MORE than half the whole table but less than all of it: splitting
    one table across two devices halves neither device's time."""
    table = CalibrationTable.synthetic()
    oracle = MeasuredOracle(table)
    raw1 = raw8[:1]
    spec = ShardSpec.even(raw1, 2)
    whole = evaluate_many(oracle, raw1, np.zeros((1, 1), np.int64), 2)[0]
    halves = evaluate_sharded(oracle, raw1, spec,
                              np.array([[0, 1]]), 2)[0]
    t = whole.fwd_comp[0]
    for d in range(2):
        h = halves.fwd_comp[d]
        assert t / 2 < h < t          # overhead floor, below whole


def test_v2_fallback_prices_proportionally(raw8, tmp_path):
    """A pre-sharding artifact loads with a warning and prices partial
    tables with the proportional model (overhead 0, exponent 1)."""
    import json
    table = CalibrationTable.synthetic()
    path = tmp_path / "v2.npz"
    # write the exact v2 format: no "sharding" scalar entry
    scalar = {"comm": table.comm.to_dict(),
              "fusion": {"fwd": table.fusion_fwd.to_dict(),
                         "bwd": table.fusion_bwd.to_dict()},
              "fingerprint": table.fingerprint, "version": 2,
              "meta": table.meta}
    np.savez(path, dims=table.dims, rows=table.rows, batches=table.batches,
             poolings=table.poolings, fwd_ms=table.fwd_ms,
             bwd_ms=table.bwd_ms, scalar_json=np.array(json.dumps(scalar)))
    with pytest.warns(UserWarning, match="proportional|PROPORTIONAL"):
        loaded = CalibrationTable.load(path)
    assert loaded.shard_fwd.is_proportional
    oracle = MeasuredOracle(loaded)
    spec = ShardSpec.even(raw8, 2)
    a = np.zeros(spec.n_shards, np.int64)
    halves = evaluate_sharded(oracle, raw8, spec, a[None], 4)[0]
    whole = evaluate_many(oracle, raw8, np.zeros((1, 8), np.int64), 4)[0]
    # proportional: two co-resident halves fuse like one whole table's
    # worth of columns -- fwd within the fusion model's discount of whole
    assert halves.fwd_comp[0] == pytest.approx(whole.fwd_comp[0], rel=0.35)


# ---- digests ------------------------------------------------------------------


def test_sharded_digest_stability(raw8, mixed_spec):
    a = np.arange(mixed_spec.n_shards) % 4
    k1 = sharded_placement_key(raw8, mixed_spec, a, 4)
    # same spec (fresh object, equal split points) -> same key
    spec2 = ShardSpec.even(raw8, np.array([1, 3, 1, 2, 1, 1, 2, 1]))
    assert sharded_placement_key(raw8, spec2, a, 4) == k1
    # different split points -> different key
    spec3 = ShardSpec.even(raw8, np.array([1, 2, 1, 3, 1, 1, 2, 1]))
    assert sharded_placement_key(raw8, spec3,
                                 np.arange(spec3.n_shards) % 4, 4) != k1
    # different shard assignment -> different key
    a2 = a.copy()
    a2[0] = (a2[0] + 1) % 4
    assert sharded_placement_key(raw8, mixed_spec, a2, 4) != k1


# ---- sharded plans + output combination ---------------------------------------


def test_sharded_plan_layout(raw8, mixed_spec):
    a = np.arange(mixed_spec.n_shards) % 4
    plan = build_plan(raw8, a, 4, sharding=mixed_spec)
    assert plan.is_sharded and plan.n_tables == 8
    assert plan.slot_cols is not None
    rows = raw8[:, F.HASH_SIZE].astype(np.int64)
    order = plan.grouped_index_order()
    # every live slot: owner repeated per shard, column range from spec
    cols = plan.slot_cols.reshape(-1, 2)
    seen = []
    for s in np.flatnonzero(order >= 0):
        t = int(order[s])
        c0, c1 = int(cols[s, 0]), int(cols[s, 1])
        seen.append((t, c0, c1))
        assert 0 <= c0 < c1 <= rows.shape[0] or True   # bounds via spec:
        assert c1 <= int(raw8[t, F.DIM])
    assert sorted(seen) == sorted(
        zip(mixed_spec.table.tolist(), mixed_spec.col_start.tolist(),
            mixed_spec.col_end.tolist()))


def test_combine_shard_outputs_matches_whole_table(raw8, mixed_spec):
    """Column-sharded lookup (arenas filled per shard slice) combines to
    the same per-table embeddings as the whole-table plan."""
    import jax.numpy as jnp
    raw = raw8.copy()
    raw[:, F.HASH_SIZE] = np.clip(raw[:, F.HASH_SIZE], 0, 300)
    rng = np.random.default_rng(2)
    M = 8
    rows = raw[:, F.HASH_SIZE].astype(np.int64)
    dims = raw[:, F.DIM].astype(np.int64)
    weights = [rng.normal(size=(rows[t], dims[t])) for t in range(M)]
    B, P = 4, 5
    idx = np.where(rng.random((B, M, P)) < 0.3, -1,
                   rng.integers(0, 200, (B, M, P))).astype(np.int32)

    def run(plan, spec):
        arenas = np.zeros((plan.n_shards, plan.rows_max, plan.dim))
        items = np.arange(M) if spec is None else np.arange(spec.n_shards)
        for s, g in enumerate(plan.groups):
            for j, i in enumerate(g):
                t = int(plan.slot_table[s, j])
                base = int(plan.base_rows[s, j])
                if spec is None:
                    c0, c1 = 0, dims[t]
                else:
                    c0 = int(spec.col_start[i])
                    c1 = int(spec.col_end[i])
                arenas[s, base:base + rows[t], :c1 - c0] = \
                    weights[t][:, c0:c1]
        gidx = jnp.asarray(E.group_indices(plan, idx))
        grouped = E.lookup_unsharded(jnp.asarray(arenas), plan.base_rows,
                                     gidx, plan)
        return np.asarray(E.combine_shard_outputs(plan, grouped))

    a_tables = np.arange(M) % 4
    plan_w = build_plan(raw, a_tables, 4)
    out_w = run(plan_w, None)

    a_shards = np.arange(mixed_spec.n_shards) % 4
    plan_s = build_plan(raw, a_shards, 4, sharding=mixed_spec)
    out_s = run(plan_s, mixed_spec)

    assert out_w.shape == out_s.shape == (B, M, plan_w.dim)
    np.testing.assert_allclose(out_w, out_s, rtol=1e-6, atol=1e-6)


# ---- packing + ShardingPlacer -------------------------------------------------


@pytest.fixture(scope="module")
def infeasible_task(dlrm_pool):
    """Largest table exceeds one device's HBM: illegal for EVERY
    whole-table placement."""
    raw = np.array(dlrm_pool[:8], dtype=np.float64)
    raw[0, F.TABLE_SIZE_GB] = 2.5 * SimOracle(seed=0).mem_capacity_gb
    return Task.of(raw, 4, name="oversized")


def test_pack_distinct_devices_per_table(raw8, mixed_spec):
    a = pack_shards(raw8, mixed_spec, 4, SimOracle(seed=0).mem_capacity_gb)
    assert a.shape == (mixed_spec.n_shards,) and (a >= 0).all()
    for t in range(8):
        devs = a[mixed_spec.table == t]
        assert len(set(devs.tolist())) == devs.size


def test_whole_table_placers_all_illegal_on_oversized(infeasible_task):
    task = infeasible_task
    oracle = SimOracle(seed=0)
    raw = task.raw_features
    rng = np.random.default_rng(0)
    for s in EXPERT_STRATEGIES:
        a = expert_place(raw, task.n_devices, oracle.mem_capacity_gb, s)
        assert not bool(legal_batch(oracle, raw, a[None], 4)[0])
    a = random_place(raw, task.n_devices, oracle.mem_capacity_gb, rng)
    assert not bool(legal_batch(oracle, raw, a[None], 4)[0])
    # exhaustively: no single-table device choice can fit table 0
    assert float(raw[0, F.TABLE_SIZE_GB]) > oracle.mem_capacity_gb


def test_sharding_placer_makes_oversized_legal(infeasible_task):
    task = infeasible_task
    oracle = SimOracle(seed=0)
    placement = ShardingPlacer(oracle).place(task)
    assert placement.is_sharded
    assert placement.sharding.shard_counts[0] >= 3      # 2.5x capacity
    assert bool(legal_sharded(oracle, task.raw_features, placement.sharding,
                              placement.shard_assignment[None], 4)[0])
    np.testing.assert_array_equal(
        placement.assignment,
        project_assignment(placement.sharding, placement.shard_assignment))
    assert placement.plan.is_sharded
    assert np.isfinite(placement.est_cost_ms)


def test_sharding_placer_passes_through_feasible(raw8):
    """Nothing oversized + legal inner proposal: the inner placement
    comes back with its assignment/plan untouched (K = 1 legacy path)."""
    task = Task.of(raw8, 4)
    oracle = SimOracle(seed=0)
    placer = ShardingPlacer(oracle)
    placement = placer.place(task)
    assert not placement.is_sharded
    assert placement.strategy == "sharding(expert)"
    np.testing.assert_array_equal(
        placement.assignment,
        expert_place(raw8, 4, oracle.mem_capacity_gb, "size"))


def test_sharding_placer_split_hottest(raw8):
    task = Task.of(raw8, 4)
    cfg = ShardingConfig(split_hottest=2)
    placement = ShardingPlacer(SimOracle(seed=0), config=cfg).place(task)
    assert placement.is_sharded
    traffic = raw8[:, F.DIM] * raw8[:, F.POOLING]
    hot = np.argsort(-traffic, kind="stable")[:2]
    assert (placement.sharding.shard_counts[hot] >= 2).all()


def test_refine_sharded_improves_or_keeps(infeasible_task):
    oracle = SimOracle(seed=0)
    seed = ShardingPlacer(oracle).place(infeasible_task)
    cfg = SearchConfig(strategy="lns", budget_ms=None, max_evals=150, seed=7)
    refined = refine_sharded(oracle, infeasible_task, seed, cfg,
                             split_rounds=1)
    assert refined.is_sharded
    assert bool(legal_sharded(
        oracle, infeasible_task.raw_features, refined.sharding,
        refined.shard_assignment[None], 4)[0])
    assert refined.est_cost_ms <= seed.est_cost_ms + 1e-9


def test_sharding_config_rejects_beam_refine():
    with pytest.raises(ValueError, match="beam"):
        ShardingConfig(refine=SearchConfig(strategy="beam"))


def test_beam_refuses_sharded_placement(raw8):
    oracle = SimOracle(seed=0)
    task = Task.of(raw8, 4)
    spec = ShardSpec.even(raw8, 2)
    placer = SearchPlacer(oracle, config=SearchConfig(strategy="lns"))
    seed = placer._wrap(task, np.zeros(spec.n_shards, np.int64),
                        sharding=spec)
    beam_cfg = SearchConfig(strategy="beam")
    beam = SearchPlacer(oracle, config=beam_cfg, agent=object())
    with pytest.raises(ValueError, match="whole-table"):
        beam.refine(task, seed)


def test_measure_placements_groups_sharded(raw8, mixed_spec):
    from repro.api import measure_placements
    oracle = SimOracle(seed=0)
    task = Task.of(raw8, 4)
    placer = SearchPlacer(oracle, config=SearchConfig(strategy="lns"))
    whole = placer._wrap(task, np.arange(8) % 4)
    shard = placer._wrap(task, np.arange(mixed_spec.n_shards) % 4,
                         sharding=mixed_spec)
    costs = measure_placements(oracle, [task, task, task],
                               [whole, shard, whole])
    single_w = evaluate_many(oracle, raw8,
                             (np.arange(8) % 4)[None], 4)[0].overall
    single_s = evaluate_sharded(
        oracle, raw8, mixed_spec,
        (np.arange(mixed_spec.n_shards) % 4)[None], 4)[0].overall
    np.testing.assert_array_equal(costs, [single_w, single_s, single_w])


# ---- telemetry ----------------------------------------------------------------


def test_sharded_telemetry_counters(raw8, mixed_spec, telemetry):
    oracle = CachedOracle(SimOracle(seed=0))
    A = np.stack([np.arange(mixed_spec.n_shards) % 4] * 2)
    evaluate_sharded(oracle, raw8, mixed_spec, A, 4)
    counters = telemetry.snapshot()["counters"]
    assert counters["oracle.cache.batched_calls"] == 1
    assert counters["oracle.cache.misses"] == 1       # duplicate row coalesced
    assert counters["oracle.cache.hits"] == 1
