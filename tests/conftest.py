import os
import sys

# NOTE: no XLA_FLAGS here on purpose -- smoke tests and benches must see the
# single real CPU device.  Multi-device tests spawn subprocesses that set
# --xla_force_host_platform_device_count themselves.
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np
import pytest

from repro.data.synthetic import make_dlrm_pool, make_prod_pool
from repro.sim.costsim import CostSimulator


@pytest.fixture(scope="session")
def dlrm_pool():
    return make_dlrm_pool(seed=0)


@pytest.fixture(scope="session")
def prod_pool():
    return make_prod_pool(seed=1)


@pytest.fixture()
def sim():
    return CostSimulator(seed=0)


@pytest.fixture()
def rng():
    return np.random.default_rng(0)
