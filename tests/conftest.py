import os
import sys

# NOTE: no XLA_FLAGS here on purpose -- smoke tests and benches must see the
# single real CPU device.  Multi-device tests spawn subprocesses that set
# --xla_force_host_platform_device_count themselves.
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np  # noqa: E402
import pytest  # noqa: E402

from repro.data.synthetic import make_dlrm_pool, make_prod_pool  # noqa: E402
from repro.sim.costsim import CostSimulator  # noqa: E402


@pytest.fixture(scope="session")
def dlrm_pool():
    return make_dlrm_pool(seed=0)


@pytest.fixture(scope="session")
def prod_pool():
    return make_prod_pool(seed=1)


@pytest.fixture()
def sim():
    return CostSimulator(seed=0)


@pytest.fixture()
def rng():
    return np.random.default_rng(0)


@pytest.fixture()
def telemetry():
    """Enabled telemetry with clean counters; restores the disabled
    default (and clears again) on teardown, so no counter state leaks
    between tests."""
    from repro import telemetry as tele
    tele.reset()
    tele.enable()
    yield tele
    tele.reset()
    tele.disable()


@pytest.fixture(scope="session")
def save_v1_calibration():
    """Writer for the exact pre-fusion (v1) artifact format, shared by
    the v1-fallback tests (test_profiling, test_fusion_properties)."""
    import json

    def _save(table, path):
        scalar = {"comm": table.comm.to_dict(),
                  "fingerprint": table.fingerprint,
                  "version": 1, "meta": table.meta}
        np.savez(path, dims=table.dims, rows=table.rows,
                 batches=table.batches, poolings=table.poolings,
                 fwd_ms=table.fwd_ms, bwd_ms=table.bwd_ms,
                 scalar_json=np.array(json.dumps(scalar)))
    return _save
