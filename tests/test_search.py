"""Search-augmented placement invariants.

Property suite over the ``repro.search`` subsystem: refined cost <=
seed cost on every task, legality preserved under capacity constraints,
anytime monotonicity (a larger eval budget never worsens the result),
and zero-budget bitwise identity.  Plus dispatch guards (search and the
candidate-scoring placers must talk to the oracle ONLY through
``evaluate_many``) and the session refiner pass.

The property tests run under hypothesis when it is installed; without
it they fall back to a fixed deterministic parameter grid, so the
invariants are exercised either way (the dependency is optional, never
required -- same policy as ``test_fusion_properties``, which skips).
"""

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    HAVE_HYPOTHESIS = False

from repro.api import (CachedOracle, PlacementSession,       # noqa: E402
                       RandomPlacer, SearchConfig, SearchPlacer,
                       SimOracle, make_baseline_placers)
from repro.core import features as F                         # noqa: E402
from repro.core.trainer import (DreamShard,                  # noqa: E402
                                DreamShardConfig)
from repro.data.tasks import Task, sample_tasks, split_pool  # noqa: E402
from repro.search import SearchScorer                        # noqa: E402
from repro.sim.costsim import CostSimulator                  # noqa: E402


def property_test(make_strategies, grid, max_examples=20):
    """``@given`` under hypothesis, else parametrize over ``grid``.

    ``make_strategies`` is a zero-arg callable returning the kwargs for
    ``given`` (lazy so ``st`` is only touched when hypothesis exists);
    ``grid`` is a list of kwargs dicts sharing the same keys.
    """
    def deco(fn):
        if HAVE_HYPOTHESIS:
            return settings(max_examples=max_examples, deadline=None)(
                given(**make_strategies())(fn))
        keys = list(grid[0])
        rows = [tuple(row[k] for k in keys) for row in grid]
        return pytest.mark.parametrize(",".join(keys), rows)(fn)
    return deco


def _oracle():
    return SimOracle(CostSimulator(seed=0))


def _tasks(pool, n_tables, n_devices, n_tasks, seed):
    _, ids = split_pool(pool, seed=0)
    return sample_tasks(pool, ids, n_tables, n_devices, n_tasks, seed=seed)


def _cost(task, assignment):
    """Reference cost from a fresh sim: bitwise-stable, state-free."""
    return CostSimulator(seed=0).evaluate(task.raw_features, assignment,
                                          task.n_devices).overall


@pytest.fixture(scope="module")
def tiny_agent(dlrm_pool):
    """A minimally-trained DreamShard: enough for beam search to have a
    real cost network to score with (quality is irrelevant here)."""
    tasks = _tasks(dlrm_pool, 10, 4, 4, seed=11)
    agent = DreamShard(tasks, CostSimulator(seed=0), DreamShardConfig(
        n_iterations=1, n_collect=4, n_cost=20, n_batch=16, n_rl=2,
        n_episode=4, inference_candidates=4))
    agent.train()
    return agent


# ---- core properties --------------------------------------------------------


@property_test(
    lambda: dict(strategy=st.sampled_from(["lns", "evolution"]),
                 n_tables=st.integers(4, 14),
                 n_devices=st.sampled_from([2, 4]),
                 task_seed=st.integers(0, 50), cfg_seed=st.integers(0, 50)),
    grid=[dict(strategy=s, n_tables=m, n_devices=d, task_seed=ts, cfg_seed=cs)
          for s in ("lns", "evolution")
          for m, d, ts, cs in ((6, 2, 3, 0), (10, 4, 17, 5), (14, 4, 42, 9))],
    max_examples=15)
def test_refined_never_worse_than_seed(dlrm_pool, strategy, n_tables,
                                       n_devices, task_seed, cfg_seed):
    """Refined cost <= seed cost, for every strategy/task/seed combo."""
    task = _tasks(dlrm_pool, n_tables, n_devices, 1, seed=task_seed)[0]
    oracle = _oracle()
    seed_placer = make_baseline_placers(oracle)["size_lookup"]
    sp = SearchPlacer(oracle, seed_placer=seed_placer,
                      config=SearchConfig(strategy=strategy, budget_ms=None,
                                          max_evals=48, seed=cfg_seed))
    refined = sp.place(task)
    seed = seed_placer.place(task)
    assert _cost(task, refined.assignment) <= \
        _cost(task, seed.assignment)


@property_test(
    lambda: dict(strategy=st.sampled_from(["lns", "evolution"]),
                 cfg_seed=st.integers(0, 50)),
    grid=[dict(strategy=s, cfg_seed=cs)
          for s in ("lns", "evolution") for cs in (0, 23)],
    max_examples=10)
def test_legality_preserved_under_tight_capacity(dlrm_pool, strategy,
                                                 cfg_seed):
    """When the seed is memory-legal on a near-full device budget, every
    refinement stays legal -- search never trades feasibility for speed."""
    raw = dlrm_pool[:8].copy()
    raw[:, F.TABLE_SIZE_GB] = 5.0        # 40 GB on 4 x 11 GB: tight
    task = Task.of(raw, 4)
    oracle = _oracle()
    sp = SearchPlacer(oracle,
                      config=SearchConfig(strategy=strategy, budget_ms=None,
                                          max_evals=64, seed=cfg_seed))
    refined = sp.place(task)
    sizes = np.bincount(refined.assignment, weights=raw[:, F.TABLE_SIZE_GB],
                        minlength=4)
    assert (sizes <= oracle.mem_capacity_gb).all()


@property_test(
    lambda: dict(strategy=st.sampled_from(["lns", "evolution"]),
                 task_seed=st.integers(0, 30), cfg_seed=st.integers(0, 30)),
    grid=[dict(strategy=s, task_seed=ts, cfg_seed=cs)
          for s in ("lns", "evolution") for ts, cs in ((2, 0), (19, 7))],
    max_examples=8)
def test_anytime_monotonicity(dlrm_pool, strategy, task_seed, cfg_seed):
    """A larger ``max_evals`` never worsens the refined cost: budgets are
    nested (same rng stream, row-capped whole-round scoring), so the
    bigger budget scores a superset of the smaller one's candidates."""
    task = _tasks(dlrm_pool, 10, 4, 1, seed=task_seed)[0]
    oracle = _oracle()
    costs = []
    for max_evals in (0, 4, 16, 64):
        sp = SearchPlacer(oracle, config=SearchConfig(
            strategy=strategy, budget_ms=None, max_evals=max_evals,
            seed=cfg_seed))
        costs.append(_cost(task, sp.place(task).assignment))
    assert all(b <= a for a, b in zip(costs, costs[1:]))


@property_test(
    lambda: dict(n_tables=st.integers(4, 12), task_seed=st.integers(0, 50),
                 zero=st.sampled_from(["budget_ms", "max_evals"])),
    grid=[dict(n_tables=m, task_seed=ts, zero=z)
          for z in ("budget_ms", "max_evals")
          for m, ts in ((5, 1), (12, 31))],
    max_examples=10)
def test_zero_budget_returns_seed_bitwise(dlrm_pool, n_tables, task_seed,
                                          zero):
    """budget_ms=0 (or max_evals=0) returns the seed placement bitwise:
    same assignment array and plan object, zero oracle evaluations."""
    task = _tasks(dlrm_pool, n_tables, 4, 1, seed=task_seed)[0]
    oracle = _oracle()
    seed_placer = make_baseline_placers(oracle)["size"]
    kw = ({"max_evals": 0, "budget_ms": None} if zero == "max_evals"
          else {"budget_ms": 0.0})
    sp = SearchPlacer(oracle, seed_placer=seed_placer,
                      config=SearchConfig(**kw))
    n0 = oracle.num_evaluations
    refined = sp.place(task)
    seed = seed_placer.place(task)
    np.testing.assert_array_equal(refined.assignment, seed.assignment)
    assert oracle.num_evaluations == n0
    assert refined.strategy == sp.name


def test_refine_is_deterministic(dlrm_pool):
    """Same config seed -> identical refined assignment, run to run."""
    task = _tasks(dlrm_pool, 12, 4, 1, seed=9)[0]
    out = []
    for _ in range(2):
        sp = SearchPlacer(_oracle(), config=SearchConfig(
            strategy="lns+evolution", budget_ms=None, max_evals=96, seed=3))
        out.append(sp.place(task).assignment)
    np.testing.assert_array_equal(out[0], out[1])


def test_single_device_returns_seed(dlrm_pool):
    task = _tasks(dlrm_pool, 6, 1, 1, seed=0)[0]
    oracle = _oracle()
    sp = SearchPlacer(oracle, config=SearchConfig(budget_ms=None,
                                                  max_evals=32))
    assert (sp.place(task).assignment == 0).all()
    assert oracle.num_evaluations == 0


def test_config_validation():
    with pytest.raises(ValueError, match="unknown search strategy"):
        SearchPlacer(_oracle(), config=SearchConfig(strategy="anneal"))
    with pytest.raises(ValueError, match="beam"):
        SearchPlacer(_oracle(), config=SearchConfig(strategy="beam"))


# ---- beam search ------------------------------------------------------------


def test_beam_refines_and_respects_budget(dlrm_pool, tiny_agent):
    """Beam leaves never worsen the seed, and a beam+lns pipeline shares
    one budget across both stages."""
    tasks = _tasks(dlrm_pool, 10, 4, 3, seed=21)
    oracle = _oracle()
    ds = tiny_agent.as_placer()
    for strategy in ("beam", "beam+lns"):
        sp = SearchPlacer(oracle, seed_placer=ds, agent=tiny_agent,
                          config=SearchConfig(strategy=strategy,
                                              budget_ms=None, max_evals=32,
                                              seed=1))
        refined = sp.place_many(tasks)
        seeds = ds.place_many(tasks)
        for t, r, s in zip(tasks, refined, seeds):
            assert _cost(t, r.assignment) <= \
                _cost(t, s.assignment)
            assert sp.last_scorer.evals <= 32


# ---- scorer -----------------------------------------------------------------


def test_scorer_caps_rows_and_dedups(dlrm_pool, rng):
    task = _tasks(dlrm_pool, 8, 4, 1, seed=2)[0]
    scorer = SearchScorer(_oracle(), task, max_evals=5)
    A = rng.integers(0, 4, size=(8, 8))
    kept = scorer.filter_new(A)
    assert scorer.filter_new(kept).shape[0] == 0        # all seen now
    costs, results = scorer.score(A)
    assert np.isfinite(costs[:5]).all() and np.isinf(costs[5:]).all()
    assert results[5] is None
    assert scorer.evals == 5 and scorer.out_of_budget()
    assert scorer.remaining_evals() == 0


# ---- dispatch guards --------------------------------------------------------

# PR 4's _SpyOracle wrapper is gone: the production ``SimOracle`` now
# counts its own dispatches through ``repro.telemetry``, so these guards
# assert against the real instrumented code path instead of a shim.


def _sim_counts(tele):
    """(single evaluate calls, batched evaluate_many calls) so far."""
    return (tele.counter_value("oracle.sim.evaluate_calls"),
            tele.counter_value("oracle.sim.evaluate_many_calls"))


def test_search_never_calls_single_evaluate(dlrm_pool, telemetry):
    """The whole search path is batched: one evaluate_many per scored
    round, zero per-candidate evaluate calls."""
    task = _tasks(dlrm_pool, 10, 4, 1, seed=5)[0]
    sp = SearchPlacer(_oracle(),
                      config=SearchConfig(strategy="lns+evolution",
                                          budget_ms=None, max_evals=128,
                                          seed=0))
    sp.place(task)
    single, batched = _sim_counts(telemetry)
    assert single == 0
    assert 1 <= batched == sp.last_scorer.batches


def test_random_placer_candidates_batched(dlrm_pool, telemetry):
    """RandomPlacer's candidate scoring is one evaluate_many, not a
    per-candidate loop."""
    task = _tasks(dlrm_pool, 10, 4, 1, seed=6)[0]
    p = RandomPlacer(_oracle(), seed=0, n_candidates=8)
    placement = p.place(task)
    single, batched = _sim_counts(telemetry)   # before the reference runs
    assert single == 0 and batched == 1
    assert telemetry.counter_value("oracle.sim.rows") == 8
    assert placement.candidates == 8 and placement.oracle_evals == 8
    # the winner is the measured argmin over the 8 draws
    ref = RandomPlacer(SimOracle(CostSimulator(seed=0)), seed=0)
    draws = [ref.place(task).assignment for _ in range(8)]
    best = min(draws, key=lambda a: _cost(task, a))
    np.testing.assert_array_equal(placement.assignment, best)


def test_portfolio_placer_batched_and_optimal(dlrm_pool, telemetry):
    """PortfolioPlacer scores all member proposals in one batch per task
    and returns the measured-best expert."""
    tasks = _tasks(dlrm_pool, 10, 4, 3, seed=7)
    placers = make_baseline_placers(_oracle(), include_portfolio=True)
    out = placers["expert_best"].place_many(tasks)
    single, batched = _sim_counts(telemetry)   # before the reference runs
    assert single == 0
    assert batched == len(tasks)
    experts = ("size", "dim", "lookup", "size_lookup")
    for t, p in zip(tasks, out):
        best = min(
            (placers[s].place(t).assignment for s in experts),
            key=lambda a: _cost(t, a))
        assert _cost(t, p.assignment) == _cost(t, best)


def test_rnn_training_rewards_batched(dlrm_pool, telemetry):
    """The RNN baseline's per-episode reward loop is gone: one
    evaluate_many per update step."""
    from repro.core.rnn_policy import RNNPlacer, RNNPolicyConfig
    tasks = _tasks(dlrm_pool, 8, 2, 2, seed=8)
    oracle = _oracle()
    rnn = RNNPlacer(tasks, oracle, RNNPolicyConfig(n_updates=3, n_episode=4))
    rnn.train()
    single, batched = _sim_counts(telemetry)
    assert single == 0
    assert batched == 3
    assert telemetry.counter_value("oracle.sim.rows") == 3 * 4
    assert oracle.num_evaluations == 3 * 4


# ---- session integration ----------------------------------------------------


def test_session_refiner_pass(dlrm_pool, tiny_agent):
    """A session with a refiner serves RL+search placements: never worse
    than the raw decode, same task order, refiner provenance."""
    tasks = _tasks(dlrm_pool, 10, 4, 4, seed=13)
    oracle = _oracle()
    refiner = SearchPlacer(oracle, config=SearchConfig(
        strategy="lns", budget_ms=None, max_evals=32, seed=0))
    plain = PlacementSession(tiny_agent).place_many(tasks)
    refined = PlacementSession(tiny_agent, refiner=refiner).place_many(tasks)
    for t, p, r in zip(tasks, plain, refined):
        assert _cost(t, r.assignment) <= _cost(t, p.assignment)
        assert r.strategy == refiner.name


def test_cached_oracle_batch_counters(dlrm_pool, rng):
    """CachedOracle splits out per-evaluate_many hit/miss accounting."""
    raw = dlrm_pool[:8]
    oracle = CachedOracle(CostSimulator(seed=0))
    A = rng.integers(0, 4, size=(6, 8))
    oracle.evaluate_many(raw, A, 4)
    oracle.evaluate_many(raw, A, 4)
    oracle.evaluate(raw, A[0], 4)              # single path: not batched
    assert oracle.batched_calls == 2
    assert oracle.batch_hits == 6 and oracle.batch_misses == 6
    assert oracle.hits == 7                    # includes the single hit
    assert oracle.last_batch == {"rows": 6, "hits": 6, "misses": 0}


def test_search_cache_locality(dlrm_pool):
    """Re-refining the same task with the same seed through a CachedOracle
    is served almost entirely from cache (the b9 hit-rate story)."""
    task = _tasks(dlrm_pool, 10, 4, 1, seed=4)[0]
    oracle = CachedOracle(CostSimulator(seed=0))
    for _ in range(2):
        sp = SearchPlacer(oracle, config=SearchConfig(
            strategy="lns", budget_ms=None, max_evals=64, seed=0))
        sp.place(task)
    batched = oracle.batch_hits + oracle.batch_misses
    assert oracle.batch_hits / batched >= 0.45  # second run all hits
    assert sp.last_scorer.hardware_evals == 0  # no new hardware measurements


# ---- hardware_evals accounting ---------------------------------------------


@pytest.mark.parametrize("strategy", ["lns", "evolution", "lns+evolution"])
def test_hardware_evals_exact_per_strategy(dlrm_pool, strategy):
    """On an uncached oracle every scored row is a hardware measurement,
    so the scorer's ledger must equal the oracle's own counter delta --
    exactly, per strategy.  (Regression: the pre-telemetry scorer
    snapshotted ``num_evaluations`` at construction time, which
    double-counted rows whenever anything else touched the oracle.)"""
    task = _tasks(dlrm_pool, 10, 4, 1, seed=6)[0]
    oracle = _oracle()
    n0 = oracle.num_evaluations
    sp = SearchPlacer(oracle, config=SearchConfig(
        strategy=strategy, budget_ms=None, max_evals=48, seed=0))
    sp.place(task)
    scorer = sp.last_scorer
    assert scorer.hardware_evals == oracle.num_evaluations - n0
    assert scorer.hardware_evals == scorer.evals  # SimOracle: no cache
    assert 0 < scorer.evals <= 48


def test_hardware_evals_ignore_foreign_traffic(dlrm_pool, rng):
    """Traffic from OTHER users of a shared oracle must not be billed to
    the scorer: only deltas across its own score() calls count."""
    task = _tasks(dlrm_pool, 8, 4, 1, seed=7)[0]
    oracle = _oracle()
    scorer = SearchScorer(oracle, task, max_evals=16)
    # foreign traffic after construction, before any score() call
    oracle.evaluate_many(task.raw_features,
                         rng.integers(0, 4, size=(5, 8)), 4)
    A = scorer.filter_new(rng.integers(0, 4, size=(4, 8)))
    scorer.score(A)
    assert scorer.hardware_evals == A.shape[0]  # 5 foreign rows excluded
