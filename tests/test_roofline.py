"""Roofline extraction unit tests: HLO collective parsing + terms."""

import pytest

from repro.launch import roofline as R

HLO_SAMPLE = """
  %ag = bf16[16,4096,5120] all-gather(bf16[16,256,5120] %x), replica_groups={{0,1,2,3,4,5,6,7,8,9,10,11,12,13,14,15}}, dimensions={1}
  %ar = f32[16,256,5120] all-reduce(f32[16,256,5120] %y), replica_groups=[16,16]<=[256] to_apply=%add
  %rs = bf16[16,256,5120] reduce-scatter(bf16[16,4096,5120] %z), replica_groups={{0,1,2,3,4,5,6,7,8,9,10,11,12,13,14,15}}, dimensions={1}
  %a2a = bf16[16,256,128] all-to-all(bf16[16,256,128] %w), replica_groups={{0,1,2,3}}
  %cp = f32[8,128] collective-permute(f32[8,128] %v), source_target_pairs={{0,1}}
"""


def test_collective_parse_kinds():
    wire = R.collective_wire_bytes(HLO_SAMPLE, 16)
    assert wire["all-gather"] > 0
    assert wire["all-reduce"] > 0
    assert wire["reduce-scatter"] > 0
    assert wire["all-to-all"] > 0
    assert wire["collective-permute"] > 0


def test_allgather_wire_formula():
    wire = R.collective_wire_bytes(HLO_SAMPLE, 16)
    full = 16 * 4096 * 5120 * 2
    assert wire["all-gather"] == pytest.approx(full * 15 / 16)


def test_allreduce_uses_iota_groups():
    wire = R.collective_wire_bytes(HLO_SAMPLE, 999)
    size = 16 * 256 * 5120 * 4
    assert wire["all-reduce"] == pytest.approx(2 * size * 15 / 16)


def test_reduce_scatter_scales_by_group():
    wire = R.collective_wire_bytes(HLO_SAMPLE, 16)
    shard = 16 * 256 * 5120 * 2
    assert wire["reduce-scatter"] == pytest.approx(shard * 16 * 15 / 16)


def test_extrapolation():
    assert R.extrapolate(10.0, 12.0, 48) == pytest.approx(10 + 47 * 2)


def test_terms_and_dominant():
    t = R.RooflineTerms(hlo_flops=197e12, hlo_bytes=819e9 * 2,
                        wire_bytes=50e9 * 0.5, wire_by_kind={},
                        model_flops=197e12 * 256 * 0.5, n_devices=256)
    assert t.compute_s == pytest.approx(1.0)
    assert t.memory_s == pytest.approx(2.0)
    assert t.collective_s == pytest.approx(0.5)
    assert t.dominant == "memory"
    assert t.useful_flops_ratio == pytest.approx(0.5)


def test_model_flops_kinds():
    from repro import configs as C
    from repro.configs.shapes import INPUT_SHAPES
    cfg = C.get_full("qwen2.5-14b")
    f_train = R.model_flops(cfg, INPUT_SHAPES["train_4k"])
    f_decode = R.model_flops(cfg, INPUT_SHAPES["decode_32k"])
    assert f_train > f_decode
    # MoE uses active params only
    moe = C.get_full("olmoe-1b-7b")
    assert moe.active_param_count() < moe.param_count()
