"""Telemetry subsystem self-tests.

The contract the rest of the stack leans on: a true no-op disabled
path (shared singleton span, untouched registry, bitwise-identical
oracle results), correct nested-span parenting per thread, lossless
counter increments under thread contention, and sink round-trips
(Chrome trace schema, JSONL, ``trace_to``, the report CLI).
"""

import json
import threading

import numpy as np
import pytest

from repro import telemetry as tele
from repro.telemetry import core
from repro.telemetry.report import main as report_main
from repro.sim.costsim import CostSimulator


# ---- disabled path ----------------------------------------------------------


def test_disabled_span_is_shared_noop_singleton():
    assert not tele.is_enabled()
    sp = tele.span("x", a=1)
    assert sp is tele.NOOP_SPAN
    assert sp is tele.span("y")                 # one object, zero alloc
    with sp as inner:
        assert inner.set(b=2) is inner          # set() is a no-op too


def test_disabled_count_and_gauge_touch_nothing():
    assert not tele.is_enabled()
    tele.count("t10.never", 5)
    tele.gauge("t10.never_g", 1.0)
    snap = tele.snapshot()
    assert snap["enabled"] is False
    assert "t10.never" not in snap["counters"]
    assert "t10.never_g" not in snap["gauges"]
    assert tele.counter_value("t10.never") == 0


def test_noop_path_does_not_change_oracle_results(dlrm_pool, rng):
    """Instrumented code must be bitwise-identical with telemetry off
    and on -- spans observe, they never participate."""
    from repro.api import SimOracle
    raw = dlrm_pool[:8]
    A = rng.integers(0, 4, size=(6, 8))

    def _costs():
        oracle = SimOracle(CostSimulator(seed=0))
        out = [r.overall for r in oracle.evaluate_many(raw, A, 4)]
        out.append(oracle.evaluate(raw, A[0], 4).overall)
        return np.asarray(out)

    assert not tele.is_enabled()
    off = _costs()
    tele.enable()
    try:
        on = _costs()
    finally:
        tele.reset()
        tele.disable()
    np.testing.assert_array_equal(off, on)


# ---- spans and counters -----------------------------------------------------


def test_nested_span_parenting(telemetry):
    with telemetry.span("outer") as outer:
        with telemetry.span("inner") as inner:
            assert inner.parent == outer.id
        with telemetry.span("inner2") as inner2:
            pass
    with telemetry.span("root2") as root2:
        pass
    events = {e[0]: e for e in telemetry.get_tracer().snapshot_events()}
    assert set(events) == {"outer", "inner", "inner2", "root2"}
    # tuple layout: (name, ts_us, dur_us, tid, span_id, parent_id, args)
    assert events["outer"][5] is None
    assert events["inner"][5] == events["outer"][4]
    assert events["inner2"][5] == events["outer"][4]
    assert events["root2"][5] is None
    assert inner2.parent == outer.id and root2.parent is None
    # children are recorded before (inside) their parent, with tighter spans
    assert events["inner"][1] >= events["outer"][1]
    assert events["inner"][2] <= events["outer"][2]


def test_span_set_attrs_and_aggregates(telemetry):
    with telemetry.span("work", phase="a") as sp:
        sp.set(result=42)
    (event,) = telemetry.get_tracer().snapshot_events()
    assert event[6] == {"phase": "a", "result": 42}
    aggs = telemetry.get_tracer().span_aggregates()
    assert aggs["work"]["count"] == 1
    assert aggs["work"]["total_ms"] >= 0
    assert telemetry.snapshot()["spans"]["work"]["count"] == 1


def test_counter_atomicity_under_threads(telemetry):
    n_threads, n_incr = 8, 10_000

    def _worker():
        for _ in range(n_incr):
            telemetry.count("t10.contended")

    threads = [threading.Thread(target=_worker) for _ in range(n_threads)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert telemetry.counter_value("t10.contended") == n_threads * n_incr


def test_spans_from_threads_get_distinct_tids(telemetry):
    def _worker():
        with telemetry.span("threaded"):
            pass

    t = threading.Thread(target=_worker)
    with telemetry.span("mainline"):
        pass
    t.start()
    t.join()
    tids = {e[3] for e in telemetry.get_tracer().snapshot_events()}
    assert len(tids) == 2


def test_event_cap_counts_drops():
    tracer = core.Tracer(max_events=3)
    for i in range(5):
        with core.Span(tracer, f"s{i}", {}):
            pass
    assert len(tracer.snapshot_events()) == 3 and tracer.dropped == 2


def test_registry_survives_disable_then_reset_clears(telemetry):
    telemetry.count("t10.kept", 2)
    telemetry.disable()
    assert telemetry.counter_value("t10.kept") == 2     # export-after-run
    telemetry.reset()
    assert telemetry.counter_value("t10.kept") == 0
    telemetry.enable()                                  # fixture teardown


# ---- sinks ------------------------------------------------------------------


def _record_sample(telemetry):
    with telemetry.span("parent", kind="demo") as sp:
        with telemetry.span("child"):
            pass
        sp.set(rows=3)
    telemetry.count("t10.calls", 3)
    telemetry.gauge("t10.level", 0.5)


def test_chrome_trace_schema(telemetry, tmp_path):
    _record_sample(telemetry)
    path = telemetry.write_chrome_trace(str(tmp_path / "trace.json"))
    with open(path) as f:
        payload = json.load(f)
    events = payload["traceEvents"]
    assert [e["name"] for e in events] == ["parent", "child"]
    for e in events:
        assert e["ph"] == "X" and e["cat"] == "repro"
        assert isinstance(e["ts"], (int, float))
        assert isinstance(e["dur"], (int, float))
        assert "span_id" in e["args"]
    child = next(e for e in events if e["name"] == "child")
    parent = next(e for e in events if e["name"] == "parent")
    assert child["args"]["parent_id"] == parent["args"]["span_id"]
    assert parent["args"]["rows"] == 3
    other = payload["otherData"]
    assert other["counters"]["t10.calls"] == 3
    assert other["gauges"]["t10.level"] == 0.5
    assert other["dropped_events"] == 0


def test_jsonl_roundtrip_and_load_trace(telemetry, tmp_path):
    _record_sample(telemetry)
    jl = telemetry.write_jsonl(str(tmp_path / "trace.jsonl"))
    ch = telemetry.write_chrome_trace(str(tmp_path / "trace.json"))
    parsed = telemetry.read_jsonl(jl)
    assert parsed["meta"]["schema"] == 1
    assert [s["name"] for s in parsed["spans"]] == ["child", "parent"]
    child, parent = parsed["spans"]
    assert child["parent"] == parent["id"]
    assert parsed["counters"] == {"t10.calls": 3}
    assert parsed["gauges"] == {"t10.level": 0.5}
    # load_trace sniffs both formats into the same shape
    for path in (jl, ch):
        trace = telemetry.load_trace(path)
        assert {s["name"] for s in trace["spans"]} == {"parent", "child"}
        assert trace["counters"]["t10.calls"] == 3


def test_trace_to_none_is_transparent():
    assert not tele.is_enabled()
    with tele.trace_to(None) as tracer:
        assert tracer is None and not tele.is_enabled()


def test_trace_to_exports_and_restores_state(tmp_path, capsys):
    assert not tele.is_enabled()
    out = str(tmp_path / "run.jsonl")
    with tele.trace_to(out):
        assert tele.is_enabled()
        with tele.span("body"):
            pass
    assert not tele.is_enabled()                # restored the default
    assert "[telemetry] wrote 1 span(s)" in capsys.readouterr().out
    assert [s["name"] for s in tele.read_jsonl(out)["spans"]] == ["body"]
    tele.reset()


def test_report_cli_smoke(telemetry, tmp_path, capsys):
    _record_sample(telemetry)
    path = telemetry.write_jsonl(str(tmp_path / "trace.jsonl"))
    assert report_main([path, "--top", "5"]) == 0
    out = capsys.readouterr().out
    assert "parent" in out and "t10.calls" in out and "gauges:" in out


def test_summarize_reports_drops(telemetry):
    trace = {"meta": {"dropped_events": 7}, "spans": [], "counters": {},
             "gauges": {}}
    assert "7 span(s) dropped" in telemetry.summarize(trace)


def test_write_without_tracer_raises(tmp_path):
    assert not tele.is_enabled()
    with pytest.raises(RuntimeError, match="not enabled"):
        tele.write_chrome_trace(str(tmp_path / "x.json"))
