"""Batched oracle evaluation: batch-vs-loop bitwise equivalence across all
four oracles, partial cache hits, batched legality, grouped placement
measurement, and the dispatch/oracle-call guard on batched collection."""

import numpy as np
import pytest

from repro.api import (CachedOracle, KernelOracle, MeasuredOracle, SimOracle,
                       ensure_oracle, evaluate_many, evaluate_placer,
                       legal_batch)
from repro.api.placement import measure_placements
from repro.core import features as F
from repro.core.trainer import DreamShard, DreamShardConfig
from repro.data.tasks import Task, sample_tasks, split_pool
from repro.profiling.calibration import CalibrationTable
from repro.sim.costsim import CostSimulator, placement_digests

RESULT_FIELDS = ("fwd_comp", "bwd_comp", "fwd_comm", "bwd_comm")


def _random_batch(rng, n_tables, n_devices, n_placements):
    return rng.integers(0, n_devices, size=(n_placements, n_tables),
                        dtype=np.int64)


def _assert_results_bitwise(batch, loop):
    assert len(batch) == len(loop)
    for b, ref in zip(batch, loop):
        for f in RESULT_FIELDS:
            np.testing.assert_array_equal(getattr(b, f), getattr(ref, f))
        assert b.overall == ref.overall


# ---- CostSimulator core -------------------------------------------------------


def test_sim_batch_bitwise_matches_sequential_loop(dlrm_pool, rng):
    """evaluate_batch == P sequential evaluate calls, bit for bit, noise
    included (each row's noise is seeded from its own placement digest)."""
    raw = dlrm_pool[:20]
    A = _random_batch(rng, 20, 4, 48)
    batch = CostSimulator(seed=0).evaluate_batch(raw, A, 4)
    loop = [CostSimulator(seed=0).evaluate(raw, a, 4) for a in A]
    _assert_results_bitwise(batch, loop)


def test_sim_batch_rows_independent_of_batch_composition(dlrm_pool, rng):
    """A row's measurement must not depend on what else is in the batch."""
    raw = dlrm_pool[:12]
    A = _random_batch(rng, 12, 3, 16)
    full = CostSimulator(seed=0).evaluate_batch(raw, A, 3)
    sub = CostSimulator(seed=0).evaluate_batch(raw, A[5:9], 3)
    _assert_results_bitwise(sub, full[5:9])


def test_sim_batch_duplicate_rows_identical(dlrm_pool):
    raw = dlrm_pool[:8]
    a = np.array([0, 1, 0, 1, 2, 3, 2, 3])
    r1, r2 = CostSimulator(seed=0).evaluate_batch(raw, np.stack([a, a]), 4)
    _assert_results_bitwise([r1], [r2])


def test_sim_batch_counts_all_measurements(dlrm_pool, rng):
    sim = CostSimulator(seed=0)
    sim.evaluate_batch(dlrm_pool[:10], _random_batch(rng, 10, 4, 7), 4)
    assert sim.num_evaluations == 7
    sim.evaluate(dlrm_pool[:10], _random_batch(rng, 10, 4, 1)[0], 4)
    assert sim.num_evaluations == 8


def test_sim_batch_rejects_flat_assignment(dlrm_pool):
    with pytest.raises(ValueError):
        CostSimulator().evaluate_batch(dlrm_pool[:4], np.array([0, 1, 0, 1]),
                                       2)


def test_placement_digests_match_scalar(dlrm_pool, rng):
    from repro.sim.costsim import placement_digest
    raw = dlrm_pool[:9]
    A = _random_batch(rng, 9, 4, 11)
    batched = placement_digests(raw, A, 4)
    scalar = [placement_digest(raw, a, 4) for a in A]
    np.testing.assert_array_equal(batched, scalar)


def test_legal_batch_matches_loop(dlrm_pool, sim, rng):
    big = dlrm_pool[:10].copy()
    big[:2, F.TABLE_SIZE_GB] = 7.0      # co-locating both overflows 11 GB
    A = _random_batch(rng, 10, 2, 40)
    batched = sim.legal_batch(big, A, 2)
    loop = [sim.legal(big, a, 2) for a in A]
    np.testing.assert_array_equal(batched, loop)
    assert batched.any() and not batched.all()   # the case is non-trivial
    # legality is a probe, not a measurement: malformed device ids are
    # reported illegal instead of raising
    bad = A[:2].copy()
    bad[0, 0] = 2
    np.testing.assert_array_equal(sim.legal_batch(big, bad, 2),
                                  [False, batched[1]])


# ---- oracle layer -------------------------------------------------------------


def _oracles(dlrm_pool):
    table = CalibrationTable.synthetic()
    return {
        "sim": SimOracle(CostSimulator(seed=0)),
        "cached": CachedOracle(CostSimulator(seed=0)),
        "measured": MeasuredOracle(table),
        "kernel": KernelOracle(table=table),
    }


@pytest.mark.parametrize("name", ["sim", "cached", "measured", "kernel"])
def test_oracle_evaluate_many_bitwise(dlrm_pool, rng, name):
    """All four oracles: evaluate_many == sequential evaluate loop bitwise
    (fresh oracle per path so cache state cannot mask a mismatch)."""
    raw = dlrm_pool[:14]
    A = _random_batch(rng, 14, 4, 24)
    batch = _oracles(dlrm_pool)[name].evaluate_many(raw, A, 4)
    loop_oracle = _oracles(dlrm_pool)[name]
    loop = [loop_oracle.evaluate(raw, a, 4) for a in A]
    _assert_results_bitwise(batch, loop)


def test_measured_oracle_fusion_batch_bitwise(dlrm_pool, rng):
    """The fused multi-table pricing (v2 calibration) keeps the batch
    guarantee: evaluate_many == sequential evaluate loop bitwise, with
    the fusion model demonstrably engaged (fused != additive)."""
    table = CalibrationTable.synthetic()
    assert not table.fusion_fwd.is_additive
    raw = dlrm_pool[:14]
    A = _random_batch(rng, 14, 4, 24)
    batch = MeasuredOracle(table).evaluate_many(raw, A, 4)
    loop_oracle = MeasuredOracle(table)
    loop = [loop_oracle.evaluate(raw, a, 4) for a in A]
    _assert_results_bitwise(batch, loop)
    additive = MeasuredOracle(table, fusion=False).evaluate_many(raw, A, 4)
    assert all(b.overall != a.overall for b, a in zip(batch, additive))
    # the additive path holds the same batch==loop guarantee
    add_loop_oracle = MeasuredOracle(table, fusion=False)
    _assert_results_bitwise(additive,
                            [add_loop_oracle.evaluate(raw, a, 4) for a in A])


def test_measured_oracle_fusion_rows_independent(dlrm_pool, rng):
    """Under the fusion model a row's rank sort happens within its own
    groups only: results are independent of batch composition."""
    table = CalibrationTable.synthetic()
    raw = dlrm_pool[:12]
    A = _random_batch(rng, 12, 3, 16)
    full = MeasuredOracle(table).evaluate_many(raw, A, 3)
    sub = MeasuredOracle(table).evaluate_many(raw, A[5:9], 3)
    _assert_results_bitwise(sub, full[5:9])


def test_cached_oracle_partial_hits(dlrm_pool, rng):
    """Pre-warmed rows are served from cache; only the misses reach the
    inner oracle (as one sub-batch), and results keep input order."""
    raw = dlrm_pool[:10]
    A = _random_batch(rng, 10, 4, 12)
    oracle = CachedOracle(CostSimulator(seed=0))
    warmed = [oracle.evaluate(raw, A[i], 4) for i in (0, 3, 7)]
    inner_before = oracle.num_evaluations
    results = oracle.evaluate_many(raw, A, 4)
    assert oracle.num_evaluations == inner_before + 9    # only the misses
    assert (oracle.hits, oracle.misses) == (3, 12)
    for i, w in zip((0, 3, 7), warmed):
        assert results[i] is w                           # served from cache
    reference = CostSimulator(seed=0)
    _assert_results_bitwise(results, [reference.evaluate(raw, a, 4)
                                      for a in A])


def test_cached_oracle_duplicates_within_batch(dlrm_pool):
    """A placement repeated inside one batch is measured once -- the later
    occurrences are hits, exactly like a sequential loop."""
    raw = dlrm_pool[:6]
    a1 = np.array([0, 1, 0, 1, 0, 1])
    a2 = np.array([1, 0, 1, 0, 1, 0])
    oracle = CachedOracle(CostSimulator(seed=0))
    results = oracle.evaluate_many(raw, np.stack([a1, a2, a1, a1]), 2)
    assert (oracle.hits, oracle.misses) == (2, 2)
    assert oracle.num_evaluations == 2
    assert results[0] is results[2] is results[3]


def test_evaluate_many_helper_falls_back_to_loop(dlrm_pool, rng):
    """Legacy oracles (pre-evaluate_many) still work through the helper
    and through ensure_oracle."""

    class LegacyOracle:
        def __init__(self):
            self.sim = CostSimulator(seed=0)

        @property
        def mem_capacity_gb(self):
            return self.sim.spec.mem_capacity_gb

        @property
        def num_evaluations(self):
            return self.sim.num_evaluations

        def evaluate(self, raw, assignment, n_devices):
            return self.sim.evaluate(raw, assignment, n_devices)

    raw = dlrm_pool[:8]
    A = _random_batch(rng, 8, 2, 5)
    legacy = LegacyOracle()
    assert ensure_oracle(legacy) is legacy
    results = evaluate_many(legacy, raw, A, 2)
    _assert_results_bitwise(results,
                            [CostSimulator(seed=0).evaluate(raw, a, 2)
                             for a in A])
    ok = legal_batch(legacy, raw, A, 2)          # generic capacity fallback
    np.testing.assert_array_equal(
        ok, [CostSimulator(seed=0).legal(raw, a, 2) for a in A])


# ---- legality edge cases (degraded / extreme meshes) --------------------------

ALL_ORACLES = ["sim", "cached", "measured", "kernel"]


def _capacity_oracles(capacity_gb):
    import dataclasses

    from repro.sim.hardware import PAPER_GPU
    spec = dataclasses.replace(PAPER_GPU, mem_capacity_gb=capacity_gb)
    table = CalibrationTable.synthetic()
    return {
        "sim": SimOracle(CostSimulator(spec=spec, seed=0)),
        "cached": CachedOracle(CostSimulator(spec=spec, seed=0)),
        "measured": MeasuredOracle(table, mem_capacity_gb=capacity_gb),
        "kernel": KernelOracle(spec=spec, table=table),
    }


@pytest.mark.parametrize("name", ALL_ORACLES)
def test_legal_batch_zero_surviving_capacity(dlrm_pool, name):
    """A mesh with no memory at all admits nothing -- reported illegal,
    never raised, on every oracle."""
    oracle = _capacity_oracles(0.0)[name]
    assert oracle.mem_capacity_gb == 0.0
    raw = dlrm_pool[:4]                   # real tables: positive sizes
    A = np.array([[0, 1, 2, 3], [0, 0, 0, 0]])
    assert not legal_batch(oracle, raw, A, 4).any()
    assert not oracle.legal(raw, A[0], 4)


@pytest.mark.parametrize("name", ALL_ORACLES)
def test_legal_batch_single_device_mesh(dlrm_pool, name):
    """D=1: legality degenerates to total-size-fits, and the only legal
    device id is 0."""
    oracle = _oracles(dlrm_pool)[name]
    cap = oracle.mem_capacity_gb
    raw = np.array(dlrm_pool[:3], dtype=np.float64)
    raw[:, F.TABLE_SIZE_GB] = cap / 4.0
    zeros = np.zeros(3, dtype=np.int64)
    assert legal_batch(oracle, raw, zeros[None, :], 1)[0]
    off_mesh = np.array([0, 1, 0])
    assert not legal_batch(oracle, raw, off_mesh[None, :], 1)[0]
    raw[:, F.TABLE_SIZE_GB] = 0.6 * cap   # 1.8x capacity in total
    assert not legal_batch(oracle, raw, zeros[None, :], 1)[0]


@pytest.mark.parametrize("name", ALL_ORACLES)
def test_degraded_wrap_rejects_tables_on_lost_device(dlrm_pool, name):
    """Every oracle wrapped in ``DegradedMeshOracle``: a placement whose
    tables all sit on the lost device fits by memory alone but must be
    illegal on the degraded mesh."""
    from repro.serve import DegradedMeshOracle
    oracle = _oracles(dlrm_pool)[name]
    raw = np.array(dlrm_pool[:4], dtype=np.float64)
    raw[:, F.TABLE_SIZE_GB] = oracle.mem_capacity_gb / 8.0
    degraded = DegradedMeshOracle(oracle,
                                  np.array([True, False, True, True]))
    on_lost = np.full(4, 1, dtype=np.int64)
    survivors = np.full(4, 2, dtype=np.int64)
    assert legal_batch(oracle, raw, on_lost[None, :], 4)[0]
    np.testing.assert_array_equal(
        degraded.legal_batch(raw, np.stack([on_lost, survivors]), 4),
        [False, True])
    assert not degraded.legal(raw, on_lost, 4)


# ---- grouped placement measurement --------------------------------------------


def test_measure_placements_groups_by_task(dlrm_pool):
    """Mixed suites (different table/device counts, repeated tasks) batch
    per distinct task and keep per-task ordering."""
    _, ids = split_pool(dlrm_pool, seed=0)
    tasks = (sample_tasks(dlrm_pool, ids, 8, 2, 2, seed=1)
             + sample_tasks(dlrm_pool, ids, 11, 4, 2, seed=2))
    tasks = tasks + tasks[:2]                    # repeated tasks share a group
    rng = np.random.default_rng(0)
    from types import SimpleNamespace

    from repro.core import baselines as B
    placements = [
        SimpleNamespace(assignment=B.random_place(
            t.raw_features, t.n_devices, 11.0, rng)) for t in tasks]
    oracle = SimOracle(CostSimulator(seed=0))
    costs = measure_placements(oracle, tasks, placements)
    reference = CostSimulator(seed=0)
    expected = [reference.evaluate(t.raw_features, p.assignment, t.n_devices)
                .overall for t, p in zip(tasks, placements)]
    np.testing.assert_array_equal(costs, expected)
    assert oracle.num_evaluations == len(tasks)


def test_evaluate_placer_unchanged_by_batching(dlrm_pool):
    """evaluate_placer through the batched path returns the same mean as
    the sequential reference."""
    from repro.api import RandomPlacer
    _, ids = split_pool(dlrm_pool, seed=0)
    tasks = sample_tasks(dlrm_pool, ids, 10, 4, 4, seed=3)
    mean = evaluate_placer(SimOracle(CostSimulator(seed=0)), tasks,
                           RandomPlacer(CostSimulator(seed=0), seed=1))
    placer = RandomPlacer(CostSimulator(seed=0), seed=1)
    reference = CostSimulator(seed=0)
    expected = float(np.mean(
        [reference.evaluate(t.raw_features, placer.place(t).assignment,
                            t.n_devices).overall for t in tasks]))
    assert mean == pytest.approx(expected, rel=1e-12)


# ---- batched collection guard -------------------------------------------------

# The PR-4 _SpyOracle wrapper is gone: ``SimOracle`` counts its own
# dispatches through ``repro.telemetry``, so the guard below asserts
# against the production instrumentation (``telemetry`` fixture).


def test_fused_collect_survives_forced_illegal_decode(dlrm_pool):
    """On a task too big for its devices, the rollout's no-legal-device
    fallback legitimately produces memory-illegal placements; the fused
    collect must measure them like the per-step loop does, not crash."""
    raw = dlrm_pool[:6].copy()
    raw[:, F.TABLE_SIZE_GB] = 8.0   # 48 GB onto 2x11 GB: always illegal
    tasks = [Task.of(raw, 2)]
    ds = DreamShard(tasks, CostSimulator(seed=0), DreamShardConfig(
        n_iterations=1, n_collect=4, n_cost=2, n_batch=2, n_rl=1))
    ds.collect()
    assert len(ds.buffer) == 4
    for s in ds.buffer:
        assert np.isfinite(s.overall)
        assert s.assignment.max() < 2   # never a padding device


def test_kernel_oracle_legal_is_calibration_free(dlrm_pool):
    """A memory-legality probe on a cold KernelOracle must not trigger
    the lazy kernel calibration sweep."""
    oracle = KernelOracle(batch_size=8, pooling=2, max_rows=256, repeats=1)
    a = np.array([0, 1, 0, 1])
    assert oracle.legal(dlrm_pool[:4], a, 2)
    assert oracle.legal_batch(dlrm_pool[:4], a[None, :], 2).all()
    assert oracle._measured is None     # no sweep ran


def test_fused_collect_batches_oracle_and_dispatches(dlrm_pool, telemetry):
    """The batched collection stage is one decode dispatch plus one ring
    scatter, and the oracle sees at most one batched call per distinct
    task -- never a per-placement loop."""
    _, ids = split_pool(dlrm_pool, seed=0)
    tasks = sample_tasks(dlrm_pool, ids, 10, 4, 4, seed=1)
    oracle = SimOracle(CostSimulator(seed=0))
    ds = DreamShard(tasks, oracle, DreamShardConfig(
        n_iterations=1, n_collect=12, n_cost=4, n_batch=4, n_rl=2))
    d0 = ds.num_dispatches
    ds.collect()
    assert ds.num_dispatches - d0 <= 2          # decode + ring append
    single = telemetry.counter_value("oracle.sim.evaluate_calls")
    batched = telemetry.counter_value("oracle.sim.evaluate_many_calls")
    assert single == 0
    assert 1 <= batched <= len(tasks)
    assert telemetry.counter_value("oracle.sim.rows") == 12
    assert oracle.num_evaluations == 12
    assert len(ds.buffer) == 12
    # a second collect reuses the compiled decode: still O(1) dispatches
    d1 = ds.num_dispatches
    ds.collect()
    assert ds.num_dispatches - d1 <= 2
