"""Data pipeline determinism/seekability + checkpoint round-trips."""

import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import restore_pytree, save_pytree
from repro.data.pipeline import DLRMBatchStream, LMBatchStream, Prefetcher


def test_lm_stream_deterministic_and_seekable():
    s = LMBatchStream(vocab=1000, batch=4, seq=32, seed=7)
    b1 = s.batch_at(13)
    b2 = LMBatchStream(vocab=1000, batch=4, seq=32, seed=7).batch_at(13)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    assert b1["tokens"].shape == (4, 32)
    assert (b1["tokens"] < 1000).all() and (b1["tokens"] >= 0).all()
    # labels are next-token shifted
    assert b1["labels"].shape == (4, 32)


def test_lm_stream_frontend_masks_loss():
    s = LMBatchStream(vocab=100, batch=2, seq=16, n_frontend_tokens=4,
                      d_model=8, seed=0)
    b = s.batch_at(0)
    assert b["tokens"].shape == (2, 12)
    assert b["embeds"].shape == (2, 4, 8)
    assert (b["loss_mask"][:, :4] == 0).all()
    assert (b["loss_mask"][:, 4:] == 1).all()


def test_dlrm_stream_respects_hash_bounds(dlrm_pool):
    s = DLRMBatchStream(dlrm_pool[:6], batch=8, seed=0)
    b = s.batch_at(3)
    assert b["indices"].shape == (8, 6, 16)
    for t in range(6):
        live = b["indices"][:, t][b["indices"][:, t] >= 0]
        assert (live < dlrm_pool[t, 1]).all()


def test_prefetcher_matches_direct():
    s = LMBatchStream(vocab=100, batch=2, seq=8, seed=1)
    p = Prefetcher(s, depth=2)
    try:
        got = [p.next() for _ in range(3)]
    finally:
        p.close()
    for i, b in enumerate(got):
        np.testing.assert_array_equal(b["tokens"], s.batch_at(i)["tokens"])


def test_checkpoint_roundtrip_mixed_dtypes():
    tree = {"a": jnp.ones((3, 4), jnp.bfloat16) * 1.5,
            "b": [jnp.arange(5), {"c": jnp.zeros((2,), jnp.float32)}],
            "step": jnp.asarray(7, jnp.int32)}
    with tempfile.TemporaryDirectory() as d:
        save_pytree(tree, os.path.join(d, "ckpt"))
        out = restore_pytree(jax.tree.map(jnp.zeros_like, tree),
                             os.path.join(d, "ckpt"))
    assert out["a"].dtype == jnp.bfloat16
    np.testing.assert_allclose(np.asarray(out["a"], np.float32), 1.5)
    np.testing.assert_array_equal(out["b"][0], np.arange(5))
    assert int(out["step"]) == 7


def test_checkpoint_model_params_roundtrip():
    from repro import configs as C
    from repro.launch import steps as ST
    cfg = C.get_smoke("qwen2.5-14b").resolve(1)
    model = ST.build_model(cfg, remat=False)
    params = model.init_params(jax.random.PRNGKey(0))
    with tempfile.TemporaryDirectory() as d:
        save_pytree(params, os.path.join(d, "ckpt"))
        out = restore_pytree(jax.tree.map(jnp.zeros_like, params),
                             os.path.join(d, "ckpt"))
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(out)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_dreamshard_agent_checkpoint_roundtrip(dlrm_pool):
    from repro.core.trainer import DreamShard, DreamShardConfig
    from repro.data.tasks import make_benchmark_suite
    from repro.sim.costsim import CostSimulator
    sim = CostSimulator(seed=0)
    train, test = make_benchmark_suite(dlrm_pool, n_tables=10, n_devices=2,
                                       n_tasks=4)
    ds = DreamShard(train, sim, DreamShardConfig(n_iterations=1, n_cost=20,
                                                 n_rl=5))
    ds.train()
    a_before = ds.place(test[0].raw_features, 2)
    with tempfile.TemporaryDirectory() as d:
        ds.save(os.path.join(d, "agent"))
        ds2 = DreamShard(train, sim, ds.cfg)     # fresh (random) networks
        ds2.restore(os.path.join(d, "agent"))
    a_after = ds2.place(test[0].raw_features, 2)
    np.testing.assert_array_equal(a_before, a_after)
