"""Optimizer sanity: convergence on quadratics, schedules, row-wise state."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.optim import (adam, adamw, apply_updates, linear_decay,
                         rowwise_adagrad, sgd)


def _minimize(opt, steps=200):
    params = {"w": jnp.asarray(np.random.default_rng(0).normal(size=(4, 8)),
                               jnp.float32)}
    state = opt.init(params)

    @jax.jit
    def step(p, s):
        g = jax.grad(lambda p: jnp.sum(p["w"] ** 2))(p)
        upd, s = opt.update(g, s, p)
        return apply_updates(p, upd), s

    for _ in range(steps):
        params, state = step(params, state)
    return float(jnp.abs(params["w"]).max())


def test_sgd_converges():
    assert _minimize(sgd(0.1)) < 1e-3


def test_sgd_momentum_converges():
    assert _minimize(sgd(0.05, momentum=0.9)) < 1e-3


def test_adam_converges():
    assert _minimize(adam(0.05)) < 1e-2


def test_adamw_decays_params():
    assert _minimize(adamw(0.05, weight_decay=0.1)) < 1e-2


def test_rowwise_adagrad_converges():
    assert _minimize(rowwise_adagrad(0.5), steps=400) < 0.05


def test_rowwise_state_is_per_row():
    opt = rowwise_adagrad(0.1)
    params = {"table": jnp.ones((10, 16))}
    state = opt.init(params)
    assert state.inner["table"].shape == (10,)


def test_linear_decay_endpoints():
    sched = linear_decay(1.0, 100)
    assert float(sched(jnp.asarray(0))) == 1.0
    assert float(sched(jnp.asarray(100))) == 0.0
    assert abs(float(sched(jnp.asarray(50))) - 0.5) < 1e-6


def test_adam_step_counts():
    opt = adam(1e-3)
    p = {"w": jnp.ones(3)}
    s = opt.init(p)
    g = {"w": jnp.ones(3)}
    _, s = opt.update(g, s, p)
    _, s = opt.update(g, s, p)
    assert int(s.step) == 2
