"""Placement serving: shared digest helpers, the placement cache,
micro-batch admission, and the drift-triggered re-placement loop."""

import hashlib

import numpy as np
import pytest

from repro.api import (PlacementSession, placement_key, placement_keys,
                       task_key)
from repro.api.digest import DIGEST_SIZE
from repro.core import features as F
from repro.core.trainer import DreamShard, DreamShardConfig
from repro.data.tasks import Task, sample_tasks, split_pool
from repro.data.traffic import TrafficConfig, make_trace
from repro.serve import (CacheEntry, DriftTracker, MigrationCostOracle,
                         PlacementCache, PlacementService, ServeConfig,
                         dist_divergence)
from repro.sim.costsim import CostSimulator, placement_bytes


@pytest.fixture(scope="module")
def agent(dlrm_pool):
    train_ids, _ = split_pool(dlrm_pool, seed=0)
    tasks = sample_tasks(dlrm_pool, train_ids, 12, 4, 2, seed=1)
    return DreamShard(tasks, CostSimulator(seed=0),
                      DreamShardConfig(n_iterations=1))


class FakeClock:
    """Deterministic seconds-valued clock for admission tests."""

    def __init__(self):
        self.t = 0.0

    def __call__(self) -> float:
        return self.t

    def advance_ms(self, ms: float) -> None:
        self.t += ms / 1e3


def _dummy_placement(n: int = 4) -> object:
    return object()   # the cache never looks inside its entries


# ---- digest helpers (shared CachedOracle / serving key machinery) ------------

def test_placement_key_matches_legacy_inline(dlrm_pool):
    """The factored helper reproduces the historical CachedOracle key:
    blake2b-128 over the canonical ``placement_bytes`` stream."""
    raw, a = dlrm_pool[:6], np.array([0, 1, 2, 3, 0, 1])
    legacy = hashlib.blake2b(placement_bytes(raw, a, 4),
                             digest_size=DIGEST_SIZE).digest()
    assert placement_key(raw, a, 4) == legacy
    assert len(legacy) == DIGEST_SIZE


def test_placement_keys_bitwise_equals_per_row(dlrm_pool, rng):
    raw = dlrm_pool[:8]
    A = rng.integers(0, 4, size=(7, 8))
    batch = placement_keys(raw, A, 4)
    single = [placement_key(raw, a, 4) for a in A]
    assert batch == single
    assert len(set(batch)) == len({a.tobytes() for a in A})


def test_task_key_distribution_policy(dlrm_pool):
    a = np.array(dlrm_pool[:5], dtype=np.float64)
    drifted = np.array(a)
    drifted[:, F.DIST_START:] = np.roll(a[:, F.DIST_START:], 1, axis=-1)
    # full key separates drifted histograms; structural key unifies them
    assert task_key(a, 4) != task_key(drifted, 4)
    assert (task_key(a, 4, include_distribution=False)
            == task_key(drifted, 4, include_distribution=False))
    # both flavours still key on structure and device count
    structural = np.array(a)
    structural[0, F.DIM] += 1
    for kw in (dict(), dict(include_distribution=False)):
        assert task_key(a, 4, **kw) != task_key(a, 2, **kw)
        assert task_key(a, 4, **kw) != task_key(structural, 4, **kw)


# ---- placement cache ---------------------------------------------------------

def test_placement_cache_lru():
    cache = PlacementCache(max_entries=2)
    k1, k2, k3 = b"k1", b"k2", b"k3"
    for k in (k1, k2):
        assert cache.get(k) is None
        cache.put(k, CacheEntry(_dummy_placement(), np.zeros((4, 17))))
    assert cache.get(k1).requests == 1          # k1 becomes most-recent
    cache.put(k3, CacheEntry(_dummy_placement(), np.zeros((4, 17))))
    assert cache.get(k1) is not None            # survived: k2 was LRU
    assert cache.get(k2) is None                # evicted
    assert (cache.hits, cache.misses, cache.evictions) == (2, 3, 1)
    assert cache.hit_rate == pytest.approx(2 / 5)
    assert len(cache) == 2


# ---- drift primitives --------------------------------------------------------

def test_dist_divergence_is_max_per_table_tv():
    p = np.zeros((3, 17))
    p[:, 0] = 1.0
    q = np.array(p)
    assert dist_divergence(p, q) == 0.0
    q[1, 0], q[1, 1] = 0.8, 0.2                 # table 1 moves 0.2 mass
    assert dist_divergence(p, q) == pytest.approx(0.2)
    q[2, 0], q[2, 5] = 0.0, 1.0                 # table 2 moves everything
    assert dist_divergence(p, q) == pytest.approx(1.0)   # max, not mean
    assert dist_divergence(q, p) == dist_divergence(p, q)


def test_drift_tracker_ewma():
    d0, d1 = np.zeros((2, 17)), np.ones((2, 17)) / 17.0
    pinned = DriftTracker(alpha=0.0)
    pinned.observe(b"k", d0)
    assert np.array_equal(pinned.observe(b"k", d1), d0)   # never moves
    latest = DriftTracker(alpha=1.0)
    latest.observe(b"k", d0)
    assert np.array_equal(latest.observe(b"k", d1), d1)   # tracks last
    ewma = DriftTracker(alpha=0.5)
    assert np.array_equal(ewma.observe(b"k", d0), d0)     # seeded exactly
    np.testing.assert_allclose(ewma.observe(b"k", d1), 0.5 * d1)
    assert ewma.estimate(b"missing") is None


def test_migration_oracle_penalty(dlrm_pool):
    raw = dlrm_pool[:6]
    incumbent = np.array([0, 1, 2, 3, 0, 1])
    inner = CostSimulator(seed=0)
    oracle = MigrationCostOracle.wrap(inner, incumbent, ms_per_gb=100.0)
    # the incumbent pays zero penalty: bitwise-equal to the inner oracle
    base = inner.evaluate(raw, incumbent, 4)
    assert oracle.evaluate(raw, incumbent, 4).overall == base.overall
    # one moved table pays exactly its size x link cost
    moved = np.array(incumbent)
    moved[2] = 0
    expect = (inner.evaluate(raw, moved, 4).overall
              + 100.0 * float(raw[2, F.TABLE_SIZE_GB]))
    assert oracle.evaluate(raw, moved, 4).overall == pytest.approx(expect)
    gb = oracle.migration_gb(raw, np.stack([incumbent, moved]))
    np.testing.assert_allclose(gb, [0.0, raw[2, F.TABLE_SIZE_GB]])
    # legality delegates untouched (the penalty is not a memory cost)
    assert oracle.legal(raw, incumbent, 4)
    assert oracle.mem_capacity_gb == inner.spec.mem_capacity_gb


# ---- micro-batch admission ---------------------------------------------------

def _request(pool, ids, n_devices=4):
    return np.array(pool[ids], dtype=np.float64), n_devices


def test_admission_flushes_on_batch_size(dlrm_pool, agent):
    clock = FakeClock()
    svc = PlacementService(agent, clock=clock, config=ServeConfig(
        max_wait_ms=1e6, max_batch=3))
    done = []
    for i in range(2):
        raw, d = _request(dlrm_pool, range(10 * i, 10 * i + 12))
        done += svc.submit(raw, d, tag=f"r{i}")
    assert done == [] and svc.pending == 2      # below batch, below deadline
    raw, d = _request(dlrm_pool, range(30, 42))
    done = svc.submit(raw, d, tag="r2")
    assert [r.tag for r in done] == ["r0", "r1", "r2"]   # batch-size flush
    assert all(r.source == "decode" for r in done)
    assert svc.pending == 0 and svc.decode_batches == 1
    assert svc.stats()["decoded_tasks"] == 3


def test_admission_flushes_on_wait_deadline(dlrm_pool, agent):
    clock = FakeClock()
    svc = PlacementService(agent, clock=clock, config=ServeConfig(
        max_wait_ms=5.0, max_batch=64))
    raw, d = _request(dlrm_pool, range(12))
    assert svc.submit(raw, d, tag="r0") == []
    clock.advance_ms(4.0)
    assert svc.poll() == []                     # deadline not reached
    clock.advance_ms(2.0)
    done = svc.poll()                           # 6ms > 5ms: due
    assert [r.tag for r in done] == ["r0"]
    assert done[0].queue_wait_ms == pytest.approx(6.0)
    assert done[0].latency_ms >= done[0].queue_wait_ms


def test_admission_coalesces_duplicate_keys(dlrm_pool, agent):
    clock = FakeClock()
    svc = PlacementService(agent, clock=clock, config=ServeConfig(
        max_wait_ms=1e6, max_batch=64))
    raw, d = _request(dlrm_pool, range(12))
    svc.submit(raw, d, tag="a")
    drifted = np.array(raw)
    drifted[:, F.DIST_START:] = np.roll(raw[:, F.DIST_START:], 1, axis=-1)
    svc.submit(drifted, d, tag="b")             # same structural key
    assert svc.pending == 1 and svc.coalesced == 1
    done = svc.flush()
    assert sorted(r.tag for r in done) == ["a", "b"]
    assert svc.decoded_tasks == 1               # ONE decode served both
    p0, p1 = done[0].placement, done[1].placement
    assert p0 is p1


def test_hits_skip_admission_entirely(dlrm_pool, agent):
    clock = FakeClock()
    svc = PlacementService(agent, clock=clock, config=ServeConfig(
        max_wait_ms=1e6, max_batch=1, drift_threshold=None))
    raw, d = _request(dlrm_pool, range(12))
    first = svc.submit(raw, d, tag="cold")
    assert first[0].source == "decode"          # max_batch=1: instant flush
    again = svc.submit(raw, d, tag="warm")
    assert again[0].source == "cache" and again[0].queue_wait_ms == 0.0
    assert again[0].placement is first[0].placement
    assert svc.cache.hits == 1 and svc.pending == 0


# ---- end-to-end serving ------------------------------------------------------

def _serve_trace(svc, trace):
    done = []
    for r in trace:
        done += svc.submit(r.raw_features, r.n_devices, tag=r.job)
    done += svc.flush()
    return done


def test_zero_drift_replay_bitwise_identical(dlrm_pool, agent):
    """A drift-free trace served through the full cache + admission path
    yields exactly ``PlacementSession.place_many`` placements."""
    cfg = TrafficConfig(n_jobs=4, n_tables=12, n_devices=4, n_requests=24,
                        drift=0.0, seed=3)
    trace = make_trace(dlrm_pool, cfg)
    svc = PlacementService(agent, config=ServeConfig(
        max_wait_ms=0.0, max_batch=8, drift_threshold=0.05))
    done = _serve_trace(svc, trace)
    assert len(done) == len(trace)
    assert svc.replace_events == 0              # nothing drifted
    assert svc.bytes_moved_gb == 0.0

    first = {}
    for r in trace:
        first.setdefault(r.job, r)
    jobs = sorted(first)
    reference = PlacementSession(agent).place_many(
        [Task.of(first[j].raw_features, first[j].n_devices) for j in jobs])
    by_job = {r.tag: r.placement for r in done}
    for j, ref in zip(jobs, reference):
        np.testing.assert_array_equal(by_job[j].assignment, ref.assignment)
        assert by_job[j].n_devices == ref.n_devices


def test_drift_triggers_incremental_replacement(dlrm_pool, agent):
    cfg = TrafficConfig(n_jobs=3, n_tables=12, n_devices=4, n_requests=48,
                        drift=1.0, zipf=0.0, seed=5)
    trace = make_trace(dlrm_pool, cfg)
    svc = PlacementService(agent, config=ServeConfig(
        max_wait_ms=0.0, max_batch=8, drift_threshold=0.05,
        ewma_alpha=0.5, replace_max_evals=24))
    done = _serve_trace(svc, trace)
    assert svc.replace_events > 0               # the loop fired
    assert any(r.replaced for r in done if r.source == "cache")
    # a re-placed entry keeps serving from cache (no key churn)
    assert svc.cache.hits > 0 and len(svc.cache) == cfg.n_jobs
    # disabled loop on the same trace: zero replaces, identical hit path
    off = PlacementService(agent, config=ServeConfig(
        max_wait_ms=0.0, max_batch=8, drift_threshold=None))
    _serve_trace(off, trace)
    assert off.replace_events == 0 and off.bytes_moved_gb == 0.0


def test_serve_telemetry_counters(dlrm_pool, agent, telemetry):
    from repro import telemetry as tele
    cfg = TrafficConfig(n_jobs=2, n_tables=12, n_devices=4, n_requests=8,
                        drift=0.0, seed=7)
    svc = PlacementService(agent, config=ServeConfig(
        max_wait_ms=0.0, max_batch=4))
    _serve_trace(svc, make_trace(dlrm_pool, cfg))
    counters = tele.snapshot()["counters"]
    assert counters["serve.requests"] == 8
    assert counters["serve.cache.hits"] == svc.cache.hits > 0
    assert counters["serve.cache.misses"] == svc.cache.misses
    assert counters["serve.flushes"] == svc.decode_batches
    assert counters["serve.decoded"] == svc.decoded_tasks == 2


# ---- opt-in sharded fallback -------------------------------------------------

def test_shard_oversized_off_by_default_serves_decode(dlrm_pool, agent):
    """Legacy healthy-mesh behavior is untouched with the knob off: the
    decode comes back whole-table (and, for an oversized table, memory-
    illegal) -- no sharding happens behind the caller's back."""
    from repro.sim.costsim import assignments_legal
    raw, d = _request(dlrm_pool, range(12))
    raw[0, F.TABLE_SIZE_GB] = 30.0              # > one device's HBM
    svc = PlacementService(agent, clock=FakeClock(), config=ServeConfig(
        max_wait_ms=0.0, max_batch=1))
    out = svc.submit(raw, d, tag="big")
    assert len(out) == 1 and out[0].source == "decode"
    p = out[0].placement
    assert not p.is_sharded
    assert not bool(assignments_legal(raw[:, F.TABLE_SIZE_GB],
                                      p.assignment[None], d,
                                      svc.oracle.mem_capacity_gb)[0])
    assert svc.shard_fallbacks == 0


def test_shard_oversized_serves_sharded_placement(dlrm_pool, agent):
    from repro.api import legal_sharded
    raw, d = _request(dlrm_pool, range(12))
    raw[0, F.TABLE_SIZE_GB] = 30.0
    svc = PlacementService(agent, clock=FakeClock(), config=ServeConfig(
        max_wait_ms=0.0, max_batch=1, shard_oversized=True))
    out = svc.submit(raw, d, tag="big")
    assert len(out) == 1 and out[0].error is None
    assert out[0].source == "fallback" and out[0].degraded == "shard"
    p = out[0].placement
    assert p.is_sharded and p.sharding.shard_counts[0] >= 3
    assert bool(legal_sharded(svc.oracle, raw, p.sharding,
                              p.shard_assignment[None], d)[0])
    assert svc.shard_fallbacks == 1
    # the sharded answer is cached: the repeat is a pure hit
    again = svc.submit(raw, d, tag="big2")
    assert again[0].source == "cache" and again[0].placement is p
