"""RNN-based RL baseline (App. D.2): trains, places legally."""

import numpy as np

from repro.core.rnn_policy import RNNPlacer, RNNPolicyConfig
from repro.data.tasks import make_benchmark_suite
from repro.sim.costsim import CostSimulator


def test_rnn_trains_and_places(dlrm_pool):
    sim = CostSimulator(seed=0)
    train, test = make_benchmark_suite(dlrm_pool, n_tables=10, n_devices=2,
                                       n_tasks=4)
    placer = RNNPlacer(train, sim, RNNPolicyConfig(n_updates=5, n_episode=4))
    placer.train()
    t = test[0]
    a = placer.place(t.raw_features, 2)
    assert a.shape == (10,)
    assert set(np.unique(a)) <= {0, 1}
    assert sim.legal(t.raw_features, a, 2)


def test_rnn_consumes_hardware_budget(dlrm_pool):
    """Unlike DreamShard, every RNN episode costs real measurements."""
    sim = CostSimulator(seed=0)
    train, _ = make_benchmark_suite(dlrm_pool, n_tables=10, n_devices=2,
                                    n_tasks=4)
    placer = RNNPlacer(train, sim, RNNPolicyConfig(n_updates=3, n_episode=4))
    placer.train()
    assert sim.num_evaluations == 3 * 4
