"""Real placement MDP semantics (paper §3.1): sparse reward, legal
actions, measurement accounting."""

import numpy as np

from repro.core.mdp import RealPlacementMDP
from repro.sim.costsim import CostSimulator


def test_episode_semantics(dlrm_pool):
    sim = CostSimulator(seed=0)
    mdp = RealPlacementMDP(dlrm_pool[:8], 2, sim)
    state = mdp.reset()
    per_device, q = state
    assert len(per_device) == 2 and q.shape == (2, 3)
    assert (q == 0).all()                      # nothing placed yet

    total_reward, steps = 0.0, 0
    while not mdp.done:
        legal = mdp.legal_actions()
        assert legal.size >= 1
        (pd, q), r, done = mdp.step(legal[0])
        total_reward += r
        steps += 1
    assert steps == 8
    assert total_reward < 0                    # final reward = -cost
    assert (mdp.assignment >= 0).all()


def test_intermediate_rewards_zero(dlrm_pool):
    sim = CostSimulator(seed=0)
    mdp = RealPlacementMDP(dlrm_pool[:5], 2, sim)
    mdp.reset()
    rewards = []
    while not mdp.done:
        _, r, _ = mdp.step(0)
        rewards.append(r)
    assert all(r == 0 for r in rewards[:-1])
    assert rewards[-1] < 0


def test_mdp_consumes_measurements(dlrm_pool):
    sim = CostSimulator(seed=0)
    before = sim.num_evaluations
    mdp = RealPlacementMDP(dlrm_pool[:5], 2, sim)
    mdp.reset()
    while not mdp.done:
        mdp.step(0)
    # every step measures the partial placement => expensive (why the
    # estimated MDP exists)
    assert sim.num_evaluations - before >= 5


def test_custom_order(dlrm_pool):
    sim = CostSimulator(seed=0)
    order = np.array([4, 3, 2, 1, 0])
    mdp = RealPlacementMDP(dlrm_pool[:5], 2, sim, order=order)
    mdp.reset()
    mdp.step(1)
    assert mdp.assignment[4] == 1 and mdp.assignment[0] == -1
