"""Unified placement API: Placer adapters vs legacy call paths, oracle
caching, batched PlacementSession parity, and PlacementPlan edge cases."""

import numpy as np
import pytest

from repro.api import (CachedOracle, CostOracle, DreamShardPlacer,
                       ExpertPlacer, KernelOracle, Placement, PlacementSession,
                       Placer, RNNPlacerAdapter, RandomPlacer, SimOracle,
                       ensure_oracle, make_baseline_placers)
from repro.core import baselines as B
from repro.core.trainer import DreamShard, DreamShardConfig
from repro.data.tasks import sample_tasks, split_pool
from repro.embedding.plan import build_plan
from repro.sim.costsim import CostSimulator, placement_digest


@pytest.fixture(scope="module")
def suite(dlrm_pool):
    """Small heterogeneous suite (two table counts) + an untrained agent."""
    _, test_ids = split_pool(dlrm_pool, seed=0)
    tasks = (sample_tasks(dlrm_pool, test_ids, 8, 2, 2, seed=1, name="s8")
             + sample_tasks(dlrm_pool, test_ids, 11, 2, 2, seed=2, name="s11"))
    sim = CostSimulator(seed=0)
    agent = DreamShard(tasks, sim, DreamShardConfig(n_iterations=1))
    return tasks, sim, agent


# ---- oracles -----------------------------------------------------------------

def test_ensure_oracle_wraps_simulator(sim):
    oracle = ensure_oracle(sim)
    assert isinstance(oracle, SimOracle)
    assert oracle.mem_capacity_gb == sim.spec.mem_capacity_gb
    assert ensure_oracle(oracle) is oracle          # idempotent
    with pytest.raises(TypeError):
        ensure_oracle(object())


def test_sim_oracle_counts_evaluations(dlrm_pool, sim):
    oracle = SimOracle(sim)
    a = np.array([0, 1, 0, 1])
    before = oracle.num_evaluations
    oracle.evaluate(dlrm_pool[:4], a, 2)
    assert oracle.num_evaluations == before + 1 == sim.num_evaluations


def test_placement_digest_deterministic(dlrm_pool):
    a = np.array([0, 1, 0, 1, 2, 2])
    d1 = placement_digest(dlrm_pool[:6], a, 4)
    d2 = placement_digest(dlrm_pool[:6].copy(), a.copy(), 4)
    assert d1 == d2
    assert d1 != placement_digest(dlrm_pool[:6], a, 2)          # device count
    assert d1 != placement_digest(dlrm_pool[1:7], a, 4)         # raw features
    a2 = a.copy()
    a2[0] = 1
    assert d1 != placement_digest(dlrm_pool[:6], a2, 4)         # assignment


def test_sim_noise_keyed_on_digest(dlrm_pool):
    """Same placement -> identical measurement across simulator instances."""
    a = np.array([0, 1, 0, 1])
    r1 = CostSimulator(seed=3).evaluate(dlrm_pool[:4], a, 2)
    r2 = CostSimulator(seed=3).evaluate(dlrm_pool[:4], a, 2)
    assert r1.overall == r2.overall


def test_cached_oracle_hit_miss_counting(dlrm_pool, sim):
    oracle = CachedOracle(sim)
    a = np.array([0, 1, 0, 1])
    r1 = oracle.evaluate(dlrm_pool[:4], a, 2)
    r2 = oracle.evaluate(dlrm_pool[:4], a, 2)
    assert (oracle.hits, oracle.misses) == (1, 1)
    assert r1.overall == r2.overall
    assert oracle.num_evaluations == 1              # hits consume no budget
    oracle.evaluate(dlrm_pool[:4], np.array([1, 0, 1, 0]), 2)   # new placement
    oracle.evaluate(dlrm_pool[2:6], a, 2)                       # new tables
    assert (oracle.hits, oracle.misses) == (1, 3)
    assert oracle.num_evaluations == 3


def test_cached_oracle_lru_eviction_and_counters(dlrm_pool, sim, telemetry):
    from repro import telemetry as tele
    oracle = CachedOracle(sim, max_entries=2)
    a1, a2, a3 = (np.array(x) for x in
                  ([0, 1, 0, 1], [1, 0, 1, 0], [0, 0, 1, 1]))
    oracle.evaluate(dlrm_pool[:4], a1, 2)
    oracle.evaluate(dlrm_pool[:4], a2, 2)
    oracle.evaluate(dlrm_pool[:4], a1, 2)       # hit: a1 becomes most-recent
    oracle.evaluate(dlrm_pool[:4], a3, 2)       # full: evicts a2, NOT a1
    oracle.evaluate(dlrm_pool[:4], a1, 2)       # still cached (LRU, not FIFO)
    assert oracle.num_evaluations == 3
    oracle.evaluate(dlrm_pool[:4], a2, 2)       # evicted -> re-measured
    assert oracle.num_evaluations == 4
    assert (oracle.hits, oracle.misses) == (2, 4)
    assert oracle.evictions == 2
    # the same accounting streams through process-wide telemetry
    counters = tele.snapshot()["counters"]
    assert counters["oracle.cache.hits"] == 2
    assert counters["oracle.cache.misses"] == 4


def test_cached_oracle_info_is_removed(sim):
    """The deprecated ``info()`` shim is gone: the supported surfaces
    are the instance counters + ``telemetry.snapshot()``, and the error
    says so."""
    with pytest.raises(AttributeError, match=r"telemetry\.snapshot"):
        CachedOracle(sim).info()
    with pytest.raises(AttributeError, match="no attribute"):
        CachedOracle(sim).nonexistent_attr


def test_costsim_comm_ms_alias_is_removed():
    """The private ``_comm_ms`` alias is gone; the error points at the
    public ``comm_ms`` name."""
    from repro.sim.costsim import CostSimulator
    with pytest.raises(AttributeError, match="comm_ms"):
        CostSimulator()._comm_ms


def test_kernel_oracle_smoke(dlrm_pool):
    oracle = KernelOracle(batch_size=8, pooling=2, max_rows=256, repeats=1)
    assert isinstance(oracle, CostOracle)
    res = oracle.evaluate(dlrm_pool[:4], np.array([0, 1, 0, 1]), 2)
    assert oracle.num_evaluations == 1
    assert np.isfinite(res.overall) and res.overall > 0
    assert res.fwd_comp.shape == (2,) and (res.fwd_comp > 0).all()
    assert res.cost_features.shape == (2, 3)


# ---- placer adapters vs legacy call paths ------------------------------------

def test_expert_placer_matches_legacy(suite):
    tasks, sim, _ = suite
    for s in B.EXPERT_STRATEGIES:
        placer = ExpertPlacer(sim, s)
        for t in tasks:
            legacy = B.expert_place(t.raw_features, t.n_devices,
                                    sim.spec.mem_capacity_gb, s)
            p = placer.place(t)
            np.testing.assert_array_equal(p.assignment, legacy)
            assert p.strategy == s and p.oracle_evals == 0


def test_random_placer_matches_legacy(suite):
    tasks, sim, _ = suite
    placer = RandomPlacer(sim, seed=42)
    rng = np.random.default_rng(42)
    for t in tasks:           # shared stream, like the legacy helper
        legacy = B.random_place(t.raw_features, t.n_devices,
                                sim.spec.mem_capacity_gb, rng)
        np.testing.assert_array_equal(placer.place(t).assignment, legacy)


def test_dreamshard_placer_matches_legacy(suite):
    tasks, _, agent = suite
    placer = agent.as_placer()
    assert isinstance(placer, Placer)
    t = tasks[0]
    p = placer.place(t)
    np.testing.assert_array_equal(
        p.assignment, agent.place(t.raw_features, t.n_devices))
    assert p.strategy == "dreamshard"
    assert p.candidates == agent.cfg.inference_candidates
    assert p.est_cost_ms is not None and np.isfinite(p.est_cost_ms)


def test_rnn_placer_adapter_matches_legacy(suite):
    from repro.core.rnn_policy import RNNPlacer, RNNPolicyConfig
    tasks, sim, _ = suite
    rnn = RNNPlacer(tasks, sim, RNNPolicyConfig(n_updates=1))  # untrained
    adapter = rnn.as_placer()
    assert isinstance(adapter, RNNPlacerAdapter)
    t = tasks[0]
    np.testing.assert_array_equal(
        adapter.place(t).assignment, rnn.place(t.raw_features, t.n_devices))


def test_placement_carries_plan(suite):
    tasks, sim, _ = suite
    p = ExpertPlacer(sim, "size").place(tasks[0])
    assert isinstance(p, Placement)
    np.testing.assert_array_equal(p.plan.assignment, p.assignment)
    assert p.plan.n_shards == tasks[0].n_devices
    assert p.n_tables == tasks[0].n_tables


def test_make_baseline_placers_all_legal(suite):
    tasks, sim, _ = suite
    placers = make_baseline_placers(sim, seed=0)
    assert set(placers) == {"random", *B.EXPERT_STRATEGIES}
    for placer in placers.values():
        for p, t in zip(placer.place_many(tasks), tasks):
            assert sim.legal(t.raw_features, p.assignment, t.n_devices)


# ---- batched PlacementSession ------------------------------------------------

def test_session_matches_per_task_place(suite):
    """Bucketed, padded, vmapped decoding == per-task Algorithm 2."""
    tasks, _, agent = suite
    session = PlacementSession(agent, bucket_tables=8)
    placements = session.place_many(tasks)
    for t, p in zip(tasks, placements):
        np.testing.assert_array_equal(
            p.assignment, agent.place(t.raw_features, t.n_devices))
        assert p.assignment.shape == (t.n_tables,)


def test_session_compiles_once_per_bucket(suite):
    tasks, _, agent = suite
    session = PlacementSession(agent, bucket_tables=8)
    # table counts 8 and 11 pad to different 8-multiples -> 2 buckets
    assert {session.bucket_key(t) for t in tasks} == {(8, 2), (16, 2)}
    session.place_many(tasks)
    assert session.num_compiles == 2
    session.place_many(tasks)                     # warm: no new traces
    assert session.num_compiles == 2
    assert session.num_decode_calls == 4


def test_session_no_retrace_across_batch_sizes(suite):
    """Batch dim pads to a power of two: 1-task and 2-task calls into the
    same bucket share one trace; a 3rd distinct (bucket, b_pad) traces."""
    tasks, _, agent = suite
    same_bucket = [t for t in tasks if t.n_tables == 8]
    session = PlacementSession(agent, bucket_tables=8)
    p1 = session.place(same_bucket[0])                 # b_pad = 1
    assert session.num_compiles == 1
    p1b = session.place(same_bucket[1])                # same shapes
    assert session.num_compiles == 1
    both = session.place_many(same_bucket)             # b_pad = 2: new trace
    assert session.num_compiles == 2
    np.testing.assert_array_equal(p1.assignment, both[0].assignment)
    np.testing.assert_array_equal(p1b.assignment, both[1].assignment)


def test_session_bucket_reuse_across_interleaved_batches(
        suite, dlrm_pool, telemetry):
    """Interleaved ``place_many`` calls over mixed (M, D) shapes reuse
    per-bucket traces: one compile per distinct (M_pad, D, b_pad)
    regardless of call order, observable via ``session.bucket_compiles``."""
    from repro import telemetry as tele
    _, _, agent = suite
    _, test_ids = split_pool(dlrm_pool, seed=0)
    t8a = sample_tasks(dlrm_pool, test_ids, 8, 2, 2, seed=11)
    t8b = sample_tasks(dlrm_pool, test_ids, 8, 2, 2, seed=12)
    t11 = sample_tasks(dlrm_pool, test_ids, 11, 2, 2, seed=13)
    t8d4 = sample_tasks(dlrm_pool, test_ids, 8, 4, 2, seed=14)
    session = PlacementSession(agent, bucket_tables=8)
    session.place_many(t8a + t11)         # cold: (8, 2) and (16, 2) buckets
    assert session.num_compiles == 2
    assert tele.counter_value("session.bucket_compiles") == 2
    session.place_many(t11 + t8b)         # interleaved revisit: no retrace
    assert session.num_compiles == 2
    session.place_many(t8d4)              # new D -> exactly one new trace
    assert session.num_compiles == 3
    session.place_many(t8b + t8d4 + t11)  # all-warm mixed batch: no retrace
    assert session.num_compiles == 3
    assert tele.counter_value("session.bucket_compiles") == 3


def test_session_estimates_match_per_task(suite):
    tasks, _, agent = suite
    session = PlacementSession(agent)
    p = session.place(tasks[0])
    _, est = agent.place_detailed(tasks[0].raw_features,
                                  tasks[0].n_devices)
    assert p.est_cost_ms == pytest.approx(est, rel=1e-5)


def test_dreamshard_placer_place_many_uses_session(suite):
    tasks, _, agent = suite
    placer = DreamShardPlacer(agent)
    placements = placer.place_many(tasks)
    assert placer.session.num_decode_calls >= 1
    assert len(placements) == len(tasks)


# ---- PlacementPlan edge cases ------------------------------------------------

def test_plan_empty_shard(dlrm_pool):
    """A device with no tables still gets a (padded) group."""
    raw = dlrm_pool[:5]
    assignment = np.array([0, 0, 2, 2, 2])        # shard 1 empty
    plan = build_plan(raw, assignment, 3)
    assert len(plan.groups[1]) == 0
    assert (plan.slot_table[1] == -1).all()
    assert (plan.base_rows[1] == 0).all()         # pad slots hit the zero row
    order = plan.grouped_index_order()
    assert order.shape == (3 * plan.k_max,)
    live = order[order >= 0]
    assert sorted(live.tolist()) == list(range(5))   # every table exactly once


def test_plan_pad_slots_in_grouped_order(dlrm_pool):
    raw = dlrm_pool[:7]
    assignment = np.array([0, 1, 0, 1, 0, 1, 0])  # 4 vs 3 tables
    plan = build_plan(raw, assignment, 2)
    assert plan.k_max == 4
    order = plan.grouped_index_order()
    assert (order == -1).sum() == 1               # one pad slot on shard 1
    assert order[plan.k_max + 3] == -1            # trailing slot of shard 1
    live = order[order >= 0]
    assert sorted(live.tolist()) == list(range(7))


def test_plan_single_shard_roundtrip(dlrm_pool):
    raw = dlrm_pool[:4]
    plan = build_plan(raw, np.zeros(4, np.int64), 1)
    assert plan.k_max == 4 and plan.n_shards == 1
    assert plan.rows_max == 1 + int(plan.table_rows.sum())


# ---- trainer integration -----------------------------------------------------

def test_trainer_accepts_oracle_and_sim(suite):
    tasks, _, _ = suite
    sim = CostSimulator(seed=0)
    via_sim = DreamShard(tasks, sim, DreamShardConfig(n_iterations=1))
    via_oracle = DreamShard(tasks, SimOracle(CostSimulator(seed=0)),
                            DreamShardConfig(n_iterations=1))
    assert via_sim.oracle.mem_capacity_gb == via_oracle.oracle.mem_capacity_gb
    assert via_sim.sim is sim                      # legacy alias


def test_restore_rebuilds_cached_placer(suite, tmp_path):
    """restore() must drop the cached PlacementSession: its candidate count
    was frozen from the pre-restore config."""
    tasks, _, _ = suite
    saved = DreamShard(tasks, CostSimulator(seed=0),
                       DreamShardConfig(n_iterations=1,
                                        inference_candidates=4))
    saved.save(str(tmp_path / "agent"))
    agent = DreamShard(tasks, CostSimulator(seed=0),
                       DreamShardConfig(n_iterations=1))
    stale = agent.as_placer()
    assert stale.session.n_candidates == 16              # default config
    agent.restore(str(tmp_path / "agent"))
    fresh = agent.as_placer()
    assert fresh is not stale
    assert fresh.session.n_candidates == 4               # restored config


def test_trainer_with_cached_oracle_collects(suite):
    tasks, _, _ = suite
    cached = CachedOracle(CostSimulator(seed=0))
    ds = DreamShard(tasks, cached,
                    DreamShardConfig(n_iterations=1, n_collect=3, n_cost=2,
                                     n_rl=1))
    ds.collect()
    assert cached.hits + cached.misses == 3


# ---- repro.api export surface ------------------------------------------------

def test_api_all_exports_resolve():
    """__all__ is sorted and deduped, every name (lazy registry
    included) resolves, and every lazy name is both exported and
    actually defined by its source module."""
    import importlib

    import repro.api as api
    assert api.__all__ == sorted(set(api.__all__))
    for name in api.__all__:
        assert getattr(api, name) is not None, name
    assert set(api._LAZY) <= set(api.__all__)
    for name, module in api._LAZY.items():
        assert getattr(importlib.import_module(module), name) \
            is getattr(api, name), name
    assert dir(api) == sorted(api.__all__)
    with pytest.raises(AttributeError, match="not_a_real_export"):
        api.not_a_real_export
