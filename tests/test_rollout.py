"""Estimated-MDP rollout: action validity, memory legality, greedy
determinism, REINFORCE updates."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import features as F
from repro.core import networks as N
from repro.core import rollout as R
from repro.optim import adam


def _nets(seed=0):
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    return N.policy_net_init(k1), N.cost_net_init(k2)


def _task(m=15, seed=0):
    rng = np.random.default_rng(seed)
    feats = jnp.asarray(rng.random((m, F.NUM_FEATURES)), jnp.float32)
    sizes = jnp.asarray(rng.uniform(0.5, 2.0, m), jnp.float32)
    return feats, sizes


def test_actions_in_range():
    pol, cost = _nets()
    feats, sizes = _task()
    actions, est = R.rollout(pol, cost, feats, sizes, 100.0,
                             jax.random.PRNGKey(0), n_devices=4,
                             n_episodes=6)
    a = np.asarray(actions)
    assert a.shape == (6, 15)
    assert ((a >= 0) & (a < 4)).all()
    assert np.isfinite(np.asarray(est)).all()


def test_memory_cap_respected():
    pol, cost = _nets()
    feats, sizes = _task()
    cap = float(np.asarray(sizes).sum()) / 4 + float(np.asarray(sizes).max())
    actions, _ = R.rollout(pol, cost, feats, sizes, cap,
                           jax.random.PRNGKey(0), n_devices=4, n_episodes=8)
    for a in np.asarray(actions):
        for d in range(4):
            assert np.asarray(sizes)[a == d].sum() <= cap + 1e-5


def test_greedy_deterministic():
    pol, cost = _nets()
    feats, sizes = _task()
    a1, _ = R.rollout(pol, cost, feats, sizes, 100.0, jax.random.PRNGKey(0),
                      n_devices=4, n_episodes=1, greedy=True)
    a2, _ = R.rollout(pol, cost, feats, sizes, 100.0, jax.random.PRNGKey(9),
                      n_devices=4, n_episodes=1, greedy=True)
    np.testing.assert_array_equal(np.asarray(a1), np.asarray(a2))


def test_rl_update_changes_policy():
    pol, cost = _nets()
    feats, sizes = _task()
    opt = adam(1e-3)
    update = R.make_rl_update(opt, n_devices=4, n_episodes=6)
    state = opt.init(pol)
    pol2, state, loss, reward = update(pol, state, cost, feats, sizes, 100.0,
                                       jax.random.PRNGKey(0))
    diffs = jax.tree.map(lambda a, b: float(jnp.abs(a - b).max()), pol, pol2)
    assert max(jax.tree.leaves(diffs)) > 0
    assert np.isfinite(float(loss))


def test_replay_logp_matches_episode_count():
    pol, cost = _nets()
    feats, sizes = _task()
    actions = jnp.zeros((3, 15), jnp.int32)
    logp, ent = R.replay_logp(pol, cost, feats, sizes, 100.0, actions,
                              n_devices=4)
    assert logp.shape == (3,)
    assert (np.asarray(logp) <= 0).all()
    assert (np.asarray(ent) >= 0).all()


def test_no_cost_feature_mode():
    pol, cost = _nets()
    feats, sizes = _task()
    actions, est = R.rollout(pol, cost, feats, sizes, 100.0,
                             jax.random.PRNGKey(0), n_devices=4,
                             n_episodes=2, use_cost=False)
    assert np.asarray(actions).shape == (2, 15)
