"""End-to-end DreamShard training (reduced budget): must produce legal
placements and beat random placement on held-out tasks."""

import numpy as np
import pytest

from repro.core import baselines as B
from repro.core.trainer import DreamShard, DreamShardConfig
from repro.data.tasks import make_benchmark_suite
from repro.sim.costsim import CostSimulator


@pytest.fixture(scope="module")
def trained():
    from repro.data.synthetic import make_dlrm_pool
    pool = make_dlrm_pool(seed=0)
    sim = CostSimulator(seed=0)
    train, test = make_benchmark_suite(pool, n_tables=20, n_devices=4,
                                       n_tasks=10)
    ds = DreamShard(train, sim,
                    DreamShardConfig(n_iterations=4, n_cost=80, n_rl=8))
    ds.train()
    return ds, sim, train, test


def test_beats_random(trained):
    ds, sim, train, test = trained
    rng = np.random.default_rng(0)
    rand = np.mean([sim.evaluate(
        t.raw_features,
        B.random_place(t.raw_features, 4, sim.spec.mem_capacity_gb, rng),
        4).overall for t in test])
    ours = ds.evaluate_tasks(test)
    assert ours < rand, (ours, rand)


def test_placements_legal(trained):
    ds, sim, _, test = trained
    for t in test[:5]:
        a = ds.place(t.raw_features, t.n_devices)
        assert a.shape == (t.n_tables,)
        assert sim.legal(t.raw_features, a, t.n_devices)


def test_placement_deterministic(trained):
    ds, _, _, test = trained
    t = test[0]
    a1 = ds.place(t.raw_features, 4)
    a2 = ds.place(t.raw_features, 4)
    np.testing.assert_array_equal(a1, a2)


def test_generalizes_to_other_device_count(trained):
    """Zero-shot transfer to 2 devices (paper Table 2 mechanism)."""
    ds, sim, _, test = trained
    t = test[0]
    a = ds.place(t.raw_features, 2)
    assert set(np.unique(a)) <= {0, 1}
    assert sim.legal(t.raw_features, a, 2)


def test_generalizes_to_other_table_count(trained):
    ds, sim, _, _ = trained
    from repro.data.synthetic import make_dlrm_pool
    pool = make_dlrm_pool(seed=3)
    a = ds.place(pool[:37], 4)
    assert a.shape == (37,)


def test_history_recorded(trained):
    ds = trained[0]
    assert len(ds.history) == 4
    assert all("cost_loss" in h for h in ds.history)
    # cost net learns: loss decreases from first to last iteration
    assert ds.history[-1]["cost_loss"] < ds.history[0]["cost_loss"]


def test_buffer_grows(trained):
    ds = trained[0]
    assert len(ds.buffer) == 4 * ds.cfg.n_collect
