"""Required per-architecture smoke tests: instantiate the REDUCED variant
of each assigned architecture family (<=2 layers, d_model<=512, <=4
experts), run one forward + one train step + one decode step on CPU, and
assert output shapes and absence of NaNs."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs as C
from repro.launch import steps as ST

B, S = 2, 64


def _batch(cfg, rng):
    nf = cfg.n_frontend_tokens if cfg.frontend else 0
    tokens = jnp.asarray(rng.integers(0, cfg.vocab, (B, S - nf)), jnp.int32)
    batch = {"tokens": tokens,
             "labels": jnp.asarray(rng.integers(0, cfg.vocab, (B, S)),
                                   jnp.int32),
             "loss_mask": jnp.ones((B, S), jnp.float32)}
    if nf:
        batch["embeds"] = jnp.asarray(
            rng.normal(0, 0.02, (B, nf, cfg.d_model)), jnp.bfloat16)
    return batch


@pytest.mark.parametrize("arch", C.ARCH_NAMES)
def test_smoke_forward_train_decode(arch):
    cfg = C.get_smoke(arch).resolve(1)
    assert cfg.n_layers <= 2 and cfg.d_model <= 512
    if cfg.moe:
        assert cfg.moe.n_experts <= 4
    model = ST.build_model(cfg, remat=False, q_chunk=32, kv_chunk=32)
    params = model.init_params(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    batch = _batch(cfg, rng)

    logits, aux = jax.jit(model.forward)(params, batch["tokens"],
                                         batch.get("embeds"))
    assert logits.shape == (B, S, cfg.vocab_padded)
    assert not bool(jnp.isnan(logits).any())

    opt, train_step = ST.make_train_step(model, lr=1e-3)
    p2, _, metrics = jax.jit(train_step)(params, opt.init(params), batch)
    assert np.isfinite(float(metrics["loss"]))
    # params actually moved
    moved = jax.tree.map(lambda a, b: float(jnp.abs(
        a.astype(jnp.float32) - b.astype(jnp.float32)).max()), params, p2)
    assert max(jax.tree.leaves(moved)) > 0

    prefill = ST.make_prefill_step(model, capacity=S)
    logits_p, cache = jax.jit(prefill)(params, batch)
    assert logits_p.shape == (B, 1, cfg.vocab_padded)
    decode = ST.make_decode_step(model)
    logits_d, cache2 = jax.jit(decode)(params, cache,
                                       {"tokens": batch["tokens"][:, :1]})
    assert logits_d.shape == (B, 1, cfg.vocab_padded)
    assert not bool(jnp.isnan(logits_d).any())
    assert int(cache2["pos"]) == int(cache["pos"]) + 1


@pytest.mark.parametrize("arch", C.ARCH_NAMES)
def test_full_config_matches_assignment(arch):
    """The FULL configs carry the exact assigned hyperparameters."""
    expect = {
        "llava-next-34b": (60, 7168, 56, 8, 20480, 64000),
        "hymba-1.5b": (32, 1600, 25, 5, 5504, 32001),
        "qwen2.5-14b": (48, 5120, 40, 8, 13824, 152064),
        "dbrx-132b": (40, 6144, 48, 8, 10752, 100352),
        "granite-34b": (88, 6144, 48, 1, 24576, 49152),
        "phi4-mini-3.8b": (32, 3072, 24, 8, 8192, 200064),
        "olmoe-1b-7b": (16, 2048, 16, 16, 1024, 50304),
        "rwkv6-1.6b": (24, 2048, 0, 0, 7168, 65536),
        "h2o-danube-1.8b": (24, 2560, 32, 8, 6912, 32000),
        "musicgen-large": (48, 2048, 32, 32, 8192, 2048),
    }[arch]
    cfg = C.get_full(arch)
    got = (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
           cfg.d_ff, cfg.vocab)
    assert got == expect
    assert cfg.source


def test_moe_configs():
    dbrx = C.get_full("dbrx-132b")
    assert dbrx.moe.n_experts == 16 and dbrx.moe.top_k == 4
    olmoe = C.get_full("olmoe-1b-7b")
    assert olmoe.moe.n_experts == 64 and olmoe.moe.top_k == 8


def test_long_context_support_flags():
    assert C.supports_shape("rwkv6-1.6b", "long_500k")
    assert C.supports_shape("hymba-1.5b", "long_500k")
    assert C.supports_shape("h2o-danube-1.8b", "long_500k")
    assert not C.supports_shape("qwen2.5-14b", "long_500k")
    assert C.supports_shape("qwen2.5-14b", "decode_32k")


def test_resolve_pads_heads():
    cfg = C.get_full("hymba-1.5b").resolve(16)
    assert cfg.n_heads_padded % 16 == 0
    assert cfg.vocab_padded % 16 == 0 and cfg.vocab_padded >= cfg.vocab
    cfg1 = C.get_full("hymba-1.5b").resolve(1)
    assert cfg1.n_heads_padded == 25        # no padding at tp=1
