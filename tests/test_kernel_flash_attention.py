"""Pallas flash-attention kernel vs two independent oracles: shape/dtype
sweep in interpret mode (per-kernel requirement)."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention import ops
from repro.models.layers import flash_attention as model_flash


def _qkv(B, S, H, hd, dtype, seed=0):
    rng = np.random.default_rng(seed)

    def mk():
        return jnp.asarray(rng.normal(size=(B, S, H, hd)) * 0.5, dtype)
    return mk(), mk(), mk()


@pytest.mark.parametrize("S", [128, 256, 384])
@pytest.mark.parametrize("hd", [128, 256])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_kernel_matches_oracle(S, hd, dtype):
    q, k, v = _qkv(1, S, 2, hd, dtype, seed=S + hd)
    out = ops.flash_attention(q, k, v, q_block=128, kv_block=128)
    ref = ops.flash_attention_ref(q, k, v)
    tol = 2e-4 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=tol, atol=tol)


def test_kernel_matches_model_blockwise_impl():
    """Second oracle: the pure-JAX blockwise scan used by the LM."""
    q, k, v = _qkv(2, 128, 2, 128, jnp.float32, seed=3)
    out = ops.flash_attention(q, k, v, q_block=64, kv_block=64)
    ref = model_flash(q, k, v, causal=True, q_chunk=32, kv_chunk=32)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=3e-4, atol=3e-4)


def test_sliding_window():
    q, k, v = _qkv(1, 256, 1, 128, jnp.float32, seed=4)
    out = ops.flash_attention(q, k, v, window=64, q_block=128, kv_block=128)
    ref = ops.flash_attention_ref(q, k, v, window=64)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-4, atol=2e-4)


def test_head_dim_padding():
    """hd=64 pads to 128 lanes internally and slices back."""
    q, k, v = _qkv(1, 128, 2, 64, jnp.float32, seed=5)
    out = ops.flash_attention(q, k, v)
    ref = ops.flash_attention_ref(q, k, v)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-4, atol=2e-4)


def test_odd_sequence_padding():
    q, k, v = _qkv(1, 100, 1, 128, jnp.float32, seed=6)
    out = ops.flash_attention(q, k, v, q_block=64, kv_block=64)
    ref = ops.flash_attention_ref(q, k, v)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-4, atol=2e-4)
