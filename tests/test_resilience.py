"""Resilient serving: fault schedules/injection, degraded-mesh repair,
typed serve errors, failover re-placement, fallback chain, latency
ledger, and warm-restart checkpoints."""

import dataclasses
import json
import os
from types import SimpleNamespace

import numpy as np
import pytest

from repro import checkpoint
from repro.core import features as F
from repro.core.trainer import DreamShard, DreamShardConfig
from repro.data.tasks import sample_tasks, split_pool
from repro.data.traffic import TrafficConfig, make_trace
from repro.serve import (CacheEntry, CapacityError, DecodeTimeout,
                         DegradedMeshOracle, FaultEvent, FaultInjector,
                         FaultSchedule, FaultyOracle, IllegalTaskError,
                         LatencyReservoir, PlacementCache, PlacementService,
                         ServeConfig, ServeError, TransientOracleError,
                         repair_assignment)
from repro.sim.costsim import CostSimulator


@pytest.fixture(scope="module")
def agent(dlrm_pool):
    train_ids, _ = split_pool(dlrm_pool, seed=0)
    tasks = sample_tasks(dlrm_pool, train_ids, 12, 4, 2, seed=1)
    return DreamShard(tasks, CostSimulator(seed=0),
                      DreamShardConfig(n_iterations=1))


class FakeClock:
    def __init__(self):
        self.t = 0.0

    def __call__(self) -> float:
        return self.t

    def advance_ms(self, ms: float) -> None:
        self.t += ms / 1e3


def _request(pool, ids, n_devices=4):
    return np.array(pool[ids], dtype=np.float64), n_devices


# ---- fault schedule ----------------------------------------------------------

def test_fault_event_validation():
    with pytest.raises(ValueError):
        FaultEvent(at=0, kind="meteor_strike")
    with pytest.raises(ValueError):
        FaultEvent(at=0, kind="device_loss")            # needs device=
    with pytest.raises(ValueError):
        FaultEvent(at=0, kind="capacity_shrink", factor=1.5)
    with pytest.raises(ValueError):
        FaultEvent(at=0, kind="oracle_error", count=0)
    with pytest.raises(ValueError):
        FaultEvent(at=0, kind="decode_spike", spike_ms=-1.0)


def test_schedule_sorts_roundtrips_and_generates_deterministically():
    sched = FaultSchedule((
        FaultEvent(at=9, kind="decode_spike", spike_ms=10.0),
        FaultEvent(at=2, kind="device_loss", device=1),
        FaultEvent(at=5, kind="device_recovery", device=1)))
    assert [e.at for e in sched] == [2, 5, 9]           # sorted by index
    assert FaultSchedule.from_json(sched.to_json()) == sched
    a = FaultSchedule.generate(seed=7, n_requests=100, n_devices=4)
    assert a == FaultSchedule.generate(seed=7, n_requests=100, n_devices=4)
    assert a != FaultSchedule.generate(seed=8, n_requests=100, n_devices=4)
    losses = [e for e in a if e.kind == "device_loss"]
    assert losses and all(25 <= e.at < 50 for e in losses)


def test_injector_state_machine_and_checkpoint_roundtrip():
    inj = FaultInjector(FaultSchedule((
        FaultEvent(at=0, kind="device_loss", device=1),
        FaultEvent(at=1, kind="oracle_error", count=2),
        FaultEvent(at=1, kind="decode_spike", spike_ms=30.0),
        FaultEvent(at=2, kind="device_recovery", device=1),
        FaultEvent(at=3, kind="capacity_shrink", factor=0.5))))
    assert [e.kind for e in inj.advance()] == ["device_loss"]
    assert inj.degraded and inj.down == {1} and inj.epoch == 1
    assert list(inj.allowed_mask(4)) == [True, False, True, True]
    fired = inj.advance()
    assert {e.kind for e in fired} == {"oracle_error", "decode_spike"}
    assert inj.epoch == 1                       # no topology change
    assert inj.take_error() and inj.take_error() and not inj.take_error()
    assert inj.take_spike_ms() == 30.0 and inj.take_spike_ms() == 0.0
    inj.advance()                               # recovery
    assert not inj.degraded and inj.epoch == 2
    inj.advance()                               # shrink
    assert inj.degraded and inj.capacity_gb(8.0) == 4.0 and inj.epoch == 3
    clone = FaultInjector(inj.schedule)
    clone.load_state_dict(json.loads(json.dumps(inj.state_dict())))
    assert clone.state_dict() == inj.state_dict()
    assert inj.advance() == [] and inj.tick == 5


def test_faulty_oracle_raises_but_legality_never_faults(dlrm_pool):
    raw = dlrm_pool[:4]
    a = np.array([0, 1, 2, 3])
    inj = FaultInjector(FaultSchedule((
        FaultEvent(at=0, kind="oracle_error", count=2),)))
    inj.advance()
    oracle = FaultyOracle(CostSimulator(seed=0), inj)
    legal = oracle.legal(raw, a, 4)             # armed, but never faults
    assert oracle.legal_batch(raw, a[None, :], 4)[0] == legal
    with pytest.raises(TransientOracleError):
        oracle.evaluate(raw, a, 4)
    with pytest.raises(TransientOracleError):
        oracle.evaluate_many(raw, a[None, :], 4)
    assert oracle.evaluate(raw, a, 4).overall > 0      # errors drained


def test_degraded_mesh_oracle_narrows_legality(dlrm_pool):
    raw = np.array(dlrm_pool[:4], dtype=np.float64)
    raw[:, F.TABLE_SIZE_GB] = 1.0
    inner = CostSimulator(seed=0)
    allowed = np.array([True, False, True, True])
    oracle = DegradedMeshOracle(inner, allowed, capacity_gb=2.0)
    A = np.array([[0, 2, 3, 0],                 # survivors only: legal
                  [0, 1, 2, 3],                 # touches lost device 1
                  [0, 0, 0, 2],                 # 3 GB on device 0 > 2 GB
                  [0, 2, 3, 9]])                # out of range: illegal
    np.testing.assert_array_equal(
        oracle.legal_batch(raw, A, 4), [True, False, False, False])
    assert oracle.legal(raw, A[0], 4) and not oracle.legal(raw, A[1], 4)
    assert oracle.mem_capacity_gb == 2.0
    # costs delegate untouched
    assert oracle.evaluate(raw, A[0], 4).overall == \
        inner.evaluate(raw, A[0], 4).overall


def test_repair_assignment_moves_only_what_it_must():
    sizes = np.array([3.0, 1.0, 2.0, 1.0])
    allowed = np.array([True, False, True])
    # table 1 stranded on lost device 1; headroom ties (5 GB on both
    # survivors) break to the lowest id -> device 0.  Settled tables
    # never move.
    a = repair_assignment(sizes, np.array([0, 1, 2, 2]), allowed, 8.0)
    np.testing.assert_array_equal(a, [0, 0, 2, 2])
    # capacity shrink sheds the LARGEST table from the over-full device
    a = repair_assignment(sizes, np.array([0, 0, 0, 0]), allowed, 4.0)
    assert a is not None
    assert a[0] != 0                      # 3 GB table shed first
    loads = np.bincount(a, weights=sizes, minlength=3)
    assert (loads <= 4.0).all() and not (a == 1).any()
    # unplaced (-1) tables count as stranded
    a = repair_assignment(sizes, np.full(4, -1), allowed, 8.0)
    assert a is not None and not (a == 1).any()
    # impossible meshes -> None, never an exception
    assert repair_assignment(sizes, np.array([0, 1, 2, 2]),
                             np.zeros(3, dtype=bool), 8.0) is None
    assert repair_assignment(sizes, np.array([0, 1, 2, 2]),
                             allowed, 0.0) is None
    assert repair_assignment(sizes, np.full(4, -1), allowed, 2.5) is None


# ---- cache invalidation ------------------------------------------------------

def _entry(assignment) -> CacheEntry:
    return CacheEntry(
        placement=SimpleNamespace(assignment=np.asarray(assignment)),
        snapshot=np.zeros((len(assignment), F.NUM_DIST_BINS)))


def test_cache_invalidate_predicate_and_devices():
    cache = PlacementCache(max_entries=8)
    cache.put(b"a", _entry([0, 1, 2]))
    cache.put(b"b", _entry([0, 2, 2]))
    cache.put(b"c", _entry([1, 1, 1]))
    cache.put(b"d", _entry([3, 0, 3]))
    assert cache.get(b"a") is not None          # refresh a's LRU position
    hits, misses = cache.hits, cache.misses
    assert cache.invalidate_devices([1]) == 2   # a and c touch device 1
    assert cache.invalidations == 2
    assert cache.get(b"a") is None and cache.get(b"c") is None
    assert cache.invalidate_devices([]) == 0
    # survivors keep their LRU order: b is still older than d
    assert [k for k, _ in cache.items()] == [b"b", b"d"]
    assert cache.invalidate(lambda k, e: k == b"missing") == 0
    assert cache.invalidate(lambda k, e: True) == 2
    assert len(cache) == 0 and cache.invalidations == 4
    # invalidation is not a hit or a miss (the two gets above miss)
    assert (cache.hits, cache.misses) == (hits, misses + 2)


# ---- typed errors ------------------------------------------------------------

def test_serve_error_hierarchy_and_describe():
    for cls, code in ((IllegalTaskError, "illegal_task"),
                      (CapacityError, "capacity"),
                      (DecodeTimeout, "decode_timeout"),
                      (TransientOracleError, "transient_oracle")):
        err = cls("boom")
        assert isinstance(err, ServeError)
        assert err.describe() == {"code": code, "message": "boom"}


def test_submit_never_raises_on_malformed_requests(dlrm_pool, agent):
    svc = PlacementService(agent, clock=FakeClock(), config=ServeConfig(
        max_wait_ms=0.0, max_batch=1))
    bad = [
        (np.zeros((2, 5)), 4),                        # wrong feature width
        (np.zeros((0, F.NUM_FEATURES)), 4),           # no tables
        (np.full((2, F.NUM_FEATURES), np.nan), 4),    # non-finite
        (_request(dlrm_pool, range(4))[0], 0),        # bad device count
        (_request(dlrm_pool, range(4))[0], "two"),
    ]
    for raw, d in bad:
        out = svc.submit(raw, d, tag="bad")
        assert len(out) == 1 and out[0].source == "error"
        assert out[0].placement is None
        assert isinstance(out[0].error, IllegalTaskError)
    assert svc.rejected == len(bad) and svc.typed_errors == len(bad)
    # the service keeps serving healthy traffic afterwards
    raw, d = _request(dlrm_pool, range(12))
    ok = svc.submit(raw, d, tag="good")
    assert ok[0].placement is not None and ok[0].error is None
    assert svc.stats()["rejected"] == len(bad)


def test_unplaceable_mesh_is_a_typed_capacity_error(dlrm_pool, agent):
    # every device lost before the first request: nothing can be placed,
    # but submit()/flush() still answer every ticket
    faults = FaultInjector(FaultSchedule(tuple(
        FaultEvent(at=0, kind="device_loss", device=d) for d in range(4))))
    svc = PlacementService(agent, faults=faults, clock=FakeClock(),
                           config=ServeConfig(max_wait_ms=0.0, max_batch=1))
    raw, d = _request(dlrm_pool, range(12))
    out = svc.submit(raw, d, tag="doomed")
    assert len(out) == 1 and out[0].source == "error"
    assert isinstance(out[0].error, CapacityError)
    assert svc.typed_errors == 1 and len(svc.cache) == 0


# ---- degraded-mode fallbacks -------------------------------------------------

def test_deadline_spike_degrades_to_expert(dlrm_pool, agent):
    faults = FaultInjector(FaultSchedule((
        FaultEvent(at=0, kind="decode_spike", spike_ms=50.0),)))
    svc = PlacementService(agent, faults=faults, clock=FakeClock(),
                           config=ServeConfig(max_wait_ms=0.0, max_batch=1,
                                              decode_deadline_ms=25.0))
    raw, d = _request(dlrm_pool, range(12))
    out = svc.submit(raw, d, tag="spiked")
    assert out[0].source == "fallback" and out[0].degraded == "expert"
    assert out[0].placement.strategy == "serve.fallback.expert"
    assert svc.deadline_skips == 1 and svc.fallbacks["expert"] == 1
    oracle = svc.oracle
    assert oracle.legal(raw, out[0].placement.assignment, d)
    # the spike was consumed: the next decode runs normally
    raw2, _ = _request(dlrm_pool, range(10, 22))
    assert svc.submit(raw2, d, tag="calm")[0].source == "decode"


def test_deadline_with_empty_chain_is_decode_timeout(dlrm_pool, agent):
    faults = FaultInjector(FaultSchedule((
        FaultEvent(at=0, kind="decode_spike", spike_ms=50.0),)))
    svc = PlacementService(agent, faults=faults, clock=FakeClock(),
                           config=ServeConfig(max_wait_ms=0.0, max_batch=1,
                                              decode_deadline_ms=25.0,
                                              fallback_chain=()))
    raw, d = _request(dlrm_pool, range(12))
    out = svc.submit(raw, d, tag="spiked")
    assert out[0].source == "error"
    assert isinstance(out[0].error, DecodeTimeout)
    with pytest.raises(ValueError):
        ServeConfig(fallback_chain=("expert", "prayer"))


def test_transient_errors_retry_with_bounded_budget(dlrm_pool, agent):
    svc = PlacementService(agent, clock=FakeClock(),
                           config=ServeConfig(oracle_retries=2))
    calls = []

    def flaky():
        calls.append(1)
        if len(calls) < 2:
            raise TransientOracleError("blip")
        return "ok"

    assert svc._with_retries(flaky) == "ok"
    assert len(calls) == 2 and svc.retries == 1 and svc.retry_exhausted == 0

    def always():
        raise TransientOracleError("down")

    assert svc._with_retries(always) is None
    assert svc.retries == 1 + 3                 # 1 + (retries + 1) attempts
    assert svc.retry_exhausted == 1


# ---- failover re-placement ---------------------------------------------------

def test_device_loss_evacuates_cache_onto_survivors(dlrm_pool, agent,
                                                    telemetry):
    from repro import telemetry as tele
    lost = 1
    faults = FaultInjector(FaultSchedule((
        FaultEvent(at=2, kind="device_loss", device=lost),
        FaultEvent(at=4, kind="device_recovery", device=lost))))
    svc = PlacementService(agent, faults=faults, clock=FakeClock(),
                           config=ServeConfig(max_wait_ms=0.0, max_batch=1,
                                              failover_max_evals=8))
    jobs = [_request(dlrm_pool, range(10 * i, 10 * i + 12)) for i in range(3)]
    for i, (raw, d) in enumerate(jobs[:2]):
        assert svc.submit(raw, d, tag=i)[0].placement is not None
    # request 2 absorbs the loss: the sweep runs before it is served
    out = svc.submit(*jobs[2], tag=2)
    assert out[0].placement is not None
    assert not (out[0].placement.assignment == lost).any()
    for _, entry in svc.cache.items():
        a = entry.placement.assignment
        assert not (a == lost).any()
        assert svc.oracle.legal(entry.raw, a, entry.placement.n_devices)
    assert svc.fault_events["device_loss"] == 1
    assert svc.evacuations + svc.evacuation_failures >= 1 or \
        svc.failover_bytes_gb == 0.0            # nothing was on the device
    counters = tele.snapshot()["counters"]
    assert counters["serve.faults.device_loss"] == 1
    # mid-outage hits keep serving the evacuated placement
    again = svc.submit(*jobs[0], tag="warm")
    assert again[0].source == "cache"
    assert not (again[0].placement.assignment == lost).any()
    # recovery widens the mesh again without touching the cache
    svc.submit(*jobs[1], tag="after")
    assert not faults.degraded and svc.fault_events["device_recovery"] == 1


# ---- latency ledger ----------------------------------------------------------

def test_latency_reservoir_quantiles_and_bound():
    r = LatencyReservoir(capacity=256, seed=0)
    assert r.summary() == {"count": 0, "mean_ms": None, "p50_ms": None,
                           "p99_ms": None}
    values = [float(v) for v in range(1, 101)]
    for v in values:
        r.record(v)
    # below capacity the ledger is exact: quantiles are np.quantile
    assert r.count == 100 and sorted(r.values()) == values
    s = r.summary()
    assert s["p50_ms"] == pytest.approx(np.quantile(values, 0.5))
    assert s["p99_ms"] == pytest.approx(np.quantile(values, 0.99))
    assert s["mean_ms"] == pytest.approx(np.mean(values))
    # past capacity the sample stays bounded but counts the full stream
    small = LatencyReservoir(capacity=16, seed=1)
    for v in range(1000):
        small.record(float(v))
    assert small.count == 1000 and len(small.values()) == 16
    assert small.mean == pytest.approx(np.mean(np.arange(1000.0)))


def test_latency_reservoir_checkpoint_is_seamless():
    a = LatencyReservoir(capacity=8, seed=3)
    for v in range(40):
        a.record(float(v))
    b = LatencyReservoir(capacity=8, seed=999)
    b.load_state_dict(json.loads(json.dumps(a.state_dict())))
    for v in range(40, 80):
        a.record(float(v))
        b.record(float(v))
    np.testing.assert_array_equal(a.values(), b.values())
    assert a.count == b.count
    with pytest.raises(ValueError):
        LatencyReservoir(capacity=4).load_state_dict(a.state_dict())


def test_service_stats_ledger_is_bounded(dlrm_pool, agent):
    svc = PlacementService(agent, clock=FakeClock(), config=ServeConfig(
        max_wait_ms=0.0, max_batch=1, reservoir_size=4))
    raw, d = _request(dlrm_pool, range(12))
    for i in range(10):
        svc.submit(raw, d, tag=i)
    lat = svc.stats()["latency"]
    assert lat["count"] == 10 and len(svc.latency.values()) == 4


# ---- warm-restart checkpoints ------------------------------------------------

def _drain(svc, trace, clock, tag0=0):
    done = []
    for i, r in enumerate(trace):
        clock.advance_ms(1.0)
        done += svc.submit(r.raw_features, r.n_devices, tag=tag0 + i)
    done += svc.flush()
    return done


def test_warm_restart_matches_uninterrupted_run(dlrm_pool, agent, tmp_path):
    cfg = TrafficConfig(n_jobs=3, n_tables=12, n_devices=4, n_requests=24,
                        drift=1.0, zipf=0.0, seed=5)
    trace = make_trace(dlrm_pool, cfg)
    sched = FaultSchedule((
        FaultEvent(at=8, kind="device_loss", device=2),
        FaultEvent(at=20, kind="device_recovery", device=2)))
    scfg = ServeConfig(max_wait_ms=2.0, max_batch=4, drift_threshold=0.05,
                       ewma_alpha=0.5, replace_max_evals=8,
                       failover_max_evals=8)

    clock = FakeClock()
    base = PlacementService(agent, faults=FaultInjector(sched), clock=clock,
                            config=scfg)
    expect = _drain(base, trace, clock)

    clock = FakeClock()
    svc = PlacementService(agent, faults=FaultInjector(sched), clock=clock,
                           config=scfg)
    done = []
    cut = 13                    # mid-outage, with requests still queued
    for i, r in enumerate(trace[:cut]):
        clock.advance_ms(1.0)
        done += svc.submit(r.raw_features, r.n_devices, tag=i)
    path = os.path.join(tmp_path, "ckpt")
    svc.save(path)
    restored = PlacementService.restore(path, agent=agent, config=scfg,
                                        faults=FaultInjector(sched),
                                        clock=clock)
    assert restored.pending == svc.pending      # queued tickets survive
    assert restored.faults.down == {2}
    done += _drain(restored, trace[cut:], clock, tag0=cut)

    by_tag = {r.tag: r for r in expect}
    assert len(done) == len(expect) == len(trace)
    for r in done:
        ref = by_tag[r.tag]
        assert (r.placement is None) == (ref.placement is None)
        if r.placement is not None:
            np.testing.assert_array_equal(r.placement.assignment,
                                          ref.placement.assignment)
    assert restored.stats()["fault_epoch"] == base.stats()["fault_epoch"]


def test_checkpoint_rejects_future_state_version(tmp_path):
    path = os.path.join(tmp_path, "state")
    checkpoint.save_state(path, {"x": np.arange(3)}, {"meta": 1})
    arrays, meta = checkpoint.load_state(path)
    np.testing.assert_array_equal(arrays["x"], np.arange(3))
    assert meta == {"meta": 1}
    envelope = json.load(open(os.path.join(path, "state.json")))
    envelope["state_version"] = checkpoint.STATE_VERSION + 1
    json.dump(envelope, open(os.path.join(path, "state.json"), "w"))
    with pytest.raises(ValueError, match="checkpoint version"):
        checkpoint.load_state(path)


def test_empty_schedule_matches_no_injector(dlrm_pool, agent):
    cfg = TrafficConfig(n_jobs=2, n_tables=12, n_devices=4, n_requests=10,
                        drift=0.5, seed=9)
    trace = make_trace(dlrm_pool, cfg)
    scfg = ServeConfig(max_wait_ms=0.0, max_batch=4)
    clock = FakeClock()
    plain = _drain(PlacementService(agent, clock=clock, config=scfg),
                   trace, clock)
    clock = FakeClock()
    faulted = _drain(PlacementService(agent, faults=FaultInjector(),
                                      clock=clock, config=scfg),
                     trace, clock)
    for a, b in zip(plain, faulted):
        assert a.tag == b.tag and a.source == b.source
        np.testing.assert_array_equal(a.placement.assignment,
                                      b.placement.assignment)
