"""Fused Algorithm-1 loop: equivalence with the seed per-step loop,
single-trace compile guarantees, device replay ring, and padded
device-mask decoding."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api.session import pad_device_mask, pad_feature_batch
from repro.core import features as F
from repro.core import networks as N
from repro.core import replay as RB
from repro.core import rollout as R
from repro.core.trainer import CostSample, DreamShard, DreamShardConfig
from repro.data.tasks import make_benchmark_suite, sample_tasks, split_pool
from repro.sim.costsim import CostSimulator


def _cfg(**kw):
    base = dict(n_iterations=2, n_collect=6, n_cost=30, n_batch=8, n_rl=4,
                n_episode=4)
    base.update(kw)
    return DreamShardConfig(**base)


@pytest.fixture(scope="module")
def suite(dlrm_pool):
    return make_benchmark_suite(dlrm_pool, n_tables=12, n_devices=4,
                                n_tasks=6)


@pytest.fixture(scope="module")
def mixed_suite(dlrm_pool):
    """Heterogeneous training set: different table AND device counts."""
    train_ids, _ = split_pool(dlrm_pool, seed=0)
    return (sample_tasks(dlrm_pool, train_ids, 10, 2, 3, seed=1)
            + sample_tasks(dlrm_pool, train_ids, 14, 4, 3, seed=2))


# ---- fused vs seed equivalence ------------------------------------------------


def test_fused_matches_seed_loop(suite):
    """Same seeds, same RNG consumption order -> the fused loop must land
    on the seed loop's cost-loss and eval within tight tolerance (on CPU
    the two are bitwise identical; tolerances absorb backend batching
    differences)."""
    train, test = suite
    runs = {}
    for fused in (True, False):
        ds = DreamShard(train, CostSimulator(seed=0), _cfg(fused=fused))
        ds.train(eval_tasks=test[:3])
        runs[fused] = ds
    f, s = runs[True], runs[False]
    assert len(f.buffer) == len(s.buffer)
    assert np.isclose(f.history[-1]["cost_loss"],
                      s.history[-1]["cost_loss"], rtol=0.25)
    assert np.isclose(f.history[-1]["eval_cost_ms"],
                      s.history[-1]["eval_cost_ms"], rtol=0.02)
    # both consumed the identical hardware budget
    assert f.oracle.num_evaluations == s.oracle.num_evaluations


def test_fused_collect_matches_seed_samples(suite):
    """One collect stage from identical state: the batched padded decode
    must produce the same measurements as the per-task loop (placements
    are sampled from identical logits + keys)."""
    train, _ = suite
    agents = [DreamShard(train, CostSimulator(seed=0), _cfg(fused=fu))
              for fu in (True, False)]
    for ds in agents:
        ds.collect()
    f, s = agents
    assert len(f.buffer) == len(s.buffer) == f.cfg.n_collect
    same = [np.array_equal(a.assignment, b.assignment)
            for a, b in zip(f.buffer, s.buffer)]
    # bitwise-equal logits -> identical placements; allow rare FP flips
    assert np.mean(same) >= 0.5
    for a in f.buffer:
        assert np.isfinite(a.overall)


def test_fused_dispatch_counts(suite):
    """The fused loop runs each stage in O(1) dispatches per iteration."""
    train, _ = suite
    ds = DreamShard(train, CostSimulator(seed=0), _cfg())
    ds.train()
    per_iter = ds.history[-1]["dispatches"]
    assert per_iter <= 5, per_iter
    ds2 = DreamShard(train, CostSimulator(seed=0), _cfg(fused=False))
    ds2.train()
    assert ds2.history[-1]["dispatches"] >= ds2.cfg.n_cost


# ---- compile-count guard ------------------------------------------------------


def test_single_trace_covers_mixed_shapes(mixed_suite):
    """ONE fused trace serves tasks with different (n_tables, n_devices):
    no per-shape recompile cache."""
    ds = DreamShard(mixed_suite, CostSimulator(seed=0), _cfg())
    ds.train()
    assert ds._fused_rl_update.traces[0] == 1
    assert ds._fused_cost_update.traces[0] == 1
    assert ds._rl_updates == {}          # per-(D, E) cache never populated
    # placements stay legal on every device count in the mix
    sim = CostSimulator(seed=0)
    for t in mixed_suite:
        a = ds.place(t.raw_features, t.n_devices)
        assert a.max() < t.n_devices
        assert sim.legal(t.raw_features, a, t.n_devices)


# ---- device replay ring -------------------------------------------------------


def test_ring_buffer_wraps():
    ring = RB.ReplayBuffer(capacity=4, m_pad=3, d_pad=2)
    B, M, D = 6, 3, 2
    feats = np.arange(B * M * F.NUM_FEATURES, dtype=np.float32).reshape(
        B, M, F.NUM_FEATURES)
    onehot = np.zeros((B, D, M), np.float32)
    tmask = np.ones((B, M), np.float32)
    dmask = np.ones((B, D), np.float32)
    q = np.zeros((B, D, 3), np.float32)
    overall = np.arange(B, dtype=np.float32)
    ring.append_batch(feats, onehot, tmask, dmask, q, overall)
    assert ring.count == 6 and ring.size == 4
    # newest four samples (2..5) live at slots i % 4
    got = np.asarray(ring.data["overall"])
    np.testing.assert_array_equal(got, [4.0, 5.0, 2.0, 3.0])
    # live-window indexing: sample_idx 0 is the oldest kept (global 2)
    np.testing.assert_array_equal(ring.slots(np.arange(4)), [2, 3, 0, 1])


def test_ring_overfull_batch_keeps_newest():
    """One append larger than the ring must deterministically keep the
    newest ``capacity`` samples (no duplicate-position scatter)."""
    ring = RB.ReplayBuffer(capacity=3, m_pad=2, d_pad=2)
    B = 8
    overall = np.arange(B, dtype=np.float32)
    ring.append_batch(np.zeros((B, 2, F.NUM_FEATURES), np.float32),
                      np.zeros((B, 2, 2), np.float32),
                      np.ones((B, 2), np.float32),
                      np.ones((B, 2), np.float32),
                      np.zeros((B, 2, 3), np.float32), overall)
    assert ring.count == 8 and ring.size == 3
    got = np.asarray(ring.data["overall"])        # slot = i % 3
    np.testing.assert_array_equal(got, [6.0, 7.0, 5.0])


def test_ring_grows_geometrically_past_budget(suite):
    """Training past the configured ``n_iterations * n_collect`` budget
    must grow the ring geometrically -- a handful of rebuild/retrace
    events, not one per step (the PR 3 behaviour rebuilt at the old size
    every update once the buffer outgrew it)."""
    train, _ = suite
    ds = DreamShard(train, CostSimulator(seed=0),
                    _cfg(n_iterations=1, n_collect=4, n_cost=4))
    ds.train()
    assert ds._ring.capacity == 4               # sized to the budget
    caps = []
    for _ in range(5):                          # run well past the budget
        ds.collect()
        ds.update_cost()
        caps.append(ds._ring.capacity)
    assert len(ds.buffer) == 24
    assert ds._ring.capacity >= len(ds.buffer)  # nothing evicted
    assert ds._ring.size == len(ds.buffer)
    # geometric growth: capacity doubles (8, 16, 32), so only ~log(n)
    # distinct ring shapes -- and each fused-update trace is tied to a
    # ring shape, so retraces stay logarithmic too
    assert set(caps) == {8, 16, 32}
    assert ds._fused_cost_update.traces[0] <= 4
    # the grown ring still trains: losses stay finite
    assert np.isfinite(ds.update_cost())


def test_same_length_buffer_reassignment_resyncs(suite):
    """Replacing ``ds.buffer`` with DIFFERENT samples of the same length
    must rebuild the ring (sync is keyed on list identity, not just
    count)."""
    train, _ = suite
    ds = DreamShard(train, CostSimulator(seed=0), _cfg())
    ds.collect()
    ds.update_cost(2)
    old_overall = np.asarray(ds._ring.data["overall"]).copy()
    replacement = [CostSample(feats_norm=s.feats_norm,
                              assignment=s.assignment,
                              q=s.q + 1.0, overall=s.overall + 1.0,
                              n_devices=s.n_devices) for s in ds.buffer]
    ds.buffer = replacement
    ds.update_cost(2)
    new_overall = np.asarray(ds._ring.data["overall"])
    live = new_overall != 0
    assert np.allclose(new_overall[live], old_overall[live] + 1.0)


def test_update_cost_after_direct_buffer_assignment(suite):
    """fig7 pattern: assign ``ds.buffer`` wholesale, then train the cost
    net -- the fused path must resync its device ring transparently."""
    train, _ = suite
    donor = DreamShard(train, CostSimulator(seed=0), _cfg())
    donor.collect()
    ds = DreamShard(train, CostSimulator(seed=1),
                    _cfg(n_collect=0, n_iterations=1))
    ds.buffer = list(donor.buffer)
    loss = ds.update_cost(10)
    assert np.isfinite(loss) and loss > 0
    assert ds._ring is not None and ds._ring.size == len(ds.buffer)
    # loss matches the per-step loop fed the same buffer + seeds
    ds2 = DreamShard(train, CostSimulator(seed=1),
                     _cfg(n_collect=0, n_iterations=1, fused=False))
    ds2.buffer = list(donor.buffer)
    loss2 = ds2.update_cost(10)
    assert np.isclose(loss, loss2, rtol=0.05)


def test_cost_mse_takes_sample_list(suite):
    """cost_mse consumes an explicit sample list (no buffer swapping) and
    leaves the training buffer untouched."""
    train, _ = suite
    ds = DreamShard(train, CostSimulator(seed=0), _cfg())
    ds.collect()
    before = list(ds.buffer)
    mse = ds.cost_mse(ds.buffer[:3])
    assert np.isfinite(mse) and mse > 0
    assert ds.buffer == before


# ---- padded rollout machinery -------------------------------------------------


def _toy(m=10, seed=0):
    rng = np.random.default_rng(seed)
    feats = jnp.asarray(rng.random((m, F.NUM_FEATURES)), jnp.float32)
    sizes = jnp.asarray(rng.uniform(0.5, 2.0, m), jnp.float32)
    return feats, sizes


def test_device_padded_greedy_decode_exact():
    """Greedy decode with devices padded+masked to D_pad returns the same
    actions as the unpadded decode (padding devices never win argmax)."""
    k1, k2 = jax.random.split(jax.random.PRNGKey(3))
    pol, cost = N.policy_net_init(k1), N.cost_net_init(k2)
    feats, sizes = _toy()
    h_pol = N.policy_table_reprs(pol, feats)
    h_cost = N.cost_table_reprs(cost, feats)
    a_ref, _, _, _ = R._scan_rollout(pol, cost, h_pol, h_cost, sizes, 100.0,
                                     jax.random.PRNGKey(0), 3, 1, True, True)
    dmask = jnp.asarray([1.0, 1.0, 1.0, 0.0, 0.0, 0.0])
    a_pad, _, _, est = R._scan_rollout(pol, cost, h_pol, h_cost, sizes,
                                       100.0, jax.random.PRNGKey(0), 6, 1,
                                       True, True, dmask=dmask)
    np.testing.assert_array_equal(np.asarray(a_ref), np.asarray(a_pad))
    assert int(np.asarray(a_pad).max()) < 3
    assert np.isfinite(np.asarray(est)).all()


def test_sort_tables_matches_host_order():
    cost = N.cost_net_init(jax.random.PRNGKey(1))
    feats, sizes = _toy(m=8)
    m_pad = 12
    fp = jnp.zeros((m_pad, F.NUM_FEATURES)).at[:8].set(feats)
    sp = jnp.zeros((m_pad,)).at[:8].set(sizes)
    tm = jnp.zeros((m_pad,)).at[:8].set(1.0)
    order, f_s, s_s, t_s = R.sort_tables(cost, fp, sp, tm)
    host = np.argsort(-np.asarray(
        N.predict_single_table_costs(cost, feats)), kind="stable")
    np.testing.assert_array_equal(np.asarray(order)[:8], host)
    # padding rows sort last and stay masked
    np.testing.assert_array_equal(np.asarray(t_s), [1.0] * 8 + [0.0] * 4)


def test_collect_batched_heterogeneous_legal():
    rng = np.random.default_rng(0)
    k1, k2 = jax.random.split(jax.random.PRNGKey(5))
    pol, cost = N.policy_net_init(k1), N.cost_net_init(k2)
    entries = [(rng.random((m, F.NUM_FEATURES)).astype(np.float32),
                rng.uniform(0.2, 1.0, m).astype(np.float32))
               for m in (6, 9, 12)]
    feats, sizes, tmask = pad_feature_batch(entries, 12)
    dmask = pad_device_mask([2, 4, 3], 4)
    keys = jax.random.split(jax.random.PRNGKey(0), 3)
    actions, est, order = R.collect_batched(
        pol, cost, jnp.asarray(feats), jnp.asarray(sizes),
        jnp.asarray(tmask), jnp.asarray(dmask), 100.0, keys)
    actions, order = np.asarray(actions), np.asarray(order)
    for b, (f, _) in enumerate(entries):
        m, d = f.shape[0], [2, 4, 3][b]
        assignment = np.empty(m, np.int64)
        assignment[order[b, :m]] = actions[b, 0, :m]
        assert assignment.max() < d        # padded devices never selected
    assert np.isfinite(np.asarray(est)).all()


def test_rollout_with_reprs_plumbs_reward_mode():
    """reward_mode / log_targets reach the estimate (satellite: they were
    silently dropped before)."""
    k1, k2 = jax.random.split(jax.random.PRNGKey(7))
    pol, cost = N.policy_net_init(k1), N.cost_net_init(k2)
    feats, sizes = _toy()
    h = N.policy_table_reprs(pol, feats)
    kw = dict(n_devices=4, n_episodes=2, greedy=True, use_cost=True)
    _, _, _, est_composed = R.rollout_with_reprs(
        pol, cost, h, feats, sizes, 100.0, jax.random.PRNGKey(0),
        reward_mode="composed", **kw)
    _, _, _, est_head = R.rollout_with_reprs(
        pol, cost, h, feats, sizes, 100.0, jax.random.PRNGKey(0),
        reward_mode="head", **kw)
    assert not np.allclose(np.asarray(est_composed), np.asarray(est_head))


def test_pad_feature_batch_shapes():
    entries = [(np.ones((4, F.NUM_FEATURES), np.float32),
                np.ones(4, np.float32))]
    feats, sizes, tmask = pad_feature_batch(entries, 8, b_pad=2)
    assert feats.shape == (2, 8, F.NUM_FEATURES)
    np.testing.assert_array_equal(tmask[0], [1] * 4 + [0] * 4)
    np.testing.assert_array_equal(tmask[1], np.zeros(8))
    np.testing.assert_array_equal(
        pad_device_mask([2, 4], 4), [[1, 1, 0, 0], [1, 1, 1, 1]])
