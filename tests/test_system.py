"""End-to-end system behaviour: the full DreamShard pipeline on the
synthetic DLRM pool reproduces the paper's qualitative results at reduced
budget, and model layers agree with independent oracles."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import baselines as B
from repro.core.trainer import DreamShard, DreamShardConfig
from repro.data.tasks import make_benchmark_suite
from repro.sim.costsim import CostSimulator


def test_dreamshard_pipeline_beats_every_baseline_on_average(dlrm_pool):
    """Reduced-budget version of Table 1 (one task size)."""
    sim = CostSimulator(seed=0)
    train, test = make_benchmark_suite(dlrm_pool, n_tables=20, n_devices=4,
                                       n_tasks=12)
    ds = DreamShard(train, sim, DreamShardConfig(n_iterations=6, n_cost=150,
                                                 n_rl=10))
    ds.train()
    ours = ds.evaluate_tasks(test)
    rng = np.random.default_rng(0)
    scores = {"random": np.mean([sim.evaluate(
        t.raw_features, B.random_place(t.raw_features, 4,
                                       sim.spec.mem_capacity_gb, rng),
        4).overall for t in test])}
    for s in B.EXPERT_STRATEGIES:
        scores[s] = np.mean([sim.evaluate(
            t.raw_features, B.expert_place(t.raw_features, 4,
                                           sim.spec.mem_capacity_gb, s),
            4).overall for t in test])
    # must beat random clearly and be at least competitive with the best
    # expert (within 3%; usually better)
    assert ours < scores["random"] * 0.9
    assert ours < min(scores.values()) * 1.03, (ours, scores)


def test_estimated_mdp_saves_measurements(dlrm_pool):
    """Fig 8 mechanism: training touches hardware only N_collect times per
    iteration regardless of RL update volume."""
    sim = CostSimulator(seed=0)
    train, _ = make_benchmark_suite(dlrm_pool, n_tables=10, n_devices=2,
                                    n_tasks=4)
    cfg = DreamShardConfig(n_iterations=2, n_collect=5, n_cost=20, n_rl=30,
                           n_episode=10)
    ds = DreamShard(train, sim, cfg)
    ds.train()
    # 2 iterations x 5 collects = 10 measurements; the 600 RL episodes were
    # free (estimated MDP)
    assert sim.num_evaluations == 10


def test_inference_needs_no_measurements(dlrm_pool):
    sim = CostSimulator(seed=0)
    train, test = make_benchmark_suite(dlrm_pool, n_tables=10, n_devices=2,
                                       n_tasks=4)
    ds = DreamShard(train, sim, DreamShardConfig(n_iterations=1, n_cost=20,
                                                 n_rl=5))
    ds.train()
    before = sim.num_evaluations
    ds.place(test[0].raw_features, 2)
    assert sim.num_evaluations == before        # Algorithm 2: no hardware


def test_ablation_without_cost_features_runs(dlrm_pool):
    sim = CostSimulator(seed=0)
    train, _ = make_benchmark_suite(dlrm_pool, n_tables=10, n_devices=2,
                                    n_tasks=4)
    cfg = DreamShardConfig(n_iterations=1, n_cost=20, n_rl=5,
                           use_cost_features=False)
    ds = DreamShard(train, sim, cfg)
    ds.train()
    a = ds.place(train[0].raw_features, 2)
    assert a.shape == (10,)


def test_feature_drop_ablation_runs(dlrm_pool):
    sim = CostSimulator(seed=0)
    train, _ = make_benchmark_suite(dlrm_pool, n_tables=10, n_devices=2,
                                    n_tasks=4)
    cfg = DreamShardConfig(n_iterations=1, n_cost=20, n_rl=5,
                           feature_drop="pooling")
    ds = DreamShard(train, sim, cfg)
    ds.train()
    assert ds.place(train[0].raw_features, 2).shape == (10,)


def test_flash_attention_matches_naive():
    """Blockwise attention == materialized softmax attention."""
    from repro.models.layers import flash_attention
    rng = np.random.default_rng(0)
    B, S, H, hd = 2, 128, 4, 32
    q = jnp.asarray(rng.normal(size=(B, S, H, hd)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, S, H, hd)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, S, H, hd)), jnp.float32)
    out = flash_attention(q, k, v, causal=True, q_chunk=32, kv_chunk=32)

    s = jnp.einsum("bqhd,bkhd->bhqk", q, k) / np.sqrt(hd)
    mask = np.tril(np.ones((S, S), bool))
    s = jnp.where(mask[None, None], s, -1e30)
    ref = jnp.einsum("bhqk,bkhd->bqhd", jax.nn.softmax(s, -1), v)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-4, atol=2e-4)


def test_flash_attention_sliding_window():
    from repro.models.layers import flash_attention
    rng = np.random.default_rng(1)
    B, S, H, hd, W = 1, 64, 2, 16, 16
    q = jnp.asarray(rng.normal(size=(B, S, H, hd)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, S, H, hd)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, S, H, hd)), jnp.float32)
    out = flash_attention(q, k, v, causal=True, window=W, q_chunk=16,
                          kv_chunk=16)
    s = jnp.einsum("bqhd,bkhd->bhqk", q, k) / np.sqrt(hd)
    qp, kp = np.arange(S)[:, None], np.arange(S)[None, :]
    mask = (qp >= kp) & (qp - kp < W)
    s = jnp.where(mask[None, None], s, -1e30)
    ref = jnp.einsum("bhqk,bkhd->bqhd", jax.nn.softmax(s, -1), v)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("arch", ["h2o-danube-1.8b", "rwkv6-1.6b",
                                  "hymba-1.5b", "musicgen-large",
                                  "qwen2.5-14b"])
def test_decode_matches_prefill_continuation_all_families(arch):
    """decode_step(t) == forward logits at position t across families --
    validates KV-cache positions AND the SSM/RWKV recurrent state handoff
    between the scan (prefill) and single-step (decode) paths."""
    from repro import configs as C
    from repro.launch import steps as ST
    cfg = C.get_smoke(arch).resolve(1)
    model = ST.build_model(cfg, remat=False, q_chunk=16, kv_chunk=16)
    params = model.init_params(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    S = 24
    tokens = jnp.asarray(rng.integers(0, cfg.vocab, (1, S)), jnp.int32)
    full_logits, _ = model.forward(params, tokens)
    _, cache = model.prefill(params, tokens[:, :-1], capacity=S)
    dec_logits, cache2 = model.decode_step(params, cache, tokens[:, -1:])
    assert int(cache2["pos"]) == S
    np.testing.assert_allclose(
        np.asarray(dec_logits[0, 0]).astype(np.float32),
        np.asarray(full_logits[0, -1]).astype(np.float32),
        rtol=0.1, atol=0.2)   # bf16 accumulation tolerance


def test_decode_matches_prefill_continuation():
    """decode_step(t) logits == forward logits at position t."""
    from repro import configs as C
    from repro.launch import steps as ST
    cfg = C.get_smoke("h2o-danube-1.8b").resolve(1)
    model = ST.build_model(cfg, remat=False, q_chunk=16, kv_chunk=16)
    params = model.init_params(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    S = 33   # odd length: flash pads internally
    tokens = jnp.asarray(rng.integers(0, cfg.vocab, (1, S)), jnp.int32)
    full_logits, _ = model.forward(params, tokens)
    _, cache = model.prefill(params, tokens[:, :-1], capacity=S)
    dec_logits, _ = model.decode_step(params, cache, tokens[:, -1:])
    np.testing.assert_allclose(
        np.asarray(dec_logits[0, 0]).astype(np.float32),
        np.asarray(full_logits[0, -1]).astype(np.float32),
        rtol=0.1, atol=0.15)   # bf16 accumulation tolerance
