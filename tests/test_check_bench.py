"""The CI benchmark-regression gate: passes on matching runs, fails on a
synthetic 30% slowdown, on eval-cost drift, on a violated fusion
invariant, and on vacuously-empty comparisons."""

import copy
import importlib.util
import json
import os

import pytest

_SPEC = importlib.util.spec_from_file_location(
    "check_bench", os.path.join(os.path.dirname(__file__), "..",
                                "benchmarks", "check_bench.py"))
check_bench = importlib.util.module_from_spec(_SPEC)
_SPEC.loader.exec_module(check_bench)

TRAIN = {
    "benchmark": "b6_train_throughput",
    "regimes": {"scale": {
        "config": {"n_iterations": 10, "n_collect": 100, "n_cost": 300,
                   "n_batch": 8, "n_rl": 10, "n_episode": 10},
        "per_iteration_speedup": 5.0,
        "seed": {"eval_cost_ms": 19.5128},
        "fused": {"eval_cost_ms": 19.5128},
    }},
}
ORACLE = {
    "benchmark": "b7_oracle_throughput",
    "regimes": {"scale": {
        "n_placements": 2000,
        "oracles": {"sim": {"speedup": 40.0},
                    "measured": {"speedup": 200.0}},
    }},
}
FUSION = {
    "benchmark": "b8_fusion_model",
    "mode": "full",
    "accuracy": {"mape_fusion_aware": 0.27, "mape_additive": 1.04},
    "determinism": {"mean_overall_fused": 1.5290863313,
                    "mean_overall_additive": 3.1231791824},
}
TELEMETRY = {
    "benchmark": "b10_telemetry_overhead",
    "limits": {"offpath_pct": 1.0, "enabled_pct": 5.0},
    "regimes": {"scale": {"offpath_overhead_pct": 0.17,
                          "enabled_overhead_pct": 1.7}},
}
SERVE = {
    "benchmark": "b11_serve",
    "limits": {"hit_speedup_p50": 20.0, "min_hit_rate": 0.5},
    "regimes": {"quick": {
        "config": {"n_jobs": 6, "n_requests": 400},
        "cold": {"p50_ms": 3.0, "p99_ms": 5.2},
        "hit_speedup_p50": 118.4,
        "legs": {
            "drift": {"hit_rate": 0.79, "hit": {"p50_ms": 0.025,
                                                "p99_ms": 0.072},
                      "bytes_moved_gb": 0.28,
                      "end_to_end_cost_ms": 7033.8,
                      "request_cost_mean_ms": 17.4},
            "never": {"hit_rate": 0.91, "hit": {"p50_ms": 0.01,
                                                "p99_ms": 0.05},
                      "bytes_moved_gb": 0.0,
                      "end_to_end_cost_ms": 8530.2,
                      "request_cost_mean_ms": 21.3},
            "always": {"hit_rate": 0.90, "hit": {"p50_ms": None,
                                                 "p99_ms": None},
                       "bytes_moved_gb": 31.0,
                       "end_to_end_cost_ms": 7430.2,
                       "request_cost_mean_ms": 16.6},
        },
        "determinism": {"requests": 24, "replaces": 0,
                        "zero_drift_identical": True},
    }},
}


RESILIENCE = {
    "benchmark": "b12_resilience",
    "limits": {"max_recovery_ratio": 0.25, "min_served": 1.0},
    "regimes": {"quick": {
        "config": {"n_jobs": 6, "n_requests": 400, "loss_device": 1},
        "faulted": {
            "requests": 404, "served": 404, "served_fraction": 1.0,
            "uncaught_exceptions": 0, "illegal_placements": 0,
            "outage_on_lost": 0, "evacuations": 6,
            "recovery": {"affected_entries": 6,
                         "scratch_bytes_gb": 4.55,
                         "recovery_bytes_gb": 0.69,
                         "recovery_ratio": 0.152,
                         "recovery_latency_ms": 23.7},
        },
        "determinism": {"deterministic_replay": True},
        "warm_restart": {"checkpoint_at": 260,
                         "warm_restart_identical": True},
    }},
}


def _gate(tmp_path, baseline, fresh, extra=()):
    b = tmp_path / "baseline.json"
    f = tmp_path / "fresh.json"
    b.write_text(json.dumps(baseline))
    f.write_text(json.dumps(fresh))
    return check_bench.main(["--pair", str(b), str(f), *extra])


@pytest.mark.parametrize("doc", [TRAIN, ORACLE, FUSION, TELEMETRY, SERVE,
                                 RESILIENCE])
def test_identical_runs_pass(tmp_path, doc):
    assert _gate(tmp_path, doc, copy.deepcopy(doc)) == 0


def test_thirty_percent_slowdown_fails(tmp_path):
    """The acceptance scenario: a synthetic 30% throughput regression
    trips the 25% gate."""
    fresh = copy.deepcopy(TRAIN)
    fresh["regimes"]["scale"]["per_iteration_speedup"] = 5.0 * 0.7
    assert _gate(tmp_path, TRAIN, fresh) == 1
    fresh = copy.deepcopy(ORACLE)
    fresh["regimes"]["scale"]["oracles"]["sim"]["speedup"] = 40.0 * 0.7
    assert _gate(tmp_path, ORACLE, fresh) == 1


def test_small_wobble_passes(tmp_path):
    fresh = copy.deepcopy(TRAIN)
    fresh["regimes"]["scale"]["per_iteration_speedup"] = 5.0 * 0.85
    assert _gate(tmp_path, TRAIN, fresh) == 0


def test_eval_cost_drift_fails(tmp_path):
    fresh = copy.deepcopy(TRAIN)
    fresh["regimes"]["scale"]["fused"]["eval_cost_ms"] = 19.8
    assert _gate(tmp_path, TRAIN, fresh) == 1
    # a looser leg-specific rtol admits the same drift
    assert _gate(tmp_path, TRAIN, fresh, extra=("--eval-rtol", "0.05")) == 0


def test_determinism_drift_fails(tmp_path):
    fresh = copy.deepcopy(FUSION)
    fresh["determinism"]["mean_overall_fused"] = 1.531
    assert _gate(tmp_path, FUSION, fresh) == 1


def test_fusion_invariant_violation_fails(tmp_path):
    fresh = copy.deepcopy(FUSION)
    fresh["accuracy"] = {"mape_fusion_aware": 1.2, "mape_additive": 1.0}
    assert _gate(tmp_path, FUSION, fresh) == 1
    # smoke runs don't gate the (noisy, tiny-sweep) MAPE invariant
    fresh["mode"] = "smoke"
    assert _gate(tmp_path, FUSION, fresh) == 0


def test_mismatched_config_refuses_to_pass(tmp_path):
    """A fresh run whose regime config differs (e.g. a smoke budget) has
    no comparable cells -- the gate fails instead of passing vacuously."""
    fresh = copy.deepcopy(TRAIN)
    fresh["regimes"]["scale"]["config"]["n_collect"] = 20
    assert _gate(tmp_path, TRAIN, fresh) == 1


def test_benchmark_kind_mismatch_fails(tmp_path):
    assert _gate(tmp_path, TRAIN, copy.deepcopy(ORACLE)) == 1


def test_serve_invariants_gate_on_fresh(tmp_path):
    """b11 gates the FRESH run's serving invariants: hit speedup and hit
    rate over the pinned limits, drift beating both strawmen, and the
    zero-drift identity."""
    fresh = copy.deepcopy(SERVE)
    fresh["regimes"]["quick"]["hit_speedup_p50"] = 12.0
    assert _gate(tmp_path, SERVE, fresh) == 1
    fresh = copy.deepcopy(SERVE)
    fresh["regimes"]["quick"]["legs"]["drift"]["hit_rate"] = 0.3
    assert _gate(tmp_path, SERVE, fresh) == 1
    # drift policy must beat never-re-place on end-to-end cost...
    fresh = copy.deepcopy(SERVE)
    fresh["regimes"]["quick"]["legs"]["drift"]["end_to_end_cost_ms"] = 9000.0
    assert _gate(tmp_path, SERVE, fresh) == 1
    # ...while moving fewer bytes than always-re-place
    fresh = copy.deepcopy(SERVE)
    fresh["regimes"]["quick"]["legs"]["drift"]["bytes_moved_gb"] = 40.0
    assert _gate(tmp_path, SERVE, fresh) == 1
    fresh = copy.deepcopy(SERVE)
    fresh["regimes"]["quick"]["determinism"]["zero_drift_identical"] = False
    assert _gate(tmp_path, SERVE, fresh) == 1
    # loosened fresh limits must not relax the gate
    fresh = copy.deepcopy(SERVE)
    fresh["limits"] = {"hit_speedup_p50": 1.0, "min_hit_rate": 0.0}
    assert _gate(tmp_path, SERVE, fresh) == 1


def test_serve_never_leg_cost_drift_fails(tmp_path):
    """The one drift-gated b11 cell: the timing-independent never-leg
    request cost, on config-matched regimes only."""
    fresh = copy.deepcopy(SERVE)
    fresh["regimes"]["quick"]["legs"]["never"]["request_cost_mean_ms"] = 22.1
    assert _gate(tmp_path, SERVE, fresh) == 1
    assert _gate(tmp_path, SERVE, fresh, extra=("--eval-rtol", "0.1")) == 0
    # config mismatch: the drift cell is skipped, invariants still gate
    fresh["regimes"]["quick"]["config"] = {"n_jobs": 2, "n_requests": 10}
    assert _gate(tmp_path, SERVE, fresh) == 0


def test_serve_empty_fresh_refuses_to_pass(tmp_path):
    """A fresh b11 file with no regimes has no checkable cells beyond
    the limits pin -- the gate must fail rather than pass vacuously."""
    fresh = {"benchmark": "b11_serve", "limits": dict(SERVE["limits"]),
             "regimes": {}}
    assert _gate(tmp_path, SERVE, fresh) == 1


def test_resilience_invariants_gate_on_fresh(tmp_path):
    """b12 gates the FRESH run's acceptance criteria: full service under
    faults, recovery bytes under the scratch ratio, deterministic
    replay, and warm-restart identity."""
    fresh = copy.deepcopy(RESILIENCE)
    fresh["regimes"]["quick"]["faulted"]["served_fraction"] = 0.99
    assert _gate(tmp_path, RESILIENCE, fresh) == 1
    fresh = copy.deepcopy(RESILIENCE)
    fresh["regimes"]["quick"]["faulted"]["uncaught_exceptions"] = 1
    assert _gate(tmp_path, RESILIENCE, fresh) == 1
    fresh = copy.deepcopy(RESILIENCE)
    fresh["regimes"]["quick"]["faulted"]["illegal_placements"] = 2
    assert _gate(tmp_path, RESILIENCE, fresh) == 1
    fresh = copy.deepcopy(RESILIENCE)
    fresh["regimes"]["quick"]["faulted"]["recovery"]["recovery_ratio"] = 0.3
    assert _gate(tmp_path, RESILIENCE, fresh) == 1
    fresh = copy.deepcopy(RESILIENCE)
    fresh["regimes"]["quick"]["determinism"]["deterministic_replay"] = False
    assert _gate(tmp_path, RESILIENCE, fresh) == 1
    fresh = copy.deepcopy(RESILIENCE)
    fresh["regimes"]["quick"]["warm_restart"]["warm_restart_identical"] = \
        False
    assert _gate(tmp_path, RESILIENCE, fresh) == 1
    # a run where the loss never touched the cache proves nothing
    fresh = copy.deepcopy(RESILIENCE)
    fresh["regimes"]["quick"]["faulted"]["recovery"]["affected_entries"] = 0
    fresh["regimes"]["quick"]["faulted"]["evacuations"] = 0
    assert _gate(tmp_path, RESILIENCE, fresh) == 1
    # loosened fresh limits must not relax the gate
    fresh = copy.deepcopy(RESILIENCE)
    fresh["limits"] = {"max_recovery_ratio": 0.9, "min_served": 0.5}
    assert _gate(tmp_path, RESILIENCE, fresh) == 1


def test_resilience_empty_fresh_refuses_to_pass(tmp_path):
    fresh = {"benchmark": "b12_resilience",
             "limits": dict(RESILIENCE["limits"]), "regimes": {}}
    assert _gate(tmp_path, RESILIENCE, fresh) == 1


def test_telemetry_overhead_gates_on_fresh_limits(tmp_path):
    """b10 gates the FRESH file's percentages (host-independent), with
    the committed limits pinned against silent loosening."""
    fresh = copy.deepcopy(TELEMETRY)
    fresh["regimes"]["scale"]["offpath_overhead_pct"] = 1.3
    assert _gate(tmp_path, TELEMETRY, fresh) == 1
    fresh = copy.deepcopy(TELEMETRY)
    fresh["regimes"]["scale"]["enabled_overhead_pct"] = 6.2
    assert _gate(tmp_path, TELEMETRY, fresh) == 1
    # loosened fresh limits must not relax the gate
    fresh = copy.deepcopy(TELEMETRY)
    fresh["limits"] = {"offpath_pct": 10.0, "enabled_pct": 50.0}
    assert _gate(tmp_path, TELEMETRY, fresh) == 1
