"""DLRM + distributed embedding substrate: plan grouping, oracle lookup,
sharded==oracle equality (subprocess with fake devices), gradient flow."""

import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import features as F
from repro.data.synthetic import make_dlrm_pool
from repro.embedding import sharded as E
from repro.embedding.plan import build_plan
from repro.models.dlrm import DLRM, DLRMConfig


@pytest.fixture(scope="module")
def setup():
    pool = make_dlrm_pool(seed=0)
    M, S = 8, 4
    raw = pool[:M].copy()
    raw[:, F.HASH_SIZE] = np.clip(raw[:, F.HASH_SIZE], 0, 500)
    assign = np.arange(M) % S
    plan = build_plan(raw, assign, S)
    cfg = DLRMConfig(n_dense_features=4, embed_dim=plan.dim,
                     bottom_mlp=(32,), top_mlp=(64, 32), n_tables=M)
    model = DLRM(cfg, plan)
    params = model.init_params(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    B, P = 16, 5
    idx = np.where(rng.random((B, M, P)) < 0.2, -1,
                   rng.integers(0, 400, (B, M, P))).astype(np.int32)
    return model, params, plan, raw, idx, rng


def _oracle(plan):
    return lambda a, b, i: E.lookup_unsharded(a, plan.base_rows, i, plan)


def test_group_indices_roundtrip(setup):
    model, params, plan, raw, idx, rng = setup
    gidx = E.group_indices(plan, idx)
    assert gidx.shape == (idx.shape[0], plan.n_shards * plan.k_max,
                          idx.shape[2])
    order = plan.grouped_index_order()
    for slot, table in enumerate(order):
        if table >= 0:
            np.testing.assert_array_equal(gidx[:, slot], idx[:, table])
        else:
            assert (gidx[:, slot] == -1).all()


def test_forward_finite(setup):
    model, params, plan, raw, idx, rng = setup
    gidx = jnp.asarray(E.group_indices(plan, idx))
    dense = jnp.asarray(rng.normal(size=(16, 4)), jnp.float32)
    logits = model.forward(params, dense, gidx, _oracle(plan))
    assert logits.shape == (16,)
    assert np.isfinite(np.asarray(logits)).all()


def test_gradients_reach_arenas(setup):
    model, params, plan, raw, idx, rng = setup
    gidx = jnp.asarray(E.group_indices(plan, idx))
    dense = jnp.asarray(rng.normal(size=(16, 4)), jnp.float32)
    labels = jnp.asarray(rng.integers(0, 2, 16), jnp.float32)

    def loss(p):
        return DLRM.loss(model.forward(p, dense, gidx, _oracle(plan)), labels)

    g = jax.grad(loss)(params)
    assert float(jnp.abs(g["arenas"]).max()) > 0
    assert float(jnp.abs(g["bottom"][0]["w"]).max()) > 0
    # zero rows receive no gradient weight updates beyond scatter artifacts
    assert np.isfinite(np.asarray(g["arenas"])).all()


def test_bce_loss_bounds():
    logits = jnp.asarray([-5.0, 0.0, 5.0])
    labels = jnp.asarray([0.0, 1.0, 1.0])
    loss = float(DLRM.loss(logits, labels))
    assert 0 < loss < 1.0


_SHARDED_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys; sys.path.insert(0, sys.argv[1])
import numpy as np, jax, jax.numpy as jnp
from repro.core import features as F
from repro.data.synthetic import make_dlrm_pool
from repro.embedding.plan import build_plan
from repro.embedding import sharded as E

pool = make_dlrm_pool(seed=0)
M, S = 8, 4
raw = pool[:M].copy()
raw[:, F.HASH_SIZE] = np.clip(raw[:, F.HASH_SIZE], 0, 500)
plan = build_plan(raw, np.arange(M) % S, S)
arenas = E.init_arenas(jax.random.PRNGKey(0), plan)
rng = np.random.default_rng(0)
B, P = 16, 5
idx = np.where(rng.random((B, M, P)) < 0.2, -1,
               rng.integers(0, 400, (B, M, P))).astype(np.int32)
gidx = jnp.asarray(E.group_indices(plan, idx))
bases = jnp.asarray(plan.base_rows)
ref = E.lookup_unsharded(arenas, plan.base_rows, gidx, plan)
import contextlib
try:                                        # jax >= 0.6
    mesh = jax.make_mesh((2, 4), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)
except (AttributeError, TypeError):         # older jax
    mesh = jax.sharding.Mesh(np.array(jax.devices()).reshape(2, 4),
                             ("data", "model"))
lookup = E.make_sharded_lookup(mesh, plan)
ctx = jax.set_mesh(mesh) if hasattr(jax, "set_mesh") \
    else contextlib.nullcontext()
with ctx:
    out = lookup(arenas, bases, gidx)
assert np.allclose(np.asarray(out), np.asarray(ref), atol=1e-5), "mismatch"
print("SHARDED_OK")
"""


def test_sharded_lookup_matches_oracle_8dev():
    src = os.path.join(os.path.dirname(__file__), "..", "src")
    r = subprocess.run([sys.executable, "-c", _SHARDED_SCRIPT, src],
                       capture_output=True, text=True, timeout=600)
    assert "SHARDED_OK" in r.stdout, r.stdout + r.stderr
