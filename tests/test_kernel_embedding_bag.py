"""Pallas fused embedding-bag kernel vs the pure-jnp oracle: shape/dtype
sweep in interpret mode + gradient check (per-kernel requirement)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.embedding_bag import ops
from repro.kernels.embedding_bag.kernel import embedding_bag_fused
from repro.kernels.embedding_bag.ref import (embedding_bag_grad_ref,
                                             embedding_bag_ref)


@pytest.mark.parametrize("rows", [8, 100, 1000])
@pytest.mark.parametrize("dim", [128, 256])
@pytest.mark.parametrize("pool", [1, 4, 16])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_kernel_matches_oracle(rows, dim, pool, dtype):
    rng = np.random.default_rng(rows * dim + pool)
    arena = jnp.asarray(rng.normal(size=(rows, dim)), dtype)
    idx = jnp.asarray(rng.integers(0, rows, (12, pool)), jnp.int32)
    out = embedding_bag_fused(arena, idx, interpret=True)
    ref = embedding_bag_ref(arena, idx)
    tol = 1e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=tol, atol=tol)


def test_zero_row_padding():
    rng = np.random.default_rng(0)
    arena = jnp.asarray(rng.normal(size=(50, 128)), jnp.float32)
    arena = arena.at[0].set(0.0)
    idx = jnp.zeros((4, 8), jnp.int32)             # all padded -> zeros
    out = embedding_bag_fused(arena, idx, interpret=True)
    np.testing.assert_allclose(np.asarray(out), 0.0)


def test_multi_table_lookup_matches_ref():
    rng = np.random.default_rng(1)
    tables = [jnp.asarray(rng.normal(size=(r, d)), jnp.float32)
              for r, d in [(64, 16), (32, 48), (128, 16), (16, 128)]]
    arena, bases = ops.build_arena(tables)
    idx = rng.integers(0, 16, (4, 6, 7))
    idx[rng.random(idx.shape) < 0.25] = -1
    idx = jnp.asarray(idx, jnp.int32)
    out = ops.fused_embedding_lookup(arena, bases, idx)
    ref = ops.fused_embedding_lookup_ref(arena, bases, idx)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)


def test_arena_layout():
    tables = [jnp.ones((10, 16)), jnp.ones((5, 64))]
    arena, bases = ops.build_arena(tables)
    assert arena.shape == (16, 128)                # 1 zero row + 10 + 5
    np.testing.assert_array_equal(bases, [1, 11])
    np.testing.assert_allclose(np.asarray(arena[0]), 0.0)
    np.testing.assert_allclose(np.asarray(arena[1, :16]), 1.0)
    np.testing.assert_allclose(np.asarray(arena[1, 16:]), 0.0)


def test_custom_vjp_matches_grad_ref():
    rng = np.random.default_rng(2)
    arena = jnp.asarray(rng.normal(size=(30, 128)), jnp.float32)
    idx = jnp.asarray(rng.integers(1, 30, (6, 4)), jnp.int32)

    def loss(a):
        return (ops.embedding_bag(a, idx) ** 2).sum()

    g = jax.grad(loss)(arena)
    out = embedding_bag_ref(arena, idx)
    gref = embedding_bag_grad_ref(arena.shape, np.asarray(idx),
                                  2 * np.asarray(out))
    np.testing.assert_allclose(np.asarray(g), gref, rtol=1e-4, atol=1e-4)


def test_kernel_jits_and_caches():
    arena = jnp.ones((16, 128), jnp.float32)
    idx = jnp.ones((4, 2), jnp.int32)
    o1 = ops.embedding_bag(arena, idx)
    o2 = ops.embedding_bag(arena, idx)
    np.testing.assert_array_equal(np.asarray(o1), np.asarray(o2))
