"""Hypothesis property-based tests on system invariants."""

import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import baselines as B  # noqa: E402
from repro.core import features as F  # noqa: E402
from repro.data.synthetic import make_pool  # noqa: E402
from repro.embedding.plan import build_plan  # noqa: E402
from repro.sim.costsim import CostSimulator  # noqa: E402

table_counts = st.integers(min_value=2, max_value=40)
device_counts = st.sampled_from([1, 2, 4, 8])
seeds = st.integers(min_value=0, max_value=2**31 - 1)


def _pool(n, seed, dim_mode="dlrm"):
    return make_pool(n, seed=seed % 1000, dim_mode=dim_mode)


@settings(max_examples=25, deadline=None)
@given(n=table_counts, d=device_counts, seed=seeds)
def test_expert_placement_covers_all_tables(n, d, seed):
    pool = _pool(n, seed)
    for s in B.EXPERT_STRATEGIES:
        a = B.expert_place(pool, d, 1e9, s)
        assert a.shape == (n,)
        assert ((a >= 0) & (a < d)).all()


@settings(max_examples=25, deadline=None)
@given(n=table_counts, d=device_counts, seed=seeds)
def test_greedy_balances_better_than_worst_case(n, d, seed):
    """Greedy max-load <= total (trivial) and >= total/d (pigeonhole)."""
    pool = _pool(n, seed)
    costs = pool[:, F.DIM] * pool[:, F.POOLING]
    a = B.expert_place(pool, d, 1e9, "lookup")
    loads = np.array([costs[a == k].sum() for k in range(d)])
    assert loads.max() >= costs.sum() / d - 1e-9
    # greedy LPT bound: max load <= (4/3 - 1/(3d)) * OPT <= 4/3 * total/d + max
    assert loads.max() <= costs.sum() / d + costs.max() + 1e-9


@settings(max_examples=20, deadline=None)
@given(n=st.integers(4, 30), d=device_counts, seed=seeds)
def test_sim_fused_op_monotone_in_tables(n, d, seed):
    """Adding a table to a fused op never makes it faster (per-device).

    NOTE: the *overall* placement cost is legitimately non-monotone --
    removing tables can worsen the all-to-all imbalance congestion
    (Table 4) -- so monotonicity is asserted on the fused op itself.
    """
    pool = _pool(n, seed)
    sim = CostSimulator(noise_std=0.0)
    rng = np.random.default_rng(seed % 997)
    a = rng.integers(0, d, n)
    r_full = sim.evaluate(pool, a, d)
    assert r_full.overall > 0
    fwd_all, bwd_all = sim.fused_op_ms(pool)
    fwd_half, bwd_half = sim.fused_op_ms(pool[: n // 2])
    assert fwd_all >= fwd_half - 1e-9
    assert bwd_all >= bwd_half - 1e-9


@settings(max_examples=20, deadline=None)
@given(n=st.integers(2, 20), seed=seeds)
def test_fused_cheaper_than_unfused(n, seed):
    """Fusion wins on average; cache contention between co-resident tables
    can eat at most a small fraction of the pipelining gain."""
    pool = _pool(n, seed)
    sim = CostSimulator(noise_std=0.0)
    fwd, bwd = sim.fused_op_ms(pool)
    assert fwd <= sim.single_table_ms(pool).sum() * 1.15 + 1e-9
    assert fwd > 0 and bwd > 0


@settings(max_examples=25, deadline=None)
@given(n=table_counts, d=device_counts, seed=seeds)
def test_plan_partitions_tables_exactly_once(n, d, seed):
    pool = _pool(n, seed)
    rng = np.random.default_rng(seed % 991)
    a = rng.integers(0, d, n)
    plan = build_plan(pool, a, d)
    seen = plan.slot_table[plan.slot_table >= 0]
    assert sorted(seen.tolist()) == list(range(n))
    # arena rows never overlap: base + rows <= next base within a shard
    for s in range(d):
        live = plan.slot_table[s] >= 0
        bases = plan.base_rows[s][live]
        rows = plan.table_rows[plan.slot_table[s][live]]
        ends = bases + rows
        assert (bases[1:] >= ends[:-1]).all() if len(bases) > 1 else True
        assert (ends <= plan.rows_max).all()


@settings(max_examples=15, deadline=None)
@given(seed=seeds)
def test_feature_normalization_bounded(seed):
    pool = _pool(50, seed, dim_mode="prod")
    norm = F.normalize_features(pool)
    assert np.isfinite(norm).all()
    assert (norm >= -0.01).all() and (norm <= 3.0).all()
    # distribution bins pass through untouched and sum to 1
    np.testing.assert_allclose(norm[:, F.DIST_START:].sum(1), 1.0, rtol=1e-5)


@settings(max_examples=10, deadline=None)
@given(seed=seeds, d=device_counts)
def test_random_placement_legal_when_feasible(seed, d):
    pool = _pool(20, seed)
    sim = CostSimulator()
    rng = np.random.default_rng(seed % 1009)
    a = B.random_place(pool, d, sim.spec.mem_capacity_gb, rng)
    total = pool[:, F.TABLE_SIZE_GB].sum()
    if total <= d * sim.spec.mem_capacity_gb * 0.5:
        assert sim.legal(pool, a, d)
