"""B12: resilient serving -- fault injection, failover, degraded fallbacks.

PR 9 adds the fault-tolerance layer to ``repro.serve``: a deterministic
``FaultInjector`` schedule (device loss/recovery, transient oracle
errors, decode-latency spikes), failover re-placement of affected cache
entries onto the surviving mesh, a deadline-aware decode fallback chain
(DreamShard -> expert -> greedy-legal), and warm-restart checkpoints.
This benchmark replays a drifting ``repro.data.traffic`` trace against
an injected failure schedule and measures what the layer guarantees:

* **faulted leg** -- the full trace with a device lost mid-stream (and
  recovered later), armed transient oracle errors, and decode spikes
  that bust the deadline.  Reports the served fraction (every request
  must complete with a legal placement or a typed ``ServeError`` --
  zero uncaught exceptions), the degraded-request fraction, recovery
  latency (the submit that absorbs the loss event, failover sweep
  included), and recovery bytes moved vs a re-place-from-scratch
  rebuild of the same affected entries (greedy size-balance on the
  survivors, no incumbent knowledge);
* **determinism** -- the same schedule replayed twice (the service on a
  virtual clock, so admission timing is part of the replayed state)
  must serve bitwise-identical assignments with identical provenance;
* **warm restart** -- the run checkpointed mid-outage
  (``PlacementService.save``) and resumed in a fresh service must match
  the uninterrupted run's assignments exactly.

Writes ``BENCH_resilience.json`` (committed at the repo root); the
``check_resilience`` gate pins the acceptance criteria: served fraction
1.0, recovery moving <= ``max_recovery_ratio`` of the scratch-rebuild
bytes, deterministic replay, and warm-restart identity.
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import time

import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from benchmarks import common as C                             # noqa: E402
from repro.api import ensure_oracle                            # noqa: E402
from repro.core import features as F                           # noqa: E402
from repro.core.baselines import expert_place                  # noqa: E402
from repro.core.trainer import DreamShardConfig                # noqa: E402
from repro.data.tasks import sample_tasks, split_pool          # noqa: E402
from repro.data.traffic import TrafficConfig, make_trace       # noqa: E402
from repro.serve import (FaultEvent, FaultInjector,            # noqa: E402
                         FaultSchedule, PlacementService, ServeConfig)

ROOT = os.path.join(os.path.dirname(__file__), "..")

# acceptance limits, committed with the baseline (the gate re-proves
# them on every fresh run and refuses silent relaxation)
LIMITS = {"max_recovery_ratio": 0.25, "min_served": 1.0}

# fixed per-regime configs: smoke runs the quick regime at its FULL
# config, so the check_bench gate always has comparable cells.  The
# 8-device mesh matters: losing one device strands ~1/8 of placed
# bytes, so minimal-movement recovery can genuinely beat the
# <=25%-of-scratch bound (on a 4-device mesh the stranded share alone
# is ~25% -- no recovery can win)
REGIMES = {
    "quick": {
        "dataset": "DLRM", "n_jobs": 6, "n_tables": 16, "n_devices": 8,
        "n_requests": 400, "drift": 0.8, "zipf": 1.0, "tail_jobs": 4,
        "trainer": "reduced", "max_wait_ms": 2.0, "max_batch": 8,
        "ewma_alpha": 0.3, "drift_threshold": 0.05,
        "migration_ms_per_gb": 25.0, "replace_max_evals": 64,
        "failover_max_evals": 64, "decode_deadline_ms": 25.0,
        "oracle_retries": 2, "seed": 0,
        # the failure schedule (request indices; committed so the gate
        # can prove the replay deterministic against the same faults)
        "loss_device": 1, "loss_at": 200, "recover_at": 320,
        "oracle_error_at": [120, 240], "oracle_error_count": 2,
        "spike_at": [80, 360], "spike_ms": 50.0,
        "checkpoint_at": 260,
    },
    "paper": {
        "dataset": "DLRM", "n_jobs": 12, "n_tables": 50, "n_devices": 8,
        "n_requests": 1500, "drift": 0.8, "zipf": 1.0, "tail_jobs": 8,
        "trainer": "paper", "max_wait_ms": 2.0, "max_batch": 8,
        "ewma_alpha": 0.3, "drift_threshold": 0.05,
        "migration_ms_per_gb": 25.0, "replace_max_evals": 96,
        "failover_max_evals": 96, "decode_deadline_ms": 25.0,
        "oracle_retries": 2, "seed": 0,
        "loss_device": 1, "loss_at": 750, "recover_at": 1200,
        "oracle_error_at": [400, 900], "oracle_error_count": 2,
        "spike_at": [300, 1350], "spike_ms": 50.0,
        "checkpoint_at": 1000,
    },
}


def _trainer_cfg(kind: str) -> DreamShardConfig:
    if kind == "paper":
        return DreamShardConfig()
    return DreamShardConfig(n_iterations=3, n_collect=6, n_cost=100,
                            n_batch=32, n_rl=5, n_episode=10,
                            inference_candidates=8)


def _serve_cfg(spec: dict) -> ServeConfig:
    return ServeConfig(
        max_wait_ms=spec["max_wait_ms"], max_batch=spec["max_batch"],
        ewma_alpha=spec["ewma_alpha"],
        drift_threshold=spec["drift_threshold"],
        migration_ms_per_gb=spec["migration_ms_per_gb"],
        replace_max_evals=spec["replace_max_evals"],
        failover_max_evals=spec["failover_max_evals"],
        decode_deadline_ms=spec["decode_deadline_ms"],
        oracle_retries=spec["oracle_retries"], seed=spec["seed"])


def _schedule(spec: dict) -> FaultSchedule:
    events = [FaultEvent(at=spec["loss_at"], kind="device_loss",
                         device=spec["loss_device"]),
              FaultEvent(at=spec["recover_at"], kind="device_recovery",
                         device=spec["loss_device"])]
    for at in spec["oracle_error_at"]:
        events.append(FaultEvent(at=at, kind="oracle_error",
                                 count=spec["oracle_error_count"]))
    for at in spec["spike_at"]:
        events.append(FaultEvent(at=at, kind="decode_spike",
                                 spike_ms=spec["spike_ms"]))
    return FaultSchedule(tuple(events))


class _VirtualClock:
    """Deterministic time source for the service: one fixed quantum per
    request, so admission flush/coalesce decisions (and therefore drift
    re-place trigger points) replay bitwise across legs.  Wall-clock
    measurements (recovery latency, throughput) still use
    ``time.perf_counter`` in the harness."""

    STEP_MS = 1.0

    def __init__(self):
        self.t = 0.0

    def __call__(self) -> float:
        return self.t

    def tick(self) -> None:
        self.t += self.STEP_MS / 1e3


def _scratch_rebuild_gb(svc, lost_device: int, capacity_gb: float) -> dict:
    """What a no-incumbent rebuild of the affected entries would move:
    every cached placement touching the lost device re-placed from
    scratch (greedy size-balance over the survivors), bytes counted
    against the incumbent it replaces."""
    scratch_gb, total_gb, affected = 0.0, 0.0, 0
    for _, e in svc.cache.items():
        a = e.placement.assignment
        if not (a == lost_device).any() or e.raw is None:
            continue
        affected += 1
        D = e.placement.n_devices
        survivors = np.array([d for d in range(D) if d != lost_device])
        sizes = e.raw[:, F.TABLE_SIZE_GB]
        compressed = expert_place(e.raw, survivors.size, capacity_gb,
                                  "size")
        rebuilt = survivors[compressed]
        scratch_gb += float(((rebuilt != a) * sizes).sum())
        total_gb += float(sizes.sum())
    return {"affected_entries": affected,
            "scratch_bytes_gb": round(scratch_gb, 4),
            "affected_total_gb": round(total_gb, 4)}


def _replay(agent, oracle, trace, spec: dict,
            checkpoint_dir: str | None = None) -> dict:
    """One faulted replay -> completed results (with completion index),
    fault/recovery measurements, and the service's final stats.  The
    service runs on a ``_VirtualClock`` so every leg sees identical
    admission timing.  With ``checkpoint_dir`` the service is
    checkpointed at ``checkpoint_at`` requests (queued tickets
    included -- no drain), torn down, and warm-restarted for the rest
    of the trace."""
    clock = _VirtualClock()
    faults = FaultInjector(_schedule(spec))
    svc = PlacementService(agent, oracle=oracle, config=_serve_cfg(spec),
                           faults=faults, clock=clock)
    completed: list[tuple[int, object]] = []   # (completion index, result)
    uncaught = 0
    recovery_latency_ms = None
    scratch = None
    t0 = time.perf_counter()
    for i, r in enumerate(trace):
        if checkpoint_dir is not None and i == spec["checkpoint_at"]:
            svc.save(checkpoint_dir)
            faults = FaultInjector(_schedule(spec))
            svc = PlacementService.restore(
                checkpoint_dir, agent=agent, oracle=oracle,
                config=_serve_cfg(spec), faults=faults, clock=clock)
        clock.tick()
        if i == spec["loss_at"]:
            # the loss event fires inside this submit; snapshot the
            # incumbents first so the scratch comparator sees the same
            # affected set the failover sweep does
            scratch = _scratch_rebuild_gb(svc, spec["loss_device"],
                                          svc.oracle.mem_capacity_gb)
            t_loss = time.perf_counter()
        try:
            out = svc.submit(r.raw_features, r.n_devices, tag=i)
        except Exception:
            uncaught += 1
            out = []
        if i == spec["loss_at"]:
            recovery_latency_ms = (time.perf_counter() - t_loss) * 1e3
        for res in out:
            completed.append((i, res))
    for res in svc.flush():
        completed.append((len(trace), res))
    wall = time.perf_counter() - t0
    return {"completed": completed, "uncaught": uncaught,
            "recovery_latency_ms": recovery_latency_ms,
            "scratch": scratch, "stats": svc.stats(), "wall_s": wall}


def _legal(oracle, trace, res) -> bool:
    r = trace[res.tag]
    return bool(oracle.legal(r.raw_features, res.placement.assignment,
                             r.n_devices))


def _faulted_leg(oracle, trace, spec: dict, run: dict) -> dict:
    completed, stats = run["completed"], run["stats"]
    n = len(trace)
    by_source: dict[str, int] = {}
    degraded = 0
    illegal = 0
    outage_on_lost = 0
    for at, res in completed:
        by_source[res.source] = by_source.get(res.source, 0) + 1
        if res.degraded is not None or res.source in ("fallback", "error"):
            degraded += 1
        if res.placement is not None:
            if not _legal(oracle, trace, res):
                illegal += 1
            if spec["loss_at"] <= at < spec["recover_at"] and \
                    (res.placement.assignment == spec["loss_device"]).any():
                outage_on_lost += 1
    served = sum(1 for _, r in completed
                 if r.placement is not None or r.error is not None)
    scratch = run["scratch"]
    recovery_gb = stats["failover_bytes_gb"]
    ratio = (recovery_gb / scratch["scratch_bytes_gb"]
             if scratch and scratch["scratch_bytes_gb"] > 0 else None)
    return {
        "requests": n,
        "served": served,
        "served_fraction": round(served / n, 4),
        "uncaught_exceptions": run["uncaught"],
        "illegal_placements": illegal,
        "outage_on_lost": outage_on_lost,
        "by_source": by_source,
        "degraded_requests": degraded,
        "degraded_fraction": round(degraded / n, 4),
        "typed_errors": stats["typed_errors"],
        "recovery": {
            **(scratch or {}),
            "recovery_latency_ms": round(run["recovery_latency_ms"], 2),
            "recovery_bytes_gb": round(recovery_gb, 4),
            "recovery_ratio": round(ratio, 4) if ratio is not None
            else None,
        },
        "evacuations": stats["evacuations"],
        "evacuation_failures": stats["evacuation_failures"],
        "fallbacks": stats["fallbacks"],
        "repairs": stats["repairs"],
        "deadline_skips": stats["deadline_skips"],
        "retries": stats["retries"],
        "retry_exhausted": stats["retry_exhausted"],
        "invalidations": stats["invalidations"],
        # ledger values are in virtual-clock ms (1 ms/request quantum)
        "latency_virtual": {k: (round(v, 4) if v == v else None)
                            for k, v in stats["latency"].items()},
        "wall_s": round(run["wall_s"], 2),
        "requests_per_s": round(n / run["wall_s"], 1),
    }


def _same_serving(a: list, b: list) -> bool:
    """Two completed-result streams serve identically: same per-tag
    assignments, provenance, and typed-error codes."""
    if len(a) != len(b):
        return False
    by_tag_a = {res.tag: res for _, res in a}
    by_tag_b = {res.tag: res for _, res in b}
    if set(by_tag_a) != set(by_tag_b):
        return False
    for tag, ra in by_tag_a.items():
        rb = by_tag_b[tag]
        if (ra.placement is None) != (rb.placement is None):
            return False
        if ra.placement is not None and not np.array_equal(
                ra.placement.assignment, rb.placement.assignment):
            return False
        if (ra.error.code if ra.error else None) != \
                (rb.error.code if rb.error else None):
            return False
    return True


def _run_regime(name: str, spec: dict, workdir: str) -> dict:
    pool = C.get_pool(spec["dataset"])
    sim = C.get_sim(spec["dataset"])
    oracle = ensure_oracle(sim)
    train_ids, _ = split_pool(pool, seed=0)
    train = sample_tasks(pool, train_ids, spec["n_tables"],
                         spec["n_devices"], 8, seed=0, name="resil-train")
    with C.Timer() as t_train:
        agent = C.train_dreamshard(train, sim, _trainer_cfg(spec["trainer"]))

    cfg = TrafficConfig(n_jobs=spec["n_jobs"], n_tables=spec["n_tables"],
                        n_devices=spec["n_devices"],
                        n_requests=spec["n_requests"], drift=spec["drift"],
                        zipf=spec["zipf"], tail_jobs=spec["tail_jobs"],
                        seed=spec["seed"])
    trace = make_trace(pool, cfg)

    run1 = _replay(agent, oracle, trace, spec)
    faulted = _faulted_leg(oracle, trace, spec, run1)
    print({"regime": name, "served_fraction": faulted["served_fraction"],
           "recovery_ratio": faulted["recovery"]["recovery_ratio"],
           "degraded_fraction": faulted["degraded_fraction"]}, flush=True)

    # same schedule replayed twice: provenance and assignments bitwise
    run2 = _replay(agent, oracle, trace, spec)
    deterministic = _same_serving(run1["completed"], run2["completed"])

    # checkpoint mid-outage, restore into a fresh service, finish the
    # trace: must serve what the uninterrupted replay served
    ckpt = os.path.join(workdir, f"b12_ckpt_{name}")
    warm = _replay(agent, oracle, trace, spec, checkpoint_dir=ckpt)
    warm_identical = _same_serving(run1["completed"], warm["completed"])

    row = {
        "config": spec,
        "train_s": round(t_train.s, 1),
        "faulted": faulted,
        "determinism": {"deterministic_replay": bool(deterministic)},
        "warm_restart": {"checkpoint_at": spec["checkpoint_at"],
                         "warm_restart_identical": bool(warm_identical)},
        "schedule": json.loads(_schedule(spec).to_json()),
    }
    print({"regime": name, "deterministic_replay": deterministic,
           "warm_restart_identical": warm_identical}, flush=True)
    return row


def run(smoke: bool = False, out: str | None = None,
        regimes: list[str] | None = None):
    selected = ["quick"] if smoke else list(REGIMES)
    if regimes:
        selected = [r for r in selected if r in regimes] or \
            [r for r in REGIMES if r in regimes]
        if not selected:
            raise SystemExit(f"no such regime(s) {regimes}")

    result = {
        "benchmark": "b12_resilience",
        "schema": 1,
        "mode": "smoke" if smoke else "full",
        "generated": time.strftime("%Y-%m-%dT%H:%M:%S"),
        "host": {"cpu_count": os.cpu_count(), "numpy": np.__version__},
        "limits": dict(LIMITS),
        "regimes": {},
    }
    import tempfile
    with tempfile.TemporaryDirectory() as workdir:
        for name in selected:
            result["regimes"][name] = _run_regime(name, REGIMES[name],
                                                  workdir)

    head_name = "paper" if "paper" in result["regimes"] \
        else next(iter(result["regimes"]))
    reg = result["regimes"][head_name]
    result["headline"] = {
        "regime": head_name,
        "served_fraction": reg["faulted"]["served_fraction"],
        "uncaught_exceptions": reg["faulted"]["uncaught_exceptions"],
        "degraded_fraction": reg["faulted"]["degraded_fraction"],
        "recovery_ratio": reg["faulted"]["recovery"]["recovery_ratio"],
        "recovery_latency_ms":
            reg["faulted"]["recovery"]["recovery_latency_ms"],
        "recovery_bytes_gb":
            reg["faulted"]["recovery"]["recovery_bytes_gb"],
        "scratch_bytes_gb":
            reg["faulted"]["recovery"]["scratch_bytes_gb"],
        "deterministic_replay":
            reg["determinism"]["deterministic_replay"],
        "warm_restart_identical":
            reg["warm_restart"]["warm_restart_identical"],
    }
    if not smoke:
        # the PR's acceptance criteria, asserted at the source
        for name in result["regimes"]:
            f = result["regimes"][name]["faulted"]
            assert f["served_fraction"] >= LIMITS["min_served"], \
                f"{name}: not every request was served"
            assert f["uncaught_exceptions"] == 0, \
                f"{name}: an exception escaped submit()"
            assert f["illegal_placements"] == 0, \
                f"{name}: an illegal placement was served"
            assert f["outage_on_lost"] == 0, \
                f"{name}: a placement touched the lost device mid-outage"
            assert f["recovery"]["recovery_ratio"] <= \
                LIMITS["max_recovery_ratio"], \
                f"{name}: failover moved more than " \
                f"{LIMITS['max_recovery_ratio']:.0%} of scratch bytes"
            assert result["regimes"][name]["determinism"][
                "deterministic_replay"], f"{name}: replay diverged"
            assert result["regimes"][name]["warm_restart"][
                "warm_restart_identical"], f"{name}: warm restart diverged"
    out = out or os.path.join(ROOT, "BENCH_resilience.json")
    with open(out, "w") as f:
        json.dump(result, f, indent=2)
        f.write("\n")
    print({"headline": result["headline"], "written": os.path.abspath(out)},
          flush=True)
    return result


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--smoke", action="store_true",
                    help="quick regime only (same config as full: the "
                         "bench gate stays comparable)")
    ap.add_argument("--out", default=None, help="output JSON path")
    ap.add_argument("--regimes", default=None,
                    help="comma-separated regime subset (quick, paper)")
    ap.add_argument("--trace", default=None, metavar="PATH",
                    help="record telemetry and export a trace on exit "
                         "(.jsonl -> event log, else Chrome trace JSON)")
    args = ap.parse_args()
    from repro import telemetry as tele
    with tele.trace_to(args.trace):
        run(smoke=args.smoke, out=args.out,
            regimes=args.regimes.split(",") if args.regimes else None)
