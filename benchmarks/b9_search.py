"""B9: search-augmented placement -- cost vs anytime budget.

DreamShard's policy emits one placement per task; Pre-train-and-Search
(PAPERS.md) shows a cheap cost model turns placement into a search
problem.  PR 4 made oracle queries nearly free here (evaluate_many,
BENCH_oracle.json), so this benchmark measures what that buys: seed each
task with the trained agent's proposal, refine it with ``SearchPlacer``,
and trace the **anytime curve** -- mean placement cost as a function of
the oracle-row budget -- for all three strategy families (LNS,
evolution, beam), plus one wall-clock headline row at the 50 ms/task
budget the acceptance criterion names.  A ``CachedOracle`` leg re-runs
the refinement to expose search's cache locality (batched hit-rate).

Regimes (fixed configs, so smoke CI runs gate against the committed
baseline):

* ``quick`` -- DLRM-20 (4), reduced trainer budget; CI-sized;
* ``paper`` -- DLRM-50 (4), the paper's Algorithm-1 budget (full only).

Writes ``BENCH_search.json`` (committed at the repo root).  Full mode
asserts the acceptance criterion: RL+search at <= 50 ms/task strictly
improves mean cost over DreamShard-only on the paper-scale suite.
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import time

import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from benchmarks import common as C                             # noqa: E402
from repro.api import (CachedOracle, SearchConfig,             # noqa: E402
                       SearchPlacer, ensure_oracle,
                       measure_placements)
from repro.core.trainer import DreamShardConfig                # noqa: E402
from repro.data.tasks import make_benchmark_suite              # noqa: E402

ROOT = os.path.join(os.path.dirname(__file__), "..")

# fixed per-regime configs: smoke runs the quick regime at its FULL
# config, so the check_bench gate always has a comparable cell
REGIMES = {
    "quick": {
        "dataset": "DLRM", "n_tables": 20, "n_devices": 4, "n_tasks": 8,
        "trainer": "reduced",
    },
    "paper": {
        "dataset": "DLRM", "n_tables": 50, "n_devices": 4, "n_tasks": 16,
        "trainer": "paper",
    },
}
CURVE_EVALS = [0, 8, 32, 128]         # deterministic anytime-budget axis
STRATEGIES = ["lns", "evolution", "beam"]
HEADLINE_BUDGET_MS = 50.0


def _trainer_cfg(kind: str) -> DreamShardConfig:
    if kind == "paper":
        return DreamShardConfig()
    return DreamShardConfig(n_iterations=3, n_collect=6, n_cost=100,
                            n_batch=32, n_rl=5, n_episode=10,
                            inference_candidates=8)


def _mean_cost(sim, tasks, placements) -> float:
    return float(np.mean(measure_placements(
        ensure_oracle(sim), tasks, placements)))


def _curve(sim, agent, tasks, strategy: str) -> dict:
    """Mean cost at each row budget -- monotone by construction."""
    costs, hw_evals = [], []
    for max_evals in CURVE_EVALS:
        oracle = ensure_oracle(sim)
        sp = SearchPlacer(oracle, seed_placer=agent.as_placer(),
                          agent=agent,
                          config=SearchConfig(strategy=strategy,
                                              budget_ms=None,
                                              max_evals=max_evals, seed=0))
        n0 = oracle.num_evaluations      # counter lives on the shared sim
        placements = sp.place_many(tasks)
        hw_evals.append(int(oracle.num_evaluations - n0))
        costs.append(round(_mean_cost(sim, tasks, placements), 4))
    return {"max_evals": CURVE_EVALS, "mean_cost_ms": costs,
            "oracle_evals_total": hw_evals}


def _headline(sim, agent, tasks) -> dict:
    """The acceptance row: LNS at a 50 ms/task wall-clock budget."""
    oracle = ensure_oracle(sim)
    sp = C.make_search_placer(oracle, agent,
                              budget_ms=HEADLINE_BUDGET_MS, seed=0)
    evals, ms = [], []
    placements = []
    for task, seed in zip(tasks, agent.as_placer().place_many(tasks)):
        t0 = time.perf_counter()
        placements.append(sp.refine(task, seed))
        ms.append((time.perf_counter() - t0) * 1e3)
        evals.append(sp.last_scorer.evals)
    return {
        "strategy": "lns", "budget_ms": HEADLINE_BUDGET_MS,
        "mean_cost_ms": round(_mean_cost(sim, tasks, placements), 4),
        "mean_wall_ms_per_task": round(float(np.mean(ms)), 2),
        "mean_evals_per_task": round(float(np.mean(evals)), 1),
    }


def _cache_leg(sim, agent, tasks) -> dict:
    """Refine the same suite twice through one CachedOracle: the second
    pass is pure cache (search proposals are deterministic per seed)."""
    cached = CachedOracle(sim)
    hardware = []
    for _ in range(2):
        sp = SearchPlacer(cached, seed_placer=agent.as_placer(),
                          agent=agent,
                          config=SearchConfig(strategy="lns",
                                              budget_ms=None,
                                              max_evals=128, seed=0))
        n0 = cached.num_evaluations
        sp.place_many(tasks)
        hardware.append(cached.num_evaluations - n0)
    batch_total = cached.batch_hits + cached.batch_misses
    return {
        "batched_calls": cached.batched_calls,
        "batched_hit_rate": round(
            cached.batch_hits / batch_total if batch_total else 0.0, 4),
        "hardware_evals_pass1": hardware[0],
        "hardware_evals_pass2": hardware[1],
    }


def _run_regime(name: str, spec: dict) -> dict:
    pool = C.get_pool(spec["dataset"])
    sim = C.get_sim(spec["dataset"])
    train, test = make_benchmark_suite(pool, spec["n_tables"],
                                       spec["n_devices"],
                                       n_tasks=spec["n_tasks"], seed=0)
    with C.Timer() as t_train:
        agent = C.train_dreamshard(train, sim, _trainer_cfg(spec["trainer"]))
    ds_cost = _mean_cost(sim, test, agent.as_placer().place_many(test))

    curves = {}
    for strategy in STRATEGIES:
        curves[strategy] = _curve(sim, agent, test, strategy)
        print({"regime": name, "strategy": strategy, **curves[strategy]},
              flush=True)
    headline = _headline(sim, agent, test)
    cache = _cache_leg(sim, agent, test)
    row = {
        "config": spec,
        "dreamshard_mean_cost_ms": round(ds_cost, 4),
        "curves": curves,
        "headline_budget": headline,
        "cache": cache,
        "train_s": round(t_train.s, 1),
    }
    gain = (ds_cost / headline["mean_cost_ms"] - 1) * 100 \
        if headline["mean_cost_ms"] else 0.0
    row["search_gain_pct"] = round(gain, 2)
    print({"regime": name, "dreamshard": row["dreamshard_mean_cost_ms"],
           "rl_search_50ms": headline["mean_cost_ms"],
           "gain_pct": row["search_gain_pct"]}, flush=True)
    return row


def run(smoke: bool = False, out: str | None = None,
        regimes: list[str] | None = None):
    selected = ["quick"] if smoke else list(REGIMES)
    if regimes:
        selected = [r for r in selected if r in regimes] or \
            [r for r in REGIMES if r in regimes]
        if not selected:
            raise SystemExit(f"no such regime(s) {regimes}")

    result = {
        "benchmark": "b9_search",
        "schema": 1,
        "mode": "smoke" if smoke else "full",
        "generated": time.strftime("%Y-%m-%dT%H:%M:%S"),
        "curve_evals": CURVE_EVALS,
        "host": {"cpu_count": os.cpu_count(), "numpy": np.__version__},
        "regimes": {},
    }
    for name in selected:
        result["regimes"][name] = _run_regime(name, REGIMES[name])

    head_name = "paper" if "paper" in result["regimes"] \
        else next(iter(result["regimes"]))
    reg = result["regimes"][head_name]
    result["headline"] = {
        "regime": head_name,
        "dreamshard_mean_cost_ms": reg["dreamshard_mean_cost_ms"],
        "rl_search_mean_cost_ms": reg["headline_budget"]["mean_cost_ms"],
        "budget_ms": HEADLINE_BUDGET_MS,
        "search_gain_pct": reg["search_gain_pct"],
        "cache_batched_hit_rate": reg["cache"]["batched_hit_rate"],
    }
    if not smoke:
        # the PR's acceptance criterion, asserted at the source
        assert reg["headline_budget"]["mean_cost_ms"] < \
            reg["dreamshard_mean_cost_ms"], \
            "RL+search at 50 ms/task did not strictly improve on " \
            "DreamShard-only"
    out = out or os.path.join(ROOT, "BENCH_search.json")
    with open(out, "w") as f:
        json.dump(result, f, indent=2)
        f.write("\n")
    print({"headline": result["headline"], "written": os.path.abspath(out)},
          flush=True)
    return result


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--smoke", action="store_true",
                    help="quick regime only (same config as full: the "
                         "bench gate stays comparable)")
    ap.add_argument("--out", default=None, help="output JSON path")
    ap.add_argument("--regimes", default=None,
                    help="comma-separated regime subset (quick, paper)")
    ap.add_argument("--trace", default=None, metavar="PATH",
                    help="record telemetry and export a trace on exit "
                         "(.jsonl -> event log, else Chrome trace JSON)")
    args = ap.parse_args()
    from repro import telemetry as tele
    with tele.trace_to(args.trace):
        run(smoke=args.smoke, out=args.out,
            regimes=args.regimes.split(",") if args.regimes else None)
