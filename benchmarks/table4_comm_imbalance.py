"""Paper Table 4: all-to-all communication time vs per-device dim-sum
imbalance (16 tables x dim 64, batch 65536, 4 devices)."""

from __future__ import annotations

import numpy as np

from benchmarks import common as C


CASES = [
    ("perfectly_balanced", [256, 256, 256, 256]),
    ("slightly_imbalanced_1", [192, 256, 320, 256]),
    ("slightly_imbalanced_2", [192, 192, 320, 320]),
    ("slightly_imbalanced_3", [128, 192, 320, 384]),
    ("very_imbalanced_1", [128, 128, 384, 384]),
    ("very_imbalanced_2", [64, 128, 384, 448]),
    ("very_imbalanced_3", [64, 64, 448, 448]),
    ("very_imbalanced_4", [64, 64, 64, 832]),
]


def run():
    sim = C.get_sim("DLRM", noise_std=0.0)
    rows = []
    for name, dims in CASES:
        comm = sim.comm_ms(np.asarray(dims, float), 4)
        rows.append({"case": name, "dim_sums": dims,
                     "per_device_ms": [round(x, 2) for x in comm],
                     "max_ms": round(float(comm.max()), 2)})
        print(rows[-1], flush=True)
    assert rows[0]["max_ms"] <= rows[-1]["max_ms"]
    return rows


if __name__ == "__main__":
    run()
