"""Benchmark-regression gate for CI.

Compares a fresh benchmark JSON against its committed baseline and fails
(non-zero exit) on a throughput regression or any eval-cost drift,
instead of silently uploading artifacts.  Usage:

    python benchmarks/check_bench.py \
        --pair BENCH_train.json fresh/BENCH_train.json \
        --pair BENCH_oracle.json fresh/BENCH_oracle.json \
        --pair BENCH_fusion.json fresh/BENCH_fusion.json \
        [--tolerance 0.25] [--exact-rtol 1e-6]

Rules, per benchmark kind (detected from the "benchmark" field):

- Throughput metrics are DIMENSIONLESS speedups (batched-vs-loop,
  fused-vs-seed), so they compare across hosts; a fresh value more than
  ``tolerance`` below baseline fails.  Host-dependent absolutes
  (placements/sec, wall seconds) are never gated.
- Eval metrics are deterministic model outputs; any drift beyond a
  tight rtol fails.  Two knobs because the noise floors differ:
  ``--eval-rtol`` covers trained-agent eval cost (goes through XLA, so
  jax version and host microarchitecture move floats -- pass a looser
  value for unpinned-jax legs) and ``--exact-rtol`` covers the fusion
  benchmark's synthetic-oracle fingerprint (pure numpy, essentially
  bit-stable everywhere).
- Only regimes whose CONFIG matches between baseline and fresh are
  compared (a smoke run with a different budget is not comparable);
  if a pair has no comparable cell at all, the gate fails rather than
  silently passing.
- b8 additionally re-asserts the fusion invariant on the fresh run:
  the fusion-aware MAPE must stay below the additive MAPE (full mode;
  smoke runs carry too little sweep data to gate timing MAPEs).
"""

from __future__ import annotations

import argparse
import json
import sys


def _rel_drop(baseline: float, fresh: float) -> float:
    return (baseline - fresh) / baseline if baseline > 0 else 0.0


def _drift(baseline: float, fresh: float) -> float:
    scale = max(abs(baseline), 1e-12)
    return abs(fresh - baseline) / scale


class Gate:
    def __init__(
        self, tolerance: float, eval_rtol: float, exact_rtol: float
    ):
        self.tolerance = tolerance
        self.eval_rtol = eval_rtol
        self.exact_rtol = exact_rtol
        self.failures: list[str] = []
        self.checked = 0

    def throughput(self, name: str, baseline: float, fresh: float) -> None:
        self.checked += 1
        drop = _rel_drop(baseline, fresh)
        status = "FAIL" if drop > self.tolerance else "ok"
        print(
            f"  [{status}] {name}: baseline {baseline:g} -> fresh "
            f"{fresh:g} ({-drop:+.1%})"
        )
        if drop > self.tolerance:
            self.failures.append(
                f"{name} regressed {drop:.1%} (> {self.tolerance:.0%}): "
                f"{baseline:g} -> {fresh:g}"
            )

    def _drift_check(
        self, name: str, baseline: float, fresh: float, rtol: float
    ) -> None:
        self.checked += 1
        drift = _drift(baseline, fresh)
        status = "FAIL" if drift > rtol else "ok"
        print(
            f"  [{status}] {name}: baseline {baseline!r} vs fresh "
            f"{fresh!r} (drift {drift:.2e})"
        )
        if drift > rtol:
            self.failures.append(
                f"{name} drifted {drift:.2e} (> rtol {rtol:g}): "
                f"{baseline!r} -> {fresh!r}"
            )

    def eval_cost(self, name: str, baseline: float, fresh: float) -> None:
        self._drift_check(name, baseline, fresh, self.eval_rtol)

    def exact(self, name: str, baseline: float, fresh: float) -> None:
        self._drift_check(name, baseline, fresh, self.exact_rtol)

    def invariant(self, name: str, ok: bool, detail: str) -> None:
        self.checked += 1
        print(f"  [{'ok' if ok else 'FAIL'}] {name}: {detail}")
        if not ok:
            self.failures.append(f"{name} violated: {detail}")


def _matched_regimes(baseline: dict, fresh: dict) -> list[str]:
    """Regime names present in both files with identical configs."""
    out = []
    for name, base_reg in baseline.get("regimes", {}).items():
        fresh_reg = fresh.get("regimes", {}).get(name)
        if fresh_reg is None:
            continue
        keys = ("config", "n_placements")
        if all(base_reg.get(k) == fresh_reg.get(k) for k in keys):
            out.append(name)
    return out


def check_train(gate: Gate, baseline: dict, fresh: dict) -> None:
    for regime in _matched_regimes(baseline, fresh):
        b, f = baseline["regimes"][regime], fresh["regimes"][regime]
        gate.throughput(
            f"b6.{regime}.per_iteration_speedup",
            b["per_iteration_speedup"],
            f["per_iteration_speedup"],
        )
        for variant in ("seed", "fused"):
            gate.eval_cost(
                f"b6.{regime}.{variant}.eval_cost_ms",
                b[variant]["eval_cost_ms"],
                f[variant]["eval_cost_ms"],
            )


def check_oracle(gate: Gate, baseline: dict, fresh: dict) -> None:
    for regime in _matched_regimes(baseline, fresh):
        b = baseline["regimes"][regime]["oracles"]
        f = fresh["regimes"][regime]["oracles"]
        for oracle in b:
            if oracle in f:
                gate.throughput(
                    f"b7.{regime}.{oracle}.speedup",
                    b[oracle]["speedup"],
                    f[oracle]["speedup"],
                )


def check_fusion(gate: Gate, baseline: dict, fresh: dict) -> None:
    for key in ("mean_overall_fused", "mean_overall_additive"):
        gate.exact(
            f"b8.determinism.{key}",
            baseline["determinism"][key],
            fresh["determinism"][key],
        )
    if fresh.get("mode") == "full":
        acc = fresh["accuracy"]
        gate.invariant(
            "b8.fusion_beats_additive",
            acc["mape_fusion_aware"] < acc["mape_additive"],
            f"fusion-aware MAPE {acc['mape_fusion_aware']} vs additive "
            f"{acc['mape_additive']}",
        )


def check_search(gate: Gate, baseline: dict, fresh: dict) -> None:
    """b9: search must (still) beat the RL-only policy, and the anytime
    curves must stay monotone.  Invariants run on the FRESH file -- they
    hold per-host by construction, so every smoke run re-proves them --
    while eval-cost drift is gated only on config-matched regimes."""
    for name, reg in fresh.get("regimes", {}).items():
        head = reg["headline_budget"]
        gate.invariant(
            f"b9.{name}.search_beats_dreamshard",
            head["mean_cost_ms"] <= reg["dreamshard_mean_cost_ms"],
            f"RL+search {head['mean_cost_ms']} ms vs DreamShard-only "
            f"{reg['dreamshard_mean_cost_ms']} ms "
            f"at {head['budget_ms']} ms/task",
        )
        for strategy, curve in reg["curves"].items():
            costs = curve["mean_cost_ms"]
            gate.invariant(
                f"b9.{name}.{strategy}.anytime_monotone",
                all(b <= a + 1e-9 for a, b in zip(costs, costs[1:])),
                f"cost vs max_evals {curve['max_evals']}: {costs}",
            )
    for regime in _matched_regimes(baseline, fresh):
        b, f = baseline["regimes"][regime], fresh["regimes"][regime]
        gate.eval_cost(
            f"b9.{regime}.dreamshard_mean_cost_ms",
            b["dreamshard_mean_cost_ms"],
            f["dreamshard_mean_cost_ms"],
        )
        for strategy in b["curves"]:
            if strategy in f["curves"] and \
                    b["curves"][strategy]["max_evals"] == \
                    f["curves"][strategy]["max_evals"]:
                gate.eval_cost(
                    f"b9.{regime}.{strategy}.curve_final_cost",
                    b["curves"][strategy]["mean_cost_ms"][-1],
                    f["curves"][strategy]["mean_cost_ms"][-1],
                )


def check_telemetry(gate: Gate, baseline: dict, fresh: dict) -> None:
    """b10: the telemetry overhead bounds are absolute invariants on the
    FRESH run (host-independent by design -- both are relative
    percentages), re-proven every CI leg; the committed baseline only
    pins the limits themselves."""
    limits = fresh.get("limits", {})
    off_limit = limits.get("offpath_pct", 1.0)
    on_limit = limits.get("enabled_pct", 5.0)
    for name, reg in fresh.get("regimes", {}).items():
        gate.invariant(
            f"b10.{name}.offpath_under_{off_limit}pct",
            reg["offpath_overhead_pct"] < off_limit,
            f"disabled-path overhead {reg['offpath_overhead_pct']}% "
            f"(limit {off_limit}%)",
        )
        gate.invariant(
            f"b10.{name}.enabled_under_{on_limit}pct",
            reg["enabled_overhead_pct"] < on_limit,
            f"enabled overhead {reg['enabled_overhead_pct']}% "
            f"(limit {on_limit}%)",
        )
    gate.invariant(
        "b10.limits_match_baseline",
        baseline.get("limits") == fresh.get("limits"),
        f"baseline limits {baseline.get('limits')} vs fresh "
        f"{fresh.get('limits')}",
    )


def check_serve(gate: Gate, baseline: dict, fresh: dict) -> None:
    """b11: serving invariants on the FRESH run (host-independent --
    hit-vs-cold ratios and policy orderings, not absolute latencies),
    with limits pinned against the committed baseline.  The only
    drift-gated cell is the ``never`` leg's request cost: its
    placements are one decode per job, independent of admission
    timing, so it is reproducible per (host, jax) like b9's costs."""
    limits = fresh.get("limits", {})
    speedup_limit = limits.get("hit_speedup_p50", 20.0)
    rate_limit = limits.get("min_hit_rate", 0.5)
    gate.invariant(
        "b11.fresh_has_regimes",
        bool(fresh.get("regimes")),
        f"fresh regimes measured: {sorted(fresh.get('regimes', {}))}",
    )
    for name, reg in fresh.get("regimes", {}).items():
        legs, cold = reg["legs"], reg["cold"]
        drift = legs["drift"]
        gate.invariant(
            f"b11.{name}.hit_speedup_p50_over_{speedup_limit}x",
            drift["hit"]["p50_ms"] is not None
            and reg["hit_speedup_p50"] >= speedup_limit,
            f"warm hit p50 {drift['hit']['p50_ms']} ms vs cold place p50 "
            f"{cold['p50_ms']} ms (speedup {reg['hit_speedup_p50']}x, "
            f"limit {speedup_limit}x)",
        )
        gate.invariant(
            f"b11.{name}.hit_rate_over_{rate_limit}",
            drift["hit_rate"] >= rate_limit,
            f"drift-leg hit rate {drift['hit_rate']} "
            f"(limit {rate_limit})",
        )
        gate.invariant(
            f"b11.{name}.hit_p99_under_cold_p50",
            drift["hit"]["p99_ms"] is not None
            and drift["hit"]["p99_ms"] <= cold["p50_ms"],
            f"hit p99 {drift['hit']['p99_ms']} ms vs cold p50 "
            f"{cold['p50_ms']} ms",
        )
        gate.invariant(
            f"b11.{name}.drift_beats_never",
            drift["end_to_end_cost_ms"]
            < legs["never"]["end_to_end_cost_ms"],
            f"end-to-end drift {drift['end_to_end_cost_ms']} ms vs "
            f"never-re-place {legs['never']['end_to_end_cost_ms']} ms",
        )
        gate.invariant(
            f"b11.{name}.drift_moves_fewer_bytes_than_always",
            drift["bytes_moved_gb"] < legs["always"]["bytes_moved_gb"],
            f"drift moved {drift['bytes_moved_gb']} GB vs always "
            f"{legs['always']['bytes_moved_gb']} GB",
        )
        gate.invariant(
            f"b11.{name}.zero_drift_replay_identical",
            reg["determinism"]["zero_drift_identical"],
            f"zero-drift replay vs place_many: {reg['determinism']}",
        )
    gate.invariant(
        "b11.limits_match_baseline",
        baseline.get("limits") == fresh.get("limits"),
        f"baseline limits {baseline.get('limits')} vs fresh "
        f"{fresh.get('limits')}",
    )
    for regime in _matched_regimes(baseline, fresh):
        b, f = baseline["regimes"][regime], fresh["regimes"][regime]
        gate.eval_cost(
            f"b11.{regime}.never_leg_request_cost_mean",
            b["legs"]["never"]["request_cost_mean_ms"],
            f["legs"]["never"]["request_cost_mean_ms"],
        )


def check_resilience(gate: Gate, baseline: dict, fresh: dict) -> None:
    """b12: the resilience acceptance criteria are host-independent
    invariants, re-proven on the FRESH run every leg (served fraction,
    legality, recovery-vs-scratch bytes, deterministic replay, warm
    restart); the committed baseline pins the limits so they cannot be
    silently relaxed."""
    limits = fresh.get("limits", {})
    max_ratio = limits.get("max_recovery_ratio", 0.25)
    min_served = limits.get("min_served", 1.0)
    gate.invariant(
        "b12.fresh_has_regimes",
        bool(fresh.get("regimes")),
        f"fresh regimes measured: {sorted(fresh.get('regimes', {}))}",
    )
    for name, reg in fresh.get("regimes", {}).items():
        f, rec = reg["faulted"], reg["faulted"]["recovery"]
        gate.invariant(
            f"b12.{name}.every_request_served",
            f["served_fraction"] >= min_served
            and f["uncaught_exceptions"] == 0,
            f"served {f['served']}/{f['requests']}, "
            f"{f['uncaught_exceptions']} uncaught exception(s)",
        )
        gate.invariant(
            f"b12.{name}.no_illegal_placements",
            f["illegal_placements"] == 0 and f["outage_on_lost"] == 0,
            f"{f['illegal_placements']} illegal, {f['outage_on_lost']} "
            "served on the lost device mid-outage",
        )
        gate.invariant(
            f"b12.{name}.failover_exercised",
            rec.get("affected_entries", 0) > 0 and f["evacuations"] > 0,
            f"{rec.get('affected_entries')} entries affected by the "
            f"loss, {f['evacuations']} evacuated",
        )
        gate.invariant(
            f"b12.{name}.recovery_under_{max_ratio}_of_scratch",
            rec["recovery_ratio"] is not None
            and rec["recovery_ratio"] <= max_ratio,
            f"failover moved {rec['recovery_bytes_gb']} GB vs scratch "
            f"rebuild {rec['scratch_bytes_gb']} GB "
            f"(ratio {rec['recovery_ratio']}, limit {max_ratio})",
        )
        gate.invariant(
            f"b12.{name}.deterministic_replay",
            reg["determinism"]["deterministic_replay"],
            f"schedule replayed twice: {reg['determinism']}",
        )
        gate.invariant(
            f"b12.{name}.warm_restart_identical",
            reg["warm_restart"]["warm_restart_identical"],
            f"checkpoint at {reg['warm_restart']['checkpoint_at']} "
            "requests, restored leg vs uninterrupted run",
        )
    gate.invariant(
        "b12.limits_match_baseline",
        baseline.get("limits") == fresh.get("limits"),
        f"baseline limits {baseline.get('limits')} vs fresh "
        f"{fresh.get('limits')}",
    )


def check_sharding(gate: Gate, baseline: dict, fresh: dict) -> None:
    """b13: the sharding acceptance criteria are host-independent
    invariants, re-proven on the FRESH run every leg (oversized tasks
    stay whole-table-infeasible yet shard-legal, the K = 1 identity
    fingerprint holds bitwise, refinement never regresses); the pure-
    numpy sharded cost is additionally pinned at the exact rtol on
    config-matched regimes."""
    limits = fresh.get("limits", {})
    max_whole = limits.get("max_whole_table_legal_fraction", 0.0)
    min_shard = limits.get("min_sharded_legal_fraction", 1.0)
    gate.invariant(
        "b13.fresh_has_regimes",
        bool(fresh.get("regimes")),
        f"fresh regimes measured: {sorted(fresh.get('regimes', {}))}",
    )
    for name, reg in fresh.get("regimes", {}).items():
        f, ident = reg["feasibility"], reg["k1_identity"]
        gate.invariant(
            f"b13.{name}.whole_table_infeasible",
            f["whole_table_legal_fraction"] <= max_whole,
            f"{f['whole_table_legal']}/{f['tasks']} oversized tasks fit "
            "a whole-table placer (expected none)",
        )
        gate.invariant(
            f"b13.{name}.sharded_all_legal",
            f["sharded_legal_fraction"] >= min_shard,
            f"ShardingPlacer legal on {f['sharded_legal']}/{f['tasks']} "
            f"oversized tasks (max shard count {f['max_shard_count_mean']})",
        )
        gate.invariant(
            f"b13.{name}.k1_identity_bitwise",
            all(v for k, v in ident.items() if k != "tasks"),
            f"trivial-spec vs legacy fingerprint over {ident['tasks']} "
            f"tasks: {ident}",
        )
        gate.invariant(
            f"b13.{name}.refine_never_regresses",
            f["refine_regressions"] == 0,
            f"{f['refine_regressions']} refine_sharded regression(s); "
            f"seed {f['sharded_cost_ms_mean']} ms -> refined "
            f"{f['refined_cost_ms_mean']} ms",
        )
    for regime in _matched_regimes(baseline, fresh):
        b = baseline["regimes"][regime]["feasibility"]
        f = fresh["regimes"][regime]["feasibility"]
        gate.exact(f"b13.{regime}.sharded_cost_ms_mean",
                   b["sharded_cost_ms_mean"], f["sharded_cost_ms_mean"])
        gate.exact(f"b13.{regime}.refined_cost_ms_mean",
                   b["refined_cost_ms_mean"], f["refined_cost_ms_mean"])
    gate.invariant(
        "b13.limits_match_baseline",
        baseline.get("limits") == fresh.get("limits"),
        f"baseline limits {baseline.get('limits')} vs fresh "
        f"{fresh.get('limits')}",
    )


CHECKERS = {
    "b6_train_throughput": check_train,
    "b7_oracle_throughput": check_oracle,
    "b8_fusion_model": check_fusion,
    "b9_search": check_search,
    "b10_telemetry_overhead": check_telemetry,
    "b11_serve": check_serve,
    "b12_resilience": check_resilience,
    "b13_sharding": check_sharding,
}


def check_pair(gate: Gate, baseline_path: str, fresh_path: str) -> None:
    with open(baseline_path) as f:
        baseline = json.load(f)
    with open(fresh_path) as f:
        fresh = json.load(f)
    kind = baseline.get("benchmark")
    print(f"{kind}: {baseline_path} vs {fresh_path}")
    if fresh.get("benchmark") != kind:
        gate.failures.append(
            f"{fresh_path} is {fresh.get('benchmark')!r}, baseline is "
            f"{kind!r}"
        )
        return
    checker = CHECKERS.get(kind)
    if checker is None:
        gate.failures.append(f"no checker for benchmark kind {kind!r}")
        return
    before = gate.checked
    checker(gate, baseline, fresh)
    if gate.checked == before:
        gate.failures.append(
            f"{fresh_path}: no comparable cells against {baseline_path} "
            "(regime configs differ?) -- refusing to pass vacuously"
        )


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument(
        "--pair",
        nargs=2,
        action="append",
        metavar=("BASELINE", "FRESH"),
        required=True,
        help="committed baseline JSON and fresh run JSON",
    )
    ap.add_argument(
        "--tolerance",
        type=float,
        default=0.25,
        help="max relative throughput drop (default 0.25)",
    )
    ap.add_argument(
        "--eval-rtol",
        type=float,
        default=5e-3,
        help="max relative drift for trained-agent eval costs "
        "(XLA-dependent; loosen for unpinned-jax legs)",
    )
    ap.add_argument(
        "--exact-rtol",
        type=float,
        default=1e-6,
        help="max relative drift for pure-numpy determinism fingerprints",
    )
    args = ap.parse_args(argv)
    gate = Gate(args.tolerance, args.eval_rtol, args.exact_rtol)
    for baseline_path, fresh_path in args.pair:
        check_pair(gate, baseline_path, fresh_path)
    if gate.failures:
        print(f"\nbench gate: {len(gate.failures)} failure(s)")
        for failure in gate.failures:
            print(f"  - {failure}")
        return 1
    print(f"\nbench gate: {gate.checked} cells ok")
    return 0


if __name__ == "__main__":
    sys.exit(main())
