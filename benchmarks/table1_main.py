"""Paper Table 1 (+6/7): overall cost vs baselines across task scales on
DLRM and Prod pools, train and held-out test tasks, with speedups over
random placement."""

from __future__ import annotations

from benchmarks import common as C


def configs():
    if C.FULL:
        return [("DLRM", 20, 4), ("DLRM", 40, 4), ("DLRM", 60, 4),
                ("DLRM", 80, 4), ("DLRM", 50, 4), ("DLRM", 40, 8),
                ("DLRM", 80, 8), ("Prod", 20, 2), ("Prod", 40, 4)]
    return [("DLRM", 20, 4), ("DLRM", 50, 4), ("DLRM", 40, 8),
            ("Prod", 20, 2)]


def run():
    rows = []
    n_tasks, base_cfg = C.budget()
    for dataset, m, d in configs():
        pool = C.get_pool(dataset)
        sim = C.get_sim(dataset)
        train, test = C.make_benchmark_suite(pool, m, d, n_tasks=n_tasks)
        cfg = base_cfg
        if dataset == "Prod":
            # Prod costs span 15-150 ms (vs the paper's ~30-50): 1.5x the
            # paper's training budget (documented in EXPERIMENTS.md)
            import dataclasses
            cfg = dataclasses.replace(base_cfg, n_iterations=15,
                                      n_collect=15, n_rl=15,
                                      inference_candidates=64)
        with C.Timer() as t_train:
            ds = C.train_dreamshard(train, sim, cfg)
        rnn = C.train_rnn(train, sim)
        search = C.make_search_placer(sim, ds)
        for split, tasks in (("train", train), ("test", test)):
            scores = C.eval_all_baselines(sim, tasks)
            scores["rnn"] = C.eval_placer(sim, tasks, rnn.as_placer())
            scores["dreamshard"] = C.eval_placer(sim, tasks, ds.as_placer())
            scores["dreamshard_search"] = C.eval_placer(sim, tasks, search)
            best_baseline = min(v for k, v in scores.items()
                                if not k.startswith("dreamshard"))
            rows.append({
                "task": f"{dataset}-{m} ({d})", "split": split,
                **{k: round(v, 2) for k, v in scores.items()},
                "speedup_vs_random": C.speedup(scores["random"],
                                               scores["dreamshard"]),
                "speedup_vs_best_baseline": C.speedup(best_baseline,
                                                      scores["dreamshard"]),
                "search_gain": C.speedup(scores["dreamshard"],
                                         scores["dreamshard_search"]),
                "beats_all": scores["dreamshard"] <= best_baseline * 1.001,
                "train_s": round(t_train.s, 1),
            })
            print(rows[-1], flush=True)
    return rows


if __name__ == "__main__":
    run()
