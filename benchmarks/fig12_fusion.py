"""Paper Fig 12 (App. A.3.2): fused multi-table cost vs sum of
single-table costs -- speedup distribution and the failure of a linear
correction (motivates the learned cost network)."""

from __future__ import annotations

import numpy as np

from benchmarks import common as C


def run():
    pool = C.get_pool("DLRM")
    sim = C.get_sim("DLRM", noise_std=0.0)
    rng = np.random.default_rng(0)
    n_samples = 200 if C.FULL else 50
    fused, singles = [], []
    for _ in range(n_samples):
        sub = pool[rng.choice(len(pool), 10, replace=False)]
        f, _ = sim.fused_op_ms(sub)
        fused.append(f)
        singles.append(float(sim.single_table_ms(sub).sum()))
    fused, singles = np.array(fused), np.array(singles)
    speedups = singles / fused
    # best single linear coefficient (paper grid-searches [1.0, 2.0])
    best_mse = min(
        float(np.mean((singles / c - fused) ** 2))
        for c in np.arange(1.0, 2.5, 0.001))
    rows = [{
        "n_samples": n_samples,
        "speedup_min": round(float(speedups.min()), 3),
        "speedup_mean": round(float(speedups.mean()), 3),
        "speedup_max": round(float(speedups.max()), 3),
        "in_paper_band_1x_3x": bool((speedups >= 1).all()
                                    and (speedups <= 3.2).all()),
        "linear_fit_mse": round(best_mse, 3),
        "correlation": round(float(np.corrcoef(fused, singles)[0, 1]), 4),
    }]
    print(rows[-1], flush=True)
    return rows


if __name__ == "__main__":
    run()
