"""Paper Fig 8: training/inference with the estimated MDP vs against real
hardware measurements.

The real-MDP variant pays one hardware measurement per episode (and, for
its augmented states, one per step); with the paper's PARAM-bench
measurement latency (~1s warmup+bench per op set) that is hours of GPU
time.  We report measured wall-clock for our simulator-backed runs plus
the modeled hardware-seconds both variants would consume on real GPUs."""

from __future__ import annotations

import time

from benchmarks import common as C
from repro.core.trainer import DreamShard
from repro.core.rnn_policy import RNNPlacer, RNNPolicyConfig

MEASUREMENT_LATENCY_S = 1.0      # paper App. B.4.2: init + 5 warmup + 10 bench


def run():
    n_tasks, cfg = C.budget()
    pool = C.get_pool("DLRM")
    sim_est = C.get_sim("DLRM")
    sim_real = C.get_sim("DLRM")
    m, d = (50, 4) if C.FULL else (20, 4)
    train, test = C.make_benchmark_suite(pool, m, d, n_tasks=n_tasks)
    rows = []

    # --- estimated MDP (DreamShard) ---
    t0 = time.perf_counter()
    ds = DreamShard(train, sim_est, cfg)
    ds.train()
    wall = time.perf_counter() - t0
    total_episodes = cfg.n_iterations * (cfg.n_collect
                                         + cfg.n_rl * cfg.n_episode)
    rows.append({
        "variant": "estimated_mdp",
        "wall_s": round(wall, 1),
        "hardware_measurements": sim_est.num_evaluations,
        "modeled_hw_seconds": sim_est.num_evaluations * MEASUREMENT_LATENCY_S,
        "episodes": total_episodes,
        "final_cost_ms": round(ds.evaluate_tasks(test[:8]), 2),
    })
    print(rows[-1], flush=True)

    # --- real MDP: every episode measured on hardware (no cost network) ---
    n_updates = cfg.n_iterations * cfg.n_rl
    t0 = time.perf_counter()
    real = RNNPlacer(train, sim_real,
                     RNNPolicyConfig(n_updates=n_updates,
                                     n_episode=cfg.n_episode))
    real.train()
    wall = time.perf_counter() - t0
    # each episode ALSO needs M per-step measurements for augmented states
    per_step = sim_real.num_evaluations * m
    rows.append({
        "variant": "real_mdp",
        "wall_s": round(wall, 1),
        "hardware_measurements": sim_real.num_evaluations + per_step,
        "modeled_hw_seconds": (sim_real.num_evaluations + per_step)
        * MEASUREMENT_LATENCY_S,
        "episodes": n_updates * cfg.n_episode,
        "final_cost_ms": round(C.eval_placer(sim_real, test[:8],
                                             real.as_placer()), 2),
    })
    print(rows[-1], flush=True)

    # --- inference scaling: placement latency vs #tables (no hardware) ---
    for n in (10, 50, 100, 200):
        sub = pool[:n]
        ds.place(sub, 4)                       # warm the jit cache
        t0 = time.perf_counter()
        ds.place(sub, 4)
        rows.append({"variant": f"inference_{n}_tables",
                     "wall_s": round(time.perf_counter() - t0, 4),
                     "hardware_measurements": 0})
        print(rows[-1], flush=True)
    return rows


if __name__ == "__main__":
    run()
