"""B7: oracle evaluation throughput -- per-placement loop vs evaluate_many.

Search-heavy sharding lives on cost-query throughput: AutoShard amortizes
measurement over thousands of candidate shardings and Pre-train-and-Search
makes batched cost queries the engine of its search.  This benchmark
measures what one task's P placements cost through each oracle backend,
per-placement loop vs the batched ``evaluate_many`` path (the two are
bitwise-identical; a prefix is asserted below), in two regimes:

* ``paper`` -- P = 100, the neighborhood of the paper's per-iteration
  collection budget (n_collect = 10..100);
* ``scale``  -- P = 2000, the ``n_collect >= 1000`` regime that the batched
  path exists for (acceptance: >= 10x placements/sec on the simulator).

Oracles: ``sim`` (analytic simulator, noise on), ``measured``
(calibration-table interpolation), and ``cached_half`` (CachedOracle with
half the batch pre-warmed -- the partial-hit path).  Writes
``BENCH_oracle.json`` (committed at the repo root; CI runs ``--smoke`` and
uploads a fresh copy per run, like b6).
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import time

import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from repro.api import CachedOracle, MeasuredOracle, SimOracle  # noqa: E402
from repro.data.synthetic import make_dlrm_pool                # noqa: E402
from repro.profiling.calibration import CalibrationTable       # noqa: E402
from repro.sim.costsim import CostSimulator                    # noqa: E402

ROOT = os.path.join(os.path.dirname(__file__), "..")
N_TABLES = 20
N_DEVICES = 4


def _oracle_factories():
    table = CalibrationTable.synthetic()
    return {
        "sim": lambda: SimOracle(CostSimulator(seed=0)),
        "measured": lambda: MeasuredOracle(table),
        "cached_half": lambda: CachedOracle(CostSimulator(seed=0)),
    }


def _check_bitwise(make_oracle, raw, A):
    batch = make_oracle().evaluate_many(raw, A, N_DEVICES)
    loop_oracle = make_oracle()
    for b, a in zip(batch, A):
        ref = loop_oracle.evaluate(raw, a, N_DEVICES)
        assert b.overall == ref.overall and \
            np.array_equal(b.fwd_comp, ref.fwd_comp), \
            "batched result diverged from the sequential loop"


def _bench_oracle(name, make_oracle, raw, A, repeats):
    P = A.shape[0]
    loop_s, batch_s = [], []
    for _ in range(repeats):
        oracle = make_oracle()
        if name == "cached_half":           # pre-warm half: partial hits
            oracle.evaluate_many(raw, A[: P // 2], N_DEVICES)
        t0 = time.perf_counter()
        for a in A:
            oracle.evaluate(raw, a, N_DEVICES)
        loop_s.append(time.perf_counter() - t0)

        oracle = make_oracle()
        if name == "cached_half":
            oracle.evaluate_many(raw, A[: P // 2], N_DEVICES)
        t0 = time.perf_counter()
        oracle.evaluate_many(raw, A, N_DEVICES)
        batch_s.append(time.perf_counter() - t0)
    loop_med, batch_med = float(np.median(loop_s)), float(np.median(batch_s))
    return {
        "loop_s": round(loop_med, 4),
        "batched_s": round(batch_med, 4),
        "loop_placements_per_sec": round(P / loop_med, 1),
        "batched_placements_per_sec": round(P / batch_med, 1),
        "speedup": round(loop_med / batch_med, 1),
    }


def run(smoke: bool = False, out: str | None = None, repeats: int = 3,
        regimes: list[str] | None = None):
    pool = make_dlrm_pool(seed=0)
    raw = pool[:N_TABLES]
    rng = np.random.default_rng(0)
    selected = {"scale": 128} if smoke else {"paper": 100, "scale": 2000}
    if regimes:
        selected = {k: v for k, v in selected.items() if k in regimes}
        if not selected:
            raise SystemExit(f"no such regime(s) {regimes}")
    repeats = 1 if smoke else repeats

    result = {
        "benchmark": "b7_oracle_throughput",
        "schema": 1,
        "mode": "smoke" if smoke else "full",
        "generated": time.strftime("%Y-%m-%dT%H:%M:%S"),
        "repeats": repeats,
        "task": {"n_tables": N_TABLES, "n_devices": N_DEVICES},
        "host": {"cpu_count": os.cpu_count(), "numpy": np.__version__},
        "regimes": {},
    }
    factories = _oracle_factories()
    _check_bitwise(factories["sim"], raw,
                   rng.integers(0, N_DEVICES, size=(8, N_TABLES)))
    for regime, P in selected.items():
        A = rng.integers(0, N_DEVICES, size=(P, N_TABLES), dtype=np.int64)
        rows = {}
        for name, make_oracle in factories.items():
            rows[name] = _bench_oracle(name, make_oracle, raw, A, repeats)
            print({"regime": regime, "n_placements": P, "oracle": name,
                   **rows[name]}, flush=True)
        result["regimes"][regime] = {"n_placements": P, "oracles": rows}

    head_name = "scale" if "scale" in result["regimes"] \
        else next(iter(result["regimes"]))
    head = result["regimes"][head_name]["oracles"]["sim"]
    result["headline"] = {
        "regime": head_name,
        "oracle": "sim",
        "n_placements": result["regimes"][head_name]["n_placements"],
        "speedup": head["speedup"],
        "batched_placements_per_sec": head["batched_placements_per_sec"],
    }
    if not smoke:
        assert head["speedup"] >= 10.0, \
            f"batched oracle only {head['speedup']}x the loop (target 10x)"
    out = out or os.path.join(ROOT, "BENCH_oracle.json")
    with open(out, "w") as f:
        json.dump(result, f, indent=2)
        f.write("\n")
    print({"headline": result["headline"], "written": os.path.abspath(out)},
          flush=True)
    return result


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--smoke", action="store_true",
                    help="tiny batch for CI: scale regime only, 1 repeat")
    ap.add_argument("--out", default=None, help="output JSON path")
    ap.add_argument("--repeats", type=int, default=3,
                    help="timing repeats; the metric is the median")
    ap.add_argument("--regimes", default=None,
                    help="comma-separated regime subset (e.g. 'scale'; CI "
                         "runs the full-config scale regime so the bench "
                         "gate can compare against the committed baseline)")
    ap.add_argument("--trace", default=None, metavar="PATH",
                    help="record telemetry and export a trace on exit "
                         "(.jsonl -> event log, else Chrome trace JSON)")
    args = ap.parse_args()
    from repro import telemetry as tele
    with tele.trace_to(args.trace):
        run(smoke=args.smoke, out=args.out, repeats=max(1, args.repeats),
            regimes=args.regimes.split(",") if args.regimes else None)
