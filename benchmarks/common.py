"""Shared benchmark plumbing: strategy evaluation over task suites,
DreamShard training at benchmark budgets, CSV row helpers.

All strategies are evaluated through the unified ``repro.api`` layer:
build a ``Placer`` (``agent.as_placer()``, ``rnn.as_placer()``,
``make_baseline_placers``), then ``eval_placer(oracle, tasks, placer)``.
No per-strategy lambda glue.
"""

from __future__ import annotations

import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.api import (ensure_oracle, evaluate_placer,        # noqa: E402
                       make_baseline_placers)
from repro.core.rnn_policy import RNNPlacer, RNNPolicyConfig   # noqa: E402
from repro.core.trainer import DreamShard, DreamShardConfig    # noqa: E402
from repro.data.synthetic import make_dlrm_pool, make_prod_pool  # noqa: E402
from repro.data.tasks import make_benchmark_suite          # noqa: E402
from repro.sim.costsim import CostSimulator                # noqa: E402
from repro.sim.hardware import PAPER_GPU, PAPER_GPU_LARGE  # noqa: E402

FULL = bool(int(os.environ.get("REPRO_BENCH_FULL", "0")))


def budget():
    """(n_tasks, trainer_config) for quick vs full benchmark runs.

    Quick mode keeps the paper's exact Algorithm-1 hyperparameters and only
    reduces the number of sampled tasks per suite (50 -> 16)."""
    if FULL:
        return 50, DreamShardConfig()
    return 16, DreamShardConfig()


def get_pool(dataset: str):
    return make_dlrm_pool(seed=0) if dataset == "DLRM" else make_prod_pool(seed=1)


def get_sim(dataset: str, **kw):
    spec = PAPER_GPU if dataset == "DLRM" else PAPER_GPU_LARGE
    return CostSimulator(spec, **kw)


def eval_placer(sim, tasks, placer) -> float:
    """Mean measured cost (ms) of one ``Placer`` over a task suite."""
    return evaluate_placer(ensure_oracle(sim), tasks, placer)


def eval_all_baselines(sim, tasks, seed=0) -> dict:
    """Random + the four expert heuristics, via the ``Placer`` protocol."""
    oracle = ensure_oracle(sim)
    return {name: evaluate_placer(oracle, tasks, placer)
            for name, placer in make_baseline_placers(oracle, seed).items()}


def train_dreamshard(train_tasks, sim, cfg=None) -> DreamShard:
    ds = DreamShard(train_tasks, sim, cfg or budget()[1])
    ds.train()
    return ds


def train_rnn(train_tasks, sim, n_updates=None) -> RNNPlacer:
    if n_updates is None:
        # match DreamShard's hardware budget (n_iterations * n_collect)
        c = budget()[1]
        n_updates = max(1, c.n_iterations * c.n_collect // 2)
    placer = RNNPlacer(train_tasks, sim,
                       RNNPolicyConfig(n_updates=n_updates, n_episode=10))
    placer.train()
    return placer


def make_search_placer(sim, agent, strategy="lns", budget_ms=50.0,
                       max_evals=None, seed=0, name=None):
    """RL+search: a ``SearchPlacer`` refining the agent's proposals.

    The default is the benchmark headline configuration -- LNS under a
    50 ms/task anytime budget, seeded by the trained DreamShard.
    """
    from repro.api import SearchConfig, SearchPlacer
    oracle = ensure_oracle(sim)
    cfg = SearchConfig(strategy=strategy, budget_ms=budget_ms,
                       max_evals=max_evals, seed=seed)
    return SearchPlacer(oracle, seed_placer=agent.as_placer(), config=cfg,
                        agent=agent, name=name)


def speedup(base: float, val: float) -> str:
    return f"{(base / val - 1) * 100:+.1f}%"


class Timer:
    def __enter__(self):
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, *a):
        self.s = time.perf_counter() - self.t0
