"""Paper App. B.3: comparison of table/device representation reductions.

The paper finds SUM for table reps + MAX for device reps gives the most
accurate cost prediction; this benchmark trains the cost network with each
alternative on the same measured samples and reports held-out MSE.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import common as C
from repro.core import baselines as B
from repro.core import features as F
from repro.core import networks as N
from repro.optim import adam, apply_updates


def _collect(pool, sim, tasks, n, rng, m_pad, d_pad):
    feats = np.zeros((n, m_pad, F.NUM_FEATURES), np.float32)
    onehot = np.zeros((n, d_pad, m_pad), np.float32)
    tmask = np.zeros((n, m_pad), np.float32)
    dmask = np.zeros((n, d_pad), np.float32)
    q_t = np.zeros((n, d_pad, 3), np.float32)
    c_t = np.zeros((n,), np.float32)
    for i in range(n):
        t = tasks[rng.integers(len(tasks))]
        a = B.random_place(t.raw_features, t.n_devices,
                           sim.spec.mem_capacity_gb, rng)
        res = sim.evaluate(t.raw_features, a, t.n_devices)
        m, d = t.n_tables, t.n_devices
        feats[i, :m] = F.normalize_features(t.raw_features)
        onehot[i, a, np.arange(m)] = 1.0
        tmask[i, :m] = 1.0
        dmask[i, :d] = 1.0
        q_t[i, :d] = np.log1p(res.cost_features)
        c_t[i] = np.log1p(res.overall)
    return tuple(map(jnp.asarray, (feats, onehot, tmask, dmask, q_t, c_t)))


def _train_eval(train_data, test_data, table_red, device_red, steps, seed=0):
    params = N.cost_net_init(jax.random.PRNGKey(seed))
    opt = adam(5e-4)
    state = opt.init(params)
    feats, onehot, tmask, dmask, q_t, c_t = train_data

    def loss_fn(p, idx):
        q, c = N.cost_net_apply(p, feats[idx], onehot[idx], tmask[idx],
                                dmask[idx], table_reduction=table_red,
                                device_reduction=device_red)
        lq = jnp.sum((q - q_t[idx]) ** 2 * dmask[idx][..., None]) / (
            3.0 * jnp.maximum(dmask[idx].sum(), 1.0))
        return lq + jnp.mean((c - c_t[idx]) ** 2)

    @jax.jit
    def step(p, s, idx):
        loss, g = jax.value_and_grad(loss_fn)(p, idx)
        u, s = opt.update(g, s, p)
        return apply_updates(p, u), s, loss

    rng = np.random.default_rng(seed)
    n = feats.shape[0]
    for _ in range(steps):
        params, state, _ = step(params, state,
                                jnp.asarray(rng.integers(n, size=64)))
    tf, to, tt, td, tq, tc = test_data
    q, c = N.cost_net_apply(params, tf, to, tt, td,
                            table_reduction=table_red,
                            device_reduction=device_red)
    lq = float(jnp.sum((q - tq) ** 2 * td[..., None])
               / (3.0 * jnp.maximum(td.sum(), 1.0)))
    return lq + float(jnp.mean((c - tc) ** 2))


def run():
    pool = C.get_pool("DLRM")
    sim = C.get_sim("DLRM")
    m, d = (20, 4)
    train, test = C.make_benchmark_suite(pool, m, d, n_tasks=16)
    rng = np.random.default_rng(0)
    n_train = 600 if C.FULL else 250
    train_data = _collect(pool, sim, train, n_train, rng, m, d)
    test_data = _collect(pool, sim, test, 120, rng, m, d)
    steps = 3000 if C.FULL else 1200

    rows = []
    # vary table reduction (device=max), then device reduction (table=sum)
    for tr, dr in [("sum", "max"), ("mean", "max"), ("max", "max"),
                   ("sum", "sum"), ("sum", "mean")]:
        mse = _train_eval(train_data, test_data, tr, dr, steps)
        rows.append({"table_reduction": tr, "device_reduction": dr,
                     "test_mse": round(mse, 4)})
        print(rows[-1], flush=True)
    best = min(rows, key=lambda r: r["test_mse"])
    rows.append({"best": f"{best['table_reduction']}/"
                         f"{best['device_reduction']}",
                 "paper_best": "sum/max"})
    print(rows[-1], flush=True)
    return rows


if __name__ == "__main__":
    run()
