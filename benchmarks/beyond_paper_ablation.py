"""Ablation of the beyond-paper refinements (DESIGN.md §4b): each switch
reverted individually back toward the paper-faithful configuration, on
DLRM-50 (4) held-out tasks.  The 'paper_faithful' row is all four reverted
(head reward, linear-scaled targets, argmax inference, log-dim features)."""

from __future__ import annotations

import dataclasses

from benchmarks import common as C


def run():
    n_tasks, base = C.budget()
    pool = C.get_pool("DLRM")
    sim = C.get_sim("DLRM")
    train, test = C.make_benchmark_suite(pool, 50, 4, n_tasks=n_tasks)
    lookup = C.eval_all_baselines(sim, test)["lookup"]

    variants = {
        "full (default)": {},
        "reward_mode=head": {"reward_mode": "head"},
        "target=scale": {"target_transform": "scale"},
        "argmax inference": {"inference_candidates": 1},
        "paper_faithful": {"reward_mode": "head",
                           "target_transform": "scale",
                           "inference_candidates": 1},
    }
    rows = []
    for name, overrides in variants.items():
        cfg = dataclasses.replace(base, **overrides)
        ds = C.train_dreamshard(train, sim, cfg)
        cost = C.eval_placer(sim, test, ds.as_placer())
        rows.append({"variant": name, "test_cost_ms": round(cost, 2),
                     "vs_lookup_expert": C.speedup(lookup, cost)})
        print(rows[-1], flush=True)
    rows.append({"variant": "lookup_expert_baseline",
                 "test_cost_ms": round(lookup, 2)})
    print(rows[-1], flush=True)
    return rows


if __name__ == "__main__":
    run()
