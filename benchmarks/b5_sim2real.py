"""B5: the sim-to-real loop -- calibration, MeasuredOracle throughput,
and cost-network quality when trained on SimOracle vs MeasuredOracle.

Three questions:

1. **Throughput** -- the old ``KernelOracle`` re-timed kernels inside
   every ``evaluate``; ``MeasuredOracle`` interpolates an offline
   calibration artifact with zero kernel launches.  How many evaluates
   per second does each sustain on a 20-table task?  (Acceptance:
   >= 100x.)
2. **Cost-network fidelity** -- train DreamShard once against the
   analytic ``SimOracle`` and once against the ``MeasuredOracle``; whose
   cost network predicts *measured* costs better (MAPE on held-out
   random placements)?
3. **End placement quality** -- evaluate both agents' placements on the
   measured oracle (the deployment metric): training against the wrong
   cost model is the sim-to-real gap this subsystem closes.
"""

from __future__ import annotations

import time

import jax.numpy as jnp
import numpy as np

from benchmarks import common as C
from repro.api import MeasuredOracle, SimOracle, evaluate_placer
from repro.core import baselines as B
from repro.core import features as F
from repro.core import networks as N
from repro.core.trainer import CostSample, DreamShard, DreamShardConfig
from repro.data.tasks import sample_tasks, split_pool
from repro.profiling import (CalibrationTable, load_or_none,
                             measure_placement)

N_TABLES = 20
N_DEVICES = 4


def get_table() -> tuple[CalibrationTable, float]:
    """Cached artifact if present (CI caches it), else a smoke sweep."""
    t0 = time.perf_counter()
    table = load_or_none()
    if table is None:
        table = CalibrationTable.measure(
            dims=(16, 64, 256), rows=(256, 4096), batches=(64,),
            poolings=(2, 8), use_pallas=False, warmup=1, repeats=2)
    return table, time.perf_counter() - t0


def costnet_mape(agent: DreamShard, samples: list[CostSample],
                 true_ms: np.ndarray) -> float:
    """MAPE of the agent's cost network vs measured overall cost (ms)."""
    batch = agent._cost_batch(samples)
    feats, onehot, tmask, dmask, _, _ = map(jnp.asarray, batch)
    _, overall = N.cost_net_apply(agent.cost_params, feats, onehot,
                                  tmask, dmask)
    pred = np.asarray(overall)
    pred_ms = np.expm1(pred) if agent.cfg.target_transform == "log1p" \
        else pred / agent.cfg.cost_scale
    return float(np.mean(np.abs(pred_ms - true_ms)
                         / np.maximum(true_ms, 1e-9)))


def measured_holdout(agent: DreamShard, oracle: MeasuredOracle, tasks,
                     n: int, seed: int = 0):
    """Held-out (placement, measured cost) pairs in the agent's units.

    Placements are drawn per probe but measured through one
    ``evaluate_many`` pass per task (bitwise the same as the old
    per-probe ``evaluate`` loop)."""
    rng = np.random.default_rng(seed)
    assigns = [B.random_place(tasks[i % len(tasks)].raw_features,
                              tasks[i % len(tasks)].n_devices,
                              oracle.mem_capacity_gb, rng)
               for i in range(n)]
    results: list = [None] * n
    for k, t in enumerate(tasks):
        idxs = list(range(k, n, len(tasks)))
        if not idxs:
            continue
        batch = oracle.evaluate_many(
            t.raw_features, np.stack([assigns[i] for i in idxs]),
            t.n_devices)
        for i, res in zip(idxs, batch):
            results[i] = res
    samples, true_ms = [], []
    for i in range(n):
        t, res = tasks[i % len(tasks)], results[i]
        samples.append(CostSample(
            feats_norm=F.normalize_features(t.raw_features),
            assignment=assigns[i],
            q=agent.transform_targets(res.cost_features),
            overall=float(agent.transform_targets(res.overall)),
            n_devices=t.n_devices))
        true_ms.append(res.overall)
    return samples, np.asarray(true_ms)


def run():
    rows = []
    pool = C.get_pool("DLRM")
    train_ids, test_ids = split_pool(pool, seed=0)
    train_tasks = sample_tasks(pool, train_ids, N_TABLES, N_DEVICES, 8,
                               seed=1, name="s2r-train")
    test_tasks = sample_tasks(pool, test_ids, N_TABLES, N_DEVICES, 6,
                              seed=2, name="s2r-test")

    table, cal_s = get_table()
    rows.append({"variant": "calibration", "wall_s": round(cal_s, 2),
                 "summary": table.summary()})
    print(rows[-1], flush=True)

    # v2 artifacts carry the fused multi-table correction, so every
    # MeasuredOracle below prices a device's tables as one fused op
    # (benchmarks/b8_fusion_model.py quantifies the accuracy win)
    rows.append({"variant": "fusion_model",
                 "fwd": table.fusion_fwd.summary(),
                 "bwd": table.fusion_bwd.summary()})
    print(rows[-1], flush=True)

    # --- 1. evaluate throughput: interpolation vs the old live loop ------
    t = train_tasks[0]
    rng = np.random.default_rng(0)
    assigns = [B.random_place(t.raw_features, t.n_devices, 11.0, rng)
               for _ in range(8)]
    oracle = MeasuredOracle(table)
    oracle.evaluate(t.raw_features, assigns[0], t.n_devices)   # warm numpy
    n_interp = 300
    t0 = time.perf_counter()
    for i in range(n_interp):
        oracle.evaluate(t.raw_features, assigns[i % len(assigns)],
                        t.n_devices)
    interp_s_per = (time.perf_counter() - t0) / n_interp

    # batched: the same workload as ONE evaluate_many pass (b7 sweeps this
    # across oracles and batch sizes; here it anchors the sim2real story)
    n_batched = 1024
    A = np.stack([assigns[i % len(assigns)] for i in range(n_batched)])
    t0 = time.perf_counter()
    oracle.evaluate_many(t.raw_features, A, t.n_devices)
    batched_s_per = (time.perf_counter() - t0) / n_batched

    n_live = 2
    t0 = time.perf_counter()
    for i in range(n_live):
        measure_placement(t.raw_features, assigns[i], t.n_devices,
                          batch_size=64, pooling=4, max_rows=4096, repeats=2)
    live_s_per = (time.perf_counter() - t0) / n_live

    speedup = live_s_per / interp_s_per
    rows.append({"variant": "evaluate_throughput",
                 "measured_evals_per_sec": round(1.0 / interp_s_per, 1),
                 "batched_evals_per_sec": round(1.0 / batched_s_per, 1),
                 "live_kernel_evals_per_sec": round(1.0 / live_s_per, 3),
                 "speedup": round(speedup, 1),
                 "batched_speedup_vs_loop": round(interp_s_per
                                                  / batched_s_per, 1),
                 "target": ">=100x"})
    print(rows[-1], flush=True)
    assert speedup >= 100.0, f"MeasuredOracle only {speedup:.0f}x faster"

    # --- 2+3. train on sim vs measured, judge on measured ----------------
    cfg = DreamShardConfig(n_iterations=2, n_collect=8, n_cost=60, n_rl=4,
                           seed=0)
    agents = {}
    for name, train_oracle in (
            ("sim", SimOracle(C.get_sim("DLRM"))),
            ("measured", MeasuredOracle(table))):
        t0 = time.perf_counter()
        agent = DreamShard(train_tasks, train_oracle, cfg)
        agent.train()
        agents[name] = agent
        rows.append({"variant": f"train_on_{name}",
                     "wall_s": round(time.perf_counter() - t0, 1),
                     "oracle_evals": train_oracle.num_evaluations})
        print(rows[-1], flush=True)

    holdout_oracle = MeasuredOracle(table)
    for name, agent in agents.items():
        samples, true_ms = measured_holdout(agent, holdout_oracle,
                                            test_tasks, 24, seed=3)
        mape = costnet_mape(agent, samples, true_ms)
        eval_oracle = MeasuredOracle(table)
        cost = evaluate_placer(eval_oracle, test_tasks, agent.as_placer())
        rows.append({"variant": f"sim2real_{name}",
                     "trained_on": name,
                     "costnet_mape_vs_measured": round(mape, 4),
                     "measured_placement_ms": round(cost, 4)})
        print(rows[-1], flush=True)

    rand_cost = np.mean([
        holdout_oracle.evaluate(
            tk.raw_features,
            B.random_place(tk.raw_features, tk.n_devices,
                           holdout_oracle.mem_capacity_gb,
                           np.random.default_rng(7)),
            tk.n_devices).overall
        for tk in test_tasks])
    rows.append({"variant": "sim2real_random_baseline",
                 "measured_placement_ms": round(float(rand_cost), 4)})
    print(rows[-1], flush=True)
    return rows


if __name__ == "__main__":
    run()
