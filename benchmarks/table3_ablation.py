"""Paper Table 3/11: feature-group and cost-feature ablations + the
w/ RNN variant, on DLRM tasks."""

from __future__ import annotations

from benchmarks import common as C
from repro.core.trainer import DreamShardConfig


def run():
    n_tasks, base_cfg = C.budget()
    pool = C.get_pool("DLRM")
    sim = C.get_sim("DLRM")
    m, d = (50, 4) if C.FULL else (20, 4)
    train, test = C.make_benchmark_suite(pool, m, d, n_tasks=n_tasks)

    variants = {
        "dreamshard": {},
        "wo_cost": {"use_cost_features": False},
        "wo_dim": {"feature_drop": "dim"},
        "wo_pooling": {"feature_drop": "pooling"},
        "wo_hash_size": {"feature_drop": "hash_size"},
        "wo_table_size": {"feature_drop": "table_size"},
        "wo_distribution": {"feature_drop": "distribution"},
    }
    rows = []
    for name, overrides in variants.items():
        cfg = DreamShardConfig(**{**vars(base_cfg).copy(), **overrides})
        ds = C.train_dreamshard(train, sim, cfg)
        rows.append({
            "variant": name,
            "train": round(ds.evaluate_tasks(train), 2),
            "test": round(ds.evaluate_tasks(test), 2),
        })
        print(rows[-1], flush=True)
    # w/ RNN variant = the RNN-augmented policy baseline
    rnn = C.train_rnn(train, sim)
    rows.append({"variant": "w_rnn",
                 "train": round(C.eval_placer(sim, train, rnn.as_placer()), 2),
                 "test": round(C.eval_placer(sim, test, rnn.as_placer()), 2)})
    print(rows[-1], flush=True)
    return rows


if __name__ == "__main__":
    run()
