"""B4: batched ``PlacementSession`` serving throughput vs per-task ``place``.

A realistic serving suite has heterogeneous table counts, and the per-task
inference path retraces its jitted rollout for every distinct ``(M, D)``
shape -- the dominant cost of placing a fresh suite.  The session buckets
tasks by padded shape and decodes each bucket in one vmapped call, so a
whole suite costs one compile per bucket (and the same assignments; the
padded rollout is exact).

Reports cold (compile-inclusive) and warm placements/sec for both paths.
"""

from __future__ import annotations

import time

import numpy as np

from benchmarks import common as C
from repro.api import PlacementSession
from repro.core.trainer import DreamShard, DreamShardConfig
from repro.data.tasks import sample_tasks, split_pool


def make_suite(pool, n_tasks: int, n_devices: int = 4, seed: int = 0):
    """Heterogeneous suite: table counts cycle over four sizes."""
    _, test_ids = split_pool(pool, seed=0)
    sizes = (18, 20, 22, 24)
    per = max(1, n_tasks // len(sizes))
    tasks = []
    for i, m in enumerate(sizes):
        tasks += sample_tasks(pool, test_ids, m, n_devices, per,
                              seed=seed + i, name=f"suite-{m}")
    return tasks[:n_tasks]


def run():
    n_tasks, _ = C.budget()
    n_tasks = max(16, n_tasks)
    pool = C.get_pool("DLRM")
    sim = C.get_sim("DLRM")
    train = make_suite(pool, 4)
    agent = DreamShard(train, sim,
                       DreamShardConfig(n_iterations=1, n_cost=20, n_rl=2))
    agent.train()                      # placement quality is irrelevant here
    tasks = make_suite(pool, n_tasks)
    rows = []

    def bench(name, fn, extra=None):
        t0 = time.perf_counter()
        out = fn()
        dt = time.perf_counter() - t0
        rows.append({"variant": name, "wall_s": round(dt, 3),
                     "placements_per_sec": round(len(tasks) / dt, 2),
                     **(extra() if extra else {})})
        print(rows[-1], flush=True)
        return out, dt

    # --- cold: compile-inclusive, the fresh-process serving cost ---
    def per_task():
        return [agent.place(t.raw_features, t.n_devices) for t in tasks]

    a_per, t_cold_per = bench("per_task_place_cold", per_task)

    session = PlacementSession(agent)
    (p_sess, t_cold_sess) = bench(
        "session_place_many_cold", lambda: session.place_many(tasks),
        lambda: {"compiles": session.num_compiles,
                 "decode_calls": session.num_decode_calls})

    # --- warm: steady-state serving throughput ---
    _, t_warm_per = bench("per_task_place_warm", per_task)
    _, t_warm_sess = bench(
        "session_place_many_warm", lambda: session.place_many(tasks),
        lambda: {"compiles": session.num_compiles})

    same = all(np.array_equal(a, p.assignment)
               for a, p in zip(a_per, p_sess))
    rows.append({"variant": "summary",
                 "identical_assignments": same,
                 "cold_speedup": round(t_cold_per / t_cold_sess, 2),
                 "warm_speedup": round(t_warm_per / t_warm_sess, 2)})
    print(rows[-1], flush=True)
    return rows


if __name__ == "__main__":
    run()
