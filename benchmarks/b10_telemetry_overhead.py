"""B10: telemetry overhead -- the instrumentation must be ~free.

The telemetry subsystem promises a near-zero disabled path (span() hands
back a shared no-op, count() early-outs on one global read) and a cheap
enabled path (one lock + tuple append per span).  This benchmark holds
it to both, on the b7 oracle workload -- the hottest instrumented loop
in the stack (one span + one counter bump per ``evaluate`` call):

Both bounds are computed analytically: a tight microbench of the
``span``/``count`` calls (disabled and enabled) gives their per-call
cost, the workload is run once enabled to count exactly how many
telemetry operations it executes, and each overhead is
``ops * ns_per_op`` over the workload's wall time.  A direct A/B
wall-clock diff of a sub-5% effect is scheduler noise on a shared
1-vCPU CI runner (the same code measured anywhere from -1.3% to +19%
across runs); the analytic number is stable, and it is the telemetry
surface itself -- a regression in span()/count() cost moves it
directly.  The raw interleaved A/B is still measured and reported
(``enabled_ab_pct``) for reference, but not gated.

Gates: off-path < 1%, enabled < 5%.

Writes ``BENCH_telemetry.json`` (committed at the repo root; CI runs
``--smoke`` and gates both bounds via ``check_bench.py``).
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import time

import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from repro import telemetry as tele                            # noqa: E402
from repro.api import SimOracle                                # noqa: E402
from repro.data.synthetic import make_dlrm_pool                # noqa: E402
from repro.sim.costsim import CostSimulator                    # noqa: E402

ROOT = os.path.join(os.path.dirname(__file__), "..")
N_TABLES = 20
N_DEVICES = 4
MICRO_ITERS = 200_000

OFF_PATH_LIMIT_PCT = 1.0
ENABLED_LIMIT_PCT = 5.0


def _per_op_ns() -> dict:
    """Per-call cost (ns) of span/count, measured in whichever state the
    tracer is currently in (disabled -> no-op path, enabled -> hot
    path).  Args mirror a typical instrumented call site."""
    t0 = time.perf_counter()
    for _ in range(MICRO_ITERS):
        with tele.span("b10.micro", x=1, y=2):
            pass
    span_ns = (time.perf_counter() - t0) / MICRO_ITERS * 1e9
    t0 = time.perf_counter()
    for _ in range(MICRO_ITERS):
        tele.count("b10.micro")
    count_ns = (time.perf_counter() - t0) / MICRO_ITERS * 1e9
    return {"span_ns": round(span_ns, 1), "count_ns": round(count_ns, 1)}


def _workload(oracle, raw, A):
    """The b7 loop+batched oracle workload (the hot instrumented path)."""
    for a in A:
        oracle.evaluate(raw, a, N_DEVICES)
    oracle.evaluate_many(raw, A, N_DEVICES)


def _telemetry_ops(raw, A) -> dict:
    """Exact telemetry operations one workload pass executes, counted by
    running it once with a fresh enabled tracer."""
    was_enabled = tele.is_enabled()
    tele.reset()
    tracer = tele.enable()
    try:
        _workload(SimOracle(CostSimulator(seed=0)), raw, A)
        spans = len(tracer.snapshot_events()) + tracer.dropped
        # every instrumented call site pairs each span with >= 1 counter
        # bump; SimOracle's evaluate_many adds a second (rows).  Count
        # the bump CALLS, not the summed values.
        counters = tele.snapshot()["counters"]
        count_ops = int(counters.get("oracle.sim.evaluate_calls", 0)) \
            + 2 * int(counters.get("oracle.sim.evaluate_many_calls", 0))
    finally:
        tele.reset()
        if not was_enabled:
            tele.disable()
    return {"spans": spans, "count_ops": count_ops}


MIN_SAMPLE_S = 0.4


def _bench_regime(raw, A, repeats: int, noop: dict, hot: dict) -> dict:
    P = A.shape[0]
    assert not tele.is_enabled()
    t0 = time.perf_counter()
    _workload(SimOracle(CostSimulator(seed=0)), raw, A)
    # repeat the workload until one timing sample is long enough that
    # scheduler noise can't fake a multi-percent slowdown
    inner = max(1, int(np.ceil(
        MIN_SAMPLE_S / max(time.perf_counter() - t0, 1e-9))))

    def _sample():
        oracle = SimOracle(CostSimulator(seed=0))
        t0 = time.perf_counter()
        for _ in range(inner):
            _workload(oracle, raw, A)
        return time.perf_counter() - t0

    off_s, on_s = [], []
    for _ in range(repeats):
        assert not tele.is_enabled()
        off_s.append(_sample())
        tele.enable()
        try:
            on_s.append(_sample())
        finally:
            tele.reset()
            tele.disable()
    # min over interleaved repeats: the least-interfered sample of each
    # arm; informational only (see module docstring)
    off_min, on_min = float(min(off_s)), float(min(on_s))
    off_med = off_min / inner        # per-workload-pass seconds
    ab_pct = (on_min - off_min) / off_min * 100.0

    ops = _telemetry_ops(raw, A)

    def _analytic_pct(per_op: dict) -> float:
        ns = ops["spans"] * per_op["span_ns"] \
            + ops["count_ops"] * per_op["count_ns"]
        return ns / (off_med * 1e9) * 100.0

    return {
        "n_placements": P,
        "inner_passes": inner,
        "workload_off_s": round(off_med, 4),
        "workload_on_s": round(on_min / inner, 4),
        "enabled_overhead_pct": round(_analytic_pct(hot), 3),
        "enabled_ab_pct": round(ab_pct, 3),
        "telemetry_ops": ops,
        "offpath_overhead_pct": round(_analytic_pct(noop), 4),
    }


def run(smoke: bool = False, out: str | None = None, repeats: int = 5,
        regimes: list[str] | None = None):
    pool = make_dlrm_pool(seed=0)
    raw = pool[:N_TABLES]
    rng = np.random.default_rng(0)
    selected = {"scale": 128} if smoke else {"paper": 100, "scale": 2000}
    if regimes:
        selected = {k: v for k, v in selected.items() if k in regimes}
        if not selected:
            raise SystemExit(f"no such regime(s) {regimes}")
    repeats = 3 if smoke else repeats

    tele.reset()
    tele.disable()
    noop = _per_op_ns()
    tele.enable()
    try:
        hot = _per_op_ns()
    finally:
        tele.reset()
        tele.disable()
    result = {
        "benchmark": "b10_telemetry_overhead",
        "schema": 1,
        "mode": "smoke" if smoke else "full",
        "generated": time.strftime("%Y-%m-%dT%H:%M:%S"),
        "repeats": repeats,
        "limits": {"offpath_pct": OFF_PATH_LIMIT_PCT,
                   "enabled_pct": ENABLED_LIMIT_PCT},
        "task": {"n_tables": N_TABLES, "n_devices": N_DEVICES},
        "host": {"cpu_count": os.cpu_count(), "numpy": np.__version__},
        "per_op_ns": {"disabled": noop, "enabled": hot},
        "regimes": {},
    }
    for regime, P in selected.items():
        A = rng.integers(0, N_DEVICES, size=(P, N_TABLES), dtype=np.int64)
        row = _bench_regime(raw, A, repeats, noop, hot)
        result["regimes"][regime] = row
        print({"regime": regime, **row}, flush=True)

    head_name = "scale" if "scale" in result["regimes"] \
        else next(iter(result["regimes"]))
    head = result["regimes"][head_name]
    result["headline"] = {
        "regime": head_name,
        "offpath_overhead_pct": head["offpath_overhead_pct"],
        "enabled_overhead_pct": head["enabled_overhead_pct"],
    }
    for regime, row in result["regimes"].items():
        assert row["offpath_overhead_pct"] < OFF_PATH_LIMIT_PCT, \
            f"{regime}: disabled-path overhead " \
            f"{row['offpath_overhead_pct']}% >= {OFF_PATH_LIMIT_PCT}%"
        assert row["enabled_overhead_pct"] < ENABLED_LIMIT_PCT, \
            f"{regime}: enabled overhead " \
            f"{row['enabled_overhead_pct']}% >= {ENABLED_LIMIT_PCT}%"
    out = out or os.path.join(ROOT, "BENCH_telemetry.json")
    with open(out, "w") as f:
        json.dump(result, f, indent=2)
        f.write("\n")
    print({"headline": result["headline"], "written": os.path.abspath(out)},
          flush=True)
    return result


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--smoke", action="store_true",
                    help="small batch + fewer repeats for CI")
    ap.add_argument("--out", default=None, help="output JSON path")
    ap.add_argument("--repeats", type=int, default=5,
                    help="interleaved off/on timing repeats "
                         "(informational A/B)")
    ap.add_argument("--regimes", default=None,
                    help="comma-separated regime subset (paper, scale)")
    args = ap.parse_args()
    run(smoke=args.smoke, out=args.out, repeats=max(1, args.repeats),
        regimes=args.regimes.split(",") if args.regimes else None)
