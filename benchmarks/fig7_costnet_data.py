"""Paper Fig 7: cost-network test MSE vs number of hardware samples, and
the quality of a policy fully trained against each (frozen-buffer) cost
network -- policy quality saturates long before the cost model is perfect."""

from __future__ import annotations

import numpy as np

from benchmarks import common as C
from repro.core import baselines as B
from repro.core.trainer import CostSample, DreamShard, DreamShardConfig
from repro.core import features as F


def _collect_samples(pool, sim, tasks, n, rng):
    """Random-policy placements measured on the simulator."""
    samples = []
    cap = sim.spec.mem_capacity_gb
    for i in range(n):
        t = tasks[rng.integers(len(tasks))]
        a = B.random_place(t.raw_features, t.n_devices, cap, rng)
        res = sim.evaluate(t.raw_features, a, t.n_devices)
        samples.append(CostSample(
            feats_norm=F.normalize_features(t.raw_features),
            assignment=a, q=np.log1p(res.cost_features),
            overall=float(np.log1p(res.overall)), n_devices=t.n_devices))
    return samples


def run():
    n_tasks, _ = C.budget()
    pool = C.get_pool("DLRM")
    sim = C.get_sim("DLRM")
    m, d = (50, 4) if C.FULL else (20, 4)
    train, test = C.make_benchmark_suite(pool, m, d, n_tasks=n_tasks)
    rng = np.random.default_rng(0)
    sizes = [25, 50, 100, 200, 400] if not C.FULL else [50, 100, 200, 400,
                                                        800, 1600]
    test_samples = _collect_samples(pool, sim, test, 100, rng)
    pool_samples = _collect_samples(pool, sim, train, max(sizes), rng)

    rows = []
    for n in sizes:
        cfg = DreamShardConfig(n_iterations=1, n_collect=0,
                               n_cost=800 if C.FULL else 400, n_rl=60)
        ds = DreamShard(train, sim, cfg)
        ds.buffer = list(pool_samples[:n])
        mse_before = ds.cost_mse(test_samples)
        ds.update_cost()
        ds.update_policy()
        rows.append({
            "n_samples": n,
            "test_mse": round(ds.cost_mse(test_samples), 4),
            "untrained_mse": round(mse_before, 2),
            "policy_cost_ms": round(ds.evaluate_tasks(test[:8]), 2),
        })
        print(rows[-1], flush=True)
    # policy quality should roughly saturate: last <= ~5% better than mid
    return rows


if __name__ == "__main__":
    run()
