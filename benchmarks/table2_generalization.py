"""Paper Table 2 (+8-10): zero-shot transfer of a trained DreamShard to
tasks with different numbers of tables and/or devices, no fine-tuning."""

from __future__ import annotations

from benchmarks import common as C


def run():
    n_tasks, _ = C.budget()
    pool = C.get_pool("DLRM")
    sim = C.get_sim("DLRM")
    rows = []

    # sources: (tables, devices); targets cover more/fewer tables + devices
    pairs = [((20, 4), (50, 4)), ((50, 4), (20, 4)),
             ((20, 4), (20, 2)), ((20, 2), (20, 4))]
    if C.FULL:
        pairs += [((40, 4), (80, 4)), ((80, 4), (40, 4)),
                  ((40, 4), (40, 2)), ((40, 2), (40, 4))]

    agents = {}
    for (sm, sd), (tm, td) in pairs:
        if (sm, sd) not in agents:
            train, _ = C.make_benchmark_suite(pool, sm, sd, n_tasks=n_tasks,
                                              seed=0)
            agents[(sm, sd)] = C.train_dreamshard(train, sim)
        if (tm, td) not in agents:
            train_t, _ = C.make_benchmark_suite(pool, tm, td,
                                                n_tasks=n_tasks, seed=0)
            agents[(tm, td)] = C.train_dreamshard(train_t, sim)
        _, test_t = C.make_benchmark_suite(pool, tm, td, n_tasks=n_tasks,
                                           seed=0)
        baselines = C.eval_all_baselines(sim, test_t)
        native = C.eval_placer(sim, test_t, agents[(tm, td)].as_placer())
        transferred = C.eval_placer(sim, test_t, agents[(sm, sd)].as_placer())
        # search-refined transfer: same zero-shot agent, its proposals
        # polished per target task through the batched oracle
        transferred_search = C.eval_placer(
            sim, test_t, C.make_search_placer(sim, agents[(sm, sd)]))
        rows.append({
            "source": f"DLRM-{sm} ({sd})", "target": f"DLRM-{tm} ({td})",
            "random": round(baselines["random"], 2),
            "best_baseline": round(min(baselines.values()), 2),
            "trained_on_target": round(native, 2),
            "transferred": round(transferred, 2),
            "transferred_search": round(transferred_search, 2),
            "transfer_gap_ms": round(transferred - native, 2),
            "search_gap_ms": round(transferred_search - native, 2),
        })
        print(rows[-1], flush=True)
    return rows


if __name__ == "__main__":
    run()
