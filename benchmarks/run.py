"""Benchmark driver: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV per benchmark plus a JSON dump of
all rows.  Quick budgets by default; set REPRO_BENCH_FULL=1 for
paper-scale budgets.

  PYTHONPATH=src python -m benchmarks.run [--only table1_main]
"""

from __future__ import annotations

import argparse
import importlib
import json
import time
import traceback

MODULES = [
    "table1_main",            # Table 1/6/7: cost vs baselines
    "table2_generalization",  # Table 2/8-10: zero-shot transfer
    "table3_ablation",        # Table 3/11: feature + cost ablations
    "fig5_efficiency",        # Fig 5: cost vs iterations / wall time
    "fig7_costnet_data",      # Fig 7: cost-net data scaling
    "fig8_estimated_mdp",     # Fig 8: estimated vs real MDP
    "table4_comm_imbalance",  # Table 4: comm vs imbalance
    "fig12_fusion",           # Fig 12: operation-fusion analysis
    "b3_reductions",          # App B.3: sum/max reduction comparison
    "b4_session_throughput",  # PlacementSession batched serving vs per-task
    "b5_sim2real",            # calibration + MeasuredOracle vs SimOracle
    "b6_train_throughput",    # fused Algorithm-1 loop vs seed per-step loop
    "b7_oracle_throughput",   # batched evaluate_many vs per-placement loop
    "b8_fusion_model",        # fusion-aware vs additive multi-table costs
    "b9_search",              # search-augmented placement anytime curves
    "b10_telemetry_overhead",  # telemetry off-path / enabled overhead bounds
    "b11_serve",              # placement serving: cache, admission, drift
    "b12_resilience",         # fault injection, failover, degraded serving
    "b13_sharding",           # column-wise sharding: feasibility + K=1 identity
    "beyond_paper_ablation",  # DESIGN 4b refinements, each reverted
    "kernel_embedding_bag",   # FBGEMM-analogue kernel timing
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None)
    ap.add_argument("--out", default="bench_results.json")
    ap.add_argument("--trace", default=None, metavar="PATH",
                    help="record telemetry and export a trace on exit "
                         "(.jsonl -> event log, else Chrome trace JSON "
                         "for chrome://tracing)")
    args = ap.parse_args()
    mods = [args.only] if args.only else MODULES

    from repro import telemetry as tele

    all_rows = {}
    with tele.trace_to(args.trace):
        print("name,us_per_call,derived")
        for name in mods:
            t0 = time.perf_counter()
            try:
                with tele.span("bench.module", module=name):
                    mod = importlib.import_module(f"benchmarks.{name}")
                    rows = mod.run()
                status = "ok"
            except Exception as e:
                rows = [{"error": f"{type(e).__name__}: {e}"}]
                traceback.print_exc()
                status = "error"
            dt = time.perf_counter() - t0
            all_rows[name] = {"status": status, "seconds": round(dt, 1),
                              "rows": rows}
            print(f"{name},{dt * 1e6 / max(len(rows), 1):.0f},"
                  f"status={status} rows={len(rows)} wall={dt:.1f}s",
                  flush=True)
    json.dump(all_rows, open(args.out, "w"), indent=1, default=str)
    print(f"results -> {args.out}")


if __name__ == "__main__":
    main()
