"""Fused embedding-bag op benchmark (the FBGEMM analogue): fused
multi-table lookup vs per-table unfused calls, jitted on CPU (the Pallas
kernel itself targets TPU; interpret-mode timing is not meaningful, so the
fusion benefit is measured on the jnp lowering and correctness is asserted
against the kernel in interpret mode)."""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.embedding_bag import ops
from repro.kernels.embedding_bag.kernel import embedding_bag_fused
from repro.kernels.embedding_bag.ref import embedding_bag_ref


def _time(fn, *args, reps=20):
    fn(*args)                                   # compile
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps * 1e6


def run():
    rng = np.random.default_rng(0)
    n_tables, rows, dim, batch, pool = 10, 4000, 16, 256, 8
    tables = [jnp.asarray(rng.normal(size=(rows, dim)), jnp.float32)
              for _ in range(n_tables)]
    arena, bases = ops.build_arena(tables)
    idx = jnp.asarray(rng.integers(0, rows, (n_tables, batch, pool)),
                      jnp.int32)

    fused = jax.jit(lambda a, i: ops.fused_embedding_lookup_ref(a, bases, i))

    # the fusion win is launch/dispatch amortization (paper App. A.3.2):
    # unfused = one separate jitted dispatch PER TABLE, as an unfused
    # embedding implementation would issue one kernel launch per table
    unfused_one = jax.jit(embedding_bag_ref)

    def unfused(tabs, i):
        outs = [unfused_one(t, i[k]) for k, t in enumerate(tabs)]
        return outs

    us_fused = _time(fused, arena, idx)
    us_unfused = _time(unfused, tables, idx)

    # correctness vs the Pallas kernel (interpret mode)
    flat = ops.rebase_indices(idx, bases).reshape(n_tables * batch, pool)
    kern = embedding_bag_fused(arena, flat, interpret=True)
    ref = embedding_bag_ref(arena, flat)
    ok = bool(np.allclose(np.asarray(kern), np.asarray(ref), atol=1e-5))

    rows_out = [{
        "name": "embedding_bag_fused", "us_per_call": round(us_fused, 1),
        "derived": f"fusion_speedup={us_unfused / us_fused:.2f}x "
                   f"kernel_matches_ref={ok}",
    }, {
        "name": "embedding_bag_unfused", "us_per_call": round(us_unfused, 1),
        "derived": f"{n_tables}x single-table calls",
    }]
    for r in rows_out:
        print(r, flush=True)
    assert ok
    return rows_out


if __name__ == "__main__":
    run()
