"""B11: placement serving -- cache hit latency, admission, drift policy.

PR 8 adds ``repro.serve``: a placement service in front of
``PlacementSession`` with a digest-keyed placement cache, micro-batch
admission, and drift-triggered incremental re-placement.  This
benchmark replays a synthetic drifting request trace
(``repro.data.traffic``) through that service and measures what
serving infrastructure buys over per-task placement:

* **cold** -- the no-service strawman: every request decoded through
  ``session.place`` (warm compile, no cache); p50/p99 per-request wall
  time;
* **serve legs** -- the same trace through ``PlacementService`` under
  three drift policies: ``drift`` (threshold + migration-cost
  objective), ``never`` (threshold disabled; placements go stale), and
  ``always`` (re-place on any movement, migration term zeroed -- free
  moves).  Each leg reports cache hit rate, hit/decode latency
  quantiles, re-placement counts, bytes migrated, and the *end-to-end
  cost*: every request's placement scored against its TRUE features at
  serve time, plus an accounting charge for every byte moved (the
  ``drift`` leg's ``migration_ms_per_gb``, applied to ALL legs).

A ``determinism`` section replays a zero-drift trace and asserts the
service returns bitwise the ``PlacementSession.place_many``
assignments (cache + admission add no decision noise).

Writes ``BENCH_serve.json`` (committed at the repo root); the
``check_serve`` gate pins the acceptance criteria: warm-hit p50 >= 20x
under cold p50, the drift policy beating ``never`` on end-to-end cost
while moving fewer bytes than ``always``.
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import time

import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from benchmarks import common as C                             # noqa: E402
from repro.api import PlacementSession, ensure_oracle          # noqa: E402
from repro.core.trainer import DreamShardConfig                # noqa: E402
from repro.data.tasks import Task, sample_tasks, split_pool    # noqa: E402
from repro.data.traffic import TrafficConfig, make_trace       # noqa: E402
from repro.serve import PlacementService, ServeConfig          # noqa: E402

ROOT = os.path.join(os.path.dirname(__file__), "..")

# acceptance limits, committed with the baseline (the gate re-proves
# them on every fresh run and refuses silent relaxation)
LIMITS = {"hit_speedup_p50": 20.0, "min_hit_rate": 0.5}

# fixed per-regime configs: smoke runs the quick regime at its FULL
# config, so the check_bench gate always has comparable cells
REGIMES = {
    "quick": {
        "dataset": "DLRM", "n_jobs": 6, "n_tables": 16, "n_devices": 4,
        "n_requests": 400, "drift": 0.8, "zipf": 1.0, "tail_jobs": 4,
        "trainer": "reduced", "max_wait_ms": 2.0, "max_batch": 8,
        "ewma_alpha": 0.3, "drift_threshold": 0.05,
        "migration_ms_per_gb": 25.0, "replace_max_evals": 64, "seed": 0,
    },
    "paper": {
        "dataset": "DLRM", "n_jobs": 12, "n_tables": 50, "n_devices": 4,
        "n_requests": 1500, "drift": 0.8, "zipf": 1.0, "tail_jobs": 8,
        "trainer": "paper", "max_wait_ms": 2.0, "max_batch": 8,
        "ewma_alpha": 0.3, "drift_threshold": 0.05,
        "migration_ms_per_gb": 25.0, "replace_max_evals": 96, "seed": 0,
    },
}


def _trainer_cfg(kind: str) -> DreamShardConfig:
    if kind == "paper":
        return DreamShardConfig()
    return DreamShardConfig(n_iterations=3, n_collect=6, n_cost=100,
                            n_batch=32, n_rl=5, n_episode=10,
                            inference_candidates=8)


def _serve_cfg(spec: dict, policy: str) -> ServeConfig:
    threshold = {"drift": spec["drift_threshold"],
                 "never": None, "always": 0.0}[policy]
    per_gb = 0.0 if policy == "always" else spec["migration_ms_per_gb"]
    return ServeConfig(
        max_wait_ms=spec["max_wait_ms"], max_batch=spec["max_batch"],
        ewma_alpha=spec["ewma_alpha"], drift_threshold=threshold,
        migration_ms_per_gb=per_gb,
        replace_max_evals=spec["replace_max_evals"],
        replace_budget_ms=None, seed=spec["seed"])


def _quantiles(ms: list[float]) -> dict:
    if not ms:
        return {"p50_ms": None, "p99_ms": None}
    return {"p50_ms": round(float(np.percentile(ms, 50)), 4),
            "p99_ms": round(float(np.percentile(ms, 99)), 4)}


def _cold_leg(agent, trace) -> dict:
    """Per-task ``session.place`` on every request: the no-cache,
    no-batching strawman (session warmed so XLA compile is excluded --
    steady-state decode cost, not first-call cost)."""
    session = PlacementSession(agent)
    session.place(Task.of(trace[0].raw_features, trace[0].n_devices))
    ms = []
    t0 = time.perf_counter()
    for r in trace:
        t = time.perf_counter()
        session.place(Task.of(r.raw_features, r.n_devices))
        ms.append((time.perf_counter() - t) * 1e3)
    return {**_quantiles(ms), "wall_s": round(time.perf_counter() - t0, 2),
            "requests": len(trace)}


def _end_to_end_cost(oracle, trace, placements, bytes_moved_gb: float,
                     accounting_ms_per_gb: float) -> dict:
    """Score every request's served placement against its TRUE features
    at that moment, plus the accounting charge for migrated bytes."""
    request_ms = [
        oracle.evaluate(r.raw_features, placements[i].assignment,
                        r.n_devices).overall
        for i, r in enumerate(trace)]
    request_sum = float(np.sum(request_ms))
    migration_ms = accounting_ms_per_gb * bytes_moved_gb
    return {
        "request_cost_sum_ms": round(request_sum, 2),
        "request_cost_mean_ms": round(request_sum / len(trace), 4),
        "migration_charge_ms": round(migration_ms, 2),
        "end_to_end_cost_ms": round(request_sum + migration_ms, 2),
    }


def _serve_leg(agent, oracle, trace, spec: dict, policy: str) -> dict:
    svc = PlacementService(agent, oracle=oracle,
                           config=_serve_cfg(spec, policy))
    done = []
    t0 = time.perf_counter()
    for i, r in enumerate(trace):
        done += svc.submit(r.raw_features, r.n_devices, tag=i)
    done += svc.flush()
    wall = time.perf_counter() - t0
    assert len(done) == len(trace), (len(done), len(trace))

    placements = [None] * len(trace)
    hit_ms, decode_ms, all_ms = [], [], []
    for res in done:
        placements[res.tag] = res.placement
        all_ms.append(res.latency_ms)
        if res.source == "cache":
            if not res.replaced:     # pure hits; replaced pay the refine
                hit_ms.append(res.latency_ms)
        else:
            decode_ms.append(res.latency_ms)
    stats = svc.stats()
    cost = _end_to_end_cost(oracle, trace, placements,
                            stats["bytes_moved_gb"],
                            spec["migration_ms_per_gb"])
    return {
        "policy": policy,
        "hit_rate": round(stats["hit_rate"], 4),
        "hits": stats["hits"],
        "coalesced": stats["coalesced"],
        "decode_batches": stats["decode_batches"],
        "decoded_tasks": stats["decoded_tasks"],
        "replace_events": stats["replace_events"],
        "migrations": stats["migrations"],
        "bytes_moved_gb": round(stats["bytes_moved_gb"], 4),
        "hit": _quantiles(hit_ms),
        "decode": _quantiles(decode_ms),
        "overall": _quantiles(all_ms),
        "wall_s": round(wall, 2),
        "requests_per_s": round(len(trace) / wall, 1),
        **cost,
    }


def _determinism(agent, pool, spec: dict) -> dict:
    """Zero-drift replay must be bitwise ``PlacementSession.place_many``."""
    cfg = TrafficConfig(n_jobs=spec["n_jobs"], n_tables=spec["n_tables"],
                        n_devices=spec["n_devices"],
                        n_requests=4 * spec["n_jobs"], drift=0.0,
                        zipf=spec["zipf"], seed=spec["seed"])
    trace = make_trace(pool, cfg)
    svc = PlacementService(agent, config=_serve_cfg(spec, "drift"))
    done = []
    for i, r in enumerate(trace):
        done += svc.submit(r.raw_features, r.n_devices, tag=i)
    done += svc.flush()
    served = {res.tag: res.placement for res in done}

    first = {}
    for i, r in enumerate(trace):
        first.setdefault(r.job, i)
    jobs = sorted(first)
    reference = PlacementSession(agent).place_many(
        [Task.of(trace[first[j]].raw_features, trace[first[j]].n_devices)
         for j in jobs])
    identical = all(
        np.array_equal(served[i].assignment, ref.assignment)
        for i, ref in ((first[j], ref) for j, ref in zip(jobs, reference)))
    identical = identical and all(
        np.array_equal(served[i].assignment,
                       served[first[trace[i].job]].assignment)
        for i in range(len(trace)))
    return {"requests": len(trace), "replaces": svc.replace_events,
            "zero_drift_identical": bool(identical and
                                         svc.replace_events == 0)}


def _run_regime(name: str, spec: dict) -> dict:
    pool = C.get_pool(spec["dataset"])
    sim = C.get_sim(spec["dataset"])
    oracle = ensure_oracle(sim)
    train_ids, _ = split_pool(pool, seed=0)
    train = sample_tasks(pool, train_ids, spec["n_tables"],
                         spec["n_devices"], 8, seed=0, name="serve-train")
    with C.Timer() as t_train:
        agent = C.train_dreamshard(train, sim, _trainer_cfg(spec["trainer"]))

    cfg = TrafficConfig(n_jobs=spec["n_jobs"], n_tables=spec["n_tables"],
                        n_devices=spec["n_devices"],
                        n_requests=spec["n_requests"], drift=spec["drift"],
                        zipf=spec["zipf"], tail_jobs=spec["tail_jobs"],
                        seed=spec["seed"])
    trace = make_trace(pool, cfg)

    cold = _cold_leg(agent, trace)
    legs = {}
    for policy in ("drift", "never", "always"):
        legs[policy] = _serve_leg(agent, oracle, trace, spec, policy)
        print({"regime": name, "leg": policy,
               "hit_rate": legs[policy]["hit_rate"],
               "end_to_end_cost_ms": legs[policy]["end_to_end_cost_ms"],
               "bytes_moved_gb": legs[policy]["bytes_moved_gb"]},
              flush=True)
    determinism = _determinism(agent, pool, spec)

    hit_p50 = legs["drift"]["hit"]["p50_ms"]
    speedup = round(cold["p50_ms"] / hit_p50, 1) if hit_p50 else None
    row = {
        "config": spec,
        "train_s": round(t_train.s, 1),
        "cold": cold,
        "legs": legs,
        "determinism": determinism,
        "hit_speedup_p50": speedup,
    }
    print({"regime": name, "cold_p50_ms": cold["p50_ms"],
           "hit_p50_ms": hit_p50, "hit_speedup_p50": speedup,
           "zero_drift_identical": determinism["zero_drift_identical"]},
          flush=True)
    return row


def run(smoke: bool = False, out: str | None = None,
        regimes: list[str] | None = None):
    selected = ["quick"] if smoke else list(REGIMES)
    if regimes:
        selected = [r for r in selected if r in regimes] or \
            [r for r in REGIMES if r in regimes]
        if not selected:
            raise SystemExit(f"no such regime(s) {regimes}")

    result = {
        "benchmark": "b11_serve",
        "schema": 1,
        "mode": "smoke" if smoke else "full",
        "generated": time.strftime("%Y-%m-%dT%H:%M:%S"),
        "host": {"cpu_count": os.cpu_count(), "numpy": np.__version__},
        "limits": dict(LIMITS),
        "regimes": {},
    }
    for name in selected:
        result["regimes"][name] = _run_regime(name, REGIMES[name])

    head_name = "paper" if "paper" in result["regimes"] \
        else next(iter(result["regimes"]))
    reg = result["regimes"][head_name]
    result["headline"] = {
        "regime": head_name,
        "cold_p50_ms": reg["cold"]["p50_ms"],
        "hit_p50_ms": reg["legs"]["drift"]["hit"]["p50_ms"],
        "hit_speedup_p50": reg["hit_speedup_p50"],
        "hit_rate": reg["legs"]["drift"]["hit_rate"],
        "end_to_end_cost_ms": {
            p: reg["legs"][p]["end_to_end_cost_ms"]
            for p in ("drift", "never", "always")},
        "bytes_moved_gb": {
            p: reg["legs"][p]["bytes_moved_gb"]
            for p in ("drift", "never", "always")},
        "zero_drift_identical":
            reg["determinism"]["zero_drift_identical"],
    }
    if not smoke:
        # the PR's acceptance criteria, asserted at the source
        legs = reg["legs"]
        assert reg["hit_speedup_p50"] >= LIMITS["hit_speedup_p50"], \
            "warm cache hits are not >= 20x faster than cold place"
        assert legs["drift"]["end_to_end_cost_ms"] < \
            legs["never"]["end_to_end_cost_ms"], \
            "drift-triggered re-placement did not beat never-re-place"
        assert legs["drift"]["bytes_moved_gb"] < \
            legs["always"]["bytes_moved_gb"], \
            "drift policy moved no fewer bytes than always-re-place"
        assert reg["determinism"]["zero_drift_identical"]
    out = out or os.path.join(ROOT, "BENCH_serve.json")
    with open(out, "w") as f:
        json.dump(result, f, indent=2)
        f.write("\n")
    print({"headline": result["headline"], "written": os.path.abspath(out)},
          flush=True)
    return result


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--smoke", action="store_true",
                    help="quick regime only (same config as full: the "
                         "bench gate stays comparable)")
    ap.add_argument("--out", default=None, help="output JSON path")
    ap.add_argument("--regimes", default=None,
                    help="comma-separated regime subset (quick, paper)")
    ap.add_argument("--trace", default=None, metavar="PATH",
                    help="record telemetry and export a trace on exit "
                         "(.jsonl -> event log, else Chrome trace JSON)")
    args = ap.parse_args()
    from repro import telemetry as tele
    with tele.trace_to(args.trace):
        run(smoke=args.smoke, out=args.out,
            regimes=args.regimes.split(",") if args.regimes else None)
