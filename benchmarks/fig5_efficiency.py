"""Paper Fig 5: DreamShard cost on held-out tasks vs training iteration
and wall-clock seconds."""

from __future__ import annotations

from benchmarks import common as C
from repro.core.trainer import DreamShard


def run():
    n_tasks, cfg = C.budget()
    pool = C.get_pool("DLRM")
    sim = C.get_sim("DLRM")
    m, d = (50, 4)
    train, test = C.make_benchmark_suite(pool, m, d, n_tasks=n_tasks)
    ds = DreamShard(train, sim, cfg)
    ds.train(eval_tasks=test[:8])
    rows = []
    wall = 0.0
    for h in ds.history:
        wall += h["wall_s"]
        rows.append({"iteration": h["iteration"],
                     "wall_s": round(wall, 1),
                     "eval_cost_ms": round(h["eval_cost_ms"], 2),
                     "cost_net_mse": round(h["cost_loss"], 4)})
        print(rows[-1], flush=True)
    return rows


if __name__ == "__main__":
    run()
