"""B6: fused Algorithm-1 trainer throughput vs the seed per-step loop.

The seed loop pays per-step host costs everywhere: ``update_cost`` issues
``n_cost`` sequential jit dispatches (each rebuilding + re-uploading a
padded numpy minibatch), ``collect`` decodes one rollout per jit call
(plus an eager per-task sort), and ``update_policy`` dispatches per step
and retraces per ``(n_devices, n_episodes)`` shape.  The fused trainer
(``DreamShardConfig(fused=True)``) runs each stage as ONE dispatch: a
vmapped padded collect whose oracle measurements go through the batched
``evaluate_many`` path (one vectorized pass per distinct task -- see
``benchmarks/b7_oracle_throughput.py`` for the oracle-side numbers), a
donated ``lax.scan`` over the device-resident replay ring, and a scan over
a padded task batch for REINFORCE -- and the two loops are numerically
equivalent (same RNG streams, same updates; see
``tests/test_fused_trainer.py``), so speedup comes with identical final
eval cost.

Two measured regimes on the 20-table/4-device suite:

* ``paper``  -- the paper's Algorithm-1 budget (n_collect=10, n_cost=300,
  n_batch=64, n_rl=10).  On CPU-only hosts the 300x64 minibatch matmuls
  dominate both variants, so this regime mostly bounds the wall win from
  below while showing the dispatch/retrace elimination.
* ``scale``  -- the collection-bound regime the paper's successors hit at
  scale (Pre-train-and-Search: the cost-model data pipeline is the
  bottleneck): 10x the measurements per iteration (n_collect=100) with
  lean minibatches (n_batch=8) that keep a 2-core CI host measuring loop
  overhead rather than matmul throughput.  This is the headline row.

Per-iteration wall-clock is the MEDIAN over warm iterations (>= 1;
iteration 0 carries each variant's compiles, reported separately), since
the per-step loop's hundreds of sync'd dispatches make it noisy on shared
hosts.  Writes ``BENCH_train.json`` (committed at the repo root; CI
uploads a fresh copy per run) so the training-throughput trajectory
accumulates across PRs.
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import os
import sys
import time

import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from benchmarks import common as C                  # noqa: E402,F401
from repro.core.trainer import DreamShard, DreamShardConfig  # noqa: E402
from repro.data.synthetic import make_dlrm_pool     # noqa: E402
from repro.data.tasks import make_benchmark_suite   # noqa: E402
from repro.sim.costsim import CostSimulator         # noqa: E402

ROOT = os.path.join(os.path.dirname(__file__), "..")


def _regimes(smoke: bool):
    if smoke:
        base = dict(n_iterations=3, n_collect=20, n_cost=40, n_rl=4)
        return {"scale": DreamShardConfig(n_batch=8, **base)}
    base = dict(n_iterations=10, n_collect=10, n_cost=300, n_rl=10)
    return {
        "paper": DreamShardConfig(n_batch=64, **base),
        "scale": DreamShardConfig(n_batch=8, **{**base, "n_collect": 100}),
    }


def _compiles(agent: DreamShard) -> int:
    """Distinct traces the trainer's update functions accumulated."""
    if agent.cfg.fused:
        return (agent._fused_cost_update.traces[0]
                + agent._fused_rl_update.traces[0])
    n = len(agent._rl_updates)
    try:
        n += agent._cost_update._cache_size()
    except AttributeError:                        # older jax
        n += 1
    return n


def _run_variant(fused: bool, cfg: DreamShardConfig, train, test) -> dict:
    sim = CostSimulator(seed=0)
    agent = DreamShard(train, sim, dataclasses.replace(cfg, fused=fused))
    t0 = time.perf_counter()
    agent.train()
    total = time.perf_counter() - t0
    walls = [h["wall_s"] for h in agent.history]
    warm = walls[1:] if len(walls) > 1 else walls
    return {
        "variant": "fused" if fused else "seed",
        "total_wall_s": round(total, 3),
        "iter_wall_s": [round(w, 4) for w in walls],
        "warm_iter_median_s": round(float(np.median(warm)), 4),
        "warm_iter_mean_s": round(float(np.mean(warm)), 4),
        "dispatches_per_iter": agent.history[-1]["dispatches"],
        "compiled_traces": _compiles(agent),
        "final_cost_loss": round(agent.history[-1]["cost_loss"], 6),
        "eval_cost_ms": round(agent.evaluate_tasks(test), 4),
    }


def run(smoke: bool = False, out: str | None = None, repeats: int = 1,
        regimes: list[str] | None = None):
    pool = make_dlrm_pool(seed=0)
    train, test = make_benchmark_suite(pool, n_tables=20, n_devices=4,
                                       n_tasks=10)
    selected = _regimes(smoke)
    if regimes:
        selected = {k: v for k, v in selected.items() if k in regimes}
        if not selected:
            raise SystemExit(f"no such regime(s) {regimes}; "
                             f"have {list(_regimes(smoke))}")
    result = {
        "benchmark": "b6_train_throughput",
        "schema": 1,
        "mode": "smoke" if smoke else "full",
        "generated": time.strftime("%Y-%m-%dT%H:%M:%S"),
        "repeats": repeats,
        "suite": {"n_tables": 20, "n_devices": 4, "n_train_tasks": len(train),
                  "n_eval_tasks": len(test)},
        "host": {"cpu_count": os.cpu_count(),
                 "jax": __import__("jax").__version__},
        "regimes": {},
    }
    for name, cfg in selected.items():
        # alternate seed/fused runs so shared-host load hits both evenly;
        # the per-iteration metric is the median of per-run warm medians
        runs = {"seed": [], "fused": []}
        for rep in range(repeats):
            for fused in (False, True):
                row = _run_variant(fused, cfg, train, test)
                runs[row["variant"]].append(row)
                print({"regime": name, "rep": rep, **row}, flush=True)
        seed_row, fused_row = runs["seed"][-1], runs["fused"][-1]
        seed_med = float(np.median(
            [r["warm_iter_median_s"] for r in runs["seed"]]))
        fused_med = float(np.median(
            [r["warm_iter_median_s"] for r in runs["fused"]]))
        eval_rel = abs(fused_row["eval_cost_ms"] - seed_row["eval_cost_ms"]) \
            / seed_row["eval_cost_ms"]
        summary = {
            "config": {k: getattr(cfg, k) for k in
                       ("n_iterations", "n_collect", "n_cost", "n_batch",
                        "n_rl", "n_episode")},
            "seed": seed_row, "fused": fused_row,
            "seed_warm_iter_medians_s": [r["warm_iter_median_s"]
                                         for r in runs["seed"]],
            "fused_warm_iter_medians_s": [r["warm_iter_median_s"]
                                          for r in runs["fused"]],
            "per_iteration_speedup": round(seed_med / fused_med, 2),
            "total_speedup": round(seed_row["total_wall_s"]
                                   / fused_row["total_wall_s"], 2),
            "dispatch_reduction": round(seed_row["dispatches_per_iter"]
                                        / fused_row["dispatches_per_iter"], 1),
            "eval_rel_diff": round(eval_rel, 5),
        }
        result["regimes"][name] = summary
        print({"regime": name,
               "per_iteration_speedup": summary["per_iteration_speedup"],
               "total_speedup": summary["total_speedup"],
               "dispatch_reduction": summary["dispatch_reduction"],
               "eval_rel_diff": summary["eval_rel_diff"]}, flush=True)

    head_name = "scale" if "scale" in result["regimes"] \
        else next(iter(result["regimes"]))
    head = result["regimes"][head_name]
    result["headline"] = {
        "regime": head_name,
        "per_iteration_speedup": head["per_iteration_speedup"],
        "dispatch_reduction": head["dispatch_reduction"],
        "eval_rel_diff": head["eval_rel_diff"],
    }
    out = out or os.path.join(ROOT, "BENCH_train.json")
    with open(out, "w") as f:
        json.dump(result, f, indent=2)
        f.write("\n")
    print({"headline": result["headline"], "written": os.path.abspath(out)},
          flush=True)
    return result


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--smoke", action="store_true",
                    help="tiny budget for CI: scale regime only")
    ap.add_argument("--out", default=None, help="output JSON path")
    ap.add_argument("--repeats", type=int, default=1,
                    help="alternating seed/fused runs per regime; the "
                         "per-iteration metric is the median across runs")
    ap.add_argument("--regimes", default=None,
                    help="comma-separated regime subset (e.g. 'scale'; CI "
                         "runs the full-config scale regime so the bench "
                         "gate can compare against the committed baseline)")
    ap.add_argument("--trace", default=None, metavar="PATH",
                    help="record telemetry and export a trace on exit "
                         "(.jsonl -> event log, else Chrome trace JSON)")
    args = ap.parse_args()
    from repro import telemetry as tele
    with tele.trace_to(args.trace):
        run(smoke=args.smoke, out=args.out, repeats=max(1, args.repeats),
            regimes=args.regimes.split(",") if args.regimes else None)
