"""B8: fusion-aware vs additive multi-table cost model, judged live.

DreamShard's first measurement insight is that a *fused* multi-table
embedding op does not cost the sum of its per-table costs (paper Fig 12):
one launch is paid instead of K, and co-scheduled tables pipeline.  PR 2's
``MeasuredOracle`` still priced per-device compute additively; the v2
calibration artifact fits a ``FusionModel`` (launch-overhead amortization
+ per-rank pipelining discount) from a fused multi-table sweep.

This benchmark scores both models against ground truth nothing was fitted
on: random multi-table placements timed LIVE by
``profiling.measure_placement`` (the old per-placement kernel loop, with
per-table pooling).  For every (placement, device, fwd/bwd) cell it
compares the live per-device compute time with

* the **additive** prediction (sum of single-table grid interpolations,
  ``MeasuredOracle(table, fusion=False)`` -- the pre-v2 model), and
* the **fusion-aware** prediction (same grid, same artifact, priced
  through the fitted ``FusionModel``),

and reports both MAPEs.  Acceptance: the fusion-aware MAPE is strictly
below the additive MAPE on the same calibration artifact.  Writes
``BENCH_fusion.json`` (committed at the repo root; CI re-runs ``--smoke``
and gates on it via ``benchmarks/check_bench.py``).

The bench pool is synthesized inside the calibrated hull (dims/rows/
poolings the grid covers, live-harness batch) so model error measures the
*fusion* gap, not extrapolation error -- the same protocol the fused
sweep itself uses, but with held-out shapes, real placements, and the
live harness rather than the sweep's own measurements.
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import time

import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from repro.api import MeasuredOracle                           # noqa: E402
from repro.core import features as F                           # noqa: E402
from repro.profiling import (CalibrationTable, load_or_none,   # noqa: E402
                             measure_placement)

ROOT = os.path.join(os.path.dirname(__file__), "..")
N_DEVICES = 4
BATCH = 64          # the live harness' per-table lookup batch
MAX_ROWS = 4096     # live harness row clamp; the pool stays below it


def _settings(smoke: bool) -> dict:
    # dim-homogeneous pools (like the DLRM suites): a fused op runs all
    # of a device's tables in one arena at the widest padded dim, so
    # mixing dims would fold arena-padding inflation -- a mix effect the
    # K/total-work model deliberately does not see -- into both models'
    # error.  Rows/poolings stay heterogeneous.
    if smoke:
        return {"grid": {"dims": (128,), "rows": (256, 1024, 4096),
                         "batches": (BATCH,), "poolings": (2, 8)},
                "fused_ks": (2, 4), "fused_per_k": 3, "warmup": 1,
                "repeats": 2, "n_tables": 12, "n_placements": 4}
    return {"grid": {"dims": (128,), "rows": (256, 1024, 4096),
                     "batches": (BATCH,), "poolings": (2, 4, 8)},
            "fused_ks": (2, 3, 4, 6), "fused_per_k": 6, "warmup": 2,
            "repeats": 5, "n_tables": 16, "n_placements": 10}


def bench_pool(n_tables: int, grid: dict, seed: int = 0) -> np.ndarray:
    """Heterogeneous tables drawn inside the calibrated hull: dims on the
    grid, rows log-uniform across it, integer poolings spanning it."""
    rng = np.random.default_rng(seed)
    dims = rng.choice(grid["dims"], size=n_tables)
    lo, hi = min(grid["rows"]), max(grid["rows"])
    rows = np.exp(rng.uniform(np.log(lo), np.log(hi), size=n_tables))
    rows = np.rint(rows).astype(np.float64)
    pools = rng.integers(min(grid["poolings"]), max(grid["poolings"]) + 1,
                         size=n_tables).astype(np.float64)
    dist = np.full((n_tables, F.NUM_DIST_BINS), 1.0 / F.NUM_DIST_BINS)
    return F.pack_features(dims, rows, pools, dist)


def get_table(settings: dict, path: str | None = None
              ) -> tuple[CalibrationTable, float]:
    """Calibrate (or reuse a matching cached artifact) at the live
    harness' operating point: same batch, dims >= the fused arena floor."""
    from repro.profiling.calibration import hardware_fingerprint
    path = path or os.path.join(ROOT, "artifacts", "calibration",
                                "b8_calibration.npz")
    t0 = time.perf_counter()
    cached = load_or_none(path)
    grid = settings["grid"]
    if (cached is not None and cached.version == 2
            and cached.fingerprint == hardware_fingerprint()
            and cached.fusion_fwd.source == "measured"
            and all(np.array_equal(getattr(cached, k),
                                   np.asarray(grid[k], np.float64))
                    for k in ("dims", "rows", "batches", "poolings"))):
        return cached, time.perf_counter() - t0
    table = CalibrationTable.measure(
        **grid, use_pallas=False, warmup=settings["warmup"],
        repeats=settings["repeats"],
        fused_ks=settings["fused_ks"], fused_per_k=settings["fused_per_k"],
        meta={"bench": "b8"})
    table.save(path)
    return table, time.perf_counter() - t0


def mape_cells(live: list, pred: list) -> float:
    """MAPE over every (placement, device, stage) compute cell that the
    live harness actually measured (devices with tables)."""
    errs = []
    for lv, pr in zip(live, pred):
        for stage in ("fwd_comp", "bwd_comp"):
            lt, pt = getattr(lv, stage), getattr(pr, stage)
            mask = lt > 0
            errs.append(np.abs(pt[mask] - lt[mask]) / lt[mask])
    return float(np.mean(np.concatenate(errs)))


def determinism_fingerprint() -> dict:
    """Hardware-free probe of the oracle pricing stack: synthetic table,
    fixed task, fixed placements.  Any unintended cost-model change shows
    up as drift here (gated by check_bench.py with a tight rtol)."""
    table = CalibrationTable.synthetic()
    rng = np.random.default_rng(7)
    dist = np.full((10, F.NUM_DIST_BINS), 1.0 / F.NUM_DIST_BINS)
    raw = F.pack_features(rng.choice((16, 64, 256), 10),
                          rng.choice((256, 4096), 10),
                          rng.integers(2, 9, 10).astype(np.float64), dist)
    A = rng.integers(0, 4, size=(16, 10), dtype=np.int64)
    out = {}
    for name, fusion in (("fused", True), ("additive", False)):
        oracle = MeasuredOracle(table, fusion=fusion)
        res = oracle.evaluate_many(raw, A, 4)
        out[f"mean_overall_{name}"] = round(
            float(np.mean([r.overall for r in res])), 10)
    return out


def run(smoke: bool = False, out: str | None = None):
    settings = _settings(smoke)
    result = {
        "benchmark": "b8_fusion_model",
        "schema": 1,
        "mode": "smoke" if smoke else "full",
        "generated": time.strftime("%Y-%m-%dT%H:%M:%S"),
        "task": {"n_tables": settings["n_tables"], "n_devices": N_DEVICES,
                 "n_placements": settings["n_placements"], "batch": BATCH},
        "host": {"cpu_count": os.cpu_count(), "numpy": np.__version__},
    }

    table, cal_s = get_table(settings)
    result["calibration"] = {
        "wall_s": round(cal_s, 2),
        "summary": table.summary(),
        "fusion_fwd": table.fusion_fwd.to_dict(),
        "fusion_bwd": table.fusion_bwd.to_dict(),
    }
    print(result["calibration"], flush=True)

    raw = bench_pool(settings["n_tables"], settings["grid"], seed=0)
    rng = np.random.default_rng(1)
    A = np.stack([rng.integers(0, N_DEVICES, size=settings["n_tables"])
                  for _ in range(settings["n_placements"])]).astype(np.int64)

    t0 = time.perf_counter()
    live = [measure_placement(raw, a, N_DEVICES, batch_size=BATCH,
                              pooling=None, max_rows=MAX_ROWS,
                              repeats=settings["repeats"]) for a in A]
    live_s = time.perf_counter() - t0

    fused = MeasuredOracle(table, batch_size=BATCH).evaluate_many(
        raw, A, N_DEVICES)
    additive = MeasuredOracle(table, batch_size=BATCH,
                              fusion=False).evaluate_many(raw, A, N_DEVICES)

    mape_fused = mape_cells(live, fused)
    mape_additive = mape_cells(live, additive)
    result["accuracy"] = {
        "live_wall_s": round(live_s, 2),
        "compute_cells": 2 * int(sum((r.fwd_comp > 0).sum() for r in live)),
        "mape_fusion_aware": round(mape_fused, 4),
        "mape_additive": round(mape_additive, 4),
        "mape_ratio": round(mape_fused / max(mape_additive, 1e-12), 4),
    }
    print(result["accuracy"], flush=True)

    result["determinism"] = determinism_fingerprint()
    result["headline"] = {
        "mape_fusion_aware": result["accuracy"]["mape_fusion_aware"],
        "mape_additive": result["accuracy"]["mape_additive"],
    }
    if not smoke:
        assert mape_fused < mape_additive, (
            f"fusion-aware MAPE {mape_fused:.4f} is not below additive "
            f"{mape_additive:.4f}")

    out = out or os.path.join(ROOT, "BENCH_fusion.json")
    with open(out, "w") as f:
        json.dump(result, f, indent=2)
        f.write("\n")
    print({"headline": result["headline"], "written": os.path.abspath(out)},
          flush=True)
    return result


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--smoke", action="store_true",
                    help="tiny calibration + few placements for CI")
    ap.add_argument("--out", default=None, help="output JSON path")
    args = ap.parse_args()
    run(smoke=args.smoke, out=args.out)
