"""B13: column-wise sharding -- feasibility beyond whole-table placement.

PR 10 redesigns the placement API around shards: ``ShardSpec`` splits a
table's embedding columns into K contiguous ranges on distinct devices,
with K = 1 a bitwise-identical special case of the legacy whole-table
path.  This benchmark measures what the redesign buys and pins what it
must not cost:

* **feasibility leg** -- a suite of tasks whose largest table exceeds
  single-device memory (``oversize_scale`` x ``mem_capacity_gb``).
  Every whole-table placer (the four expert heuristics + random) must
  come back memory-illegal on every task; ``ShardingPlacer`` must
  produce a legal column-sharded placement for all of them.  Reports
  the legal fractions, mean shard counts, and the sharded cost vs the
  (illegal) whole-table expert cost on the same tasks;
* **K = 1 identity leg** -- on the unmodified (feasible) suite, the
  trivial spec routed through ``evaluate_sharded`` / ``legal_sharded``
  / ``sharded_placement_key`` must match ``evaluate_many`` /
  ``legal_batch`` / ``placement_key`` bitwise, and a trivially-sharded
  query must HIT the cache entry written by the legacy query (same
  digest -> shared ``CachedOracle`` entry);
* **refine leg** -- ``refine_sharded`` (shard-move search alternated
  with split/merge spec mutations) must never return a worse placement
  than the ``ShardingPlacer`` seed it starts from.

Writes ``BENCH_sharding.json`` (committed at the repo root); the
``check_sharding`` gate re-proves the feasibility counts, the K = 1
identity fingerprint, and the refine monotonicity on every fresh run.
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import time

import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from benchmarks import common as C                             # noqa: E402
from repro.api import (CachedOracle, ShardSpec,                 # noqa: E402
                       ShardingPlacer, ensure_oracle, evaluate_many,
                       evaluate_sharded, legal_batch, legal_sharded,
                       make_baseline_placers, placement_key, refine_sharded,
                       sharded_placement_key)
from repro.core import features as F                           # noqa: E402
from repro.data.tasks import Task, sample_tasks, split_pool    # noqa: E402
from repro.search import SearchConfig                          # noqa: E402

ROOT = os.path.join(os.path.dirname(__file__), "..")

# acceptance limits, committed with the baseline (the gate re-proves
# them on every fresh run and refuses silent relaxation)
LIMITS = {"min_sharded_legal_fraction": 1.0,
          "max_whole_table_legal_fraction": 0.0}

# fixed per-regime configs: smoke runs the quick regime at its FULL
# config, so the check_bench gate always has comparable cells
REGIMES = {
    "quick": {
        "dataset": "DLRM", "n_tasks": 6, "n_tables": 12, "n_devices": 4,
        "oversize_scale": 2.5, "refine_max_evals": 96, "seed": 0,
    },
    "paper": {
        "dataset": "DLRM", "n_tasks": 12, "n_tables": 24, "n_devices": 8,
        "oversize_scale": 3.0, "refine_max_evals": 192, "seed": 0,
    },
}


def _suites(spec: dict):
    """(feasible, oversized) task suites drawn from the test pool.

    The oversized suite clones each feasible task and inflates its
    largest table to ``oversize_scale`` x device memory -- illegal for
    every whole-table placement by construction."""
    pool = C.get_pool(spec["dataset"])
    _, test_ids = split_pool(pool, seed=0)
    feasible = sample_tasks(pool, test_ids, spec["n_tables"],
                            spec["n_devices"], spec["n_tasks"],
                            seed=spec["seed"], name="shard")
    sim = C.get_sim(spec["dataset"])
    capacity = float(sim.spec.mem_capacity_gb)
    oversized = []
    for t in feasible:
        raw = np.array(t.raw_features, dtype=np.float64)
        big = int(np.argmax(raw[:, F.TABLE_SIZE_GB]))
        raw[big, F.TABLE_SIZE_GB] = spec["oversize_scale"] * capacity
        oversized.append(Task.of(raw, t.n_devices, name=t.name + "-over"))
    return feasible, oversized, sim


def _feasibility_leg(oracle, oversized: list[Task], spec: dict) -> dict:
    whole = make_baseline_placers(oracle, seed=spec["seed"])
    whole_legal = 0
    whole_costs = []
    for task in oversized:
        raw = task.raw_features
        legal_any = False
        for placer in whole.values():
            p = placer.place(task)
            a = np.asarray(p.assignment, np.int64)
            legal_any |= bool(legal_batch(oracle, raw, a[None],
                                          task.n_devices)[0])
        whole_legal += int(legal_any)
        # the (illegal) expert placement is still priced: the overhead
        # comparator for the legal sharded placement below
        a = np.asarray(whole["size"].place(task).assignment, np.int64)
        whole_costs.append(float(evaluate_many(oracle, raw, a[None],
                                               task.n_devices)[0].overall))

    sharder = ShardingPlacer(oracle)
    sharded_legal = 0
    sharded_costs, refined_costs, shard_counts = [], [], []
    refine_cfg = SearchConfig(strategy="lns", budget_ms=None,
                              max_evals=spec["refine_max_evals"],
                              seed=spec["seed"])
    for task in oversized:
        p = sharder.place(task)
        ok = bool(legal_sharded(oracle, task.raw_features, p.sharding,
                                np.asarray(p.shard_assignment)[None],
                                task.n_devices)[0])
        sharded_legal += int(ok)
        sharded_costs.append(float(p.est_cost_ms))
        shard_counts.append(int(p.sharding.shard_counts.max()))
        r = refine_sharded(oracle, task, p, refine_cfg, split_rounds=1)
        refined_costs.append(float(r.est_cost_ms))
    n = len(oversized)
    return {
        "tasks": n,
        "whole_table_legal": whole_legal,
        "whole_table_legal_fraction": round(whole_legal / n, 4),
        "sharded_legal": sharded_legal,
        "sharded_legal_fraction": round(sharded_legal / n, 4),
        "max_shard_count_mean": round(float(np.mean(shard_counts)), 2),
        "whole_cost_ms_mean": round(float(np.mean(whole_costs)), 4),
        "sharded_cost_ms_mean": round(float(np.mean(sharded_costs)), 4),
        "refined_cost_ms_mean": round(float(np.mean(refined_costs)), 4),
        "sharded_vs_whole": round(float(np.mean(sharded_costs)
                                        / np.mean(whole_costs)), 4),
        "refine_regressions": sum(1 for s, r in zip(sharded_costs,
                                                    refined_costs)
                                  if r > s + 1e-9),
    }


def _identity_leg(oracle, feasible: list[Task], spec: dict) -> dict:
    """K = 1 fingerprint: trivial-spec sharded calls reduce bitwise to
    the legacy whole-table path -- costs, legality, digests, and shared
    cache entries."""
    expert = make_baseline_placers(oracle, seed=spec["seed"])["size"]
    cost_bitwise = digest_equal = legal_equal = True
    cache_shared = True
    for task in feasible:
        raw = task.raw_features
        a = np.asarray(expert.place(task).assignment, np.int64)
        trivial = ShardSpec.trivial(raw)
        r_leg = evaluate_many(oracle, raw, a[None], task.n_devices)
        r_sh = evaluate_sharded(oracle, raw, trivial, a[None],
                                task.n_devices)
        cost_bitwise &= (len(r_leg) == len(r_sh)) and all(
            rl.overall == rs.overall for rl, rs in zip(r_leg, r_sh))
        legal_equal &= (legal_batch(oracle, raw, a[None],
                                    task.n_devices).tolist()
                        == legal_sharded(oracle, raw, trivial, a[None],
                                         task.n_devices).tolist())
        digest_equal &= (placement_key(raw, a, task.n_devices)
                         == sharded_placement_key(raw, trivial, a,
                                                  task.n_devices))
        # legacy query warms the cache; the trivially-sharded repeat of
        # the SAME query must hit the same entry
        cache = CachedOracle(oracle)
        evaluate_many(cache, raw, a[None], task.n_devices)
        evaluate_sharded(cache, raw, trivial, a[None], task.n_devices)
        cache_shared &= (cache.misses, cache.hits) == (1, 1)
    return {"tasks": len(feasible),
            "cost_bitwise": bool(cost_bitwise),
            "legality_equal": bool(legal_equal),
            "digest_equal": bool(digest_equal),
            "cache_entry_shared": bool(cache_shared)}


def _run_regime(name: str, spec: dict) -> dict:
    feasible, oversized, sim = _suites(spec)
    oracle = ensure_oracle(sim)
    t0 = time.perf_counter()
    feas = _feasibility_leg(oracle, oversized, spec)
    ident = _identity_leg(oracle, feasible, spec)
    wall = time.perf_counter() - t0
    row = {
        "config": spec,
        "capacity_gb": float(sim.spec.mem_capacity_gb),
        "feasibility": feas,
        "k1_identity": ident,
        "oracle_evals": int(oracle.num_evaluations),
        "wall_s": round(wall, 2),
    }
    print({"regime": name,
           "whole_table_legal": feas["whole_table_legal"],
           "sharded_legal": f"{feas['sharded_legal']}/{feas['tasks']}",
           "sharded_vs_whole": feas["sharded_vs_whole"],
           "k1_identity": all(v for k, v in ident.items()
                              if k != "tasks")}, flush=True)
    return row


def run(smoke: bool = False, out: str | None = None,
        regimes: list[str] | None = None):
    selected = ["quick"] if smoke else list(REGIMES)
    if regimes:
        selected = [r for r in selected if r in regimes] or \
            [r for r in REGIMES if r in regimes]
        if not selected:
            raise SystemExit(f"no such regime(s) {regimes}")

    result = {
        "benchmark": "b13_sharding",
        "schema": 1,
        "mode": "smoke" if smoke else "full",
        "generated": time.strftime("%Y-%m-%dT%H:%M:%S"),
        "host": {"cpu_count": os.cpu_count(), "numpy": np.__version__},
        "limits": dict(LIMITS),
        "regimes": {name: _run_regime(name, REGIMES[name])
                    for name in selected},
    }

    head_name = "paper" if "paper" in result["regimes"] \
        else next(iter(result["regimes"]))
    reg = result["regimes"][head_name]
    result["headline"] = {
        "regime": head_name,
        "whole_table_legal_fraction":
            reg["feasibility"]["whole_table_legal_fraction"],
        "sharded_legal_fraction":
            reg["feasibility"]["sharded_legal_fraction"],
        "sharded_vs_whole": reg["feasibility"]["sharded_vs_whole"],
        "refined_cost_ms_mean": reg["feasibility"]["refined_cost_ms_mean"],
        "k1_identity": all(v for k, v in reg["k1_identity"].items()
                           if k != "tasks"),
    }
    if not smoke:
        # the PR's acceptance criteria, asserted at the source
        for name, r in result["regimes"].items():
            f, ident = r["feasibility"], r["k1_identity"]
            assert f["whole_table_legal_fraction"] <= \
                LIMITS["max_whole_table_legal_fraction"], \
                f"{name}: a whole-table placer fit an oversized table"
            assert f["sharded_legal_fraction"] >= \
                LIMITS["min_sharded_legal_fraction"], \
                f"{name}: ShardingPlacer left a task memory-illegal"
            assert f["refine_regressions"] == 0, \
                f"{name}: refine_sharded returned a worse placement"
            assert all(v for k, v in ident.items() if k != "tasks"), \
                f"{name}: K=1 identity fingerprint broke: {ident}"
    out = out or os.path.join(ROOT, "BENCH_sharding.json")
    with open(out, "w") as f:
        json.dump(result, f, indent=2)
        f.write("\n")
    print({"headline": result["headline"], "written": os.path.abspath(out)},
          flush=True)
    return result


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--smoke", action="store_true",
                    help="quick regime only (same config as full: the "
                         "bench gate stays comparable)")
    ap.add_argument("--out", default=None, help="output JSON path")
    ap.add_argument("--regimes", default=None,
                    help="comma-separated regime subset (quick, paper)")
    ap.add_argument("--trace", default=None, metavar="PATH",
                    help="record telemetry and export a trace on exit "
                         "(.jsonl -> event log, else Chrome trace JSON)")
    args = ap.parse_args()
    from repro import telemetry as tele
    with tele.trace_to(args.trace):
        run(smoke=args.smoke, out=args.out,
            regimes=args.regimes.split(",") if args.regimes else None)
