"""Unified decoder-only LM covering all assigned architecture families.

One parametric model implements: dense GQA decoders (qwen/granite/phi4/
danube/llava backbone/musicgen), MoE decoders (dbrx/olmoe), the hymba
hybrid block (parallel attention + selective-SSM heads), and RWKV-6.
Multimodal frontends (ViT patches / EnCodec frames) are stubs: the model
consumes precomputed frame/patch embeddings alongside token embeddings.

Layers are stacked along a leading L axis and applied with `lax.scan`
(+ optional `jax.checkpoint`), which keeps HLO size and 512-device compile
times tractable for 88-layer configs.

Three entry points (built into jitted steps by ``repro.launch.steps``):
  * ``forward``      -- train/eval logits over a full sequence
  * ``prefill``      -- forward + populated KV/state caches
  * ``decode_step``  -- one token against a (circular) cache
"""

from __future__ import annotations


import jax
import jax.numpy as jnp
import numpy as np

from repro.models import layers as L
from repro.models import ssm as S
from repro.models.config import ArchConfig
from repro.models.sharding import NO_SHARDING, ShardingRules
from jax.sharding import PartitionSpec as P


def _barrier_differentiable() -> bool:
    """Older jax (< 0.5) has no differentiation rule for
    ``optimization_barrier``; probe once and fall back to identity there
    (the barrier is a memory-layout optimization, not a semantic one)."""
    global _BARRIER_OK
    if _BARRIER_OK is None:
        try:
            jax.grad(lambda v: jax.lax.optimization_barrier(v).sum())(
                jnp.ones((1,)))
            _BARRIER_OK = True
        except NotImplementedError:
            _BARRIER_OK = False
    return _BARRIER_OK


_BARRIER_OK: bool | None = None


def _init(key, shape, scale, dtype):
    return (jax.random.normal(key, shape) * scale).astype(dtype)


class LM:
    def __init__(self, cfg: ArchConfig, rules: ShardingRules = NO_SHARDING,
                 remat: bool = True, q_chunk: int = 1024,
                 kv_chunk: int = 1024, dtype=jnp.bfloat16,
                 layer_loop: str = "scan"):
        assert cfg.tp >= 1 and cfg.head_dim, "config must be resolve()d"
        assert layer_loop in ("scan", "unrolled")
        self.cfg = cfg
        self.rules = rules
        self.remat = remat
        self.q_chunk = q_chunk
        self.kv_chunk = kv_chunk
        self.dtype = dtype
        # "unrolled" replaces the layer scan with a python loop: used by the
        # dry-run's metric compiles (cost_analysis counts a scan body once,
        # so roofline terms are extrapolated from unrolled 1/2-layer builds)
        self.layer_loop = layer_loop
        self.seq_parallel = True
        # one-hot matmul embedding: needed for sharded TRAINING gradients
        # (steps.lower_prefill/lower_decode switch it off -- see _embed)
        self.embed_onehot = True
        if cfg.n_heads:
            # map (padded) q head -> true kv head; padded heads reuse head 0
            g = max(1, cfg.n_heads // cfg.n_kv_heads)
            self.kv_map = np.array(
                [min(i // g, cfg.n_kv_heads - 1) if i < cfg.n_heads else 0
                 for i in range(cfg.n_heads_padded)])
            self.grouped = cfg.n_heads_padded % cfg.n_kv_heads == 0
        else:
            self.kv_map, self.grouped = None, False

    # ---- parameters ----------------------------------------------------------

    def _init_layer(self, key):
        cfg, dt = self.cfg, self.dtype
        ks = iter(jax.random.split(key, 24))
        p = {}
        if cfg.block == "rwkv":
            p["ln1"] = jnp.ones((cfg.d_model,), dt)
            p["ln2"] = jnp.ones((cfg.d_model,), dt)
            p["rwkv"] = S.rwkv_init(next(ks), cfg.d_model, cfg.d_ff, dt)
            return p
        hd, Hq, Hkv = cfg.head_dim, cfg.n_heads_padded, cfg.n_kv_heads
        p["ln1"] = jnp.ones((cfg.d_model,), dt)
        p["ln2"] = jnp.ones((cfg.d_model,), dt)
        p["wq"] = _init(next(ks), (cfg.d_model, Hq * hd), 0.02, dt)
        p["wk"] = _init(next(ks), (cfg.d_model, Hkv * hd), 0.02, dt)
        p["wv"] = _init(next(ks), (cfg.d_model, Hkv * hd), 0.02, dt)
        p["wo"] = _init(next(ks), (Hq * hd, cfg.d_model), 0.02, dt)
        if cfg.qkv_bias:
            p["bq"] = jnp.zeros((Hq * hd,), dt)
            p["bk"] = jnp.zeros((Hkv * hd,), dt)
            p["bv"] = jnp.zeros((Hkv * hd,), dt)
        if cfg.block == "hybrid":
            p["ssm"] = S.ssm_init(next(ks), cfg.d_model, cfg.ssm.state_dim,
                                  cfg.ssm.expand, cfg.ssm.conv_width, dt)
        if cfg.moe:
            E = cfg.moe.n_experts
            p["moe"] = {
                "router": _init(next(ks), (cfg.d_model, E), 0.02, jnp.float32),
                "wg": _init(next(ks), (E, cfg.d_model, cfg.d_ff), 0.02, dt),
                "wu": _init(next(ks), (E, cfg.d_model, cfg.d_ff), 0.02, dt),
                "wo": _init(next(ks), (E, cfg.d_ff, cfg.d_model), 0.02, dt),
            }
        else:
            p["mlp"] = {"wu": _init(next(ks), (cfg.d_model, cfg.d_ff), 0.02, dt),
                        "wo": _init(next(ks), (cfg.d_ff, cfg.d_model), 0.02, dt)}
            if cfg.act == "swiglu":
                p["mlp"]["wg"] = _init(next(ks), (cfg.d_model, cfg.d_ff), 0.02, dt)
        return p

    def init_params(self, key):
        cfg, dt = self.cfg, self.dtype
        k_emb, k_head, k_layers = jax.random.split(key, 3)
        params = {
            "embed": _init(k_emb, (cfg.vocab_padded, cfg.d_model), 0.02, dt),
            "final_norm": jnp.ones((cfg.d_model,), dt),
            "layers": jax.vmap(self._init_layer)(
                jax.random.split(k_layers, cfg.n_layers)),
        }
        if not cfg.tie_embeddings:
            params["lm_head"] = _init(k_head, (cfg.d_model, cfg.vocab_padded),
                                      0.02, dt)
        return params

    def abstract_params(self):
        return jax.eval_shape(self.init_params, jax.random.PRNGKey(0))

    # ---- parameter partition specs --------------------------------------------

    def param_specs(self, fsdp: bool | None = None):
        """Parameter PartitionSpecs.  With ``fsdp`` (default: on when
        sharding is enabled), each weight's d_model-like dim is additionally
        sharded over the data axis (ZeRO-3): GSPMD all-gathers weights
        just-in-time per layer and reduce-scatters their grads, removing
        the data-axis replication of params + optimizer state."""
        cfg = self.cfg
        m = self.rules.model_axis          # None = pure-FSDP (no TP)
        fsdp = self.rules.enabled if fsdp is None else fsdp
        d = self.rules.fsdp_dim if fsdp else None
        kv_shardable = cfg.n_kv_heads and cfg.n_kv_heads % cfg.tp == 0
        kv = P(None, d, m) if kv_shardable else P(None, d, None)
        kvb = P(None, m) if kv_shardable else P(None, None)
        lay = {}
        if cfg.block == "rwkv":
            lay = {"ln1": P(None, None), "ln2": P(None, None),
                   "rwkv": {
                       "att": {"mu": P(None, None, None),
                               "wr": P(None, d, m), "wk": P(None, d, m),
                               "wv": P(None, d, m), "wg": P(None, d, m),
                               "ww": P(None, d, m),
                               "w_bias": P(None, None),
                               "u": P(None, m, None),
                               "wo": P(None, m, d)},
                       "ffn": {"mu": P(None, None, None),
                               "wk": P(None, d, m),
                               "wv": P(None, m, d),
                               "wr": P(None, d, None)}}}
        else:
            lay = {"ln1": P(None, None), "ln2": P(None, None),
                   "wq": P(None, d, m), "wk": kv, "wv": kv,
                   "wo": P(None, m, d)}
            if cfg.qkv_bias:
                lay.update({"bq": P(None, m), "bk": kvb, "bv": kvb})
            if cfg.block == "hybrid":
                lay["ssm"] = {"in_proj": P(None, d, m),
                              "conv": P(None, None, m),
                              "wdt": P(None, m),
                              "wB": P(None, m, None), "wC": P(None, m, None),
                              "logA": P(None, m, None),
                              "out_proj": P(None, m, d),
                              "dskip": P(None, m)}
            if cfg.moe:
                lay["moe"] = {"router": P(None, None, None),
                              "wg": P(None, m, d, None),
                              "wu": P(None, m, d, None),
                              "wo": P(None, m, None, d)}
            else:
                mlp = {"wu": P(None, d, m), "wo": P(None, m, d)}
                if cfg.act == "swiglu":
                    mlp["wg"] = P(None, d, m)
                lay["mlp"] = mlp
        specs = {"embed": P(m, d), "final_norm": P(None), "layers": lay}
        if not cfg.tie_embeddings:
            specs["lm_head"] = P(d, m)
        return specs

    # ---- sublayers -------------------------------------------------------------

    def _expand_all_kv(self, k):
        """Expand kv heads to the full (padded) q head count via take."""
        if k.shape[2] == self.cfg.n_heads_padded:
            return k
        return jnp.take(k, jnp.asarray(self.kv_map), axis=2)

    def _attn(self, lp, h, positions, cache=None, pos=None):
        cfg, rules = self.cfg, self.rules
        B, Sq, D = h.shape
        hd, Hq, Hkv = cfg.head_dim, cfg.n_heads_padded, cfg.n_kv_heads
        q = jnp.einsum("bsd,de->bse", h, lp["wq"])
        k = jnp.einsum("bsd,de->bse", h, lp["wk"])
        v = jnp.einsum("bsd,de->bse", h, lp["wv"])
        if cfg.qkv_bias:
            q, k, v = q + lp["bq"], k + lp["bk"], v + lp["bv"]
        q = q.reshape(B, Sq, Hq, hd)
        k = k.reshape(B, Sq, Hkv, hd)
        v = v.reshape(B, Sq, Hkv, hd)
        q = L.apply_rope(q, positions, cfg.rope_theta)
        k = L.apply_rope(k, positions, cfg.rope_theta)
        q = rules.constrain(q, "batch", None, "model", None)

        def pin(t):
            return self.rules.constrain(t, None, "batch", None,
                                        "model", None)

        new_cache = None
        if cache is None:                               # train/eval, no cache
            ke, ve = self._expand_all_kv(k), self._expand_all_kv(v)
            out = L.flash_attention(q, ke, ve, causal=True,
                                    window=cfg.sliding_window,
                                    q_chunk=self.q_chunk,
                                    kv_chunk=self.kv_chunk, constrain=pin)
        elif Sq > 1:                                    # prefill into cache
            T = cache["k"].shape[1]
            kc = jax.lax.dynamic_update_slice(cache["k"], k.astype(cache["k"].dtype),
                                              (0, 0, 0, 0))
            vc = jax.lax.dynamic_update_slice(cache["v"], v.astype(cache["v"].dtype),
                                              (0, 0, 0, 0))
            new_cache = {"k": kc, "v": vc}
            ke, ve = self._expand_all_kv(k), self._expand_all_kv(v)
            out = L.flash_attention(q, ke, ve, causal=True,
                                    window=cfg.sliding_window,
                                    q_chunk=self.q_chunk,
                                    kv_chunk=self.kv_chunk, constrain=pin)
        else:                                           # single-token decode
            T = cache["k"].shape[1]
            idx = pos % T                               # circular buffer
            kc = jax.lax.dynamic_update_slice(
                cache["k"], k.astype(cache["k"].dtype), (0, idx, 0, 0))
            vc = jax.lax.dynamic_update_slice(
                cache["v"], v.astype(cache["v"].dtype), (0, idx, 0, 0))
            new_cache = {"k": kc, "v": vc}
            # circular buffer: once pos >= T every slot holds one of the
            # last T tokens (T = sliding window for SWA archs)
            n_valid = jnp.minimum(pos + 1, T)
            valid = (jnp.arange(T) < n_valid)[None, :].repeat(B, 0)
            if self.grouped:        # grouped decode: no kv expansion,
                ke, ve = kc, vc     # cache stays at true kv heads
            else:
                ke, ve = self._expand_all_kv(kc), self._expand_all_kv(vc)
            out = L.decode_attention(q, ke, ve, valid)
        out = out.reshape(B, Sq, Hq * hd)
        return jnp.einsum("bse,ed->bsd", out, lp["wo"]), new_cache

    def _ffn(self, lp, h):
        cfg = self.cfg
        if cfg.moe:
            y, aux = L.moe_apply(
                lp["moe"], h, n_experts=cfg.moe.n_experts,
                top_k=cfg.moe.top_k,
                capacity_factor=cfg.moe.capacity_factor, act=cfg.act,
                constrain=(self.rules.constrain if self.rules.enabled
                           else None),
                seq_chunks=(self.cfg.tp if h.shape[1] % self.cfg.tp == 0
                            else 1))
            return y, aux
        return L.mlp_apply(lp["mlp"], h, cfg.act), 0.0

    def _layer(self, lp, x, positions, cache=None, pos=None):
        """One block. Returns (x, new_cache_layer, aux)."""
        cfg = self.cfg
        if cfg.block == "rwkv":
            h = L.rms_norm(x, lp["ln1"], cfg.norm_eps)
            sx0 = cache["sx_att"] if cache else jnp.zeros(
                (x.shape[0], cfg.d_model), x.dtype)
            st0 = cache["wkv"] if cache else jnp.zeros(
                (x.shape[0], cfg.d_model // S.RWKV_HEAD_DIM,
                 S.RWKV_HEAD_DIM, S.RWKV_HEAD_DIM), jnp.float32)
            y, sx_att, wkv = S.rwkv_time_mix(lp["rwkv"]["att"], h, sx0, st0)
            x = x + y
            h = L.rms_norm(x, lp["ln2"], cfg.norm_eps)
            sx1 = cache["sx_ffn"] if cache else jnp.zeros(
                (x.shape[0], cfg.d_model), x.dtype)
            y, sx_ffn = S.rwkv_channel_mix(lp["rwkv"]["ffn"], h, sx1)
            x = x + y
            new_cache = {"wkv": wkv, "sx_att": sx_att.astype(x.dtype),
                         "sx_ffn": sx_ffn.astype(x.dtype)} if cache else None
            return x, new_cache, 0.0

        h = L.rms_norm(x, lp["ln1"], cfg.norm_eps)
        attn_out, attn_cache = self._attn(
            lp, h, positions,
            cache=({"k": cache["k"], "v": cache["v"]} if cache else None),
            pos=pos)
        mix = attn_out
        new_cache = dict(attn_cache) if attn_cache else None
        if cfg.block == "hybrid":
            st = (cache["ssm_state"], cache["conv"]) if cache else (None, None)
            ssm_out, (ssm_state, conv) = S.ssm_apply(lp["ssm"], h,
                                                     state=st[0],
                                                     conv_carry=st[1])
            mix = mix + ssm_out
            if cache:
                new_cache.update({"ssm_state": ssm_state, "conv": conv})
        # constrain the (partial-sum) sublayer output to the stream spec
        # BEFORE the residual add: GSPMD then emits a reduce-scatter into
        # the sequence-sharded domain instead of a full all-reduce (2x wire)
        x = x + self._constrain_stream(mix)
        h = L.rms_norm(x, lp["ln2"], cfg.norm_eps)
        y, aux = self._ffn(lp, h)
        x = self._constrain_stream(x + self._constrain_stream(y))
        return x, new_cache, aux

    def _constrain_stream(self, x):
        """Residual stream: sequence-parallel over the model axis when the
        sequence divides (Megatron-SP); the per-layer gather/scatter GSPMD
        inserts costs the same wire bytes as the plain all-reduce but cuts
        the remat-saved activations by the TP degree."""
        if x.shape[1] > 1 and x.shape[1] % self.cfg.tp == 0 and self.seq_parallel:
            return self.rules.constrain(x, "batch", "model", None)
        return self.rules.constrain(x, "batch", None, None)

    # ---- embeddings / logits ----------------------------------------------------

    def _embed(self, params, tokens, embeds):
        xs = []
        if embeds is not None:
            xs.append(embeds.astype(self.dtype))
        if tokens is not None:
            if self.rules.enabled and self.embed_onehot:
                # one-hot matmul (training): the take()-gather's scatter-add
                # backward replicates the full-vocab f32 gradient on every
                # device; the matmul form keeps fwd and bwd vocab-sharded.
                # At inference (no gradient) the plain gather is far
                # cheaper: the one-hot itself is (B, S, V) -- 7.8 GB/dev
                # for llava's 32k prefill.
                oh = jax.nn.one_hot(tokens, params["embed"].shape[0],
                                    dtype=self.dtype)
                xs.append(jnp.einsum("bsv,vd->bsd", oh, params["embed"]))
            else:
                xs.append(jnp.take(params["embed"], tokens, axis=0))
        x = jnp.concatenate(xs, axis=1) if len(xs) > 1 else xs[0]
        return self._constrain_stream(x)

    def _head(self, params, x):
        head = (params["embed"].T if self.cfg.tie_embeddings
                else params["lm_head"])
        logits = jnp.einsum("bsd,dv->bsv", x, head)
        return self.rules.constrain(logits, "batch", None, "model")

    def _logits(self, params, x):
        x = L.rms_norm(x, params["final_norm"], self.cfg.norm_eps)
        return self._head(params, x)

    # ---- entry points -------------------------------------------------------------

    def _backbone(self, params, tokens=None, embeds=None):
        """Embed + layer stack + final norm. Returns (x (B,S,D), aux)."""
        x = self._embed(params, tokens, embeds)
        positions = jnp.arange(x.shape[1])[None, :]

        def body(xc, lp):
            # the barrier stops XLA from hoisting the rms_norm bf16->f32
            # convert of the whole saved activation stack out of the
            # backward loop (a 2x-per-elem temp blowup otherwise)
            if _barrier_differentiable():
                xc = jax.lax.optimization_barrier(xc)
            xo, _, aux = self._layer(lp, xc, positions)
            return xo, aux

        if self.remat:
            body = jax.checkpoint(body)
        if self.layer_loop == "unrolled":
            auxs = []
            for i in range(self.cfg.n_layers):
                lp = jax.tree.map(lambda a: a[i], params["layers"])
                x, aux = body(x, lp)
                auxs.append(aux)
            aux = jnp.mean(jnp.stack(auxs))
        else:
            x, auxs = jax.lax.scan(body, x, params["layers"])
            aux = jnp.mean(auxs)
        x = L.rms_norm(x, params["final_norm"], self.cfg.norm_eps)
        return x, aux

    def forward(self, params, tokens=None, embeds=None):
        """Train/eval forward. Returns (logits (B,S,Vp), moe aux loss)."""
        x, aux = self._backbone(params, tokens, embeds)
        return self._head(params, x), aux

    def forward_loss(self, params, tokens, labels, loss_mask=None,
                     embeds=None, loss_chunk: int = 512):
        """Fused chunked cross-entropy: never materializes (B,S,Vp) logits.

        The head matmul + CE run per sequence chunk under jax.checkpoint,
        so peak logits memory is (B, chunk, Vp/tp) and the backward
        recomputes each chunk's logits instead of saving them.
        """
        x, aux = self._backbone(params, tokens, embeds)
        head = (params["embed"].T if self.cfg.tie_embeddings
                else params["lm_head"])
        B, S, D = x.shape
        c = min(loss_chunk, S)
        n = S // c
        assert S % c == 0
        xs = jnp.moveaxis(x.reshape(B, n, c, D), 1, 0)
        ls = jnp.moveaxis(labels.reshape(B, n, c), 1, 0)
        if loss_mask is None:
            loss_mask = jnp.ones((B, S), jnp.float32)
        ms = jnp.moveaxis(loss_mask.reshape(B, n, c), 1, 0)

        @jax.checkpoint
        def body(carry, inp):
            xc, lc, mc = inp
            logits = jnp.einsum("bsd,dv->bsv", xc, head)
            logits = self.rules.constrain(logits, "batch", None, "model")
            nll, msum = _chunk_ce(logits, lc, mc, self.cfg.vocab)
            return (carry[0] + nll, carry[1] + msum), None

        (nll, msum), _ = jax.lax.scan(body, (0.0, 0.0), (xs, ls, ms))
        return nll / jnp.maximum(msum, 1.0), aux

    def init_cache(self, batch: int, capacity: int):
        cfg, dt = self.cfg, self.dtype
        c = {}
        if cfg.block in ("attn", "hybrid"):
            kv_shape = (cfg.n_layers, batch, capacity, cfg.n_kv_heads,
                        cfg.head_dim)
            c["k"] = jnp.zeros(kv_shape, dt)
            c["v"] = jnp.zeros(kv_shape, dt)
        if cfg.block == "hybrid":
            di = cfg.ssm.expand * cfg.d_model
            c["ssm_state"] = jnp.zeros(
                (cfg.n_layers, batch, di, cfg.ssm.state_dim), jnp.float32)
            c["conv"] = jnp.zeros(
                (cfg.n_layers, batch, cfg.ssm.conv_width - 1, di), dt)
        if cfg.block == "rwkv":
            H = cfg.d_model // S.RWKV_HEAD_DIM
            c["wkv"] = jnp.zeros((cfg.n_layers, batch, H, S.RWKV_HEAD_DIM,
                                  S.RWKV_HEAD_DIM), jnp.float32)
            c["sx_att"] = jnp.zeros((cfg.n_layers, batch, cfg.d_model), dt)
            c["sx_ffn"] = jnp.zeros((cfg.n_layers, batch, cfg.d_model), dt)
        return {"layers": c, "pos": jnp.zeros((), jnp.int32)}

    def cache_specs(self, rules: ShardingRules | None = None):
        """PartitionSpecs for the cache pytree."""
        r = rules or self.rules
        cfg = self.cfg
        kv_shardable = cfg.n_kv_heads and cfg.n_kv_heads % cfg.tp == 0
        # kv cache: batch over data; heads over model when divisible,
        # otherwise sequence over model (sequence-parallel decode attention).
        kv = (r.spec(None, "batch", None, "model", None) if kv_shardable
              else r.spec(None, "batch", "model", None, None))
        c = {}
        if cfg.block in ("attn", "hybrid"):
            c["k"] = kv
            c["v"] = kv
        if cfg.block == "hybrid":
            c["ssm_state"] = r.spec(None, "batch", "model", None)
            c["conv"] = r.spec(None, "batch", None, "model")
        if cfg.block == "rwkv":
            c["wkv"] = r.spec(None, "batch", "model", None, None)
            c["sx_att"] = r.spec(None, "batch", None)
            c["sx_ffn"] = r.spec(None, "batch", None)
        return {"layers": c, "pos": P()}

    def prefill(self, params, tokens=None, embeds=None, capacity=None):
        """Forward pass that also populates caches. Returns (logits, cache)."""
        x = self._embed(params, tokens, embeds)
        B, Sq = x.shape[0], x.shape[1]
        capacity = capacity or Sq
        cache0 = self.init_cache(B, capacity)
        positions = jnp.arange(Sq)[None, :]

        def body(xc, inp):
            lp, cl = inp
            xo, new_cl, aux = self._layer(lp, xc, positions, cache=cl,
                                          pos=jnp.zeros((), jnp.int32))
            return xo, (new_cl, aux)

        if self.remat:
            body = jax.checkpoint(body)
        if self.layer_loop == "unrolled":
            outs = []
            for i in range(self.cfg.n_layers):
                inp = jax.tree.map(lambda a: a[i],
                                   (params["layers"], cache0["layers"]))
                x, out = body(x, inp)
                outs.append(out)
            new_layers = jax.tree.map(lambda *a: jnp.stack(a), *
                                      [o[0] for o in outs])
        else:
            x, (new_layers, _) = jax.lax.scan(
                body, x, (params["layers"], cache0["layers"]))
        cache = {"layers": new_layers,
                 "pos": jnp.full((), Sq, jnp.int32)}
        return self._logits(params, x[:, -1:]), cache

    def decode_step(self, params, cache, tokens):
        """One decode step. tokens: (B, 1). Returns (logits (B,1,Vp), cache)."""
        x = self._embed(params, tokens, None)
        pos = cache["pos"]
        positions = jnp.full((x.shape[0], 1), pos)

        if self.layer_loop == "unrolled":
            outs = []
            for i in range(self.cfg.n_layers):
                lp, cl = jax.tree.map(lambda a: a[i],
                                      (params["layers"], cache["layers"]))
                x, out, _ = self._layer(lp, x, positions, cache=cl, pos=pos)
                outs.append(out)
            new_layers = jax.tree.map(lambda *a: jnp.stack(a), *outs)
            cache = {"layers": new_layers, "pos": pos + 1}
            return self._logits(params, x), cache

        # cache travels as scan CARRY with per-layer dynamic updates: with
        # donation the update aliases in place.  (As xs/ys the stacked cache
        # is copied input->output through the loop: 2x cache temp.)
        def body(carry, inp):
            xc, cl_all = carry
            i, lp = inp
            cl = jax.tree.map(
                lambda a: jax.lax.dynamic_index_in_dim(a, i, 0, False),
                cl_all)
            xo, new_cl, _ = self._layer(lp, xc, positions, cache=cl, pos=pos)
            cl_all = jax.tree.map(
                lambda a, n: jax.lax.dynamic_update_index_in_dim(a, n, i, 0),
                cl_all, new_cl)
            return (xo, cl_all), None

        (x, new_layers), _ = jax.lax.scan(
            body, (x, cache["layers"]),
            (jnp.arange(self.cfg.n_layers), params["layers"]))
        cache = {"layers": new_layers, "pos": pos + 1}
        return self._logits(params, x), cache


# ---- loss ----------------------------------------------------------------------

def _chunk_ce(logits, labels, mask, vocab: int | None):
    """Summed masked CE over one chunk. Returns (sum_nll, sum_mask)."""
    logits = logits.astype(jnp.float32)
    if vocab is not None and vocab < logits.shape[-1]:
        live = jnp.arange(logits.shape[-1]) < vocab
        logits = jnp.where(live, logits, -1e9)
    lse = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = (lse - ll) * mask
    return nll.sum(), mask.sum()


def lm_loss(logits, labels, mask=None, vocab: int | None = None):
    """Mean next-token cross-entropy. logits: (B,S,Vp), labels: (B,S)."""
    logits = logits.astype(jnp.float32)
    if vocab is not None and vocab < logits.shape[-1]:
        live = jnp.arange(logits.shape[-1]) < vocab
        logits = jnp.where(live, logits, -1e9)
    lse = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = lse - ll
    if mask is not None:
        return jnp.sum(nll * mask) / jnp.maximum(mask.sum(), 1.0)
    return jnp.mean(nll)
