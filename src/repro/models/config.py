"""Architecture configuration for the assigned model pool.

Each assigned architecture gets a module in ``repro.configs`` exporting a
``FULL`` ArchConfig (exact published shape) and a ``SMOKE`` reduced variant
(<=2 layers, d_model<=512, <=4 experts) for CPU tests.  ``resolve(tp)``
adapts head counts to a tensor-parallel degree: query heads are padded to a
multiple of tp (inert zero heads, vLLM-style) and KV heads replicated up to
tp when smaller -- the padding shows up honestly in the roofline's
MODEL_FLOPS/HLO_FLOPs ratio.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Optional


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    capacity_factor: float = 1.25


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    state_dim: int = 16
    conv_width: int = 4
    expand: int = 2


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                     # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int                    # 0 for attention-free archs
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0               # 0 -> d_model // n_heads
    qkv_bias: bool = False
    rope_theta: float = 1e4
    sliding_window: Optional[int] = None   # tokens; None = full attention
    block: str = "attn"             # attn | hybrid (attn+ssm) | rwkv
    act: str = "swiglu"             # swiglu | gelu
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    frontend: Optional[str] = None  # None | vlm | audio (stubbed embeddings)
    n_frontend_tokens: int = 0      # embeddings prepended by the stub
    tie_embeddings: bool = False
    norm_eps: float = 1e-5
    source: str = ""                # citation for the config

    # resolved sharding-dependent fields (set by resolve())
    tp: int = 1
    n_heads_padded: int = 0
    n_kv_padded: int = 0
    vocab_padded: int = 0

    def resolve(self, tp: int) -> "ArchConfig":
        """Bind the config to a tensor-parallel degree."""
        hd = self.head_dim or (self.d_model // max(self.n_heads, 1))
        nh = self.n_heads
        nkv = self.n_kv_heads
        nh_pad = math.ceil(nh / tp) * tp if nh else 0
        if nkv and nkv < tp:
            nkv_pad = tp                       # replicate KV heads across TP
        elif nkv:
            nkv_pad = math.ceil(nkv / tp) * tp
        else:
            nkv_pad = 0
        # query heads per kv group must stay integral after padding
        if nkv_pad:
            group = max(1, nh_pad // nkv_pad)
            nh_pad = group * nkv_pad
        vpad = math.ceil(self.vocab / tp) * tp
        assert self.d_ff % tp == 0, (self.name, self.d_ff, tp)
        return dataclasses.replace(
            self, tp=tp, head_dim=hd, n_heads_padded=nh_pad,
            n_kv_padded=nkv_pad, vocab_padded=vpad)

    @property
    def q_per_kv(self) -> int:
        return self.n_heads_padded // max(self.n_kv_padded, 1)

    def param_count(self) -> int:
        """Approximate parameter count of the FULL (unpadded) architecture."""
        d, ff, v, L = self.d_model, self.d_ff, self.vocab, self.n_layers
        hd = self.head_dim or (self.d_model // max(self.n_heads, 1))
        emb = v * d * (1 if self.tie_embeddings else 2)
        per_layer = 0
        if self.block in ("attn", "hybrid"):
            per_layer += d * hd * self.n_heads + hd * self.n_heads * d  # q, o
            per_layer += 2 * d * hd * self.n_kv_heads                   # k, v
        if self.block == "hybrid" and self.ssm:
            di = self.ssm.expand * d
            per_layer += d * 2 * di + di * d + di * self.ssm.state_dim * 2
        if self.block == "rwkv":
            per_layer += 6 * d * d
        n_ffn = 3 if self.act == "swiglu" else 2
        if self.moe:
            per_layer += d * self.moe.n_experts  # router
            per_layer += self.moe.n_experts * n_ffn * d * ff
        else:
            per_layer += n_ffn * d * ff
        return emb + L * per_layer

    def active_param_count(self) -> int:
        """Parameters touched per token (MoE: only routed experts)."""
        if not self.moe:
            return self.param_count()
        full = self.param_count()
        n_ffn = 3 if self.act == "swiglu" else 2
        expert = n_ffn * self.d_model * self.d_ff
        inactive = self.n_layers * (self.moe.n_experts - self.moe.top_k) * expert
        return full - inactive
