"""DLRM recommender model (paper App. A.1, after Naumov et al. 2019).

Dense features -> bottom MLP; sparse features -> distributed embedding
lookups (table-wise model parallel, DreamShard-placed) -> pairwise dot
interaction with the dense representation -> top MLP -> CTR logit.

The dense parts are data-parallel (replicated params, batch-sharded
activations); the embedding arenas are model-parallel via
``repro.embedding.sharded``.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.embedding import sharded as E
from repro.embedding.plan import PlacementPlan


@dataclasses.dataclass(frozen=True)
class DLRMConfig:
    n_dense_features: int = 13
    embed_dim: int = 128            # padded feature dim (plan.dim)
    bottom_mlp: tuple = (512, 256)
    top_mlp: tuple = (1024, 512, 256)
    n_tables: int = 50


def _mlp_init(key, sizes, dtype):
    params = []
    for n_in, n_out in zip(sizes[:-1], sizes[1:]):
        key, k = jax.random.split(key)
        params.append({
            "w": (jax.random.normal(k, (n_in, n_out))
                  * np.sqrt(2.0 / n_in)).astype(dtype),
            "b": jnp.zeros((n_out,), dtype)})
    return params


def _mlp(params, x):
    for i, layer in enumerate(params):
        x = x @ layer["w"] + layer["b"]
        if i < len(params) - 1:
            x = jax.nn.relu(x)
    return x


class DLRM:
    def __init__(self, cfg: DLRMConfig, plan: PlacementPlan,
                 dtype=jnp.float32):
        self.cfg = cfg
        self.plan = plan
        self.dtype = dtype
        self.n_slots = plan.n_shards * plan.k_max

    def init_params(self, key):
        cfg = self.cfg
        k1, k2, k3 = jax.random.split(key, 3)
        n_inter = cfg.n_tables + 1          # tables + dense rep
        inter_dim = n_inter * (n_inter - 1) // 2 + cfg.embed_dim
        return {
            "arenas": E.init_arenas(k1, self.plan, self.dtype),
            "bottom": _mlp_init(k2, (cfg.n_dense_features, *cfg.bottom_mlp,
                                     cfg.embed_dim), self.dtype),
            "top": _mlp_init(k3, (inter_dim, *cfg.top_mlp, 1), self.dtype),
        }

    def _interact(self, dense_rep, sparse):
        """Pairwise dot interaction. sparse: (B, T, D); dense: (B, D)."""
        feats = jnp.concatenate([dense_rep[:, None, :], sparse], axis=1)
        z = jnp.einsum("bid,bjd->bij", feats, feats)
        n = feats.shape[1]
        iu, ju = np.triu_indices(n, k=1)
        return jnp.concatenate([dense_rep, z[:, iu, ju]], axis=-1)

    def forward(self, params, dense, grouped_indices, lookup_fn):
        """dense: (B, n_dense); grouped_indices: (B, S*K, P) (plan layout).

        lookup_fn: the sharded (or oracle) embedding lookup.
        Returns CTR logits (B,).
        """
        plan = self.plan
        bases = jnp.asarray(plan.base_rows)
        sparse_all = lookup_fn(params["arenas"], bases, grouped_indices)
        # drop padded slots, keep true tables in original order
        order = plan.grouped_index_order()
        keep = np.flatnonzero(order >= 0)
        inv = keep[np.argsort(order[keep], kind="stable")]
        sparse = jnp.take(sparse_all, jnp.asarray(inv), axis=1)
        dense_rep = _mlp(params["bottom"], dense.astype(self.dtype))
        x = self._interact(dense_rep, sparse.astype(self.dtype))
        return _mlp(params["top"], x)[:, 0]

    @staticmethod
    def loss(logits, labels):
        """Binary cross-entropy with logits."""
        logits = logits.astype(jnp.float32)
        return jnp.mean(jnp.maximum(logits, 0) - logits * labels
                        + jnp.log1p(jnp.exp(-jnp.abs(logits))))
