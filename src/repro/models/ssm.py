"""State-space sequence mixers: selective SSM (Mamba-style, for hymba's
parallel attn+SSM heads) and RWKV-6 "Finch" time-mix with data-dependent
decay.

Both expose a full-sequence form (lax.scan over time -- O(S) state, used
for train/prefill) and a single-step form carrying recurrent state (used
for decode; this is what makes `long_500k` tractable for these families).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


# ---- selective SSM (Mamba-style) ----------------------------------------------

def ssm_init(key, d_model: int, state_dim: int, expand: int, conv_width: int,
             dtype=jnp.bfloat16):
    di = expand * d_model
    ks = jax.random.split(key, 6)
    def init(k, shape, scale):
        return (jax.random.normal(k, shape) * scale).astype(dtype)

    return {
        "in_proj": init(ks[0], (d_model, 2 * di), 0.02),
        "conv": init(ks[1], (conv_width, di), 0.2),
        "wdt": init(ks[2], (di,), 0.02),
        "wB": init(ks[3], (di, state_dim), 0.02),
        "wC": init(ks[4], (di, state_dim), 0.02),
        # log-A parametrization keeps the recurrence stable
        "logA": jnp.log(jnp.arange(1, state_dim + 1, dtype=jnp.float32)
                        )[None, :].repeat(di, 0).astype(jnp.float32),
        "out_proj": init(ks[5], (di, d_model), 0.02),
        "dskip": jnp.ones((di,), dtype),
    }


def _ssm_recurrence(params, x, h0):
    """x: (B, S, Di) post-conv activations; h0: (B, Di, N). -> (y, hT)."""
    A = -jnp.exp(params["logA"])                               # (Di, N)
    dt = jax.nn.softplus((x * params["wdt"]).astype(jnp.float32))
    Bc = jnp.einsum("bsd,dn->bsn", x, params["wB"]).astype(jnp.float32)
    Cc = jnp.einsum("bsd,dn->bsn", x, params["wC"]).astype(jnp.float32)

    def step(h, t):
        x_t, dt_t, b_t, c_t = t
        decay = jnp.exp(dt_t[..., None] * A[None])             # (B, Di, N)
        h = h * decay + (dt_t * x_t.astype(jnp.float32))[..., None] * b_t[:, None, :]
        y = jnp.einsum("bdn,bn->bd", h, c_t)
        return h, y

    xs = (jnp.moveaxis(x, 1, 0), jnp.moveaxis(dt, 1, 0),
          jnp.moveaxis(Bc, 1, 0), jnp.moveaxis(Cc, 1, 0))
    hT, ys = jax.lax.scan(step, h0, xs)
    y = jnp.moveaxis(ys, 0, 1)                                 # (B, S, Di)
    return y.astype(x.dtype), hT


def _causal_conv(x, conv, carry=None):
    """Depthwise causal conv. x: (B,S,Di), conv: (W,Di), carry: (B,W-1,Di)."""
    W = conv.shape[0]
    if carry is None:
        carry = jnp.zeros((x.shape[0], W - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([carry, x], axis=1)
    out = sum(xp[:, i:i + x.shape[1]] * conv[i] for i in range(W))
    return out, xp[:, -(W - 1):]


def ssm_apply(params, x, state=None, conv_carry=None):
    """x: (B, S, D). Returns (y (B,S,D), (state, conv_carry))."""
    di = params["out_proj"].shape[0]
    xz = jnp.einsum("bsd,de->bse", x, params["in_proj"])
    xi, z = jnp.split(xz, 2, axis=-1)
    xi, conv_carry = _causal_conv(xi, params["conv"], conv_carry)
    xi = jax.nn.silu(xi.astype(jnp.float32)).astype(x.dtype)
    if state is None:
        state = jnp.zeros((x.shape[0], di, params["wB"].shape[1]), jnp.float32)
    y, state = _ssm_recurrence(params, xi, state)
    y = y + xi * params["dskip"]
    y = y * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype)
    return jnp.einsum("bsd,de->bse", y, params["out_proj"]), (state, conv_carry)


# ---- RWKV-6 (Finch) ------------------------------------------------------------

RWKV_HEAD_DIM = 64


def rwkv_init(key, d_model: int, d_ff: int, dtype=jnp.bfloat16):
    H = d_model // RWKV_HEAD_DIM
    ks = jax.random.split(key, 10)
    def init(k, shape, scale=0.02):
        return (jax.random.normal(k, shape) * scale).astype(dtype)

    return {
        "att": {
            "mu": init(ks[0], (5, d_model), 0.5),       # token-shift mixes r,k,v,w,g
            "wr": init(ks[1], (d_model, d_model)),
            "wk": init(ks[2], (d_model, d_model)),
            "wv": init(ks[3], (d_model, d_model)),
            "wg": init(ks[4], (d_model, d_model)),
            "ww": init(ks[5], (d_model, d_model)),      # data-dependent decay proj
            "w_bias": jnp.full((d_model,), -6.0, jnp.float32),
            "u": init(ks[6], (H, RWKV_HEAD_DIM), 0.5),  # per-head bonus
            "wo": init(ks[7], (d_model, d_model)),
        },
        "ffn": {
            "mu": init(ks[8], (2, d_model), 0.5),
            "wk": init(ks[9], (d_model, d_ff)),
            "wv": init(jax.random.fold_in(key, 11), (d_ff, d_model)),
            "wr": init(jax.random.fold_in(key, 12), (d_model, d_model)),
        },
    }


def _token_shift(x, sx):
    """x: (B,S,D); sx: (B,D) last token of previous chunk -> shifted x."""
    prev = jnp.concatenate([sx[:, None, :], x[:, :-1]], axis=1)
    return prev, x[:, -1]


def rwkv_time_mix(p, x, sx, state):
    """RWKV6 time mixing. state: (B,H,hd,hd) f32; sx: (B,D). Returns y, sx', state'."""
    B, S, D = x.shape
    H = D // RWKV_HEAD_DIM
    hd = RWKV_HEAD_DIM
    prev, sx_new = _token_shift(x, sx)

    def mix(i):
        return x + (prev - x) * p["mu"][i]

    r = jnp.einsum("bsd,de->bse", mix(0), p["wr"]).reshape(B, S, H, hd)
    k = jnp.einsum("bsd,de->bse", mix(1), p["wk"]).reshape(B, S, H, hd)
    v = jnp.einsum("bsd,de->bse", mix(2), p["wv"]).reshape(B, S, H, hd)
    # data-dependent decay (Finch): w in (0,1) per channel per step
    wlog = jnp.einsum("bsd,de->bse", mix(3), p["ww"]).astype(jnp.float32)
    w = jnp.exp(-jnp.exp(wlog + p["w_bias"])).reshape(B, S, H, hd)
    g = jax.nn.silu(jnp.einsum("bsd,de->bse", mix(4), p["wg"]).astype(jnp.float32))

    def step(s, t):
        r_t, k_t, v_t, w_t = t                                  # (B,H,hd)
        kv = jnp.einsum("bhk,bhv->bhkv", k_t.astype(jnp.float32),
                        v_t.astype(jnp.float32))
        y = jnp.einsum("bhk,bhkv->bhv", r_t.astype(jnp.float32),
                       s + p["u"][None, :, :, None] * kv)
        s = w_t.astype(jnp.float32)[..., None] * s + kv
        return s, y

    xs = tuple(jnp.moveaxis(a, 1, 0) for a in (r, k, v, w))
    state, ys = jax.lax.scan(step, state, xs)
    y = jnp.moveaxis(ys, 0, 1).reshape(B, S, D)
    y = (y * g.reshape(B, S, D)).astype(x.dtype)
    return jnp.einsum("bsd,de->bse", y, p["wo"]), sx_new, state


def rwkv_channel_mix(p, x, sx):
    prev, sx_new = _token_shift(x, sx)
    xk = x + (prev - x) * p["mu"][0]
    xr = x + (prev - x) * p["mu"][1]
    k = jnp.einsum("bsd,df->bsf", xk, p["wk"])
    k = jnp.square(jax.nn.relu(k.astype(jnp.float32))).astype(x.dtype)
    kv = jnp.einsum("bsf,fd->bsd", k, p["wv"])
    r = jax.nn.sigmoid(jnp.einsum("bsd,de->bse", xr, p["wr"]).astype(jnp.float32))
    return (r * kv.astype(jnp.float32)).astype(x.dtype), sx_new


def rwkv_state_init(batch: int, d_model: int):
    H = d_model // RWKV_HEAD_DIM
    return {
        "wkv": jnp.zeros((batch, H, RWKV_HEAD_DIM, RWKV_HEAD_DIM), jnp.float32),
        "sx_att": jnp.zeros((batch, d_model), jnp.bfloat16),
        "sx_ffn": jnp.zeros((batch, d_model), jnp.bfloat16),
    }
