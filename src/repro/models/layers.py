"""Core transformer layers: RMSNorm, RoPE, flash-style attention (GQA +
sliding window), SwiGLU/GELU MLP, and sort-based expert-parallel MoE.

All attention in train/prefill is blockwise (nested `lax.scan` over query
and key chunks with running-max/denominator accumulation -- the TPU-adapted
flash pattern) so the 32k prefill never materializes an S x S score matrix.
Decode attends a KV cache with position masking (circular buffer for
sliding-window archs).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

NEG_INF = -1e30


def rms_norm(x, weight, eps=1e-5):
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    out = x.astype(jnp.float32) * jax.lax.rsqrt(var + eps)
    return (out * weight.astype(jnp.float32)).astype(x.dtype)


# ---- rotary embeddings --------------------------------------------------------

def rope_freqs(head_dim: int, theta: float):
    return 1.0 / (theta ** (np.arange(0, head_dim, 2) / head_dim))


def apply_rope(x, positions, theta: float):
    """x: (..., S, H, hd), positions: broadcastable to (..., S)."""
    hd = x.shape[-1]
    freqs = jnp.asarray(rope_freqs(hd, theta), dtype=jnp.float32)
    ang = positions.astype(jnp.float32)[..., None] * freqs      # (..., S, hd/2)
    cos = jnp.cos(ang)[..., None, :]                            # (..., S, 1, hd/2)
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---- blockwise (flash-style) attention ---------------------------------------

def flash_attention(q, k, v, *, causal: bool = True,
                    window: int | None = None,
                    q_chunk: int = 1024, kv_chunk: int = 1024,
                    constrain=None):
    """Blockwise attention. q, k, v: (B,S,H,hd) -- SAME head count (the
    caller expands GQA kv heads first so the head axis stays cleanly
    shardable over the model axis; a grouped layout would split heads into
    (Hkv, G) factors no mesh axis divides).

    Returns (B, S, H, hd).  Never materializes more than a
    (B, H, q_chunk, kv_chunk) score block.
    """
    B, S, H, hd = q.shape
    T = k.shape[1]
    qc = min(q_chunk, S)
    kc = min(kv_chunk, T)
    # pad non-divisible lengths; padded k positions are in the causal
    # future of every real q position, so the mask discards them, and
    # padded q rows are sliced off the output
    S_pad = -S % qc
    T_pad = -T % kc
    if S_pad:
        q = jnp.pad(q, ((0, 0), (0, S_pad), (0, 0), (0, 0)))
    if T_pad:
        k = jnp.pad(k, ((0, 0), (0, T_pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, T_pad), (0, 0), (0, 0)))
    S_full, T_full = S + S_pad, T + T_pad
    nq, nk = S_full // qc, T_full // kc
    scale = 1.0 / np.sqrt(hd)
    pin = constrain or (lambda t: t)

    qb = pin(jnp.moveaxis(q.reshape(B, nq, qc, H, hd), 1, 0))   # (nq,B,qc,H,hd)
    kb = pin(jnp.moveaxis(k.reshape(B, nk, kc, H, hd), 1, 0))
    vb = pin(jnp.moveaxis(v.reshape(B, nk, kc, H, hd), 1, 0))
    del k, v

    @jax.checkpoint
    def q_step(_, q_xs):
        qi, qblk = q_xs
        q_pos = qi * qc + jnp.arange(qc)

        @jax.checkpoint
        def kv_step(carry, kv_xs):
            m, denom, acc = carry
            ki, kblk, vblk = kv_xs
            k_pos = ki * kc + jnp.arange(kc)
            s = jnp.einsum("bqhd,bkhd->bhqk", qblk.astype(jnp.float32),
                           kblk.astype(jnp.float32)) * scale
            mask = jnp.ones((qc, kc), bool)
            if causal:
                mask &= q_pos[:, None] >= k_pos[None, :]
            if window is not None:
                mask &= (q_pos[:, None] - k_pos[None, :]) < window
            s = jnp.where(mask[None, None], s, NEG_INF)
            m_new = jnp.maximum(m, s.max(axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            denom = denom * corr + p.sum(axis=-1)
            pv = jnp.einsum("bhqk,bkhd->bhqd", p, vblk.astype(jnp.float32))
            acc = acc * corr[..., None] + pv
            return (m_new, denom, acc), None

        init = (jnp.full((B, H, qc), NEG_INF, jnp.float32),
                jnp.zeros((B, H, qc), jnp.float32),
                jnp.zeros((B, H, qc, hd), jnp.float32))
        (m, denom, acc), _ = jax.lax.scan(
            kv_step, init, (jnp.arange(nk), kb, vb))
        out = acc / jnp.maximum(denom, 1e-20)[..., None]        # (B,H,qc,hd)
        return None, jnp.moveaxis(out, 2, 1)                    # (B,qc,H,hd)

    _, blocks = jax.lax.scan(q_step, None, (jnp.arange(nq), qb))
    out = jnp.moveaxis(blocks, 0, 1).reshape(B, S_full, H, hd)
    return out[:, :S].astype(q.dtype)


def decode_attention(q, k_cache, v_cache, valid_mask):
    """Single-token attention over a cache.

    q: (B, 1, Hq, hd); caches: (B, T, Hkv, hd); valid_mask: (B, T) bool.
    """
    B, _, Hq, hd = q.shape
    Hkv = k_cache.shape[2]
    G = Hq // Hkv
    scale = 1.0 / np.sqrt(hd)
    qr = q.reshape(B, Hkv, G, hd)
    s = jnp.einsum("bhgd,bkhd->bhgk", qr.astype(jnp.float32),
                   k_cache.astype(jnp.float32)) * scale
    s = jnp.where(valid_mask[:, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgk,bkhd->bhgd", p, v_cache.astype(jnp.float32))
    return out.reshape(B, 1, Hq, hd).astype(q.dtype)


# ---- MLP ----------------------------------------------------------------------

def mlp_apply(params, x, act: str):
    if act == "swiglu":
        gate = jnp.einsum("...d,df->...f", x, params["wg"])
        up = jnp.einsum("...d,df->...f", x, params["wu"])
        h = jax.nn.silu(gate.astype(jnp.float32)).astype(x.dtype) * up
    else:
        h = jnp.einsum("...d,df->...f", x, params["wu"])
        h = jax.nn.gelu(h.astype(jnp.float32)).astype(x.dtype)
    return jnp.einsum("...f,fd->...d", h, params["wo"])


# ---- sort-based expert-parallel MoE -------------------------------------------

def moe_apply(params, x, *, n_experts: int, top_k: int,
              capacity_factor: float, act: str, constrain=None,
              seq_chunks: int = 1):
    """Top-k routed MoE with block-local sort-based capacity dispatch.

    x: (B, S, D).  Routing, sorting, and packing happen PER (example,
    sequence-chunk) block; with ``seq_chunks = tp`` the chunk axis carries
    the model-axis sharding, so the dispatch gather and combine scatter
    stay fully shard-local (no all-gather of the sequence-parallel stream)
    and the ONLY cross-shard traffic is the expert-parallel all-to-all
    into the (E/model) expert grid around the expert FFN einsum.  Only
    integer/weight index maps are built at routing granularity -- never an
    (S*K, D) tensor.  FLOPs scale with routed capacity E*C = S*K*cf.
    """
    B, S, D = x.shape
    n = max(1, seq_chunks)
    while S % n:
        n //= 2
    Sn = S // n
    NK = Sn * top_k
    cap = int(np.ceil(capacity_factor * NK / n_experts))
    EC = n_experts * cap
    pin = constrain or (lambda t, *a: t)

    router_logits = jnp.einsum("bsd,de->bse", x, params["router"])
    probs = jax.nn.softmax(router_logits.astype(jnp.float32), axis=-1)
    top_w, top_e = jax.lax.top_k(probs, top_k)                  # (B, S, K)
    top_w = top_w / jnp.maximum(top_w.sum(-1, keepdims=True), 1e-9)

    tok_of_slot = jnp.repeat(jnp.arange(Sn), top_k)             # (NK,)

    def route(fe, fw):
        """fe/fw: (NK,) -> (EC,) slot->token index map + weights."""
        order = jnp.argsort(fe, stable=True)
        sorted_e = fe[order]
        group_start = jnp.searchsorted(sorted_e, jnp.arange(n_experts))
        pos = jnp.arange(NK) - group_start[sorted_e]
        keep = pos < cap
        dest = jnp.where(keep, sorted_e * cap + pos, EC)
        tok_buf = jnp.full((EC + 1,), Sn, jnp.int32).at[dest].set(
            tok_of_slot[order].astype(jnp.int32))
        w_buf = jnp.zeros((EC + 1,), x.dtype).at[dest].set(
            fw[order].astype(x.dtype))
        return tok_buf[:-1], w_buf[:-1]

    fe = top_e.reshape(B, n, NK)
    fw = top_w.reshape(B, n, NK)
    tok_buf, w_buf = jax.vmap(jax.vmap(route))(fe, fw)          # (B, n, EC)
    tok_buf = pin(tok_buf, "batch", "model", None)
    w_buf = pin(w_buf, "batch", "model", None)

    # dispatch: block-local gather from the sentinel-padded token stream
    xr = pin(x.reshape(B, n, Sn, D), "batch", "model", None, None)
    x1 = jnp.concatenate([xr, jnp.zeros((B, n, 1, D), x.dtype)], axis=2)
    expert_in = jnp.take_along_axis(
        x1, tok_buf[..., None].astype(jnp.int32), axis=2)       # (B, n, EC, D)
    grid = expert_in.reshape(B, n, n_experts, cap, D)
    grid = pin(grid, "batch", None, "model", None, None)        # expert a2a

    if act == "swiglu":
        gate = jnp.einsum("bnecd,edf->bnecf", grid, params["wg"])
        up = jnp.einsum("bnecd,edf->bnecf", grid, params["wu"])
        h = jax.nn.silu(gate.astype(jnp.float32)).astype(x.dtype) * up
    else:
        h = jnp.einsum("bnecd,edf->bnecf", grid, params["wu"])
        h = jax.nn.gelu(h.astype(jnp.float32)).astype(x.dtype)
    expert_out = jnp.einsum("bnecf,efd->bnecd", h, params["wo"])
    expert_out = pin(expert_out.reshape(B, n, EC, D),
                     "batch", "model", None, None)              # back a2a

    # combine: weight in model dtype, block-local scatter-add by token id
    weighted = expert_out * w_buf[..., None]

    def combine(rows, toks):
        y = jnp.zeros((Sn + 1, D), rows.dtype)
        return y.at[toks].add(rows)[:Sn]

    y = jax.vmap(jax.vmap(combine))(weighted, tok_buf)          # (B, n, Sn, D)
    y = pin(y, "batch", "model", None, None)
    aux = moe_load_balance_loss(probs.reshape(B * S, n_experts),
                                top_e.reshape(B * S, top_k), n_experts)
    return y.reshape(B, S, D).astype(x.dtype), aux


def moe_load_balance_loss(probs, top_e, n_experts: int):
    """Switch-style load-balance auxiliary loss."""
    frac_routed = jnp.mean(
        jax.nn.one_hot(top_e[:, 0], n_experts, dtype=jnp.float32), axis=0)
    mean_prob = jnp.mean(probs, axis=0)
    return n_experts * jnp.sum(frac_routed * mean_prob)
