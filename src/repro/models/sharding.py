"""Sharding rules: parameter PartitionSpecs + activation constraints.

Single-pod production mesh is ``(data=16, model=16)``; multi-pod prepends a
``pod`` axis folded into data parallelism.  The model code is mesh-agnostic:
it receives a ``ShardingRules`` and calls ``constrain`` with logical axis
names; with rules disabled (CPU smoke tests) everything is a no-op.

Logical axes:
  batch  -> ('pod', 'data') or ('data',)
  model  -> 'model' (tensor/expert parallel)
  None   -> replicated
"""

from __future__ import annotations

import dataclasses

import jax
from jax.sharding import NamedSharding, PartitionSpec as P


@dataclasses.dataclass(frozen=True)
class ShardingRules:
    batch_axes: tuple = ("data",)
    model_axis: str | None = "model"     # None = no tensor parallelism
    fsdp_axes: tuple = ("data",)         # axes weights are ZeRO-3-sharded on
    enabled: bool = True

    def spec(self, *logical) -> P:
        dims = []
        for ax in logical:
            if ax == "batch":
                if not self.batch_axes:          # batch too small to shard
                    dims.append(None)
                elif len(self.batch_axes) > 1:
                    dims.append(self.batch_axes)
                else:
                    dims.append(self.batch_axes[0])
            elif ax == "model":
                dims.append(self.model_axis)
            else:
                dims.append(None)
        return P(*dims)

    @property
    def fsdp_dim(self):
        """Mesh-axis entry for a weight dim sharded ZeRO-3 style."""
        if not self.fsdp_axes:
            return None
        return self.fsdp_axes if len(self.fsdp_axes) > 1 else self.fsdp_axes[0]

    def for_batch(self, global_batch: int, mesh) -> "ShardingRules":
        """Drop batch sharding when the global batch doesn't divide the
        data axes (e.g. the batch=1 long-context decode shape)."""
        n = 1
        for ax in self.batch_axes:
            n *= mesh.shape[ax]
        if global_batch % max(n, 1) == 0:
            return self
        return dataclasses.replace(self, batch_axes=())

    def constrain(self, x, *logical):
        if not self.enabled:
            return x
        return jax.lax.with_sharding_constraint(x, self.spec(*logical))


NO_SHARDING = ShardingRules(enabled=False)


def tree_named_shardings(mesh, spec_tree):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))
