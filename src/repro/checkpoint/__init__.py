from repro.checkpoint.io import save_pytree, restore_pytree  # noqa: F401
