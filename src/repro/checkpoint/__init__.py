from repro.checkpoint.io import (STATE_VERSION, load_state,  # noqa: F401
                                 restore_pytree, save_pytree, save_state)
