"""Minimal pytree checkpointing: npz arrays + json tree structure.

Flat key-path encoding keeps restore independent of import order; arrays
round-trip through numpy (bf16 stored as uint16 views with a dtype tag).
"""

from __future__ import annotations

import json
import os

import jax
import jax.numpy as jnp
import numpy as np


def _flatten(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        out[key] = leaf
    return out, treedef


def save_pytree(tree, path: str):
    os.makedirs(path, exist_ok=True)
    flat, _ = _flatten(tree)
    arrays, meta = {}, {}
    for k, v in flat.items():
        arr = np.asarray(v)
        if arr.dtype == jnp.bfloat16:
            arrays[k] = arr.view(np.uint16)
            meta[k] = "bfloat16"
        else:
            arrays[k] = arr
            meta[k] = str(arr.dtype)
    np.savez(os.path.join(path, "arrays.npz"), **arrays)
    json.dump(meta, open(os.path.join(path, "meta.json"), "w"))


def restore_pytree(template, path: str):
    """Restore into the structure of `template` (shapes must match)."""
    flat_t, treedef = _flatten(template)
    data = np.load(os.path.join(path, "arrays.npz"))
    meta = json.load(open(os.path.join(path, "meta.json")))
    leaves = []
    for k in flat_t:
        arr = data[k]
        if meta[k] == "bfloat16":
            arr = arr.view(jnp.bfloat16)
        leaves.append(jnp.asarray(arr))
    return jax.tree_util.tree_unflatten(treedef, leaves)


# ---- versioned state envelopes (service crash recovery) ----------------------

STATE_VERSION = 1


def save_state(path: str, arrays: dict, meta: dict):
    """Versioned state checkpoint: named numpy arrays + a JSON metadata
    envelope.  Unlike ``save_pytree`` (template-shaped restore of jax
    parameters), this is for *service* state -- heterogeneous arrays
    plus arbitrary JSON-serializable metadata -- and ``load_state``
    refuses envelopes written by a future format version instead of
    misreading them.
    """
    os.makedirs(path, exist_ok=True)
    np.savez(os.path.join(path, "state.npz"),
             **{k: np.asarray(v) for k, v in arrays.items()})
    envelope = {"state_version": STATE_VERSION, "meta": meta}
    with open(os.path.join(path, "state.json"), "w") as fh:
        json.dump(envelope, fh)


def load_state(path: str) -> tuple[dict, dict]:
    """Load a ``save_state`` checkpoint -> ``(arrays, meta)``.

    Raises ``ValueError`` on an unknown ``state_version`` -- a crashed
    process must not warm-restart from a checkpoint it cannot decode.
    """
    with open(os.path.join(path, "state.json")) as fh:
        envelope = json.load(fh)
    version = envelope.get("state_version")
    if version != STATE_VERSION:
        raise ValueError(
            f"unsupported state checkpoint version {version!r} "
            f"(this build reads version {STATE_VERSION})")
    with np.load(os.path.join(path, "state.npz")) as data:
        arrays = {k: np.array(data[k]) for k in data.files}
    return arrays, envelope["meta"]
