"""musicgen-large [audio] -- 48L d_model=2048 32H (GQA kv=32, i.e. MHA)
d_ff=8192 vocab=2048, decoder-only over EnCodec tokens.  [arXiv:2306.05284]

The mel-spectrogram/EnCodec conv frontend is a STUB: ``input_specs``
provides 256 precomputed conditioning frame embeddings; the decoder
autoregresses over the 2048-entry EnCodec codebook vocabulary.
"""

from repro.models.config import ArchConfig

FULL = ArchConfig(
    name="musicgen-large", family="audio",
    n_layers=48, d_model=2048, n_heads=32, n_kv_heads=32,
    d_ff=8192, vocab=2048,
    act="gelu",
    frontend="audio", n_frontend_tokens=256,
    source="arXiv:2306.05284",
)

SMOKE = ArchConfig(
    name="musicgen-large-smoke", family="audio",
    n_layers=2, d_model=256, n_heads=4, n_kv_heads=4,
    d_ff=512, vocab=256,
    act="gelu",
    frontend="audio", n_frontend_tokens=16,
    source="reduced variant of musicgen-large",
)
