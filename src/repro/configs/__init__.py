"""Registry of assigned architectures (public-literature pool) + the
paper's own DLRM recommender (see ``repro.configs.dlrm``).
"""

from repro.configs import (
    dbrx_132b, granite_34b, h2o_danube_1p8b, hymba_1p5b, llava_next_34b,
    musicgen_large, olmoe_1b_7b, phi4_mini_3p8b, qwen2p5_14b, rwkv6_1p6b,
)
from repro.configs.shapes import INPUT_SHAPES, InputShape, input_specs  # noqa: F401

_MODULES = {
    "llava-next-34b": llava_next_34b,
    "hymba-1.5b": hymba_1p5b,
    "qwen2.5-14b": qwen2p5_14b,
    "dbrx-132b": dbrx_132b,
    "granite-34b": granite_34b,
    "phi4-mini-3.8b": phi4_mini_3p8b,
    "olmoe-1b-7b": olmoe_1b_7b,
    "rwkv6-1.6b": rwkv6_1p6b,
    "h2o-danube-1.8b": h2o_danube_1p8b,
    "musicgen-large": musicgen_large,
}

ARCH_NAMES = tuple(_MODULES)

# sub-quadratic archs that can serve the 524k-token decode shape
LONG_CONTEXT_ARCHS = ("rwkv6-1.6b", "hymba-1.5b", "h2o-danube-1.8b")


def get_full(name: str):
    return _MODULES[name].FULL


def get_smoke(name: str):
    return _MODULES[name].SMOKE


def supports_shape(name: str, shape_name: str) -> bool:
    if shape_name == "long_500k":
        return name in LONG_CONTEXT_ARCHS
    return True
