"""Assigned input shapes and ``input_specs()`` stand-ins.

``input_specs`` returns ShapeDtypeStructs only -- weak-type-correct,
shardable, no device allocation -- for every model input of a given
(arch, shape) pair.  For VLM/audio archs, the modality frontend is a stub:
the specs include a precomputed patch/frame embedding tensor of the right
shape and the token span shrinks accordingly.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models.config import ArchConfig


@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str                    # train | prefill | decode


INPUT_SHAPES = {
    "train_4k": InputShape("train_4k", 4096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524288, 1, "decode"),
}


def sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def input_specs(cfg: ArchConfig, shape: InputShape, dtype=jnp.bfloat16):
    """ShapeDtypeStruct stand-ins for the model inputs of one step.

    train   -> {tokens, labels, loss_mask [, embeds]}
    prefill -> {tokens [, embeds]}
    decode  -> {tokens}  (the KV cache spec comes from LM.init_cache)
    """
    B, S = shape.global_batch, shape.seq_len
    nf = cfg.n_frontend_tokens if cfg.frontend else 0
    if shape.kind == "train":
        specs = {"tokens": sds((B, S - nf), jnp.int32),
                 "labels": sds((B, S), jnp.int32),
                 "loss_mask": sds((B, S), jnp.float32)}
        if nf:
            specs["embeds"] = sds((B, nf, cfg.d_model), dtype)
        return specs
    if shape.kind == "prefill":
        specs = {"tokens": sds((B, S - nf), jnp.int32)}
        if nf:
            specs["embeds"] = sds((B, nf, cfg.d_model), dtype)
        return specs
    if shape.kind == "decode":
        return {"tokens": sds((B, 1), jnp.int32)}
    raise ValueError(shape.kind)


def batch_specs_partition(cfg: ArchConfig, shape: InputShape, rules):
    """PartitionSpecs matching input_specs (batch over data axes)."""
    specs = {}
    for name in input_specs(cfg, shape):
        rank = {"tokens": 2, "labels": 2, "loss_mask": 2, "embeds": 3}[name]
        specs[name] = rules.spec("batch", *([None] * (rank - 1)))
    return specs
