"""llava-next-34b [vlm] -- 60L d_model=7168 56H (GQA kv=8) d_ff=20480
vocab=64000, anyres tiling.  [hf:llava-hf/llava-v1.6-mistral-7b-hf]

The ViT/SigLIP vision encoder + projector is a STUB: ``input_specs`` provides
precomputed anyres patch embeddings of shape (B, 2304, d_model); the config
here describes the language backbone that consumes them.
"""

from repro.models.config import ArchConfig

FULL = ArchConfig(
    name="llava-next-34b", family="vlm",
    n_layers=60, d_model=7168, n_heads=56, n_kv_heads=8,
    d_ff=20480, vocab=64000,
    rope_theta=1e6, act="swiglu",
    frontend="vlm", n_frontend_tokens=2304,
    source="hf:llava-hf/llava-v1.6-mistral-7b-hf",
)

SMOKE = ArchConfig(
    name="llava-next-34b-smoke", family="vlm",
    n_layers=2, d_model=256, n_heads=8, n_kv_heads=2,
    d_ff=512, vocab=512,
    rope_theta=1e6, act="swiglu",
    frontend="vlm", n_frontend_tokens=16,
    source="reduced variant of llava-next-34b",
)
