"""rwkv6-1.6b [ssm] -- 24L d_model=2048 (attention-free) d_ff=7168
vocab=65536, Finch: data-dependent decay.  [arXiv:2404.05892]

Attention-free linear recurrence: decode carries a (H, 64, 64) wkv state
per layer, so `long_500k` costs O(1) memory in sequence length.
"""

from repro.models.config import ArchConfig

FULL = ArchConfig(
    name="rwkv6-1.6b", family="ssm",
    n_layers=24, d_model=2048, n_heads=0, n_kv_heads=0,
    d_ff=7168, vocab=65536,
    block="rwkv",
    source="arXiv:2404.05892",
)

SMOKE = ArchConfig(
    name="rwkv6-1.6b-smoke", family="ssm",
    n_layers=2, d_model=256, n_heads=0, n_kv_heads=0,
    d_ff=512, vocab=512,
    block="rwkv",
    source="reduced variant of rwkv6-1.6b",
)
