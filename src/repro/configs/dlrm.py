"""DLRM recommender -- the paper's own architecture [arXiv:1906.00091,
Meta DLRM; table statistics follow the open-sourced DLRM dataset, App. C].

Unlike the LM pool, DLRM's placement-relevant inputs are the embedding
tables themselves; its dry-run shape is one training step at production
batch 65536 with DreamShard-placed tables on the model axis.
"""

from repro.models.dlrm import DLRMConfig

FULL = DLRMConfig(
    n_dense_features=13,
    embed_dim=128,              # 16-dim tables padded to one 128 lane tile
    bottom_mlp=(512, 256),
    top_mlp=(1024, 512, 256),
    n_tables=200,
)

SMOKE = DLRMConfig(
    n_dense_features=4,
    embed_dim=128,
    bottom_mlp=(32,),
    top_mlp=(64, 32),
    n_tables=8,
)

TRAIN_BATCH = 65536
SMOKE_BATCH = 64
