"""hymba-1.5b [hybrid] -- 32L d_model=1600 25H (GQA kv=5) d_ff=5504
vocab=32001, ssm_state=16, parallel attn+mamba heads.  [arXiv:2411.13676]

Each block runs attention (sliding-window) and a selective-SSM branch in
parallel on the same normed input -- the hybrid-head structure of Hymba.
Sub-quadratic (SWA + SSM state), so `long_500k` runs for this arch.
"""

from repro.models.config import ArchConfig, SSMConfig

FULL = ArchConfig(
    name="hymba-1.5b", family="hybrid",
    n_layers=32, d_model=1600, n_heads=25, n_kv_heads=5,
    d_ff=5504, vocab=32001,
    sliding_window=1024, block="hybrid",
    ssm=SSMConfig(state_dim=16, conv_width=4, expand=2),
    act="swiglu",
    source="arXiv:2411.13676",
)

SMOKE = ArchConfig(
    name="hymba-1.5b-smoke", family="hybrid",
    n_layers=2, d_model=256, n_heads=4, n_kv_heads=2,
    d_ff=512, vocab=512,
    sliding_window=64, block="hybrid",
    ssm=SSMConfig(state_dim=8, conv_width=4, expand=2),
    act="swiglu",
    source="reduced variant of hymba-1.5b",
)
