"""phi4-mini-3.8b [dense] -- 32L d_model=3072 24H (GQA kv=8) d_ff=8192
vocab=200064, RoPE + SwiGLU + GQA.  [arXiv:2412.08905]
"""

from repro.models.config import ArchConfig

FULL = ArchConfig(
    name="phi4-mini-3.8b", family="dense",
    n_layers=32, d_model=3072, n_heads=24, n_kv_heads=8,
    d_ff=8192, vocab=200064,
    rope_theta=1e4, act="swiglu", tie_embeddings=True,
    source="arXiv:2412.08905",
)

SMOKE = ArchConfig(
    name="phi4-mini-3.8b-smoke", family="dense",
    n_layers=2, d_model=256, n_heads=8, n_kv_heads=4,
    d_ff=512, vocab=512,
    act="swiglu", tie_embeddings=True,
    source="reduced variant of phi4-mini-3.8b",
)
