"""granite-34b [dense] -- 88L d_model=6144 48H (GQA kv=1, i.e. MQA)
d_ff=24576 vocab=49152, llama-arch code model.  [arXiv:2405.04324]

MQA: the single KV head is replicated across tensor-parallel shards; the
decode KV cache is sharded over the sequence axis instead
(sequence-parallel decode attention).
"""

from repro.models.config import ArchConfig

FULL = ArchConfig(
    name="granite-34b", family="dense",
    n_layers=88, d_model=6144, n_heads=48, n_kv_heads=1,
    d_ff=24576, vocab=49152,
    act="gelu", tie_embeddings=False,
    source="arXiv:2405.04324",
)

SMOKE = ArchConfig(
    name="granite-34b-smoke", family="dense",
    n_layers=2, d_model=256, n_heads=8, n_kv_heads=1,
    d_ff=512, vocab=512,
    act="gelu",
    source="reduced variant of granite-34b",
)
