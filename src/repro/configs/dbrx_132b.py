"""dbrx-132b [moe] -- 40L d_model=6144 48H (GQA kv=8) d_ff=10752
vocab=100352, MoE 16 experts top-4 (fine-grained).  [hf:databricks/dbrx-base]

Experts are sharded over the model axis (1 expert per shard at tp=16) with
sort-based capacity dispatch; DreamShard's placement technique applies here
as the beyond-paper expert-placement feature (see
examples/moe_expert_placement.py).
"""

from repro.models.config import ArchConfig, MoEConfig

FULL = ArchConfig(
    name="dbrx-132b", family="moe",
    n_layers=40, d_model=6144, n_heads=48, n_kv_heads=8,
    d_ff=10752, vocab=100352,
    moe=MoEConfig(n_experts=16, top_k=4), act="swiglu",
    source="hf:databricks/dbrx-base",
)

SMOKE = ArchConfig(
    name="dbrx-132b-smoke", family="moe",
    n_layers=2, d_model=256, n_heads=8, n_kv_heads=2,
    d_ff=512, vocab=512,
    moe=MoEConfig(n_experts=4, top_k=2), act="swiglu",
    source="reduced variant of dbrx-132b",
)
