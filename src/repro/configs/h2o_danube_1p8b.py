"""h2o-danube-1.8b [dense] -- 24L d_model=2560 32H (GQA kv=8) d_ff=6912
vocab=32000, llama+mistral mix with sliding-window attention.
[arXiv:2401.16818]

SWA (window 4096) makes this the one *dense* arch that runs `long_500k`:
the decode cache is a circular window buffer, O(window) not O(seq).
"""

from repro.models.config import ArchConfig

FULL = ArchConfig(
    name="h2o-danube-1.8b", family="dense",
    n_layers=24, d_model=2560, n_heads=32, n_kv_heads=8,
    d_ff=6912, vocab=32000,
    sliding_window=4096, act="swiglu",
    source="arXiv:2401.16818",
)

SMOKE = ArchConfig(
    name="h2o-danube-1.8b-smoke", family="dense",
    n_layers=2, d_model=256, n_heads=8, n_kv_heads=2,
    d_ff=512, vocab=512,
    sliding_window=64, act="swiglu",
    source="reduced variant of h2o-danube-1.8b",
)
