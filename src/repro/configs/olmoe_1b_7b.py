"""olmoe-1b-7b [moe] -- 16L d_model=2048 16H (GQA kv=16, i.e. MHA)
d_ff=1024 vocab=50304, MoE 64 experts top-8.  [arXiv:2409.02060]

The fine-grained 64-expert/top-8 configuration is where expert-placement
balance matters most; 4 experts per model shard at tp=16.
"""

from repro.models.config import ArchConfig, MoEConfig

FULL = ArchConfig(
    name="olmoe-1b-7b", family="moe",
    n_layers=16, d_model=2048, n_heads=16, n_kv_heads=16,
    d_ff=1024, vocab=50304,
    moe=MoEConfig(n_experts=64, top_k=8), act="swiglu",
    source="arXiv:2409.02060",
)

SMOKE = ArchConfig(
    name="olmoe-1b-7b-smoke", family="moe",
    n_layers=2, d_model=256, n_heads=4, n_kv_heads=4,
    d_ff=128, vocab=512,
    moe=MoEConfig(n_experts=4, top_k=2), act="swiglu",
    source="reduced variant of olmoe-1b-7b",
)
