"""qwen2.5-14b [dense] -- 48L d_model=5120 40H (GQA kv=8) d_ff=13824
vocab=152064, GQA with QKV bias.  [hf:Qwen/Qwen2.5-0.5B]
"""

from repro.models.config import ArchConfig

FULL = ArchConfig(
    name="qwen2.5-14b", family="dense",
    n_layers=48, d_model=5120, n_heads=40, n_kv_heads=8,
    d_ff=13824, vocab=152064,
    qkv_bias=True, rope_theta=1e6, act="swiglu",
    source="hf:Qwen/Qwen2.5-0.5B",
)

SMOKE = ArchConfig(
    name="qwen2.5-14b-smoke", family="dense",
    n_layers=2, d_model=256, n_heads=8, n_kv_heads=2,
    d_ff=512, vocab=512,
    qkv_bias=True, rope_theta=1e6, act="swiglu",
    source="reduced variant of qwen2.5-14b",
)
