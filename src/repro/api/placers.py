"""``Placer`` adapters for every placement strategy in the repo.

All four strategy families -- the trained DreamShard agent, the RNN
baseline, the human-expert greedy heuristics, and random -- are exposed
through the same ``Placer`` protocol, so suites, benchmarks, and examples
iterate over strategies without per-strategy lambda glue.
"""

from __future__ import annotations

import numpy as np

from repro.api.oracle import ensure_oracle, evaluate_many
from repro.api.placement import BasePlacer, Placement
from repro.api.session import PlacementSession
from repro.core import baselines as B
from repro.data.tasks import Task


class DreamShardPlacer(BasePlacer):
    """Trained DreamShard agent behind the ``Placer`` protocol.

    Both ``place`` and ``place_many`` route through a shared
    ``PlacementSession``: a whole suite decodes with one compile per shape
    bucket, single-task calls reuse those bucket traces, and the decoded
    assignments are identical to the agent's per-task Algorithm-2 path
    (verified in ``tests/test_api.py``).
    """

    name = "dreamshard"

    def __init__(self, agent, n_candidates: int | None = None,
                 bucket_tables: int = 8, refiner=None):
        self.agent = agent
        self.session = PlacementSession(agent, n_candidates=n_candidates,
                                        bucket_tables=bucket_tables,
                                        refiner=refiner)
        if refiner is not None:
            self.name = f"dreamshard+{getattr(refiner, 'name', 'refined')}"

    def place(self, task: Task) -> Placement:
        return self.session.place(task)       # reuses bucket traces

    def place_many(self, tasks) -> list[Placement]:
        return self.session.place_many(list(tasks))


class RNNPlacerAdapter(BasePlacer):
    """RNN REINFORCE baseline (App. D.2) behind the ``Placer`` protocol."""

    name = "rnn"

    def __init__(self, rnn_placer):
        self.rnn = rnn_placer

    def _assign(self, task: Task):
        a = self.rnn.place(task.raw_features, task.n_devices)
        return a, None, 1, 0


class ExpertPlacer(BasePlacer):
    """Greedy human-expert heuristic (paper App. D.1): one scalar cost per
    table, sorted descending, least-loaded legal device."""

    def __init__(self, oracle, strategy: str):
        if strategy not in B.EXPERT_STRATEGIES:
            raise ValueError(f"unknown expert strategy {strategy!r}")
        self.oracle = ensure_oracle(oracle)
        self.strategy = strategy
        self.name = strategy

    def place(self, task: Task) -> Placement:
        a = B.expert_place(task.raw_features, task.n_devices,
                           self.oracle.mem_capacity_gb, self.strategy)
        return self._wrap(task, a)


class RandomPlacer(BasePlacer):
    """Memory-legal random placement (stateful rng, like the legacy helper:
    successive calls consume the same stream as ``random_place`` with a
    shared generator).

    ``n_candidates > 1`` draws that many placements and keeps the
    oracle-measured best, scored in ONE ``evaluate_many`` batch -- never a
    per-candidate ``evaluate`` loop (``tests/test_search.py`` counts the
    dispatches).  The default stays 1: the paper's random baseline is
    single-shot and hardware-free.
    """

    name = "random"

    def __init__(self, oracle, seed: int = 0, n_candidates: int = 1):
        self.oracle = ensure_oracle(oracle)
        self.rng = np.random.default_rng(seed)
        self.n_candidates = max(1, n_candidates)

    def place(self, task: Task) -> Placement:
        cap = self.oracle.mem_capacity_gb
        A = np.stack([B.random_place(task.raw_features, task.n_devices,
                                     cap, self.rng)
                      for _ in range(self.n_candidates)])
        if self.n_candidates == 1:
            return self._wrap(task, A[0])
        evals0 = self.oracle.num_evaluations
        results = evaluate_many(self.oracle, task.raw_features, A,
                                task.n_devices)
        costs = np.array([r.overall for r in results])
        best = int(np.argmin(costs))
        return self._wrap(task, A[best], est_cost_ms=float(costs[best]),
                          candidates=self.n_candidates,
                          oracle_evals=self.oracle.num_evaluations - evals0)


class PortfolioPlacer(BasePlacer):
    """Best-of-N over member placers, scored through ONE batched oracle
    pass per task.

    The members' proposals (e.g. the four expert heuristics, which were
    previously only comparable by looping per-strategy ``evaluate``
    calls) are stacked into a single ``(N, M)`` assignment matrix and
    measured with one ``evaluate_many`` call; the cheapest wins.  This is
    the degenerate no-search ancestor of ``repro.search.SearchPlacer`` --
    portfolio picks among fixed proposals, search keeps refining them.
    """

    def __init__(self, oracle, placers: dict[str, BasePlacer],
                 name: str = "portfolio"):
        if not placers:
            raise ValueError("PortfolioPlacer needs at least one member")
        self.oracle = ensure_oracle(oracle)
        self.placers = dict(placers)
        self.name = name

    def place_many(self, tasks) -> list[Placement]:
        tasks = list(tasks)
        proposals = {k: p.place_many(tasks)          # members may batch
                     for k, p in self.placers.items()}
        out = []
        for i, task in enumerate(tasks):
            cands = [proposals[k][i] for k in self.placers]
            A = np.stack([c.assignment for c in cands])
            evals0 = self.oracle.num_evaluations
            results = evaluate_many(self.oracle, task.raw_features, A,
                                    task.n_devices)
            costs = np.array([r.overall for r in results])
            best = int(np.argmin(costs))
            out.append(Placement(
                assignment=cands[best].assignment, plan=cands[best].plan,
                n_devices=task.n_devices, strategy=self.name,
                est_cost_ms=float(costs[best]), candidates=len(cands),
                oracle_evals=self.oracle.num_evaluations - evals0))
        return out

    def place(self, task: Task) -> Placement:
        return self.place_many([task])[0]


def make_baseline_placers(oracle, seed: int = 0,
                          include_portfolio: bool = False
                          ) -> dict[str, BasePlacer]:
    """Random + the four expert heuristics, keyed by strategy name.

    ``include_portfolio=True`` adds ``"expert_best"``: the batched
    best-of-the-four-experts portfolio (one ``evaluate_many`` per task).
    """
    oracle = ensure_oracle(oracle)
    placers: dict[str, BasePlacer] = {"random": RandomPlacer(oracle, seed)}
    for s in B.EXPERT_STRATEGIES:
        placers[s] = ExpertPlacer(oracle, s)
    if include_portfolio:
        experts = {s: placers[s] for s in B.EXPERT_STRATEGIES}
        placers["expert_best"] = PortfolioPlacer(oracle, experts,
                                                 name="expert_best")
    return placers
