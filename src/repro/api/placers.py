"""``Placer`` adapters for every placement strategy in the repo.

All four strategy families -- the trained DreamShard agent, the RNN
baseline, the human-expert greedy heuristics, and random -- are exposed
through the same ``Placer`` protocol, so suites, benchmarks, and examples
iterate over strategies without per-strategy lambda glue.
"""

from __future__ import annotations

import numpy as np

from repro.api.oracle import ensure_oracle
from repro.api.placement import BasePlacer, Placement
from repro.api.session import PlacementSession
from repro.core import baselines as B
from repro.data.tasks import Task


class DreamShardPlacer(BasePlacer):
    """Trained DreamShard agent behind the ``Placer`` protocol.

    Both ``place`` and ``place_many`` route through a shared
    ``PlacementSession``: a whole suite decodes with one compile per shape
    bucket, single-task calls reuse those bucket traces, and the decoded
    assignments are identical to the agent's per-task Algorithm-2 path
    (verified in ``tests/test_api.py``).
    """

    name = "dreamshard"

    def __init__(self, agent, n_candidates: int | None = None,
                 bucket_tables: int = 8):
        self.agent = agent
        self.session = PlacementSession(agent, n_candidates=n_candidates,
                                        bucket_tables=bucket_tables)

    def place(self, task: Task) -> Placement:
        return self.session.place(task)       # reuses bucket traces

    def place_many(self, tasks) -> list[Placement]:
        return self.session.place_many(list(tasks))


class RNNPlacerAdapter(BasePlacer):
    """RNN REINFORCE baseline (App. D.2) behind the ``Placer`` protocol."""

    name = "rnn"

    def __init__(self, rnn_placer):
        self.rnn = rnn_placer

    def _assign(self, task: Task):
        a = self.rnn.place(task.raw_features, task.n_devices)
        return a, None, 1, 0


class ExpertPlacer(BasePlacer):
    """Greedy human-expert heuristic (paper App. D.1): one scalar cost per
    table, sorted descending, least-loaded legal device."""

    def __init__(self, oracle, strategy: str):
        if strategy not in B.EXPERT_STRATEGIES:
            raise ValueError(f"unknown expert strategy {strategy!r}")
        self.oracle = ensure_oracle(oracle)
        self.strategy = strategy
        self.name = strategy

    def place(self, task: Task) -> Placement:
        a = B.expert_place(task.raw_features, task.n_devices,
                           self.oracle.mem_capacity_gb, self.strategy)
        return self._wrap(task, a)


class RandomPlacer(BasePlacer):
    """Memory-legal random placement (stateful rng, like the legacy helper:
    successive calls consume the same stream as ``random_place`` with a
    shared generator)."""

    name = "random"

    def __init__(self, oracle, seed: int = 0):
        self.oracle = ensure_oracle(oracle)
        self.rng = np.random.default_rng(seed)

    def place(self, task: Task) -> Placement:
        a = B.random_place(task.raw_features, task.n_devices,
                           self.oracle.mem_capacity_gb, self.rng)
        return self._wrap(task, a)


def make_baseline_placers(oracle, seed: int = 0) -> dict[str, BasePlacer]:
    """Random + the four expert heuristics, keyed by strategy name."""
    oracle = ensure_oracle(oracle)
    placers: dict[str, BasePlacer] = {"random": RandomPlacer(oracle, seed)}
    for s in B.EXPERT_STRATEGIES:
        placers[s] = ExpertPlacer(oracle, s)
    return placers
