"""Shared blake2b digest helpers: the one key machinery for every cache.

Both memoization layers key on deterministic digests of their query
bytes -- ``CachedOracle`` on *(task, placement)* pairs (cost cache),
``repro.serve.PlacementCache`` on *tasks* (placement cache).  The key
construction used to live inline in ``CachedOracle._key`` /
``_keys_batch``; it is factored out here so both caches hash the same
canonical byte streams (``repro.sim.costsim.placement_bytes`` for
placements) with the same width.

All keys are blake2b-128: wide enough to be collision-safe at any sweep
size, stable across processes (unlike the salted built-in ``hash``),
and cheap (~1 us per key).  Batched variants hash the shared ``raw``
prefix ONCE and fork the hash state per row, so a ``(P, M)`` batch pays
for one prefix plus P suffixes.
"""

from __future__ import annotations

import hashlib

import numpy as np

from repro.core import features as F
from repro.sim.costsim import placement_bytes

DIGEST_SIZE = 16        # blake2b-128 everywhere


def placement_key(raw: np.ndarray, assignment: np.ndarray,
                  n_devices: int) -> bytes:
    """Digest of one *(task, placement)* query -- the ``CachedOracle``
    memo key.  Hashes the canonical ``placement_bytes`` stream (raw
    features + assignment + device count)."""
    return hashlib.blake2b(placement_bytes(raw, assignment, n_devices),
                           digest_size=DIGEST_SIZE).digest()


def placement_keys(raw: np.ndarray, assignments: np.ndarray,
                   n_devices: int) -> list[bytes]:
    """Row-wise ``placement_key`` over a ``(P, M)`` assignment batch.

    The shared ``raw`` prefix is hashed once (blake2b state copy per
    row), so the values are bitwise-identical to P independent
    ``placement_key`` calls at a fraction of the cost.
    """
    r = np.ascontiguousarray(np.asarray(raw, dtype=np.float64))
    a = np.ascontiguousarray(np.asarray(assignments, dtype=np.int64))
    h0 = hashlib.blake2b(r.tobytes(), digest_size=DIGEST_SIZE)
    suffix = int(n_devices).to_bytes(8, "little")
    keys = []
    for row in a:
        h = h0.copy()
        h.update(row.tobytes() + suffix)
        keys.append(h.digest())
    return keys


def sharded_placement_key(raw: np.ndarray, spec,
                          shard_assignment: np.ndarray,
                          n_devices: int) -> bytes:
    """Digest of one *(task, sharding, shard placement)* query.

    Hashes the expanded per-shard feature bytes
    (``repro.sharding.shard_features``) plus the ``(S,)`` shard
    assignment -- so a trivial spec (K = 1 everywhere) produces the SAME
    key as the legacy ``placement_key`` (the expansion is byte-identical
    to ``raw``), while different split points change the expanded
    ``dim`` / ``table_size_gb`` bytes and therefore the key.
    """
    from repro.sharding.spec import shard_features
    return placement_key(shard_features(raw, spec), shard_assignment,
                         n_devices)


def sharded_placement_keys(raw: np.ndarray, spec,
                           shard_assignments: np.ndarray,
                           n_devices: int) -> list[bytes]:
    """Row-wise ``sharded_placement_key`` over ``(P, S)`` assignments
    (shared expanded-prefix hashing, like ``placement_keys``)."""
    from repro.sharding.spec import shard_features
    return placement_keys(shard_features(raw, spec), shard_assignments,
                          n_devices)


def task_key(raw: np.ndarray, n_devices: int, *,
             include_distribution: bool = True) -> bytes:
    """Digest of one *task* (raw features + device count) -- the
    ``repro.serve.PlacementCache`` key.

    ``include_distribution=False`` drops the 17-bin access-histogram
    columns from the digest, keying only on the structural features
    (dim, hash size, pooling, table size).  That is the serving-cache
    policy: a stream of near-duplicate requests whose table popularity
    drifts slowly maps onto ONE cache entry (so repeats skip decode
    entirely), and histogram movement is handled by the drift loop
    rather than by key churn.
    """
    r = np.ascontiguousarray(np.asarray(raw, dtype=np.float64))
    if not include_distribution:
        r = np.ascontiguousarray(r[:, :F.DIST_START])
    return hashlib.blake2b(
        r.tobytes() + int(n_devices).to_bytes(8, "little"),
        digest_size=DIGEST_SIZE).digest()
