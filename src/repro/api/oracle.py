"""Cost oracles: the unified "how expensive is this placement?" seam.

Every placement strategy and benchmark talks to hardware through a
``CostOracle``:

* ``SimOracle``    -- wraps the analytic ``CostSimulator`` (the default
  "hardware" of the reproduction);
* ``CachedOracle`` -- memoizes repeated placement queries on the
  deterministic ``placement_digest`` so benchmark sweeps and greedy
  searches never pay twice for the same placement;
* ``KernelOracle`` -- measured-cost seam: times the real
  ``kernels/embedding_bag`` lookup per device group and models the
  all-to-all analytically, the hook *Pre-train and Search*-style
  deployments plug real measurements into.

The trainer (``DreamShard``), the RNN baseline, and every ``Placer``
adapter accept either a ``CostOracle`` or a bare ``CostSimulator``
(auto-wrapped via ``ensure_oracle``).
"""

from __future__ import annotations

import time
from typing import Protocol, runtime_checkable

import numpy as np

from repro.sim.costsim import (CostSimulator, SimResult, placement_bytes,
                               placement_digest)
from repro.sim.hardware import HardwareSpec, PAPER_GPU


@runtime_checkable
class CostOracle(Protocol):
    """Protocol every cost backend implements."""

    @property
    def mem_capacity_gb(self) -> float:
        """Per-device memory budget a legal placement must respect."""
        ...

    @property
    def num_evaluations(self) -> int:
        """Hardware measurements consumed so far (sample-efficiency axis)."""
        ...

    def evaluate(self, raw: np.ndarray, assignment: np.ndarray,
                 n_devices: int) -> SimResult:
        """Measure one placement; the analogue of one benchmark run."""
        ...


def ensure_oracle(sim_or_oracle) -> "CostOracle":
    """Accept a ``CostOracle`` or a bare ``CostSimulator`` (auto-wrap)."""
    if isinstance(sim_or_oracle, CostSimulator):
        return SimOracle(sim_or_oracle)
    if isinstance(sim_or_oracle, CostOracle):
        return sim_or_oracle
    raise TypeError(
        f"expected a CostOracle or CostSimulator, got {type(sim_or_oracle)!r}")


class SimOracle:
    """``CostOracle`` view over the analytic ``CostSimulator``."""

    def __init__(self, sim: CostSimulator | None = None, **sim_kwargs):
        self.sim = sim if sim is not None else CostSimulator(**sim_kwargs)

    @property
    def mem_capacity_gb(self) -> float:
        return self.sim.spec.mem_capacity_gb

    @property
    def num_evaluations(self) -> int:
        return self.sim.num_evaluations

    def evaluate(self, raw, assignment, n_devices) -> SimResult:
        return self.sim.evaluate(raw, assignment, n_devices)

    def legal(self, raw, assignment, n_devices) -> bool:
        return self.sim.legal(raw, assignment, n_devices)


class CachedOracle:
    """Memoizing wrapper: repeated placements are served from cache.

    Keys are a deterministic digest of the raw features, the assignment,
    and the device count (the shared ``placement_bytes`` stream that also
    feeds ``placement_digest``, but hashed wide -- blake2b-128 -- so the
    cache is collision-safe at any sweep size).  Hit/miss behaviour is
    reproducible across processes.  ``num_evaluations`` reports the
    *inner* oracle's count -- cache hits consume no hardware budget.
    """

    def __init__(self, inner, max_entries: int = 100_000):
        self.inner = ensure_oracle(inner)
        self.max_entries = max_entries
        self.hits = 0
        self.misses = 0
        self._cache: dict[bytes, SimResult] = {}

    @property
    def mem_capacity_gb(self) -> float:
        return self.inner.mem_capacity_gb

    @property
    def num_evaluations(self) -> int:
        return self.inner.num_evaluations

    def _key(self, raw, assignment, n_devices) -> bytes:
        import hashlib
        return hashlib.blake2b(
            placement_bytes(raw, assignment, n_devices),
            digest_size=16).digest()

    def evaluate(self, raw, assignment, n_devices) -> SimResult:
        key = self._key(raw, assignment, n_devices)
        hit = self._cache.get(key)
        if hit is not None:
            self.hits += 1
            return hit
        self.misses += 1
        res = self.inner.evaluate(raw, assignment, n_devices)
        if len(self._cache) >= self.max_entries:      # FIFO eviction
            self._cache.pop(next(iter(self._cache)))
        self._cache[key] = res
        return res


class KernelOracle:
    """Measured-cost oracle stub backed by the ``embedding_bag`` kernel.

    For each device group this oracle builds a small arena, synthesizes
    zipf-ish lookup indices, and *times* the fused embedding-bag forward
    and its scatter-add backward (the Pallas kernel on TPU, the jnp
    reference in interpret/CPU mode).  Communication has no single-host
    analogue, so the all-to-all stage reuses the analytic model.

    This is deliberately a seam, not a production harness: batch and
    arena rows are capped so one ``evaluate`` stays cheap on CPU, and
    measured milliseconds are comparable *within* one oracle, not with
    ``SimOracle`` numbers.
    """

    def __init__(self, spec: HardwareSpec = PAPER_GPU, batch_size: int = 64,
                 pooling: int = 4, max_rows: int = 4096, repeats: int = 2,
                 use_pallas: bool = False, seed: int = 0):
        self.spec = spec
        self.batch_size = batch_size
        self.pooling = pooling
        self.max_rows = max_rows
        self.repeats = repeats
        self.use_pallas = use_pallas
        self.seed = seed
        self._num_evaluations = 0
        # analytic comm model shared with the simulator (deterministic)
        self._comm_model = CostSimulator(spec, noise_std=0.0)

    @property
    def mem_capacity_gb(self) -> float:
        return self.spec.mem_capacity_gb

    @property
    def num_evaluations(self) -> int:
        return self._num_evaluations

    def _time_ms(self, fn, *args) -> float:
        fn(*args).block_until_ready()            # warmup / compile
        best = float("inf")
        for _ in range(self.repeats):
            t0 = time.perf_counter()
            fn(*args).block_until_ready()
            best = min(best, time.perf_counter() - t0)
        return best * 1e3

    def evaluate(self, raw, assignment, n_devices) -> SimResult:
        import jax.numpy as jnp
        from repro.core import features as F
        from repro.kernels.embedding_bag.ref import (embedding_bag_grad_ref,
                                                     embedding_bag_ref)
        if self.use_pallas:
            from repro.kernels.embedding_bag.ops import embedding_bag
        self._num_evaluations += 1
        raw = np.asarray(raw, dtype=np.float64)
        assignment = np.asarray(assignment)
        rng = np.random.default_rng(
            placement_digest(raw, assignment, n_devices) ^ self.seed)
        dim = max(128, int(np.ceil(raw[:, F.DIM].max() / 128) * 128))
        fwd = np.zeros(n_devices)
        bwd = np.zeros(n_devices)
        dim_sums = np.zeros(n_devices)
        for d in range(n_devices):
            sub = raw[assignment == d]
            if sub.shape[0] == 0:
                continue
            rows = np.minimum(sub[:, F.HASH_SIZE].astype(np.int64),
                              self.max_rows)
            bases = np.concatenate([[1], 1 + np.cumsum(rows)[:-1]])
            arena = jnp.zeros((1 + int(rows.sum()), dim), jnp.float32)
            idx = np.zeros((self.batch_size * len(rows), self.pooling),
                           np.int32)
            for k, (b, r) in enumerate(zip(bases, rows)):
                draws = rng.zipf(1.5, size=(self.batch_size, self.pooling))
                lo = k * self.batch_size
                idx[lo:lo + self.batch_size] = b + draws % r
            idx = jnp.asarray(idx)
            if self.use_pallas:
                fwd[d] = self._time_ms(embedding_bag, arena, idx)
            else:
                fwd[d] = self._time_ms(embedding_bag_ref, arena, idx)
            g = jnp.ones((idx.shape[0], dim), jnp.float32)
            bwd[d] = self._time_ms(embedding_bag_grad_ref, arena.shape, idx, g)
            dim_sums[d] = sub[:, F.DIM].sum()
        comm = self._comm_model._comm_ms(dim_sums, n_devices)
        fwd_comm = (fwd.max() - fwd) + comm
        overall = fwd.max() + 2.0 * comm.max() + bwd.max()
        return SimResult(fwd_comp=fwd, bwd_comp=bwd, fwd_comm=fwd_comm,
                         bwd_comm=comm, overall=float(overall))
