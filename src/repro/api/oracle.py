"""Cost oracles: the unified "how expensive is this placement?" seam.

Every placement strategy and benchmark talks to hardware through a
``CostOracle``:

* ``SimOracle``    -- wraps the analytic ``CostSimulator`` (the default
  "hardware" of the reproduction);
* ``CachedOracle`` -- memoizes repeated placement queries (LRU) so
  benchmark sweeps and greedy searches never pay twice for the same
  placement;
* ``MeasuredOracle`` -- measured hardware costs at simulator speed:
  interpolates per-table kernel times and alpha-beta comm costs from a
  persisted ``repro.profiling.CalibrationTable`` (offline micro-benchmark
  artifact), ZERO kernel launches per ``evaluate`` -- the *Pre-train and
  Search*-style closing of the sim-to-real loop;
* ``KernelOracle`` -- thin adapter over the profiling subsystem: runs a
  small calibration sweep once (lazily) and then delegates every
  ``evaluate`` to a ``MeasuredOracle`` (it used to re-time kernels inside
  every call).

The trainer (``DreamShard``), the RNN baseline, and every ``Placer``
adapter accept either a ``CostOracle`` or a bare ``CostSimulator``
(auto-wrapped via ``ensure_oracle``).
"""

from __future__ import annotations

import os
from typing import Protocol, runtime_checkable

import numpy as np

from repro import telemetry as tele
from repro.api.digest import (placement_key, placement_keys,
                              sharded_placement_keys)
from repro.core import features as F
from repro.sim.costsim import (CostSimulator, SimResult, assignments_legal,
                               check_assignment_batch, per_device_sums)
from repro.sim.hardware import HardwareSpec, PAPER_GPU


@runtime_checkable
class CostOracle(Protocol):
    """Protocol every cost backend implements."""

    @property
    def mem_capacity_gb(self) -> float:
        """Per-device memory budget a legal placement must respect."""
        ...

    @property
    def num_evaluations(self) -> int:
        """Hardware measurements consumed so far (sample-efficiency axis)."""
        ...

    def evaluate(self, raw: np.ndarray, assignment: np.ndarray,
                 n_devices: int) -> SimResult:
        """Measure one placement; the analogue of one benchmark run."""
        ...

    def evaluate_many(self, raw: np.ndarray, assignments: np.ndarray,
                      n_devices: int) -> list[SimResult]:
        """Measure P placements of ONE task (shared ``raw``/``n_devices``)
        in a single batched pass.  ``assignments`` is ``(P, M)``; results
        follow row order and are bitwise-identical to P sequential
        ``evaluate`` calls (same per-placement noise digests); counts P
        hardware measurements."""
        ...


def ensure_oracle(sim_or_oracle) -> "CostOracle":
    """Accept a ``CostOracle`` or a bare ``CostSimulator`` (auto-wrap)."""
    if isinstance(sim_or_oracle, CostSimulator):
        return SimOracle(sim_or_oracle)
    if isinstance(sim_or_oracle, CostOracle):
        return sim_or_oracle
    # pre-evaluate_many oracles (the protocol before this method existed):
    # accept the legacy surface; `evaluate_many` consumers fall back to a
    # per-placement loop for them
    if all(hasattr(sim_or_oracle, a)
           for a in ("evaluate", "mem_capacity_gb", "num_evaluations")):
        return sim_or_oracle
    raise TypeError(
        f"expected a CostOracle or CostSimulator, got {type(sim_or_oracle)!r}")


def evaluate_many(oracle, raw: np.ndarray, assignments: np.ndarray,
                  n_devices: int) -> list[SimResult]:
    """Batched measurement through any oracle: uses the oracle's
    ``evaluate_many`` when it has one, else falls back to a sequential
    per-placement loop (identical results either way)."""
    assignments = check_assignment_batch(assignments, n_devices)
    fn = getattr(oracle, "evaluate_many", None)
    if fn is not None:
        return fn(raw, assignments, n_devices)
    return [oracle.evaluate(raw, a, n_devices) for a in assignments]


def legal_batch(oracle, raw: np.ndarray, assignments: np.ndarray,
                n_devices: int) -> np.ndarray:
    """Vectorized ``(P,)`` memory-legality check through any oracle: uses
    the oracle's own ``legal_batch`` when present, else the shared
    bincount check against ``oracle.mem_capacity_gb``."""
    fn = getattr(oracle, "legal_batch", None)
    if fn is not None:
        return fn(raw, assignments, n_devices)
    sizes = np.asarray(raw, dtype=np.float64)[:, F.TABLE_SIZE_GB]
    return assignments_legal(sizes, assignments, n_devices,
                             oracle.mem_capacity_gb)


def evaluate_sharded(oracle, raw: np.ndarray, spec,
                     assignments: np.ndarray,
                     n_devices: int) -> list[SimResult]:
    """Batched *shard-level* measurement through any oracle.

    ``assignments`` is ``(P, S)`` over the shards of a
    ``repro.sharding.ShardSpec``.  Uses the oracle's own
    ``evaluate_sharded`` when it has one (shard-aware pricing:
    the simulator's per-shard cache curve, ``MeasuredOracle``'s
    calibrated shard model); otherwise falls back to ``evaluate_many``
    over the expanded per-shard features -- pricing each shard as a
    table of its column width, the generic additive-fraction model.
    For a trivial spec every route is bitwise the whole-table
    ``evaluate_many``.
    """
    assignments = check_assignment_batch(assignments, n_devices)
    fn = getattr(oracle, "evaluate_sharded", None)
    if fn is not None:
        return fn(raw, spec, assignments, n_devices)
    from repro.sharding.spec import shard_features
    return evaluate_many(oracle, shard_features(raw, spec), assignments,
                         n_devices)


def legal_sharded(oracle, raw: np.ndarray, spec,
                  assignments: np.ndarray, n_devices: int) -> np.ndarray:
    """Vectorized ``(P,)`` memory legality of shard-level assignments:
    per-device sums of per-shard bytes against the oracle's capacity."""
    fn = getattr(oracle, "legal_sharded", None)
    if fn is not None:
        return fn(raw, spec, assignments, n_devices)
    from repro.sharding.spec import shard_sizes_gb
    return assignments_legal(shard_sizes_gb(raw, spec), assignments,
                             n_devices, oracle.mem_capacity_gb)


class SimOracle:
    """``CostOracle`` view over the analytic ``CostSimulator``.

    Each call emits a telemetry span (``oracle.sim.evaluate[_many]``
    with P/M/n_devices attributes) and bumps the dispatch counters the
    batched-path regression tests assert on -- all no-ops until
    ``repro.telemetry.enable()``.
    """

    def __init__(self, sim: CostSimulator | None = None, **sim_kwargs):
        self.sim = sim if sim is not None else CostSimulator(**sim_kwargs)

    @property
    def mem_capacity_gb(self) -> float:
        return self.sim.spec.mem_capacity_gb

    @property
    def num_evaluations(self) -> int:
        return self.sim.num_evaluations

    def evaluate(self, raw, assignment, n_devices) -> SimResult:
        tele.count("oracle.sim.evaluate_calls")
        with tele.span("oracle.sim.evaluate", M=len(raw),
                       n_devices=n_devices):
            return self.sim.evaluate(raw, assignment, n_devices)

    def evaluate_many(self, raw, assignments, n_devices) -> list[SimResult]:
        P = len(assignments)
        tele.count("oracle.sim.evaluate_many_calls")
        tele.count("oracle.sim.rows", P)
        with tele.span("oracle.sim.evaluate_many", P=P, M=len(raw),
                       n_devices=n_devices):
            return self.sim.evaluate_batch(raw, assignments, n_devices)

    def legal(self, raw, assignment, n_devices) -> bool:
        return self.sim.legal(raw, assignment, n_devices)

    def legal_batch(self, raw, assignments, n_devices) -> np.ndarray:
        return self.sim.legal_batch(raw, assignments, n_devices)

    def evaluate_sharded(self, raw, spec, assignments,
                         n_devices) -> list[SimResult]:
        P = len(assignments)
        tele.count("oracle.sim.evaluate_sharded_calls")
        tele.count("oracle.sim.rows", P)
        with tele.span("oracle.sim.evaluate_sharded", P=P,
                       S=spec.n_shards, n_devices=n_devices):
            return self.sim.evaluate_sharded_batch(raw, spec, assignments,
                                                   n_devices)

    def legal_sharded(self, raw, spec, assignments,
                      n_devices) -> np.ndarray:
        return self.sim.legal_sharded_batch(raw, spec, assignments,
                                            n_devices)


class CachedOracle:
    """Memoizing wrapper: repeated placements are served from cache.

    Keys are a deterministic digest of the raw features, the assignment,
    and the device count (the shared ``placement_bytes`` stream that also
    feeds ``placement_digest``, but hashed wide -- blake2b-128 -- so the
    cache is collision-safe at any sweep size).  Hit/miss behaviour is
    reproducible across processes.  ``num_evaluations`` reports the
    *inner* oracle's count -- cache hits consume no hardware budget.

    Eviction is LRU (a hit moves its entry to the back of the insertion
    order), so long greedy searches keep their hot placements cached
    even past ``max_entries``; the ``hits`` / ``misses`` counters and the
    ``oracle.cache.*`` telemetry (``repro.telemetry.snapshot()``) expose
    the cache behaviour.

    Sharded queries (``evaluate_sharded``) share the same store under
    ``repro.api.digest.sharded_placement_keys`` -- for a trivial spec
    those keys EQUAL the legacy whole-table keys, so K = 1 sharded
    lookups hit entries populated by plain ``evaluate_many`` and vice
    versa.
    """

    def __init__(self, inner, max_entries: int = 100_000):
        self.inner = ensure_oracle(inner)
        self.max_entries = max_entries
        self.hits = 0
        self.misses = 0
        self.evictions = 0
        # per-evaluate_many accounting: search workloads hammer the cache
        # with near-duplicate batches, and these make that locality
        # visible (b9 reports the batched hit-rate per budget point)
        self.batched_calls = 0
        self.batch_hits = 0
        self.batch_misses = 0
        self.last_batch: dict = {"rows": 0, "hits": 0, "misses": 0}
        self._cache: dict[bytes, SimResult] = {}

    @property
    def mem_capacity_gb(self) -> float:
        return self.inner.mem_capacity_gb

    @property
    def num_evaluations(self) -> int:
        return self.inner.num_evaluations

    # key machinery lives in ``repro.api.digest`` (shared with the
    # serving-side placement cache); these aliases keep the historical
    # per-instance surface
    def _key(self, raw, assignment, n_devices) -> bytes:
        return placement_key(raw, assignment, n_devices)

    def _keys_batch(self, raw, assignments, n_devices) -> list[bytes]:
        return placement_keys(raw, assignments, n_devices)

    def _store(self, key: bytes, res: SimResult):
        if len(self._cache) >= self.max_entries:      # evict least-recent
            self._cache.pop(next(iter(self._cache)))
            self.evictions += 1
            tele.count("oracle.cache.evictions")
        self._cache[key] = res

    def evaluate(self, raw, assignment, n_devices) -> SimResult:
        key = self._key(raw, assignment, n_devices)
        hit = self._cache.get(key)
        if hit is not None:
            self.hits += 1
            tele.count("oracle.cache.hits")
            del self._cache[key]                      # LRU: move to end
            self._cache[key] = hit
            return hit
        self.misses += 1
        tele.count("oracle.cache.misses")
        with tele.span("oracle.cache.evaluate", M=len(raw),
                       n_devices=n_devices):
            res = self.inner.evaluate(raw, assignment, n_devices)
        self._store(key, res)
        return res

    def evaluate_many(self, raw, assignments, n_devices) -> list[SimResult]:
        """Batched evaluation with partial cache hits: only the rows that
        miss are forwarded (as one sub-batch) to the inner oracle's
        ``evaluate_many``.  Duplicate rows within a batch are measured once
        and count as hits thereafter -- exactly what a sequential loop over
        ``evaluate`` would do, since the first occurrence populates the
        cache.  Results follow input row order."""
        assignments = check_assignment_batch(assignments, n_devices)
        sp = tele.span("oracle.cache.evaluate_many",
                       P=len(assignments), M=len(raw), n_devices=n_devices)
        with sp:
            keys = self._keys_batch(raw, assignments, n_devices)
            return self._serve_batch(
                keys, assignments, sp,
                lambda rows: evaluate_many(self.inner, raw, rows, n_devices))

    def evaluate_sharded(self, raw, spec, assignments,
                         n_devices) -> list[SimResult]:
        """Batched shard-level evaluation through the same LRU store.

        Keys come from ``sharded_placement_keys`` (hash of the expanded
        per-shard features + shard assignment), and misses forward to the
        inner oracle via the module-level ``evaluate_sharded`` -- so a
        shard-aware inner backend prices misses with its own shard model
        rather than the generic expanded-features fallback."""
        assignments = check_assignment_batch(assignments, n_devices)
        sp = tele.span("oracle.cache.evaluate_sharded",
                       P=len(assignments), S=spec.n_shards,
                       n_devices=n_devices)
        with sp:
            keys = sharded_placement_keys(raw, spec, assignments, n_devices)
            return self._serve_batch(
                keys, assignments, sp,
                lambda rows: evaluate_sharded(self.inner, raw, spec, rows,
                                              n_devices))

    def _serve_batch(self, keys, assignments, sp, miss_fn):
        hits0, misses0 = self.hits, self.misses
        out: list[SimResult | None] = [None] * len(keys)
        miss_slot: dict[bytes, int] = {}     # key -> index into miss batch
        miss_rows: list[int] = []
        for i, key in enumerate(keys):
            hit = self._cache.get(key)
            if hit is not None:
                self.hits += 1
                del self._cache[key]                  # LRU: move to end
                self._cache[key] = hit
                out[i] = hit
            elif key in miss_slot:                    # duplicate in batch
                self.hits += 1
            else:
                self.misses += 1
                miss_slot[key] = len(miss_rows)
                miss_rows.append(i)
        if miss_rows:
            fresh = miss_fn(assignments[miss_rows])
            for key, slot in miss_slot.items():
                self._store(key, fresh[slot])
            for i, key in enumerate(keys):
                if out[i] is None:
                    out[i] = fresh[miss_slot[key]]
        self.batched_calls += 1
        self.batch_hits += self.hits - hits0
        self.batch_misses += self.misses - misses0
        self.last_batch = {"rows": len(keys), "hits": self.hits - hits0,
                           "misses": self.misses - misses0}
        tele.count("oracle.cache.batched_calls")
        tele.count("oracle.cache.hits", self.hits - hits0)
        tele.count("oracle.cache.misses", self.misses - misses0)
        sp.set(hits=self.hits - hits0, misses=self.misses - misses0)
        return out

    def legal(self, raw, assignment, n_devices) -> bool:
        return bool(self.legal_batch(
            raw, np.asarray(assignment)[None, :], n_devices)[0])

    def legal_batch(self, raw, assignments, n_devices) -> np.ndarray:
        return legal_batch(self.inner, raw, assignments, n_devices)

    def legal_sharded(self, raw, spec, assignments,
                      n_devices) -> np.ndarray:
        return legal_sharded(self.inner, raw, spec, assignments, n_devices)

    def __getattr__(self, name: str):
        # ``info()`` (deprecated since the telemetry PR) is gone: the
        # per-instance counters are plain attributes (``hits`` /
        # ``misses`` / ``batched_calls`` / ``last_batch``) and the
        # process-wide view lives in the telemetry ``oracle.cache.*``
        # counters.
        if name == "info":
            raise AttributeError(
                "CachedOracle.info() was removed; enable repro.telemetry "
                "and read the oracle.cache.* counters via "
                "repro.telemetry.snapshot() (per-instance counts remain "
                "as the hits/misses/batched_calls attributes)")
        raise AttributeError(
            f"{type(self).__name__!r} object has no attribute {name!r}")


class MeasuredOracle:
    """Measured hardware costs at ``SimOracle`` speed.

    Wraps a ``repro.profiling.CalibrationTable`` -- the persisted
    offline micro-benchmark artifact (``python -m
    repro.profiling.calibrate``) -- and prices a placement by pure
    interpolation:

    * per-table forward/backward kernel time is log2-multilinear
      interpolation of the measured ``(dim, rows, batch, pooling)`` grid
      (clamped at the grid edges);
    * a device's K co-resident tables are priced as ONE fused op through
      the artifact's fitted ``FusionModel`` (per-launch overhead
      amortization + per-rank pipelining discount; the paper's Fig-12
      point that fused cost != sum of per-table costs), in O(tables);
      a v1 artifact has no fused sweep and falls back to the additive
      per-table sum (with a load-time warning);
    * the all-to-all is the fitted alpha-beta model applied to each
      device's payload (``batch * dim_sum * bytes * (n-1)/n``).

    ``evaluate`` performs ZERO kernel launches, so the DreamShard
    trainer can collect cost-network data against measured hardware at
    full speed (see ``benchmarks/b5_sim2real.py`` for the throughput
    win over the old per-call timing loop, and
    ``benchmarks/b8_fusion_model.py`` for the fusion-aware model's
    accuracy against live-timed multi-table placements).  Measured
    milliseconds are comparable *within* one calibration artifact, not
    with ``SimOracle`` numbers.

    ``table`` may be a ``CalibrationTable``, a path to one, or ``None``
    (load the default artifact, see
    ``repro.profiling.default_artifact_path``).  ``batch_size`` defaults
    to the table's largest *calibrated* batch so compute interpolation
    and comm payload are priced at the same operating point (an explicit
    batch outside the grid is edge-clamped on the compute side while the
    comm payload keeps growing -- calibrate a matching batch instead).
    ``fusion=False`` forces the additive per-table model regardless of
    the artifact (the pre-v2 behaviour; b8's comparison baseline).
    """

    def __init__(self, table=None, *, batch_size: int | None = None,
                 spec: HardwareSpec = PAPER_GPU,
                 mem_capacity_gb: float | None = None, fusion: bool = True):
        from repro.profiling.calibration import (CalibrationTable,
                                                 FusionModel, ShardModel,
                                                 default_artifact_path)
        if table is None:
            path = default_artifact_path()
            if not os.path.exists(path):
                raise FileNotFoundError(
                    f"no calibration artifact at {path!r}; run `python -m "
                    "repro.profiling.calibrate` (or pass a CalibrationTable)")
            table = CalibrationTable.load(path)
        elif isinstance(table, (str, os.PathLike)):
            table = CalibrationTable.load(os.fspath(table))
        self.table = table
        self.spec = spec
        self.batch_size = int(table.batches[-1]) if batch_size is None \
            else batch_size
        if fusion:
            self.fusion_fwd = table.fusion_fwd
            self.fusion_bwd = table.fusion_bwd
        else:
            self.fusion_fwd = FusionModel.additive()
            self.fusion_bwd = FusionModel.additive()
        # shard pricing: the v3 artifact's fitted sharded-gather models;
        # older tables (and hand-built ones without the field) price a
        # partial table proportionally to its column fraction
        sf = getattr(table, "shard_fwd", None)
        sb = getattr(table, "shard_bwd", None)
        self.shard_fwd = sf if sf is not None else ShardModel.proportional()
        self.shard_bwd = sb if sb is not None else ShardModel.proportional()
        self._mem_capacity_gb = (spec.mem_capacity_gb
                                 if mem_capacity_gb is None
                                 else mem_capacity_gb)
        self._num_evaluations = 0

    @property
    def mem_capacity_gb(self) -> float:
        return self._mem_capacity_gb

    @property
    def num_evaluations(self) -> int:
        return self._num_evaluations

    def per_table_ms(self, raw) -> tuple[np.ndarray, np.ndarray]:
        """Interpolated (fwd, bwd) kernel ms per table -- (M,), (M,).

        Duplicate table shapes (common in production pools) interpolate
        once: queries are deduplicated before hitting the grid, and the
        fwd/bwd grids share one corner-weight computation
        (``CalibrationTable.lookup_ms``)."""
        raw = np.asarray(raw, dtype=np.float64)
        q = raw[:, (F.DIM, F.HASH_SIZE, F.POOLING)]
        uniq, inverse = np.unique(q, axis=0, return_inverse=True)
        inverse = inverse.reshape(-1)   # numpy 2.x shape-change insurance
        fwd, bwd = self.table.lookup_ms(uniq[:, 0], uniq[:, 1],
                                        self.batch_size, uniq[:, 2])
        return fwd[inverse], bwd[inverse]

    def evaluate(self, raw, assignment, n_devices) -> SimResult:
        tele.count("oracle.measured.evaluate_calls")
        with tele.span("oracle.measured.evaluate", M=len(raw),
                       n_devices=n_devices):
            return self._evaluate_many_impl(
                raw, np.asarray(assignment)[None, :], n_devices)[0]

    def evaluate_many(self, raw, assignments, n_devices) -> list[SimResult]:
        """All P placements in one pass: per-table kernel costs interpolate
        once (they depend on the task, not the placement), each device's
        tables are fused through the ``FusionModel`` (rank sort + segment
        sums over the ``(P, M)`` assignment matrix), and the alpha-beta
        comm model prices the whole ``(P, D)`` payload grid."""
        P = len(assignments)
        tele.count("oracle.measured.evaluate_many_calls")
        tele.count("oracle.measured.rows", P)
        with tele.span("oracle.measured.evaluate_many", P=P, M=len(raw),
                       n_devices=n_devices):
            return self._evaluate_many_impl(raw, assignments, n_devices)

    def _evaluate_many_impl(self, raw, assignments,
                            n_devices) -> list[SimResult]:
        raw = np.asarray(raw, dtype=np.float64)
        assignments = check_assignment_batch(assignments, n_devices)
        if assignments.shape[0] == 0:
            return []
        per_fwd, per_bwd = self.per_table_ms(raw)
        return self._price(raw[:, F.DIM], per_fwd, per_bwd, assignments,
                           n_devices)

    def evaluate_sharded(self, raw, spec, assignments,
                         n_devices) -> list[SimResult]:
        """Batched shard-level pricing: each table's kernel time
        interpolates ONCE at its full shape, then splits across its
        shards through the calibrated ``ShardModel`` (per-gather launch
        overhead + the column fraction of the streaming cost) -- a K-way
        split costs MORE than K times ``1/K`` of the table, matching the
        measured sharded-gather sweep.  Fusion and comm then price the
        per-shard costs exactly like per-table ones.  For a trivial spec
        the model returns the full-table times bitwise, so K = 1 results
        equal ``evaluate_many``."""
        P = len(assignments)
        tele.count("oracle.measured.evaluate_sharded_calls")
        tele.count("oracle.measured.rows", P)
        with tele.span("oracle.measured.evaluate_sharded", P=P,
                       S=spec.n_shards, n_devices=n_devices):
            raw = np.asarray(raw, dtype=np.float64)
            assignments = check_assignment_batch(assignments, n_devices)
            if assignments.shape[0] == 0:
                return []
            per_fwd, per_bwd = self.per_table_ms(raw)
            t = spec.table
            frac = spec.widths / raw[t, F.DIM]
            fwd = self.shard_fwd.shard_ms(per_fwd[t], frac)
            bwd = self.shard_bwd.shard_ms(per_bwd[t], frac)
            return self._price(spec.widths.astype(np.float64), fwd, bwd,
                               assignments, n_devices)

    def _price(self, dims, per_fwd, per_bwd, assignments,
               n_devices) -> list[SimResult]:
        """Fusion + comm pricing of per-item (table or shard) kernel
        times over a validated ``(P, S)`` assignment batch; ``dims`` is
        the per-item embedding width the all-to-all payload sums."""
        P, _ = assignments.shape
        self._num_evaluations += P
        # the additive fast path never touches counts -- don't pay the
        # bincount unless a fusion model will rank-sort with it
        counts = None \
            if self.fusion_fwd.is_additive and self.fusion_bwd.is_additive \
            else per_device_sums(assignments, n_devices)
        fwd = self.fusion_fwd.device_ms(per_fwd, assignments, n_devices,
                                        counts)
        bwd = self.fusion_bwd.device_ms(per_bwd, assignments, n_devices,
                                        counts)
        dim_sums = per_device_sums(assignments, n_devices, dims)
        payload_mb = (self.batch_size * dim_sums * self.spec.bytes_per_elem
                      * (n_devices - 1) / n_devices / 1e6)
        comm = self.table.comm_ms(payload_mb)
        # reported fwd comm spans from each device's compute finish to the
        # synced end of the all-to-all (same convention as the simulator)
        fwd_comm = (fwd.max(axis=-1, keepdims=True) - fwd) + comm
        overall = fwd.max(axis=-1) + 2.0 * comm.max(axis=-1) + bwd.max(axis=-1)
        return [SimResult(fwd_comp=fwd[p], bwd_comp=bwd[p],
                          fwd_comm=fwd_comm[p], bwd_comm=comm[p],
                          overall=float(overall[p])) for p in range(P)]

    def legal(self, raw, assignment, n_devices) -> bool:
        return bool(self.legal_batch(
            raw, np.asarray(assignment)[None, :], n_devices)[0])

    def legal_batch(self, raw, assignments, n_devices) -> np.ndarray:
        sizes = np.asarray(raw, dtype=np.float64)[:, F.TABLE_SIZE_GB]
        return assignments_legal(sizes, assignments, n_devices,
                                 self.mem_capacity_gb)

    def legal_sharded(self, raw, spec, assignments,
                      n_devices) -> np.ndarray:
        from repro.sharding.spec import shard_sizes_gb
        return assignments_legal(shard_sizes_gb(raw, spec), assignments,
                                 n_devices, self.mem_capacity_gb)


class KernelOracle:
    """Measured-cost oracle backed by the ``embedding_bag`` kernel: a thin
    adapter over the ``repro.profiling`` subsystem.

    On first ``evaluate`` it runs ONE small micro-benchmark sweep at the
    configured ``(batch_size, pooling)`` operating point (kernel timing
    via ``repro.profiling.microbench``; Pallas on TPU when
    ``use_pallas``, jnp reference otherwise) and builds a
    ``MeasuredOracle`` over the resulting ``CalibrationTable``.  Every
    subsequent ``evaluate`` is pure interpolation -- the old behaviour of
    re-timing kernels inside each call lives on only as
    ``repro.profiling.measure_placement`` (validation/baseline).

    Communication keeps the analytic alpha-beta model derived from the
    hardware spec (a single host has no real all-to-all to measure).
    Pass ``table=`` to reuse a persisted calibration artifact instead of
    sweeping; ``batch_size`` then defaults to that table's largest
    calibrated batch (like ``MeasuredOracle``), else to 64.
    """

    DEFAULT_SWEEP_BATCH = 64

    def __init__(self, spec: HardwareSpec = PAPER_GPU,
                 batch_size: int | None = None,
                 pooling: int = 4, max_rows: int = 4096, repeats: int = 2,
                 use_pallas: bool = False, seed: int = 0, table=None,
                 max_dim: int = 768):
        self.spec = spec
        self.batch_size = batch_size
        self.pooling = pooling
        self.max_rows = max_rows
        self.repeats = repeats
        self.use_pallas = use_pallas
        self.seed = seed
        self.table = table
        self.max_dim = max_dim
        self._measured: MeasuredOracle | None = None

    def _calibration_grid(self) -> dict:
        # the grid must reach the widest table the pools serve (prod dims
        # go to 768) -- interpolation clamps at the top dim, so a short
        # grid would silently underprice exactly the most expensive
        # tables.  dims must be 128-multiples when timing the Pallas
        # kernel (lane padding would alias smaller dims onto the same
        # compiled shape).
        dims = (128, 256) if self.use_pallas else (16, 64, 256)
        if self.max_dim > dims[-1]:
            pad = (int(np.ceil(self.max_dim / 128) * 128)
                   if self.use_pallas else int(self.max_dim))
            dims = dims + (pad,)
        return {"dims": dims,
                "rows": (64, max(128, self.max_rows)),
                "batches": (self.batch_size if self.batch_size is not None
                            else self.DEFAULT_SWEEP_BATCH,),
                "poolings": (self.pooling,)}

    def measured(self) -> MeasuredOracle:
        """The underlying interpolating oracle (calibrates on first use)."""
        if self._measured is None:
            from repro.profiling.calibration import CalibrationTable
            from repro.profiling.collectives import CommModel
            table = self.table
            batch = self.batch_size
            if table is None:
                grid = self._calibration_grid()
                # small fused sweep: enough to fit the launch-overhead
                # amortization without stretching the lazy first call
                tele.count("oracle.kernel.calibrations")
                with tele.span("oracle.kernel.calibrate",
                               use_pallas=self.use_pallas,
                               dims=len(grid["dims"])):
                    table = CalibrationTable.measure(
                        **grid, use_pallas=self.use_pallas,
                        warmup=1, repeats=self.repeats, seed=self.seed,
                        spec=self.spec, comm=CommModel.from_spec(self.spec),
                        fused_ks=(2, 4), fused_per_k=3)
                batch = grid["batches"][0]
            elif isinstance(table, (str, os.PathLike)):
                table = CalibrationTable.load(os.fspath(table))
            # batch=None -> the table's calibrated batch (coherent
            # compute/comm operating point, same as MeasuredOracle)
            self._measured = MeasuredOracle(table, batch_size=batch,
                                            spec=self.spec)
        return self._measured

    @property
    def mem_capacity_gb(self) -> float:
        return self.spec.mem_capacity_gb

    @property
    def num_evaluations(self) -> int:
        return 0 if self._measured is None else \
            self._measured.num_evaluations

    def evaluate(self, raw, assignment, n_devices) -> SimResult:
        tele.count("oracle.kernel.evaluate_calls")
        with tele.span("oracle.kernel.evaluate", M=len(raw),
                       n_devices=n_devices):
            return self.measured().evaluate(raw, assignment, n_devices)

    def evaluate_many(self, raw, assignments, n_devices) -> list[SimResult]:
        P = len(assignments)
        tele.count("oracle.kernel.evaluate_many_calls")
        tele.count("oracle.kernel.rows", P)
        with tele.span("oracle.kernel.evaluate_many", P=P, M=len(raw),
                       n_devices=n_devices):
            return self.measured().evaluate_many(raw, assignments, n_devices)

    def legal(self, raw, assignment, n_devices) -> bool:
        return bool(self.legal_batch(
            raw, np.asarray(assignment)[None, :], n_devices)[0])

    def legal_batch(self, raw, assignments, n_devices) -> np.ndarray:
        # pure spec arithmetic -- must NOT touch measured(), which would
        # run the lazy calibration sweep just to answer a memory probe
        sizes = np.asarray(raw, dtype=np.float64)[:, F.TABLE_SIZE_GB]
        return assignments_legal(sizes, assignments, n_devices,
                                 self.spec.mem_capacity_gb)

    def evaluate_sharded(self, raw, spec, assignments,
                         n_devices) -> list[SimResult]:
        P = len(assignments)
        tele.count("oracle.kernel.evaluate_sharded_calls")
        tele.count("oracle.kernel.rows", P)
        with tele.span("oracle.kernel.evaluate_sharded", P=P,
                       S=spec.n_shards, n_devices=n_devices):
            return self.measured().evaluate_sharded(raw, spec, assignments,
                                                    n_devices)

    def legal_sharded(self, raw, spec, assignments,
                      n_devices) -> np.ndarray:
        # like legal_batch: spec arithmetic only, no lazy calibration
        from repro.sharding.spec import shard_sizes_gb
        return assignments_legal(shard_sizes_gb(raw, spec), assignments,
                                 n_devices, self.spec.mem_capacity_gb)
