"""The unified placement interface: ``Placer`` protocol + ``Placement``.

A ``Placer`` turns a ``Task`` (table subset + device count) into a
``Placement``: the assignment vector, the physical ``PlacementPlan`` the
sharded embedding op consumes, the strategy's own cost estimate (when it
has one), and provenance -- which strategy produced it, how many candidate
placements were ranked, and how many hardware oracle evaluations were
consumed.  Every strategy in the repo (DreamShard, the RNN baseline, the
expert heuristics, random) is exposed through this one interface, so
benchmarks and examples compare strategies without per-strategy glue.
"""

from __future__ import annotations

import dataclasses
from typing import Iterable, Protocol, runtime_checkable

import numpy as np

from repro.api.digest import task_key
from repro.api.oracle import evaluate_many, evaluate_sharded
from repro.data.tasks import Task
from repro.embedding.plan import PlacementPlan, build_plan


@dataclasses.dataclass
class Placement:
    """One strategy's answer for one task, with provenance.

    A placement may be *column-sharded*: ``sharding`` (a
    ``repro.sharding.ShardSpec``) describes how tables split into
    contiguous column ranges and ``shard_assignment`` maps each shard to
    its device.  ``assignment`` then holds the legacy ``(M,)``
    projection (each table's first shard's device) so whole-table
    consumers keep working; shard-aware consumers -- ``evaluate_sharded``,
    the plan builder, digests -- read the shard fields.  Whole-table
    placements (``sharding is None``) are exactly what they always were.
    """

    assignment: np.ndarray          # (M,) table -> device
    plan: PlacementPlan             # physical layout for the sharded op
    n_devices: int
    strategy: str                   # producing Placer's name
    est_cost_ms: float | None = None   # strategy's own (hardware-free) estimate
    candidates: int = 1             # candidate placements ranked internally
    oracle_evals: int = 0           # hardware evaluations consumed producing it
    sharding: object | None = None     # ShardSpec of a column-sharded answer
    shard_assignment: np.ndarray | None = None   # (S,) shard -> device

    @property
    def n_tables(self) -> int:
        return self.assignment.shape[0]

    @property
    def is_sharded(self) -> bool:
        return self.sharding is not None

    @property
    def n_shards(self) -> int:
        """Placed shard count (== ``n_tables`` when whole-table)."""
        return self.n_tables if self.sharding is None \
            else self.sharding.n_shards


@runtime_checkable
class Placer(Protocol):
    """Protocol every placement strategy implements."""

    name: str

    def place(self, task: Task) -> Placement:
        """Place one task."""
        ...

    def place_many(self, tasks: Iterable[Task]) -> list[Placement]:
        """Place a suite of tasks (batched/amortized where possible)."""
        ...


class BasePlacer:
    """Shared plumbing: subclasses implement ``_assign``.

    ``_assign(task) -> (assignment, est_cost_ms, candidates, oracle_evals)``
    """

    name = "base"

    def _assign(self, task: Task):
        raise NotImplementedError

    def _wrap(self, task: Task, assignment: np.ndarray,
              est_cost_ms: float | None = None, candidates: int = 1,
              oracle_evals: int = 0, sharding=None) -> Placement:
        """With ``sharding``, ``assignment`` is the ``(S,)`` shard
        assignment; the stored ``(M,)`` assignment is its projection."""
        assignment = np.asarray(assignment, dtype=np.int64)
        plan = build_plan(task.raw_features, assignment, task.n_devices,
                          sharding=sharding)
        shard_assignment = None
        if sharding is not None:
            from repro.sharding import project_assignment
            shard_assignment = assignment
            assignment = project_assignment(sharding, shard_assignment)
        return Placement(assignment=assignment, plan=plan,
                         n_devices=task.n_devices, strategy=self.name,
                         est_cost_ms=est_cost_ms, candidates=candidates,
                         oracle_evals=oracle_evals, sharding=sharding,
                         shard_assignment=shard_assignment)

    def place(self, task: Task) -> Placement:
        return self._wrap(task, *self._assign(task))

    def place_many(self, tasks: Iterable[Task]) -> list[Placement]:
        return [self.place(t) for t in tasks]


def measure_placements(oracle, tasks: Iterable[Task],
                       placements: Iterable[Placement]) -> np.ndarray:
    """Measured cost (ms) of each placement over its task -- ``(N,)``.

    The hot path of every benchmark sweep: (task, placement) pairs that
    share raw features, a device count, and a sharding are measured
    through ONE ``evaluate_many`` / ``evaluate_sharded`` pass
    (bitwise-identical to per-pair ``evaluate`` calls), so suites that
    repeat tasks pay vector width, not Python call count.  Oracles
    without ``evaluate_many`` fall back to a loop.
    """
    pairs = list(zip(tasks, placements))
    groups: dict[bytes, list[int]] = {}
    for i, (t, p) in enumerate(pairs):
        key = task_key(t.raw_features, t.n_devices)
        # duck-typed placements (anything with .assignment) are
        # whole-table; only real sharded Placements carry a spec
        spec = getattr(p, "sharding", None)
        if spec is not None:
            key += spec.to_bytes()
        groups.setdefault(key, []).append(i)
    costs = np.empty(len(pairs))
    for idxs in groups.values():
        task, first = pairs[idxs[0]]
        if getattr(first, "sharding", None) is None:
            assignments = np.stack([pairs[i][1].assignment for i in idxs])
            results = evaluate_many(oracle, task.raw_features, assignments,
                                    task.n_devices)
        else:
            assignments = np.stack([pairs[i][1].shard_assignment
                                    for i in idxs])
            results = evaluate_sharded(oracle, task.raw_features,
                                       first.sharding, assignments,
                                       task.n_devices)
        for i, res in zip(idxs, results):
            costs[i] = res.overall
    return costs


def evaluate_placements(oracle, tasks: Iterable[Task],
                        placements: Iterable[Placement]) -> float:
    """Mean measured cost (ms) of placements over their tasks."""
    return float(np.mean(measure_placements(oracle, tasks, placements)))


def evaluate_placer(oracle, tasks: Iterable[Task], placer: Placer) -> float:
    """Place a suite through one ``Placer`` and return its mean cost (ms)."""
    tasks = list(tasks)           # survive generators: placed AND re-zipped
    return evaluate_placements(oracle, tasks, placer.place_many(tasks))
