"""The unified placement interface: ``Placer`` protocol + ``Placement``.

A ``Placer`` turns a ``Task`` (table subset + device count) into a
``Placement``: the assignment vector, the physical ``PlacementPlan`` the
sharded embedding op consumes, the strategy's own cost estimate (when it
has one), and provenance -- which strategy produced it, how many candidate
placements were ranked, and how many hardware oracle evaluations were
consumed.  Every strategy in the repo (DreamShard, the RNN baseline, the
expert heuristics, random) is exposed through this one interface, so
benchmarks and examples compare strategies without per-strategy glue.
"""

from __future__ import annotations

import dataclasses
from typing import Iterable, Protocol, runtime_checkable

import numpy as np

from repro.api.digest import task_key
from repro.api.oracle import evaluate_many
from repro.data.tasks import Task
from repro.embedding.plan import PlacementPlan, build_plan


@dataclasses.dataclass
class Placement:
    """One strategy's answer for one task, with provenance."""

    assignment: np.ndarray          # (M,) table -> device
    plan: PlacementPlan             # physical layout for the sharded op
    n_devices: int
    strategy: str                   # producing Placer's name
    est_cost_ms: float | None = None   # strategy's own (hardware-free) estimate
    candidates: int = 1             # candidate placements ranked internally
    oracle_evals: int = 0           # hardware evaluations consumed producing it

    @property
    def n_tables(self) -> int:
        return self.assignment.shape[0]


@runtime_checkable
class Placer(Protocol):
    """Protocol every placement strategy implements."""

    name: str

    def place(self, task: Task) -> Placement:
        """Place one task."""
        ...

    def place_many(self, tasks: Iterable[Task]) -> list[Placement]:
        """Place a suite of tasks (batched/amortized where possible)."""
        ...


class BasePlacer:
    """Shared plumbing: subclasses implement ``_assign``.

    ``_assign(task) -> (assignment, est_cost_ms, candidates, oracle_evals)``
    """

    name = "base"

    def _assign(self, task: Task):
        raise NotImplementedError

    def _wrap(self, task: Task, assignment: np.ndarray,
              est_cost_ms: float | None = None, candidates: int = 1,
              oracle_evals: int = 0) -> Placement:
        assignment = np.asarray(assignment, dtype=np.int64)
        plan = build_plan(task.raw_features, assignment, task.n_devices)
        return Placement(assignment=assignment, plan=plan,
                         n_devices=task.n_devices, strategy=self.name,
                         est_cost_ms=est_cost_ms, candidates=candidates,
                         oracle_evals=oracle_evals)

    def place(self, task: Task) -> Placement:
        return self._wrap(task, *self._assign(task))

    def place_many(self, tasks: Iterable[Task]) -> list[Placement]:
        return [self.place(t) for t in tasks]


def measure_placements(oracle, tasks: Iterable[Task],
                       placements: Iterable[Placement]) -> np.ndarray:
    """Measured cost (ms) of each placement over its task -- ``(N,)``.

    The hot path of every benchmark sweep: (task, placement) pairs that
    share raw features and a device count are measured through ONE
    ``evaluate_many`` pass (bitwise-identical to per-pair ``evaluate``
    calls), so suites that repeat tasks pay vector width, not Python call
    count.  Oracles without ``evaluate_many`` fall back to a loop.
    """
    pairs = list(zip(tasks, placements))
    groups: dict[bytes, list[int]] = {}
    for i, (t, _) in enumerate(pairs):
        groups.setdefault(task_key(t.raw_features, t.n_devices),
                          []).append(i)
    costs = np.empty(len(pairs))
    for idxs in groups.values():
        task = pairs[idxs[0]][0]
        assignments = np.stack([pairs[i][1].assignment for i in idxs])
        results = evaluate_many(oracle, task.raw_features, assignments,
                                task.n_devices)
        for i, res in zip(idxs, results):
            costs[i] = res.overall
    return costs


def evaluate_placements(oracle, tasks: Iterable[Task],
                        placements: Iterable[Placement]) -> float:
    """Mean measured cost (ms) of placements over their tasks."""
    return float(np.mean(measure_placements(oracle, tasks, placements)))


def evaluate_placer(oracle, tasks: Iterable[Task], placer: Placer) -> float:
    """Place a suite through one ``Placer`` and return its mean cost (ms)."""
    tasks = list(tasks)           # survive generators: placed AND re-zipped
    return evaluate_placements(oracle, tasks, placer.place_many(tasks))
