"""Batched DreamShard serving: decode many tasks per jitted call.

``DreamShard.place`` retraces its rollout for every distinct table count
``M`` (and device count ``D``) -- a 50-task suite with heterogeneous sizes
pays tens of XLA compiles.  ``PlacementSession`` instead buckets tasks by
padded ``(M_pad, D)`` shape, pads each task's (sorted) features to the
bucket's table count with masked rows, and decodes the whole bucket in ONE
vmapped+jitted call: one compile per (bucket shape, power-of-two batch
size), amortized across every task in the bucket and every future
``place_many`` call on the session.

The padded rollout is exact, not approximate: masked rows contribute
nothing to the policy/cost device sums or memory, and the candidate key
schedule matches ``DreamShard.place``, so the session returns the *same*
assignments as per-task ``place`` -- just much faster (see
``benchmarks/b4_session_throughput.py``).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro import telemetry as tele
from repro.api.placement import Placement, measure_placements
from repro.core import features as FEAT
from repro.core import rollout as R
from repro.data.tasks import Task
from repro.embedding.plan import build_plan


def pad_feature_batch(entries, m_pad: int, b_pad: int | None = None):
    """Pad per-task ``(feats (m, F), sizes (m,))`` pairs into one dense
    batch: ``(feats (B, m_pad, F), sizes (B, m_pad), tmask (B, m_pad))``.

    Rows beyond each task's table count (and whole batch rows beyond
    ``len(entries)`` when ``b_pad`` over-allocates to a power of two) are
    zero with ``tmask == 0``.  Shared by ``PlacementSession.place_many``
    and the fused trainer's batched collect / RL task batches, so serving
    and training pad identically.
    """
    B = len(entries) if b_pad is None else b_pad
    feats = np.zeros((B, m_pad, FEAT.NUM_FEATURES), np.float32)
    sizes = np.zeros((B, m_pad), np.float32)
    tmask = np.zeros((B, m_pad), np.float32)
    for j, (f, s) in enumerate(entries):
        m = f.shape[0]
        feats[j, :m] = f
        sizes[j, :m] = s
        tmask[j, :m] = 1.0
    return feats, sizes, tmask


def pad_device_mask(device_counts, d_pad: int) -> np.ndarray:
    """(B, d_pad) mask with row b's first ``device_counts[b]`` entries 1."""
    dmask = np.zeros((len(device_counts), d_pad), np.float32)
    for j, d in enumerate(device_counts):
        dmask[j, :d] = 1.0
    return dmask


class PlacementSession:
    """Long-lived serving handle for one trained DreamShard agent.

    Parameters
    ----------
    agent: a ``DreamShard`` (trained or not; uses its current networks).
    n_candidates: candidate placements ranked per task (default: the
        agent's ``inference_candidates``).
    bucket_tables: bucket granularity -- table counts are padded up to the
        next multiple, trading a little padded compute for far fewer
        compiles across heterogeneous suites.
    refiner: optional post-decode refinement pass -- anything with a
        ``refine(task, placement) -> Placement`` method (canonically a
        ``repro.search.SearchPlacer``).  Each decoded placement is handed
        to the refiner before being returned, so a session can serve
        RL+search placements under one handle; ``refiner=None`` (the
        default) serves the raw decode.
    """

    def __init__(self, agent, n_candidates: int | None = None,
                 bucket_tables: int = 8, refiner=None):
        self.agent = agent
        self._n_candidates_override = n_candidates
        self.bucket_tables = max(1, bucket_tables)
        self.refiner = refiner
        self.num_compiles = 0          # distinct bucket shapes traced
        self.num_decode_calls = 0      # jitted decode invocations
        self._decode_fns: dict[tuple, callable] = {}

    @property
    def n_candidates(self) -> int:
        """Candidates ranked per task -- read live from the agent's config
        (unless overridden) so a config change, e.g. via ``restore``, never
        lets the session drift from per-task ``place``."""
        if self._n_candidates_override is not None:
            return self._n_candidates_override
        return self.agent.cfg.inference_candidates

    # ---- bucketing -----------------------------------------------------------

    def _pad_tables(self, m: int) -> int:
        b = self.bucket_tables
        return int(np.ceil(m / b) * b)

    def bucket_key(self, task: Task) -> tuple[int, int]:
        return (self._pad_tables(task.n_tables), task.n_devices)

    def _decode_fn(self, m_pad: int, n_devices: int, b_pad: int):
        cfg = self.agent.cfg
        # cfg-derived statics are part of the key: a config change on a
        # live agent (e.g. restore()) must not serve stale traces
        key = (m_pad, n_devices, self.n_candidates, b_pad,
               cfg.use_cost_features, cfg.reward_mode, self.agent._log_targets)
        fn = self._decode_fns.get(key)
        if fn is None:
            self.num_compiles += 1
            tele.count("session.bucket_compiles")
            tele.count("jit.retraces")
            decode = functools.partial(
                R.decode_candidates, n_devices=n_devices,
                n_candidates=self.n_candidates,
                use_cost=cfg.use_cost_features, reward_mode=cfg.reward_mode,
                log_targets=self.agent._log_targets)

            @jax.jit
            def fn(policy_params, cost_params, feats, sizes, tmask, cap):
                def one(f, s, m):
                    return decode(policy_params, cost_params, f, s, cap,
                                  tmask=m)
                return jax.vmap(one)(feats, sizes, tmask)

            self._decode_fns[key] = fn
        return fn

    # ---- serving -------------------------------------------------------------

    def place_many(self, tasks: list[Task]) -> list[Placement]:
        """Place a suite, decoding each ``(M_pad, D)`` bucket in one call."""
        tasks = list(tasks)
        buckets: dict[tuple, list[int]] = {}
        for i, t in enumerate(tasks):
            buckets.setdefault(self.bucket_key(t), []).append(i)

        out: list[Placement | None] = [None] * len(tasks)
        for (m_pad, n_devices), idxs in buckets.items():
            B = len(idxs)
            # pad the batch dim to a power of two with fully-masked rows so
            # differently-sized calls into the same bucket reuse one trace
            b_pad = 1 << max(0, B - 1).bit_length()
            entries, orders = [], []
            for i in idxs:
                f, s, order = self.agent._inference_inputs(
                    tasks[i].raw_features)
                entries.append((f[order], s[order]))
                orders.append(order)
            feats, sizes, tmask = pad_feature_batch(entries, m_pad, b_pad)
            c0 = self.num_compiles
            fn = self._decode_fn(m_pad, n_devices, b_pad)
            fresh = self.num_compiles > c0
            args = (self.agent.policy_params, self.agent.cost_params,
                    jnp.asarray(feats), jnp.asarray(sizes),
                    jnp.asarray(tmask), self.agent.oracle.mem_capacity_gb)
            with tele.span("session.decode", m_pad=m_pad,
                           n_devices=n_devices, tasks=B, b_pad=b_pad,
                           fresh_compile=fresh):
                if fresh:
                    # jit compiles lazily: a fresh fn pays its XLA trace
                    # inside this first invocation
                    with tele.span("session.compile", m_pad=m_pad,
                                   n_devices=n_devices, b_pad=b_pad):
                        actions, est = fn(*args)
                else:
                    actions, est = fn(*args)
            self.num_decode_calls += 1
            tele.count("session.decode_calls")
            actions, est = np.asarray(actions), np.asarray(est)
            for j, i in enumerate(idxs):
                t, order = tasks[i], orders[j]
                best = int(np.argmin(est[j]))
                assignment = np.empty(t.n_tables, dtype=np.int64)
                assignment[order] = actions[j, best, :t.n_tables]
                out[i] = Placement(
                    assignment=assignment,
                    plan=build_plan(t.raw_features, assignment, n_devices),
                    n_devices=n_devices, strategy="dreamshard",
                    est_cost_ms=float(est[j, best]),
                    candidates=self.n_candidates, oracle_evals=0)
        if self.refiner is not None:
            out = [self.refiner.refine(t, p) for t, p in zip(tasks, out)]
        return out

    def place(self, task: Task) -> Placement:
        return self.place_many([task])[0]

    def place_and_measure(self, tasks: list[Task], oracle
                          ) -> tuple[list[Placement], np.ndarray]:
        """Serve a suite end-to-end batched: bucketed decode
        (``place_many``) followed by one grouped ``evaluate_many``
        measurement pass per distinct (raw features, device count) --
        both halves scale with vector width, not task count.  Returns
        ``(placements, per-task measured ms)``."""
        tasks = list(tasks)
        placements = self.place_many(tasks)
        return placements, measure_placements(oracle, tasks, placements)
