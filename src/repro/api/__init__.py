"""Public placement API.

One interface over every placement strategy and cost backend:

* ``CostOracle`` (protocol) with ``SimOracle`` / ``CachedOracle`` /
  ``MeasuredOracle`` / ``KernelOracle`` implementations --
  `evaluate(raw, assignment, n_devices) -> SimResult` and the batched
  `evaluate_many(raw, (P, M) assignments, n_devices)` (one vectorized
  pass, bitwise-identical to P sequential calls) plus
  `mem_capacity_gb` / `num_evaluations`; ``MeasuredOracle``
  interpolates a persisted ``repro.profiling`` calibration artifact
  (measured kernel/collective costs, zero kernel launches per call);
* ``Placer`` (protocol) + ``Placement`` (assignment, physical
  ``PlacementPlan``, estimated cost, provenance) with adapters for
  DreamShard, the RNN baseline, expert heuristics, and random;
* ``PlacementSession`` -- batched DreamShard serving: tasks bucketed by
  padded ``(M, D)`` shape, many tasks decoded per jitted call.

See ``docs/api.md`` for usage and the migration guide.
"""

from repro.api.oracle import (CachedOracle, CostOracle, KernelOracle,
                              MeasuredOracle, SimOracle, ensure_oracle,
                              evaluate_many, legal_batch)
from repro.api.placement import (BasePlacer, Placement, Placer,
                                 evaluate_placements, evaluate_placer,
                                 measure_placements)
from repro.api.placers import (DreamShardPlacer, ExpertPlacer, RNNPlacerAdapter,
                               RandomPlacer, make_baseline_placers)
from repro.api.session import PlacementSession

__all__ = [
    "BasePlacer", "CachedOracle", "CostOracle", "DreamShardPlacer",
    "ExpertPlacer", "KernelOracle", "MeasuredOracle", "Placement",
    "PlacementSession", "Placer",
    "RNNPlacerAdapter", "RandomPlacer", "SimOracle", "ensure_oracle",
    "evaluate_many", "evaluate_placements", "evaluate_placer", "legal_batch",
    "make_baseline_placers", "measure_placements",
]
