"""Public placement API.

One interface over every placement strategy and cost backend:

* ``CostOracle`` (protocol) with ``SimOracle`` / ``CachedOracle`` /
  ``MeasuredOracle`` / ``KernelOracle`` implementations --
  `evaluate(raw, assignment, n_devices) -> SimResult` and the batched
  `evaluate_many(raw, (P, M) assignments, n_devices)` (one vectorized
  pass, bitwise-identical to P sequential calls) plus
  `mem_capacity_gb` / `num_evaluations`; ``MeasuredOracle``
  interpolates a persisted ``repro.profiling`` calibration artifact
  (measured kernel/collective costs, zero kernel launches per call);
* ``Placer`` (protocol) + ``Placement`` (assignment, physical
  ``PlacementPlan``, estimated cost, provenance) with adapters for
  DreamShard, the RNN baseline, expert heuristics, and random;
* ``PlacementSession`` -- batched DreamShard serving: tasks bucketed by
  padded ``(M, D)`` shape, many tasks decoded per jitted call, with an
  optional post-decode ``refiner`` pass;
* ``SearchPlacer`` / ``SearchConfig`` (re-exported lazily from
  ``repro.search``) -- anytime search refinement of any seed placer
  through the batched oracle;
* ``PlacementService`` / ``ServeConfig`` (re-exported lazily from
  ``repro.serve``) -- long-running serving: digest-keyed placement
  cache, micro-batch admission, drift-triggered re-placement, plus the
  fault layer (``FaultInjector`` / ``FaultSchedule``, typed
  ``ServeError`` results, failover and warm-restart checkpoints);
* blake2b digest helpers (``placement_key`` / ``placement_keys`` /
  ``task_key``) shared by ``CachedOracle`` and the serving cache.

See ``docs/api.md`` for usage and the migration guide.
"""

from repro.api.digest import placement_key, placement_keys, task_key
from repro.api.oracle import (CachedOracle, CostOracle, KernelOracle,
                              MeasuredOracle, SimOracle, ensure_oracle,
                              evaluate_many, legal_batch)
from repro.api.placement import (BasePlacer, Placement, Placer,
                                 evaluate_placements, evaluate_placer,
                                 measure_placements)
from repro.api.placers import (DreamShardPlacer, ExpertPlacer,
                               PortfolioPlacer, RNNPlacerAdapter,
                               RandomPlacer, make_baseline_placers)
from repro.api.session import PlacementSession

# repro.search / repro.serve import from repro.api, so their names are
# re-exported lazily (PEP 562) to keep `import repro.api` cycle-free
_SEARCH_EXPORTS = ("SearchConfig", "SearchPlacer", "SearchScorer")
_SERVE_EXPORTS = ("CapacityError", "DecodeTimeout", "FaultEvent",
                  "FaultInjector", "FaultSchedule", "IllegalTaskError",
                  "PlacementCache", "PlacementService", "ServeConfig",
                  "ServeError", "ServeResult", "TransientOracleError")

__all__ = [
    "BasePlacer", "CachedOracle", "CapacityError", "CostOracle",
    "DecodeTimeout", "DreamShardPlacer", "ExpertPlacer", "FaultEvent",
    "FaultInjector", "FaultSchedule", "IllegalTaskError", "KernelOracle",
    "MeasuredOracle", "Placement", "PlacementCache", "PlacementService",
    "PlacementSession", "Placer", "PortfolioPlacer", "RNNPlacerAdapter",
    "RandomPlacer", "SearchConfig", "SearchPlacer", "SearchScorer",
    "ServeConfig", "ServeError", "ServeResult", "SimOracle",
    "TransientOracleError", "ensure_oracle", "evaluate_many",
    "evaluate_placements", "evaluate_placer", "legal_batch",
    "make_baseline_placers", "measure_placements", "placement_key",
    "placement_keys", "task_key",
]


def __getattr__(name: str):
    if name in _SEARCH_EXPORTS:
        import repro.search as _search
        return getattr(_search, name)
    if name in _SERVE_EXPORTS:
        import repro.serve as _serve
        return getattr(_serve, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


def __dir__():
    return sorted(__all__)
