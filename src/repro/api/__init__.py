"""Public placement API.

One interface over every placement strategy and cost backend:

* ``CostOracle`` (protocol) with ``SimOracle`` / ``CachedOracle`` /
  ``MeasuredOracle`` / ``KernelOracle`` implementations --
  `evaluate(raw, assignment, n_devices) -> SimResult` and the batched
  `evaluate_many(raw, (P, M) assignments, n_devices)` (one vectorized
  pass, bitwise-identical to P sequential calls) plus
  `mem_capacity_gb` / `num_evaluations`; ``MeasuredOracle``
  interpolates a persisted ``repro.profiling`` calibration artifact
  (measured kernel/collective costs, zero kernel launches per call);
* column-wise sharding (``repro.sharding``) -- ``ShardSpec`` +
  ``shard_features`` expand tables into per-shard pseudo-tables;
  ``evaluate_sharded`` / ``legal_sharded`` price and bound-check
  ``(P, S)`` shard assignments on every oracle (K = 1 bitwise-equal to
  the whole-table path); ``ShardingPlacer`` wraps any placer to split
  oversized/hottest tables;
* ``Placer`` (protocol) + ``Placement`` (assignment, physical
  ``PlacementPlan``, estimated cost, provenance) with adapters for
  DreamShard, the RNN baseline, expert heuristics, and random;
* ``PlacementSession`` -- batched DreamShard serving: tasks bucketed by
  padded ``(M, D)`` shape, many tasks decoded per jitted call, with an
  optional post-decode ``refiner`` pass;
* ``SearchPlacer`` / ``SearchConfig`` (re-exported lazily from
  ``repro.search``) -- anytime search refinement of any seed placer
  through the batched oracle;
* ``PlacementService`` / ``ServeConfig`` (re-exported lazily from
  ``repro.serve``) -- long-running serving: digest-keyed placement
  cache, micro-batch admission, drift-triggered re-placement, plus the
  fault layer (``FaultInjector`` / ``FaultSchedule``, typed
  ``ServeError`` results, failover and warm-restart checkpoints);
* blake2b digest helpers (``placement_key`` / ``placement_keys`` /
  ``sharded_placement_key(s)`` / ``task_key``) shared by
  ``CachedOracle`` and the serving cache.

See ``docs/api.md`` for usage and the migration guide.
"""

import importlib

from repro.api.digest import (placement_key, placement_keys,
                              sharded_placement_key, sharded_placement_keys,
                              task_key)
from repro.api.oracle import (CachedOracle, CostOracle, KernelOracle,
                              MeasuredOracle, SimOracle, ensure_oracle,
                              evaluate_many, evaluate_sharded, legal_batch,
                              legal_sharded)
from repro.api.placement import (BasePlacer, Placement, Placer,
                                 evaluate_placements, evaluate_placer,
                                 measure_placements)
from repro.api.placers import (DreamShardPlacer, ExpertPlacer,
                               PortfolioPlacer, RNNPlacerAdapter,
                               RandomPlacer, make_baseline_placers)
from repro.api.session import PlacementSession
from repro.sharding import (ShardSpec, project_assignment, shard_features,
                            shard_sizes_gb)

# ``repro.search`` / ``repro.serve`` / ``repro.sharding.placer`` import
# from repro.api, so their names are re-exported lazily (PEP 562) from
# this ONE registry to keep `import repro.api` cycle-free.  The __all__
# consistency test pins that every lazy name resolves and is exported.
_LAZY = {
    # repro.search
    "SearchConfig": "repro.search",
    "SearchPlacer": "repro.search",
    "SearchScorer": "repro.search",
    # repro.serve
    "CapacityError": "repro.serve",
    "DecodeTimeout": "repro.serve",
    "FaultEvent": "repro.serve",
    "FaultInjector": "repro.serve",
    "FaultSchedule": "repro.serve",
    "IllegalTaskError": "repro.serve",
    "PlacementCache": "repro.serve",
    "PlacementService": "repro.serve",
    "ServeConfig": "repro.serve",
    "ServeError": "repro.serve",
    "ServeResult": "repro.serve",
    "TransientOracleError": "repro.serve",
    # repro.sharding (the placer layer sits above repro.search)
    "ShardingConfig": "repro.sharding",
    "ShardingPlacer": "repro.sharding",
    "refine_sharded": "repro.sharding",
}

__all__ = sorted([
    "BasePlacer", "CachedOracle", "CostOracle", "DreamShardPlacer",
    "ExpertPlacer", "KernelOracle", "MeasuredOracle", "Placement",
    "PlacementSession", "Placer", "PortfolioPlacer", "RNNPlacerAdapter",
    "RandomPlacer", "ShardSpec", "SimOracle", "ensure_oracle",
    "evaluate_many", "evaluate_placements", "evaluate_placer",
    "evaluate_sharded", "legal_batch", "legal_sharded",
    "make_baseline_placers", "measure_placements", "placement_key",
    "placement_keys", "project_assignment", "shard_features",
    "shard_sizes_gb", "sharded_placement_key", "sharded_placement_keys",
    "task_key", *_LAZY,
])


def __getattr__(name: str):
    module = _LAZY.get(name)
    if module is not None:
        return getattr(importlib.import_module(module), name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


def __dir__():
    return sorted(__all__)
