"""Execution-cost simulator for sharded embedding lookups.

Stands in for the paper's GPU measurement harness (PARAM benchmark).  It
reproduces the phenomena the paper documents analytically:

* memory-bound gather cost with a cache model driven by the 17-bin access
  distribution and the table working set (App. A.3.1, Figs 10/11);
* operation fusion: a fused multi-table op costs
  ``c0 + sum_i m_i / pipeline_eff(k)`` while k single-table ops cost
  ``sum_i (c0 + m_i)`` -- the fused/unfused ratio lands in the paper's
  observed 1x-3x band and is non-linear in the table mix (Fig 12);
* all-to-all cost proportional to per-device dim-sums with a congestion
  penalty for imbalance (Table 4);
* the 4-stage cost decomposition (fwd comp, fwd comm, bwd comm, bwd comp)
  with the overall latency as the sum of per-stage bottlenecks, and the
  3-element per-device cost features q = [fwd_comp, bwd_comp, bwd_comm]
  (fwd comm excluded -- App. A.4);
* seeded multiplicative log-normal noise emulating measurement jitter.

Everything is vectorized numpy; one `evaluate` call is the analogue of one
PARAM benchmarking run on real hardware, and `evaluate_batch` measures all
P placements of a task in one pass over the ``(P, M)`` assignment matrix
(segment sums + an in-row rank sort instead of a per-device Python loop),
bitwise-identical to P sequential `evaluate` calls -- `evaluate` is its
P = 1 special case.
"""

from __future__ import annotations

import dataclasses
import zlib

import numpy as np

from repro.core import features as F
from repro.sim.hardware import HardwareSpec, PAPER_GPU

DEFAULT_BATCH = 65536

# splitmix64: stateless counter-based hashing for the measurement-noise
# stream (vectorizes over whole evaluation batches, unlike Generator objects)
_SM64_GAMMA = np.uint64(0x9E3779B97F4A7C15)


def _mix64(x: np.ndarray) -> np.ndarray:
    """splitmix64 finalizer: bijective uint64 avalanche hash."""
    x = np.asarray(x, dtype=np.uint64)
    x = (x ^ (x >> np.uint64(30))) * np.uint64(0xBF58476D1CE4E5B9)
    x = (x ^ (x >> np.uint64(27))) * np.uint64(0x94D049BB133111EB)
    return x ^ (x >> np.uint64(31))


def placement_bytes(raw: np.ndarray, assignment: np.ndarray,
                    n_devices: int) -> bytes:
    """Canonical byte serialization of one placement query -- the shared
    input to the simulator's noise digest and ``CachedOracle`` keys."""
    r = np.ascontiguousarray(np.asarray(raw, dtype=np.float64))
    a = np.ascontiguousarray(np.asarray(assignment, dtype=np.int64))
    return r.tobytes() + a.tobytes() + int(n_devices).to_bytes(8, "little")


def placement_digest(raw: np.ndarray, assignment: np.ndarray,
                     n_devices: int) -> int:
    """Deterministic 32-bit digest of one placement query.

    Unlike the built-in ``hash`` (salted per process by PYTHONHASHSEED),
    ``zlib.crc32`` is stable across processes, so it reproducibly seeds
    the simulator's measurement noise.  ``repro.api.CachedOracle`` hashes
    the same ``placement_bytes`` stream (wide, collision-safe) for its
    memo keys.
    """
    return zlib.crc32(placement_bytes(raw, assignment, n_devices))


def per_device_sums(assignments: np.ndarray, n_devices: int,
                    weights: np.ndarray | None = None) -> np.ndarray:
    """Per-(placement, device) segment sum over a ``(P, M)`` assignment
    batch -> ``(P, D)``: one bincount over flattened group ids (no Python
    loop over placements or devices).  Within each group, accumulation
    follows table order -- the property the bitwise batch-vs-loop
    guarantee rests on.  ``weights`` is per-table ``(M,)`` or per-cell
    ``(P, M)``; ``None`` counts tables."""
    P, M = assignments.shape
    gid = assignments + n_devices * np.arange(P)[:, None]
    w = None if weights is None else \
        np.broadcast_to(weights, (P, M)).ravel()
    return np.bincount(gid.ravel(), weights=w,
                       minlength=P * n_devices).reshape(P, n_devices)


def check_assignment_batch(assignments: np.ndarray,
                           n_devices: int) -> np.ndarray:
    """Canonicalize + validate a batched assignment matrix: int64
    ``(P, M)`` with device ids in ``[0, n_devices)`` (out-of-range ids
    would alias into a neighboring row's groups in the flattened
    segment sums)."""
    a = np.asarray(assignments, dtype=np.int64)
    if a.ndim != 2:
        raise ValueError(f"assignments must be (P, M), got shape {a.shape}")
    if a.size and ((a < 0) | (a >= n_devices)).any():
        raise ValueError(f"assignment device ids must be in [0, {n_devices})")
    return a


def placement_digests(raw: np.ndarray, assignments: np.ndarray,
                      n_devices: int) -> np.ndarray:
    """Row-wise ``placement_digest`` over a ``(P, M)`` assignment batch.

    crc32 is a streaming checksum, so the shared ``raw`` prefix is hashed
    ONCE and each row only pays for its own assignment bytes -- the values
    are identical to P independent ``placement_digest`` calls.
    """
    r = np.ascontiguousarray(np.asarray(raw, dtype=np.float64))
    a = np.ascontiguousarray(np.asarray(assignments, dtype=np.int64))
    prefix = zlib.crc32(r.tobytes())
    suffix = int(n_devices).to_bytes(8, "little")
    return np.array([zlib.crc32(row.tobytes() + suffix, prefix)
                     for row in a], dtype=np.int64)


@dataclasses.dataclass
class SimResult:
    """Measured costs for one placement (all times in milliseconds)."""

    fwd_comp: np.ndarray   # (D,) fused forward computation per device
    bwd_comp: np.ndarray   # (D,) fused backward computation per device
    fwd_comm: np.ndarray   # (D,) forward all-to-all (incl. waiting; App A.4)
    bwd_comm: np.ndarray   # (D,) backward all-to-all
    overall: float         # end-to-end latency of the embedding stages

    @property
    def cost_features(self) -> np.ndarray:
        """Per-device q_{t,d} = [fwd_comp, bwd_comp, bwd_comm]  -> (D, 3)."""
        return np.stack([self.fwd_comp, self.bwd_comp, self.bwd_comm], axis=1)


class CostSimulator:
    """The 'hardware' the RL loop measures against."""

    def __init__(self, spec: HardwareSpec = PAPER_GPU,
                 batch_size: int = DEFAULT_BATCH,
                 noise_std: float = 0.01, seed: int = 0):
        self.spec = spec
        self.batch_size = batch_size
        self.noise_std = noise_std
        self.seed = seed
        self.num_evaluations = 0  # bookkeeping: "GPU measurements" consumed

    # ---- per-table primitives ------------------------------------------------

    # fraction of a table's touched rows that form its cache-resident "hot
    # head" (zipf head); shared-cache contention operates on these bytes
    HOT_HEAD = 0.08
    HIT_CAP = 0.6

    def _reuse_and_ws(self, raw: np.ndarray):
        """(reuse fraction, hot working-set bytes) per table (M,)."""
        dist = raw[:, F.DIST_START:]
        # Reuse fraction: an index accessed c times has (c-1)/c of its
        # accesses as repeats; weight by bin mass.
        reuse = dist @ (1.0 - 1.0 / F.BIN_MEAN_COUNT)
        touched = np.minimum(
            self.batch_size * raw[:, F.POOLING] * np.maximum(1e-3, 1.0 - reuse),
            raw[:, F.HASH_SIZE],
        )
        ws_bytes = (touched * raw[:, F.DIM] * self.spec.bytes_per_elem
                    * self.HOT_HEAD)
        return reuse, ws_bytes

    def _cache_hit_rate(self, raw: np.ndarray,
                        shared: bool = False) -> np.ndarray:
        """Fraction of gather traffic served by the cache, per table (M,).

        With ``shared=True`` the tables CO-RESIDE on one device and compete
        for the same cache: the capacity fraction uses the SUM of hot
        working sets.  This interaction is what makes fused multi-table
        costs combination-dependent (paper Fig 12) and single-table-cost
        greedy balancing systematically over-optimistic.
        """
        reuse, ws_bytes = self._reuse_and_ws(raw)
        denom = ws_bytes.sum() if shared else np.maximum(ws_bytes, 1.0)
        capacity_frac = np.minimum(1.0, self.spec.cache_bytes
                                   / np.maximum(denom, 1.0))
        return np.clip(reuse * capacity_frac, 0.0, self.HIT_CAP)

    def _marginals_from_hit(self, raw: np.ndarray, reuse: np.ndarray,
                            hit: np.ndarray
                            ) -> tuple[np.ndarray, np.ndarray]:
        """(marginal fwd ms, marginal bwd ms) given per-table cache hit
        rates.  THE cost-model formula: both the scalar ``_marginals``
        path (public ``fused_op_ms``/``marginal_*_ms`` surface) and the
        batched ``_grouped_marginals`` path (``hit`` of shape (P, M))
        price tables through this one function, so the model cannot
        fork."""
        bw = self.spec.gather_bw_gbs * 1e9
        # Blend cold and cached bandwidth.
        blend = (1.0 - hit) / bw + hit / (bw * self.spec.cache_speedup)
        pooled = self.batch_size * raw[:, F.POOLING]
        fwd_bytes = pooled * raw[:, F.DIM] * self.spec.bytes_per_elem
        # backward: read+write of unique rows, plus streaming the incoming
        # gradients
        touched = np.minimum(pooled * np.maximum(1e-3, 1.0 - reuse),
                             raw[:, F.HASH_SIZE])
        bwd_bytes = ((2.0 * touched + 0.25 * pooled)
                     * raw[:, F.DIM] * self.spec.bytes_per_elem)
        return (fwd_bytes * blend * 1e3,
                bwd_bytes * blend * 1e3 * self.spec.bwd_comp_scale)

    def _marginals(self, raw: np.ndarray,
                   shared: bool = False) -> tuple[np.ndarray, np.ndarray]:
        """(marginal fwd ms, marginal bwd ms) per table (M,), computed in
        one pass: the reuse/working-set/cache-hit intermediates are shared
        between the two stages (the split helpers recomputed them four
        times per fused op, the hottest line of every ``evaluate``)."""
        reuse, ws_bytes = self._reuse_and_ws(raw)
        denom = ws_bytes.sum() if shared else np.maximum(ws_bytes, 1.0)
        capacity_frac = np.minimum(1.0, self.spec.cache_bytes
                                   / np.maximum(denom, 1.0))
        hit = np.clip(reuse * capacity_frac, 0.0, self.HIT_CAP)
        return self._marginals_from_hit(raw, reuse, hit)

    def marginal_fwd_ms(self, raw: np.ndarray,
                        shared: bool = False) -> np.ndarray:
        """Marginal (overhead-free) forward gather time per table (M,)."""
        return self._marginals(raw, shared=shared)[0]

    def marginal_bwd_ms(self, raw: np.ndarray,
                        shared: bool = False) -> np.ndarray:
        """Marginal backward (gradient apply) time per table (M,).

        The backward is a row-wise scatter-add over the UNIQUE rows touched
        (read + modify + write), so its cost tracks ``touched * dim``, not
        ``pooling * dim``: reuse-heavy tables have cheap backwards, uniform
        tables cost ~2x their forward.  fwd and bwd balance are therefore
        *different objectives* -- a single greedy cost function cannot
        satisfy both, which is exactly the multi-stage trade-off DreamShard
        learns (paper Fig 1: fwd- vs bwd-bottlenecked placements differ).
        """
        return self._marginals(raw, shared=shared)[1]

    def _pipeline_eff(self, k: np.ndarray) -> np.ndarray:
        k = np.maximum(k, 1)
        return np.minimum(self.spec.pipeline_cap,
                          1.0 + self.spec.pipeline_coef * np.log2(k))

    def fused_op_ms(self, raw_subset: np.ndarray) -> tuple[float, float]:
        """(fwd, bwd) time of ONE fused op over the given tables.

        Each table's marginal cost is divided by a per-rank pipeline factor
        (deeper fusion overlaps better), with tables sorted by cost so the
        model is monotone: adding a table always adds positive time, yet
        the fused/unfused ratio still lands in the paper's 1-3x band.
        """
        if raw_subset.shape[0] == 0:
            return 0.0, 0.0
        ranks = np.arange(1, raw_subset.shape[0] + 1)
        eff = self._pipeline_eff(ranks)
        mf, mb = self._marginals(raw_subset, shared=True)
        mf = np.sort(mf)[::-1]
        mb = np.sort(mb)[::-1]
        fwd = self.spec.comp_overhead_ms + float((mf / eff).sum())
        bwd = self.spec.comp_overhead_ms + float((mb / eff).sum())
        return fwd, bwd

    def single_table_ms(self, raw: np.ndarray) -> np.ndarray:
        """Unfused per-table forward cost c0 + m_i (M,) -- Fig 12 baseline."""
        return self.spec.comp_overhead_ms + self.marginal_fwd_ms(raw)

    # ---- placement evaluation ------------------------------------------------

    def comm_ms(self, dim_sums: np.ndarray, n_devices: int) -> np.ndarray:
        """Per-device all-to-all time given per-device output dim sums.

        Public model surface (measured oracles and the live measurement
        harness reuse it for the stages a single host cannot time)."""
        return self._comm_ms_batch(
            np.asarray(dim_sums, dtype=np.float64)[None, :], n_devices)[0]

    def __getattr__(self, name: str):
        if name == "_comm_ms":
            raise AttributeError(
                "CostSimulator._comm_ms was removed; use the public "
                "CostSimulator.comm_ms(dim_sums, n_devices) instead")
        raise AttributeError(
            f"{type(self).__name__!r} object has no attribute {name!r}")

    def _comm_ms_batch(self, dim_sums: np.ndarray,
                       n_devices: int) -> np.ndarray:
        """``comm_ms`` over a ``(P, D)`` batch of per-device dim sums."""
        if n_devices <= 1:
            return np.zeros_like(dim_sums)
        payload = (self.batch_size * dim_sums * self.spec.bytes_per_elem
                   * (n_devices - 1) / n_devices)
        bw = self.spec.a2a_bw_gbs * 1e9
        base = payload / bw * 1e3
        imbalance = np.maximum(
            0.0, base.max(axis=-1) - base.mean(axis=-1))[..., None]
        return np.where(dim_sums > 0,
                        self.spec.comm_overhead_ms + base
                        + self.spec.congestion * imbalance,
                        0.0)

    def _noise_batch(self, keys: np.ndarray, n_devices: int) -> np.ndarray:
        """``(P, 4, D)`` multiplicative log-normal noise for a whole batch.

        Counter-based: every (placement, stage, device) cell hashes its own
        uint64 word (splitmix64 of the row's placement digest + cell index)
        into two uniforms and one Box-Muller normal -- one vectorized pass,
        no generator objects.  The old ``_noise`` built a fresh
        ``np.random.default_rng`` four times per evaluate, which dominated
        batched evaluation cost.  Values are a pure function of
        ``(sim seed, placement digest, cell)``, so they are reproducible
        across processes and independent of batch composition (the
        batch-vs-loop bitwise guarantee).
        """
        P = len(keys)
        if self.noise_std <= 0:
            return np.ones((P, 4, n_devices))
        seed_word = _mix64(np.array([self.seed & 0xFFFFFFFFFFFFFFFF],
                                    dtype=np.uint64))
        base = _mix64(seed_word + keys.astype(np.uint64))
        cell = np.arange(4 * n_devices, dtype=np.uint64) + np.uint64(1)
        w1 = _mix64(base[:, None] + cell * _SM64_GAMMA)
        w2 = _mix64(w1 + _SM64_GAMMA)
        # 53-bit mantissa uniforms; u1 < 1 keeps the log argument positive
        u1 = (w1 >> np.uint64(11)).astype(np.float64) * (2.0 ** -53)
        u2 = (w2 >> np.uint64(11)).astype(np.float64) * (2.0 ** -53)
        z = np.sqrt(-2.0 * np.log1p(-u1)) * np.cos(2.0 * np.pi * u2)
        return np.exp(self.noise_std * z).reshape(P, 4, n_devices)

    def _grouped_marginals(self, raw: np.ndarray, assignments: np.ndarray,
                           n_devices: int):
        """Per-table fused marginal costs under each placement's co-residence
        pattern: ``(mf, mb)`` of shape ``(P, M)``.

        The cache-contention denominator (sum of hot working sets sharing a
        device) is the only placement-dependent input, so the per-table
        intermediates are computed once, only the group sums span the
        ``(P, M)`` batch, and the actual pricing shares
        ``_marginals_from_hit`` with the scalar path.
        """
        reuse, ws_bytes = self._reuse_and_ws(raw)
        P, _ = assignments.shape
        denom = per_device_sums(assignments, n_devices, ws_bytes)
        capacity_frac = np.minimum(1.0, self.spec.cache_bytes
                                   / np.maximum(denom, 1.0))
        hit = np.clip(reuse * capacity_frac[np.arange(P)[:, None],
                                            assignments],
                      0.0, self.HIT_CAP)
        return self._marginals_from_hit(raw, reuse, hit)

    def _fused_sum(self, marginal: np.ndarray, assignments: np.ndarray,
                   counts: np.ndarray, starts: np.ndarray,
                   n_devices: int) -> np.ndarray:
        """Pipeline-discounted per-device fused-op time ``(P, D)`` from
        per-table marginals ``(P, M)``: within every (placement, device)
        group tables are ranked by descending marginal cost and divided by
        the per-rank pipeline efficiency, exactly as ``fused_op_ms``."""
        P, M = assignments.shape
        rows = np.arange(P)[:, None]
        order = np.lexsort((-marginal, assignments), axis=-1)
        dev_sorted = assignments[rows, order]
        rank = np.arange(M)[None, :] - starts[rows, dev_sorted]
        contrib = marginal[rows, order] / self._pipeline_eff(rank + 1)
        sums = per_device_sums(dev_sorted, n_devices, contrib)
        return np.where(counts > 0, self.spec.comp_overhead_ms + sums, 0.0)

    def evaluate_batch(self, raw: np.ndarray, assignments: np.ndarray,
                       n_devices: int) -> list[SimResult]:
        """Measure P placements of one task in a single vectorized pass.

        ``assignments`` is ``(P, M)``; the result list follows row order and
        each row is bitwise-identical to ``evaluate(raw, assignments[p],
        n_devices)`` -- every per-row computation (group sums, rank sort,
        reductions, digest-seeded noise) is independent of the other rows,
        and ``evaluate`` itself is the ``P == 1`` special case of this
        path.  Counts ``P`` hardware measurements.
        """
        raw = np.asarray(raw, dtype=np.float64)
        assignments = check_assignment_batch(assignments, n_devices)
        P, M = assignments.shape
        if P == 0:
            return []
        self.num_evaluations += P

        counts = per_device_sums(assignments, n_devices)
        starts = np.concatenate(
            [np.zeros((P, 1), np.int64),
             np.cumsum(counts, axis=1)[:, :-1]], axis=1)
        mf, mb = self._grouped_marginals(raw, assignments, n_devices)
        fwd = self._fused_sum(mf, assignments, counts, starts, n_devices)
        bwd = self._fused_sum(mb, assignments, counts, starts, n_devices)
        dim_sums = per_device_sums(assignments, n_devices, raw[:, F.DIM])
        comm = self._comm_ms_batch(dim_sums, n_devices)

        keys = placement_digests(raw, assignments, n_devices) & 0x7FFFFFFF
        noise = self._noise_batch(keys, n_devices)
        fwd = fwd * noise[:, 0]
        bwd = bwd * noise[:, 1]
        bwd_comm = comm * noise[:, 2]
        # Forward comm as *reported* includes waiting for the slowest fwd
        # computation (App. A.4): every device's fwd-comm timer spans from
        # its own compute finish to the synced end of the all-to-all.
        fwd_comm = (fwd.max(axis=-1, keepdims=True) - fwd) + comm * noise[:, 3]
        overall = (fwd.max(axis=-1) + comm.max(axis=-1)
                   + bwd_comm.max(axis=-1) + bwd.max(axis=-1))
        return [SimResult(fwd_comp=fwd[p], bwd_comp=bwd[p],
                          fwd_comm=fwd_comm[p], bwd_comm=bwd_comm[p],
                          overall=float(overall[p])) for p in range(P)]

    def evaluate(self, raw: np.ndarray, assignment: np.ndarray,
                 n_devices: int) -> SimResult:
        """Measure a full placement: the analogue of one GPU benchmark run.

        Single-placement view of ``evaluate_batch`` (P = 1), so sequential
        loops and the batched path are bitwise-identical by construction.
        """
        return self.evaluate_batch(
            raw, np.asarray(assignment)[None, :], n_devices)[0]

    # ---- placement legality --------------------------------------------------

    def table_sizes_gb(self, raw: np.ndarray) -> np.ndarray:
        return raw[:, F.TABLE_SIZE_GB]

    def legal_batch(self, raw: np.ndarray, assignments: np.ndarray,
                    n_devices: int) -> np.ndarray:
        """Memory legality of a ``(P, M)`` assignment batch -> ``(P,)`` bool
        (bincount over the assignment matrix, no per-device loop)."""
        return assignments_legal(self.table_sizes_gb(np.asarray(raw)),
                                 assignments, n_devices,
                                 self.spec.mem_capacity_gb)

    def legal(self, raw: np.ndarray, assignment: np.ndarray,
              n_devices: int) -> bool:
        return bool(self.legal_batch(
            raw, np.asarray(assignment)[None, :], n_devices)[0])

    # ---- column-wise sharding ------------------------------------------------

    def evaluate_sharded_batch(self, raw: np.ndarray, spec,
                               assignments: np.ndarray,
                               n_devices: int) -> list[SimResult]:
        """Measure P *shard-level* placements: ``assignments`` is
        ``(P, S)`` over the shards of a ``repro.sharding.ShardSpec``.

        Pricing is ``evaluate_batch`` over the expanded per-shard feature
        matrix (``shard_features``): each shard flows through the cache-hit
        curve at its own column width, same-device sibling shards contend
        for cache like distinct tables, and the comm payload sums shard
        widths per device.  A trivial spec expands byte-identically to
        ``raw``, so K = 1 sharded costs (noise digests included) are
        bitwise the whole-table costs.
        """
        from repro.sharding.spec import shard_features
        return self.evaluate_batch(shard_features(raw, spec), assignments,
                                   n_devices)

    def evaluate_sharded(self, raw: np.ndarray, spec,
                         shard_assignment: np.ndarray,
                         n_devices: int) -> SimResult:
        """Single-placement view of ``evaluate_sharded_batch`` (P = 1)."""
        return self.evaluate_sharded_batch(
            raw, spec, np.asarray(shard_assignment)[None, :], n_devices)[0]

    def legal_sharded_batch(self, raw: np.ndarray, spec,
                            assignments: np.ndarray,
                            n_devices: int) -> np.ndarray:
        """Memory legality of ``(P, S)`` shard assignments: per-device
        sums of per-shard bytes (``table_size_gb`` scaled by column
        fraction) against capacity."""
        from repro.sharding.spec import shard_sizes_gb
        return assignments_legal(shard_sizes_gb(raw, spec), assignments,
                                 n_devices, self.spec.mem_capacity_gb)


def assignments_legal(sizes_gb: np.ndarray, assignments: np.ndarray,
                      n_devices: int, capacity_gb: float) -> np.ndarray:
    """Vectorized per-device memory check shared by every cost backend:
    ``(P,)`` bools for a ``(P, M)`` assignment batch over tables of
    ``sizes_gb`` ``(M,)``.  A legality probe answers for ANY input, so a
    row with device ids outside ``[0, n_devices)`` is reported illegal
    rather than raising (unlike measurement, where malformed ids are a
    programming error)."""
    assignments = np.asarray(assignments, dtype=np.int64)
    bad = (assignments < 0) | (assignments >= n_devices)
    per_dev = per_device_sums(np.where(bad, 0, assignments), n_devices,
                              sizes_gb)
    return (per_dev <= capacity_gb).all(axis=1) & ~bad.any(axis=1)
