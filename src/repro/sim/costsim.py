"""Execution-cost simulator for sharded embedding lookups.

Stands in for the paper's GPU measurement harness (PARAM benchmark).  It
reproduces the phenomena the paper documents analytically:

* memory-bound gather cost with a cache model driven by the 17-bin access
  distribution and the table working set (App. A.3.1, Figs 10/11);
* operation fusion: a fused multi-table op costs
  ``c0 + sum_i m_i / pipeline_eff(k)`` while k single-table ops cost
  ``sum_i (c0 + m_i)`` -- the fused/unfused ratio lands in the paper's
  observed 1x-3x band and is non-linear in the table mix (Fig 12);
* all-to-all cost proportional to per-device dim-sums with a congestion
  penalty for imbalance (Table 4);
* the 4-stage cost decomposition (fwd comp, fwd comm, bwd comm, bwd comp)
  with the overall latency as the sum of per-stage bottlenecks, and the
  3-element per-device cost features q = [fwd_comp, bwd_comp, bwd_comm]
  (fwd comm excluded -- App. A.4);
* seeded multiplicative log-normal noise emulating measurement jitter.

Everything is vectorized numpy; one `evaluate` call is the analogue of one
PARAM benchmarking run on real hardware.
"""

from __future__ import annotations

import dataclasses
import zlib

import numpy as np

from repro.core import features as F
from repro.sim.hardware import HardwareSpec, PAPER_GPU

DEFAULT_BATCH = 65536


def placement_bytes(raw: np.ndarray, assignment: np.ndarray,
                    n_devices: int) -> bytes:
    """Canonical byte serialization of one placement query -- the shared
    input to the simulator's noise digest and ``CachedOracle`` keys."""
    r = np.ascontiguousarray(np.asarray(raw, dtype=np.float64))
    a = np.ascontiguousarray(np.asarray(assignment, dtype=np.int64))
    return r.tobytes() + a.tobytes() + int(n_devices).to_bytes(8, "little")


def placement_digest(raw: np.ndarray, assignment: np.ndarray,
                     n_devices: int) -> int:
    """Deterministic 32-bit digest of one placement query.

    Unlike the built-in ``hash`` (salted per process by PYTHONHASHSEED),
    ``zlib.crc32`` is stable across processes, so it reproducibly seeds
    the simulator's measurement noise.  ``repro.api.CachedOracle`` hashes
    the same ``placement_bytes`` stream (wide, collision-safe) for its
    memo keys.
    """
    return zlib.crc32(placement_bytes(raw, assignment, n_devices))


@dataclasses.dataclass
class SimResult:
    """Measured costs for one placement (all times in milliseconds)."""

    fwd_comp: np.ndarray   # (D,) fused forward computation per device
    bwd_comp: np.ndarray   # (D,) fused backward computation per device
    fwd_comm: np.ndarray   # (D,) forward all-to-all (incl. waiting; App A.4)
    bwd_comm: np.ndarray   # (D,) backward all-to-all
    overall: float         # end-to-end latency of the embedding stages

    @property
    def cost_features(self) -> np.ndarray:
        """Per-device q_{t,d} = [fwd_comp, bwd_comp, bwd_comm]  -> (D, 3)."""
        return np.stack([self.fwd_comp, self.bwd_comp, self.bwd_comm], axis=1)


class CostSimulator:
    """The 'hardware' the RL loop measures against."""

    def __init__(self, spec: HardwareSpec = PAPER_GPU,
                 batch_size: int = DEFAULT_BATCH,
                 noise_std: float = 0.01, seed: int = 0):
        self.spec = spec
        self.batch_size = batch_size
        self.noise_std = noise_std
        self.seed = seed
        self.num_evaluations = 0  # bookkeeping: "GPU measurements" consumed

    # ---- per-table primitives ------------------------------------------------

    # fraction of a table's touched rows that form its cache-resident "hot
    # head" (zipf head); shared-cache contention operates on these bytes
    HOT_HEAD = 0.08
    HIT_CAP = 0.6

    def _reuse_and_ws(self, raw: np.ndarray):
        """(reuse fraction, hot working-set bytes) per table (M,)."""
        dist = raw[:, F.DIST_START:]
        # Reuse fraction: an index accessed c times has (c-1)/c of its
        # accesses as repeats; weight by bin mass.
        reuse = dist @ (1.0 - 1.0 / F.BIN_MEAN_COUNT)
        touched = np.minimum(
            self.batch_size * raw[:, F.POOLING] * np.maximum(1e-3, 1.0 - reuse),
            raw[:, F.HASH_SIZE],
        )
        ws_bytes = (touched * raw[:, F.DIM] * self.spec.bytes_per_elem
                    * self.HOT_HEAD)
        return reuse, ws_bytes

    def _cache_hit_rate(self, raw: np.ndarray,
                        shared: bool = False) -> np.ndarray:
        """Fraction of gather traffic served by the cache, per table (M,).

        With ``shared=True`` the tables CO-RESIDE on one device and compete
        for the same cache: the capacity fraction uses the SUM of hot
        working sets.  This interaction is what makes fused multi-table
        costs combination-dependent (paper Fig 12) and single-table-cost
        greedy balancing systematically over-optimistic.
        """
        reuse, ws_bytes = self._reuse_and_ws(raw)
        denom = ws_bytes.sum() if shared else np.maximum(ws_bytes, 1.0)
        capacity_frac = np.minimum(1.0, self.spec.cache_bytes
                                   / np.maximum(denom, 1.0))
        return np.clip(reuse * capacity_frac, 0.0, self.HIT_CAP)

    def _marginals(self, raw: np.ndarray,
                   shared: bool = False) -> tuple[np.ndarray, np.ndarray]:
        """(marginal fwd ms, marginal bwd ms) per table (M,), computed in
        one pass: the reuse/working-set/cache-hit intermediates are shared
        between the two stages (the split helpers recomputed them four
        times per fused op, the hottest line of every ``evaluate``)."""
        reuse, ws_bytes = self._reuse_and_ws(raw)
        denom = ws_bytes.sum() if shared else np.maximum(ws_bytes, 1.0)
        capacity_frac = np.minimum(1.0, self.spec.cache_bytes
                                   / np.maximum(denom, 1.0))
        hit = np.clip(reuse * capacity_frac, 0.0, self.HIT_CAP)
        bw = self.spec.gather_bw_gbs * 1e9
        # Blend cold and cached bandwidth.
        blend = (1.0 - hit) / bw + hit / (bw * self.spec.cache_speedup)
        pooled = self.batch_size * raw[:, F.POOLING]
        fwd_bytes = pooled * raw[:, F.DIM] * self.spec.bytes_per_elem
        # backward: read+write of unique rows, plus streaming the incoming
        # gradients
        touched = np.minimum(pooled * np.maximum(1e-3, 1.0 - reuse),
                             raw[:, F.HASH_SIZE])
        bwd_bytes = ((2.0 * touched + 0.25 * pooled)
                     * raw[:, F.DIM] * self.spec.bytes_per_elem)
        return (fwd_bytes * blend * 1e3,
                bwd_bytes * blend * 1e3 * self.spec.bwd_comp_scale)

    def marginal_fwd_ms(self, raw: np.ndarray,
                        shared: bool = False) -> np.ndarray:
        """Marginal (overhead-free) forward gather time per table (M,)."""
        return self._marginals(raw, shared=shared)[0]

    def marginal_bwd_ms(self, raw: np.ndarray,
                        shared: bool = False) -> np.ndarray:
        """Marginal backward (gradient apply) time per table (M,).

        The backward is a row-wise scatter-add over the UNIQUE rows touched
        (read + modify + write), so its cost tracks ``touched * dim``, not
        ``pooling * dim``: reuse-heavy tables have cheap backwards, uniform
        tables cost ~2x their forward.  fwd and bwd balance are therefore
        *different objectives* -- a single greedy cost function cannot
        satisfy both, which is exactly the multi-stage trade-off DreamShard
        learns (paper Fig 1: fwd- vs bwd-bottlenecked placements differ).
        """
        return self._marginals(raw, shared=shared)[1]

    def _pipeline_eff(self, k: np.ndarray) -> np.ndarray:
        k = np.maximum(k, 1)
        return np.minimum(self.spec.pipeline_cap,
                          1.0 + self.spec.pipeline_coef * np.log2(k))

    def fused_op_ms(self, raw_subset: np.ndarray) -> tuple[float, float]:
        """(fwd, bwd) time of ONE fused op over the given tables.

        Each table's marginal cost is divided by a per-rank pipeline factor
        (deeper fusion overlaps better), with tables sorted by cost so the
        model is monotone: adding a table always adds positive time, yet
        the fused/unfused ratio still lands in the paper's 1-3x band.
        """
        if raw_subset.shape[0] == 0:
            return 0.0, 0.0
        ranks = np.arange(1, raw_subset.shape[0] + 1)
        eff = self._pipeline_eff(ranks)
        mf, mb = self._marginals(raw_subset, shared=True)
        mf = np.sort(mf)[::-1]
        mb = np.sort(mb)[::-1]
        fwd = self.spec.comp_overhead_ms + float((mf / eff).sum())
        bwd = self.spec.comp_overhead_ms + float((mb / eff).sum())
        return fwd, bwd

    def single_table_ms(self, raw: np.ndarray) -> np.ndarray:
        """Unfused per-table forward cost c0 + m_i (M,) -- Fig 12 baseline."""
        return self.spec.comp_overhead_ms + self.marginal_fwd_ms(raw)

    # ---- placement evaluation ------------------------------------------------

    def comm_ms(self, dim_sums: np.ndarray, n_devices: int) -> np.ndarray:
        """Per-device all-to-all time given per-device output dim sums.

        Public model surface (measured oracles and the live measurement
        harness reuse it for the stages a single host cannot time)."""
        if n_devices <= 1:
            return np.zeros_like(dim_sums)
        payload = (self.batch_size * dim_sums * self.spec.bytes_per_elem
                   * (n_devices - 1) / n_devices)
        bw = self.spec.a2a_bw_gbs * 1e9
        base = payload / bw * 1e3
        imbalance = np.maximum(0.0, base.max() - base.mean())
        return np.where(dim_sums > 0,
                        self.spec.comm_overhead_ms + base
                        + self.spec.congestion * imbalance,
                        0.0)

    def _comm_ms(self, dim_sums: np.ndarray, n_devices: int) -> np.ndarray:
        """Deprecated private alias of ``comm_ms`` (kept for old callers)."""
        import warnings
        warnings.warn("CostSimulator._comm_ms is deprecated; use the public "
                      "comm_ms", DeprecationWarning, stacklevel=2)
        return self.comm_ms(dim_sums, n_devices)

    def _noise(self, key: int, shape) -> np.ndarray:
        if self.noise_std <= 0:
            return np.ones(shape)
        rng = np.random.default_rng((self.seed, key))
        return np.exp(rng.normal(0.0, self.noise_std, size=shape))

    def evaluate(self, raw: np.ndarray, assignment: np.ndarray,
                 n_devices: int) -> SimResult:
        """Measure a full placement: the analogue of one GPU benchmark run."""
        self.num_evaluations += 1
        raw = np.asarray(raw, dtype=np.float64)
        assignment = np.asarray(assignment)
        fwd = np.zeros(n_devices)
        bwd = np.zeros(n_devices)
        dim_sums = np.zeros(n_devices)
        for d in range(n_devices):
            sub = raw[assignment == d]
            fwd[d], bwd[d] = self.fused_op_ms(sub)
            dim_sums[d] = sub[:, F.DIM].sum() if sub.shape[0] else 0.0
        comm = self.comm_ms(dim_sums, n_devices)

        key = placement_digest(raw, assignment, n_devices) & 0x7FFFFFFF
        fwd = fwd * self._noise(key ^ 1, fwd.shape)
        bwd = bwd * self._noise(key ^ 2, bwd.shape)
        bwd_comm = comm * self._noise(key ^ 3, comm.shape)

        # Forward comm as *reported* includes waiting for the slowest fwd
        # computation (App. A.4): every device's fwd-comm timer spans from its
        # own compute finish to the synced end of the all-to-all.
        fwd_comm = (fwd.max() - fwd) + comm * self._noise(key ^ 4, comm.shape)

        overall = (fwd.max() + comm.max() + bwd_comm.max() + bwd.max())
        return SimResult(fwd_comp=fwd, bwd_comp=bwd, fwd_comm=fwd_comm,
                         bwd_comm=bwd_comm, overall=float(overall))

    # ---- placement legality --------------------------------------------------

    def table_sizes_gb(self, raw: np.ndarray) -> np.ndarray:
        return raw[:, F.TABLE_SIZE_GB]

    def legal(self, raw: np.ndarray, assignment: np.ndarray,
              n_devices: int) -> bool:
        sizes = self.table_sizes_gb(raw)
        for d in range(n_devices):
            if sizes[assignment == d].sum() > self.spec.mem_capacity_gb:
                return False
        return True
