"""Hardware specifications for the embedding-cost execution simulator.

The paper measures embedding op costs on real GPUs (2080Ti / V100).  This
container has no accelerator, so the RL loop's measurement oracle is a
calibrated analytical simulator (see ``repro.sim.costsim``).  Constants for
the default spec are calibrated so that random placement on DLRM-50 (4
devices, batch 65536, dim 16, mean pooling 15) lands at the paper's ~50 ms
scale (Table 6), with fused-op speedups in the paper's observed 1-3x band
(Fig. 12) and all-to-all congestion matching Table 4's imbalance behaviour.

A TPU-v5e spec is provided for the TPU-target experiments: 819 GB/s HBM,
~50 GB/s/link ICI, 197 TFLOP/s bf16 (the roofline constants used by
``launch/dryrun.py`` as well).
"""

from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class HardwareSpec:
    """Parameters of one accelerator + interconnect for the cost simulator."""

    name: str
    # Effective bandwidth (GB/s) of random row gather from device memory when
    # the access misses the cache hierarchy.  Far below peak HBM bandwidth
    # because embedding gathers are scattered, short rows.
    gather_bw_gbs: float
    # Multiplier on gather bandwidth for cache-resident rows.
    cache_speedup: float
    # Capacity (bytes) of the fast level that caches hot embedding rows.
    cache_bytes: float
    # Effective per-device all-to-all bandwidth (GB/s), including protocol
    # overheads; calibrated to the paper's Table 4, not to link peak.
    a2a_bw_gbs: float
    # Fixed per-fused-op launch/setup overhead (ms).  Amortized by fusion;
    # this term is what makes fused cost != sum of single-table costs.
    comp_overhead_ms: float
    # Fixed all-to-all launch overhead (ms).
    comm_overhead_ms: float
    # Backward computation multiplier over forward (gradient read+apply).
    bwd_comp_scale: float
    # Congestion coefficient: extra per-device all-to-all time proportional
    # to (max - mean) payload imbalance (Table 4 shows even non-bottleneck
    # devices slow down under imbalance).
    congestion: float
    # Device memory capacity (GB) for placement legality.
    mem_capacity_gb: float
    # Bytes per embedding element (fp16/bf16).
    bytes_per_elem: int = 2
    # Pipelining efficiency gain from fusing k tables into one op: the
    # marginal gather streams overlap; eff(k) = min(cap, 1 + coef*log2(k)).
    pipeline_coef: float = 0.15
    pipeline_cap: float = 1.7

    # Roofline constants (used by the dry-run analysis, not the simulator).
    peak_flops: float = 0.0          # FLOP/s
    hbm_bw_gbs: float = 0.0          # GB/s
    ici_bw_gbs: float = 0.0          # GB/s per link


# Calibrated to the paper's 2080Ti numbers (Tables 1/6, Fig 12, Table 4).
PAPER_GPU = HardwareSpec(
    name="2080ti-calibrated",
    gather_bw_gbs=22.0,
    cache_speedup=4.0,          # Fig 11: sparse access speedup band
    cache_bytes=12e6,           # effective cache hierarchy (L2+TLB+row buf)
    a2a_bw_gbs=4.0,
    comp_overhead_ms=0.25,
    comm_overhead_ms=0.5,
    bwd_comp_scale=1.5,
    congestion=0.1,
    mem_capacity_gb=11.0,
)

# Larger-memory spec standing in for V100 (Prod-style diverse-dim tables).
PAPER_GPU_LARGE = dataclasses.replace(
    PAPER_GPU, name="v100-calibrated", mem_capacity_gb=32.0,
    gather_bw_gbs=55.0, a2a_bw_gbs=4.0, cache_bytes=6e6,
)

# TPU v5e target (the deployment hardware for the JAX/Pallas build).
TPU_V5E = HardwareSpec(
    name="tpu-v5e",
    gather_bw_gbs=200.0,        # random-gather effective, ~25% of HBM peak
    cache_speedup=4.0,
    cache_bytes=64e6,           # usable VMEM budget for hot rows
    a2a_bw_gbs=45.0,
    comp_overhead_ms=0.02,
    comm_overhead_ms=0.05,
    bwd_comp_scale=1.3,
    congestion=0.2,
    mem_capacity_gb=16.0,
    peak_flops=197e12,
    hbm_bw_gbs=819.0,
    ici_bw_gbs=50.0,
)

SPECS = {s.name: s for s in (PAPER_GPU, PAPER_GPU_LARGE, TPU_V5E)}
