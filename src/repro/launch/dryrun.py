import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512").strip()

"""Multi-pod dry-run: prove every (architecture x input shape x mesh)
combination lowers and compiles on the production mesh, and extract the
roofline terms for EXPERIMENTS.md.

For each combination:
  1. full-depth `lower().compile()` with the layer scan -- the lowering
     proof; `memory_analysis()` from this compile shows the footprint.
  2. unrolled 1-layer and 2-layer metric compiles -- cost_analysis FLOPs/
     bytes and parsed collective wire bytes, extrapolated to full depth
     (cost_analysis counts a scan body once; see launch/roofline.py).

Results append incrementally to a JSON file so partial runs are resumable.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun                 # everything
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen2.5-14b \
      --shape train_4k --mesh single                           # one combo
"""

import argparse  # noqa: E402
import dataclasses  # noqa: E402
import json  # noqa: E402
import time  # noqa: E402
import traceback  # noqa: E402

import jax  # noqa: E402

from repro import configs as C  # noqa: E402
from repro.configs.shapes import INPUT_SHAPES  # noqa: E402
from repro.launch import roofline as R  # noqa: E402
from repro.launch.mesh import make_production_mesh, production_rules  # noqa: E402
from repro.launch import steps as ST  # noqa: E402

TP = 16
# decode cache capacity for sliding-window archs on the 500k shape
LONG_DECODE_WINDOW = {"h2o-danube-1.8b": 4096, "hymba-1.5b": 1024,
                      "rwkv6-1.6b": None}


def _lower(cfg, shape, mesh, rules, layer_loop, remat=True,
           n_microbatches=1):
    with jax.set_mesh(mesh):
        return _lower_inner(cfg, shape, mesh, rules, layer_loop, remat,
                            n_microbatches)


def _lower_inner(cfg, shape, mesh, rules, layer_loop, remat=True,
                 n_microbatches=1):
    if shape.kind == "train":
        lowered, _ = ST.lower_train(cfg, shape, mesh, rules,
                                    layer_loop=layer_loop, remat=remat,
                                    n_microbatches=n_microbatches)
    elif shape.kind == "prefill":
        lowered, _ = ST.lower_prefill(cfg, shape, mesh, rules,
                                      layer_loop=layer_loop, remat=remat)
    else:
        window = None
        if shape.name == "long_500k":
            window = LONG_DECODE_WINDOW.get(cfg.name.split("-smoke")[0])
        lowered, _ = ST.lower_decode(cfg, shape, mesh, rules,
                                     window_capacity=window,
                                     layer_loop=layer_loop)
    return lowered


def _lower_dlrm(mesh, rules, batch=65536, n_tables=160, pool_slots=16):
    """Paper's own architecture: DLRM train step with table-parallel
    embedding (shard_map + all-to-all), DreamShard-style placement plan.

    Arenas are stored at the native dim (16) -- the Pallas kernel pads to
    128 lanes transiently; storing padded would waste 8x HBM.  Hash sizes
    are clipped to 4e6 rows so the 160-table pool fits a v5e-16 shard
    budget (the paper's 11 GB GPUs hold ~20-80 tables per device)."""
    import numpy as np
    import jax.numpy as jnp
    from repro.core import baselines as B
    from repro.core import features as F
    from repro.data.synthetic import make_dlrm_pool
    from repro.embedding import sharded as E
    from repro.embedding.plan import build_plan
    from repro.models.dlrm import DLRM, DLRMConfig
    from repro.optim import adam, apply_updates, rowwise_adagrad
    from repro.optim.optimizers import OptState
    from jax.sharding import NamedSharding, PartitionSpec as P

    tp = mesh.shape["model"]
    pool = make_dlrm_pool(seed=0)[:n_tables].copy()
    pool[:, F.HASH_SIZE] = np.clip(pool[:, F.HASH_SIZE], 1e4, 4e6)
    pool[:, F.TABLE_SIZE_GB] = F.table_size_gb(pool[:, F.DIM],
                                               pool[:, F.HASH_SIZE])
    assign = B.expert_place(pool, tp, 1e9, "size")
    plan = build_plan(pool, assign, tp, pad_dim_to=16)
    cfg = DLRMConfig(n_dense_features=13, embed_dim=plan.dim,
                     bottom_mlp=(512, 256), top_mlp=(1024, 512, 256),
                     n_tables=n_tables)
    model = DLRM(cfg, plan, dtype=jnp.bfloat16)
    lookup = E.make_sharded_lookup(mesh, plan,
                                   data_axes=rules.batch_axes or ("data",),
                                   model_axis=rules.model_axis)
    emb_opt = rowwise_adagrad(0.05)
    dense_opt = adam(1e-3)

    def train_step(params, emb_state, dense_state, batch_in):
        def loss_fn(p):
            logits = model.forward(p, batch_in["dense"], batch_in["gidx"],
                                   lookup)
            return DLRM.loss(logits, batch_in["labels"])
        loss, g = jax.value_and_grad(loss_fn)(params)
        eu, emb_state = emb_opt.update({"arenas": g["arenas"]}, emb_state)
        du, dense_state = dense_opt.update(
            {k: g[k] for k in ("bottom", "top")}, dense_state)
        params = {**apply_updates({k: params[k] for k in ("bottom", "top")},
                                  du),
                  **apply_updates({"arenas": params["arenas"]}, eu)}
        return params, emb_state, dense_state, loss

    aparams = jax.eval_shape(model.init_params, jax.random.PRNGKey(0))
    a_emb = jax.eval_shape(emb_opt.init, {"arenas": aparams["arenas"]})
    a_dense = jax.eval_shape(
        dense_opt.init, {k: aparams[k] for k in ("bottom", "top")})
    batch_specs = {
        "dense": jax.ShapeDtypeStruct((batch, 13), jnp.float32),
        "gidx": jax.ShapeDtypeStruct(
            (batch, plan.n_shards * plan.k_max, pool_slots), jnp.int32),
        "labels": jax.ShapeDtypeStruct((batch,), jnp.float32),
    }
    m = rules.model_axis
    pspecs = {"arenas": P(m, None, None),
              "bottom": [{"w": P(None, None), "b": P(None)}
                         for _ in aparams["bottom"]],
              "top": [{"w": P(None, None), "b": P(None)}
                      for _ in aparams["top"]]}
    e_specs = OptState(P(), {"arenas": P(m, None)})   # rowwise acc (S, R)
    d_specs = jax.tree.map(lambda x: P() if getattr(x, "ndim", 0) == 0
                           else P(None, None) if x.ndim == 2 else P(None),
                           a_dense)
    bspec = {"dense": rules.spec("batch", None),
             "gidx": rules.spec("batch", None, None),
             "labels": rules.spec("batch")}
    def ns(t):
        return jax.tree.map(lambda s: NamedSharding(mesh, s), t,
                            is_leaf=lambda s: isinstance(s, P))

    in_sh = (ns(pspecs), ns(e_specs), ns(d_specs), ns(bspec))
    out_sh = (ns(pspecs), ns(e_specs), ns(d_specs),
              NamedSharding(mesh, P()))
    fn = jax.jit(train_step, in_shardings=in_sh, out_shardings=out_sh,
                 donate_argnums=(0, 1, 2))
    return fn.lower(aparams, a_emb, a_dense, batch_specs)


def run_dlrm(mesh_kind: str) -> dict:
    multi = mesh_kind == "multi"
    mesh = make_production_mesh(multi_pod=multi)
    rules = production_rules(multi_pod=multi)
    rec = {"arch": "dlrm", "shape": "train_65k", "mesh": mesh_kind,
           "n_devices": mesh.size, "status": "ok"}
    t0 = time.perf_counter()
    with jax.set_mesh(mesh):
        lowered = _lower_dlrm(mesh, rules)
        t_lower = time.perf_counter() - t0
        compiled = lowered.compile()
    t_compile = time.perf_counter() - t0 - t_lower
    ma = compiled.memory_analysis()
    peak = (ma.argument_size_in_bytes + ma.output_size_in_bytes
            + ma.temp_size_in_bytes - ma.alias_size_in_bytes)
    ca = compiled.cost_analysis()
    from repro.launch import roofline as R
    wire = R.collective_wire_bytes(compiled.as_text(), 16)
    terms = R.RooflineTerms(
        hlo_flops=float(ca.get("flops", 0.0)),
        hlo_bytes=float(ca.get("bytes accessed", 0.0)),
        wire_bytes=sum(wire.values()), wire_by_kind=wire,
        model_flops=0.0, n_devices=mesh.size)
    rec.update({"lower_s": round(t_lower, 2),
                "compile_s": round(t_compile, 2),
                "arg_bytes_per_dev": int(ma.argument_size_in_bytes),
                "out_bytes_per_dev": int(ma.output_size_in_bytes),
                "temp_bytes_per_dev": int(ma.temp_size_in_bytes),
                "alias_bytes_per_dev": int(ma.alias_size_in_bytes),
                "peak_bytes_per_dev": int(peak),
                "fits_16gb_hbm": bool(peak < 16e9),
                "roofline": terms.as_dict()})
    return rec


def run_combo(arch: str, shape_name: str, mesh_kind: str,
              skip_metrics: bool = False, strategy: str = "tp",
              n_microbatches: int = 1) -> dict:
    if arch == "dlrm":
        return run_dlrm(mesh_kind)
    shape = INPUT_SHAPES[shape_name]
    multi = mesh_kind == "multi"
    mesh = make_production_mesh(multi_pod=multi)
    rules = production_rules(multi_pod=multi, strategy=strategy)
    n_dev = mesh.size
    cfg = C.get_full(arch).resolve(1 if strategy == "fsdp" else TP)
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_kind,
           "n_devices": n_dev, "status": "ok", "strategy": strategy}
    t0 = time.perf_counter()

    rec["n_microbatches"] = n_microbatches
    # 1) full-depth lowering proof (scan over layers)
    lowered = _lower(cfg, shape, mesh, rules, "scan",
                     n_microbatches=n_microbatches)
    t_lower = time.perf_counter() - t0
    compiled = lowered.compile()
    t_compile = time.perf_counter() - t0 - t_lower
    ma = compiled.memory_analysis()
    rec.update({
        "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
        "arg_bytes_per_dev": int(ma.argument_size_in_bytes),
        "out_bytes_per_dev": int(ma.output_size_in_bytes),
        "temp_bytes_per_dev": int(ma.temp_size_in_bytes),
        "alias_bytes_per_dev": int(ma.alias_size_in_bytes),
    })
    peak = (ma.argument_size_in_bytes + ma.output_size_in_bytes
            + ma.temp_size_in_bytes - ma.alias_size_in_bytes)
    rec["peak_bytes_per_dev"] = int(peak)
    rec["fits_16gb_hbm"] = bool(peak < 16e9)
    del compiled, lowered

    if skip_metrics:
        return rec

    # 2) metric compiles: unrolled depth 1 and 2, extrapolate to L
    L = cfg.n_layers
    metrics = {}
    for k in (1, 2):
        cfg_k = dataclasses.replace(cfg, n_layers=k)
        lw = _lower(cfg_k, shape, mesh, rules, "unrolled",
                    n_microbatches=n_microbatches)
        cp = lw.compile()
        ca = cp.cost_analysis()
        metrics[k] = {
            "flops": float(ca.get("flops", 0.0)),
            "bytes": float(ca.get("bytes accessed", 0.0)),
            "wire": R.collective_wire_bytes(cp.as_text(), TP),
        }
        del cp, lw
    flops = R.extrapolate(metrics[1]["flops"], metrics[2]["flops"], L)
    bytes_ = R.extrapolate(metrics[1]["bytes"], metrics[2]["bytes"], L)
    wire_by_kind = {
        k: R.extrapolate(metrics[1]["wire"][k], metrics[2]["wire"][k], L)
        for k in metrics[1]["wire"]}
    terms = R.RooflineTerms(
        hlo_flops=flops, hlo_bytes=bytes_,
        wire_bytes=sum(wire_by_kind.values()), wire_by_kind=wire_by_kind,
        model_flops=R.model_flops(cfg, shape), n_devices=n_dev)
    rec["roofline"] = terms.as_dict()
    rec["total_s"] = round(time.perf_counter() - t0, 2)
    return rec


def iter_combos(archs, shapes, meshes):
    for arch in archs:
        if arch == "dlrm":          # paper's own arch: one training shape
            for mesh in meshes:
                yield arch, "train_65k", mesh
            continue
        for shape in shapes:
            if not C.supports_shape(arch, shape):
                continue
            for mesh in meshes:
                yield arch, shape, mesh


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default=None, choices=[None, "single", "multi"])
    ap.add_argument("--out", default="dryrun_results.json")
    ap.add_argument("--strategy", default="tp", choices=["tp", "fsdp"])
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--skip-metrics", action="store_true")
    ap.add_argument("--skip-done", action="store_true",
                    help="skip combos already in the output file")
    args = ap.parse_args()

    archs = [args.arch] if args.arch else list(C.ARCH_NAMES)
    shapes = [args.shape] if args.shape else list(INPUT_SHAPES)
    meshes = [args.mesh] if args.mesh else ["single", "multi"]

    try:
        results = json.load(open(args.out))
    except (FileNotFoundError, json.JSONDecodeError):
        results = []
    done = {(r["arch"], r["shape"], r["mesh"]) for r in results
            if r.get("status") == "ok"} if args.skip_done else set()

    for arch, shape, mesh in iter_combos(archs, shapes, meshes):
        if (arch, shape, mesh) in done:
            continue
        print(f"== {arch} x {shape} x {mesh} ==", flush=True)
        try:
            rec = run_combo(arch, shape, mesh,
                            skip_metrics=args.skip_metrics,
                            strategy=args.strategy,
                            n_microbatches=args.microbatches)
            rl = rec.get("roofline", {})
            print(f"   ok compile={rec['compile_s']}s "
                  f"peak={rec['peak_bytes_per_dev']/1e9:.2f}GB/dev "
                  f"dominant={rl.get('dominant', '-')}", flush=True)
        except Exception as e:
            rec = {"arch": arch, "shape": shape, "mesh": mesh,
                   "status": "error", "error": f"{type(e).__name__}: {e}",
                   "traceback": traceback.format_exc()[-2000:]}
            print(f"   ERROR {type(e).__name__}: {e}", flush=True)
        results = [r for r in results
                   if (r["arch"], r["shape"], r["mesh"]) != (arch, shape, mesh)]
        results.append(rec)
        json.dump(results, open(args.out, "w"), indent=1)
        jax.clear_caches()

    n_ok = sum(r.get("status") == "ok" for r in results)
    print(f"done: {n_ok}/{len(results)} combos ok")


if __name__ == "__main__":
    main()
