"""Roofline term extraction from compiled dry-run artifacts.

Terms per (arch, shape, mesh), all per-device on TPU v5e constants:

  compute_s    = HLO_FLOPs / peak_FLOPs          (197 TF bf16/chip)
  memory_s     = HLO_bytes / HBM_bw              (819 GB/s)
  collective_s = wire_bytes / link_bw            (~50 GB/s/link ICI)

`cost_analysis()` counts a `lax.scan` body once, so the driver compiles
unrolled 1-layer and 2-layer variants of the same step and extrapolates
metric(L) = m(1) + (L-1) * (m(2) - m(1)) -- exact for homogeneous stacks.
Collective wire bytes come from parsing the post-SPMD HLO text: every
all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute
result shape, converted to per-device ring-wire bytes via its
replica-group size.
"""

from __future__ import annotations

import dataclasses
import re


PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "tuple": 0, "token": 0,
}

_COLL_RE = re.compile(
    r"=\s*((?:\([^)]*\)|[a-z0-9]+\[[0-9,]*\])[^\s]*)\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(", re.M)
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_GROUPS_RE = re.compile(r"replica_groups=\{?\{([0-9, ]+)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_wire_bytes(hlo_text: str, default_group: int) -> dict:
    """Per-device ring wire bytes by collective kind, from HLO text."""
    out = {"all-gather": 0.0, "all-reduce": 0.0, "reduce-scatter": 0.0,
           "all-to-all": 0.0, "collective-permute": 0.0}
    for m in _COLL_RE.finditer(hlo_text):
        type_str, kind = m.group(1), m.group(2)
        size = _shape_bytes(type_str)
        tail = hlo_text[m.end():m.end() + 600]
        g = _GROUPS_RE.search(tail)
        gi = _GROUPS_IOTA_RE.search(tail)
        if g:
            D = len(g.group(1).split(","))
        elif gi:
            D = int(gi.group(2))
        else:
            D = default_group
        D = max(D, 1)
        frac = (D - 1) / D
        if kind == "all-gather":
            wire = size * frac                  # result = gathered full
        elif kind == "reduce-scatter":
            wire = size * D * frac              # result = scattered shard
        elif kind == "all-reduce":
            wire = 2 * size * frac
        elif kind == "all-to-all":
            wire = size * frac
        else:                                    # collective-permute
            wire = size
        out[kind] += wire
    return out


@dataclasses.dataclass
class RooflineTerms:
    hlo_flops: float            # per device
    hlo_bytes: float            # per device
    wire_bytes: float           # per device
    wire_by_kind: dict
    model_flops: float          # global analytic 6*N*D
    n_devices: int

    @property
    def compute_s(self):
        return self.hlo_flops / PEAK_FLOPS

    @property
    def memory_s(self):
        return self.hlo_bytes / HBM_BW

    @property
    def collective_s(self):
        return self.wire_bytes / ICI_BW

    @property
    def dominant(self):
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def useful_flops_ratio(self):
        global_hlo = self.hlo_flops * self.n_devices
        return self.model_flops / global_hlo if global_hlo else 0.0

    def as_dict(self):
        return {
            "hlo_flops_per_dev": self.hlo_flops,
            "hlo_bytes_per_dev": self.hlo_bytes,
            "wire_bytes_per_dev": self.wire_bytes,
            "wire_by_kind": self.wire_by_kind,
            "compute_s": self.compute_s,
            "memory_s": self.memory_s,
            "collective_s": self.collective_s,
            "dominant": self.dominant,
            "model_flops": self.model_flops,
            "useful_flops_ratio": self.useful_flops_ratio,
            "n_devices": self.n_devices,
        }


def extrapolate(m1: float, m2: float, n_layers: int) -> float:
    return m1 + (n_layers - 1) * (m2 - m1)


def model_flops(cfg, shape) -> float:
    """6*N*D (dense) / 6*N_active*D (MoE); decode processes B tokens."""
    n = cfg.active_param_count()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        mult = 6.0
    elif shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        mult = 2.0
    else:
        tokens = shape.global_batch
        mult = 2.0
    return mult * n * tokens
