"""Production mesh construction.

Functions (not module constants) so importing never touches jax device
state.  Single pod = 256 chips as (data=16, model=16); multi-pod = 2 pods
= 512 chips as (pod=2, data=16, model=16) with the pod axis folded into
data parallelism.
"""

from __future__ import annotations

import jax

from repro.models.sharding import ShardingRules


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes,
                         axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def production_rules(*, multi_pod: bool = False,
                     strategy: str = "tp") -> ShardingRules:
    """strategy: "tp" = 16-way tensor parallel x 16-way FSDP/data (default);
    "fsdp" = pure ZeRO-3 over all 256 chips, no tensor parallelism (wins
    when per-device batch is small and layers are fat -- see EXPERIMENTS.md
    §Perf it-4)."""
    if strategy == "fsdp":
        batch = (("pod", "data", "model") if multi_pod
                 else ("data", "model"))
        return ShardingRules(batch_axes=batch, model_axis=None,
                             fsdp_axes=("data", "model"))
    batch = ("pod", "data") if multi_pod else ("data",)
    return ShardingRules(batch_axes=batch, model_axis="model")


def make_mesh(shape, axes):
    """Arbitrary mesh for tests/experiments."""
    return jax.make_mesh(tuple(shape), tuple(axes),
                         axis_types=(jax.sharding.AxisType.Auto,) * len(axes))
