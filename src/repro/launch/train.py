"""Training launcher: pick an architecture config (``--arch``), an input
shape, and a mesh; runs real steps at reduced scale on CPU or lowers the
full production config (``--dryrun`` delegates to launch.dryrun).

  PYTHONPATH=src python -m repro.launch.train --arch qwen2.5-14b --smoke \
      --steps 10
"""

from __future__ import annotations

import argparse
import time

import numpy as np


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config, real steps on local devices")
    ap.add_argument("--steps", type=int, default=10)
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    args = ap.parse_args()

    import jax
    import jax.numpy as jnp
    from repro import configs as C
    from repro.launch import steps as ST

    cfg = (C.get_smoke(args.arch) if args.smoke
           else C.get_full(args.arch)).resolve(1)
    model = ST.build_model(cfg, remat=False, q_chunk=min(args.seq, 512),
                           kv_chunk=min(args.seq, 512))
    params = model.init_params(jax.random.PRNGKey(0))
    n_params = sum(int(np.prod(p.shape)) for p in jax.tree.leaves(params))
    print(f"arch={cfg.name} params={n_params / 1e6:.1f}M")

    opt, train_step = ST.make_train_step(model, lr=args.lr)
    opt_state = opt.init(params)
    step = jax.jit(train_step, donate_argnums=(0, 1))

    rng = np.random.default_rng(0)
    nf = cfg.n_frontend_tokens if cfg.frontend else 0
    B, S = args.batch, args.seq
    for i in range(args.steps):
        batch = {"tokens": jnp.asarray(
            rng.integers(0, cfg.vocab, (B, S - nf)), jnp.int32),
            "labels": jnp.asarray(rng.integers(0, cfg.vocab, (B, S)),
                                  jnp.int32),
            "loss_mask": jnp.ones((B, S), jnp.float32)}
        if nf:
            batch["embeds"] = jnp.asarray(
                rng.normal(0, 0.02, (B, nf, cfg.d_model)), jnp.bfloat16)
        t0 = time.perf_counter()
        params, opt_state, metrics = step(params, opt_state, batch)
        loss = float(metrics["loss"])
        print(f"step {i:3d} loss {loss:.4f} "
              f"({(time.perf_counter() - t0) * 1e3:.0f} ms)")
        assert np.isfinite(loss)


if __name__ == "__main__":
    main()
