"""Jitted step builders: train_step (loss + AdamW), prefill and decode
serve steps, with in/out shardings bound to a mesh.

These are the functions the multi-pod dry-run lowers and compiles for every
(architecture x input shape x mesh) combination, and the same functions the
CPU examples execute at reduced scale.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.shapes import InputShape, input_specs
from repro.models.config import ArchConfig
from repro.models.sharding import NO_SHARDING, ShardingRules
from repro.models.transformer import LM
from repro.optim import adamw, apply_updates


@dataclasses.dataclass
class StepBundle:
    model: LM
    train_step: Optional[object] = None
    prefill_step: Optional[object] = None
    decode_step: Optional[object] = None
    init_fn: Optional[object] = None


def build_model(cfg: ArchConfig, rules: ShardingRules = NO_SHARDING,
                remat: bool = True, q_chunk: int = 1024,
                kv_chunk: int = 1024, layer_loop: str = "scan") -> LM:
    return LM(cfg, rules=rules, remat=remat, q_chunk=q_chunk,
              kv_chunk=kv_chunk, layer_loop=layer_loop)


def make_train_step(model: LM, lr: float = 3e-4, weight_decay: float = 0.1,
                    moe_aux_weight: float = 0.01, n_microbatches: int = 1):
    """(params, opt_state, batch) -> (params, opt_state, metrics).

    With ``n_microbatches > 1`` the global batch is split and gradients
    accumulate across a `lax.scan` (one grads-sized f32 buffer); peak
    activation memory scales down with the microbatch count while the
    optimizer update and gradient reductions still happen once per step.
    """
    opt = adamw(lr, weight_decay=weight_decay)
    cfg = model.cfg

    def loss_fn(params, batch):
        loss, aux = model.forward_loss(
            params, batch["tokens"], batch["labels"],
            loss_mask=batch.get("loss_mask"), embeds=batch.get("embeds"))
        if cfg.moe:
            loss = loss + moe_aux_weight * aux
        return loss, aux

    def train_step(params, opt_state, batch):
        if n_microbatches == 1:
            (loss, aux), grads = jax.value_and_grad(loss_fn, has_aux=True)(
                params, batch)
        else:
            n = n_microbatches
            mb = jax.tree.map(
                lambda a: a.reshape(n, a.shape[0] // n, *a.shape[1:]), batch)

            def acc(carry, b):
                g_acc, l_acc, a_acc = carry
                (loss_b, a), g = jax.value_and_grad(loss_fn, has_aux=True)(
                    params, b)
                g_acc = jax.tree.map(
                    lambda ga, gi: ga + gi.astype(ga.dtype), g_acc, g)
                return (g_acc, l_acc + loss_b, a_acc + a), None

            # f32 accumulator for <=4 microbatches; bf16 beyond (the f32
            # param-scale buffer dominates temp memory at high counts)
            acc_dt = jnp.float32 if n <= 4 else jnp.bfloat16
            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, acc_dt), params)
            (grads, loss, aux), _ = jax.lax.scan(acc, (zeros, 0.0, 0.0), mb)
            grads = jax.tree.map(lambda g, p: (g / n).astype(p.dtype),
                                 grads, params)
            loss, aux = loss / n, aux / n
        updates, opt_state = opt.update(grads, opt_state, params)
        params = apply_updates(params, updates)
        return params, opt_state, {"loss": loss, "moe_aux": aux}

    return opt, train_step


def make_prefill_step(model: LM, capacity: int | None = None):
    def prefill_step(params, batch):
        logits, cache = model.prefill(params, tokens=batch["tokens"],
                                      embeds=batch.get("embeds"),
                                      capacity=capacity)
        return logits, cache
    return prefill_step


def make_decode_step(model: LM):
    def decode_step(params, cache, batch):
        return model.decode_step(params, cache, batch["tokens"])
    return decode_step


# ---- sharded AOT lowering (used by the dry-run and real launches) --------------

def _ns(mesh, spec_tree):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda s: isinstance(s, P))


def _mirror_specs(state_shape, param_specs):
    """Map param specs onto any state pytree whose leaves mirror params."""
    flat_specs, _ = jax.tree.flatten(
        param_specs, is_leaf=lambda s: isinstance(s, P))

    def assign(leaf):
        # scalars (step counters) replicate; tensors mirror params by shape
        return P() if getattr(leaf, "ndim", 0) == 0 else None

    leaves, treedef = jax.tree.flatten(state_shape)
    specs = []
    # params appear repeatedly (m, v); cycle through param specs by shape
    pool = list(flat_specs)
    pi = 0
    for leaf in leaves:
        if getattr(leaf, "ndim", 0) == 0:
            specs.append(P())
        else:
            specs.append(pool[pi % len(pool)])
            pi += 1
    return jax.tree.unflatten(treedef, specs)


def lower_train(cfg: ArchConfig, shape: InputShape, mesh,
                rules: ShardingRules, lr: float = 3e-4,
                q_chunk: int = 1024, kv_chunk: int = 1024,
                layer_loop: str = "scan", remat: bool = True,
                n_microbatches: int = 1):
    """AOT-lower a full sharded train step from ShapeDtypeStructs."""
    rules = rules.for_batch(shape.global_batch, mesh)
    model = build_model(cfg, rules, remat=remat, q_chunk=q_chunk,
                        kv_chunk=kv_chunk, layer_loop=layer_loop)
    opt, train_step = make_train_step(model, lr=lr,
                                      n_microbatches=n_microbatches)
    aparams = model.abstract_params()
    pspecs = model.param_specs()
    astate = jax.eval_shape(opt.init, aparams)
    sspecs = _mirror_specs(astate, pspecs)
    batch = input_specs(cfg, shape)
    bspecs = {k: rules.spec("batch", *([None] * (len(v.shape) - 1)))
              for k, v in batch.items()}
    in_sh = (_ns(mesh, pspecs), _ns(mesh, sspecs), _ns(mesh, bspecs))
    out_sh = (_ns(mesh, pspecs), _ns(mesh, sspecs),
              {"loss": NamedSharding(mesh, P()),
               "moe_aux": NamedSharding(mesh, P())})
    fn = jax.jit(train_step, in_shardings=in_sh, out_shardings=out_sh,
                 donate_argnums=(0, 1))
    return fn.lower(aparams, astate, batch), model


def lower_prefill(cfg: ArchConfig, shape: InputShape, mesh,
                  rules: ShardingRules, q_chunk: int = 1024,
                  kv_chunk: int = 1024, layer_loop: str = "scan",
                  remat: bool = True):
    rules = rules.for_batch(shape.global_batch, mesh)
    model = build_model(cfg, rules, remat=remat, q_chunk=q_chunk,
                        kv_chunk=kv_chunk, layer_loop=layer_loop)
    model.embed_onehot = False          # inference: plain gather embed
    step = make_prefill_step(model, capacity=shape.seq_len)
    aparams = model.abstract_params()
    pspecs = model.param_specs()
    batch = input_specs(cfg, shape)
    bspecs = {k: rules.spec("batch", *([None] * (len(v.shape) - 1)))
              for k, v in batch.items()}
    cspecs = model.cache_specs(rules)
    in_sh = (_ns(mesh, pspecs), _ns(mesh, bspecs))
    out_sh = (NamedSharding(mesh, rules.spec("batch", None, "model")),
              _ns(mesh, cspecs))
    fn = jax.jit(step, in_shardings=in_sh, out_shardings=out_sh)
    return fn.lower(aparams, batch), model


def lower_decode(cfg: ArchConfig, shape: InputShape, mesh,
                 rules: ShardingRules, window_capacity: int | None = None,
                 layer_loop: str = "scan"):
    """serve_step: ONE new token against a seq_len KV cache."""
    rules = rules.for_batch(shape.global_batch, mesh)
    model = build_model(cfg, rules, remat=False, layer_loop=layer_loop)
    model.embed_onehot = False          # inference: plain gather embed
    step = make_decode_step(model)
    aparams = model.abstract_params()
    pspecs = model.param_specs()
    capacity = window_capacity or shape.seq_len
    acache = jax.eval_shape(
        lambda: model.init_cache(shape.global_batch, capacity))
    cspecs = model.cache_specs(rules)
    batch = input_specs(cfg, shape)
    bspecs = {k: rules.spec("batch", *([None] * (len(v.shape) - 1)))
              for k, v in batch.items()}
    in_sh = (_ns(mesh, pspecs), _ns(mesh, cspecs), _ns(mesh, bspecs))
    out_sh = (NamedSharding(mesh, rules.spec("batch", None, "model")),
              _ns(mesh, cspecs))
    fn = jax.jit(step, in_shardings=in_sh, out_shardings=out_sh,
                 donate_argnums=(1,))
    return fn.lower(aparams, acache, batch), model
