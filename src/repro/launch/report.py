"""Format dryrun_results.json into the EXPERIMENTS.md §Dry-run / §Roofline
markdown tables.

  PYTHONPATH=src python -m repro.launch.report dryrun_results.json
"""

from __future__ import annotations

import json
import sys


def fmt_bytes(b):
    return f"{b / 1e9:.2f}GB"


def fmt_s(s):
    if s >= 1:
        return f"{s:.2f}s"
    return f"{s * 1e3:.1f}ms"


def dryrun_table(results):
    rows = ["| arch | shape | mesh | devices | compile | peak/dev | fits 16GB |",
            "|---|---|---|---|---|---|---|"]
    for r in sorted(results, key=lambda r: (r["arch"], r["shape"], r["mesh"])):
        if r.get("status") != "ok":
            rows.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | - | "
                        f"ERROR | - | - |")
            continue
        rows.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | {r['n_devices']} "
            f"| {r['compile_s']}s | {fmt_bytes(r['peak_bytes_per_dev'])} "
            f"| {'yes' if r['fits_16gb_hbm'] else 'NO'} |")
    return "\n".join(rows)


def roofline_table(results):
    rows = ["| arch | shape | compute | memory | collective | dominant | "
            "MODEL_FLOPS | useful ratio |",
            "|---|---|---|---|---|---|---|---|"]
    for r in sorted(results, key=lambda r: (r["arch"], r["shape"])):
        if r.get("status") != "ok" or "roofline" not in r:
            continue
        if r["mesh"] != "single":          # roofline table is single-pod
            continue
        rl = r["roofline"]
        rows.append(
            f"| {r['arch']} | {r['shape']} | {fmt_s(rl['compute_s'])} "
            f"| {fmt_s(rl['memory_s'])} | {fmt_s(rl['collective_s'])} "
            f"| **{rl['dominant']}** | {rl['model_flops']:.2e} "
            f"| {rl['useful_flops_ratio']:.2f} |")
    return "\n".join(rows)


def wire_breakdown(results):
    rows = ["| arch | shape | all-gather | all-reduce | reduce-scatter | "
            "all-to-all | permute |",
            "|---|---|---|---|---|---|---|"]
    for r in sorted(results, key=lambda r: (r["arch"], r["shape"])):
        if r.get("status") != "ok" or "roofline" not in r:
            continue
        if r["mesh"] != "single":
            continue
        w = r["roofline"]["wire_by_kind"]
        rows.append(
            f"| {r['arch']} | {r['shape']} | {fmt_bytes(w['all-gather'])} "
            f"| {fmt_bytes(w['all-reduce'])} "
            f"| {fmt_bytes(w['reduce-scatter'])} "
            f"| {fmt_bytes(w['all-to-all'])} "
            f"| {fmt_bytes(w['collective-permute'])} |")
    return "\n".join(rows)


def main():
    path = sys.argv[1] if len(sys.argv) > 1 else "dryrun_results.json"
    results = json.load(open(path))
    n_ok = sum(r.get("status") == "ok" for r in results)
    print(f"### Dry-run ({n_ok}/{len(results)} combos ok)\n")
    print(dryrun_table(results))
    print("\n### Roofline (single-pod, per device, TPU v5e constants)\n")
    print(roofline_table(results))
    print("\n### Collective wire bytes per device (single-pod)\n")
    print(wire_breakdown(results))


if __name__ == "__main__":
    main()
