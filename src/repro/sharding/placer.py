"""``ShardingPlacer``: column-split tables so infeasible tasks place.

A whole-table placer cannot place a task whose largest table exceeds one
device's HBM -- every assignment is illegal.  ``ShardingPlacer`` wraps
any inner placer (expert by default) and post-processes its proposal:
tables whose footprint exceeds ``headroom * mem_capacity_gb`` split
column-wise into K near-even ranges (K chosen so each shard fits), the
``split_hottest`` highest-traffic tables optionally split in two for
load spreading, and the resulting shards pack greedily
(tightest-fit-decreasing, a table's shards on distinct devices).  When
nothing needs splitting and the inner proposal is legal, the inner
placement comes back relabeled -- the K = 1 path stays the legacy path.

``refine_sharded`` adds the anytime loop on top: shard-move/swap
neighborhoods via ``SearchPlacer`` (lns/evolution operate on shard rows
unchanged) interleaved with split/merge mutations of the spec itself.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro import telemetry as tele
from repro.api.oracle import (ensure_oracle, evaluate_sharded, legal_batch,
                              legal_sharded)
from repro.api.placement import BasePlacer, Placement, Placer
from repro.core import features as F
from repro.core.baselines import expert_place
from repro.data.tasks import Task
from repro.search.placer import SearchConfig, SearchPlacer
from repro.sharding.spec import (ShardSpec, project_assignment,
                                 shard_sizes_gb)


@dataclasses.dataclass(frozen=True)
class ShardingConfig:
    """Knobs for ``ShardingPlacer``.

    ``headroom`` is the fill fraction targeted when sizing K (a 10 GB
    table on 11 GB devices at 0.9 headroom splits into 2, not 1, so the
    shard leaves room for co-residents).  ``split_hottest`` additionally
    splits that many highest-traffic (``dim * pooling``) tables in two
    even when they fit.  ``max_retries`` bounds the split-and-repack
    rounds when greedy packing still comes back illegal.  ``refine``
    (a ``SearchConfig``) turns on shard-move search over the packed
    assignment; its ``"beam"`` stage is whole-table only and rejected.
    """

    headroom: float = 0.9
    split_hottest: int = 0
    max_retries: int = 8
    refine: SearchConfig | None = None

    def __post_init__(self):
        if not 0.0 < self.headroom <= 1.0:
            raise ValueError(f"headroom must be in (0, 1], "
                             f"got {self.headroom}")
        if self.refine is not None and "beam" in self.refine.stages():
            raise ValueError("ShardingConfig.refine cannot use the 'beam' "
                             "stage (whole-table only); use lns/evolution")


def pack_shards(raw: np.ndarray, spec: ShardSpec, n_devices: int,
                capacity_gb: float,
                table_seed: np.ndarray | None = None) -> np.ndarray:
    """Greedy tightest-fit-decreasing packing of a spec's shards.

    Shards go largest-first onto the most-loaded device that still fits
    them (classic best-fit: preserves large holes for large shards), a
    table's shards always on DISTINCT devices.  Unsplit (K = 1) tables
    keep ``table_seed``'s device when it fits, so a legal inner proposal
    survives the post-processing wherever possible.  Always returns a
    complete ``(S,)`` assignment; when the task genuinely does not fit
    the overflow lands on the least-loaded device (illegal, best-effort,
    detectable via ``legal_sharded``).
    """
    raw = np.asarray(raw, dtype=np.float64)
    sizes = shard_sizes_gb(raw, spec)
    counts = spec.shard_counts
    mem = np.zeros(n_devices)
    out = np.full(spec.n_shards, -1, np.int64)
    for s in np.argsort(-sizes, kind="stable"):
        t = int(spec.table[s])
        siblings = out[spec.table == t]
        used = set(int(d) for d in siblings[siblings >= 0])
        free = np.array([d for d in range(n_devices) if d not in used],
                        np.int64)
        if free.size == 0:                 # K > n_devices shouldn't happen,
            free = np.arange(n_devices)    # but never leave a shard unplaced
        fits = free[mem[free] + sizes[s] <= capacity_gb]
        pick = None
        if table_seed is not None and counts[t] == 1:
            d0 = int(table_seed[t])
            if d0 in fits:
                pick = d0
        if pick is None and fits.size:
            pick = int(fits[np.argmax(mem[fits])])       # tightest fit
        if pick is None:
            pick = int(free[np.argmin(mem[free])])       # overflow fallback
        out[s] = pick
        mem[pick] += sizes[s]
    return out


def _shard_limit(raw: np.ndarray, n_devices: int) -> np.ndarray:
    """Max K per table: can't exceed the column count, and siblings live
    on distinct devices so K <= n_devices."""
    dims = np.asarray(raw, np.float64)[:, F.DIM].astype(np.int64)
    return np.minimum(np.maximum(dims, 1), n_devices)


def _grow_spec(raw: np.ndarray, spec: ShardSpec,
               n_devices: int) -> ShardSpec | None:
    """Split the table owning the largest still-growable shard one step
    further (the move most likely to fix an illegal packing), or None
    when every table is at its shard limit."""
    sizes = shard_sizes_gb(raw, spec)
    k = spec.shard_counts
    limit = _shard_limit(raw, n_devices)
    growable = k[spec.table] < limit[spec.table]
    if not growable.any():
        return None
    s = int(np.flatnonzero(growable)[np.argmax(sizes[growable])])
    return spec.split(int(spec.table[s]))


class ShardingPlacer(BasePlacer):
    """Wrap any whole-table placer with column-wise sharding.

    ``inner=None`` seeds from the greedy size-balance expert.  The
    wrapped proposal is returned untouched (relabeled) when no table
    needs splitting and it is already legal; otherwise oversized /
    hottest tables split, shards repack, and packing retries with
    progressively finer splits until legal or out of retries.
    """

    def __init__(self, oracle, inner: Placer | None = None,
                 config: ShardingConfig | None = None):
        self.oracle = ensure_oracle(oracle)
        self.inner = inner
        self.config = config if config is not None else ShardingConfig()
        inner_name = inner.name if inner is not None else "expert"
        self.name = f"sharding({inner_name})"

    # ---- spec sizing --------------------------------------------------------

    def required_spec(self, task: Task) -> ShardSpec:
        """The split this placer would apply to a task: K =
        ceil(size / (headroom * capacity)) per table (1 for tables that
        fit), plus the ``split_hottest`` traffic leaders at K >= 2,
        clamped to each table's shard limit."""
        raw = np.asarray(task.raw_features, dtype=np.float64)
        cfg = self.config
        budget = max(self.oracle.mem_capacity_gb * cfg.headroom, 1e-12)
        k = np.ceil(raw[:, F.TABLE_SIZE_GB] / budget).astype(np.int64)
        k = np.maximum(k, 1)
        if cfg.split_hottest > 0:
            traffic = raw[:, F.DIM] * raw[:, F.POOLING]
            hot = np.argsort(-traffic, kind="stable")[:cfg.split_hottest]
            k[hot] = np.maximum(k[hot], 2)
        return ShardSpec.even(raw, np.minimum(
            k, _shard_limit(raw, task.n_devices)))

    # ---- placement ----------------------------------------------------------

    def _seed(self, task: Task) -> Placement:
        if self.inner is not None:
            return self.inner.place(task)
        a = expert_place(task.raw_features, task.n_devices,
                         self.oracle.mem_capacity_gb, "size")
        return self._wrap(task, a)

    def place(self, task: Task) -> Placement:
        with tele.span("sharding.place", M=task.n_tables,
                       n_devices=task.n_devices) as sp:
            out = self._place_impl(task)
            sp.set(n_shards=out.n_shards, sharded=out.is_sharded)
            return out

    def _place_impl(self, task: Task) -> Placement:
        raw = np.asarray(task.raw_features, dtype=np.float64)
        seed = self._seed(task)
        seed_a = np.asarray(seed.assignment, dtype=np.int64)
        spec = self.required_spec(task)
        if spec.is_trivial and bool(legal_batch(
                self.oracle, raw, seed_a[None], task.n_devices)[0]):
            return dataclasses.replace(seed, strategy=self.name)

        cap = self.oracle.mem_capacity_gb
        shard_a = pack_shards(raw, spec, task.n_devices, cap,
                              table_seed=seed_a)
        retries = 0
        while retries < self.config.max_retries and not bool(legal_sharded(
                self.oracle, raw, spec, shard_a[None], task.n_devices)[0]):
            finer = _grow_spec(raw, spec, task.n_devices)
            if finer is None:
                break                       # at the shard limit everywhere
            spec, retries = finer, retries + 1
            shard_a = pack_shards(raw, spec, task.n_devices, cap,
                                  table_seed=seed_a)
        tele.count("sharding.pack_retries", retries)

        hw0 = self.oracle.num_evaluations
        res = evaluate_sharded(self.oracle, raw, spec, shard_a[None],
                               task.n_devices)
        placement = self._wrap(
            task, shard_a, est_cost_ms=float(res[0].overall),
            candidates=seed.candidates + retries + 1,
            oracle_evals=seed.oracle_evals
            + (self.oracle.num_evaluations - hw0),
            sharding=spec)
        if self.config.refine is not None:
            searcher = SearchPlacer(self.oracle, config=self.config.refine,
                                    name=self.name)
            placement = searcher.refine(task, placement)
        return placement


def refine_sharded(oracle, task: Task, placement: Placement,
                   config: SearchConfig | None = None, *,
                   split_rounds: int = 2) -> Placement:
    """Anytime refinement over shard assignment AND split structure.

    Alternates ``SearchPlacer`` shard-move/swap search (lns/evolution on
    the ``(S,)`` rows) with split/merge mutations of the spec: each
    round proposes splitting the largest growable shard's table and
    merging the smallest split table, repacks, re-searches, and adopts a
    mutation only when it is strictly better (legality first, then
    cost).  A whole-table seed enters as the trivial (K = 1) spec, so
    this also upgrades legacy placements in place.
    """
    oracle = ensure_oracle(oracle)
    cfg = config if config is not None else SearchConfig()
    searcher = SearchPlacer(oracle, config=cfg,
                            name=f"refine_sharded[{cfg.strategy}]")
    raw = np.asarray(task.raw_features, dtype=np.float64)
    if placement.sharding is None:
        placement = searcher._wrap(
            task, np.asarray(placement.assignment, np.int64),
            est_cost_ms=placement.est_cost_ms,
            candidates=placement.candidates,
            oracle_evals=placement.oracle_evals,
            sharding=ShardSpec.trivial(raw))

    def measure(p: Placement) -> tuple[bool, float]:
        legal = bool(legal_sharded(oracle, raw, p.sharding,
                                   p.shard_assignment[None],
                                   task.n_devices)[0])
        res = evaluate_sharded(oracle, raw, p.sharding,
                               p.shard_assignment[None], task.n_devices)
        return legal, float(res[0].overall)

    best = searcher.refine(task, placement)
    best_legal, best_cost = measure(best)
    cap = oracle.mem_capacity_gb
    for _ in range(max(0, split_rounds)):
        spec = best.sharding
        candidates: list[ShardSpec] = []
        finer = _grow_spec(raw, spec, task.n_devices)
        if finer is not None:
            candidates.append(finer)
        split_tables = np.flatnonzero(spec.shard_counts > 1)
        if split_tables.size:
            t = int(split_tables[np.argmin(
                raw[split_tables, F.TABLE_SIZE_GB])])
            candidates.append(spec.merge(t))
        improved = False
        seed_tables = project_assignment(spec, best.shard_assignment)
        for cand_spec in candidates:
            a = pack_shards(raw, cand_spec, task.n_devices, cap,
                            table_seed=seed_tables)
            cand = searcher.refine(task, searcher._wrap(
                task, a, sharding=cand_spec))
            cand_legal, cand_cost = measure(cand)
            if (cand_legal, -cand_cost) > (best_legal, -best_cost):
                best, best_legal, best_cost = cand, cand_legal, cand_cost
                improved = True
        if not improved:
            break
    tele.count("sharding.refine_calls", 1)
    return best
