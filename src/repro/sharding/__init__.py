"""Column-wise table sharding: spec schema, feature expansion, and the
``ShardingPlacer`` wrapper that makes oversized tables placeable.

``repro.sharding.spec`` is dependency-light (numpy + the feature schema
only) so the sim / oracle / digest layers can import it without cycles;
``repro.sharding.placer`` sits on top of ``repro.api`` and is therefore
re-exported lazily here (and from ``repro.api``).
"""

from repro.sharding.spec import (ShardSpec, project_assignment,
                                 shard_features, shard_sizes_gb)

_LAZY = {
    "ShardingPlacer": "repro.sharding.placer",
    "ShardingConfig": "repro.sharding.placer",
    "refine_sharded": "repro.sharding.placer",
}

__all__ = ["ShardSpec", "shard_features", "shard_sizes_gb",
           "project_assignment", *sorted(_LAZY)]


def __getattr__(name):
    mod = _LAZY.get(name)
    if mod is None:
        raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
    import importlib
    return getattr(importlib.import_module(mod), name)


def __dir__():
    return sorted(__all__)
