"""Column-wise table sharding: the ``ShardSpec`` schema and the
expanded-features transform every shard-aware consumer shares.

A ``ShardSpec`` describes how each of a task's M tables splits into K >= 1
contiguous column ranges ("shards").  The whole stack prices and places
shards through ONE transform: ``shard_features`` expands the task's
``(M, 21)`` raw feature matrix into an ``(S, 21)`` per-shard matrix where
each shard inherits its owner's row count / pooling / access histogram,
its ``dim`` becomes the column width, and its ``table_size_gb`` scales by
``width / dim``.  A shard then *is* a table as far as the cost models,
legality checks, digests, and caches are concerned -- the sharded problem
reduces to the whole-table problem over S pseudo-tables, and every
batched path (``evaluate_many`` / ``legal_batch`` / key machinery) works
unchanged on ``(P, S)`` shard-assignment matrices.

The K = 1 guarantee: a trivial spec (every table one shard spanning
``[0, dim)``) expands to the raw feature matrix BYTE-IDENTICALLY
(``width / dim == 1.0`` exactly in float64), so costs, noise digests,
cache keys, and legality verdicts are bitwise what the legacy whole-table
path produces.  Nothing special-cases K = 1 downstream; identity falls
out of the bytes.

Specs are canonical by construction (shards sorted by owning table, then
by ``col_start``; ranges tile ``[0, dim)`` exactly), so equal shardings
serialize to equal bytes -- the property the digest stability tests pin.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.core import features as F


@dataclasses.dataclass(frozen=True)
class ShardSpec:
    """Column ranges for every shard of a task's tables (canonical form).

    ``table[s]`` is shard ``s``'s owning table; ``col_start[s]:col_end[s]``
    is the half-open column range it carries.  Shards are ordered by
    ``(table, col_start)``, each table owns at least one shard, and a
    table's shards tile ``[0, dim)`` contiguously -- validated against the
    ``dims`` recorded at construction.
    """

    table: np.ndarray       # (S,) shard -> owning table id
    col_start: np.ndarray   # (S,) first column (inclusive)
    col_end: np.ndarray     # (S,) last column (exclusive)
    dims: np.ndarray        # (M,) full column count per table

    def __post_init__(self):
        t = np.ascontiguousarray(np.asarray(self.table, np.int64))
        cs = np.ascontiguousarray(np.asarray(self.col_start, np.int64))
        ce = np.ascontiguousarray(np.asarray(self.col_end, np.int64))
        d = np.ascontiguousarray(np.asarray(self.dims, np.int64))
        object.__setattr__(self, "table", t)
        object.__setattr__(self, "col_start", cs)
        object.__setattr__(self, "col_end", ce)
        object.__setattr__(self, "dims", d)
        M = d.shape[0]
        if t.shape != cs.shape or t.shape != ce.shape or t.ndim != 1:
            raise ValueError("table/col_start/col_end must be 1-D and "
                             "equal length")
        if t.size < M or (np.diff(t) < 0).any():
            raise ValueError("shards must be sorted by owning table and "
                             "cover every table")
        if t.size and (t[0] != 0 or t[-1] != M - 1
                       or np.setdiff1d(np.arange(M), t).size):
            raise ValueError(f"shards must cover tables 0..{M - 1}, "
                             f"got owners {np.unique(t)}")
        if (ce <= cs).any():
            raise ValueError("every shard needs a positive column width")
        # per-table tiling: first shard starts at 0, ranges are contiguous
        # (next col_start == previous col_end), last shard ends at dim
        first = np.concatenate([[True], np.diff(t) > 0]) if t.size \
            else np.zeros(0, bool)
        if (cs[first] != 0).any():
            raise ValueError("each table's first shard must start at col 0")
        same = ~first[1:] if t.size > 1 else np.zeros(0, bool)
        if (cs[1:][same] != ce[:-1][same]).any():
            raise ValueError("a table's shards must be contiguous "
                             "(col_start == previous col_end)")
        last = np.concatenate([first[1:], [True]]) if t.size else first
        if (ce[last] != d[t[last]]).any():
            raise ValueError("each table's last shard must end at its dim")

    # ---- views --------------------------------------------------------------

    @property
    def n_shards(self) -> int:
        return self.table.shape[0]

    @property
    def n_tables(self) -> int:
        return self.dims.shape[0]

    @property
    def widths(self) -> np.ndarray:
        """Column width per shard ``(S,)``."""
        return self.col_end - self.col_start

    @property
    def shard_counts(self) -> np.ndarray:
        """K per table ``(M,)``."""
        return np.bincount(self.table, minlength=self.n_tables)

    @property
    def first_shard(self) -> np.ndarray:
        """Index of each table's first shard ``(M,)`` (the shard whose
        device the legacy ``(M,)`` assignment projection reports)."""
        counts = self.shard_counts
        return np.concatenate([[0], np.cumsum(counts)[:-1]])

    @property
    def is_trivial(self) -> bool:
        """True when every table is whole (K = 1 everywhere) -- the case
        whose expansion is byte-identical to the raw features."""
        return self.n_shards == self.n_tables

    def to_bytes(self) -> bytes:
        """Canonical serialization (specs are canonical, so equal
        shardings -- same split points -- give equal bytes)."""
        return (self.table.tobytes() + self.col_start.tobytes()
                + self.col_end.tobytes() + self.dims.tobytes())

    # ---- construction -------------------------------------------------------

    @classmethod
    def trivial(cls, raw: np.ndarray) -> "ShardSpec":
        """One whole-table shard per table (the K = 1 identity spec)."""
        dims = np.asarray(raw, np.float64)[:, F.DIM].astype(np.int64)
        M = dims.shape[0]
        return cls(table=np.arange(M), col_start=np.zeros(M, np.int64),
                   col_end=dims, dims=dims)

    @classmethod
    def even(cls, raw: np.ndarray, k) -> "ShardSpec":
        """Split table ``t`` into ``k[t]`` near-equal contiguous column
        ranges (``k`` scalar or ``(M,)``; clamped to ``[1, dim]``)."""
        dims = np.asarray(raw, np.float64)[:, F.DIM].astype(np.int64)
        M = dims.shape[0]
        k = np.broadcast_to(np.asarray(k, np.int64), (M,))
        k = np.clip(k, 1, np.maximum(dims, 1))
        table, cs, ce = [], [], []
        for t in range(M):
            # deterministic near-even split via truncated linspace bounds
            bounds = np.linspace(0, dims[t], k[t] + 1).astype(np.int64)
            table.extend([t] * int(k[t]))
            cs.extend(bounds[:-1].tolist())
            ce.extend(bounds[1:].tolist())
        return cls(table=np.asarray(table, np.int64),
                   col_start=np.asarray(cs, np.int64),
                   col_end=np.asarray(ce, np.int64), dims=dims)

    def split(self, t: int) -> "ShardSpec":
        """One more shard for table ``t``: re-split it evenly into K + 1
        parts (no-op spec copy when already at ``dim`` shards)."""
        k = self.shard_counts.copy()
        if k[t] < self.dims[t]:
            k[t] += 1
        return self._resplit(k)

    def merge(self, t: int) -> "ShardSpec":
        """One fewer shard for table ``t`` (even re-split; no-op at 1)."""
        k = self.shard_counts.copy()
        if k[t] > 1:
            k[t] -= 1
        return self._resplit(k)

    def _resplit(self, k: np.ndarray) -> "ShardSpec":
        raw_like = np.zeros((self.n_tables, F.NUM_FEATURES))
        raw_like[:, F.DIM] = self.dims
        return ShardSpec.even(raw_like, k)


def shard_features(raw: np.ndarray, spec: ShardSpec) -> np.ndarray:
    """Expand ``(M, 21)`` raw table features into ``(S, 21)`` per-shard
    features -- THE transform behind every shard-aware code path.

    Each shard copies its owner's row (same hash size, pooling, access
    histogram: a column slice sees the identical index stream), with
    ``dim`` replaced by the column width and ``table_size_gb`` scaled by
    ``width / dim``.  Two shards of one table co-resident on a device
    then correctly occupy disjoint cache/memory bytes, and the simulator's
    cache-hit curve sees each shard's own (smaller) working set.

    For a trivial spec the result is byte-identical to
    ``np.asarray(raw, float64)`` (``width / dim == 1.0`` exactly), which
    is what makes K = 1 sharded costs, noise digests, and cache keys
    bitwise-equal to the legacy whole-table path.
    """
    raw = np.ascontiguousarray(np.asarray(raw, dtype=np.float64))
    if raw.shape[0] != spec.n_tables:
        raise ValueError(f"spec covers {spec.n_tables} tables, raw has "
                         f"{raw.shape[0]}")
    if spec.is_trivial:
        return raw
    out = raw[spec.table].copy()
    width = spec.widths.astype(np.float64)
    frac = width / raw[spec.table, F.DIM]
    out[:, F.DIM] = width
    out[:, F.TABLE_SIZE_GB] *= frac
    return np.ascontiguousarray(out)


def shard_sizes_gb(raw: np.ndarray, spec: ShardSpec) -> np.ndarray:
    """Memory footprint per shard ``(S,)`` -- what per-device legality
    sums.  A table's shard sizes sum to its ``table_size_gb`` (up to
    float rounding of the width fractions)."""
    return shard_features(raw, spec)[:, F.TABLE_SIZE_GB]


def project_assignment(spec: ShardSpec,
                       shard_assignment: np.ndarray) -> np.ndarray:
    """Legacy ``(M,)`` view of a ``(S,)`` shard assignment: each table
    reports its FIRST shard's device (exact for K = 1 tables; a
    documented projection for split ones)."""
    a = np.asarray(shard_assignment, dtype=np.int64)
    return a[..., spec.first_shard]
