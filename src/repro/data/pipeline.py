"""Training data pipelines: deterministic, seekable synthetic streams for
LM and DLRM training, with background host prefetch.

Production input pipelines are keyed by (shard, step) so any step is
reproducible and restartable from a checkpointed step counter -- the same
property is kept here: `batch_at(step)` is a pure function of (seed, step).
"""

from __future__ import annotations

import queue
import threading

import numpy as np

from repro.core import features as F


class LMBatchStream:
    """Synthetic token batches with a zipf unigram distribution.

    Yields dicts matching ``configs.shapes.input_specs`` for train shapes.
    """

    def __init__(self, vocab: int, batch: int, seq: int,
                 n_frontend_tokens: int = 0, d_model: int = 0,
                 seed: int = 0, zipf_a: float = 1.3):
        self.vocab = vocab
        self.batch = batch
        self.seq = seq
        self.nf = n_frontend_tokens
        self.d_model = d_model
        self.seed = seed
        self.zipf_a = zipf_a

    def batch_at(self, step: int) -> dict:
        rng = np.random.default_rng((self.seed, step))
        n_text = self.seq - self.nf
        tokens = rng.zipf(self.zipf_a, size=(self.batch, n_text + 1))
        tokens = (tokens % self.vocab).astype(np.int32)
        out = {
            "tokens": tokens[:, :-1],
            # next-token labels over the full stream (frontend positions
            # are masked out)
            "labels": np.concatenate(
                [np.zeros((self.batch, self.nf), np.int32),
                 tokens[:, 1:]], axis=1),
            "loss_mask": np.concatenate(
                [np.zeros((self.batch, self.nf), np.float32),
                 np.ones((self.batch, n_text), np.float32)], axis=1),
        }
        if self.nf:
            out["embeds"] = rng.normal(
                0, 0.02, (self.batch, self.nf, self.d_model)
            ).astype(np.float32)
        return out

    def __iter__(self):
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1


class DLRMBatchStream:
    """Synthetic CTR batches for a table pool (indices + dense + label)."""

    def __init__(self, raw_features: np.ndarray, batch: int,
                 n_dense: int = 13, pool_slots: int = 16, seed: int = 0):
        self.raw = raw_features
        self.batch = batch
        self.n_dense = n_dense
        self.pool_slots = pool_slots
        self.seed = seed
        self.hashes = raw_features[:, F.HASH_SIZE].astype(np.int64)
        self.pools = np.minimum(
            raw_features[:, F.POOLING].astype(np.int64) + 1, pool_slots)

    def batch_at(self, step: int) -> dict:
        rng = np.random.default_rng((self.seed, step))
        M = self.raw.shape[0]
        idx = np.full((self.batch, M, self.pool_slots), -1, np.int32)
        for t in range(M):
            draws = rng.zipf(1.5, size=(self.batch, self.pools[t]))
            idx[:, t, :self.pools[t]] = (draws % self.hashes[t]).astype(
                np.int32)
        return {
            "indices": idx,
            "dense": rng.normal(size=(self.batch, self.n_dense)).astype(
                np.float32),
            "labels": (rng.random(self.batch) < 0.3).astype(np.float32),
        }


class Prefetcher:
    """Background-thread host prefetch over any `batch_at(step)` stream."""

    def __init__(self, stream, depth: int = 2, start_step: int = 0):
        self.stream = stream
        self._q = queue.Queue(maxsize=depth)
        self._stop = threading.Event()
        self._step = start_step
        self._thread = threading.Thread(target=self._fill, daemon=True)
        self._thread.start()

    def _fill(self):
        step = self._step
        while not self._stop.is_set():
            try:
                self._q.put(self.stream.batch_at(step), timeout=0.2)
                step += 1
            except queue.Full:
                continue

    def next(self):
        return self._q.get()

    def close(self):
        self._stop.set()
        self._thread.join(timeout=2)
