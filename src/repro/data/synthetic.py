"""Synthetic table pools matching the DLRM dataset statistics (App. C).

The open-sourced DLRM dataset has 856 tables with hash sizes around 1e6 (up
to ~1e7, Fig 15), power-law pooling factors with mean ~15 (Fig 16, up to
~200), fixed dim 16 (App. C.3), and heavy-tailed index access frequencies
(Fig 18).  The `prod` pool mimics the paper's production workload: same
scale but diverse dims in [4, 768].

Pools are (M, 21) raw feature matrices (see ``repro.core.features``).
"""

from __future__ import annotations

import numpy as np

from repro.core import features as F

_PROD_DIMS = np.array([4, 8, 16, 24, 32, 48, 64, 96, 128, 160, 192,
                       256, 320, 384, 512, 640, 768], dtype=np.float64)


def _zipf_distribution(rng: np.random.Generator, hash_size: float,
                       pooling: float, batch: int = 65536) -> np.ndarray:
    """17-bin access-count histogram for a zipf(s) index stream (App. A.2)."""
    # wide exponent range: near-uniform (s<1, low reuse) through heavily
    # skewed (s~1.7, reuse-dominated) -- per-table access locality varies
    # strongly in production workloads (paper Fig 18)
    s = rng.uniform(0.35, 1.7)
    n = int(min(hash_size, 2e5))             # rank support (subsampled tail)
    ranks = np.unique(np.round(np.logspace(0, np.log10(n), 400)).astype(np.int64))
    weights = ranks.astype(np.float64) ** (-s)
    # each sampled rank bucket represents the ranks up to the next one
    widths = np.diff(np.concatenate([ranks, [n + 1]])).astype(np.float64)
    mass = weights * widths
    total_draws = batch * pooling
    # expected #accesses of an index at each sampled rank:
    counts = total_draws * weights / mass.sum()
    edges = np.concatenate([[0.0], 2.0 ** np.arange(F.NUM_DIST_BINS - 1), [np.inf]])
    hist = np.zeros(F.NUM_DIST_BINS)
    bin_idx = np.searchsorted(edges, counts, side="left") - 1
    bin_idx = np.clip(bin_idx, 0, F.NUM_DIST_BINS - 1)
    np.add.at(hist, bin_idx, mass)
    hist /= hist.sum()
    return hist


def make_pool(n_tables: int = 856, seed: int = 0,
              dim_mode: str = "dlrm") -> np.ndarray:
    """Generate a raw-feature table pool. dim_mode: 'dlrm' (16) or 'prod'."""
    rng = np.random.default_rng(seed)
    hash_size = np.clip(rng.lognormal(np.log(8e5), 1.2, n_tables), 1e4, 2e7)
    hash_size = np.round(hash_size)
    pooling = np.clip((rng.pareto(1.2, n_tables) + 1.0) * 3.0, 1.0, 200.0)
    if dim_mode == "dlrm":
        dim = np.full(n_tables, 16.0)
    elif dim_mode == "prod":
        dim = rng.choice(_PROD_DIMS, size=n_tables,
                         p=_dim_probs())
    else:
        raise ValueError(dim_mode)
    dist = np.stack([_zipf_distribution(rng, h, p)
                     for h, p in zip(hash_size, pooling)])
    return F.pack_features(dim, hash_size, pooling, dist)


def _dim_probs() -> np.ndarray:
    """Smaller dims are more common in production pools."""
    w = 1.0 / np.sqrt(_PROD_DIMS)
    return w / w.sum()


def make_dlrm_pool(seed: int = 0) -> np.ndarray:
    return make_pool(856, seed=seed, dim_mode="dlrm")


def make_prod_pool(seed: int = 0) -> np.ndarray:
    return make_pool(856, seed=seed, dim_mode="prod")
