"""Placement task construction (paper §4.1 / App. E).

A task T = (table subset, n_devices).  The pool is split in half into a
training pool and a disjoint testing pool; tasks sample tables from one
pool, so every table in a test task is unseen during training.
"""

from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class Task:
    raw_features: np.ndarray   # (M, 21)
    n_devices: int
    table_ids: np.ndarray      # indices into the originating pool
    name: str = ""

    @property
    def n_tables(self) -> int:
        return self.raw_features.shape[0]

    @classmethod
    def of(cls, raw_features: np.ndarray, n_devices: int,
           name: str = "") -> "Task":
        """Ad-hoc task over raw features not drawn from a pool (serving)."""
        raw = np.asarray(raw_features)
        return cls(raw_features=raw, n_devices=n_devices,
                   table_ids=np.arange(raw.shape[0]), name=name)


def split_pool(pool: np.ndarray, seed: int = 0):
    """Disjoint 50/50 train/test table pools (App. E)."""
    rng = np.random.default_rng(seed)
    perm = rng.permutation(pool.shape[0])
    half = pool.shape[0] // 2
    return perm[:half], perm[half:]


def sample_tasks(pool: np.ndarray, pool_ids: np.ndarray, n_tables: int,
                 n_devices: int, n_tasks: int, seed: int = 0,
                 name: str = "") -> list[Task]:
    rng = np.random.default_rng(seed)
    tasks = []
    for i in range(n_tasks):
        ids = rng.choice(pool_ids, size=n_tables, replace=False)
        tasks.append(Task(raw_features=pool[ids], n_devices=n_devices,
                          table_ids=ids, name=f"{name}-{n_tables}({n_devices})#{i}"))
    return tasks


def make_benchmark_suite(pool: np.ndarray, n_tables: int, n_devices: int,
                         n_tasks: int = 50, seed: int = 0,
                         name: str = "DLRM"):
    """Train/test task suites like 'DLRM-50 (4)' with 50 tasks each."""
    train_ids, test_ids = split_pool(pool, seed=seed)
    train = sample_tasks(pool, train_ids, n_tables, n_devices, n_tasks,
                         seed=seed + 1, name=name + "-train")
    test = sample_tasks(pool, test_ids, n_tables, n_devices, n_tasks,
                        seed=seed + 2, name=name + "-test")
    return train, test
