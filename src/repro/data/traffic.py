"""Synthetic serving traffic: drifting placement-request traces.

Serving workloads (`repro.serve`) are streams of *requests*, not task
suites: a handful of recurring jobs (one embedding-table subset each)
is requested over and over with skewed popularity, while each job's
per-table access histograms drift as traffic moves between tables.
``make_trace`` generates that shape deterministically from a table
pool:

* each job samples ``n_tables`` structural rows from the pool;
* its histograms interpolate from the sampled tables' own access
  distributions toward an *endpoint* drawn from different pool tables
  (real-looking start and end, not noise), advancing linearly with
  trace progress scaled by ``drift``;
* ``drift=0.0`` yields bitwise-identical features on every repeat of a
  job -- the zero-drift replay the serving tests pin against
  ``PlacementSession.place_many``.

Jobs are requested under a Zipf-like popularity (job ``k`` with weight
``1/(k+1)^zipf``), so traces exercise both hot cached jobs and a cold
tail, plus an optional burst of brand-new one-off jobs at the end
(``tail_jobs``) to exercise eviction.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.core import features as F
from repro.data.tasks import split_pool


@dataclasses.dataclass(frozen=True)
class TrafficConfig:
    """Shape of one synthetic request trace."""

    n_jobs: int = 8          # distinct recurring jobs
    n_tables: int = 16       # tables per job
    n_devices: int = 4
    n_requests: int = 512    # total requests across all jobs
    drift: float = 0.0       # total histogram drift over the trace [0, 1]
    zipf: float = 1.0        # job-popularity skew (0 = uniform)
    tail_jobs: int = 0       # one-off cold jobs appended at the end
    seed: int = 0


@dataclasses.dataclass
class Request:
    """One serving request: a job's features at one moment in time."""

    job: int                   # stable job id (trace-local)
    raw_features: np.ndarray   # (n_tables, 21); dist columns drift
    n_devices: int
    progress: float            # trace position in [0, 1]


def _job_features(pool: np.ndarray, ids: np.ndarray,
                  rng: np.random.Generator):
    """Structural rows + (base, endpoint) histogram pair for one job."""
    base = np.array(pool[ids], dtype=np.float64)
    others = rng.choice(
        np.setdiff1d(np.arange(pool.shape[0]), ids),
        size=ids.shape[0], replace=False)
    endpoint = np.array(pool[others, F.DIST_START:], dtype=np.float64)
    return base, endpoint


def make_trace(pool: np.ndarray,
               config: TrafficConfig | None = None) -> list[Request]:
    """Deterministic drifting request trace over ``pool`` tables."""
    cfg = config if config is not None else TrafficConfig()
    rng = np.random.default_rng(cfg.seed)
    _, ids = split_pool(pool, seed=cfg.seed)     # serve from the test half

    jobs = []
    for _ in range(cfg.n_jobs):
        picked = rng.choice(ids, size=cfg.n_tables, replace=False)
        jobs.append(_job_features(pool, picked, rng))

    weights = 1.0 / (1.0 + np.arange(cfg.n_jobs)) ** cfg.zipf
    weights /= weights.sum()
    picks = rng.choice(cfg.n_jobs, size=cfg.n_requests, p=weights)

    trace = []
    denom = max(1, cfg.n_requests - 1)
    for i, j in enumerate(picks):
        base, endpoint = jobs[j]
        progress = i / denom
        w = min(1.0, cfg.drift * progress)
        raw = np.array(base)
        if w > 0.0:     # exact branch: drift=0 repeats are bitwise-equal
            raw[:, F.DIST_START:] = (
                (1.0 - w) * base[:, F.DIST_START:] + w * endpoint)
        trace.append(Request(job=int(j), raw_features=raw,
                             n_devices=cfg.n_devices, progress=progress))

    for k in range(cfg.tail_jobs):               # cold one-offs at the end
        picked = rng.choice(ids, size=cfg.n_tables, replace=False)
        base, _ = _job_features(pool, picked, rng)
        trace.append(Request(job=cfg.n_jobs + k, raw_features=base,
                             n_devices=cfg.n_devices, progress=1.0))
    return trace
