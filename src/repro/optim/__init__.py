"""Pure-JAX pytree optimizers (no external deps)."""

from repro.optim.optimizers import (  # noqa: F401
    adam, adamw, sgd, rowwise_adagrad, apply_updates, linear_decay,
    OptState, Optimizer,
)
