"""Minimal optax-style optimizers as pure-JAX pytree transforms.

Each optimizer is a pair of pure functions ``init(params) -> state`` and
``update(grads, state, params) -> (updates, state)``; ``apply_updates`` adds
updates to params.  Learning-rate may be a float or a callable step->lr
(used for the paper's linear decay schedule).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Union

import jax
import jax.numpy as jnp

Schedule = Union[float, Callable[[jnp.ndarray], jnp.ndarray]]


class OptState(NamedTuple):
    step: jnp.ndarray
    inner: Any


@dataclasses.dataclass(frozen=True)
class Optimizer:
    init: Callable[[Any], OptState]
    update: Callable[[Any, OptState, Any], tuple[Any, OptState]]


def _lr_at(lr: Schedule, step: jnp.ndarray) -> jnp.ndarray:
    return lr(step) if callable(lr) else jnp.asarray(lr)


def linear_decay(base_lr: float, total_steps: int) -> Callable:
    def sched(step):
        frac = jnp.clip(1.0 - step / max(total_steps, 1), 0.0, 1.0)
        return base_lr * frac
    return sched


def sgd(lr: Schedule, momentum: float = 0.0) -> Optimizer:
    def init(params):
        mu = jax.tree.map(jnp.zeros_like, params) if momentum else None
        return OptState(jnp.zeros((), jnp.int32), mu)

    def update(grads, state, params=None):
        step = state.step + 1
        lrv = _lr_at(lr, step)
        if momentum:
            mu = jax.tree.map(lambda m, g: momentum * m + g, state.inner, grads)
            upd = jax.tree.map(lambda m: -lrv * m, mu)
            return upd, OptState(step, mu)
        upd = jax.tree.map(lambda g: -lrv * g, grads)
        return upd, OptState(step, None)

    return Optimizer(init, update)


def adam(lr: Schedule, b1: float = 0.9, b2: float = 0.999,
         eps: float = 1e-8, weight_decay: float = 0.0) -> Optimizer:
    def init(params):
        def zeros():
            return jax.tree.map(jnp.zeros_like, params)
        return OptState(jnp.zeros((), jnp.int32), (zeros(), zeros()))

    def update(grads, state, params=None):
        step = state.step + 1
        m, v = state.inner
        m = jax.tree.map(lambda m_, g: b1 * m_ + (1 - b1) * g, m, grads)
        v = jax.tree.map(lambda v_, g: b2 * v_ + (1 - b2) * g * g, v, grads)
        lrv = _lr_at(lr, step)
        bc1 = 1 - b1 ** step.astype(jnp.float32)
        bc2 = 1 - b2 ** step.astype(jnp.float32)

        def one(m_, v_, p):
            upd = -lrv * (m_ / bc1) / (jnp.sqrt(v_ / bc2) + eps)
            if weight_decay and p is not None:
                upd = upd - lrv * weight_decay * p
            return upd

        if weight_decay:
            upd = jax.tree.map(one, m, v, params)
        else:
            upd = jax.tree.map(lambda m_, v_: one(m_, v_, None), m, v)
        return upd, OptState(step, (m, v))

    return Optimizer(init, update)


def adamw(lr: Schedule, b1: float = 0.9, b2: float = 0.999,
          eps: float = 1e-8, weight_decay: float = 0.01) -> Optimizer:
    return adam(lr, b1=b1, b2=b2, eps=eps, weight_decay=weight_decay)


def rowwise_adagrad(lr: Schedule, eps: float = 1e-8) -> Optimizer:
    """Row-wise Adagrad for embedding tables: one accumulator per row.

    Accumulates the row-mean squared gradient -- the standard optimizer for
    large embedding tables (one float of state per row instead of per elem).
    Falls back to full Adagrad for rank<2 leaves.
    """

    def init(params):
        def acc(p):
            if p.ndim >= 2:
                return jnp.zeros(p.shape[:-1], p.dtype)
            return jnp.zeros_like(p)
        return OptState(jnp.zeros((), jnp.int32), jax.tree.map(acc, params))

    def update(grads, state, params=None):
        step = state.step + 1
        lrv = _lr_at(lr, step)

        def one(a, g):
            if g.ndim >= 2:
                a = a + jnp.mean(g * g, axis=-1)
                scale = 1.0 / (jnp.sqrt(a) + eps)
                return a, -lrv * g * scale[..., None]
            a = a + g * g
            return a, -lrv * g / (jnp.sqrt(a) + eps)

        flat_a, treedef = jax.tree.flatten(state.inner)
        flat_g = treedef.flatten_up_to(grads)
        pairs = [one(a, g) for a, g in zip(flat_a, flat_g)]
        new_acc = treedef.unflatten([p[0] for p in pairs])
        upd = treedef.unflatten([p[1] for p in pairs])
        return upd, OptState(step, new_acc)

    return Optimizer(init, update)


def apply_updates(params, updates):
    return jax.tree.map(lambda p, u: p + u.astype(p.dtype), params, updates)
