"""Pure-jnp oracle for the flash attention kernel."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def attention_ref(q, k, v, causal: bool = True, window: int | None = None):
    """q, k, v: (BH, S, hd) -> (BH, S, hd); materialized softmax."""
    S, T = q.shape[1], k.shape[1]
    s = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) / np.sqrt(q.shape[-1])
    qp = np.arange(S)[:, None]
    kp = np.arange(T)[None, :]
    mask = np.ones((S, T), bool)
    if causal:
        mask &= qp >= kp
    if window is not None:
        mask &= (qp - kp) < window
    s = jnp.where(mask[None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bqk,bkd->bqd", p,
                      v.astype(jnp.float32)).astype(q.dtype)
