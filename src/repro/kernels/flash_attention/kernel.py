"""Pallas TPU kernel: causal flash attention forward (optionally sliding
window).

TPU-native tiling: the grid is (batch*heads, q_blocks, kv_blocks) with the
kv axis innermost, so each (bh, qi) output tile is revisited sequentially
across kv steps -- the online-softmax running max / denominator / weighted
accumulator live in VMEM scratch and the normalized tile is written once
on the last kv step.  Block shapes are (q_block, head_dim) with head_dim a
128-lane multiple and q/kv blocks MXU-aligned; the S x S score matrix is
never materialized (only a (q_block, kv_block) tile).

This is the serving/prefill hot-spot kernel; the pure-jnp oracle is
``ref.py`` and the blockwise lax.scan implementation used by the model
(`repro.models.layers.flash_attention`) is an independent second oracle.
Validated in interpret mode (CPU container; TPU is the target).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_fwd_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                      q_block, kv_block, n_kv, causal, window, scale):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0].astype(jnp.float32)                    # (qb, hd)
    k = k_ref[0].astype(jnp.float32)                    # (kb, hd)
    v = v_ref[0].astype(jnp.float32)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale

    q_pos = qi * q_block + jax.lax.broadcasted_iota(jnp.int32,
                                                    (q_block, kv_block), 0)
    k_pos = ki * kv_block + jax.lax.broadcasted_iota(jnp.int32,
                                                     (q_block, kv_block), 1)
    mask = jnp.ones((q_block, kv_block), jnp.bool_)
    if causal:
        mask &= q_pos >= k_pos
    if window is not None:
        mask &= (q_pos - k_pos) < window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]
    l_prev = l_ref[...]
    m_new = jnp.maximum(m_prev, s.max(axis=-1))
    p = jnp.exp(s - m_new[:, None])
    corr = jnp.exp(m_prev - m_new)
    l_new = l_prev * corr + p.sum(axis=-1)
    acc_ref[...] = (acc_ref[...] * corr[:, None]
                    + jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())),
                                          preferred_element_type=jnp.float32))
    m_ref[...] = m_new
    l_ref[...] = l_new

    @pl.when(ki == n_kv - 1)
    def _finalize():
        out = acc_ref[...] / jnp.maximum(l_ref[...], 1e-20)[:, None]
        o_ref[0] = out.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "window", "q_block",
                                             "kv_block", "interpret",
                                             "scale"))
def flash_attention_fwd(q, k, v, *, causal: bool = True,
                        window: int | None = None, q_block: int = 128,
                        kv_block: int = 128, interpret: bool = True,
                        scale: float | None = None):
    """q, k, v: (BH, S, hd) same head count, hd % 128 == 0 -> (BH, S, hd)."""
    BH, S, hd = q.shape
    T = k.shape[1]
    assert hd % 128 == 0, "pad head_dim to a 128-lane multiple (ops.py)"
    assert S % q_block == 0 and T % kv_block == 0
    nq, nk = S // q_block, T // kv_block
    # scale uses the TRUE head dim (the caller may have lane-padded hd)
    scale = scale if scale is not None else 1.0 / np.sqrt(hd)

    kernel = functools.partial(
        _flash_fwd_kernel, q_block=q_block, kv_block=kv_block, n_kv=nk,
        causal=causal, window=window, scale=scale)
    return pl.pallas_call(
        kernel,
        grid=(BH, nq, nk),
        in_specs=[
            pl.BlockSpec((1, q_block, hd), lambda b, qi, ki: (b, qi, 0)),
            pl.BlockSpec((1, kv_block, hd), lambda b, qi, ki: (b, ki, 0)),
            pl.BlockSpec((1, kv_block, hd), lambda b, qi, ki: (b, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, q_block, hd), lambda b, qi, ki: (b, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, S, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((q_block,), jnp.float32),
            pltpu.VMEM((q_block,), jnp.float32),
            pltpu.VMEM((q_block, hd), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
