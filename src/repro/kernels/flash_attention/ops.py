"""Public op: Pallas flash attention over (B, S, H, hd) layouts.

Pads head_dim to 128 lanes and sequence to block multiples, folds (B, H)
into the grid's leading axis, and dispatches the Pallas kernel (interpret
mode off-TPU).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.flash_attention.kernel import flash_attention_fwd
from repro.kernels.flash_attention.ref import attention_ref


def _use_interpret() -> bool:
    return jax.default_backend() != "tpu"


def flash_attention(q, k, v, *, causal: bool = True,
                    window: int | None = None, q_block: int = 128,
                    kv_block: int = 128):
    """q, k, v: (B, S, H, hd) same head count -> (B, S, H, hd)."""
    B, S, H, hd = q.shape
    T = k.shape[1]
    hd_pad = int(np.ceil(hd / 128) * 128) - hd
    s_pad = -S % q_block
    t_pad = -T % kv_block

    def prep(x, seq_pad):
        x = jnp.pad(x, ((0, 0), (0, seq_pad), (0, 0), (0, hd_pad)))
        x = jnp.moveaxis(x, 2, 1)                      # (B, H, S, hd)
        return x.reshape(B * H, x.shape[2], hd + hd_pad)

    out = flash_attention_fwd(prep(q, s_pad), prep(k, t_pad), prep(v, t_pad),
                              causal=causal, window=window, q_block=q_block,
                              kv_block=kv_block, interpret=_use_interpret(),
                              scale=1.0 / np.sqrt(hd))
    out = out.reshape(B, H, S + s_pad, hd + hd_pad)
    return jnp.moveaxis(out, 1, 2)[:, :S, :, :hd]


def flash_attention_ref(q, k, v, *, causal: bool = True,
                        window: int | None = None):
    B, S, H, hd = q.shape

    def fold(x):
        return jnp.moveaxis(x, 2, 1).reshape(B * H, x.shape[1], hd)

    out = attention_ref(fold(q), fold(k), fold(v), causal=causal,
                        window=window)
    return jnp.moveaxis(out.reshape(B, H, S, hd), 1, 2)
