"""Pure-jnp oracle for the fused embedding-bag kernel."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def embedding_bag_ref(arena: jax.Array, indices: jax.Array) -> jax.Array:
    """arena: (R, D), indices: (N, P) arena rows (0 = zero row) -> (N, D)."""
    rows = jnp.take(arena, indices, axis=0)          # (N, P, D)
    return rows.astype(jnp.float32).sum(axis=1)


def embedding_bag_grad_ref(arena_shape, indices: jax.Array,
                           grad_out: jax.Array) -> jax.Array:
    """Scatter-add gradient w.r.t. the arena (row-wise)."""
    n, p = indices.shape
    g = jnp.zeros(arena_shape, jnp.float32)
    flat_idx = indices.reshape(-1)
    flat_g = jnp.repeat(grad_out.astype(jnp.float32)[:, None, :], p,
                        axis=1).reshape(-1, arena_shape[1])
    g = g.at[flat_idx].add(flat_g)
    return g.at[0].set(0.0)                          # zero row stays zero
