"""Public op: fused multi-table embedding bag with custom VJP.

``fused_embedding_lookup`` is the user-facing op: it packs a list of tables
into a zero-row arena (done once, at placement time, by
``repro.embedding``), pads the feature dim to 128 lanes, rebases per-table
indices, and dispatches the Pallas kernel (interpret mode on CPU, compiled
on TPU).  Backward is the row-wise scatter-add from ``ref.py`` (the
backward FBGEMM kernel would mirror the forward's scalar-prefetch pattern;
on the paper's cost model it is bwd_comp = bwd_scale x fwd traffic).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.embedding_bag.kernel import embedding_bag_fused
from repro.kernels.embedding_bag.ref import (embedding_bag_grad_ref,
                                             embedding_bag_ref)


def _use_interpret() -> bool:
    return jax.default_backend() != "tpu"


def pad_dim(d: int) -> int:
    return int(np.ceil(d / 128) * 128)


def build_arena(tables: list[jax.Array]):
    """Stack tables into a zero-row arena. Returns (arena, base_rows)."""
    dim = max(t.shape[1] for t in tables)
    dp = pad_dim(dim)
    parts = [jnp.zeros((1, dp), tables[0].dtype)]
    bases = []
    row = 1
    for t in tables:
        bases.append(row)
        pad = ((0, 0), (0, dp - t.shape[1]))
        parts.append(jnp.pad(t, pad))
        row += t.shape[0]
    return jnp.concatenate(parts, axis=0), np.asarray(bases)


def rebase_indices(indices: jax.Array, base_rows: np.ndarray) -> jax.Array:
    """indices: (T, B, P) per-table rows, -1 = padded slot -> arena rows."""
    base = jnp.asarray(base_rows)[:, None, None]
    return jnp.where(indices >= 0, indices + base, 0)


@functools.partial(jax.custom_vjp, nondiff_argnums=())
def embedding_bag(arena, indices):
    """arena: (R, D128); indices: (N, P) arena rows -> pooled sums (N, D128)."""
    return embedding_bag_fused(arena, indices, interpret=_use_interpret())


def _fwd(arena, indices):
    return embedding_bag(arena, indices), (arena.shape, indices)


def _bwd(res, g):
    arena_shape, indices = res
    return embedding_bag_grad_ref(arena_shape, indices, g), None


embedding_bag.defvjp(_fwd, _bwd)


def fused_embedding_lookup(arena, base_rows, indices):
    """Multi-table fused lookup.

    indices: (T, B, P) per-table row ids (-1 padding).
    Returns (T, B, D128) pooled embeddings.
    """
    T, B, P = indices.shape
    flat = rebase_indices(indices, base_rows).reshape(T * B, P)
    out = embedding_bag(arena, flat)
    return out.reshape(T, B, -1)


def fused_embedding_lookup_ref(arena, base_rows, indices):
    T, B, P = indices.shape
    flat = rebase_indices(indices, base_rows).reshape(T * B, P)
    return embedding_bag_ref(arena, flat).reshape(T, B, -1)
