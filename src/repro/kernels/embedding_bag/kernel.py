"""Pallas TPU kernel: fused multi-table pooled embedding lookup.

TPU adaptation of the FBGEMM fused embedding-bag (paper's hot-spot op).
The GPU idiom (one warp per row, warp-shuffle reductions) has no TPU
analogue; the transferable insight is *fusion*: all tables of one device
are stacked into a single height-padded arena so ONE kernel launch serves
every (sample, table) lookup, amortizing launch overhead exactly like the
fused op the paper models (App. A.3.2).

Design:
  * arena: (rows, dim_padded) -- all tables vertically stacked; row 0 is a
    reserved zero row that padded pooling slots point at.
  * indices: (n_bags, pool) int32 arena-row ids, one bag per
    (sample, table) pair, already offset by table base row.
  * grid = (n_bags, pool): a scalar-prefetch index map DMAs exactly one
    embedding row HBM->VMEM per step; the output BlockSpec pins the same
    (1, dim) VMEM tile for all `pool` steps of a bag so the pooled sum
    accumulates in VMEM and is written back once (revisiting guarantees of
    the sequential grid).
  * dim is padded to a 128-lane multiple; rows stream as (1, dim) tiles.

Validated against ``ref.py`` in interpret mode (this container is CPU-only;
TPU is the target).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _bag_kernel(idx_ref, row_ref, out_ref):
    """Accumulate one arena row into the bag's output tile."""
    p = pl.program_id(1)

    @pl.when(p == 0)
    def _init():
        out_ref[...] = row_ref[...].astype(out_ref.dtype)

    @pl.when(p > 0)
    def _acc():
        out_ref[...] += row_ref[...].astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def embedding_bag_fused(arena: jax.Array, indices: jax.Array,
                        *, interpret: bool = True) -> jax.Array:
    """Pooled-sum lookup. arena: (R, D128), indices: (N, P) -> (N, D128).

    Padded pooling slots must point at row 0 (zero row).
    """
    n_bags, pool = indices.shape
    dim = arena.shape[1]
    assert dim % 128 == 0, "pad dim to a 128-lane multiple (ops.py does this)"

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(n_bags, pool),
        in_specs=[
            pl.BlockSpec((1, dim), lambda b, p, idx: (idx[b, p], 0)),
        ],
        out_specs=pl.BlockSpec((1, dim), lambda b, p, idx: (b, 0)),
    )
    return pl.pallas_call(
        _bag_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((n_bags, dim), jnp.float32),
        interpret=interpret,
    )(indices, arena)
