"""Persisted calibration artifact: measured kernel/collective costs.

A ``CalibrationTable`` holds the micro-benchmark grids from
``repro.profiling.microbench`` (per-shape forward/backward kernel
milliseconds over ``(dim, rows, batch, pooling)``), the fitted
``CommModel`` from ``repro.profiling.collectives``, the fitted
``FusionModel`` pair from the fused multi-table sweep (format v2), a
hardware fingerprint, and a format version.  It persists as a single
``.npz`` (arrays raw, scalar metadata JSON-encoded) and answers
interpolation queries: per-table costs are *multilinear in log2-space*
over the grid, clamped to the grid's convex hull (out-of-range queries
snap to the nearest edge -- calibrate a wider grid if that matters).

The cost of a *fused* multi-table op is not the sum of its per-table
costs (the paper's core measurement insight, Fig 12): one launch is
paid instead of K, and co-scheduled tables pipeline.  A ``FusionModel``
captures that deviation parametrically -- a fitted per-launch overhead
``c0`` plus a per-rank pipelining efficiency ``eff(r) = min(cap,
1 + coef * log2(r))`` -- so measured oracles can price a device's K
tables as ``c0 + sum_r max(t_(r) - c0, 0) / eff(r)`` (tables ranked by
descending single-table time) instead of ``sum_i t_i``.  v1 artifacts
(no fused sweep) still load and fall back to the additive model with a
warning.

Format v3 adds the *sharded-gather* sweep behind column-wise table
sharding (``repro.sharding``): a ``ShardModel`` pair fitted to measured
partial-width lookups, pricing a shard covering column fraction ``f``
of a table as ``o + (t_full - o) * f**e`` -- the per-gather overhead
``o`` is NOT amortized by splitting, which is why K shards cost more
than the whole table.  v2 artifacts load with a warning and fall back
to proportional pricing (``t_full * f``, the overhead-free model).

``CalibrationTable.synthetic`` builds a deterministic table from the
analytic ``CostSimulator`` instead of measuring -- the bridge used by
tests and by sim-vs-measured comparisons where hardware timing noise
would make assertions flaky.
"""

from __future__ import annotations

import dataclasses
import itertools
import json
import os
import warnings

import numpy as np

from repro.profiling.collectives import CommModel, calibrate_comm
from repro.sim.hardware import HardwareSpec, PAPER_GPU

CALIBRATION_VERSION = 3

# fused-sweep defaults: fusion depths K and heterogeneous draws per K
DEFAULT_FUSED_KS = (2, 4, 8)
DEFAULT_FUSED_PER_K = 4

# sharded-sweep defaults: column fractions and draws per fraction
DEFAULT_SHARD_FRACS = (0.25, 0.5, 0.75)
DEFAULT_SHARD_PER_FRAC = 3

# tiny CI-friendly grid (--smoke); dims stay unpadded so CPU reference
# timings actually differ per point (the Pallas path pads to 128 lanes)
SMOKE_GRID = {
    "dims": (16, 64, 256),
    "rows": (256, 4096),
    "batches": (32,),
    "poolings": (2, 8),
}

# moderate default grid for a real offline calibration run
DEFAULT_GRID = {
    "dims": (16, 64, 128, 256, 512),
    "rows": (1024, 16384, 262144),
    "batches": (1024, 16384),
    "poolings": (2, 8, 32),
}


def default_artifact_path() -> str:
    """Artifact location: ``$REPRO_CALIBRATION`` or the scratch dir that
    CI caches between runs (gitignored)."""
    return os.environ.get("REPRO_CALIBRATION",
                          os.path.join("artifacts", "calibration",
                                       "calibration.npz"))


def hardware_fingerprint() -> dict:
    """What hardware produced a measurement (artifact staleness check)."""
    import platform
    import jax
    devs = jax.devices()
    return {
        "backend": jax.default_backend(),
        "device_kind": devs[0].device_kind,
        "n_devices": len(devs),
        "platform": platform.platform(),
        "machine": platform.machine(),
    }


def _axis_weights(grid: np.ndarray, x: np.ndarray):
    """Per-query ``(lo, hi, w)`` along one log2-spaced axis, clamped to
    the grid range; a singleton axis contributes weight 0 at index 0."""
    g = np.asarray(grid, dtype=np.float64)
    x = np.clip(np.asarray(x, dtype=np.float64), g[0], g[-1])
    if g.size == 1:
        z = np.zeros(x.shape, dtype=np.int64)
        return z, z, np.zeros(x.shape)
    pos = np.interp(np.log2(np.maximum(x, 1e-9)), np.log2(g),
                    np.arange(g.size, dtype=np.float64))
    lo = np.minimum(pos.astype(np.int64), g.size - 2)
    return lo, lo + 1, pos - lo


@dataclasses.dataclass(frozen=True)
class FusionModel:
    """Parametric fused multi-table cost model for one kernel direction.

    Prices one fused op over K tables whose *single-table* calibrated
    times are ``t_1..t_K``:

        fused = c0 + sum_r max(t_(r) - c0, 0) / eff(r)
        eff(r) = min(cap, 1 + coef * log2(r))      (ranks sorted by
                                                    descending time)

    ``c0`` (``overhead_ms``) is the per-launch overhead every
    single-table measurement pays but a fused op amortizes across its K
    tables; ``eff`` is the pipelining discount deeper fusion earns.
    The model is a function of K and total work only -- by construction
    it is monotone in both (adding a table or growing any table's time
    never lowers the fused cost; see ``tests/test_fusion_properties``),
    it reduces to the exact single-table grid value at K = 1, and with
    ``overhead_ms == pipeline_coef == 0`` it IS the additive model
    (``is_additive``), which per-device pricing then computes via the
    plain table-order segment sum -- bitwise what pre-v2 oracles did.
    """

    overhead_ms: float       # c0: fitted per-launch overhead
    pipeline_coef: float     # eff(r) = min(cap, 1 + coef * log2(r))
    pipeline_cap: float      # >= 1
    source: str = "additive"           # "measured"|"synthetic"|"additive"
    n_samples: int = 0                 # fused sweep points behind the fit
    fit_mape: float = 0.0              # model MAPE on the sweep
    additive_mape: float = 0.0         # additive-baseline MAPE on the sweep

    def __post_init__(self):
        if self.overhead_ms < 0 or self.pipeline_coef < 0 \
                or self.pipeline_cap < 1.0:
            raise ValueError(
                f"need overhead_ms >= 0, pipeline_coef >= 0, "
                f"pipeline_cap >= 1, got {self}")

    @property
    def is_additive(self) -> bool:
        """True when the model degenerates to the plain per-table sum."""
        return self.overhead_ms == 0.0 and self.pipeline_coef == 0.0

    @classmethod
    def additive(cls, source: str = "additive") -> "FusionModel":
        """The identity correction: fused cost == sum of per-table costs
        (the only model a v1 artifact can support)."""
        return cls(overhead_ms=0.0, pipeline_coef=0.0, pipeline_cap=1.0,
                   source=source)

    def eff(self, ranks) -> np.ndarray:
        """Per-rank pipelining efficiency (rank 1 is always 1.0)."""
        r = np.maximum(np.asarray(ranks, dtype=np.float64), 1.0)
        return np.minimum(self.pipeline_cap,
                          1.0 + self.pipeline_coef * np.log2(r))

    def fused_ms(self, per_table_ms) -> float:
        """Fused-op time for one group of tables given their single-table
        calibrated times.  K = 0 costs nothing, K = 1 returns the
        single-table value bitwise (no correction to round-trip)."""
        t = np.atleast_1d(np.asarray(per_table_ms, dtype=np.float64))
        if t.size == 0:
            return 0.0
        if t.size == 1 or self.is_additive:
            return float(t.sum())
        m = np.sort(np.maximum(t - self.overhead_ms, 0.0))[::-1]
        ranks = np.arange(1, t.size + 1)
        return float(self.overhead_ms + (m / self.eff(ranks)).sum())

    def device_ms(self, per_table_ms: np.ndarray, assignments: np.ndarray,
                  n_devices: int, counts: np.ndarray | None = None
                  ) -> np.ndarray:
        """Per-(placement, device) fused compute time ``(P, D)`` over a
        ``(P, M)`` assignment batch -- the batched form of ``fused_ms``.

        Within every (placement, device) group tables are ranked by
        descending single-table time (ties broken by table index, fixed
        across batch compositions) and discounted by ``eff(rank)``; each
        row is independent of the others, so ``evaluate`` stays the
        P = 1 special case of ``evaluate_many`` bitwise.  Cells with one
        table take the plain segment sum (the exact grid value), and an
        additive model takes it for every cell -- table-order summation,
        bitwise identical to the pre-v2 oracle arithmetic.
        """
        from repro.sim.costsim import per_device_sums
        per = np.asarray(per_table_ms, dtype=np.float64)
        P, M = assignments.shape
        sums = per_device_sums(assignments, n_devices, per)
        if self.is_additive:
            return sums                  # never needs the counts bincount
        if counts is None:
            counts = per_device_sums(assignments, n_devices)
        rows = np.arange(P)[:, None]
        starts = np.concatenate(
            [np.zeros((P, 1), np.int64),
             np.cumsum(counts, axis=1)[:, :-1]], axis=1)
        m = np.broadcast_to(np.maximum(per - self.overhead_ms, 0.0), (P, M))
        order = np.lexsort((-m, assignments), axis=-1)
        dev_sorted = assignments[rows, order]
        rank = np.arange(M)[None, :] - starts[rows, dev_sorted]
        contrib = m[rows, order] / self.eff(rank + 1)
        fused = (per_device_sums(dev_sorted, n_devices, contrib)
                 + self.overhead_ms)
        return np.where(counts > 1, fused, sums)

    @classmethod
    def fit(cls, singles: list, fused_ms: np.ndarray, *,
            source: str = "measured") -> "FusionModel":
        """Fit ``(c0, coef, cap)`` to a fused sweep.

        ``singles[k]`` holds sample k's per-table single-table times (as
        interpolated from the just-measured grid), ``fused_ms[k]`` the
        measured fused-op time.  For a fixed ``(coef, cap)`` the
        prediction is linear in ``c0`` (``c0 * (1 - sum_r 1/eff(r)) +
        sum_r t_(r)/eff(r)``), so ``c0`` has a closed-form relative
        least-squares solution and only ``(coef, cap)`` are grid
        searched -- deterministic, dependency-free, and a few thousand
        dot products.  ``c0`` is clamped to the smallest single-table
        time seen so fitted marginals stay non-negative.
        """
        y = np.asarray(fused_ms, dtype=np.float64)
        ts = [np.sort(np.asarray(t, np.float64))[::-1] for t in singles]
        if y.size == 0 or y.size != len(ts):
            raise ValueError("need one fused measurement per sample")
        c0_max = min(float(t.min()) for t in ts)
        additive = np.array([t.sum() for t in ts])
        additive_mape = float(np.mean(np.abs(additive - y) / y))
        best = None
        # bounded search: deep-fusion discounts beyond ~6x are not
        # physical for these kernels, and a wider box just lets timing
        # outliers pick absurd pipelining factors
        coefs = np.concatenate([[0.0], np.geomspace(0.02, 3.0, 24)])
        caps = (1.0, 1.25, 1.5, 2.0, 3.0, 4.0, 6.0)
        for coef in coefs:
            for cap in caps:
                if coef == 0.0 and cap != 1.0:
                    continue                  # eff is flat: caps all alias
                probe = cls(overhead_ms=0.0, pipeline_coef=float(coef),
                            pipeline_cap=float(cap), source=source)
                w = [1.0 / probe.eff(np.arange(1, t.size + 1)) for t in ts]
                a = np.array([1.0 - wk.sum() for wk in w])
                b = np.array([(wk * t).sum() for wk, t in zip(w, ts)])
                denom = ((a / y) ** 2).sum()
                c0 = 0.0 if denom <= 0 else \
                    float((a * (y - b) / y ** 2).sum() / denom)
                c0 = min(max(c0, 0.0), c0_max)
                pred = a * c0 + b
                mape = float(np.mean(np.abs(pred - y) / y))
                if best is None or mape < best[0]:
                    best = (mape, c0, float(coef), float(cap))
        mape, c0, coef, cap = best
        return cls(overhead_ms=c0, pipeline_coef=coef, pipeline_cap=cap,
                   source=source, n_samples=int(y.size),
                   fit_mape=round(mape, 6),
                   additive_mape=round(additive_mape, 6))

    @classmethod
    def from_spec(cls, spec: HardwareSpec = PAPER_GPU) -> "FusionModel":
        """Analytic model mirroring the simulator's fused-op pricing
        (same ``c0``/pipeline constants, no measurement)."""
        return cls(overhead_ms=spec.comp_overhead_ms,
                   pipeline_coef=spec.pipeline_coef,
                   pipeline_cap=spec.pipeline_cap, source="synthetic")

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: dict) -> "FusionModel":
        return cls(**d)

    def summary(self) -> str:
        return (f"{self.source}: c0={self.overhead_ms:.4f}ms "
                f"eff=min({self.pipeline_cap:g}, "
                f"1+{self.pipeline_coef:g}*log2(r)) "
                f"[{self.n_samples} pts, mape {self.fit_mape:.3f} "
                f"vs additive {self.additive_mape:.3f}]")


@dataclasses.dataclass(frozen=True)
class ShardModel:
    """Parametric partial-table (column-shard) cost model, one direction.

    Prices a shard that carries column fraction ``f`` of a table whose
    full single-table calibrated time is ``t``:

        shard = o + (t - o) * f ** e        (o clamped to t)

    ``o`` (``overhead_ms``) is the per-gather cost a column split does
    not shrink -- index decode, launch, per-row addressing all run at
    the FULL lookup count whatever the width -- so K shards of one table
    sum to ``K*o + (t - o) * sum(f_k**e)`` > ``t``: sharding buys
    feasibility and parallelism, never free compute.  ``e``
    (``exponent``) bends the streaming term for sub-linear column
    scaling (cache-line quantization at narrow widths).

    ``f >= 1`` returns ``t`` bitwise -- NOT via the arithmetic (in
    floats ``o + (t - o) != t`` in general) but via an explicit
    ``where``, which is what keeps K = 1 sharded pricing
    bitwise-identical to the whole-table path.  ``proportional()``
    (``o = 0, e = 1``) is the pure column-fraction model v2 artifacts
    fall back to.
    """

    overhead_ms: float       # o: per-gather floor a split cannot shrink
    exponent: float          # e: column-fraction exponent
    source: str = "proportional"   # "measured"|"synthetic"|"proportional"
    n_samples: int = 0             # sharded sweep points behind the fit
    fit_mape: float = 0.0          # model MAPE on the sweep
    proportional_mape: float = 0.0  # t*f baseline MAPE on the sweep

    def __post_init__(self):
        if self.overhead_ms < 0 or self.exponent <= 0:
            raise ValueError(f"need overhead_ms >= 0 and exponent > 0, "
                             f"got {self}")

    @property
    def is_proportional(self) -> bool:
        """True when the model degenerates to ``t * f``."""
        return self.overhead_ms == 0.0 and self.exponent == 1.0

    @classmethod
    def proportional(cls, source: str = "proportional") -> "ShardModel":
        """The overhead-free model: shard cost == column fraction of the
        table cost (the only model a pre-v3 artifact can support)."""
        return cls(overhead_ms=0.0, exponent=1.0, source=source)

    @classmethod
    def from_spec(cls, spec: HardwareSpec = PAPER_GPU) -> "ShardModel":
        """Analytic model matching the simulator's convention: the
        spec's per-op overhead is the unsplittable floor, streaming cost
        linear in columns."""
        return cls(overhead_ms=spec.comp_overhead_ms, exponent=1.0,
                   source="synthetic")

    def shard_ms(self, full_ms, frac) -> np.ndarray:
        """Per-shard kernel time given each shard's FULL-table time and
        column fraction (vectorized; ``frac == 1`` returns ``full_ms``
        bitwise)."""
        t = np.asarray(full_ms, dtype=np.float64)
        f = np.asarray(frac, dtype=np.float64)
        o = np.minimum(self.overhead_ms, t)
        pred = o + (t - o) * f ** self.exponent
        return np.where(f < 1.0, pred, t)

    @classmethod
    def fit(cls, full_ms, fracs, measured_ms, *,
            source: str = "measured") -> "ShardModel":
        """Fit ``(o, e)`` to a sharded sweep.

        For a fixed exponent the prediction is linear in ``o``
        (``o * (1 - f**e) + t * f**e``), so ``o`` has a closed-form
        relative least-squares solution and only ``e`` is grid
        searched -- the same deterministic scheme as
        ``FusionModel.fit``.  ``o`` is clamped to the smallest
        full-table time seen so fitted shard costs stay within
        ``[o, t]``.
        """
        t = np.asarray(full_ms, dtype=np.float64)
        f = np.asarray(fracs, dtype=np.float64)
        y = np.asarray(measured_ms, dtype=np.float64)
        if y.size == 0 or t.shape != y.shape or f.shape != y.shape:
            raise ValueError("need matching full/frac/measured arrays")
        o_max = float(t.min())
        prop_mape = float(np.mean(np.abs(t * f - y) / y))
        best = None
        # sub-linear exponents model cache-line quantization; above ~1.5
        # the streaming term would vanish faster than columns do, which
        # is not physical for a contiguous-row gather
        for e in np.concatenate([[1.0], np.linspace(0.5, 1.5, 21)]):
            g = f ** e
            a = 1.0 - g
            b = t * g
            denom = ((a / y) ** 2).sum()
            o = 0.0 if denom <= 0 else \
                float((a * (y - b) / y ** 2).sum() / denom)
            o = min(max(o, 0.0), o_max)
            pred = a * o + b
            mape = float(np.mean(np.abs(pred - y) / y))
            if best is None or mape < best[0]:
                best = (mape, o, float(e))
        mape, o, e = best
        return cls(overhead_ms=o, exponent=e, source=source,
                   n_samples=int(y.size), fit_mape=round(mape, 6),
                   proportional_mape=round(prop_mape, 6))

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: dict) -> "ShardModel":
        return cls(**d)

    def summary(self) -> str:
        return (f"{self.source}: o={self.overhead_ms:.4f}ms "
                f"e={self.exponent:g} [{self.n_samples} pts, "
                f"mape {self.fit_mape:.3f} vs proportional "
                f"{self.proportional_mape:.3f}]")


@dataclasses.dataclass
class CalibrationTable:
    """Measured (or synthetic) kernel/collective cost grids + provenance."""

    dims: np.ndarray        # (Nd,) strictly increasing
    rows: np.ndarray        # (Nr,)
    batches: np.ndarray     # (Nb,)
    poolings: np.ndarray    # (Np,)
    fwd_ms: np.ndarray      # (Nd, Nr, Nb, Np)
    bwd_ms: np.ndarray      # (Nd, Nr, Nb, Np)
    comm: CommModel
    fingerprint: dict
    version: int = CALIBRATION_VERSION
    meta: dict = dataclasses.field(default_factory=dict)
    # v2: fused multi-table correction (None -> additive fallback) and the
    # fused-sweep trace behind the fit (k, additive-vs-measured ms arrays)
    fusion_fwd: FusionModel | None = None
    fusion_bwd: FusionModel | None = None
    fusion_sweep: dict = dataclasses.field(default_factory=dict)
    # v3: partial-table (column-shard) pricing (None -> proportional
    # fallback) and the sharded-sweep trace behind the fit
    shard_fwd: ShardModel | None = None
    shard_bwd: ShardModel | None = None
    shard_sweep: dict = dataclasses.field(default_factory=dict)

    def __post_init__(self):
        if self.fusion_fwd is None:
            self.fusion_fwd = FusionModel.additive()
        if self.fusion_bwd is None:
            self.fusion_bwd = FusionModel.additive()
        if self.shard_fwd is None:
            self.shard_fwd = ShardModel.proportional()
        if self.shard_bwd is None:
            self.shard_bwd = ShardModel.proportional()
        for name in ("dims", "rows", "batches", "poolings"):
            g = np.asarray(getattr(self, name), dtype=np.float64)
            if g.ndim != 1 or g.size == 0 or np.any(np.diff(g) <= 0) \
                    or g[0] <= 0:
                raise ValueError(f"{name} must be positive and strictly "
                                 f"increasing, got {g}")
            setattr(self, name, g)
        shape = (self.dims.size, self.rows.size, self.batches.size,
                 self.poolings.size)
        self.fwd_ms = np.asarray(self.fwd_ms, dtype=np.float64)
        self.bwd_ms = np.asarray(self.bwd_ms, dtype=np.float64)
        if self.fwd_ms.shape != shape or self.bwd_ms.shape != shape:
            raise ValueError(f"cost grids must have shape {shape}, got "
                             f"{self.fwd_ms.shape} / {self.bwd_ms.shape}")

    # ---- interpolation -----------------------------------------------------

    def _corner_weights(self, dim, rows, batch, pooling):
        """Per-query corner indices and axis weights, shared by every grid
        interpolated at the same query points."""
        q = np.broadcast_arrays(np.asarray(dim, np.float64),
                                np.asarray(rows, np.float64),
                                np.asarray(batch, np.float64),
                                np.asarray(pooling, np.float64))
        axes = (self.dims, self.rows, self.batches, self.poolings)
        los, his, ws = zip(*(_axis_weights(g, x) for g, x in zip(axes, q)))
        return q[0].shape, los, his, ws

    def _interp_grids(self, tables, shape, los, his, ws):
        """Multilinear blend of one or more grids over shared corner
        weights: the 16 corner weight products are computed once however
        many grids are queried."""
        outs = [np.zeros(shape) for _ in tables]
        for corner in itertools.product((0, 1), repeat=4):
            idx = tuple(his[i] if c else los[i]
                        for i, c in enumerate(corner))
            w = np.ones(shape)
            for i, c in enumerate(corner):
                w = w * (ws[i] if c else 1.0 - ws[i])
            for out, table in zip(outs, tables):
                out += w * table[idx]
        return outs

    def _interp(self, table: np.ndarray, dim, rows, batch, pooling):
        shape, los, his, ws = self._corner_weights(dim, rows, batch, pooling)
        return self._interp_grids((table,), shape, los, his, ws)[0]

    def fwd_lookup_ms(self, dim, rows, batch, pooling) -> np.ndarray:
        """Interpolated forward kernel time (ms) per query (vectorized)."""
        return self._interp(self.fwd_ms, dim, rows, batch, pooling)

    def bwd_lookup_ms(self, dim, rows, batch, pooling) -> np.ndarray:
        """Interpolated backward (scatter-add) time (ms) per query."""
        return self._interp(self.bwd_ms, dim, rows, batch, pooling)

    def lookup_ms(self, dim, rows, batch, pooling
                  ) -> tuple[np.ndarray, np.ndarray]:
        """Interpolated ``(fwd, bwd)`` kernel times per query in ONE pass:
        both grids share the corner-weight computation (the batched
        ``MeasuredOracle`` hot path)."""
        shape, los, his, ws = self._corner_weights(dim, rows, batch, pooling)
        fwd, bwd = self._interp_grids((self.fwd_ms, self.bwd_ms),
                                      shape, los, his, ws)
        return fwd, bwd

    def comm_ms(self, payload_mb) -> np.ndarray:
        """Fitted alpha-beta all-to-all time per per-device payload."""
        return self.comm.comm_ms(payload_mb)

    # ---- persistence -------------------------------------------------------

    def save(self, path: str) -> str:
        if not path.endswith(".npz"):
            path += ".npz"                # np.savez appends it anyway
        os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
        scalar = {"comm": self.comm.to_dict(),
                  "fingerprint": self.fingerprint,
                  "version": self.version,
                  "meta": self.meta,
                  "fusion": {"fwd": self.fusion_fwd.to_dict(),
                             "bwd": self.fusion_bwd.to_dict()},
                  "sharding": {"fwd": self.shard_fwd.to_dict(),
                               "bwd": self.shard_bwd.to_dict()}}
        sweep = {f"fusion_{k}": np.asarray(v, np.float64)
                 for k, v in self.fusion_sweep.items()}
        sweep.update({f"shard_{k}": np.asarray(v, np.float64)
                      for k, v in self.shard_sweep.items()})
        # atomic: an interrupted calibration must not leave a truncated
        # artifact behind for the next loader
        tmp = path + ".tmp.npz"
        np.savez(tmp, dims=self.dims, rows=self.rows,
                 batches=self.batches, poolings=self.poolings,
                 fwd_ms=self.fwd_ms, bwd_ms=self.bwd_ms,
                 scalar_json=np.array(json.dumps(scalar)), **sweep)
        os.replace(tmp, path)
        return path

    @classmethod
    def load(cls, path: str) -> "CalibrationTable":
        with np.load(path, allow_pickle=False) as z:
            scalar = json.loads(str(z["scalar_json"]))
            if scalar["version"] > CALIBRATION_VERSION:
                raise ValueError(
                    f"calibration artifact {path} has version "
                    f"{scalar['version']} > supported {CALIBRATION_VERSION};"
                    " upgrade the code or re-calibrate")
            if "fusion" in scalar:
                fusion_fwd = FusionModel.from_dict(scalar["fusion"]["fwd"])
                fusion_bwd = FusionModel.from_dict(scalar["fusion"]["bwd"])
            else:
                # v1 artifact: no fused sweep was measured.  Load it --
                # interpolation grids are still good -- but per-device
                # pricing degrades to the additive per-table model.
                warnings.warn(
                    f"calibration artifact {path} is v{scalar['version']} "
                    "(pre-fusion): falling back to the ADDITIVE multi-table "
                    "model; re-run `python -m repro.profiling.calibrate` to "
                    "measure the fused correction", stacklevel=2)
                fusion_fwd = FusionModel.additive(source="v1-fallback")
                fusion_bwd = FusionModel.additive(source="v1-fallback")
            if "sharding" in scalar:
                shard_fwd = ShardModel.from_dict(scalar["sharding"]["fwd"])
                shard_bwd = ShardModel.from_dict(scalar["sharding"]["bwd"])
            else:
                # pre-v3 artifact: no sharded-gather sweep was measured.
                # Whole-table pricing is unaffected; partial tables fall
                # back to the additive column-fraction model.
                warnings.warn(
                    f"calibration artifact {path} is v{scalar['version']} "
                    "(pre-sharding): partial-table costs use the "
                    "PROPORTIONAL column-fraction model; re-run `python -m "
                    "repro.profiling.calibrate` to measure the "
                    "sharded-gather correction", stacklevel=2)
                shard_fwd = ShardModel.proportional(source="v2-fallback")
                shard_bwd = ShardModel.proportional(source="v2-fallback")
            fusion_sweep = {k[len("fusion_"):]: z[k] for k in z.files
                            if k.startswith("fusion_")}
            shard_sweep = {k[len("shard_"):]: z[k] for k in z.files
                           if k.startswith("shard_")}
            return cls(dims=z["dims"], rows=z["rows"], batches=z["batches"],
                       poolings=z["poolings"], fwd_ms=z["fwd_ms"],
                       bwd_ms=z["bwd_ms"],
                       comm=CommModel.from_dict(scalar["comm"]),
                       fingerprint=scalar["fingerprint"],
                       version=scalar["version"], meta=scalar["meta"],
                       fusion_fwd=fusion_fwd, fusion_bwd=fusion_bwd,
                       fusion_sweep=fusion_sweep,
                       shard_fwd=shard_fwd, shard_bwd=shard_bwd,
                       shard_sweep=shard_sweep)

    # ---- construction ------------------------------------------------------

    @classmethod
    def measure(cls, *, dims=None, rows=None, batches=None, poolings=None,
                use_pallas: bool | None = None, warmup: int = 1,
                repeats: int = 5, seed: int = 0,
                spec: HardwareSpec = PAPER_GPU,
                comm: CommModel | None = None,
                fused: bool = True, fused_ks=None, fused_per_k: int | None = None,
                sharded: bool = True, shard_fracs=None,
                shard_per_frac: int | None = None,
                progress=None, meta: dict | None = None
                ) -> "CalibrationTable":
        """Run the full offline calibration: kernel sweep + comm fit +
        fused multi-table sweep + sharded-gather sweep (``fused=False``
        / ``sharded=False`` skip a sweep and leave the additive /
        proportional fallback model, like a v1 / v2 artifact)."""
        from repro.profiling import microbench
        grid = {"dims": dims or DEFAULT_GRID["dims"],
                "rows": rows or DEFAULT_GRID["rows"],
                "batches": batches or DEFAULT_GRID["batches"],
                "poolings": poolings or DEFAULT_GRID["poolings"]}
        if use_pallas is None:
            use_pallas = microbench.default_use_pallas()
        if use_pallas:
            # the Pallas kernel pads dims to 128 lanes, so sub-128 dims
            # would all time the identical compiled shape -- collapse the
            # dim axis to the padded dims actually measured, keeping the
            # artifact truthful about its grid
            from repro.kernels.embedding_bag.ops import pad_dim
            grid["dims"] = tuple(sorted({pad_dim(int(d))
                                         for d in grid["dims"]}))
        fwd, bwd = microbench.sweep(grid["dims"], grid["rows"],
                                    grid["batches"], grid["poolings"],
                                    use_pallas=use_pallas, warmup=warmup,
                                    repeats=repeats, seed=seed,
                                    progress=progress)
        if comm is None:
            comm = calibrate_comm(spec=spec, warmup=warmup,
                                  repeats=repeats, seed=seed)
        table = cls(dims=np.asarray(grid["dims"], np.float64),
                    rows=np.asarray(grid["rows"], np.float64),
                    batches=np.asarray(grid["batches"], np.float64),
                    poolings=np.asarray(grid["poolings"], np.float64),
                    fwd_ms=fwd, bwd_ms=bwd, comm=comm,
                    fingerprint=hardware_fingerprint(),
                    meta={"warmup": warmup, "repeats": repeats, "seed": seed,
                          "use_pallas": bool(use_pallas), **(meta or {})})
        if fused:
            table.calibrate_fusion(
                ks=fused_ks or DEFAULT_FUSED_KS,
                per_k=fused_per_k or DEFAULT_FUSED_PER_K,
                use_pallas=use_pallas, warmup=warmup, repeats=repeats,
                seed=seed, progress=progress)
        if sharded:
            table.calibrate_sharding(
                fracs=shard_fracs or DEFAULT_SHARD_FRACS,
                per_frac=shard_per_frac or DEFAULT_SHARD_PER_FRAC,
                use_pallas=use_pallas, warmup=warmup, repeats=repeats,
                seed=seed, progress=progress)
        return table

    def calibrate_fusion(self, *, ks=DEFAULT_FUSED_KS,
                         per_k: int = DEFAULT_FUSED_PER_K,
                         use_pallas: bool | None = None, warmup: int = 1,
                         repeats: int = 5, seed: int = 0, progress=None
                         ) -> None:
        """Measure the fused multi-table sweep over this table's grid and
        fit the forward/backward ``FusionModel`` pair in place.

        Each sweep point stacks K heterogeneous ``(dim, rows, pooling)``
        draws (grid points, so the single-table baseline is
        interpolation-exact) into ONE arena launch at the table's
        largest calibrated batch; the fit explains the measured
        deviation from the sum of the K single-table grid values.
        """
        from repro.profiling import microbench
        batch = int(self.batches[-1])
        points = microbench.sweep_fused(
            self.dims, self.rows, self.poolings, batch, ks=ks,
            per_k=per_k, use_pallas=use_pallas, warmup=warmup,
            repeats=repeats, seed=seed, progress=progress)
        singles_fwd, singles_bwd = [], []
        for pt in points:
            f, b = self.lookup_ms(np.asarray(pt.dims), np.asarray(pt.rows),
                                  batch, np.asarray(pt.poolings))
            singles_fwd.append(f)
            singles_bwd.append(b)
        meas_fwd = np.array([pt.fwd_ms for pt in points])
        meas_bwd = np.array([pt.bwd_ms for pt in points])
        self.fusion_fwd = FusionModel.fit(singles_fwd, meas_fwd)
        self.fusion_bwd = FusionModel.fit(singles_bwd, meas_bwd)
        self.fusion_sweep = {
            "k": np.array([pt.k for pt in points], np.float64),
            "fwd_additive_ms": np.array([f.sum() for f in singles_fwd]),
            "fwd_ms": meas_fwd,
            "bwd_additive_ms": np.array([b.sum() for b in singles_bwd]),
            "bwd_ms": meas_bwd,
        }
        self.meta = {**self.meta, "fused_ks": [int(k) for k in ks],
                     "fused_per_k": int(per_k), "fused_batch": batch}

    def calibrate_sharding(self, *, fracs=DEFAULT_SHARD_FRACS,
                           per_frac: int = DEFAULT_SHARD_PER_FRAC,
                           use_pallas: bool | None = None, warmup: int = 1,
                           repeats: int = 5, seed: int = 0, progress=None
                           ) -> None:
        """Measure the sharded-gather sweep over this table's grid and
        fit the forward/backward ``ShardModel`` pair in place (the v3
        field behind ``MeasuredOracle.evaluate_sharded``).

        Each sweep point times one shape at a partial column width AND
        at its full width (same index stream), so the fit sees exactly
        the ratio the oracle will apply to interpolated full-table
        times.
        """
        from repro.profiling import microbench
        batch = int(self.batches[-1])
        points = microbench.sweep_sharded(
            self.dims, self.rows, self.poolings, batch, fracs=fracs,
            per_frac=per_frac, use_pallas=use_pallas, warmup=warmup,
            repeats=repeats, seed=seed, progress=progress)
        frac = np.array([pt.frac for pt in points])
        self.shard_fwd = ShardModel.fit(
            np.array([pt.full_fwd_ms for pt in points]), frac,
            np.array([pt.fwd_ms for pt in points]))
        self.shard_bwd = ShardModel.fit(
            np.array([pt.full_bwd_ms for pt in points]), frac,
            np.array([pt.bwd_ms for pt in points]))
        self.shard_sweep = {
            "frac": frac,
            "fwd_full_ms": np.array([pt.full_fwd_ms for pt in points]),
            "fwd_ms": np.array([pt.fwd_ms for pt in points]),
            "bwd_full_ms": np.array([pt.full_bwd_ms for pt in points]),
            "bwd_ms": np.array([pt.bwd_ms for pt in points]),
        }
        self.meta = {**self.meta,
                     "shard_fracs": [float(f) for f in fracs],
                     "shard_per_frac": int(per_frac),
                     "shard_batch": batch}

    @classmethod
    def synthetic(cls, spec: HardwareSpec = PAPER_GPU, *, dims=None,
                  rows=None, batches=None, poolings=None
                  ) -> "CalibrationTable":
        """Deterministic table from the analytic ``CostSimulator``: grid
        cells are the simulator's noise-free per-table fused-op cost at
        that shape (uniform access distribution).  No kernels run."""
        from repro.core import features as F
        from repro.sim.costsim import CostSimulator
        grid = {"dims": dims or SMOKE_GRID["dims"],
                "rows": rows or SMOKE_GRID["rows"],
                "batches": batches or SMOKE_GRID["batches"],
                "poolings": poolings or SMOKE_GRID["poolings"]}
        g = {k: np.asarray(v, np.float64) for k, v in grid.items()}
        shape = tuple(g[k].size for k in ("dims", "rows", "batches",
                                          "poolings"))
        fwd = np.zeros(shape)
        bwd = np.zeros(shape)
        dist = np.full((1, F.NUM_DIST_BINS), 1.0 / F.NUM_DIST_BINS)
        for k, b in enumerate(g["batches"]):
            sim = CostSimulator(spec, batch_size=int(b), noise_std=0.0)
            for i, d in enumerate(g["dims"]):
                for j, r in enumerate(g["rows"]):
                    for n, p in enumerate(g["poolings"]):
                        raw = F.pack_features([d], [r], [p], dist)
                        fwd[i, j, k, n] = (spec.comp_overhead_ms
                                           + sim.marginal_fwd_ms(raw)[0])
                        bwd[i, j, k, n] = (spec.comp_overhead_ms
                                           + sim.marginal_bwd_ms(raw)[0])
        return cls(dims=g["dims"], rows=g["rows"], batches=g["batches"],
                   poolings=g["poolings"], fwd_ms=fwd, bwd_ms=bwd,
                   comm=CommModel.from_spec(spec),
                   fingerprint={"backend": "synthetic", "device_kind": spec.name,
                                "n_devices": 0, "platform": "analytic",
                                "machine": "analytic"},
                   meta={"source": "costsim", "spec": spec.name},
                   # the grid cells are the simulator's c0 + marginal, so
                   # the spec's own pipeline constants ARE the matching
                   # fused correction: pricing K co-resident tables
                   # through this model reproduces fused_op_ms modulo the
                   # placement-dependent shared-cache term
                   fusion_fwd=FusionModel.from_spec(spec),
                   fusion_bwd=FusionModel.from_spec(spec),
                   # same reasoning for partial tables: the spec's c0 is
                   # the unsplittable per-gather floor, streaming cost
                   # proportional to columns
                   shard_fwd=ShardModel.from_spec(spec),
                   shard_bwd=ShardModel.from_spec(spec))

    def summary(self) -> str:
        n_pts = self.fwd_ms.size
        return (f"CalibrationTable v{self.version}: {n_pts} kernel points "
                f"(dims {self.dims.astype(int).tolist()}, "
                f"rows {self.rows.astype(int).tolist()}, "
                f"batches {self.batches.astype(int).tolist()}, "
                f"poolings {self.poolings.astype(int).tolist()}), "
                f"comm {self.comm.source} alpha={self.comm.alpha_ms:.4f}ms "
                f"beta={self.comm.beta_ms_per_mb:.4f}ms/MB, "
                f"fusion fwd {self.fusion_fwd.source}"
                f" c0={self.fusion_fwd.overhead_ms:.4f}ms"
                f"/bwd c0={self.fusion_bwd.overhead_ms:.4f}ms, "
                f"shard fwd {self.shard_fwd.source}"
                f" o={self.shard_fwd.overhead_ms:.4f}ms"
                f"/bwd o={self.shard_bwd.overhead_ms:.4f}ms, "
                f"hw={self.fingerprint.get('backend')}/"
                f"{self.fingerprint.get('device_kind')}")


def load_or_none(path: str | None = None) -> CalibrationTable | None:
    """Load the artifact if present and readable, else ``None`` (a
    corrupt/stale artifact means "re-measure", never a crash)."""
    import zipfile
    path = default_artifact_path() if path is None else path
    if not os.path.exists(path):
        return None
    try:
        return CalibrationTable.load(path)
    except (ValueError, OSError, KeyError, json.JSONDecodeError,
            zipfile.BadZipFile):
        return None
