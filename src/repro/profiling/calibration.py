"""Persisted calibration artifact: measured kernel/collective costs.

A ``CalibrationTable`` holds the micro-benchmark grids from
``repro.profiling.microbench`` (per-shape forward/backward kernel
milliseconds over ``(dim, rows, batch, pooling)``), the fitted
``CommModel`` from ``repro.profiling.collectives``, a hardware
fingerprint, and a format version.  It persists as a single ``.npz``
(arrays raw, scalar metadata JSON-encoded) and answers interpolation
queries: per-table costs are *multilinear in log2-space* over the grid,
clamped to the grid's convex hull (out-of-range queries snap to the
nearest edge -- calibrate a wider grid if that matters).

``CalibrationTable.synthetic`` builds a deterministic table from the
analytic ``CostSimulator`` instead of measuring -- the bridge used by
tests and by sim-vs-measured comparisons where hardware timing noise
would make assertions flaky.
"""

from __future__ import annotations

import dataclasses
import itertools
import json
import os

import numpy as np

from repro.profiling.collectives import CommModel, calibrate_comm
from repro.sim.hardware import HardwareSpec, PAPER_GPU

CALIBRATION_VERSION = 1

# tiny CI-friendly grid (--smoke); dims stay unpadded so CPU reference
# timings actually differ per point (the Pallas path pads to 128 lanes)
SMOKE_GRID = {
    "dims": (16, 64, 256),
    "rows": (256, 4096),
    "batches": (32,),
    "poolings": (2, 8),
}

# moderate default grid for a real offline calibration run
DEFAULT_GRID = {
    "dims": (16, 64, 128, 256, 512),
    "rows": (1024, 16384, 262144),
    "batches": (1024, 16384),
    "poolings": (2, 8, 32),
}


def default_artifact_path() -> str:
    """Artifact location: ``$REPRO_CALIBRATION`` or the scratch dir that
    CI caches between runs (gitignored)."""
    return os.environ.get("REPRO_CALIBRATION",
                          os.path.join("artifacts", "calibration",
                                       "calibration.npz"))


def hardware_fingerprint() -> dict:
    """What hardware produced a measurement (artifact staleness check)."""
    import platform
    import jax
    devs = jax.devices()
    return {
        "backend": jax.default_backend(),
        "device_kind": devs[0].device_kind,
        "n_devices": len(devs),
        "platform": platform.platform(),
        "machine": platform.machine(),
    }


def _axis_weights(grid: np.ndarray, x: np.ndarray):
    """Per-query ``(lo, hi, w)`` along one log2-spaced axis, clamped to
    the grid range; a singleton axis contributes weight 0 at index 0."""
    g = np.asarray(grid, dtype=np.float64)
    x = np.clip(np.asarray(x, dtype=np.float64), g[0], g[-1])
    if g.size == 1:
        z = np.zeros(x.shape, dtype=np.int64)
        return z, z, np.zeros(x.shape)
    pos = np.interp(np.log2(np.maximum(x, 1e-9)), np.log2(g),
                    np.arange(g.size, dtype=np.float64))
    lo = np.minimum(pos.astype(np.int64), g.size - 2)
    return lo, lo + 1, pos - lo


@dataclasses.dataclass
class CalibrationTable:
    """Measured (or synthetic) kernel/collective cost grids + provenance."""

    dims: np.ndarray        # (Nd,) strictly increasing
    rows: np.ndarray        # (Nr,)
    batches: np.ndarray     # (Nb,)
    poolings: np.ndarray    # (Np,)
    fwd_ms: np.ndarray      # (Nd, Nr, Nb, Np)
    bwd_ms: np.ndarray      # (Nd, Nr, Nb, Np)
    comm: CommModel
    fingerprint: dict
    version: int = CALIBRATION_VERSION
    meta: dict = dataclasses.field(default_factory=dict)

    def __post_init__(self):
        for name in ("dims", "rows", "batches", "poolings"):
            g = np.asarray(getattr(self, name), dtype=np.float64)
            if g.ndim != 1 or g.size == 0 or np.any(np.diff(g) <= 0) \
                    or g[0] <= 0:
                raise ValueError(f"{name} must be positive and strictly "
                                 f"increasing, got {g}")
            setattr(self, name, g)
        shape = (self.dims.size, self.rows.size, self.batches.size,
                 self.poolings.size)
        self.fwd_ms = np.asarray(self.fwd_ms, dtype=np.float64)
        self.bwd_ms = np.asarray(self.bwd_ms, dtype=np.float64)
        if self.fwd_ms.shape != shape or self.bwd_ms.shape != shape:
            raise ValueError(f"cost grids must have shape {shape}, got "
                             f"{self.fwd_ms.shape} / {self.bwd_ms.shape}")

    # ---- interpolation -----------------------------------------------------

    def _corner_weights(self, dim, rows, batch, pooling):
        """Per-query corner indices and axis weights, shared by every grid
        interpolated at the same query points."""
        q = np.broadcast_arrays(np.asarray(dim, np.float64),
                                np.asarray(rows, np.float64),
                                np.asarray(batch, np.float64),
                                np.asarray(pooling, np.float64))
        axes = (self.dims, self.rows, self.batches, self.poolings)
        los, his, ws = zip(*(_axis_weights(g, x) for g, x in zip(axes, q)))
        return q[0].shape, los, his, ws

    def _interp_grids(self, tables, shape, los, his, ws):
        """Multilinear blend of one or more grids over shared corner
        weights: the 16 corner weight products are computed once however
        many grids are queried."""
        outs = [np.zeros(shape) for _ in tables]
        for corner in itertools.product((0, 1), repeat=4):
            idx = tuple(his[i] if c else los[i]
                        for i, c in enumerate(corner))
            w = np.ones(shape)
            for i, c in enumerate(corner):
                w = w * (ws[i] if c else 1.0 - ws[i])
            for out, table in zip(outs, tables):
                out += w * table[idx]
        return outs

    def _interp(self, table: np.ndarray, dim, rows, batch, pooling):
        shape, los, his, ws = self._corner_weights(dim, rows, batch, pooling)
        return self._interp_grids((table,), shape, los, his, ws)[0]

    def fwd_lookup_ms(self, dim, rows, batch, pooling) -> np.ndarray:
        """Interpolated forward kernel time (ms) per query (vectorized)."""
        return self._interp(self.fwd_ms, dim, rows, batch, pooling)

    def bwd_lookup_ms(self, dim, rows, batch, pooling) -> np.ndarray:
        """Interpolated backward (scatter-add) time (ms) per query."""
        return self._interp(self.bwd_ms, dim, rows, batch, pooling)

    def lookup_ms(self, dim, rows, batch, pooling
                  ) -> tuple[np.ndarray, np.ndarray]:
        """Interpolated ``(fwd, bwd)`` kernel times per query in ONE pass:
        both grids share the corner-weight computation (the batched
        ``MeasuredOracle`` hot path)."""
        shape, los, his, ws = self._corner_weights(dim, rows, batch, pooling)
        fwd, bwd = self._interp_grids((self.fwd_ms, self.bwd_ms),
                                      shape, los, his, ws)
        return fwd, bwd

    def comm_ms(self, payload_mb) -> np.ndarray:
        """Fitted alpha-beta all-to-all time per per-device payload."""
        return self.comm.comm_ms(payload_mb)

    # ---- persistence -------------------------------------------------------

    def save(self, path: str) -> str:
        if not path.endswith(".npz"):
            path += ".npz"                # np.savez appends it anyway
        os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
        scalar = {"comm": self.comm.to_dict(),
                  "fingerprint": self.fingerprint,
                  "version": self.version,
                  "meta": self.meta}
        # atomic: an interrupted calibration must not leave a truncated
        # artifact behind for the next loader
        tmp = path + ".tmp.npz"
        np.savez(tmp, dims=self.dims, rows=self.rows,
                 batches=self.batches, poolings=self.poolings,
                 fwd_ms=self.fwd_ms, bwd_ms=self.bwd_ms,
                 scalar_json=np.array(json.dumps(scalar)))
        os.replace(tmp, path)
        return path

    @classmethod
    def load(cls, path: str) -> "CalibrationTable":
        with np.load(path, allow_pickle=False) as z:
            scalar = json.loads(str(z["scalar_json"]))
            if scalar["version"] > CALIBRATION_VERSION:
                raise ValueError(
                    f"calibration artifact {path} has version "
                    f"{scalar['version']} > supported {CALIBRATION_VERSION};"
                    " upgrade the code or re-calibrate")
            return cls(dims=z["dims"], rows=z["rows"], batches=z["batches"],
                       poolings=z["poolings"], fwd_ms=z["fwd_ms"],
                       bwd_ms=z["bwd_ms"],
                       comm=CommModel.from_dict(scalar["comm"]),
                       fingerprint=scalar["fingerprint"],
                       version=scalar["version"], meta=scalar["meta"])

    # ---- construction ------------------------------------------------------

    @classmethod
    def measure(cls, *, dims=None, rows=None, batches=None, poolings=None,
                use_pallas: bool | None = None, warmup: int = 1,
                repeats: int = 5, seed: int = 0,
                spec: HardwareSpec = PAPER_GPU,
                comm: CommModel | None = None,
                progress=None, meta: dict | None = None
                ) -> "CalibrationTable":
        """Run the full offline calibration: kernel sweep + comm fit."""
        from repro.profiling import microbench
        grid = {"dims": dims or DEFAULT_GRID["dims"],
                "rows": rows or DEFAULT_GRID["rows"],
                "batches": batches or DEFAULT_GRID["batches"],
                "poolings": poolings or DEFAULT_GRID["poolings"]}
        if use_pallas is None:
            use_pallas = microbench.default_use_pallas()
        if use_pallas:
            # the Pallas kernel pads dims to 128 lanes, so sub-128 dims
            # would all time the identical compiled shape -- collapse the
            # dim axis to the padded dims actually measured, keeping the
            # artifact truthful about its grid
            from repro.kernels.embedding_bag.ops import pad_dim
            grid["dims"] = tuple(sorted({pad_dim(int(d))
                                         for d in grid["dims"]}))
        fwd, bwd = microbench.sweep(grid["dims"], grid["rows"],
                                    grid["batches"], grid["poolings"],
                                    use_pallas=use_pallas, warmup=warmup,
                                    repeats=repeats, seed=seed,
                                    progress=progress)
        if comm is None:
            comm = calibrate_comm(spec=spec, warmup=warmup,
                                  repeats=repeats, seed=seed)
        return cls(dims=np.asarray(grid["dims"], np.float64),
                   rows=np.asarray(grid["rows"], np.float64),
                   batches=np.asarray(grid["batches"], np.float64),
                   poolings=np.asarray(grid["poolings"], np.float64),
                   fwd_ms=fwd, bwd_ms=bwd, comm=comm,
                   fingerprint=hardware_fingerprint(),
                   meta={"warmup": warmup, "repeats": repeats, "seed": seed,
                         "use_pallas": bool(use_pallas), **(meta or {})})

    @classmethod
    def synthetic(cls, spec: HardwareSpec = PAPER_GPU, *, dims=None,
                  rows=None, batches=None, poolings=None
                  ) -> "CalibrationTable":
        """Deterministic table from the analytic ``CostSimulator``: grid
        cells are the simulator's noise-free per-table fused-op cost at
        that shape (uniform access distribution).  No kernels run."""
        from repro.core import features as F
        from repro.sim.costsim import CostSimulator
        grid = {"dims": dims or SMOKE_GRID["dims"],
                "rows": rows or SMOKE_GRID["rows"],
                "batches": batches or SMOKE_GRID["batches"],
                "poolings": poolings or SMOKE_GRID["poolings"]}
        g = {k: np.asarray(v, np.float64) for k, v in grid.items()}
        shape = tuple(g[k].size for k in ("dims", "rows", "batches",
                                          "poolings"))
        fwd = np.zeros(shape)
        bwd = np.zeros(shape)
        dist = np.full((1, F.NUM_DIST_BINS), 1.0 / F.NUM_DIST_BINS)
        for k, b in enumerate(g["batches"]):
            sim = CostSimulator(spec, batch_size=int(b), noise_std=0.0)
            for i, d in enumerate(g["dims"]):
                for j, r in enumerate(g["rows"]):
                    for l, p in enumerate(g["poolings"]):
                        raw = F.pack_features([d], [r], [p], dist)
                        fwd[i, j, k, l] = (spec.comp_overhead_ms
                                           + sim.marginal_fwd_ms(raw)[0])
                        bwd[i, j, k, l] = (spec.comp_overhead_ms
                                           + sim.marginal_bwd_ms(raw)[0])
        return cls(dims=g["dims"], rows=g["rows"], batches=g["batches"],
                   poolings=g["poolings"], fwd_ms=fwd, bwd_ms=bwd,
                   comm=CommModel.from_spec(spec),
                   fingerprint={"backend": "synthetic", "device_kind": spec.name,
                                "n_devices": 0, "platform": "analytic",
                                "machine": "analytic"},
                   meta={"source": "costsim", "spec": spec.name})

    def summary(self) -> str:
        n_pts = self.fwd_ms.size
        return (f"CalibrationTable v{self.version}: {n_pts} kernel points "
                f"(dims {self.dims.astype(int).tolist()}, "
                f"rows {self.rows.astype(int).tolist()}, "
                f"batches {self.batches.astype(int).tolist()}, "
                f"poolings {self.poolings.astype(int).tolist()}), "
                f"comm {self.comm.source} alpha={self.comm.alpha_ms:.4f}ms "
                f"beta={self.comm.beta_ms_per_mb:.4f}ms/MB, "
                f"hw={self.fingerprint.get('backend')}/"
                f"{self.fingerprint.get('device_kind')}")


def load_or_none(path: str | None = None) -> CalibrationTable | None:
    """Load the artifact if present and readable, else ``None`` (a
    corrupt/stale artifact means "re-measure", never a crash)."""
    import zipfile
    path = default_artifact_path() if path is None else path
    if not os.path.exists(path):
        return None
    try:
        return CalibrationTable.load(path)
    except (ValueError, OSError, KeyError, json.JSONDecodeError,
            zipfile.BadZipFile):
        return None
