"""All-to-all / all-gather measurement harness and alpha-beta comm model.

Embedding redistribution cost is dominated by the forward/backward
all-to-all (paper App. A.4).  This module measures that collective over
the real ``jax.devices()`` mesh via ``shard_map`` at a sweep of payload
sizes and fits the classic alpha-beta model

    t(p) = alpha_ms + beta_ms_per_mb * p          (p = per-device MB sent)

so a measured oracle can price communication with two scalars.  On a
single-device host (CPU CI) there is no collective to time, so the
harness falls back to a *seeded synthetic trace* generated from a
``HardwareSpec``'s analytic bandwidth -- same fitting path, deterministic
output, clearly labelled ``source="synthetic"``.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.sim.hardware import HardwareSpec, PAPER_GPU

# per-device payload sizes (MB) swept by default
DEFAULT_PAYLOAD_MB = (0.25, 0.5, 1.0, 2.0, 4.0, 8.0)


@dataclasses.dataclass(frozen=True)
class CommModel:
    """Fitted alpha-beta latency/bandwidth model for one collective."""

    alpha_ms: float          # fixed launch/latency term
    beta_ms_per_mb: float    # inverse effective bandwidth
    n_devices: int           # mesh size the fit was taken on
    source: str = "synthetic"          # "measured" | "synthetic"
    payload_mb: tuple = ()             # the fitted trace, for provenance
    times_ms: tuple = ()

    def comm_ms(self, payload_mb) -> np.ndarray:
        """Predicted per-device all-to-all time; zero payload costs zero
        (a device with no tables never enters the collective)."""
        p = np.asarray(payload_mb, dtype=np.float64)
        return np.where(p > 0.0,
                        self.alpha_ms + self.beta_ms_per_mb * p, 0.0)

    @classmethod
    def from_spec(cls, spec: HardwareSpec = PAPER_GPU,
                  n_devices: int = 0) -> "CommModel":
        """Analytic model from a hardware spec (no measurement): alpha is
        the spec's launch overhead, beta the inverse a2a bandwidth
        (GB/s -> ms/MB is exactly ``1 / bw``)."""
        return cls(alpha_ms=spec.comm_overhead_ms,
                   beta_ms_per_mb=1.0 / spec.a2a_bw_gbs,
                   n_devices=n_devices, source="synthetic")

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: dict) -> "CommModel":
        d = dict(d)
        d["payload_mb"] = tuple(d.get("payload_mb", ()))
        d["times_ms"] = tuple(d.get("times_ms", ()))
        return cls(**d)


def fit_alpha_beta(payload_mb, times_ms) -> tuple[float, float]:
    """Least-squares fit of ``t = alpha + beta * p`` (both clamped >= 0:
    measurement noise can push the intercept slightly negative)."""
    p = np.asarray(payload_mb, dtype=np.float64)
    t = np.asarray(times_ms, dtype=np.float64)
    A = np.stack([np.ones_like(p), p], axis=1)
    (alpha, beta), *_ = np.linalg.lstsq(A, t, rcond=None)
    return float(max(alpha, 0.0)), float(max(beta, 0.0))


def synthetic_trace(payload_mb, *, spec: HardwareSpec = PAPER_GPU,
                    noise_std: float = 0.03, seed: int = 0) -> np.ndarray:
    """Seeded stand-in trace for hosts with no multi-device mesh: the
    spec's analytic alpha-beta times under log-normal jitter."""
    rng = np.random.default_rng(seed)
    p = np.asarray(payload_mb, dtype=np.float64)
    base = spec.comm_overhead_ms + p / spec.a2a_bw_gbs
    return base * np.exp(rng.normal(0.0, noise_std, size=base.shape))


def measure_all_to_all(payload_mb, *, devices=None, warmup: int = 1,
                       repeats: int = 5, dim: int = 128) -> np.ndarray:
    """Time ``jax.lax.all_to_all`` over the real device mesh at each
    per-device payload size (MB sent per device).  Requires >= 2 devices.
    """
    import jax
    import jax.numpy as jnp
    from jax.sharding import Mesh, PartitionSpec as P
    from repro.embedding.sharded import shard_map
    from repro.profiling.microbench import median_time_ms

    devices = list(jax.devices()) if devices is None else list(devices)
    n = len(devices)
    if n < 2:
        raise ValueError(
            f"all-to-all needs >= 2 devices, have {n}; use synthetic_trace")
    mesh = Mesh(np.asarray(devices), ("x",))

    def local(x):
        return jax.lax.all_to_all(x, "x", split_axis=0, concat_axis=0,
                                  tiled=True)

    fn = jax.jit(shard_map(local, mesh=mesh, in_specs=P("x"),
                           out_specs=P("x"), check_vma=False))
    times = []
    for mb in payload_mb:
        # each device holds `rows` fp32 rows of width `dim` and sends
        # (n-1)/n of them -> choose rows so the sent volume is `mb` MB
        send_bytes = mb * 1e6
        rows = max(n, int(send_bytes * n / max(n - 1, 1) / (4 * dim)))
        rows -= rows % n                      # all_to_all splits rows n-ways
        rows = max(rows, n)
        x = jnp.zeros((n * rows, dim), jnp.float32)
        times.append(median_time_ms(fn, (x,), warmup=warmup,
                                    repeats=repeats))
    return np.asarray(times)


def calibrate_comm(*, spec: HardwareSpec = PAPER_GPU, payload_mb=None,
                   devices=None, warmup: int = 1, repeats: int = 5,
                   seed: int = 0) -> CommModel:
    """Measure (multi-device) or synthesize (single-device) an all-to-all
    trace and fit the alpha-beta model."""
    import jax
    payload_mb = DEFAULT_PAYLOAD_MB if payload_mb is None else payload_mb
    devices = list(jax.devices()) if devices is None else list(devices)
    if len(devices) >= 2:
        times = measure_all_to_all(payload_mb, devices=devices,
                                   warmup=warmup, repeats=repeats)
        source = "measured"
    else:
        times = synthetic_trace(payload_mb, spec=spec, seed=seed)
        source = "synthetic"
    alpha, beta = fit_alpha_beta(payload_mb, times)
    return CommModel(alpha_ms=alpha, beta_ms_per_mb=beta,
                     n_devices=len(devices), source=source,
                     payload_mb=tuple(float(p) for p in payload_mb),
                     times_ms=tuple(float(t) for t in times))
