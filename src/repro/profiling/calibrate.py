"""Offline calibration CLI.

  PYTHONPATH=src python -m repro.profiling.calibrate [--smoke] [--out PATH]

Sweeps the embedding-bag kernels over a ``(dim, rows, batch, pooling)``
grid, measures (or synthesizes, single-device) the all-to-all alpha-beta
model, and persists a versioned ``CalibrationTable`` artifact that
``repro.api.MeasuredOracle`` interpolates at zero kernel launches per
``evaluate``.

If the artifact already exists with the same format version, hardware
fingerprint, and grid, the run is a no-op (CI caches the artifact
between runs); ``--force`` re-measures unconditionally.
"""

from __future__ import annotations

import argparse
import sys
import time

import numpy as np


def _ints(csv: str) -> tuple[int, ...]:
    return tuple(int(x) for x in csv.split(",") if x.strip())


def _floats(csv: str) -> tuple[float, ...]:
    return tuple(float(x) for x in csv.split(",") if x.strip())


def build_parser() -> argparse.ArgumentParser:
    from repro.profiling.calibration import default_artifact_path
    ap = argparse.ArgumentParser(
        prog="python -m repro.profiling.calibrate",
        description="Measure kernel/collective costs into a calibration "
                    "artifact for MeasuredOracle.")
    ap.add_argument("--out", default=default_artifact_path(),
                    help="artifact path (default: %(default)s, "
                         "override via $REPRO_CALIBRATION)")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny grid + few repeats (CI / smoke testing)")
    ap.add_argument("--dims", type=_ints, default=None)
    ap.add_argument("--rows", type=_ints, default=None)
    ap.add_argument("--batches", type=_ints, default=None)
    ap.add_argument("--poolings", type=_ints, default=None)
    ap.add_argument("--fused-ks", type=_ints, default=None,
                    help="fusion depths for the fused multi-table sweep "
                         "(default 2,4,8; 2,4 in --smoke)")
    ap.add_argument("--fused-per-k", type=int, default=None,
                    help="heterogeneous draws per fusion depth "
                         "(default 4; 3 in --smoke)")
    ap.add_argument("--no-fused", action="store_true",
                    help="skip the fused sweep (additive fusion model, "
                         "like a v1 artifact)")
    ap.add_argument("--shard-fracs", type=_floats, default=None,
                    help="column fractions for the sharded-gather sweep "
                         "(default 0.25,0.5,0.75; 0.5 in --smoke)")
    ap.add_argument("--shard-per-frac", type=int, default=None,
                    help="heterogeneous draws per column fraction "
                         "(default 3; 2 in --smoke)")
    ap.add_argument("--no-sharded", action="store_true",
                    help="skip the sharded-gather sweep (proportional "
                         "partial-table model, like a v2 artifact)")
    ap.add_argument("--warmup", type=int, default=1)
    ap.add_argument("--repeats", type=int, default=None,
                    help="timing repeats per shape (default 5; 2 in --smoke)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--pallas", choices=("auto", "on", "off"), default="auto",
                    help="time the Pallas kernel (auto: only on TPU)")
    ap.add_argument("--force", action="store_true",
                    help="re-measure even if a matching artifact exists")
    ap.add_argument("--quiet", action="store_true")
    ap.add_argument("--trace", default=None, metavar="PATH",
                    help="record telemetry during the sweep and export a "
                         "trace on exit (.jsonl -> event log, else Chrome "
                         "trace JSON)")
    return ap


def _resolve_grid(args) -> dict:
    from repro.profiling.calibration import DEFAULT_GRID, SMOKE_GRID
    base = SMOKE_GRID if args.smoke else DEFAULT_GRID
    return {k: tuple(getattr(args, k) or base[k])
            for k in ("dims", "rows", "batches", "poolings")}


def _up_to_date(path: str, grid: dict, fused_cfg: tuple | None,
                shard_cfg: tuple | None) -> bool:
    from repro.profiling.calibration import (CALIBRATION_VERSION,
                                             hardware_fingerprint,
                                             load_or_none)
    table = load_or_none(path)
    if table is None or table.version != CALIBRATION_VERSION:
        return False
    if table.fingerprint != hardware_fingerprint():
        return False
    if fused_cfg is not None:
        # a fused run must find ITS fused sweep in the artifact --
        # re-running with different ks/per-k (or after --no-fused) is a
        # re-measure, not a silent no-op.  --no-fused against a fused
        # artifact stays a no-op: the artifact is a superset.
        ks, per_k = fused_cfg
        if table.meta.get("fused_ks") != [int(k) for k in ks] \
                or table.meta.get("fused_per_k") != int(per_k):
            return False
    if shard_cfg is not None:
        # same contract for the sharded-gather sweep
        fracs, per_frac = shard_cfg
        if table.meta.get("shard_fracs") != [float(f) for f in fracs] \
                or table.meta.get("shard_per_frac") != int(per_frac):
            return False
    return all(np.array_equal(getattr(table, k),
                              np.asarray(grid[k], np.float64))
               for k in ("dims", "rows", "batches", "poolings"))


def main(argv=None) -> int:
    args = build_parser().parse_args(argv)
    from repro import telemetry as tele
    with tele.trace_to(args.trace, quiet=args.quiet):
        return _main_impl(args)


def _main_impl(args) -> int:
    from repro.profiling.calibration import (CALIBRATION_VERSION,
                                             CalibrationTable,
                                             DEFAULT_FUSED_KS,
                                             DEFAULT_FUSED_PER_K,
                                             DEFAULT_SHARD_FRACS,
                                             DEFAULT_SHARD_PER_FRAC,
                                             load_or_none)
    from repro.profiling.microbench import default_use_pallas
    grid = _resolve_grid(args)
    say = (lambda *a: None) if args.quiet else \
        (lambda *a: print(*a, flush=True))

    use_pallas = {"auto": None, "on": True, "off": False}[args.pallas]
    resolved_pallas = default_use_pallas() if use_pallas is None \
        else use_pallas
    if resolved_pallas:
        # mirror CalibrationTable.measure: the Pallas kernel pads dims to
        # 128 lanes, so the measured (and stored) dim axis is the padded,
        # deduplicated one -- compare against that for the no-op check
        from repro.kernels.embedding_bag.ops import pad_dim
        grid["dims"] = tuple(sorted({pad_dim(int(d))
                                     for d in grid["dims"]}))

    fused_ks = args.fused_ks or ((2, 4) if args.smoke else DEFAULT_FUSED_KS)
    fused_per_k = args.fused_per_k or (3 if args.smoke
                                       else DEFAULT_FUSED_PER_K)
    fused_cfg = None if args.no_fused else (fused_ks, fused_per_k)
    shard_fracs = args.shard_fracs or ((0.5,) if args.smoke
                                       else DEFAULT_SHARD_FRACS)
    shard_per_frac = args.shard_per_frac or (2 if args.smoke
                                             else DEFAULT_SHARD_PER_FRAC)
    shard_cfg = None if args.no_sharded else (shard_fracs, shard_per_frac)

    import warnings
    with warnings.catch_warnings():   # a stale v1/v2 artifact warns on load;
        warnings.simplefilter("ignore")  # we print our own message below
        up_to_date = _up_to_date(args.out, grid, fused_cfg, shard_cfg)
        stale = None if up_to_date else load_or_none(args.out)
    if not args.force and up_to_date:
        say(f"[calibrate] {args.out} is up to date "
            "(version/fingerprint/grid match); use --force to re-measure")
        return 0
    if stale is not None and stale.version < CALIBRATION_VERSION:
        missing = ("no fused multi-table sweep"
                   if stale.version < 2 else "no sharded-gather sweep")
        say(f"[calibrate] {args.out} is schema v{stale.version} "
            f"(< v{CALIBRATION_VERSION}: {missing}) -- re-measuring")

    repeats = args.repeats if args.repeats is not None \
        else (2 if args.smoke else 5)
    n_shapes = int(np.prod([len(v) for v in grid.values()]))
    say(f"[calibrate] sweeping {n_shapes} kernel shapes "
        f"(repeats={repeats}, pallas={args.pallas}) ...")

    def _progress(pt):
        if hasattr(pt, "dims"):                       # FusedBenchPoint
            print(f"  fused k={pt.k} dims={list(pt.dims)} "
                  f"rows={list(pt.rows)} pools={list(pt.poolings)} "
                  f"fwd={pt.fwd_ms:.4f}ms bwd={pt.bwd_ms:.4f}ms", flush=True)
        elif hasattr(pt, "frac"):                     # ShardBenchPoint
            print(f"  shard dim={pt.dim:<4d} width={pt.width:<4d} "
                  f"rows={pt.rows:<7d} pool={pt.pooling:<3d} "
                  f"fwd={pt.fwd_ms:.4f}/{pt.full_fwd_ms:.4f}ms "
                  f"bwd={pt.bwd_ms:.4f}/{pt.full_bwd_ms:.4f}ms", flush=True)
        else:
            print(f"  dim={pt.dim:<4d} rows={pt.rows:<7d} "
                  f"batch={pt.batch:<6d} pool={pt.pooling:<3d} "
                  f"fwd={pt.fwd_ms:.4f}ms bwd={pt.bwd_ms:.4f}ms", flush=True)

    t0 = time.perf_counter()
    from repro import telemetry as tele
    with tele.span("calibrate.sweep", shapes=n_shapes, repeats=repeats):
        table = CalibrationTable.measure(
            **grid, use_pallas=use_pallas, warmup=args.warmup,
            repeats=repeats, seed=args.seed, fused=not args.no_fused,
            fused_ks=fused_ks, fused_per_k=fused_per_k,
            sharded=not args.no_sharded, shard_fracs=shard_fracs,
            shard_per_frac=shard_per_frac,
            progress=None if args.quiet else _progress,
            meta={"cli": True, "smoke": bool(args.smoke)})
    path = table.save(args.out)
    say(f"[calibrate] {table.summary()}")
    if not args.no_fused:
        say(f"[calibrate] fusion fwd {table.fusion_fwd.summary()}")
        say(f"[calibrate] fusion bwd {table.fusion_bwd.summary()}")
    if not args.no_sharded:
        say(f"[calibrate] shard fwd {table.shard_fwd.summary()}")
        say(f"[calibrate] shard bwd {table.shard_bwd.summary()}")
    say(f"[calibrate] wrote {path} in {time.perf_counter() - t0:.1f}s")
    return 0


if __name__ == "__main__":
    sys.exit(main())
