"""Compiled-kernel micro-benchmark harness for the embedding-bag op.

AutoShard-style offline data collector: sweeps the fused
``kernels/embedding_bag`` forward and scatter-add backward over a grid
of ``(dim, rows, batch, pooling)`` shapes with proper warmup and
median-of-k timing.  The resulting grid feeds a persisted
``CalibrationTable`` (see ``repro.profiling.calibration``) that measured
cost oracles *interpolate* -- kernels are timed once here, offline,
never inside an ``evaluate`` call.

``measure_placement`` preserves the old per-``evaluate`` live timing
loop (the pre-subsystem ``KernelOracle`` behaviour) for validation and
for the before/after comparison in ``benchmarks/b5_sim2real.py``.

jax is imported lazily so the CLI and the calibration artifact loader
stay light.
"""

from __future__ import annotations

import dataclasses
import time

import numpy as np

from repro.sim.hardware import HardwareSpec, PAPER_GPU


def default_use_pallas() -> bool:
    """Compiled Pallas kernel on TPU, jnp reference elsewhere (the Pallas
    op only *interprets* on CPU, which times the interpreter, not HW)."""
    import jax
    return jax.default_backend() == "tpu"


def median_time_ms(fn, args, *, warmup: int = 1, repeats: int = 5) -> float:
    """Median wall time (ms) of ``fn(*args)`` over ``repeats`` runs after
    ``warmup`` untimed calls (the first of which pays compilation)."""
    import jax
    for _ in range(max(1, warmup)):
        jax.block_until_ready(fn(*args))
    times = []
    for _ in range(max(1, repeats)):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        times.append((time.perf_counter() - t0) * 1e3)
    return float(np.median(times))


@dataclasses.dataclass(frozen=True)
class BenchPoint:
    """One measured grid point (times in milliseconds)."""

    dim: int
    rows: int
    batch: int
    pooling: int
    fwd_ms: float
    bwd_ms: float


def make_inputs(dim: int, rows: int, batch: int, pooling: int,
                seed: int = 0):
    """(arena, indices, grad_out) for one benchmark shape.

    Arena row 0 is the zero row (never indexed here); indices follow a
    zipf-ish reuse pattern like real lookup streams, seeded for
    reproducible index working sets.
    """
    import jax.numpy as jnp
    rng = np.random.default_rng(seed)
    arena = jnp.zeros((rows + 1, dim), jnp.float32)
    draws = rng.zipf(1.5, size=(batch, pooling))
    idx = jnp.asarray(1 + draws % rows, jnp.int32)
    g = jnp.ones((batch, dim), jnp.float32)
    return arena, idx, g


def bench_shape(dim: int, rows: int, batch: int, pooling: int, *,
                use_pallas: bool | None = None, warmup: int = 1,
                repeats: int = 5, seed: int = 0) -> BenchPoint:
    """Time the forward and backward kernels at one grid point."""
    import jax
    from repro.kernels.embedding_bag.ops import pad_dim
    from repro.kernels.embedding_bag.ref import (embedding_bag_grad_ref,
                                                 embedding_bag_ref)
    if use_pallas is None:
        use_pallas = default_use_pallas()
    if use_pallas:
        from repro.kernels.embedding_bag.ops import embedding_bag
        dim = pad_dim(dim)                 # Pallas lanes are 128-wide
        fwd_fn = jax.jit(embedding_bag)
    else:
        fwd_fn = jax.jit(embedding_bag_ref)
    bwd_fn = jax.jit(embedding_bag_grad_ref, static_argnums=0)

    arena, idx, g = make_inputs(dim, rows, batch, pooling, seed=seed)
    fwd_ms = median_time_ms(fwd_fn, (arena, idx),
                            warmup=warmup, repeats=repeats)
    bwd_ms = median_time_ms(bwd_fn, (arena.shape, idx, g),
                            warmup=warmup, repeats=repeats)
    return BenchPoint(dim=int(dim), rows=int(rows), batch=int(batch),
                      pooling=int(pooling), fwd_ms=fwd_ms, bwd_ms=bwd_ms)


def sweep(dims, rows, batches, poolings, *, use_pallas: bool | None = None,
          warmup: int = 1, repeats: int = 5, seed: int = 0,
          progress=None) -> tuple[np.ndarray, np.ndarray]:
    """Dense grid sweep -> ``(fwd_ms, bwd_ms)`` arrays of shape
    ``(len(dims), len(rows), len(batches), len(poolings))``.

    ``progress`` (optional) is called with each finished ``BenchPoint``.
    """
    shape = (len(dims), len(rows), len(batches), len(poolings))
    fwd = np.zeros(shape)
    bwd = np.zeros(shape)
    for i, d in enumerate(dims):
        for j, r in enumerate(rows):
            for k, b in enumerate(batches):
                for l, p in enumerate(poolings):
                    pt = bench_shape(int(d), int(r), int(b), int(p),
                                     use_pallas=use_pallas, warmup=warmup,
                                     repeats=repeats, seed=seed)
                    fwd[i, j, k, l] = pt.fwd_ms
                    bwd[i, j, k, l] = pt.bwd_ms
                    if progress is not None:
                        progress(pt)
    return fwd, bwd


def measure_placement(raw: np.ndarray, assignment: np.ndarray,
                      n_devices: int, *, spec: HardwareSpec = PAPER_GPU,
                      batch_size: int = 64, pooling: int = 4,
                      max_rows: int = 4096, repeats: int = 2,
                      use_pallas: bool = False, seed: int = 0):
    """LIVE per-placement measurement: the old ``KernelOracle.evaluate``
    timing loop, preserved as a validation/baseline path.

    Builds a per-device arena, synthesizes zipf-ish lookups, and times
    forward + backward kernels for every device group -- slow and noisy
    by construction (this is exactly what the calibration subsystem
    replaces).  Communication reuses the simulator's analytic model.
    """
    import jax.numpy as jnp
    from repro.core import features as F
    from repro.kernels.embedding_bag.ref import (embedding_bag_grad_ref,
                                                 embedding_bag_ref)
    from repro.sim.costsim import CostSimulator, SimResult, placement_digest
    if use_pallas:
        from repro.kernels.embedding_bag.ops import embedding_bag

    raw = np.asarray(raw, dtype=np.float64)
    assignment = np.asarray(assignment)
    rng = np.random.default_rng(
        placement_digest(raw, assignment, n_devices) ^ seed)
    dim = max(128, int(np.ceil(raw[:, F.DIM].max() / 128) * 128))
    fwd = np.zeros(n_devices)
    bwd = np.zeros(n_devices)
    dim_sums = np.zeros(n_devices)

    def _time_ms(fn, *args) -> float:
        fn(*args).block_until_ready()            # warmup / compile
        best = float("inf")
        for _ in range(repeats):
            t0 = time.perf_counter()
            fn(*args).block_until_ready()
            best = min(best, time.perf_counter() - t0)
        return best * 1e3

    for d in range(n_devices):
        sub = raw[assignment == d]
        if sub.shape[0] == 0:
            continue
        rows = np.minimum(sub[:, F.HASH_SIZE].astype(np.int64), max_rows)
        bases = np.concatenate([[1], 1 + np.cumsum(rows)[:-1]])
        arena = jnp.zeros((1 + int(rows.sum()), dim), jnp.float32)
        idx = np.zeros((batch_size * len(rows), pooling), np.int32)
        for k, (b, r) in enumerate(zip(bases, rows)):
            draws = rng.zipf(1.5, size=(batch_size, pooling))
            lo = k * batch_size
            idx[lo:lo + batch_size] = b + draws % r
        idx = jnp.asarray(idx)
        if use_pallas:
            fwd[d] = _time_ms(embedding_bag, arena, idx)
        else:
            fwd[d] = _time_ms(embedding_bag_ref, arena, idx)
        g = jnp.ones((idx.shape[0], dim), jnp.float32)
        bwd[d] = _time_ms(embedding_bag_grad_ref, arena.shape, idx, g)
        dim_sums[d] = sub[:, F.DIM].sum()

    comm = CostSimulator(spec, noise_std=0.0).comm_ms(dim_sums, n_devices)
    fwd_comm = (fwd.max() - fwd) + comm
    overall = fwd.max() + 2.0 * comm.max() + bwd.max()
    return SimResult(fwd_comp=fwd, bwd_comp=bwd, fwd_comm=fwd_comm,
                     bwd_comm=comm, overall=float(overall))
