"""Compiled-kernel micro-benchmark harness for the embedding-bag op.

AutoShard-style offline data collector: sweeps the fused
``kernels/embedding_bag`` forward and scatter-add backward over a grid
of ``(dim, rows, batch, pooling)`` shapes with proper warmup and
median-of-k timing.  The resulting grid feeds a persisted
``CalibrationTable`` (see ``repro.profiling.calibration``) that measured
cost oracles *interpolate* -- kernels are timed once here, offline,
never inside an ``evaluate`` call.

``measure_placement`` preserves the old per-``evaluate`` live timing
loop (the pre-subsystem ``KernelOracle`` behaviour) for validation and
for the before/after comparison in ``benchmarks/b5_sim2real.py``.

jax is imported lazily so the CLI and the calibration artifact loader
stay light.
"""

from __future__ import annotations

import dataclasses
import time

import numpy as np

from repro.sim.hardware import HardwareSpec, PAPER_GPU


def default_use_pallas() -> bool:
    """Compiled Pallas kernel on TPU, jnp reference elsewhere (the Pallas
    op only *interprets* on CPU, which times the interpreter, not HW)."""
    import jax
    return jax.default_backend() == "tpu"


def median_time_ms(fn, args, *, warmup: int = 1, repeats: int = 5) -> float:
    """Median wall time (ms) of ``fn(*args)`` over ``repeats`` runs after
    ``warmup`` untimed calls (the first of which pays compilation)."""
    import jax
    for _ in range(max(1, warmup)):
        jax.block_until_ready(fn(*args))
    times = []
    for _ in range(max(1, repeats)):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        times.append((time.perf_counter() - t0) * 1e3)
    return float(np.median(times))


@dataclasses.dataclass(frozen=True)
class BenchPoint:
    """One measured grid point (times in milliseconds)."""

    dim: int
    rows: int
    batch: int
    pooling: int
    fwd_ms: float
    bwd_ms: float


def make_inputs(dim: int, rows: int, batch: int, pooling: int,
                seed: int = 0):
    """(arena, indices, grad_out) for one benchmark shape.

    Arena row 0 is the zero row (never indexed here); indices follow a
    zipf-ish reuse pattern like real lookup streams, seeded for
    reproducible index working sets.
    """
    import jax.numpy as jnp
    rng = np.random.default_rng(seed)
    arena = jnp.zeros((rows + 1, dim), jnp.float32)
    draws = rng.zipf(1.5, size=(batch, pooling))
    idx = jnp.asarray(1 + draws % rows, jnp.int32)
    g = jnp.ones((batch, dim), jnp.float32)
    return arena, idx, g


def bench_shape(dim: int, rows: int, batch: int, pooling: int, *,
                use_pallas: bool | None = None, warmup: int = 1,
                repeats: int = 5, seed: int = 0) -> BenchPoint:
    """Time the forward and backward kernels at one grid point."""
    import jax
    from repro.kernels.embedding_bag.ops import pad_dim
    from repro.kernels.embedding_bag.ref import (embedding_bag_grad_ref,
                                                 embedding_bag_ref)
    if use_pallas is None:
        use_pallas = default_use_pallas()
    if use_pallas:
        from repro.kernels.embedding_bag.ops import embedding_bag
        dim = pad_dim(dim)                 # Pallas lanes are 128-wide
        fwd_fn = jax.jit(embedding_bag)
    else:
        fwd_fn = jax.jit(embedding_bag_ref)
    bwd_fn = jax.jit(embedding_bag_grad_ref, static_argnums=0)

    arena, idx, g = make_inputs(dim, rows, batch, pooling, seed=seed)
    fwd_ms = median_time_ms(fwd_fn, (arena, idx),
                            warmup=warmup, repeats=repeats)
    bwd_ms = median_time_ms(bwd_fn, (arena.shape, idx, g),
                            warmup=warmup, repeats=repeats)
    return BenchPoint(dim=int(dim), rows=int(rows), batch=int(batch),
                      pooling=int(pooling), fwd_ms=fwd_ms, bwd_ms=bwd_ms)


def sweep(dims, rows, batches, poolings, *, use_pallas: bool | None = None,
          warmup: int = 1, repeats: int = 5, seed: int = 0,
          progress=None) -> tuple[np.ndarray, np.ndarray]:
    """Dense grid sweep -> ``(fwd_ms, bwd_ms)`` arrays of shape
    ``(len(dims), len(rows), len(batches), len(poolings))``.

    ``progress`` (optional) is called with each finished ``BenchPoint``.
    """
    shape = (len(dims), len(rows), len(batches), len(poolings))
    fwd = np.zeros(shape)
    bwd = np.zeros(shape)
    for i, d in enumerate(dims):
        for j, r in enumerate(rows):
            for k, b in enumerate(batches):
                for n, p in enumerate(poolings):
                    pt = bench_shape(int(d), int(r), int(b), int(p),
                                     use_pallas=use_pallas, warmup=warmup,
                                     repeats=repeats, seed=seed)
                    fwd[i, j, k, n] = pt.fwd_ms
                    bwd[i, j, k, n] = pt.bwd_ms
                    if progress is not None:
                        progress(pt)
    return fwd, bwd


@dataclasses.dataclass(frozen=True)
class FusedBenchPoint:
    """One measured fused multi-table op (times in milliseconds)."""

    dims: tuple
    rows: tuple
    poolings: tuple
    batch: int
    fwd_ms: float
    bwd_ms: float

    @property
    def k(self) -> int:
        return len(self.dims)


def fused_arena_dim(dims) -> int:
    """Arena width of a fused op over heterogeneous tables: the widest
    table, padded to 128 lanes -- the same convention the live
    ``measure_placement`` harness (and the Pallas kernel) uses, so fused
    sweep measurements and live placements price the same op."""
    return max(128, int(np.ceil(max(dims) / 128) * 128))


def make_fused_inputs(dims, rows, batch: int, poolings, seed: int = 0):
    """(arena, indices, grad_out) for ONE fused op over K stacked tables.

    Tables live back to back in a shared arena (row 0 = zero row) at
    ``fused_arena_dim`` width; each table contributes ``batch`` zipf-ish
    lookups at its own pooling factor, padded to the widest pooling with
    the zero row (exact for sum pooling, and part of what the real fused
    op pays).
    """
    import jax.numpy as jnp
    rng = np.random.default_rng(seed)
    rows = np.asarray(rows, dtype=np.int64)
    dim = fused_arena_dim(dims)
    bases = np.concatenate([[1], 1 + np.cumsum(rows)[:-1]])
    arena = jnp.zeros((1 + int(rows.sum()), dim), jnp.float32)
    p_max = int(max(poolings))
    idx = np.zeros((batch * len(rows), p_max), np.int32)
    for k, (b, r, p) in enumerate(zip(bases, rows, poolings)):
        draws = rng.zipf(1.5, size=(batch, int(p)))
        idx[k * batch:(k + 1) * batch, :int(p)] = b + draws % r
    g = jnp.ones((idx.shape[0], dim), jnp.float32)
    return arena, jnp.asarray(idx), g


def bench_fused_shape(dims, rows, batch: int, poolings, *,
                      use_pallas: bool | None = None, warmup: int = 1,
                      repeats: int = 5, seed: int = 0) -> FusedBenchPoint:
    """Time ONE fused forward + backward op over K heterogeneous tables.

    This is the measurement the additive per-table model cannot predict:
    one launch instead of K, co-scheduled gathers, one shared arena.
    ``repro.profiling.calibration`` fits the deviation from the
    single-table grid into a ``FusionModel``.
    """
    import jax
    from repro.kernels.embedding_bag.ref import (embedding_bag_grad_ref,
                                                 embedding_bag_ref)
    if use_pallas is None:
        use_pallas = default_use_pallas()
    if use_pallas:
        from repro.kernels.embedding_bag.ops import embedding_bag
        fwd_fn = jax.jit(embedding_bag)
    else:
        fwd_fn = jax.jit(embedding_bag_ref)
    bwd_fn = jax.jit(embedding_bag_grad_ref, static_argnums=0)

    arena, idx, g = make_fused_inputs(dims, rows, batch, poolings, seed=seed)
    fwd_ms = median_time_ms(fwd_fn, (arena, idx),
                            warmup=warmup, repeats=repeats)
    bwd_ms = median_time_ms(bwd_fn, (arena.shape, idx, g),
                            warmup=warmup, repeats=repeats)
    return FusedBenchPoint(dims=tuple(int(d) for d in dims),
                           rows=tuple(int(r) for r in rows),
                           poolings=tuple(int(p) for p in poolings),
                           batch=int(batch), fwd_ms=fwd_ms, bwd_ms=bwd_ms)


def sweep_fused(dims, rows, poolings, batch: int, *, ks=(2, 4, 8),
                per_k: int = 4, use_pallas: bool | None = None,
                warmup: int = 1, repeats: int = 5, seed: int = 0,
                progress=None) -> list[FusedBenchPoint]:
    """Fused multi-table sweep: for each fusion depth K, measure
    ``per_k`` ops over heterogeneous ``(rows, pooling)`` draws from the
    given grid axes (with replacement, seeded).  Draws land exactly on
    grid points so the single-table baseline each op is compared to is
    interpolation-exact.

    Each op's K tables share ONE dim (drawn per op): the fused arena
    runs every table at the group's widest padded dim, so a mixed-dim
    group would fold arena-padding inflation -- a table-mix effect the
    K/total-work ``FusionModel`` deliberately does not see, and one that
    can push measured-fused above the additive baseline -- into the fit
    that prices every placement.  Real embedding pools are
    dim-homogeneous per fused op anyway (the DLRM suites are single-dim
    pools).
    """
    rng = np.random.default_rng(seed)
    dims = np.asarray(dims)
    rows = np.asarray(rows)
    poolings = np.asarray(poolings)
    points = []
    for k in ks:
        for _ in range(per_k):
            dim = dims[rng.integers(0, dims.size)]
            pt = bench_fused_shape(
                np.full(k, dim),
                rows[rng.integers(0, rows.size, size=k)],
                batch, poolings[rng.integers(0, poolings.size, size=k)],
                use_pallas=use_pallas, warmup=warmup, repeats=repeats,
                seed=int(rng.integers(0, 2**31)))
            points.append(pt)
            if progress is not None:
                progress(pt)
    return points


@dataclasses.dataclass(frozen=True)
class ShardBenchPoint:
    """One measured partial-width (column-shard) gather vs its full table.

    ``frac`` is the measured column fraction ``width / dim`` (both after
    any Pallas lane padding, so the ratio describes the shapes actually
    timed)."""

    dim: int            # full table width
    width: int          # shard width actually timed
    rows: int
    batch: int
    pooling: int
    frac: float         # width / dim
    fwd_ms: float       # shard gather time
    bwd_ms: float
    full_fwd_ms: float  # same shape at full width (the K=1 baseline)
    full_bwd_ms: float


def sweep_sharded(dims, rows, poolings, batch: int, *,
                  fracs=(0.25, 0.5, 0.75), per_frac: int = 3,
                  use_pallas: bool | None = None, warmup: int = 1,
                  repeats: int = 5, seed: int = 0,
                  progress=None) -> list[ShardBenchPoint]:
    """Sharded-gather sweep: time partial-width lookups against their
    full-width baselines.

    For each column fraction, ``per_frac`` heterogeneous ``(dim, rows,
    pooling)`` draws from the grid axes are timed twice -- once at the
    shard width ``max(1, round(dim * frac))`` and once at the full
    ``dim`` (a grid point, so it doubles as an interpolation sanity
    anchor).  The pairs feed ``ShardModel.fit``: the deviation of
    ``shard_ms / full_ms`` from ``frac`` is the per-gather overhead a
    column split does NOT amortize (index decode, launch, row
    addressing), which is exactly why K shards of one table cost more
    than the whole table.  On the Pallas path both widths go through the
    kernel's 128-lane padding, and ``frac`` reports the padded ratio.
    """
    rng = np.random.default_rng(seed)
    dims = np.asarray(dims)
    rows = np.asarray(rows)
    poolings = np.asarray(poolings)
    # fractions below one lane are unmeasurable on the padded kernel;
    # only dims wide enough to split are worth drawing
    wide = dims[dims >= 2] if (dims >= 2).any() else dims
    points = []
    for frac in fracs:
        for _ in range(per_frac):
            d = int(wide[rng.integers(0, wide.size)])
            r = int(rows[rng.integers(0, rows.size)])
            p = int(poolings[rng.integers(0, poolings.size)])
            width = max(1, int(round(d * float(frac))))
            s = int(rng.integers(0, 2**31))
            part = bench_shape(width, r, batch, p, use_pallas=use_pallas,
                               warmup=warmup, repeats=repeats, seed=s)
            full = bench_shape(d, r, batch, p, use_pallas=use_pallas,
                               warmup=warmup, repeats=repeats, seed=s)
            pt = ShardBenchPoint(
                dim=full.dim, width=part.dim, rows=r, batch=batch,
                pooling=p, frac=part.dim / full.dim,
                fwd_ms=part.fwd_ms, bwd_ms=part.bwd_ms,
                full_fwd_ms=full.fwd_ms, full_bwd_ms=full.bwd_ms)
            points.append(pt)
            if progress is not None:
                progress(pt)
    return points


def measure_placement(raw: np.ndarray, assignment: np.ndarray,
                      n_devices: int, *, spec: HardwareSpec = PAPER_GPU,
                      batch_size: int = 64, pooling: int | None = 4,
                      max_rows: int = 4096, repeats: int = 2,
                      use_pallas: bool = False, seed: int = 0):
    """LIVE per-placement measurement: the old ``KernelOracle.evaluate``
    timing loop, preserved as a validation/baseline path.

    Builds a per-device arena, synthesizes zipf-ish lookups, and times
    forward + backward kernels for every device group -- slow and noisy
    by construction (this is exactly what the calibration subsystem
    replaces).  Communication reuses the simulator's analytic model.
    ``pooling=None`` takes each table's own pooling factor from ``raw``
    (blocks padded to the device's widest pooling with the zero row, as
    in ``make_fused_inputs``); an int forces that factor everywhere (the
    pre-fusion behaviour).  ``benchmarks/b8_fusion_model.py`` uses this
    path as ground truth for the fused multi-table cost model.
    """
    import jax
    import jax.numpy as jnp
    from repro.core import features as F
    from repro.kernels.embedding_bag.ref import (embedding_bag_grad_ref,
                                                 embedding_bag_ref)
    from repro.sim.costsim import CostSimulator, SimResult, placement_digest

    # time the COMPILED ops (compile paid by the warmup call), matching
    # the micro-benchmark sweep -- eager timing would fold per-op Python
    # dispatch into the "hardware" cost no production path pays
    if use_pallas:
        from repro.kernels.embedding_bag.ops import embedding_bag
        fwd_fn = jax.jit(embedding_bag)
    else:
        fwd_fn = jax.jit(embedding_bag_ref)
    bwd_fn = jax.jit(embedding_bag_grad_ref, static_argnums=0)

    raw = np.asarray(raw, dtype=np.float64)
    assignment = np.asarray(assignment)
    rng = np.random.default_rng(
        placement_digest(raw, assignment, n_devices) ^ seed)
    dim = max(128, int(np.ceil(raw[:, F.DIM].max() / 128) * 128))
    fwd = np.zeros(n_devices)
    bwd = np.zeros(n_devices)
    dim_sums = np.zeros(n_devices)

    def _time_ms(fn, *args) -> float:
        # median-of-repeats, the same estimator the calibration sweep
        # uses (min-of-k vs median-of-k differ by 2-3x under bursty host
        # contention, which would bias every live-vs-interpolated
        # comparison)
        fn(*args).block_until_ready()            # warmup / compile
        times = []
        for _ in range(repeats):
            t0 = time.perf_counter()
            fn(*args).block_until_ready()
            times.append(time.perf_counter() - t0)
        return float(np.median(times)) * 1e3

    for d in range(n_devices):
        sub = raw[assignment == d]
        if sub.shape[0] == 0:
            continue
        rows = np.minimum(sub[:, F.HASH_SIZE].astype(np.int64), max_rows)
        if pooling is None:
            pools = np.maximum(1, np.rint(sub[:, F.POOLING]).astype(np.int64))
        else:
            pools = np.full(len(rows), int(pooling), np.int64)
        bases = np.concatenate([[1], 1 + np.cumsum(rows)[:-1]])
        arena = jnp.zeros((1 + int(rows.sum()), dim), jnp.float32)
        idx = np.zeros((batch_size * len(rows), int(pools.max())), np.int32)
        for k, (b, r, p) in enumerate(zip(bases, rows, pools)):
            draws = rng.zipf(1.5, size=(batch_size, int(p)))
            lo = k * batch_size
            idx[lo:lo + batch_size, :int(p)] = b + draws % r
        idx = jnp.asarray(idx)
        fwd[d] = _time_ms(fwd_fn, arena, idx)
        g = jnp.ones((idx.shape[0], dim), jnp.float32)
        bwd[d] = _time_ms(bwd_fn, arena.shape, idx, g)
        dim_sums[d] = sub[:, F.DIM].sum()

    comm = CostSimulator(spec, noise_std=0.0).comm_ms(dim_sums, n_devices)
    fwd_comm = (fwd.max() - fwd) + comm
    overall = fwd.max() + 2.0 * comm.max() + bwd.max()
    return SimResult(fwd_comp=fwd, bwd_comp=bwd, fwd_comm=fwd_comm,
                     bwd_comm=comm, overall=float(overall))
