"""Offline calibration + measured-cost profiling subsystem.

Closes the sim-to-real loop: instead of learning placement against the
analytic ``CostSimulator`` only, measure the real kernels/collectives
ONCE offline (AutoShard-style micro-benchmarks) and let oracles
*interpolate* those measurements at search/training speed
(*Pre-train and Search*-style).

* ``microbench``   -- compiled-kernel timing harness over a
  ``(dim, rows, batch, pooling)`` grid (Pallas on TPU, jnp ref on CPU);
* ``collectives``  -- all-to-all measurement over the real device mesh
  (seeded synthetic trace on single-device hosts) fitted to an
  alpha-beta latency/bandwidth model;
* ``calibration``  -- the persisted, versioned ``CalibrationTable``
  artifact (npz + hardware fingerprint) with log2-multilinear
  interpolation;
* ``calibrate``    -- the ``python -m repro.profiling.calibrate`` CLI.

``repro.api.MeasuredOracle`` consumes the artifact; the workflow is
calibrate (once) -> train (``DreamShard(tasks, MeasuredOracle())``) ->
place.  See ``docs/api.md`` ("Measured costs & calibration").
"""

from repro.profiling.calibration import (CALIBRATION_VERSION,
                                         CalibrationTable, FusionModel,
                                         ShardModel, default_artifact_path,
                                         hardware_fingerprint, load_or_none)
from repro.profiling.collectives import (CommModel, calibrate_comm,
                                         fit_alpha_beta, measure_all_to_all,
                                         synthetic_trace)
from repro.profiling.microbench import (BenchPoint, FusedBenchPoint,
                                        ShardBenchPoint, bench_fused_shape,
                                        bench_shape, measure_placement,
                                        median_time_ms, sweep, sweep_fused,
                                        sweep_sharded)

__all__ = [
    "BenchPoint", "CALIBRATION_VERSION", "CalibrationTable", "CommModel",
    "FusedBenchPoint", "FusionModel", "ShardBenchPoint", "ShardModel",
    "bench_fused_shape", "bench_shape", "calibrate_comm",
    "default_artifact_path", "fit_alpha_beta", "hardware_fingerprint",
    "load_or_none", "measure_all_to_all", "measure_placement",
    "median_time_ms", "sweep", "sweep_fused", "sweep_sharded",
    "synthetic_trace",
]
