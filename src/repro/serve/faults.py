"""Deterministic fault injection for the serving stack.

Real fleets lose devices mid-flight, return transient measurement
errors, and miss decode deadlines.  This module makes those regimes
*replayable*: a ``FaultSchedule`` pins every event to a request index
(never wall clock), a ``FaultInjector`` folds the schedule into mesh
state as the service ticks it forward, and two thin oracle wrappers
project that state onto any ``CostOracle`` without touching its hot
paths:

* ``FaultyOracle``      -- raises ``TransientOracleError`` from
  ``evaluate``/``evaluate_many`` while errors are armed (legality
  probes never fault: a memory check is pure arithmetic, not a
  measurement);
* ``DegradedMeshOracle`` -- restricts legality to the surviving device
  set at (possibly shrunk) capacity, so ``SearchPlacer`` refinement and
  the fallback chain can only ever emit placements the degraded mesh
  can hold.

Because every decision is keyed on the request counter, replaying the
same schedule over the same trace is bitwise-identical -- the property
``benchmarks/b12_resilience.py`` asserts.
"""

from __future__ import annotations

import dataclasses
import json

import numpy as np

from repro.serve.errors import TransientOracleError
from repro.sim.costsim import assignments_legal

KINDS = ("device_loss", "device_recovery", "capacity_shrink",
         "oracle_error", "decode_spike")


@dataclasses.dataclass(frozen=True)
class FaultEvent:
    """One scheduled fault, pinned to a request index.

    ``at``       request index the event fires on (0-based; fires when
                 the injector's tick counter reaches it);
    ``kind``     one of ``KINDS``;
    ``device``   target device id (device_loss / device_recovery);
    ``factor``   surviving capacity fraction in (0, 1] (capacity_shrink;
                 multiplicative with earlier shrinks);
    ``count``    consecutive oracle calls that fail (oracle_error);
    ``spike_ms`` injected decode latency (decode_spike; consumed by the
                 next flush).
    """

    at: int
    kind: str
    device: int | None = None
    factor: float | None = None
    count: int | None = None
    spike_ms: float | None = None

    def __post_init__(self):
        if self.kind not in KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r}")
        if self.kind in ("device_loss", "device_recovery") \
                and self.device is None:
            raise ValueError(f"{self.kind} needs device=")
        if self.kind == "capacity_shrink" and \
                not (self.factor and 0.0 < self.factor <= 1.0):
            raise ValueError("capacity_shrink needs factor in (0, 1]")
        if self.kind == "oracle_error" and not (self.count and self.count > 0):
            raise ValueError("oracle_error needs count > 0")
        if self.kind == "decode_spike" and \
                (self.spike_ms is None or self.spike_ms < 0.0):
            raise ValueError("decode_spike needs spike_ms >= 0")

    def to_dict(self) -> dict:
        d = {"at": self.at, "kind": self.kind}
        for f in ("device", "factor", "count", "spike_ms"):
            v = getattr(self, f)
            if v is not None:
                d[f] = v
        return d


@dataclasses.dataclass(frozen=True)
class FaultSchedule:
    """An immutable, replayable sequence of ``FaultEvent``s.

    Events are stored sorted by ``at`` (ties keep construction order).
    ``generate`` builds a seeded random schedule; ``to_json`` /
    ``from_json`` round-trip exactly, so a benchmark can commit the
    schedule it measured against.
    """

    events: tuple[FaultEvent, ...] = ()

    def __post_init__(self):
        ordered = tuple(sorted(self.events, key=lambda e: e.at))
        object.__setattr__(self, "events", ordered)

    def __len__(self) -> int:
        return len(self.events)

    def __iter__(self):
        return iter(self.events)

    @classmethod
    def generate(cls, seed: int, n_requests: int, n_devices: int,
                 n_losses: int = 1, recover: bool = True,
                 n_oracle_errors: int = 2, n_spikes: int = 2,
                 spike_ms: float = 50.0) -> "FaultSchedule":
        """Seeded random schedule: ``n_losses`` device losses in the
        middle half of the trace (each recovered later when ``recover``),
        plus transient oracle errors and decode spikes scattered over
        the full trace.  Same seed + shape args -> identical schedule."""
        rng = np.random.default_rng([int(seed), n_requests, n_devices])
        events: list[FaultEvent] = []
        lo, hi = n_requests // 4, max(n_requests // 4 + 1, n_requests // 2)
        devices = rng.permutation(n_devices)[:max(0, min(n_losses,
                                                         n_devices - 1))]
        for dev in devices:
            at = int(rng.integers(lo, hi))
            events.append(FaultEvent(at=at, kind="device_loss",
                                     device=int(dev)))
            if recover:
                back = int(rng.integers(min(at + 1, n_requests),
                                        n_requests + 1))
                events.append(FaultEvent(at=back, kind="device_recovery",
                                         device=int(dev)))
        for _ in range(n_oracle_errors):
            events.append(FaultEvent(
                at=int(rng.integers(0, n_requests)), kind="oracle_error",
                count=int(rng.integers(1, 3))))
        for _ in range(n_spikes):
            events.append(FaultEvent(
                at=int(rng.integers(0, n_requests)), kind="decode_spike",
                spike_ms=float(spike_ms)))
        return cls(events=tuple(events))

    def to_json(self) -> str:
        return json.dumps({"events": [e.to_dict() for e in self.events]})

    @classmethod
    def from_json(cls, text: str) -> "FaultSchedule":
        payload = json.loads(text)
        return cls(events=tuple(FaultEvent(**e) for e in payload["events"]))


class FaultInjector:
    """Folds a ``FaultSchedule`` into live mesh state, one tick at a time.

    The service calls ``advance()`` once per submitted request; events
    whose ``at`` equals the current tick fire (in schedule order) and
    are returned so the caller can react (failover, re-validation).
    Between ticks the injector answers the degraded-mesh questions:

    * ``down``            -- set of lost device ids;
    * ``allowed_mask(D)`` -- boolean survivors mask;
    * ``capacity_gb(b)``  -- base capacity after cumulative shrinks;
    * ``take_error()``    -- consume one armed transient-oracle error;
    * ``take_spike_ms()`` -- consume the pending decode spike.

    ``epoch`` bumps on every topology event (loss / recovery /
    shrink) -- the version stamp checkpointed with service state so a
    warm restart resumes mid-schedule exactly where it stopped
    (``state_dict`` / ``load_state_dict``).
    """

    def __init__(self, schedule: FaultSchedule | None = None,
                 n_devices: int | None = None):
        self.schedule = schedule if schedule is not None else FaultSchedule()
        self.n_devices = n_devices
        self.tick = 0
        self.cursor = 0              # next un-fired event in the schedule
        self.down: set[int] = set()
        self.capacity_scale = 1.0
        self.armed_errors = 0
        self.pending_spike_ms = 0.0
        self.epoch = 0

    # ---- ticking -------------------------------------------------------------

    def advance(self) -> list[FaultEvent]:
        """Fire every event scheduled at the current tick, then move on.
        Returns the fired events so the caller can react to each."""
        fired: list[FaultEvent] = []
        events = self.schedule.events
        while self.cursor < len(events) and \
                events[self.cursor].at <= self.tick:
            ev = events[self.cursor]
            self.cursor += 1
            self._apply(ev)
            fired.append(ev)
        self.tick += 1
        return fired

    def _apply(self, ev: FaultEvent) -> None:
        if ev.kind == "device_loss":
            if ev.device not in self.down:
                self.down.add(ev.device)
                self.epoch += 1
        elif ev.kind == "device_recovery":
            if ev.device in self.down:
                self.down.discard(ev.device)
                self.epoch += 1
        elif ev.kind == "capacity_shrink":
            self.capacity_scale *= ev.factor
            self.epoch += 1
        elif ev.kind == "oracle_error":
            self.armed_errors += ev.count
        elif ev.kind == "decode_spike":
            self.pending_spike_ms = max(self.pending_spike_ms, ev.spike_ms)

    # ---- degraded-mesh queries -----------------------------------------------

    @property
    def degraded(self) -> bool:
        return bool(self.down) or self.capacity_scale < 1.0

    def allowed_mask(self, n_devices: int) -> np.ndarray:
        """(D,) bool mask of surviving devices."""
        mask = np.ones(n_devices, dtype=bool)
        for dev in self.down:
            if 0 <= dev < n_devices:
                mask[dev] = False
        return mask

    def capacity_gb(self, base_gb: float) -> float:
        return base_gb * self.capacity_scale

    def take_error(self) -> bool:
        """Consume one armed transient-oracle error (False when none)."""
        if self.armed_errors > 0:
            self.armed_errors -= 1
            return True
        return False

    def take_spike_ms(self) -> float:
        """Consume the pending decode-latency spike (0.0 when none)."""
        spike, self.pending_spike_ms = self.pending_spike_ms, 0.0
        return spike

    # ---- checkpointing -------------------------------------------------------

    def state_dict(self) -> dict:
        """Runtime state for ``PlacementService.save`` (the schedule
        itself is configuration and travels separately)."""
        return {"tick": self.tick, "cursor": self.cursor,
                "down": sorted(self.down),
                "capacity_scale": self.capacity_scale,
                "armed_errors": self.armed_errors,
                "pending_spike_ms": self.pending_spike_ms,
                "epoch": self.epoch}

    def load_state_dict(self, state: dict) -> None:
        self.tick = int(state["tick"])
        self.cursor = int(state["cursor"])
        self.down = set(int(d) for d in state["down"])
        self.capacity_scale = float(state["capacity_scale"])
        self.armed_errors = int(state["armed_errors"])
        self.pending_spike_ms = float(state["pending_spike_ms"])
        self.epoch = int(state["epoch"])


class FaultyOracle:
    """``CostOracle`` wrapper that fails measurements on command.

    While the injector has errors armed, each ``evaluate`` /
    ``evaluate_many`` call consumes one and raises
    ``TransientOracleError``; otherwise every call delegates bitwise to
    the inner oracle.  Legality probes (``legal`` / ``legal_batch``)
    NEVER fault -- they are spec arithmetic, not hardware measurements,
    and the fallback chain depends on them staying available.
    """

    def __init__(self, inner, injector: FaultInjector):
        self.inner = inner
        self.injector = injector

    @property
    def mem_capacity_gb(self) -> float:
        return self.inner.mem_capacity_gb

    @property
    def num_evaluations(self) -> int:
        return self.inner.num_evaluations

    def _maybe_fault(self):
        if self.injector.take_error():
            raise TransientOracleError("injected transient oracle failure")

    def evaluate(self, raw, assignment, n_devices):
        self._maybe_fault()
        return self.inner.evaluate(raw, assignment, n_devices)

    def evaluate_many(self, raw, assignments, n_devices):
        self._maybe_fault()
        from repro.api.oracle import evaluate_many
        return evaluate_many(self.inner, raw, assignments, n_devices)

    def legal(self, raw, assignment, n_devices) -> bool:
        return bool(self.legal_batch(
            raw, np.asarray(assignment)[None, :], n_devices)[0])

    def legal_batch(self, raw, assignments, n_devices) -> np.ndarray:
        from repro.api.oracle import legal_batch
        return legal_batch(self.inner, raw, assignments, n_devices)


class DegradedMeshOracle:
    """``CostOracle`` wrapper that narrows legality to the surviving mesh.

    ``legal_batch`` rejects any placement touching a disallowed device
    and checks per-device loads against the (possibly shrunk)
    ``capacity_gb`` on survivors only.  ``evaluate`` delegates
    unchanged -- costs are still the inner oracle's; only the feasible
    set shrinks.  Wrap this *outermost* (e.g. around a
    ``MigrationCostOracle``) so search strategies can only admit
    candidates the degraded mesh can actually hold.
    """

    def __init__(self, inner, allowed: np.ndarray,
                 capacity_gb: float | None = None):
        self.inner = inner
        self.allowed = np.asarray(allowed, dtype=bool)
        self._capacity_gb = (inner.mem_capacity_gb if capacity_gb is None
                             else float(capacity_gb))

    @property
    def mem_capacity_gb(self) -> float:
        return self._capacity_gb

    @property
    def num_evaluations(self) -> int:
        return self.inner.num_evaluations

    def evaluate(self, raw, assignment, n_devices):
        return self.inner.evaluate(raw, assignment, n_devices)

    def evaluate_many(self, raw, assignments, n_devices):
        from repro.api.oracle import evaluate_many
        return evaluate_many(self.inner, raw, assignments, n_devices)

    def legal(self, raw, assignment, n_devices) -> bool:
        return bool(self.legal_batch(
            raw, np.asarray(assignment)[None, :], n_devices)[0])

    def legal_batch(self, raw, assignments, n_devices) -> np.ndarray:
        from repro.core import features as F
        raw = np.asarray(raw, dtype=np.float64)
        assignments = np.asarray(assignments)
        ok = assignments_legal(raw[:, F.TABLE_SIZE_GB], assignments,
                               n_devices, self._capacity_gb)
        allowed = self.allowed
        if len(allowed) < n_devices:     # devices beyond the mask survive
            allowed = np.concatenate(
                [allowed, np.ones(n_devices - len(allowed), dtype=bool)])
        in_range = (assignments >= 0) & (assignments < n_devices)
        on_lost = np.where(in_range, ~allowed[np.clip(assignments, 0,
                                                      n_devices - 1)], False)
        return ok & ~on_lost.any(axis=1)


def repair_assignment(sizes_gb: np.ndarray, assignment: np.ndarray,
                      allowed: np.ndarray,
                      capacity_gb: float) -> np.ndarray | None:
    """Deterministic greedy repair of one assignment onto a degraded mesh.

    Tables stranded on disallowed devices -- plus, after a capacity
    shrink, tables shed from over-full surviving devices (largest
    first) -- are re-homed one at a time onto the allowed device with
    the most headroom (ties -> lowest id).  Moves only what it must:
    tables already legal on surviving devices never move.  Returns the
    repaired ``(M,)`` assignment, or ``None`` when the surviving
    capacity cannot hold the task at all.
    """
    sizes = np.asarray(sizes_gb, dtype=np.float64)
    a = np.asarray(assignment).copy()
    allowed = np.asarray(allowed, dtype=bool)
    D = len(allowed)
    if not allowed.any():
        return None
    settled = (a >= 0) & (a < D) & allowed[np.clip(a, 0, D - 1)]
    loads = np.bincount(a[settled], weights=sizes[settled],
                        minlength=D)[:D].astype(np.float64)
    stranded = [int(t) for t in np.nonzero(~settled)[0]]
    # shed: surviving devices over the (possibly shrunk) budget drop
    # their largest tables until they fit
    for dev in np.nonzero(allowed)[0]:
        if loads[dev] <= capacity_gb:
            continue
        on_dev = sorted((int(t) for t in np.nonzero(settled & (a == dev))[0]),
                        key=lambda t: (-sizes[t], t))
        for t in on_dev:
            if loads[dev] <= capacity_gb:
                break
            loads[dev] -= sizes[t]
            stranded.append(t)
    # re-home largest first onto the max-headroom survivor (ties -> lowest
    # id): deterministic, and big tables claim space before fragments do
    stranded.sort(key=lambda t: (-sizes[t], t))
    for t in stranded:
        headroom = np.where(allowed, capacity_gb - loads, -np.inf)
        dev = int(np.argmax(headroom))
        if headroom[dev] < sizes[t]:
            return None
        a[t] = dev
        loads[dev] += sizes[t]
    if not bool(assignments_legal(sizes, a[None, :], D, capacity_gb)[0]):
        return None
    return a
