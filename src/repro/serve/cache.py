"""Digest-keyed placement cache: repeat tasks skip decode entirely.

The serving analogue of ``CachedOracle``, but for *placements* rather
than costs: entries are keyed on a blake2b task digest
(``repro.api.digest.task_key``) and evicted LRU, so a stream of repeat
or near-duplicate requests is served in dictionary time while cold
tasks still pay exactly one bucketed decode.

Each entry also carries the per-table access-histogram *snapshot* the
placement was computed against -- the reference the drift loop
(``repro.serve.drift``) compares live traffic statistics to when
deciding whether a re-placement is due.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro import telemetry as tele
from repro.api.placement import Placement


@dataclasses.dataclass
class CacheEntry:
    """One cached placement plus the state the drift loop needs."""

    placement: Placement
    snapshot: np.ndarray    # (M, 17) access histograms at placement time
    requests: int = 0       # requests served from this entry
    replaces: int = 0       # drift-triggered re-placements applied
    raw: np.ndarray | None = None   # (M, 21) features at placement time
                                    # (failover re-places from these)


class PlacementCache:
    """LRU placement cache keyed on ``task_key`` digests.

    A ``get`` hit moves the entry to the back of the insertion order
    (LRU, matching ``CachedOracle``), so hot tasks survive past
    ``max_entries`` even under a long tail of one-off tasks.
    Hit/miss/eviction behaviour is surfaced both as instance counters
    and as ``serve.cache.*`` telemetry counters.
    """

    def __init__(self, max_entries: int = 4096):
        self.max_entries = max_entries
        self.hits = 0
        self.misses = 0
        self.evictions = 0
        self.invalidations = 0
        self._entries: dict[bytes, CacheEntry] = {}

    def __len__(self) -> int:
        return len(self._entries)

    def get(self, key: bytes) -> CacheEntry | None:
        entry = self._entries.get(key)
        if entry is None:
            self.misses += 1
            tele.count("serve.cache.misses")
            return None
        self.hits += 1
        tele.count("serve.cache.hits")
        del self._entries[key]                    # LRU: move to end
        self._entries[key] = entry
        entry.requests += 1
        return entry

    def put(self, key: bytes, entry: CacheEntry) -> None:
        if key in self._entries:                  # refresh keeps recency
            del self._entries[key]
        elif len(self._entries) >= self.max_entries:
            self._entries.pop(next(iter(self._entries)))
            self.evictions += 1
            tele.count("serve.cache.evictions")
        self._entries[key] = entry

    def entries(self) -> list[CacheEntry]:
        """Live entries in LRU -> MRU order (a snapshot, not a view)."""
        return list(self._entries.values())

    def items(self) -> list[tuple[bytes, CacheEntry]]:
        """(key, entry) pairs in LRU -> MRU order (a snapshot)."""
        return list(self._entries.items())

    def invalidate(self, predicate) -> int:
        """Drop every entry where ``predicate(key, entry)`` is true.

        Surviving entries keep their relative LRU order; dropped entries
        count as invalidations (NOT evictions -- they were removed for
        correctness, not capacity) and leave hit/miss counters untouched.
        Returns the number of entries dropped.
        """
        doomed = [k for k, e in self._entries.items() if predicate(k, e)]
        for k in doomed:
            del self._entries[k]
        self.invalidations += len(doomed)
        if doomed:
            tele.count("serve.cache.invalidations", len(doomed))
        return len(doomed)

    def invalidate_devices(self, lost) -> int:
        """Drop entries whose placement touches any device in ``lost``
        (the device-loss failover sweep).  Returns the count dropped."""
        lost = set(int(d) for d in lost)
        if not lost:
            return 0

        def touches(key, entry):
            return bool(np.isin(entry.placement.assignment,
                                sorted(lost)).any())

        return self.invalidate(touches)

    @property
    def hit_rate(self) -> float:
        total = self.hits + self.misses
        return self.hits / total if total else 0.0
