"""``PlacementService``: the long-running placement serving loop.

``PlacementSession`` is batched *cold* placement: every ``place_many``
decodes every task from scratch.  Production traffic is a stream of
millions of near-duplicate requests with slowly drifting table
popularity, and this module turns the session into a service for that
workload:

1. **Placement cache** -- requests are keyed on a blake2b task digest
   (``repro.api.digest.task_key``; structural features only by
   default, so popularity drift maps to ONE entry).  Repeat tasks are
   served in dictionary time, skipping decode entirely.
2. **Micro-batch admission** -- cache misses queue briefly, coalesced
   by digest, and are flushed per ``(M_pad, D)`` bucket (``max_batch``
   full, or the oldest request older than ``max_wait_ms``), so every
   vmapped decode amortizes one compiled bucket shape across a full
   batch instead of paying ragged singleton calls.
3. **Drift-triggered re-placement** -- per-table access-histogram
   EWMAs (``DriftTracker``) are compared to the placed snapshot on
   every hit; past ``drift_threshold`` the entry is re-placed
   *incrementally*: ``SearchPlacer.refine`` seeded from the incumbent,
   scored through a ``MigrationCostOracle`` so moves must pay for the
   bytes they migrate.

Everything is observable through ``serve.*`` telemetry (cache
hit/miss/eviction counters, flush spans with batch size and queue
wait, re-place spans with divergence and bytes moved) plus the
instance-level ``stats()`` snapshot.  ``benchmarks/b11_serve.py``
replays a synthetic drifting trace through this loop.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Callable

import numpy as np

from repro import telemetry as tele
from repro.api.digest import task_key
from repro.api.oracle import ensure_oracle
from repro.api.placement import Placement
from repro.api.session import PlacementSession
from repro.core import features as F
from repro.data.tasks import Task
from repro.serve.cache import CacheEntry, PlacementCache
from repro.serve.drift import (DriftTracker, MigrationCostOracle,
                               dist_divergence)


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    """Knobs for one ``PlacementService``.

    Admission: a queued bucket flushes when it holds ``max_batch``
    distinct tasks or its oldest request has waited ``max_wait_ms``.
    Cache: ``cache_entries`` LRU capacity; ``key_distribution=True``
    folds the access histograms into the digest (every drifted request
    then misses -- the always-decode policy; the default keys on
    structural features only).
    Drift: histogram EWMAs (``ewma_alpha``) trigger a re-placement when
    their max per-table total-variation distance from the placed
    snapshot exceeds ``drift_threshold`` (``None`` disables the loop);
    the refinement runs ``replace_strategy`` under
    ``replace_max_evals``/``replace_budget_ms`` with a migration term
    of ``migration_ms_per_gb`` x bytes moved in its objective.
    """

    max_wait_ms: float = 2.0
    max_batch: int = 16
    cache_entries: int = 4096
    key_distribution: bool = False
    ewma_alpha: float = 0.1
    drift_threshold: float | None = 0.1
    migration_ms_per_gb: float = 25.0
    replace_strategy: str = "lns"
    replace_max_evals: int | None = 96
    replace_budget_ms: float | None = None
    seed: int = 0


@dataclasses.dataclass
class ServeResult:
    """One served request: the placement plus serving provenance."""

    placement: Placement
    source: str             # "cache" | "decode"
    latency_ms: float       # submit -> placement available
    queue_wait_ms: float    # admission-queue share of the latency
    replaced: bool = False  # a drift re-placement ran while serving this
    tag: object = None      # caller's correlation token


@dataclasses.dataclass
class _Pending:
    """One queued decode (unique task digest) with its waiting tickets."""

    key: bytes
    raw: np.ndarray
    n_devices: int
    tickets: list[tuple[object, float]]   # (tag, t_enqueue)


class PlacementService:
    """Cache + admission + drift loop in front of a ``PlacementSession``.

    Parameters
    ----------
    agent: a trained ``DreamShard`` (decode path), or pass ``session=``
        to reuse an existing warmed ``PlacementSession``.
    oracle: the ``CostOracle`` scoring drift re-placements (defaults to
        the agent's training oracle).
    clock: seconds-valued time source (injectable for deterministic
        admission tests; defaults to ``time.perf_counter``).

    ``submit`` returns the list of requests *completed by that call*: a
    cache hit completes immediately; a miss enqueues and may complete
    together with other queued requests when its bucket flushes.  Call
    ``flush()`` to drain stragglers (end of stream) and ``poll()`` to
    flush buckets whose wait deadline passed without new traffic.
    """

    def __init__(self, agent=None, oracle=None,
                 config: ServeConfig | None = None,
                 session: PlacementSession | None = None,
                 clock: Callable[[], float] = time.perf_counter):
        if session is None:
            if agent is None:
                raise ValueError("pass a DreamShard agent or a session")
            session = PlacementSession(agent)
        self.session = session
        self.oracle = ensure_oracle(
            oracle if oracle is not None else session.agent.oracle)
        self.config = config if config is not None else ServeConfig()
        self.clock = clock
        self.cache = PlacementCache(self.config.cache_entries)
        self.drift = DriftTracker(self.config.ewma_alpha)
        self._queues: dict[tuple, dict[bytes, _Pending]] = {}
        self.requests = 0
        self.coalesced = 0          # misses absorbed by a queued duplicate
        self.decode_batches = 0
        self.decoded_tasks = 0
        self.replace_events = 0     # drift triggers (refine ran)
        self.migrations = 0         # triggers that actually moved tables
        self.bytes_moved_gb = 0.0

    # ---- keying --------------------------------------------------------------

    def request_key(self, raw_features: np.ndarray, n_devices: int) -> bytes:
        return task_key(raw_features, n_devices,
                        include_distribution=self.config.key_distribution)

    # ---- serving -------------------------------------------------------------

    def submit(self, raw_features: np.ndarray, n_devices: int,
               tag: object = None) -> list[ServeResult]:
        """Serve one request; returns every request completed by this
        call (the hit itself, or queued requests whose bucket flushed)."""
        now = self.clock()
        self.requests += 1
        tele.count("serve.requests")
        raw = np.asarray(raw_features, dtype=np.float64)
        key = self.request_key(raw, n_devices)
        ewma = self.drift.observe(key, raw[:, F.DIST_START:])

        entry = self.cache.get(key)
        if entry is not None:
            replaced = self._maybe_replace(key, entry, raw, ewma, n_devices)
            latency = (self.clock() - now) * 1e3
            return [ServeResult(placement=entry.placement, source="cache",
                                latency_ms=latency, queue_wait_ms=0.0,
                                replaced=replaced, tag=tag)]

        bucket = self.session.bucket_key(Task.of(raw, n_devices))
        queue = self._queues.setdefault(bucket, {})
        pending = queue.get(key)
        if pending is not None:                   # near-duplicate in flight
            self.coalesced += 1
            tele.count("serve.coalesced")
            pending.tickets.append((tag, now))
        else:
            queue[key] = _Pending(key=key, raw=raw, n_devices=n_devices,
                                  tickets=[(tag, now)])
        return self._flush_due(now)

    def poll(self) -> list[ServeResult]:
        """Flush buckets whose oldest request outwaited ``max_wait_ms``
        (call between requests on a quiet stream)."""
        return self._flush_due(self.clock())

    def flush(self) -> list[ServeResult]:
        """Drain every queued request regardless of batch/wait state."""
        out = []
        for bucket in list(self._queues):
            out.extend(self._flush_bucket(bucket))
        return out

    @property
    def pending(self) -> int:
        return sum(len(q) for q in self._queues.values())

    # ---- admission -----------------------------------------------------------

    def _flush_due(self, now: float) -> list[ServeResult]:
        cfg = self.config
        out = []
        for bucket in list(self._queues):
            queue = self._queues[bucket]
            if not queue:
                continue
            oldest = min(t for p in queue.values() for _, t in p.tickets)
            if len(queue) >= cfg.max_batch or \
                    (now - oldest) * 1e3 >= cfg.max_wait_ms:
                out.extend(self._flush_bucket(bucket))
        return out

    def _flush_bucket(self, bucket: tuple) -> list[ServeResult]:
        pendings = list(self._queues.pop(bucket, {}).values())
        if not pendings:
            return []
        t0 = self.clock()
        oldest = min(t for p in pendings for _, t in p.tickets)
        tasks = [Task.of(p.raw, p.n_devices) for p in pendings]
        with tele.span("serve.flush", m_pad=bucket[0], n_devices=bucket[1],
                       tasks=len(tasks),
                       queue_wait_ms=round((t0 - oldest) * 1e3, 3)):
            placements = self.session.place_many(tasks)
        t1 = self.clock()
        self.decode_batches += 1
        self.decoded_tasks += len(tasks)
        tele.count("serve.flushes")
        tele.count("serve.decoded", len(tasks))
        out = []
        for pend, placement in zip(pendings, placements):
            self.cache.put(pend.key, CacheEntry(
                placement=placement,
                snapshot=np.array(pend.raw[:, F.DIST_START:])))
            for tag, t_enq in pend.tickets:
                out.append(ServeResult(
                    placement=placement, source="decode",
                    latency_ms=(t1 - t_enq) * 1e3,
                    queue_wait_ms=(t0 - t_enq) * 1e3, tag=tag))
        return out

    # ---- drift ---------------------------------------------------------------

    def _maybe_replace(self, key: bytes, entry: CacheEntry,
                       raw: np.ndarray, ewma: np.ndarray,
                       n_devices: int) -> bool:
        cfg = self.config
        if cfg.drift_threshold is None:
            return False
        divergence = dist_divergence(ewma, entry.snapshot)
        if divergence <= cfg.drift_threshold:
            return False
        # re-place against the *current* traffic estimate: structural
        # features from the request, histograms from the EWMA
        from repro.search import SearchConfig, SearchPlacer
        current = np.array(raw)
        current[:, F.DIST_START:] = ewma
        task = Task.of(current, n_devices)
        incumbent = entry.placement
        with tele.span("serve.replace", divergence=round(divergence, 4),
                       M=task.n_tables, n_devices=n_devices) as sp:
            oracle = MigrationCostOracle.wrap(
                self.oracle, incumbent.assignment, cfg.migration_ms_per_gb)
            placer = SearchPlacer(
                oracle, agent=self.session.agent, name="serve.replace",
                config=SearchConfig(strategy=cfg.replace_strategy,
                                    budget_ms=cfg.replace_budget_ms,
                                    max_evals=cfg.replace_max_evals,
                                    seed=cfg.seed))
            refined = placer.refine(task, incumbent)
            moved_gb = float(((refined.assignment != incumbent.assignment)
                              * current[:, F.TABLE_SIZE_GB]).sum())
            sp.set(moved_gb=round(moved_gb, 4))
        entry.placement = refined
        entry.snapshot = np.array(ewma)
        entry.replaces += 1
        self.replace_events += 1
        self.bytes_moved_gb += moved_gb
        tele.count("serve.replace_events")
        if moved_gb > 0.0:
            self.migrations += 1
            tele.count("serve.migrations")
        return True

    # ---- introspection -------------------------------------------------------

    def stats(self) -> dict:
        """Serving-behaviour snapshot (instance counters; the same
        signals stream through ``serve.*`` telemetry counters)."""
        return {
            "requests": self.requests,
            "hits": self.cache.hits,
            "misses": self.cache.misses,
            "hit_rate": self.cache.hit_rate,
            "evictions": self.cache.evictions,
            "entries": len(self.cache),
            "coalesced": self.coalesced,
            "pending": self.pending,
            "decode_batches": self.decode_batches,
            "decoded_tasks": self.decoded_tasks,
            "replace_events": self.replace_events,
            "migrations": self.migrations,
            "bytes_moved_gb": self.bytes_moved_gb,
        }
