"""``PlacementService``: the long-running placement serving loop.

``PlacementSession`` is batched *cold* placement: every ``place_many``
decodes every task from scratch.  Production traffic is a stream of
millions of near-duplicate requests with slowly drifting table
popularity, and this module turns the session into a service for that
workload:

1. **Placement cache** -- requests are keyed on a blake2b task digest
   (``repro.api.digest.task_key``; structural features only by
   default, so popularity drift maps to ONE entry).  Repeat tasks are
   served in dictionary time, skipping decode entirely.
2. **Micro-batch admission** -- cache misses queue briefly, coalesced
   by digest, and are flushed per ``(M_pad, D)`` bucket (``max_batch``
   full, or the oldest request older than ``max_wait_ms``), so every
   vmapped decode amortizes one compiled bucket shape across a full
   batch instead of paying ragged singleton calls.
3. **Drift-triggered re-placement** -- per-table access-histogram
   EWMAs (``DriftTracker``) are compared to the placed snapshot on
   every hit; past ``drift_threshold`` the entry is re-placed
   *incrementally*: ``SearchPlacer.refine`` seeded from the incumbent,
   scored through a ``MigrationCostOracle`` so moves must pay for the
   bytes they migrate.
4. **Fault tolerance** -- with a ``FaultInjector`` attached, the
   service rides out device loss, capacity shrink, transient oracle
   errors, and decode-latency spikes: affected cache entries fail over
   onto the surviving mesh (greedy repair seeded into
   ``SearchPlacer.refine`` under the migration objective, so recovery
   moves only what it must), decodes that bust the deadline degrade
   down a fallback chain (DreamShard -> expert -> greedy-legal), oracle
   errors retry with bounded backoff, and every request completes with
   a legal placement or a typed ``ServeError`` -- never an exception
   out of ``submit``/``flush``.  ``save``/``restore`` checkpoint the
   whole serving state (cache, drift EWMAs, fault epoch, latency
   ledger) through ``repro.checkpoint`` for warm restarts.

Everything is observable through ``serve.*`` telemetry (cache
hit/miss/eviction counters, flush spans, re-place spans,
``serve.faults.*`` / ``serve.fallback.*`` fault-path counters) plus the
instance-level ``stats()`` snapshot.  ``benchmarks/b11_serve.py``
replays a synthetic drifting trace through this loop;
``benchmarks/b12_resilience.py`` replays one against an injected
failure schedule.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Callable

import numpy as np

from repro import telemetry as tele
from repro.api.digest import task_key
from repro.api.oracle import ensure_oracle
from repro.api.placement import Placement
from repro.api.session import PlacementSession
from repro.core import features as F
from repro.core.baselines import expert_place
from repro.data.tasks import Task
from repro.embedding.plan import build_plan
from repro.serve.cache import CacheEntry, PlacementCache
from repro.serve.drift import (DriftTracker, MigrationCostOracle,
                               dist_divergence)
from repro.serve.errors import (CapacityError, DecodeTimeout,
                                IllegalTaskError, ServeError,
                                TransientOracleError)
from repro.serve.faults import (KINDS, DegradedMeshOracle, FaultInjector,
                                FaultyOracle, repair_assignment)
from repro.serve.ledger import LatencyReservoir
from repro.sim.costsim import assignments_legal

FALLBACK_STAGES = ("expert", "greedy")


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    """Knobs for one ``PlacementService``.

    Admission: a queued bucket flushes when it holds ``max_batch``
    distinct tasks or its oldest request has waited ``max_wait_ms``.
    Cache: ``cache_entries`` LRU capacity; ``key_distribution=True``
    folds the access histograms into the digest (every drifted request
    then misses -- the always-decode policy; the default keys on
    structural features only).
    Drift: histogram EWMAs (``ewma_alpha``) trigger a re-placement when
    their max per-table total-variation distance from the placed
    snapshot exceeds ``drift_threshold`` (``None`` disables the loop);
    the refinement runs ``replace_strategy`` under
    ``replace_max_evals``/``replace_budget_ms`` with a migration term
    of ``migration_ms_per_gb`` x bytes moved in its objective.
    Resilience: a decode whose injected latency spike reaches
    ``decode_deadline_ms`` skips DreamShard and walks
    ``fallback_chain`` (``"expert"``: greedy size-balance on the
    surviving devices; ``"greedy"``: guaranteed-legal best-fit; an
    empty chain turns a busted deadline into ``DecodeTimeout``).
    Transient oracle errors retry up to ``oracle_retries`` times with
    ``retry_backoff_ms * 2**attempt`` sleeps (0 disables sleeping).
    Failover refinement is metered by ``failover_max_evals``; per-
    request latencies sample into a ``reservoir_size`` ledger.
    Sharding: ``shard_oversized=True`` adds a last-resort stage on the
    healthy mesh -- a task no whole-table layout can hold (e.g. one
    table larger than a device's HBM) gets a column-sharded placement
    via ``repro.sharding.ShardingPlacer`` instead of a
    ``CapacityError``.  Off by default: the legacy serving path stays
    bitwise.
    """

    max_wait_ms: float = 2.0
    max_batch: int = 16
    cache_entries: int = 4096
    key_distribution: bool = False
    ewma_alpha: float = 0.1
    drift_threshold: float | None = 0.1
    migration_ms_per_gb: float = 25.0
    replace_strategy: str = "lns"
    replace_max_evals: int | None = 96
    replace_budget_ms: float | None = None
    seed: int = 0
    decode_deadline_ms: float | None = None
    fallback_chain: tuple[str, ...] = ("expert", "greedy")
    oracle_retries: int = 2
    retry_backoff_ms: float = 0.0
    failover_max_evals: int | None = 64
    reservoir_size: int = 4096
    shard_oversized: bool = False

    def __post_init__(self):
        for stage in self.fallback_chain:
            if stage not in FALLBACK_STAGES:
                raise ValueError(f"unknown fallback stage {stage!r}; "
                                 f"expected one of {FALLBACK_STAGES}")


@dataclasses.dataclass
class ServeResult:
    """One served request: the placement plus serving provenance.

    ``source`` is ``"cache"`` / ``"decode"`` / ``"fallback"`` (a
    degraded-mode stage produced the placement) / ``"error"`` (no legal
    placement; ``placement`` is ``None`` and ``error`` carries the
    typed ``ServeError``).  ``degraded`` names the degradation applied
    (``"repair"`` / ``"expert"`` / ``"greedy"``), ``None`` on the
    healthy path.
    """

    placement: Placement | None
    source: str             # "cache" | "decode" | "fallback" | "error"
    latency_ms: float       # submit -> placement available
    queue_wait_ms: float    # admission-queue share of the latency
    replaced: bool = False  # a drift re-placement ran while serving this
    tag: object = None      # caller's correlation token
    error: ServeError | None = None
    degraded: str | None = None


@dataclasses.dataclass
class _Pending:
    """One queued decode (unique task digest) with its waiting tickets."""

    key: bytes
    raw: np.ndarray
    n_devices: int
    tickets: list[tuple[object, float]]   # (tag, t_enqueue)


class PlacementService:
    """Cache + admission + drift + fault loop over a ``PlacementSession``.

    Parameters
    ----------
    agent: a trained ``DreamShard`` (decode path), or pass ``session=``
        to reuse an existing warmed ``PlacementSession``.
    oracle: the ``CostOracle`` scoring drift re-placements (defaults to
        the agent's training oracle).
    faults: an optional ``FaultInjector``; when present it is ticked
        once per request, its events drive failover/degradation, and
        the serving oracle is wrapped in ``FaultyOracle`` so injected
        measurement errors exercise the retry path.
    clock: seconds-valued time source (injectable for deterministic
        admission tests; defaults to ``time.perf_counter``).

    ``submit`` returns the list of requests *completed by that call*: a
    cache hit completes immediately; a miss enqueues and may complete
    together with other queued requests when its bucket flushes.  Call
    ``flush()`` to drain stragglers (end of stream) and ``poll()`` to
    flush buckets whose wait deadline passed without new traffic.
    Neither ever raises for a bad request: malformed tasks and
    unplaceable meshes come back as ``ServeResult.error``.
    """

    def __init__(self, agent=None, oracle=None,
                 config: ServeConfig | None = None,
                 session: PlacementSession | None = None,
                 faults: FaultInjector | None = None,
                 clock: Callable[[], float] = time.perf_counter):
        if session is None:
            if agent is None:
                raise ValueError("pass a DreamShard agent or a session")
            session = PlacementSession(agent)
        self.session = session
        self.oracle = ensure_oracle(
            oracle if oracle is not None else session.agent.oracle)
        self.faults = faults
        if faults is not None:
            self.oracle = FaultyOracle(self.oracle, faults)
        self.config = config if config is not None else ServeConfig()
        self.clock = clock
        self.cache = PlacementCache(self.config.cache_entries)
        self.drift = DriftTracker(self.config.ewma_alpha)
        self.latency = LatencyReservoir(self.config.reservoir_size,
                                        seed=self.config.seed)
        self._queues: dict[tuple, dict[bytes, _Pending]] = {}
        self.requests = 0
        self.coalesced = 0          # misses absorbed by a queued duplicate
        self.decode_batches = 0
        self.decoded_tasks = 0
        self.replace_events = 0     # drift triggers (refine ran)
        self.migrations = 0         # triggers that actually moved tables
        self.bytes_moved_gb = 0.0
        # fault-path counters
        self.fault_events = {k: 0 for k in KINDS}
        self.evacuations = 0        # failover re-placements applied
        self.evacuation_failures = 0   # entries dropped (mesh can't hold)
        self.failover_bytes_gb = 0.0   # failover share of bytes_moved_gb
        self.fallbacks = {s: 0 for s in FALLBACK_STAGES}
        self.shard_fallbacks = 0    # sharded last-resort placements served
        self.repairs = 0            # decode outputs re-homed onto survivors
        self.deadline_skips = 0     # flushes that skipped DreamShard
        self.decode_errors = 0      # place_many raised (served via fallback)
        self.typed_errors = 0       # requests completed with a ServeError
        self.rejected = 0           # malformed requests (IllegalTaskError)
        self.retries = 0            # transient-oracle attempts that failed
        self.retry_exhausted = 0    # retry budgets fully consumed

    # ---- keying --------------------------------------------------------------

    def request_key(self, raw_features: np.ndarray, n_devices: int) -> bytes:
        return task_key(raw_features, n_devices,
                        include_distribution=self.config.key_distribution)

    # ---- serving -------------------------------------------------------------

    def submit(self, raw_features: np.ndarray, n_devices: int,
               tag: object = None) -> list[ServeResult]:
        """Serve one request; returns every request completed by this
        call (the hit itself, or queued requests whose bucket flushed).
        Never raises for a bad request -- malformed tasks complete
        immediately with a typed ``IllegalTaskError`` result."""
        now = self.clock()
        self.requests += 1
        tele.count("serve.requests")
        if self.faults is not None:
            for ev in self.faults.advance():
                self._on_fault(ev)
        err = self._validate(raw_features, n_devices)
        if err is not None:
            self.rejected += 1
            self.typed_errors += 1
            tele.count("serve.fallback.errors")
            latency = (self.clock() - now) * 1e3
            self.latency.record(latency)
            return [ServeResult(placement=None, source="error",
                                latency_ms=latency, queue_wait_ms=0.0,
                                error=err, tag=tag)]
        raw = np.asarray(raw_features, dtype=np.float64)
        key = self.request_key(raw, n_devices)
        ewma = self.drift.observe(key, raw[:, F.DIST_START:])

        entry = self.cache.get(key)
        if entry is not None:
            replaced = self._maybe_replace(key, entry, raw, ewma, n_devices)
            latency = (self.clock() - now) * 1e3
            self.latency.record(latency)
            return [ServeResult(placement=entry.placement, source="cache",
                                latency_ms=latency, queue_wait_ms=0.0,
                                replaced=replaced, tag=tag)]

        bucket = self.session.bucket_key(Task.of(raw, n_devices))
        queue = self._queues.setdefault(bucket, {})
        pending = queue.get(key)
        if pending is not None:                   # near-duplicate in flight
            self.coalesced += 1
            tele.count("serve.coalesced")
            pending.tickets.append((tag, now))
        else:
            queue[key] = _Pending(key=key, raw=raw, n_devices=n_devices,
                                  tickets=[(tag, now)])
        return self._flush_due(now)

    def poll(self) -> list[ServeResult]:
        """Flush buckets whose oldest request outwaited ``max_wait_ms``
        (call between requests on a quiet stream)."""
        return self._flush_due(self.clock())

    def flush(self) -> list[ServeResult]:
        """Drain every queued request regardless of batch/wait state."""
        out = []
        for bucket in list(self._queues):
            out.extend(self._flush_bucket(bucket))
        return out

    @property
    def pending(self) -> int:
        return sum(len(q) for q in self._queues.values())

    # ---- validation ----------------------------------------------------------

    def _validate(self, raw_features, n_devices) -> IllegalTaskError | None:
        try:
            raw = np.asarray(raw_features, dtype=np.float64)
        except Exception:
            return IllegalTaskError("raw_features is not numeric")
        if raw.ndim != 2 or raw.shape[1] != F.NUM_FEATURES:
            return IllegalTaskError(
                f"raw_features must be (M, {F.NUM_FEATURES}), "
                f"got shape {raw.shape}")
        if raw.shape[0] == 0:
            return IllegalTaskError("task has no tables")
        if not np.isfinite(raw).all():
            return IllegalTaskError("raw_features contains non-finite values")
        if (raw[:, F.TABLE_SIZE_GB] < 0.0).any():
            return IllegalTaskError("negative table sizes")
        try:
            n = int(n_devices)
        except (TypeError, ValueError):
            return IllegalTaskError(f"bad n_devices {n_devices!r}")
        if n < 1 or n != n_devices:
            return IllegalTaskError(f"n_devices must be a positive int, "
                                    f"got {n_devices!r}")
        return None

    # ---- fault handling ------------------------------------------------------

    def _mesh(self, n_devices: int) -> tuple[np.ndarray, float]:
        """(survivors mask, per-device capacity) for the current epoch."""
        if self.faults is None:
            return (np.ones(n_devices, dtype=bool),
                    self.oracle.mem_capacity_gb)
        return (self.faults.allowed_mask(n_devices),
                self.faults.capacity_gb(self.oracle.mem_capacity_gb))

    def _on_fault(self, ev) -> None:
        self.fault_events[ev.kind] += 1
        tele.count(f"serve.faults.{ev.kind}")
        if ev.kind in ("device_loss", "capacity_shrink"):
            self._failover_sweep(ev.kind)
        # device_recovery only widens the mesh (nothing cached is newly
        # illegal); oracle_error / decode_spike stay armed in the
        # injector until the next measurement / flush consumes them

    def _failover_sweep(self, reason: str) -> None:
        """Re-validate every cached placement against the shrunk mesh
        and evacuate the ones it can no longer hold."""
        t0 = self.clock()
        doomed: list[tuple[bytes, CacheEntry]] = []
        for key, entry in self.cache.items():
            D = entry.placement.n_devices
            allowed, capacity = self._mesh(D)
            if entry.raw is None:
                doomed.append((key, entry))       # nothing to re-place from
                continue
            a = entry.placement.assignment
            on_lost = not allowed[np.clip(a, 0, D - 1)].all()
            sizes = entry.raw[:, F.TABLE_SIZE_GB]
            fits = bool(assignments_legal(sizes, a[None, :], D, capacity)[0])
            if on_lost or not fits:
                doomed.append((key, entry))
        with tele.span("serve.failover", reason=reason,
                       affected=len(doomed)) as sp:
            moved0 = self.failover_bytes_gb
            for key, entry in doomed:
                self._evacuate(key, entry)
            sp.set(moved_gb=round(self.failover_bytes_gb - moved0, 4),
                   ms=round((self.clock() - t0) * 1e3, 3))

    def _evacuate(self, key: bytes, entry: CacheEntry) -> None:
        """Fail one cached placement over to the surviving mesh: greedy
        repair for immediate legality, then ``SearchPlacer.refine``
        seeded from that repair under the migration objective (restricted
        to survivors), so recovery moves only the bytes it must."""
        cfg = self.config
        if entry.raw is None:
            self.cache.invalidate(lambda k, e: k == key)
            self.evacuation_failures += 1
            tele.count("serve.faults.invalidated")
            return
        incumbent = entry.placement
        D = incumbent.n_devices
        allowed, capacity = self._mesh(D)
        sizes = entry.raw[:, F.TABLE_SIZE_GB]
        seed_a = repair_assignment(sizes, incumbent.assignment, allowed,
                                   capacity)
        if seed_a is None:                 # survivors cannot hold the task
            self.cache.invalidate(lambda k, e: k == key)
            self.evacuation_failures += 1
            tele.count("serve.faults.invalidated")
            return
        current = np.array(entry.raw)
        ewma = self.drift.estimate(key)
        if ewma is not None:
            current[:, F.DIST_START:] = ewma
        task = Task.of(current, D)
        from repro.search import SearchConfig, SearchPlacer
        oracle = DegradedMeshOracle(
            MigrationCostOracle.wrap(self.oracle, incumbent.assignment,
                                     cfg.migration_ms_per_gb),
            allowed, capacity)
        placer = SearchPlacer(
            oracle, agent=self.session.agent, name="serve.failover",
            config=SearchConfig(strategy=cfg.replace_strategy,
                                budget_ms=cfg.replace_budget_ms,
                                max_evals=cfg.failover_max_evals,
                                seed=cfg.seed))
        seed = Placement(assignment=seed_a,
                         plan=build_plan(current, seed_a, D),
                         n_devices=D, strategy="serve.failover")
        refined = self._with_retries(lambda: placer.refine(task, seed))
        if refined is None:                # retry budget exhausted: the
            refined = seed                 # repaired seed is still legal
        moved_gb = float(((refined.assignment != incumbent.assignment)
                          * sizes).sum())
        entry.placement = refined
        if ewma is not None:
            entry.snapshot = np.array(ewma)
        self.evacuations += 1
        self.failover_bytes_gb += moved_gb
        self.bytes_moved_gb += moved_gb
        self.migrations += 1
        tele.count("serve.faults.evacuated")
        tele.count("serve.migrations")

    def _with_retries(self, fn):
        """Run ``fn`` retrying ``TransientOracleError`` with bounded
        exponential backoff; ``None`` when the budget is exhausted."""
        cfg = self.config
        for attempt in range(cfg.oracle_retries + 1):
            try:
                return fn()
            except TransientOracleError:
                self.retries += 1
                tele.count("serve.fallback.retries")
                if attempt < cfg.oracle_retries and cfg.retry_backoff_ms > 0:
                    time.sleep(cfg.retry_backoff_ms * (2 ** attempt) / 1e3)
        self.retry_exhausted += 1
        tele.count("serve.fallback.retry_exhausted")
        return None

    # ---- admission -----------------------------------------------------------

    def _flush_due(self, now: float) -> list[ServeResult]:
        cfg = self.config
        out = []
        for bucket in list(self._queues):
            queue = self._queues[bucket]
            if not queue:
                continue
            oldest = min(t for p in queue.values() for _, t in p.tickets)
            if len(queue) >= cfg.max_batch or \
                    (now - oldest) * 1e3 >= cfg.max_wait_ms:
                out.extend(self._flush_bucket(bucket))
        return out

    def _flush_bucket(self, bucket: tuple) -> list[ServeResult]:
        pendings = list(self._queues.pop(bucket, {}).values())
        if not pendings:
            return []
        cfg = self.config
        t0 = self.clock()
        oldest = min(t for p in pendings for _, t in p.tickets)
        tasks = [Task.of(p.raw, p.n_devices) for p in pendings]
        spike_ms = (self.faults.take_spike_ms()
                    if self.faults is not None else 0.0)
        busted = (cfg.decode_deadline_ms is not None
                  and spike_ms >= cfg.decode_deadline_ms)
        decoded: list[Placement | None]
        if busted:
            self.deadline_skips += 1
            tele.count("serve.fallback.deadline")
            decoded = [None] * len(tasks)
        else:
            try:
                with tele.span("serve.flush", m_pad=bucket[0],
                               n_devices=bucket[1], tasks=len(tasks),
                               queue_wait_ms=round((t0 - oldest) * 1e3, 3)):
                    decoded = self.session.place_many(tasks)
                self.decode_batches += 1
                self.decoded_tasks += len(tasks)
                tele.count("serve.flushes")
                tele.count("serve.decoded", len(tasks))
            except Exception:              # decode itself died: the chain
                self.decode_errors += 1    # still owes every ticket an answer
                tele.count("serve.fallback.decode_errors")
                decoded = [None] * len(tasks)
        resolved = [self._resolve(task, placement, busted)
                    for task, placement in zip(tasks, decoded)]
        t1 = self.clock()
        out = []
        for pend, (placement, err, degraded) in zip(pendings, resolved):
            if placement is not None:
                self.cache.put(pend.key, CacheEntry(
                    placement=placement,
                    snapshot=np.array(pend.raw[:, F.DIST_START:]),
                    raw=np.array(pend.raw)))
            source = "error" if err is not None else \
                ("fallback" if degraded in (*FALLBACK_STAGES, "shard")
                 else "decode")
            if err is not None:
                self.typed_errors += len(pend.tickets)
                tele.count("serve.fallback.errors", len(pend.tickets))
            for tag, t_enq in pend.tickets:
                latency = (t1 - t_enq) * 1e3
                self.latency.record(latency)
                out.append(ServeResult(
                    placement=placement, source=source,
                    latency_ms=latency,
                    queue_wait_ms=(t0 - t_enq) * 1e3, tag=tag,
                    error=err, degraded=degraded))
        return out

    def _resolve(self, task: Task, decoded: Placement | None, busted: bool):
        """Turn one decode output (or its absence) into a legal placement
        via the fallback chain -> ``(placement, error, degraded)``."""
        cfg = self.config
        D = task.n_devices
        allowed, capacity = self._mesh(D)
        degraded_mesh = self.faults is not None and self.faults.degraded
        sizes = task.raw_features[:, F.TABLE_SIZE_GB]
        if decoded is not None:
            if not degraded_mesh:
                if cfg.shard_oversized and not bool(assignments_legal(
                        sizes, decoded.assignment[None], D, capacity)[0]):
                    # no whole-table layout can hold this task (e.g. one
                    # oversized table): opt-in column-sharded answer
                    placement = self._shard_stage(task)
                    if placement is not None:
                        self.shard_fallbacks += 1
                        tele.count("serve.fallback.shard")
                        return placement, None, "shard"
                return decoded, None, None       # healthy path: bitwise
            repaired = repair_assignment(sizes, decoded.assignment,
                                         allowed, capacity)
            if repaired is not None:
                if np.array_equal(repaired, decoded.assignment):
                    return decoded, None, None
                self.repairs += 1
                tele.count("serve.fallback.repairs")
                fixed = Placement(
                    assignment=repaired,
                    plan=build_plan(task.raw_features, repaired, D),
                    n_devices=D, strategy=decoded.strategy + "+repair",
                    candidates=decoded.candidates,
                    oracle_evals=decoded.oracle_evals)
                return fixed, None, "repair"
            # survivors can't hold the decode's layout at all; the chain
            # below gets its own shot before we declare capacity failure
        for stage in cfg.fallback_chain:
            placement = self._fallback_stage(stage, task, sizes, allowed,
                                             capacity)
            if placement is not None:
                self.fallbacks[stage] += 1
                tele.count(f"serve.fallback.{stage}")
                return placement, None, stage
        if cfg.shard_oversized and bool(allowed.all()):
            placement = self._shard_stage(task)
            if placement is not None:
                self.shard_fallbacks += 1
                tele.count("serve.fallback.shard")
                return placement, None, "shard"
        if busted and decoded is None and not cfg.fallback_chain:
            return None, DecodeTimeout(
                f"decode deadline {cfg.decode_deadline_ms}ms busted and "
                "no fallback stage is enabled"), None
        return None, CapacityError(
            f"no legal placement for {task.n_tables} tables on the "
            f"surviving mesh ({int(allowed.sum())}/{D} devices, "
            f"{capacity:.2f} GB each)"), None

    def _fallback_stage(self, stage: str, task: Task, sizes: np.ndarray,
                        allowed: np.ndarray,
                        capacity: float) -> Placement | None:
        """One degraded-mode placement attempt; ``None`` when the stage
        cannot produce a legal layout on the surviving devices."""
        D = task.n_devices
        survivors = np.flatnonzero(allowed)
        if survivors.size == 0:
            return None
        if stage == "expert":
            # greedy size-balance on the compressed survivor mesh, then
            # mapped back to physical ids (expert_place may overflow as a
            # last resort, so re-check)
            compressed = expert_place(task.raw_features, survivors.size,
                                      capacity, "size")
            a = survivors[compressed]
        else:                              # "greedy": guaranteed-legal
            a = repair_assignment(sizes, np.full(task.n_tables, -1,
                                                 dtype=np.int64),
                                  allowed, capacity)
            if a is None:
                return None
        if not bool(assignments_legal(sizes, a[None, :], D, capacity)[0]):
            return None
        return Placement(assignment=np.asarray(a, dtype=np.int64),
                         plan=build_plan(task.raw_features, a, D),
                         n_devices=D, strategy=f"serve.fallback.{stage}")

    def _shard_stage(self, task: Task) -> Placement | None:
        """Opt-in last resort (``shard_oversized``): column-shard so a
        task no whole-table layout can hold still serves.  Healthy-mesh
        only -- sharding does not know the degraded device mask."""
        from repro.api.oracle import legal_sharded
        from repro.sharding import ShardingPlacer
        try:
            placement = ShardingPlacer(self.oracle).place(task)
        except Exception:
            return None
        if placement.sharding is not None:
            legal = bool(legal_sharded(
                self.oracle, task.raw_features, placement.sharding,
                placement.shard_assignment[None], task.n_devices)[0])
        else:
            legal = bool(assignments_legal(
                task.raw_features[:, F.TABLE_SIZE_GB],
                placement.assignment[None], task.n_devices,
                self.oracle.mem_capacity_gb)[0])
        return placement if legal else None

    # ---- drift ---------------------------------------------------------------

    def _maybe_replace(self, key: bytes, entry: CacheEntry,
                       raw: np.ndarray, ewma: np.ndarray,
                       n_devices: int) -> bool:
        cfg = self.config
        if cfg.drift_threshold is None:
            return False
        divergence = dist_divergence(ewma, entry.snapshot)
        if divergence <= cfg.drift_threshold:
            return False
        # re-place against the *current* traffic estimate: structural
        # features from the request, histograms from the EWMA
        from repro.search import SearchConfig, SearchPlacer
        current = np.array(raw)
        current[:, F.DIST_START:] = ewma
        task = Task.of(current, n_devices)
        incumbent = entry.placement
        with tele.span("serve.replace", divergence=round(divergence, 4),
                       M=task.n_tables, n_devices=n_devices) as sp:
            oracle = MigrationCostOracle.wrap(
                self.oracle, incumbent.assignment, cfg.migration_ms_per_gb)
            if self.faults is not None and self.faults.degraded:
                # drift refinement must not re-home tables onto a lost
                # device while the mesh is degraded
                allowed, capacity = self._mesh(n_devices)
                oracle = DegradedMeshOracle(oracle, allowed, capacity)
            placer = SearchPlacer(
                oracle, agent=self.session.agent, name="serve.replace",
                config=SearchConfig(strategy=cfg.replace_strategy,
                                    budget_ms=cfg.replace_budget_ms,
                                    max_evals=cfg.replace_max_evals,
                                    seed=cfg.seed))
            refined = self._with_retries(
                lambda: placer.refine(task, incumbent))
            if refined is None:            # retries exhausted: keep serving
                sp.set(kept_incumbent=True)   # the incumbent unchanged
                return False
            moved_gb = float(((refined.assignment != incumbent.assignment)
                              * current[:, F.TABLE_SIZE_GB]).sum())
            sp.set(moved_gb=round(moved_gb, 4))
        entry.placement = refined
        entry.snapshot = np.array(ewma)
        entry.raw = np.array(raw)
        entry.replaces += 1
        self.replace_events += 1
        self.bytes_moved_gb += moved_gb
        tele.count("serve.replace_events")
        if moved_gb > 0.0:
            self.migrations += 1
            tele.count("serve.migrations")
        return True

    # ---- checkpointing -------------------------------------------------------

    def save(self, path: str) -> None:
        """Checkpoint the serving state (cache entries in LRU order,
        drift EWMAs, admission queues, counters, latency ledger, fault
        epoch) through ``repro.checkpoint.save_state``.  Queued request
        tickets are serialized too, so a warm restart owes exactly the
        in-flight work the crash interrupted -- their ``tag`` values
        must be JSON-serializable (or ``flush()`` first)."""
        from repro import checkpoint
        arrays: dict[str, np.ndarray] = {}
        entries_meta = []
        i = 0
        for key, e in self.cache.items():
            if e.raw is None:       # hand-built entry: nothing to restore
                continue            # a placement from, so not checkpointed
            arrays[f"entry{i}.raw"] = e.raw
            arrays[f"entry{i}.snapshot"] = e.snapshot
            arrays[f"entry{i}.assignment"] = e.placement.assignment
            entries_meta.append({
                "key": key.hex(),
                "n_devices": e.placement.n_devices,
                "strategy": e.placement.strategy,
                "est_cost_ms": e.placement.est_cost_ms,
                "candidates": e.placement.candidates,
                "oracle_evals": e.placement.oracle_evals,
                "requests": e.requests, "replaces": e.replaces})
            i += 1
        drift_keys = []
        for i, (key, ewma) in enumerate(self.drift._ewma.items()):
            arrays[f"ewma{i}"] = ewma
            drift_keys.append(key.hex())
        queues_meta = []
        q = 0
        for bucket, queue in self._queues.items():
            pendings_meta = []
            for pend in queue.values():
                arrays[f"queue{q}.raw"] = pend.raw
                pendings_meta.append({
                    "key": pend.key.hex(), "raw_idx": q,
                    "n_devices": pend.n_devices,
                    "tickets": [[tag, t] for tag, t in pend.tickets]})
                q += 1
            queues_meta.append({"bucket": [int(b) for b in bucket],
                                "pendings": pendings_meta})
        meta = {
            "entries": entries_meta,
            "drift_keys": drift_keys,
            "queues": queues_meta,
            "counters": self._counter_state(),
            "cache_counters": {"hits": self.cache.hits,
                               "misses": self.cache.misses,
                               "evictions": self.cache.evictions,
                               "invalidations": self.cache.invalidations},
            "reservoir": self.latency.state_dict(),
            "faults": (self.faults.state_dict()
                       if self.faults is not None else None),
        }
        checkpoint.save_state(path, arrays, meta)
        tele.count("serve.checkpoint.saves")

    @classmethod
    def restore(cls, path: str, agent=None, oracle=None,
                config: ServeConfig | None = None,
                session: PlacementSession | None = None,
                faults: FaultInjector | None = None,
                clock: Callable[[], float] = time.perf_counter
                ) -> "PlacementService":
        """Warm-restart a service from a ``save`` checkpoint.  The model
        and oracle are reconstructed by the caller (they have their own
        checkpoints); this restores the *serving* state -- cache, drift,
        queued tickets, counters, ledger -- and advances ``faults`` to
        the epoch the
        checkpoint was taken at, so replaying the remaining stream is
        bitwise-identical to a run that never stopped."""
        from repro import checkpoint
        arrays, meta = checkpoint.load_state(path)
        svc = cls(agent=agent, oracle=oracle, config=config,
                  session=session, faults=faults, clock=clock)
        if faults is not None and meta["faults"] is not None:
            faults.load_state_dict(meta["faults"])
        for i, em in enumerate(meta["entries"]):
            raw = np.asarray(arrays[f"entry{i}.raw"], dtype=np.float64)
            a = np.asarray(arrays[f"entry{i}.assignment"], dtype=np.int64)
            placement = Placement(
                assignment=a,
                plan=build_plan(raw, a, int(em["n_devices"])),
                n_devices=int(em["n_devices"]), strategy=em["strategy"],
                est_cost_ms=em["est_cost_ms"],
                candidates=int(em["candidates"]),
                oracle_evals=int(em["oracle_evals"]))
            svc.cache.put(bytes.fromhex(em["key"]), CacheEntry(
                placement=placement,
                snapshot=np.asarray(arrays[f"entry{i}.snapshot"],
                                    dtype=np.float64),
                requests=int(em["requests"]),
                replaces=int(em["replaces"]), raw=raw))
        for i, key_hex in enumerate(meta["drift_keys"]):
            svc.drift._ewma[bytes.fromhex(key_hex)] = np.asarray(
                arrays[f"ewma{i}"], dtype=np.float64)
        for qm in meta.get("queues", []):
            queue = svc._queues.setdefault(tuple(qm["bucket"]), {})
            for pm in qm["pendings"]:
                key = bytes.fromhex(pm["key"])
                queue[key] = _Pending(
                    key=key,
                    raw=np.asarray(arrays[f"queue{pm['raw_idx']}.raw"],
                                   dtype=np.float64),
                    n_devices=int(pm["n_devices"]),
                    tickets=[(tag, float(t)) for tag, t in pm["tickets"]])
        svc._load_counter_state(meta["counters"])
        cc = meta["cache_counters"]
        svc.cache.hits = int(cc["hits"])
        svc.cache.misses = int(cc["misses"])
        svc.cache.evictions = int(cc["evictions"])
        svc.cache.invalidations = int(cc["invalidations"])
        svc.latency.load_state_dict(meta["reservoir"])
        tele.count("serve.checkpoint.restores")
        return svc

    def _counter_state(self) -> dict:
        return {
            "requests": self.requests, "coalesced": self.coalesced,
            "decode_batches": self.decode_batches,
            "decoded_tasks": self.decoded_tasks,
            "replace_events": self.replace_events,
            "migrations": self.migrations,
            "bytes_moved_gb": self.bytes_moved_gb,
            "fault_events": dict(self.fault_events),
            "evacuations": self.evacuations,
            "evacuation_failures": self.evacuation_failures,
            "failover_bytes_gb": self.failover_bytes_gb,
            "fallbacks": dict(self.fallbacks),
            "repairs": self.repairs,
            "deadline_skips": self.deadline_skips,
            "decode_errors": self.decode_errors,
            "typed_errors": self.typed_errors,
            "rejected": self.rejected,
            "retries": self.retries,
            "retry_exhausted": self.retry_exhausted,
        }

    def _load_counter_state(self, state: dict) -> None:
        for name in ("requests", "coalesced", "decode_batches",
                     "decoded_tasks", "replace_events", "migrations",
                     "evacuations", "evacuation_failures", "repairs",
                     "deadline_skips", "decode_errors", "typed_errors",
                     "rejected", "retries", "retry_exhausted"):
            setattr(self, name, int(state[name]))
        self.bytes_moved_gb = float(state["bytes_moved_gb"])
        self.failover_bytes_gb = float(state["failover_bytes_gb"])
        self.fault_events = {k: int(v)
                             for k, v in state["fault_events"].items()}
        self.fallbacks = {k: int(v) for k, v in state["fallbacks"].items()}

    # ---- introspection -------------------------------------------------------

    def stats(self) -> dict:
        """Serving-behaviour snapshot (instance counters; the same
        signals stream through ``serve.*`` telemetry counters)."""
        return {
            "requests": self.requests,
            "hits": self.cache.hits,
            "misses": self.cache.misses,
            "hit_rate": self.cache.hit_rate,
            "evictions": self.cache.evictions,
            "invalidations": self.cache.invalidations,
            "entries": len(self.cache),
            "coalesced": self.coalesced,
            "pending": self.pending,
            "decode_batches": self.decode_batches,
            "decoded_tasks": self.decoded_tasks,
            "replace_events": self.replace_events,
            "migrations": self.migrations,
            "bytes_moved_gb": self.bytes_moved_gb,
            "fault_events": dict(self.fault_events),
            "fault_epoch": (self.faults.epoch
                            if self.faults is not None else 0),
            "evacuations": self.evacuations,
            "evacuation_failures": self.evacuation_failures,
            "failover_bytes_gb": self.failover_bytes_gb,
            "fallbacks": dict(self.fallbacks),
            "repairs": self.repairs,
            "deadline_skips": self.deadline_skips,
            "decode_errors": self.decode_errors,
            "typed_errors": self.typed_errors,
            "rejected": self.rejected,
            "retries": self.retries,
            "retry_exhausted": self.retry_exhausted,
            "latency": self.latency.summary(),
        }
