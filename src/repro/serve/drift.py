"""Drift detection and the migration-aware re-placement objective.

RecShard's observation (PAPERS.md) is that *access-distribution
statistics* are the right trigger for re-sharding: a placement computed
against yesterday's table popularity degrades as the histogram moves,
and the moment to re-place is when the observed distribution has
diverged measurably from the one the placement was optimized for.

Two pieces implement that here:

* ``DriftTracker`` -- per-task EWMAs of the 17-bin per-table access
  histograms carried on every request, plus the total-variation
  divergence against the placed snapshot that the service compares to
  its threshold;
* ``MigrationCostOracle`` -- a ``CostOracle`` wrapper that adds a
  migration term (bytes moved off the incumbent placement x link cost)
  to every measured cost, so the re-placement search
  (``SearchPlacer.refine``) only accepts moves whose steady-state win
  pays for the transfer: a 10 GB table does not bounce between devices
  for a 1% win.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.api.oracle import ensure_oracle, evaluate_many, legal_batch
from repro.core import features as F
from repro.sim.costsim import check_assignment_batch


def dist_divergence(observed: np.ndarray, snapshot: np.ndarray) -> float:
    """Max per-table total-variation distance between two ``(M, 17)``
    histogram stacks -- the drift metric.

    TV distance is ``0.5 * |p - q|_1`` per table: bounded in [0, 1],
    symmetric, and zero iff the distributions match, so a threshold on
    it reads directly as "this much probability mass has moved".  The
    max over tables (rather than a mean) triggers on a single table
    going hot, which is exactly the case that unbalances a device.
    """
    observed = np.asarray(observed, dtype=np.float64)
    snapshot = np.asarray(snapshot, dtype=np.float64)
    return float(0.5 * np.abs(observed - snapshot).sum(axis=-1).max())


class DriftTracker:
    """Per-key EWMAs of observed per-table access histograms.

    ``observe`` folds one request's histograms into the key's running
    estimate (initialized to the first observation, the standard EWMA
    seed) and returns the current estimate.  With ``alpha=0`` the
    estimate never moves off the first observation -- useful for
    pinning zero-drift replays bitwise.
    """

    def __init__(self, alpha: float = 0.05):
        self.alpha = alpha
        self._ewma: dict[bytes, np.ndarray] = {}

    def observe(self, key: bytes, dist: np.ndarray) -> np.ndarray:
        dist = np.asarray(dist, dtype=np.float64)
        cur = self._ewma.get(key)
        if cur is None or self.alpha >= 1.0:
            cur = dist.copy()
        elif self.alpha > 0.0:
            cur = (1.0 - self.alpha) * cur + self.alpha * dist
        self._ewma[key] = cur
        return cur

    def estimate(self, key: bytes) -> np.ndarray | None:
        return self._ewma.get(key)


@dataclasses.dataclass(frozen=True)
class MigrationCostOracle:
    """``CostOracle`` adding bytes-moved x link cost to every result.

    ``incumbent`` is the currently-served assignment; a candidate's
    migration penalty is ``ms_per_gb`` times the total size of tables
    it places on a *different* device.  The incumbent itself (the seed
    of every ``SearchPlacer.refine``) pays zero penalty, so search
    under this oracle accepts a move only when the measured placement
    win exceeds the cost of actually performing it.  ``num_evaluations``
    and legality delegate to the wrapped oracle -- the penalty is pure
    arithmetic, never a hardware measurement.
    """

    inner: object
    incumbent: np.ndarray
    ms_per_gb: float

    @classmethod
    def wrap(cls, oracle, incumbent: np.ndarray,
             ms_per_gb: float) -> "MigrationCostOracle":
        return cls(inner=ensure_oracle(oracle),
                   incumbent=np.asarray(incumbent, dtype=np.int64),
                   ms_per_gb=float(ms_per_gb))

    @property
    def mem_capacity_gb(self) -> float:
        return self.inner.mem_capacity_gb

    @property
    def num_evaluations(self) -> int:
        return self.inner.num_evaluations

    def migration_gb(self, raw: np.ndarray,
                     assignments: np.ndarray) -> np.ndarray:
        """Bytes (GB) each candidate row moves off the incumbent -- (P,)."""
        sizes = np.asarray(raw, dtype=np.float64)[:, F.TABLE_SIZE_GB]
        moved = np.asarray(assignments, dtype=np.int64) != self.incumbent
        return (moved * sizes).sum(axis=-1)

    def evaluate_many(self, raw, assignments, n_devices):
        assignments = check_assignment_batch(assignments, n_devices)
        results = evaluate_many(self.inner, raw, assignments, n_devices)
        penalty = self.migration_gb(raw, assignments) * self.ms_per_gb
        return [r if p == 0.0 else
                dataclasses.replace(r, overall=r.overall + float(p))
                for r, p in zip(results, penalty)]

    def evaluate(self, raw, assignment, n_devices):
        return self.evaluate_many(
            raw, np.asarray(assignment)[None, :], n_devices)[0]

    def legal(self, raw, assignment, n_devices) -> bool:
        return bool(self.legal_batch(
            raw, np.asarray(assignment)[None, :], n_devices)[0])

    def legal_batch(self, raw, assignments, n_devices) -> np.ndarray:
        return legal_batch(self.inner, raw, assignments, n_devices)
