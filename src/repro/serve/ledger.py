"""Bounded per-request latency ledger for ``PlacementService.stats()``.

A week-long replay submits millions of requests; keeping every latency
in a growing list is an OOM waiting to happen.  ``LatencyReservoir``
keeps a fixed-size uniform sample (Vitter's Algorithm R) with a seeded
generator, so memory is O(capacity) forever, quantiles over the sample
are unbiased estimates of the stream's, and two replays of the same
stream report identical numbers.

Semantics pinned by ``tests/test_resilience.py``:

* below ``capacity`` the reservoir holds *every* observation, so
  ``quantile`` is exact;
* ``quantile(q)`` is ``numpy.quantile`` (linear interpolation) over the
  current sample, ``nan`` when empty;
* ``count`` always reflects the full stream, not the sample size.
"""

from __future__ import annotations

import numpy as np


class LatencyReservoir:
    """Fixed-size uniform sample of a latency stream (Algorithm R)."""

    def __init__(self, capacity: int = 4096, seed: int = 0):
        if capacity < 1:
            raise ValueError("capacity must be >= 1")
        self.capacity = capacity
        self.seed = seed
        self.count = 0                     # stream length, not sample size
        self.total = 0.0
        self._sample = np.empty(capacity, dtype=np.float64)
        self._rng = np.random.default_rng(seed)

    def __len__(self) -> int:
        return min(self.count, self.capacity)

    def record(self, value_ms: float) -> None:
        v = float(value_ms)
        if self.count < self.capacity:
            self._sample[self.count] = v
        else:
            # accept with probability capacity / (count + 1); evict uniform
            j = int(self._rng.integers(0, self.count + 1))
            if j < self.capacity:
                self._sample[j] = v
        self.count += 1
        self.total += v

    def values(self) -> np.ndarray:
        """Current sample (a copy), unordered."""
        return np.array(self._sample[:len(self)])

    def quantile(self, q: float) -> float:
        if len(self) == 0:
            return float("nan")
        return float(np.quantile(self._sample[:len(self)], q))

    @property
    def mean(self) -> float:
        return self.total / self.count if self.count else float("nan")

    def summary(self) -> dict:
        """The ``stats()`` cell: stream count/mean + sampled p50/p99
        (``None`` while empty -- the dict is written to JSON as-is)."""
        if self.count == 0:
            return {"count": 0, "mean_ms": None, "p50_ms": None,
                    "p99_ms": None}
        return {"count": self.count,
                "mean_ms": self.mean,
                "p50_ms": self.quantile(0.50),
                "p99_ms": self.quantile(0.99)}

    # ---- checkpointing -------------------------------------------------------

    def state_dict(self) -> dict:
        """Serializable state: sample buffer + generator state, so a
        restored reservoir continues the *same* sampling decisions."""
        return {"capacity": self.capacity, "seed": self.seed,
                "count": self.count, "total": self.total,
                "sample": self.values().tolist(),
                "rng": self._rng.bit_generator.state}

    def load_state_dict(self, state: dict) -> None:
        if int(state["capacity"]) != self.capacity:
            raise ValueError("reservoir capacity mismatch on restore")
        self.count = int(state["count"])
        self.total = float(state["total"])
        sample = np.asarray(state["sample"], dtype=np.float64)
        self._sample[:len(sample)] = sample
        self._rng.bit_generator.state = state["rng"]
