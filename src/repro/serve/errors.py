"""Typed serving errors: the structured failure surface of ``repro.serve``.

``PlacementService`` promises that ``submit``/``poll``/``flush`` never
leak a raw ``AssertionError``/``ValueError`` for a bad *request*: every
request completes with either a legal placement or a ``ServeResult``
carrying one of these typed errors, so a stream replay survives
malformed tasks, lost capacity, and busted decode deadlines without an
exception unwinding the whole admission loop.

The hierarchy is deliberately small:

* ``IllegalTaskError``     -- the request itself is malformed (wrong
  feature width, non-finite values, no tables, bad device count);
* ``CapacityError``        -- the task is well-formed but no legal
  placement exists on the (possibly degraded) mesh: every stage of the
  fallback chain failed the memory check;
* ``DecodeTimeout``        -- the decode deadline was busted and the
  fallback chain was disabled, so nothing could serve the bucket;
* ``TransientOracleError`` -- a cost-oracle measurement failed in a
  retryable way (raised by ``FaultInjector``-wrapped oracles; the
  service retries with backoff and degrades gracefully on exhaustion --
  this one is *handled internally* and only surfaces in telemetry).
"""

from __future__ import annotations


class ServeError(Exception):
    """Base of every typed serving error.

    ``code`` is a stable machine-readable slug (mirrors the class name)
    so structured consumers (benchmarks, log pipelines) can switch on it
    without string-matching messages.
    """

    code = "serve_error"

    def describe(self) -> dict:
        """Structured view for logs / benchmark JSON."""
        return {"code": self.code, "message": str(self)}


class IllegalTaskError(ServeError):
    """The request is malformed; no placement can even be attempted."""

    code = "illegal_task"


class CapacityError(ServeError):
    """No legal placement exists on the surviving mesh capacity."""

    code = "capacity"


class DecodeTimeout(ServeError):
    """The decode deadline passed and no fallback stage was allowed."""

    code = "decode_timeout"


class TransientOracleError(ServeError):
    """A retryable cost-oracle failure (injected or real); the service
    retries with bounded backoff and keeps the incumbent on exhaustion."""

    code = "transient_oracle"
