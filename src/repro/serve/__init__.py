"""High-throughput placement serving.

``PlacementService`` fronts a ``PlacementSession`` with a digest-keyed
placement cache, micro-batch admission, and drift-triggered incremental
re-placement.  See ``docs/api.md`` ("Placement serving & drift
re-placement") and ``examples/serve_workflow.py``.
"""

from repro.serve.cache import CacheEntry, PlacementCache
from repro.serve.drift import (DriftTracker, MigrationCostOracle,
                               dist_divergence)
from repro.serve.service import PlacementService, ServeConfig, ServeResult

__all__ = [
    "CacheEntry", "DriftTracker", "MigrationCostOracle",
    "PlacementCache", "PlacementService", "ServeConfig", "ServeResult",
    "dist_divergence",
]
