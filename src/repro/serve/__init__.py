"""High-throughput placement serving.

``PlacementService`` fronts a ``PlacementSession`` with a digest-keyed
placement cache, micro-batch admission, drift-triggered incremental
re-placement, and a fault-tolerance layer (``FaultInjector`` schedules,
failover re-placement, degraded-mode fallbacks, typed ``ServeError``
results, warm-restart checkpoints).  See ``docs/api.md`` ("Placement
serving & drift re-placement", "Resilient serving") and
``examples/serve_workflow.py``.
"""

from repro.serve.cache import CacheEntry, PlacementCache
from repro.serve.drift import (DriftTracker, MigrationCostOracle,
                               dist_divergence)
from repro.serve.errors import (CapacityError, DecodeTimeout,
                                IllegalTaskError, ServeError,
                                TransientOracleError)
from repro.serve.faults import (DegradedMeshOracle, FaultEvent,
                                FaultInjector, FaultSchedule, FaultyOracle,
                                repair_assignment)
from repro.serve.ledger import LatencyReservoir
from repro.serve.service import PlacementService, ServeConfig, ServeResult

__all__ = [
    "CacheEntry", "CapacityError", "DecodeTimeout", "DegradedMeshOracle",
    "DriftTracker", "FaultEvent", "FaultInjector", "FaultSchedule",
    "FaultyOracle", "IllegalTaskError", "LatencyReservoir",
    "MigrationCostOracle", "PlacementCache", "PlacementService",
    "ServeConfig", "ServeError", "ServeResult", "TransientOracleError",
    "dist_divergence", "repair_assignment",
]
