"""Plain-text report over a persisted telemetry trace.

  PYTHONPATH=src python -m repro.telemetry.report trace.jsonl
  PYTHONPATH=src python -m repro.telemetry.report trace.json --top 10

Accepts either sink format (JSONL event log or Chrome trace JSON) and
prints the per-span-name aggregate table plus counters and gauges --
the quick look before opening the trace in ``chrome://tracing`` /
Perfetto.
"""

from __future__ import annotations

import argparse
import sys

from repro.telemetry.sinks import load_trace, summarize


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.telemetry.report",
        description="Summarize a telemetry trace (JSONL or Chrome JSON).")
    ap.add_argument("trace", help="path written by --trace / write_jsonl / "
                                  "write_chrome_trace")
    ap.add_argument("--top", type=int, default=30,
                    help="span names shown, by total time (default 30)")
    args = ap.parse_args(argv)
    trace = load_trace(args.trace)
    print(f"{args.trace}: {len(trace['spans'])} span(s), "
          f"{len(trace['counters'])} counter(s), "
          f"{len(trace['gauges'])} gauge(s)")
    print(summarize(trace, top=args.top))
    return 0


if __name__ == "__main__":
    sys.exit(main())
