"""Unified telemetry: spans, counters, gauges, and trace export.

The instrumentation seam for the whole stack -- oracles, search,
placement sessions, and the trainer all emit through this module (and
stay no-ops until ``enable()`` / a ``--trace`` flag turns recording
on).  See ``docs/api.md`` "Telemetry & tracing" for the span API, sink
formats, and how to read a placement trace in Perfetto.
"""

from repro.telemetry.core import (DEFAULT_MAX_EVENTS, MetricsRegistry,
                                  NOOP_SPAN, Span, Tracer, count,
                                  counter_value, disable, enable, gauge,
                                  get_tracer, is_enabled, reset, snapshot,
                                  span)
from repro.telemetry.sinks import (load_trace, read_chrome_trace, read_jsonl,
                                   summarize, trace_to, write_chrome_trace,
                                   write_jsonl)

__all__ = [
    "DEFAULT_MAX_EVENTS", "MetricsRegistry", "NOOP_SPAN", "Span", "Tracer",
    "count", "counter_value", "disable", "enable", "gauge", "get_tracer",
    "is_enabled", "load_trace", "read_chrome_trace", "read_jsonl", "reset",
    "snapshot", "span", "summarize", "trace_to", "write_chrome_trace",
    "write_jsonl",
]
