"""Zero-dependency runtime telemetry: spans, counters, and gauges.

DreamShard's pitch is cost transparency, so the stack instruments its
own hot paths the same way: every oracle query, search round, bucket
decode, and trainer stage can emit a wall-clock **span** (nested,
thread-aware) and bump **counters**/**gauges** in a process-global
``MetricsRegistry``.  The subsystem is stdlib-only and *disabled by
default*: with no tracer installed, ``span()`` returns a shared no-op
context manager and ``count()``/``gauge()`` early-out after one global
read -- the off path is a boolean check plus (for spans) one kwargs
dict, well under 1% of any instrumented workload
(``benchmarks/b10_telemetry_overhead.py`` asserts this in CI).

Usage::

    from repro import telemetry as tele

    tele.enable()
    with tele.span("search.round", strategy="lns") as sp:
        ...
        sp.set(incumbent_ms=12.5)       # attrs may be added mid-span
    tele.count("oracle.cache.hits", 3)
    tele.snapshot()                      # counters + gauges + span aggs
    tele.write_chrome_trace("trace.json")   # open in chrome://tracing

``sinks.py`` holds the exporters (Chrome ``trace_event`` JSON, JSONL,
plain-text summary); ``report.py`` is the CLI over a persisted trace.
"""

from __future__ import annotations

import itertools
import threading
import time

# spans kept in memory before the tracer starts dropping (long-running
# services must export + reset periodically; ``dropped`` reports losses)
DEFAULT_MAX_EVENTS = 1_000_000


class MetricsRegistry:
    """Process-global monotonic counters and last-value gauges.

    One lock serializes writers, so concurrent ``count`` calls from
    worker threads never lose increments (asserted in
    ``tests/test_telemetry.py``).  Reads (``snapshot``) copy under the
    same lock.
    """

    def __init__(self):
        self._lock = threading.Lock()
        self._counters: dict[str, float] = {}
        self._gauges: dict[str, float] = {}

    def count(self, name: str, value=1) -> None:
        with self._lock:
            self._counters[name] = self._counters.get(name, 0) + value

    def gauge(self, name: str, value) -> None:
        with self._lock:
            self._gauges[name] = value

    def counters(self) -> dict:
        with self._lock:
            return dict(self._counters)

    def gauges(self) -> dict:
        with self._lock:
            return dict(self._gauges)

    def clear(self) -> None:
        with self._lock:
            self._counters.clear()
            self._gauges.clear()


class Span:
    """One live wall-clock span; records itself on ``__exit__``.

    ``set(**attrs)`` merges attributes any time before exit -- round
    spans use it to attach results (incumbent cost, rows scored) that
    only exist once the round ran.
    """

    __slots__ = ("_tracer", "name", "args", "id", "parent", "_t0")

    def __init__(self, tracer: "Tracer", name: str, args: dict):
        self._tracer = tracer
        self.name = name
        self.args = args
        self.id = next(tracer._ids)
        self.parent = None
        self._t0 = 0.0

    def set(self, **attrs) -> "Span":
        self.args.update(attrs)
        return self

    def __enter__(self) -> "Span":
        stack = self._tracer._stack()
        self.parent = stack[-1].id if stack else None
        stack.append(self)
        self._t0 = time.perf_counter()
        return self

    def __exit__(self, *exc) -> bool:
        t1 = time.perf_counter()
        stack = self._tracer._stack()
        if stack and stack[-1] is self:
            stack.pop()
        self._tracer._record(self, self._t0, t1)
        return False


class _NoopSpan:
    """The disabled-path singleton: enter/exit/set all do nothing."""

    __slots__ = ()

    def set(self, **attrs) -> "_NoopSpan":
        return self

    def __enter__(self) -> "_NoopSpan":
        return self

    def __exit__(self, *exc) -> bool:
        return False


NOOP_SPAN = _NoopSpan()


class Tracer:
    """Thread-safe span recorder.

    Spans nest per thread (a ``threading.local`` stack provides the
    parent id) and finished spans are appended to one bounded in-memory
    event list as ``(name, ts_us, dur_us, tid, span_id, parent_id,
    args)`` tuples -- microseconds since the tracer's epoch, the unit
    Chrome's ``trace_event`` format wants natively.
    """

    def __init__(self, max_events: int = DEFAULT_MAX_EVENTS):
        self.max_events = max_events
        self.epoch = time.perf_counter()
        self.epoch_unix = time.time()
        self.events: list[tuple] = []
        self.dropped = 0
        self._ids = itertools.count(1)
        self._lock = threading.Lock()
        self._local = threading.local()
        self._tids: dict[int, int] = {}

    def span(self, name: str, args: dict) -> Span:
        return Span(self, name, args)

    def _stack(self) -> list:
        stack = getattr(self._local, "stack", None)
        if stack is None:
            stack = self._local.stack = []
        return stack

    def _tid(self) -> int:
        """Small stable per-thread id (0 = the first thread seen)."""
        ident = threading.get_ident()
        tid = self._tids.get(ident)
        if tid is None:
            with self._lock:
                tid = self._tids.setdefault(ident, len(self._tids))
        return tid

    def _record(self, span: Span, t0: float, t1: float) -> None:
        event = (span.name,
                 (t0 - self.epoch) * 1e6,       # ts (us)
                 (t1 - t0) * 1e6,               # dur (us)
                 self._tid(), span.id, span.parent, span.args)
        with self._lock:
            if len(self.events) >= self.max_events:
                self.dropped += 1
            else:
                self.events.append(event)

    def snapshot_events(self) -> list[tuple]:
        with self._lock:
            return list(self.events)

    def span_aggregates(self) -> dict:
        """Per-name ``{count, total_ms, max_ms}`` over recorded spans."""
        aggs: dict[str, dict] = {}
        for name, _ts, dur, *_rest in self.snapshot_events():
            a = aggs.get(name)
            if a is None:
                a = aggs[name] = {"count": 0, "total_ms": 0.0, "max_ms": 0.0}
            a["count"] += 1
            a["total_ms"] += dur / 1e3
            a["max_ms"] = max(a["max_ms"], dur / 1e3)
        for a in aggs.values():
            a["total_ms"] = round(a["total_ms"], 6)
            a["max_ms"] = round(a["max_ms"], 6)
        return aggs

    def clear(self) -> None:
        with self._lock:
            self.events.clear()
            self.dropped = 0


# ---- module-global state -----------------------------------------------------

# ONE registry for the process (survives enable/disable cycles so a
# snapshot taken after disable still sees the run's counters) and an
# optional tracer; ``_TRACER is None`` IS the disabled fast path.
_REGISTRY = MetricsRegistry()
_TRACER: Tracer | None = None


def enable(max_events: int = DEFAULT_MAX_EVENTS) -> Tracer:
    """Install the process tracer (idempotent); returns it."""
    global _TRACER
    if _TRACER is None:
        _TRACER = Tracer(max_events=max_events)
    return _TRACER


def disable() -> None:
    """Remove the tracer: instrumentation reverts to the no-op path.

    Recorded events and counters are kept (export-after-run works);
    ``reset()`` clears them.
    """
    global _TRACER
    _TRACER = None


def is_enabled() -> bool:
    return _TRACER is not None


def get_tracer() -> Tracer | None:
    return _TRACER


def span(name: str, /, **attrs):
    """A wall-clock span context manager (no-op singleton when off).

    ``name`` is positional-only so an attribute may itself be called
    ``name`` without colliding."""
    tracer = _TRACER
    if tracer is None:
        return NOOP_SPAN
    return Span(tracer, name, attrs)


def count(name: str, value=1) -> None:
    """Bump a monotonic counter (no-op when telemetry is off)."""
    if _TRACER is None:
        return
    _REGISTRY.count(name, value)


def gauge(name: str, value) -> None:
    """Set a last-value gauge (no-op when telemetry is off)."""
    if _TRACER is None:
        return
    _REGISTRY.gauge(name, value)


def counter_value(name: str, default=0):
    """Current value of one counter (0 when never bumped)."""
    return _REGISTRY.counters().get(name, default)


def snapshot() -> dict:
    """The unified introspection surface: counters, gauges, and span
    aggregates in one dict (the ``CachedOracle.info()``-style views now
    all live here)."""
    tracer = _TRACER
    return {
        "enabled": tracer is not None,
        "counters": _REGISTRY.counters(),
        "gauges": _REGISTRY.gauges(),
        "spans": tracer.span_aggregates() if tracer is not None else {},
        "dropped_events": tracer.dropped if tracer is not None else 0,
    }


def reset() -> None:
    """Clear counters, gauges, and recorded spans (keeps enabled state)."""
    _REGISTRY.clear()
    if _TRACER is not None:
        _TRACER.clear()
