"""Telemetry sinks: Chrome ``trace_event`` JSON, JSONL, text summary.

Three ways out of the in-memory tracer:

* ``write_chrome_trace(path)`` -- the Trace Event Format (``ph: "X"``
  complete events, microsecond timestamps) that ``chrome://tracing``
  and Perfetto load directly; span attributes land in ``args``;
* ``write_jsonl(path)`` / ``read_jsonl(path)`` -- a structured
  line-per-record event log (spans + final counter/gauge values) that
  round-trips losslessly;
* ``summarize(...)`` -- the plain-text per-span-name table behind
  ``python -m repro.telemetry.report``.

``trace_to(path)`` is the one-liner CLI integration: a context manager
that enables telemetry, runs the body, and exports on exit (``.jsonl``
suffix selects the JSONL sink, anything else the Chrome sink).
"""

from __future__ import annotations

import contextlib
import json
import os

from repro.telemetry import core

JSONL_SCHEMA = 1


def _chrome_payload(tracer: core.Tracer) -> dict:
    events = []
    for name, ts, dur, tid, sid, parent, args in tracer.snapshot_events():
        events.append({
            "name": name, "cat": "repro", "ph": "X",
            "ts": round(ts, 3), "dur": round(dur, 3),
            "pid": os.getpid(), "tid": tid,
            "args": {**args, "span_id": sid, "parent_id": parent},
        })
    events.sort(key=lambda e: e["ts"])
    return {
        "traceEvents": events,
        "displayTimeUnit": "ms",
        "otherData": {
            "producer": "repro.telemetry",
            "counters": core._REGISTRY.counters(),
            "gauges": core._REGISTRY.gauges(),
            "dropped_events": tracer.dropped,
        },
    }


def write_chrome_trace(path: str, tracer: core.Tracer | None = None) -> str:
    """Export recorded spans as Chrome/Perfetto trace JSON."""
    tracer = tracer if tracer is not None else core.get_tracer()
    if tracer is None:
        raise RuntimeError("telemetry is not enabled; nothing to export")
    with open(path, "w") as f:
        json.dump(_chrome_payload(tracer), f, indent=1, default=str)
        f.write("\n")
    return path


def write_jsonl(path: str, tracer: core.Tracer | None = None) -> str:
    """Export spans + counters + gauges as one JSON object per line."""
    tracer = tracer if tracer is not None else core.get_tracer()
    if tracer is None:
        raise RuntimeError("telemetry is not enabled; nothing to export")
    with open(path, "w") as f:
        meta = {"type": "meta", "schema": JSONL_SCHEMA,
                "epoch_unix": tracer.epoch_unix, "pid": os.getpid(),
                "dropped_events": tracer.dropped}
        f.write(json.dumps(meta, default=str) + "\n")
        for name, ts, dur, tid, sid, parent, args in tracer.snapshot_events():
            rec = {"type": "span", "name": name, "ts_us": round(ts, 3),
                   "dur_us": round(dur, 3), "tid": tid, "id": sid,
                   "parent": parent, "args": args}
            f.write(json.dumps(rec, default=str) + "\n")
        for name, value in sorted(core._REGISTRY.counters().items()):
            f.write(json.dumps({"type": "counter", "name": name,
                                "value": value}) + "\n")
        for name, value in sorted(core._REGISTRY.gauges().items()):
            f.write(json.dumps({"type": "gauge", "name": name,
                                "value": value}, default=str) + "\n")
    return path


def read_jsonl(path: str) -> dict:
    """Parse a JSONL event log back into
    ``{meta, spans: [..], counters: {..}, gauges: {..}}``."""
    out = {"meta": {}, "spans": [], "counters": {}, "gauges": {}}
    with open(path) as f:
        for line in f:
            line = line.strip()
            if not line:
                continue
            rec = json.loads(line)
            kind = rec.get("type")
            if kind == "span":
                out["spans"].append(rec)
            elif kind == "counter":
                out["counters"][rec["name"]] = rec["value"]
            elif kind == "gauge":
                out["gauges"][rec["name"]] = rec["value"]
            elif kind == "meta":
                out["meta"] = rec
    return out


def read_chrome_trace(path: str) -> dict:
    """Parse a Chrome trace JSON into the same shape as ``read_jsonl``."""
    with open(path) as f:
        payload = json.load(f)
    other = payload.get("otherData", {})
    spans = [{"type": "span", "name": e["name"], "ts_us": e["ts"],
              "dur_us": e["dur"], "tid": e.get("tid", 0),
              "id": e.get("args", {}).get("span_id"),
              "parent": e.get("args", {}).get("parent_id"),
              "args": e.get("args", {})}
             for e in payload.get("traceEvents", [])
             if e.get("ph") == "X"]
    return {"meta": {"dropped_events": other.get("dropped_events", 0)},
            "spans": spans, "counters": other.get("counters", {}),
            "gauges": other.get("gauges", {})}


def load_trace(path: str) -> dict:
    """Load either sink format (sniffs the first character)."""
    with open(path) as f:
        head = f.read(1)
    if head == "{":
        with open(path) as f:
            first = f.readline()
        try:
            rec = json.loads(first)
        except json.JSONDecodeError:
            rec = None
        if isinstance(rec, dict) and rec.get("type") == "meta":
            return read_jsonl(path)
        return read_chrome_trace(path)
    return read_jsonl(path)


def summarize(trace: dict, top: int = 30) -> str:
    """Plain-text report over a loaded trace: per-span-name aggregates
    (calls, total/mean/max ms) plus counters and gauges."""
    aggs: dict[str, dict] = {}
    for s in trace["spans"]:
        a = aggs.setdefault(s["name"],
                            {"count": 0, "total_us": 0.0, "max_us": 0.0})
        a["count"] += 1
        a["total_us"] += s["dur_us"]
        a["max_us"] = max(a["max_us"], s["dur_us"])
    lines = []
    lines.append(f"{'span':<32} {'calls':>8} {'total ms':>12} "
                 f"{'mean ms':>10} {'max ms':>10}")
    lines.append("-" * 76)
    ordered = sorted(aggs.items(), key=lambda kv: -kv[1]["total_us"])
    for name, a in ordered[:top]:
        lines.append(
            f"{name:<32} {a['count']:>8} {a['total_us'] / 1e3:>12.3f} "
            f"{a['total_us'] / 1e3 / a['count']:>10.4f} "
            f"{a['max_us'] / 1e3:>10.3f}")
    if len(ordered) > top:
        lines.append(f"... {len(ordered) - top} more span name(s)")
    if trace["counters"]:
        lines.append("")
        lines.append("counters:")
        for name, value in sorted(trace["counters"].items()):
            lines.append(f"  {name:<40} {value}")
    if trace["gauges"]:
        lines.append("")
        lines.append("gauges:")
        for name, value in sorted(trace["gauges"].items()):
            lines.append(f"  {name:<40} {value}")
    dropped = trace.get("meta", {}).get("dropped_events", 0)
    if dropped:
        lines.append(f"\nWARNING: {dropped} span(s) dropped "
                     "(tracer event cap hit)")
    return "\n".join(lines)


@contextlib.contextmanager
def trace_to(path: str | None, quiet: bool = False):
    """Enable telemetry for the body and export to ``path`` on exit.

    ``path=None`` is a transparent no-op (benchmarks pass their
    ``--trace`` argument straight through).  A pre-existing enabled
    state is preserved; a ``.jsonl`` suffix selects the JSONL sink,
    anything else the Chrome-trace sink.
    """
    if path is None:
        yield None
        return
    was_enabled = core.is_enabled()
    tracer = core.enable()
    try:
        yield tracer
    finally:
        writer = write_jsonl if path.endswith(".jsonl") \
            else write_chrome_trace
        out = writer(path, tracer)
        if not quiet:
            n = len(tracer.snapshot_events())
            print(f"[telemetry] wrote {n} span(s) -> {out}", flush=True)
        if not was_enabled:
            core.disable()
