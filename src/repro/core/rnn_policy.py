"""RNN-based device placement baseline (paper App. D.2, after [13]).

Adapted as in the paper: same 21-feature extraction MLP and per-device
scoring head as DreamShard, but table representations are passed through an
LSTM + content attention before the sum reduction, there is NO cost network
(no cost features, zeros fed to the cost branch), and training is plain
REINFORCE against *real hardware measurements* (the simulator) -- which is
exactly why it is sample-starved and unstable on harder tasks (Table 1).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.api.oracle import CostOracle, ensure_oracle, evaluate_many
from repro.core import features as F
from repro.core import networks as N
from repro.core import rollout as R
from repro.data.tasks import Task
from repro.optim import adam, apply_updates, linear_decay
from repro.sim.costsim import CostSimulator

H = N.HIDDEN


def lstm_init(key, dim_in, dim_h):
    k1, k2 = jax.random.split(key)
    scale = 1.0 / np.sqrt(dim_h)
    return {
        "wx": jax.random.normal(k1, (dim_in, 4 * dim_h)) * scale,
        "wh": jax.random.normal(k2, (dim_h, 4 * dim_h)) * scale,
        "b": jnp.zeros((4 * dim_h,)),
    }


def lstm_apply(params, xs):
    """(M, dim_in) -> (M, dim_h) hidden sequence."""
    dim_h = params["wh"].shape[0]

    def step(carry, x):
        h, c = carry
        gates = x @ params["wx"] + h @ params["wh"] + params["b"]
        i, f, g, o = jnp.split(gates, 4, axis=-1)
        c = jax.nn.sigmoid(f) * c + jax.nn.sigmoid(i) * jnp.tanh(g)
        h = jax.nn.sigmoid(o) * jnp.tanh(c)
        return (h, c), h

    init = (jnp.zeros((dim_h,)), jnp.zeros((dim_h,)))
    _, hs = jax.lax.scan(step, init, xs)
    return hs


def attention(hs):
    """Content-based self attention over the hidden sequence (M, H)."""
    scores = hs @ hs.T / np.sqrt(hs.shape[-1])
    mask = jnp.tril(jnp.ones_like(scores))            # causal over sequence
    scores = jnp.where(mask > 0, scores, -1e9)
    return jax.nn.softmax(scores, axis=-1) @ hs


def rnn_policy_init(key):
    ks = jax.random.split(key, 3)
    base = N.policy_net_init(ks[0])
    base["lstm"] = lstm_init(ks[1], H, H)
    return base


def rnn_table_reprs(params, feats):
    h = N.policy_table_reprs(params, feats)           # shared feature MLP
    hs = lstm_apply(params["lstm"], h)
    return attention(hs)


@dataclasses.dataclass
class RNNPolicyConfig:
    n_updates: int = 100          # hardware-measured REINFORCE updates
    n_episode: int = 10
    entropy_weight: float = 1e-3
    lr: float = 5e-4
    seed: int = 0
    # estimated-cost head settings, forwarded to the shared rollout core
    # (only consulted when use_cost is enabled, e.g. hybrid ablations;
    # previously rollout_with_reprs silently used its own defaults)
    reward_mode: str = "composed"
    log_targets: bool = True


class RNNPlacer:
    """REINFORCE on real measurements; matched hardware budget vs DreamShard."""

    def __init__(self, train_tasks: list[Task],
                 oracle: CostOracle | CostSimulator,
                 config: RNNPolicyConfig | None = None):
        self.tasks = train_tasks
        self.oracle = ensure_oracle(oracle)
        self.cfg = config or RNNPolicyConfig()
        self.rng = np.random.default_rng(self.cfg.seed)
        key = jax.random.PRNGKey(self.cfg.seed)
        k, self._key = jax.random.split(key)
        self.params = rnn_policy_init(k)
        self._opt = adam(linear_decay(self.cfg.lr, self.cfg.n_updates))
        self.opt_state = self._opt.init(self.params)
        self._grad_fns = {}
        self._sample_fns = {}

    def _next_key(self):
        self._key, k = jax.random.split(self._key)
        return k

    def _sample_fn(self, n_devices, n_episodes, greedy):
        sig = (n_devices, n_episodes, greedy)
        if sig in self._sample_fns:
            return self._sample_fns[sig]

        @jax.jit
        def fn(params, feats, sizes, cap, key):
            h = rnn_table_reprs(params, feats)
            actions, _, _, _ = R.rollout_with_reprs(
                params, params, h, feats, sizes, cap, key,
                n_devices=n_devices, n_episodes=n_episodes, greedy=greedy,
                use_cost=False, reward_mode=self.cfg.reward_mode,
                log_targets=self.cfg.log_targets)
            return actions

        self._sample_fns[sig] = fn
        return fn

    def _grad_fn(self, n_devices, n_episodes):
        sig = (n_devices, n_episodes)
        if sig in self._grad_fns:
            return self._grad_fns[sig]

        def loss_fn(params, feats, sizes, cap, actions, adv, w_ent):
            h = rnn_table_reprs(params, feats)
            _, sum_logp, sum_ent, _ = R.rollout_with_reprs(
                params, params, h, feats, sizes, cap,
                jax.random.PRNGKey(0), n_devices=n_devices,
                n_episodes=n_episodes, use_cost=False, actions_in=actions,
                reward_mode=self.cfg.reward_mode,
                log_targets=self.cfg.log_targets)
            return -jnp.mean(adv * sum_logp) - w_ent * jnp.mean(sum_ent)

        self._grad_fns[sig] = jax.jit(jax.grad(loss_fn))
        return self._grad_fns[sig]

    def train(self, log: bool = False):
        cap = self.oracle.mem_capacity_gb
        for step in range(self.cfg.n_updates):
            task = self.tasks[self.rng.integers(len(self.tasks))]
            feats = jnp.asarray(F.normalize_features(task.raw_features))
            sizes = jnp.asarray(
                task.raw_features[:, F.TABLE_SIZE_GB].astype(np.float32))
            sample = self._sample_fn(task.n_devices, self.cfg.n_episode, False)
            actions = np.asarray(sample(self.params, feats, sizes, cap,
                                        self._next_key()))
            # all n_episode rewards in ONE batched oracle pass
            # (bitwise-identical to per-episode evaluate calls)
            results = evaluate_many(self.oracle, task.raw_features,
                                    actions.astype(np.int64),
                                    task.n_devices)
            rewards = -np.array([r.overall for r in results])
            adv = (rewards - rewards.mean()) / 10.0   # same 10ms scaling
            grads = self._grad_fn(task.n_devices, self.cfg.n_episode)(
                self.params, feats, sizes, cap, jnp.asarray(actions),
                jnp.asarray(adv, dtype=jnp.float32),
                self.cfg.entropy_weight)
            upd, self.opt_state = self._opt.update(grads, self.opt_state,
                                                   self.params)
            self.params = apply_updates(self.params, upd)
            if log and step % 20 == 0:
                print(f"[rnn] step={step} mean_cost={-rewards.mean():.2f}ms")

    def place(self, raw_features: np.ndarray, n_devices: int) -> np.ndarray:
        feats = jnp.asarray(F.normalize_features(raw_features))
        sizes = jnp.asarray(raw_features[:, F.TABLE_SIZE_GB].astype(np.float32))
        sample = self._sample_fn(n_devices, 1, True)
        actions = sample(self.params, feats, sizes,
                         self.oracle.mem_capacity_gb, jax.random.PRNGKey(0))
        return np.asarray(actions[0])

    def as_placer(self):
        """This baseline behind the unified ``repro.api.Placer`` protocol."""
        from repro.api.placers import RNNPlacerAdapter
        return RNNPlacerAdapter(self)
