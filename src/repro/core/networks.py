"""DreamShard's cost network and policy network (paper §3.2/3.3, App. B.1/B.2).

Pure-JAX parameter pytrees; no framework deps.  Architectures follow the
paper exactly:

Cost network f_cost:
  * shared table MLP 21-128-32 (ReLU)
  * device repr = elementwise SUM of table reprs on the device
  * three per-device heads 32-64-1: fwd-compute / bwd-compute / bwd-comm
  * overall repr = elementwise MAX over device reprs; overall head 32-64-1

Policy network pi:
  * independent shared table MLP 21-128-32
  * device repr = SUM of table reprs (incrementally maintainable)
  * cost-feature MLP 3-64-32 on q_{t,d}
  * shared scoring head 64-1 on concat(device repr, cost repr), softmax over
    devices -> works for any number of devices.

Both are size-agnostic: any number of tables/devices, enabling zero-shot
generalization (paper Table 2).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

HIDDEN = 32
NUM_COST_FEATURES = 3  # [fwd_comp, bwd_comp, bwd_comm]


# ---- generic MLP -------------------------------------------------------------

def mlp_init(key, sizes):
    params = []
    for i, (n_in, n_out) in enumerate(zip(sizes[:-1], sizes[1:])):
        key, k = jax.random.split(key)
        w = jax.random.normal(k, (n_in, n_out)) * jnp.sqrt(2.0 / n_in)
        params.append({"w": w.astype(jnp.float32),
                       "b": jnp.zeros((n_out,), jnp.float32)})
    return params


def mlp_apply(params, x):
    for i, layer in enumerate(params):
        x = x @ layer["w"] + layer["b"]
        if i < len(params) - 1:
            x = jax.nn.relu(x)
    return x


# ---- cost network ------------------------------------------------------------

def cost_net_init(key, num_features: int = 21):
    ks = jax.random.split(key, 5)
    return {
        "table_mlp": mlp_init(ks[0], [num_features, 128, HIDDEN]),
        "head_fwd": mlp_init(ks[1], [HIDDEN, 64, 1]),
        "head_bwd": mlp_init(ks[2], [HIDDEN, 64, 1]),
        "head_comm": mlp_init(ks[3], [HIDDEN, 64, 1]),
        "head_overall": mlp_init(ks[4], [HIDDEN, 64, 1]),
    }


def cost_table_reprs(params, feats):
    """(..., M, F) -> (..., M, HIDDEN)."""
    return mlp_apply(params["table_mlp"], feats)


def cost_device_heads(params, dev_repr):
    """Per-device cost features from device reprs: (..., D, H) -> (..., D, 3)."""
    fwd = mlp_apply(params["head_fwd"], dev_repr)
    bwd = mlp_apply(params["head_bwd"], dev_repr)
    comm = mlp_apply(params["head_comm"], dev_repr)
    return jnp.concatenate([fwd, bwd, comm], axis=-1)


def cost_overall_head(params, dev_repr, dev_mask=None):
    """MAX-reduce device reprs -> overall cost scalar (..., )."""
    if dev_mask is not None:
        neg = jnp.finfo(dev_repr.dtype).min
        dev_repr = jnp.where(dev_mask[..., None] > 0, dev_repr, neg)
    overall_repr = jnp.max(dev_repr, axis=-2)
    return mlp_apply(params["head_overall"], overall_repr)[..., 0]


def reduce_tables(h, assign_onehot, reduction: str = "sum"):
    """Reduce table reprs (..., M, H) into device reprs (..., D, H)."""
    if reduction == "sum":
        return assign_onehot @ h
    if reduction == "mean":
        counts = assign_onehot.sum(-1, keepdims=True)
        return (assign_onehot @ h) / jnp.maximum(counts, 1.0)
    if reduction == "max":
        neg = jnp.finfo(h.dtype).min
        masked = jnp.where(assign_onehot[..., None] > 0, h[..., None, :, :],
                           neg)
        out = masked.max(axis=-2)
        return jnp.where(assign_onehot.sum(-1, keepdims=True) > 0, out, 0.0)
    raise ValueError(reduction)


def reduce_devices(dev, dev_mask=None, reduction: str = "max"):
    """Reduce device reprs (..., D, H) into the overall repr (..., H)."""
    if reduction == "max":
        if dev_mask is not None:
            neg = jnp.finfo(dev.dtype).min
            dev = jnp.where(dev_mask[..., None] > 0, dev, neg)
        return dev.max(axis=-2)
    if dev_mask is not None:
        dev = dev * dev_mask[..., None]
    if reduction == "sum":
        return dev.sum(axis=-2)
    if reduction == "mean":
        n = (dev_mask.sum(-1, keepdims=True) if dev_mask is not None
             else dev.shape[-2])
        return dev.sum(axis=-2) / jnp.maximum(n, 1.0)
    raise ValueError(reduction)


def cost_net_apply(params, feats, assign_onehot, table_mask=None,
                   dev_mask=None, table_reduction: str = "sum",
                   device_reduction: str = "max"):
    """Full forward pass on a (possibly padded) placement.

    feats: (..., M, F) normalized features
    assign_onehot: (..., D, M) -- row d selects tables on device d
    table_mask: (..., M) 1 for real tables; dev_mask: (..., D)
    Reductions default to the paper's sum/max pair (App. B.3 compares the
    alternatives; see benchmarks/b3_reductions.py).
    returns (q (..., D, 3), overall (...,))
    """
    h = cost_table_reprs(params, feats)
    if table_mask is not None:
        h = h * table_mask[..., None]
    dev = reduce_tables(h, assign_onehot, table_reduction)
    q = cost_device_heads(params, dev)
    if dev_mask is not None:
        q = q * dev_mask[..., None]
    overall_repr = reduce_devices(dev, dev_mask, device_reduction)
    overall = mlp_apply(params["head_overall"], overall_repr)[..., 0]
    return q, overall


def predict_single_table_costs(params, feats):
    """Per-table 'alone on a device' scalar cost -- used for the descending
    sort before each episode (App. B.4.2)."""
    h = cost_table_reprs(params, feats)           # (M, H)
    q = cost_device_heads(params, h)              # (M, 3)
    return q.sum(axis=-1)


# ---- policy network ----------------------------------------------------------

def policy_net_init(key, num_features: int = 21):
    ks = jax.random.split(key, 3)
    return {
        "table_mlp": mlp_init(ks[0], [num_features, 128, HIDDEN]),
        "cost_mlp": mlp_init(ks[1], [NUM_COST_FEATURES, 64, HIDDEN]),
        "head": mlp_init(ks[2], [2 * HIDDEN, 1]),
    }


def policy_table_reprs(params, feats):
    return mlp_apply(params["table_mlp"], feats)


def policy_logits(params, dev_repr, q, dev_mask=None):
    """(..., D, H) device sums + (..., D, 3) cost features -> (..., D) logits.

    ``dev_mask`` (..., D) marks real devices; padding devices score a large
    negative logit, so one trace padded to D_pad serves any device count.
    """
    hc = mlp_apply(params["cost_mlp"], q)
    x = jnp.concatenate([dev_repr, hc], axis=-1)
    logits = mlp_apply(params["head"], x)[..., 0]
    if dev_mask is not None:
        logits = jnp.where(dev_mask > 0, logits, -1e9)
    return logits
