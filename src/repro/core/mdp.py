"""The *real* placement MDP (paper §3.1): states/rewards measured on hardware.

Used by the Fig-8 comparison (training directly against hardware
measurements, i.e. the simulator here) and by tests.  Every `step` costs D
fused-op measurements; DreamShard's estimated MDP exists precisely to avoid
paying this.
"""

from __future__ import annotations

import numpy as np

from repro.core import features as F
from repro.sim.costsim import CostSimulator


class RealPlacementMDP:
    """One-table-per-step placement environment measured on the simulator."""

    def __init__(self, raw_features: np.ndarray, n_devices: int,
                 sim: CostSimulator, order: np.ndarray | None = None):
        self.raw = np.asarray(raw_features)
        self.n_devices = n_devices
        self.sim = sim
        self.order = (np.asarray(order) if order is not None
                      else np.arange(self.raw.shape[0]))
        self.reset()

    def reset(self):
        self.t = 0
        self.assignment = np.full(self.raw.shape[0], -1, dtype=np.int64)
        self.mem = np.zeros(self.n_devices)
        return self._augmented_state()

    @property
    def done(self) -> bool:
        return self.t >= self.raw.shape[0]

    def legal_actions(self) -> np.ndarray:
        table = self.order[self.t]
        size = self.raw[table, F.TABLE_SIZE_GB]
        legal = (self.mem + size) <= self.sim.spec.mem_capacity_gb
        if not legal.any():
            legal[:] = True
        return np.flatnonzero(legal)

    def _augmented_state(self):
        """(per-device table features, measured q_{t,d}) -- needs hardware."""
        placed = self.assignment >= 0
        if placed.any():
            res = self.sim.evaluate(self.raw[placed], self.assignment[placed],
                                    self.n_devices)
            q = res.cost_features
        else:
            q = np.zeros((self.n_devices, 3))
        per_device = [self.raw[(self.assignment == d)]
                      for d in range(self.n_devices)]
        return per_device, q

    def step(self, action: int):
        assert not self.done
        table = self.order[self.t]
        self.assignment[table] = int(action)
        self.mem[action] += self.raw[table, F.TABLE_SIZE_GB]
        self.t += 1
        if self.done:
            res = self.sim.evaluate(self.raw, self.assignment, self.n_devices)
            return self._augmented_state(), -res.overall, True
        return self._augmented_state(), 0.0, False
