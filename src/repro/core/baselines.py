"""Human-expert greedy placement strategies (paper App. D.1) + random.

Each strategy assigns a per-table scalar cost, sorts tables descending, and
greedily places each on the least-loaded device that satisfies the memory
constraint.
"""

from __future__ import annotations

import numpy as np

from repro.core import features as F


def _greedy_balance(costs: np.ndarray, sizes: np.ndarray, n_devices: int,
                    capacity_gb: float) -> np.ndarray:
    order = np.argsort(-costs, kind="stable")
    load = np.zeros(n_devices)
    mem = np.zeros(n_devices)
    assignment = np.zeros(costs.shape[0], dtype=np.int64)
    for t in order:
        legal = (mem + sizes[t]) <= capacity_gb
        if not legal.any():
            legal[:] = True
        cand = np.where(legal, load, np.inf)
        d = int(np.argmin(cand))
        assignment[t] = d
        load[d] += costs[t]
        mem[d] += sizes[t]
    return assignment


def _costs(raw: np.ndarray, strategy: str) -> np.ndarray:
    dim = raw[:, F.DIM]
    pool = raw[:, F.POOLING]
    size = raw[:, F.TABLE_SIZE_GB]
    if strategy == "size":
        return size
    if strategy == "dim":
        return dim
    if strategy == "lookup":
        return dim * pool
    if strategy == "size_lookup":
        return dim * pool * size
    raise ValueError(f"unknown strategy {strategy!r}")


def expert_place(raw: np.ndarray, n_devices: int, capacity_gb: float,
                 strategy: str) -> np.ndarray:
    return _greedy_balance(_costs(raw, strategy), raw[:, F.TABLE_SIZE_GB],
                           n_devices, capacity_gb)


def random_place(raw: np.ndarray, n_devices: int, capacity_gb: float,
                 rng: np.random.Generator) -> np.ndarray:
    sizes = raw[:, F.TABLE_SIZE_GB]
    assignment = np.zeros(raw.shape[0], dtype=np.int64)
    mem = np.zeros(n_devices)
    for t in rng.permutation(raw.shape[0]):
        legal = np.flatnonzero((mem + sizes[t]) <= capacity_gb)
        if legal.size == 0:
            legal = np.arange(n_devices)
        d = int(rng.choice(legal))
        assignment[t] = d
        mem[d] += sizes[t]
    return assignment


EXPERT_STRATEGIES = ("size", "dim", "lookup", "size_lookup")
