"""DreamShard training (paper Algorithm 1) and inference (Algorithm 2).

Iteratively: (1) collect N_collect cost measurements from the hardware
oracle using placements generated on the estimated MDP by the current
policy; (2) update the cost network N_cost mini-batches of MSE (Eq. 1);
(3) update the policy N_RL REINFORCE steps purely inside the estimated MDP
(Eq. 2) -- no hardware touched.
"""

from __future__ import annotations

import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import telemetry as tele
from repro.api.oracle import (CostOracle, SimOracle, ensure_oracle,
                              evaluate_many, legal_batch)
from repro.api.session import pad_device_mask, pad_feature_batch
from repro.core import features as F
from repro.core import networks as N
from repro.core import replay as RB
from repro.core import rollout as R
from repro.data.tasks import Task
from repro.optim import adam, apply_updates, linear_decay
from repro.sim.costsim import CostSimulator


@dataclasses.dataclass
class DreamShardConfig:
    n_iterations: int = 10
    n_collect: int = 10
    n_cost: int = 300
    n_batch: int = 64
    n_rl: int = 10
    n_episode: int = 10
    entropy_weight: float = 1e-3
    lr: float = 5e-4
    cost_scale: float = 0.1      # targets in units of 10ms ('scale' mode)
    # target transform: 'log1p' fits relative error (tasks span 15-150 ms);
    # 'scale' is plain linear scaling
    target_transform: str = "log1p"
    seed: int = 0
    use_cost_features: bool = True   # ablation: 'w/o cost'
    feature_drop: str | None = None  # ablation: zero a feature group
    # episode-reward estimator: "composed" rebuilds the stage decomposition
    # from the per-device q heads (beyond-paper refinement, much denser
    # supervision); "head" is the paper's max-reduced overall head
    reward_mode: str = "composed"
    # inference: greedy argmax (paper Algorithm 2) plus this many sampled
    # candidate placements, keeping the lowest ESTIMATED cost -- still
    # hardware-free.  1 = paper-faithful pure argmax.
    inference_candidates: int = 16
    # fused loop: device-resident replay ring + single-dispatch scan
    # updates (one trace per stage covers every task shape); False falls
    # back to the per-step Algorithm-1 loop (the numerical reference,
    # see tests/test_fused_trainer.py and benchmarks/b6_train_throughput.py)
    fused: bool = True
    # replay ring capacity; None sizes it to hold every sample the
    # configured run can collect (matching the per-step loop's unbounded
    # list); smaller values overwrite the oldest samples
    buffer_capacity: int | None = None


@dataclasses.dataclass
class CostSample:
    feats_norm: np.ndarray   # (M, F)
    assignment: np.ndarray   # (M,)
    q: np.ndarray            # (D, 3) scaled
    overall: float           # scaled
    n_devices: int


class DreamShard:
    """End-to-end DreamShard agent bound to a hardware ``CostOracle``.

    Accepts any ``repro.api.CostOracle`` (or a bare ``CostSimulator``,
    auto-wrapped): the trainer only ever touches ``evaluate`` /
    ``mem_capacity_gb`` / ``num_evaluations``, so measured (KernelOracle)
    or memoized (CachedOracle) backends drop in without code changes.
    When the backend is a v2-calibrated ``MeasuredOracle``, the batched
    measured-collect path (``_measure_collected``) therefore trains the
    cost network on fusion-aware per-device costs -- fused multi-table
    ops priced through the artifact's ``FusionModel``, not the additive
    per-table sum (the paper's cost network is likewise trained on
    fused-op measurements).
    """

    def __init__(self, train_tasks: list[Task],
                 oracle: CostOracle | CostSimulator,
                 config: DreamShardConfig | None = None):
        self.tasks = train_tasks
        self.oracle = ensure_oracle(oracle)
        # legacy alias: the underlying simulator, when there is one
        self.sim = self.oracle.sim if isinstance(self.oracle, SimOracle) \
            else None
        self.cfg = config or DreamShardConfig()
        self.rng = np.random.default_rng(self.cfg.seed)
        key = jax.random.PRNGKey(self.cfg.seed)
        k1, k2, self._key = jax.random.split(key, 3)
        self.cost_params = N.cost_net_init(k1)
        self.policy_params = N.policy_net_init(k2)

        self._rebuild_opt_and_caches()

        self.buffer: list[CostSample] = []
        self._m_pad = max(t.n_tables for t in train_tasks)
        self._d_pad = max(t.n_devices for t in train_tasks)
        self.history: list[dict] = []
        self._placer = None      # cached repro.api placer (see as_placer)
        self._placer_sig = None
        # device computations launched by the trainer loop (one per jitted
        # call or eager op sequence) -- the b6 benchmark's dispatch metric
        self.num_dispatches = 0

    def _rebuild_opt_and_caches(self):
        """(Re)create everything derived from the config: optimizers, their
        states, and the jitted update caches.  Called from ``__init__`` and
        again from ``restore`` -- a restored config must not run against
        update functions traced under the old one."""
        total_cost_steps = self.cfg.n_iterations * self.cfg.n_cost
        total_rl_steps = self.cfg.n_iterations * self.cfg.n_rl
        self._cost_opt = adam(linear_decay(self.cfg.lr, total_cost_steps))
        self._rl_opt = adam(linear_decay(self.cfg.lr, total_rl_steps))
        self.cost_opt_state = self._cost_opt.init(self.cost_params)
        self.rl_opt_state = self._rl_opt.init(self.policy_params)
        self._rl_updates = {}    # (D, E) -> jitted update (per-step path)
        self._cost_update = self._build_cost_update()
        self._prepared_cache = {}  # task index -> (feats_norm, sizes_gb)
        # fused path: one trace per stage, any task shape (see replay.py /
        # rollout.make_fused_rl_update); the ring is rebuilt lazily so a
        # restore with changed target units starts from a clean buffer
        self._ring: RB.ReplayBuffer | None = None
        self._ring_host: tuple | None = None  # _host_sig() at last mirror
        self._fused_cost_update = RB.make_fused_cost_update(self._cost_opt)
        self._fused_rl_update = R.make_fused_rl_update(
            self._rl_opt, n_episodes=self.cfg.n_episode,
            w_entropy=self.cfg.entropy_weight,
            use_cost=self.cfg.use_cost_features,
            reward_mode=self.cfg.reward_mode, log_targets=self._log_targets)

    # ---- feature plumbing -----------------------------------------------------

    def _prepared(self, task: Task):
        raw = task.raw_features
        if self.cfg.feature_drop:
            raw = F.drop_feature_group(raw, self.cfg.feature_drop)
        feats = F.normalize_features(raw)
        sizes = task.raw_features[:, F.TABLE_SIZE_GB].astype(np.float32)
        return feats, sizes

    def _prepared_train(self, task_idx: int):
        """``_prepared`` for a training-set task, memoized: the pool is
        fixed, so each task normalizes once per config (cache cleared on
        ``restore`` -- feature_drop may change)."""
        hit = self._prepared_cache.get(task_idx)
        if hit is None:
            hit = self._prepared(self.tasks[task_idx])
            self._prepared_cache[task_idx] = hit
        return hit

    def _sorted_order(self, feats_norm: np.ndarray) -> np.ndarray:
        """Descending predicted single-table cost (App. B.4.2)."""
        costs = np.asarray(
            N.predict_single_table_costs(self.cost_params, jnp.asarray(feats_norm)))
        return np.argsort(-costs, kind="stable")

    def _next_key(self):
        self._key, k = jax.random.split(self._key)
        return k

    def transform_targets(self, ms):
        if self.cfg.target_transform == "log1p":
            return np.log1p(ms)
        return np.asarray(ms) * self.cfg.cost_scale

    @property
    def _log_targets(self) -> bool:
        return self.cfg.target_transform == "log1p"

    # ---- Algorithm 1 stage 1: data collection ---------------------------------

    def _record_sample(self, task: Task, feats_norm: np.ndarray,
                       assignment: np.ndarray) -> CostSample:
        res = self.oracle.evaluate(task.raw_features, assignment,
                                   task.n_devices)
        sample = CostSample(
            feats_norm=feats_norm, assignment=assignment,
            q=self.transform_targets(res.cost_features),
            overall=float(self.transform_targets(res.overall)),
            n_devices=task.n_devices)
        self.buffer.append(sample)
        return sample

    def collect(self):
        if self.cfg.fused:
            return self._collect_fused()
        cap = self.oracle.mem_capacity_gb
        for _ in range(self.cfg.n_collect):
            ti = int(self.rng.integers(len(self.tasks)))
            task = self.tasks[ti]
            feats, sizes = self._prepared_train(ti)
            order = self._sorted_order(feats)
            self.num_dispatches += 2          # sort + rollout
            actions, _ = R.rollout(
                self.policy_params, self.cost_params,
                jnp.asarray(feats[order]), jnp.asarray(sizes[order]), cap,
                self._next_key(), n_devices=task.n_devices, n_episodes=1,
                greedy=False, use_cost=self.cfg.use_cost_features,
                reward_mode=self.cfg.reward_mode,
                log_targets=self._log_targets)
            assignment = np.empty(task.n_tables, dtype=np.int64)
            assignment[order] = np.asarray(actions[0])
            self._record_sample(task, feats, assignment)

    def _collect_fused(self):
        """All ``n_collect`` rollouts in ONE padded vmapped dispatch: sort
        and decode happen in-graph (``rollout.collect_batched``) and the
        oracle measurements run through the batched ``evaluate_many`` path
        (one vectorized pass per distinct task, instead of the last
        remaining host-side per-placement loop)."""
        n = self.cfg.n_collect
        if n == 0:
            return
        idxs = [int(self.rng.integers(len(self.tasks))) for _ in range(n)]
        tasks = [self.tasks[i] for i in idxs]
        keys = jnp.stack([self._next_key() for _ in range(n)])
        prepared = [self._prepared_train(i) for i in idxs]
        feats, sizes, tmask = pad_feature_batch(prepared, self._m_pad)
        dmask = pad_device_mask([t.n_devices for t in tasks], self._d_pad)
        actions, _, order = R.collect_batched(
            self.policy_params, self.cost_params, jnp.asarray(feats),
            jnp.asarray(sizes), jnp.asarray(tmask), jnp.asarray(dmask),
            self.oracle.mem_capacity_gb, keys, n_episodes=1,
            use_cost=self.cfg.use_cost_features,
            reward_mode=self.cfg.reward_mode, log_targets=self._log_targets)
        self.num_dispatches += 1
        actions, order = np.asarray(actions), np.asarray(order)
        assignments = []
        for j, task in enumerate(tasks):
            m = task.n_tables
            assignment = np.empty(m, dtype=np.int64)
            assignment[order[j, :m]] = actions[j, 0, :m]
            assignments.append(assignment)
        appended = self._measure_collected(idxs, prepared, assignments)
        self.buffer.extend(appended)
        self._ring_extend(appended)

    def _measure_collected(self, idxs: list[int], prepared: list,
                           assignments: list[np.ndarray]
                           ) -> list["CostSample"]:
        """Measure decoded placements through the oracle's batched path.

        Placements of the same training task (``n_collect`` rollouts
        usually revisit a small pool many times) are stacked into one
        ``evaluate_many`` call -- bitwise the same measurements as the old
        per-placement loop, in a fraction of the oracle calls -- and the
        returned samples keep collection order, preserving the buffer
        layout (and thus the minibatch RNG stream) exactly.  A vectorized
        ``legal_batch`` check guards the padded decode: a memory-illegal
        placement is legitimate on over-tight tasks (the rollout's
        no-legal-device fallback) and is measured like the per-step loop
        measures it, but an illegal row that uses a device id outside the
        task's range means the padding mask is broken -- that one raises.
        """
        groups: dict[int, list[int]] = {}
        for j, ti in enumerate(idxs):
            groups.setdefault(ti, []).append(j)
        samples: list[CostSample | None] = [None] * len(idxs)
        for ti, js in groups.items():
            task = self.tasks[ti]
            batch = np.stack([assignments[j] for j in js])
            ok = legal_batch(self.oracle, task.raw_features, batch,
                             task.n_devices)
            if not ok.all():
                bad = batch[~ok]
                if ((bad < 0) | (bad >= task.n_devices)).any():
                    raise RuntimeError(
                        "collection decoded a placement onto a padding "
                        f"device for task {ti}: device masking is broken")
            results = evaluate_many(self.oracle, task.raw_features, batch,
                                    task.n_devices)
            for j, res in zip(js, results):
                samples[j] = CostSample(
                    feats_norm=prepared[j][0], assignment=assignments[j],
                    q=self.transform_targets(res.cost_features),
                    overall=float(self.transform_targets(res.overall)),
                    n_devices=task.n_devices)
        return samples

    # ---- Algorithm 1 stage 2: cost network update (Eq. 1) ---------------------

    def _build_cost_update(self):
        opt = self._cost_opt

        @jax.jit
        def update(cost_params, opt_state, feats, onehot, tmask, dmask,
                   q_t, c_t):
            def loss_fn(cp):
                q, overall = N.cost_net_apply(cp, feats, onehot, tmask, dmask)
                lq = jnp.sum((q - q_t) ** 2 * dmask[..., None]) / (
                    3.0 * jnp.maximum(dmask.sum(), 1.0))
                lc = jnp.mean((overall - c_t) ** 2)
                return lq + lc
            loss, grads = jax.value_and_grad(loss_fn)(cost_params)
            upd, opt_state = opt.update(grads, opt_state, cost_params)
            return apply_updates(cost_params, upd), opt_state, loss

        return update

    def _cost_batch(self, samples: list["CostSample"]):
        """Pad an explicit sample list into dense cost-net training arrays
        (feats, onehot, tmask, dmask, q_t, c_t).  Pads grow beyond the
        training-suite shape when given larger held-out samples
        (``cost_mse`` / benchmark probes)."""
        B = len(samples)
        Mp = max([self._m_pad] + [s.feats_norm.shape[0] for s in samples])
        Dp = max([self._d_pad] + [s.n_devices for s in samples])
        feats = np.zeros((B, Mp, F.NUM_FEATURES), np.float32)
        onehot = np.zeros((B, Dp, Mp), np.float32)
        tmask = np.zeros((B, Mp), np.float32)
        dmask = np.zeros((B, Dp), np.float32)
        q_t = np.zeros((B, Dp, 3), np.float32)
        c_t = np.zeros((B,), np.float32)
        for j, s in enumerate(samples):
            m, d = s.feats_norm.shape[0], s.n_devices
            feats[j, :m] = s.feats_norm
            onehot[j, s.assignment, np.arange(m)] = 1.0
            tmask[j, :m] = 1.0
            dmask[j, :d] = 1.0
            q_t[j, :d] = s.q
            c_t[j] = s.overall
        return feats, onehot, tmask, dmask, q_t, c_t

    # ---- device-resident replay ring (fused path) -----------------------------

    def _ring_capacity(self) -> int:
        if self.cfg.buffer_capacity is not None:
            return max(1, self.cfg.buffer_capacity)
        return max(1, self.cfg.n_iterations * self.cfg.n_collect,
                   len(self.buffer))

    def _host_sig(self):
        """Cheap identity signature of the host buffer the ring mirrors:
        list object, length, and tail-sample object.  Catches wholesale
        reassignment (``ds.buffer = other``), slice assignment
        (``ds.buffer[:] = other``), and tail replacement -- in-place
        mutation of an existing ``CostSample``'s arrays is NOT detected
        (replace the sample object instead)."""
        return (id(self.buffer), len(self.buffer),
                id(self.buffer[-1]) if self.buffer else None)

    def _ring_in_sync(self) -> bool:
        return self._ring is not None and \
            self._ring.count == len(self.buffer) and \
            self._ring_host == self._host_sig()

    def _ring_extend(self, samples: list["CostSample"]):
        """Mirror freshly collected samples into the device ring (one
        scatter); falls back to a full rebuild if the ring is stale.
        ``self.buffer`` already contains ``samples`` as its tail."""
        stale = self._ring is None or \
            self._ring.count != len(self.buffer) - len(samples) or \
            self._ring_host is None or \
            self._ring_host[0] != id(self.buffer) or \
            self._ring_host[1] != len(self.buffer) - len(samples)
        if stale:
            return self._sync_ring()
        self._ring.append_batch(*self._cost_batch(samples))
        self._ring_host = self._host_sig()
        self.num_dispatches += 1

    def _sync_ring(self):
        """(Re)build the device ring from ``self.buffer``.  Normally a
        no-op: ``collect`` appends to both in lockstep.  Needed when the
        host buffer was assigned directly (e.g. fig7's frozen-buffer
        sweeps) or invalidated by ``restore``."""
        if self._ring_in_sync() and \
                self._ring.capacity >= self._ring_capacity():
            return
        n = len(self.buffer)
        cap = self._ring_capacity()
        if self._ring is not None and cap > self._ring.capacity and \
                self.cfg.buffer_capacity is None:
            # training ran past the configured n_iterations * n_collect
            # budget: grow geometrically, so continued training rebuilds
            # (and retraces -- each ring shape is a fresh trace of the
            # fused update) O(log n) times instead of at every step
            cap = max(cap, 2 * self._ring.capacity)
        tele.count("jit.retraces")
        self._ring = RB.ReplayBuffer(cap, self._m_pad, self._d_pad)
        self._ring_host = self._host_sig()
        if n:
            kept = self.buffer[-cap:]         # ring semantics: newest wins
            self._ring.count = n - len(kept)  # so slots land at i % cap
            self._ring.append_batch(*self._cost_batch(kept))
            self.num_dispatches += 1

    def update_cost(self, n_steps: int | None = None):
        n_steps = n_steps if n_steps is not None else self.cfg.n_cost
        if self.cfg.fused:
            return self._update_cost_fused(n_steps)
        losses = []
        for _ in range(n_steps):
            idx = self.rng.integers(len(self.buffer),
                                    size=min(self.cfg.n_batch, len(self.buffer)))
            batch = self._cost_batch([self.buffer[i] for i in idx])
            self.cost_params, self.cost_opt_state, loss = self._cost_update(
                self.cost_params, self.cost_opt_state, *map(jnp.asarray, batch))
            self.num_dispatches += 1
            losses.append(float(loss))
        return float(np.mean(losses)) if losses else 0.0

    def _update_cost_fused(self, n_steps: int):
        """The whole Eq.-1 stage as ONE jitted scan over on-device
        minibatches (replay.make_fused_cost_update): indices are drawn on
        the host in the per-step loop's exact RNG order, the padded tail of
        partially-filled minibatches is weight-masked, and params/opt-state
        are donated."""
        if n_steps == 0 or not self.buffer:
            return 0.0
        self._sync_ring()
        size = self._ring.size
        b = min(self.cfg.n_batch, size)
        idx = np.zeros((n_steps, self.cfg.n_batch), np.int32)
        w = np.zeros((n_steps, self.cfg.n_batch), np.float32)
        for t in range(n_steps):
            idx[t, :b] = self._ring.slots(self.rng.integers(size, size=b))
            w[t, :b] = 1.0
        self.cost_params, self.cost_opt_state, losses = \
            self._fused_cost_update(self.cost_params, self.cost_opt_state,
                                    self._ring.data, jnp.asarray(idx),
                                    jnp.asarray(w))
        self.num_dispatches += 1
        return float(jnp.mean(losses))

    # ---- Algorithm 1 stage 3: policy update on the estimated MDP (Eq. 2) ------

    def _rl_update_fn(self, n_devices: int):
        key = (n_devices, self.cfg.n_episode)
        if key not in self._rl_updates:
            tele.count("jit.retraces")
            self._rl_updates[key] = R.make_rl_update(
                self._rl_opt, n_devices=n_devices,
                n_episodes=self.cfg.n_episode,
                w_entropy=self.cfg.entropy_weight,
                use_cost=self.cfg.use_cost_features,
                reward_mode=self.cfg.reward_mode,
                log_targets=self._log_targets)
        return self._rl_updates[key]

    def update_policy(self, n_steps: int | None = None):
        n_steps = n_steps if n_steps is not None else self.cfg.n_rl
        if self.cfg.fused:
            return self._update_policy_fused(n_steps)
        cap = self.oracle.mem_capacity_gb
        rewards = []
        for _ in range(n_steps):
            ti = int(self.rng.integers(len(self.tasks)))
            task = self.tasks[ti]
            feats, sizes = self._prepared_train(ti)
            order = self._sorted_order(feats)
            update = self._rl_update_fn(task.n_devices)
            self.num_dispatches += 2          # sort + update
            self.policy_params, self.rl_opt_state, _, reward = update(
                self.policy_params, self.rl_opt_state, self.cost_params,
                jnp.asarray(feats[order]), jnp.asarray(sizes[order]), cap,
                self._next_key())
            rewards.append(float(np.mean(np.asarray(reward))))
        return float(np.mean(rewards)) if rewards else 0.0

    def _update_policy_fused(self, n_steps: int):
        """All ``n_rl`` REINFORCE steps as ONE jitted scan over a
        pre-sampled padded task batch (rollout.make_fused_rl_update):
        tables tmask'd to M_pad, devices dmask'd to D_pad, so a single
        trace covers every (n_tables, n_devices) in the training set --
        no per-shape recompile cache."""
        if n_steps == 0:
            return 0.0
        idxs = [int(self.rng.integers(len(self.tasks)))
                for _ in range(n_steps)]
        tasks = [self.tasks[i] for i in idxs]
        keys = jnp.stack([self._next_key() for _ in range(n_steps)])
        prepared = [self._prepared_train(i) for i in idxs]
        feats, sizes, tmask = pad_feature_batch(prepared, self._m_pad)
        dmask = pad_device_mask([t.n_devices for t in tasks], self._d_pad)
        self.policy_params, self.rl_opt_state, _, rewards = \
            self._fused_rl_update(
                self.policy_params, self.rl_opt_state, self.cost_params,
                jnp.asarray(feats), jnp.asarray(sizes), jnp.asarray(tmask),
                jnp.asarray(dmask), self.oracle.mem_capacity_gb, keys)
        self.num_dispatches += 1
        return float(np.mean(np.asarray(rewards)))

    # ---- full loop -------------------------------------------------------------

    def train(self, eval_tasks: list[Task] | None = None,
              log: bool = False):
        for it in range(self.cfg.n_iterations):
            t0 = time.perf_counter()
            d0 = self.num_dispatches
            with tele.span("train.iteration", iteration=it) as sp:
                with tele.span("train.collect", iteration=it):
                    self.collect()
                with tele.span("train.cost_update", iteration=it):
                    cost_loss = self.update_cost()
                with tele.span("train.rl_update", iteration=it):
                    mean_reward = self.update_policy()
                sp.set(cost_loss=cost_loss, mean_est_reward=mean_reward)
            entry = {"iteration": it, "cost_loss": cost_loss,
                     "mean_est_reward": mean_reward,
                     "wall_s": time.perf_counter() - t0,
                     "dispatches": self.num_dispatches - d0,
                     "sim_evals": self.oracle.num_evaluations}
            if eval_tasks is not None:
                entry["eval_cost_ms"] = self.evaluate_tasks(eval_tasks)
            self.history.append(entry)
            if log:
                print(f"[dreamshard] iter={it} cost_loss={cost_loss:.4f} "
                      f"est_reward={mean_reward:.3f} "
                      + (f"eval={entry.get('eval_cost_ms', float('nan')):.2f}ms"
                         if eval_tasks else ""))
        return self.history

    # ---- Algorithm 2: inference -------------------------------------------------

    def _inference_inputs(self, raw_features: np.ndarray):
        """(feats_norm (M,F), sizes_gb (M,), descending-cost order (M,))."""
        raw = (F.drop_feature_group(raw_features, self.cfg.feature_drop)
               if self.cfg.feature_drop else raw_features)
        feats = F.normalize_features(raw)
        sizes = raw_features[:, F.TABLE_SIZE_GB].astype(np.float32)
        return feats, sizes, self._sorted_order(feats)

    def place_detailed(self, raw_features: np.ndarray, n_devices: int,
                       n_candidates: int | None = None
                       ) -> tuple[np.ndarray, float]:
        """Algorithm 2 (hardware-free inference): greedy argmax decode, plus
        optional sampled candidates ranked by the estimated cost.  Returns
        ``(assignment, estimated_cost_ms_of_the_chosen_candidate)``."""
        feats, sizes, order = self._inference_inputs(raw_features)
        k = self.cfg.inference_candidates if n_candidates is None \
            else n_candidates
        actions, est = R.decode_candidates_jit(
            self.policy_params, self.cost_params,
            jnp.asarray(feats[order]), jnp.asarray(sizes[order]),
            self.oracle.mem_capacity_gb, n_devices=n_devices,
            n_candidates=k, use_cost=self.cfg.use_cost_features,
            reward_mode=self.cfg.reward_mode, log_targets=self._log_targets)
        actions, est = np.asarray(actions), np.asarray(est)
        best = int(np.argmin(est))
        assignment = np.empty(raw_features.shape[0], dtype=np.int64)
        assignment[order] = actions[best]
        return assignment, float(est[best])

    def place(self, raw_features: np.ndarray, n_devices: int,
              n_candidates: int | None = None) -> np.ndarray:
        return self.place_detailed(raw_features, n_devices, n_candidates)[0]

    def as_placer(self, n_candidates: int | None = None,
                  bucket_tables: int = 8):
        """This agent behind the unified ``repro.api.Placer`` protocol
        (cached: repeated calls share one batched ``PlacementSession``)."""
        from repro.api.placers import DreamShardPlacer
        if self._placer is None or \
                (n_candidates, bucket_tables) != self._placer_sig:
            self._placer = DreamShardPlacer(self, n_candidates=n_candidates,
                                            bucket_tables=bucket_tables)
            self._placer_sig = (n_candidates, bucket_tables)
        return self._placer

    def save(self, path: str):
        """Checkpoint the trained agent (both networks + config)."""
        import json
        import os
        from repro.checkpoint import save_pytree
        save_pytree({"cost": self.cost_params,
                     "policy": self.policy_params}, path)
        with open(os.path.join(path, "config.json"), "w") as f:
            json.dump(dataclasses.asdict(self.cfg), f, indent=2)

    def restore(self, path: str):
        """Restore networks AND config: a round-trip reproduces the saved
        agent's inference behaviour (candidate count, reward mode, ...)."""
        import json
        import os
        from repro.checkpoint import restore_pytree
        old_cfg = self.cfg
        cfg_path = os.path.join(path, "config.json")
        if os.path.exists(cfg_path):
            with open(cfg_path) as f:
                stored = json.load(f)
            known = {fld.name for fld in dataclasses.fields(DreamShardConfig)}
            self.cfg = DreamShardConfig(
                **{k: v for k, v in stored.items() if k in known})
        tree = restore_pytree({"cost": self.cost_params,
                               "policy": self.policy_params}, path)
        self.cost_params = tree["cost"]
        self.policy_params = tree["policy"]
        # everything traced/derived under the old config is now stale:
        # optimizers, jitted updates, and the cached placer's session
        self._rebuild_opt_and_caches()
        self._placer = None
        self._placer_sig = None
        if (old_cfg.target_transform, old_cfg.cost_scale) != \
                (self.cfg.target_transform, self.cfg.cost_scale):
            self.buffer = []     # old samples are in the old target units

    def cost_mse(self, samples: list["CostSample"]) -> float:
        """Test MSE of the cost network on held-out cost samples (Fig 7)."""
        batch = self._cost_batch(samples)
        feats, onehot, tmask, dmask, q_t, c_t = map(jnp.asarray, batch)
        q, overall = N.cost_net_apply(self.cost_params, feats, onehot,
                                      tmask, dmask)
        lq = float(jnp.sum((q - q_t) ** 2 * dmask[..., None])
                   / (3.0 * jnp.maximum(dmask.sum(), 1.0)))
        lc = float(jnp.mean((overall - c_t) ** 2))
        return lq + lc

    def evaluate_tasks(self, tasks: list[Task]) -> float:
        """Mean measured cost over a suite, decoded through the batched
        ``PlacementSession`` (one compile per task-shape bucket)."""
        from repro.api.placement import evaluate_placer
        return evaluate_placer(self.oracle, tasks, self.as_placer())
