"""Embedding-table feature schema (paper Appendix A.2).

Each table is described by 21 raw features:

  [0]      dim            -- embedding vector dimension (columns)
  [1]      hash_size      -- number of rows
  [2]      pooling_factor -- mean #indices per lookup
  [3]      table_size_gb  -- memory footprint in GB
  [4:21]   distribution   -- 17-bin normalized access-frequency histogram
                             over per-index access counts in a 65536 batch:
                             (0,1],(1,2],(2,4],...,(16384,32768],(32768,inf)

Raw features are what the simulator consumes; the networks consume a
normalized view (log-scaled magnitudes, distribution bins passed through).
"""

from __future__ import annotations

import numpy as np

NUM_FEATURES = 21
NUM_DIST_BINS = 17

DIM = 0
HASH_SIZE = 1
POOLING = 2
TABLE_SIZE_GB = 3
DIST_START = 4

# Geometric-mean access count per distribution bin; bin j covers
# (2^(j-1), 2^j] for j>=1 with bin 0 = (0,1].  Used by the simulator's cache
# model: mass in high bins means heavy index reuse.
BIN_MEAN_COUNT = np.array(
    [1.0] + [np.sqrt(2.0 ** (j - 1) * 2.0 ** j) for j in range(1, NUM_DIST_BINS)]
)


def table_size_gb(dim: np.ndarray, hash_size: np.ndarray,
                  bytes_per_elem: int = 2) -> np.ndarray:
    return dim * hash_size * bytes_per_elem / 1e9


def pack_features(dim, hash_size, pooling, dist) -> np.ndarray:
    """Assemble the (M, 21) raw feature matrix."""
    dim = np.asarray(dim, dtype=np.float64)
    hash_size = np.asarray(hash_size, dtype=np.float64)
    pooling = np.asarray(pooling, dtype=np.float64)
    dist = np.asarray(dist, dtype=np.float64)
    assert dist.shape == (dim.shape[0], NUM_DIST_BINS)
    out = np.zeros((dim.shape[0], NUM_FEATURES))
    out[:, DIM] = dim
    out[:, HASH_SIZE] = hash_size
    out[:, POOLING] = pooling
    out[:, TABLE_SIZE_GB] = table_size_gb(dim, hash_size)
    out[:, DIST_START:] = dist
    return out


def normalize_features(raw: np.ndarray) -> np.ndarray:
    """Network input normalization.

    dim is LINEAR (dim/256): both compute and all-to-all payload are linear
    in dim, and the networks' sum-reduction can then represent per-device
    dim sums exactly -- with log encoding the comm objective becomes
    sum-of-exp, which measurably hurts placement on diverse-dim (Prod)
    pools.  Heavy-tailed magnitudes (hash, pooling, size) stay log-scaled.
    """
    raw = np.asarray(raw, dtype=np.float64)
    out = raw.copy().astype(np.float32)
    out[..., DIM] = raw[..., DIM] / 256.0
    out[..., HASH_SIZE] = np.log2(np.maximum(raw[..., HASH_SIZE], 1.0)) / 25.0
    out[..., POOLING] = np.log2(1.0 + raw[..., POOLING]) / 8.0
    out[..., TABLE_SIZE_GB] = np.log2(1.0 + 100.0 * raw[..., TABLE_SIZE_GB]) / 12.0
    return out


def drop_feature_group(raw: np.ndarray, group: str) -> np.ndarray:
    """Zero out one feature group (for the Table 3/11 ablations)."""
    out = raw.copy()
    if group == "dim":
        out[..., DIM] = 16.0            # replace with a constant, not zero
    elif group == "hash_size":
        out[..., HASH_SIZE] = 1e6
    elif group == "pooling":
        out[..., POOLING] = 15.0
    elif group == "table_size":
        out[..., TABLE_SIZE_GB] = 0.032
    elif group == "distribution":
        out[..., DIST_START:] = 1.0 / NUM_DIST_BINS
    else:
        raise ValueError(f"unknown feature group {group!r}")
    return out
