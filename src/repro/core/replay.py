"""Device-resident replay buffer + fused cost-network trainer.

The seed Algorithm-1 loop paid ~300 host round-trips per iteration: every
cost-network minibatch was re-padded row-by-row in numpy, re-uploaded, and
dispatched as its own jitted step.  Here the padded sample arrays live on
device in a fixed-capacity ring buffer (``ReplayBuffer``), ``collect``
appends whole batches with one donated scatter, and the entire ``n_cost``-
step update is ONE jitted ``lax.scan`` over on-device gathered minibatches
with donated params/opt-state (``make_fused_cost_update``).

Minibatch indices are still drawn on the host (cheap, keeps the RNG stream
identical to the per-step loop); a per-sample weight column masks the tail
of partially-filled minibatches so one trace covers every buffer fill
level, reproducing the per-step loop's ``min(n_batch, len(buffer))``
batches exactly.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import features as F
from repro.core import networks as N
from repro.optim import apply_updates


@functools.partial(jax.jit, donate_argnums=(0,))
def _scatter(buf, update, pos):
    return jax.tree.map(lambda b, u: b.at[pos].set(u), buf, update)


class ReplayBuffer:
    """Fixed-capacity ring of padded cost samples, resident on device.

    Arrays (all padded to one ``(m_pad, d_pad)`` shape so the fused update
    compiles once): ``feats (C, M, F)``, ``onehot (C, D, M)``, ``tmask
    (C, M)``, ``dmask (C, D)``, ``q (C, D, 3)``, ``overall (C,)``.  The
    write cursor advances modulo capacity; ``count`` is the total number of
    samples ever appended (host int -- slot of global sample ``i`` is
    ``i % capacity``).
    """

    def __init__(self, capacity: int, m_pad: int, d_pad: int,
                 num_features: int = F.NUM_FEATURES):
        self.capacity = int(capacity)
        self.m_pad, self.d_pad = int(m_pad), int(d_pad)
        self.count = 0
        C, M, D = self.capacity, self.m_pad, self.d_pad
        self.data = {
            "feats": jnp.zeros((C, M, num_features), jnp.float32),
            "onehot": jnp.zeros((C, D, M), jnp.float32),
            "tmask": jnp.zeros((C, M), jnp.float32),
            "dmask": jnp.zeros((C, D), jnp.float32),
            "q": jnp.zeros((C, D, 3), jnp.float32),
            "overall": jnp.zeros((C,), jnp.float32),
        }

    @property
    def size(self) -> int:
        """Number of live samples (<= capacity)."""
        return min(self.count, self.capacity)

    def append_batch(self, feats, onehot, tmask, dmask, q, overall):
        """Append B padded samples in one donated device scatter."""
        B = feats.shape[0]
        if B == 0:
            return
        # a batch larger than the ring would scatter duplicate positions
        # (undefined winner): only the newest `capacity` samples can
        # survive anyway, so drop the overwritten head up front
        keep = slice(max(0, B - self.capacity), B)
        pos = (self.count + np.arange(B)[keep]) % self.capacity
        update = {"feats": feats[keep], "onehot": onehot[keep],
                  "tmask": tmask[keep], "dmask": dmask[keep],
                  "q": q[keep], "overall": overall[keep]}
        self.data = _scatter(self.data, update, jnp.asarray(pos))
        self.count += B

    def slots(self, sample_idx: np.ndarray) -> np.ndarray:
        """Ring slots for indices into the LIVE window (0 = oldest kept)."""
        return (self.count - self.size + sample_idx) % self.capacity


def make_fused_cost_update(optimizer):
    """Build the single-dispatch ``n_cost``-step cost-network trainer.

    The returned jitted function scans Eq.-1 MSE minibatch steps over
    pre-sampled ring slots ``idx (n_steps, n_batch)`` with per-sample
    weights ``w (n_steps, n_batch)`` (0 marks the padded tail of a
    partially-filled minibatch); params and opt-state are donated, and the
    buffer arrays are gathered on device -- zero host round-trips inside
    the loop.  Weighted losses reduce exactly to the per-step loop's
    ``lq + lc`` when every weight is 1.  ``update.traces[0]`` counts
    retraces.
    """
    traces = [0]

    def _update(cost_params, opt_state, buf, idx, w):
        traces[0] += 1

        def step(carry, xs):
            cp, st = carry
            ib, wb = xs
            feats = buf["feats"][ib]
            onehot = buf["onehot"][ib]
            tmask = buf["tmask"][ib]
            dmask = buf["dmask"][ib]
            q_t = buf["q"][ib]
            c_t = buf["overall"][ib]

            def loss_fn(p):
                q, overall = N.cost_net_apply(p, feats, onehot, tmask, dmask)
                wd = dmask * wb[:, None]
                lq = jnp.sum((q - q_t) ** 2 * wd[..., None]) / (
                    3.0 * jnp.maximum(wd.sum(), 1.0))
                lc = jnp.sum((overall - c_t) ** 2 * wb) / jnp.maximum(
                    wb.sum(), 1.0)
                return lq + lc

            loss, grads = jax.value_and_grad(loss_fn)(cp)
            upd, st = optimizer.update(grads, st, cp)
            return (apply_updates(cp, upd), st), loss

        (cost_params, opt_state), losses = jax.lax.scan(
            step, (cost_params, opt_state), (idx, w))
        return cost_params, opt_state, losses

    update = jax.jit(_update, donate_argnums=(0, 1))
    update.traces = traces
    return update
