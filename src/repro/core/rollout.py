"""Estimated-MDP rollouts (paper §3.1/3.3) as a single `lax.scan`.

The MDP places one table per step.  Because both networks reduce tables with
an elementwise SUM, the entire environment state is carried as running
per-device sums of table representations -- no recomputation per step:

  carry = (policy device sums (E,D,H), cost device sums (E,D,H),
           memory used (E,D), rng, sum log-prob, sum entropy)

At each step the cost network's per-device heads produce the augmented-state
cost features q_{t,d} from the cost device sums, the policy scores each
device, illegal devices (memory cap) are masked, and an action is sampled
(or argmax'd at inference).  The final estimated reward is the negative of
the cost network's overall head on the max-reduced device sums.

Episodes are vmapped (E parallel episodes of the same task), the step loop
is `lax.scan` over tables, and everything jits end-to-end -- one XLA call
per (M, D, E) shape covers rollout + REINFORCE loss + gradient.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core import networks as N

NEG = -1e9


def _legal_mask(mem, size_t, cap, dmask=None):
    """(E, D) legality; if a row has no legal device, everything is legal.

    ``dmask`` (D,) marks real devices -- padding devices are never legal,
    and the no-legal-device fallback opens only the real ones.
    """
    legal = (mem + size_t) <= cap
    if dmask is not None:
        legal = jnp.logical_and(legal, dmask > 0)
    any_legal = jnp.any(legal, axis=-1, keepdims=True)
    fallback = (dmask > 0) if dmask is not None else jnp.bool_(True)
    return jnp.where(any_legal, legal, fallback)


def estimate_overall(cost_params, dev_cost, reward_mode: str,
                     log_targets: bool = True, dmask=None):
    """Estimated episode cost from final cost-net device sums (E, D, H).

    "head": the paper's max-reduced overall head.
    "composed": rebuild the stage decomposition from the per-device q
    heads -- max_d fwd + max_d bwd + 2 * max_d comm.  The q heads get 3*D
    supervision targets per measurement (vs 1 for the overall head), so the
    composed estimate ranks placements markedly better (see EXPERIMENTS.md
    "Beyond-paper: composed reward").

    With ``log_targets`` the cost net is trained on log1p(ms) (relative
    error -- tasks span 15..150 ms), so predictions are mapped back with
    expm1 before composing stage times.
    """
    inv = (lambda x: jnp.expm1(jnp.minimum(x, 12.0))) if log_targets \
        else (lambda x: x)
    if reward_mode == "head":
        return inv(N.cost_overall_head(cost_params, dev_cost, dmask))
    q = N.cost_device_heads(cost_params, dev_cost)        # (E, D, 3)
    if dmask is not None:                 # padding devices must not win max
        q = jnp.where(dmask[..., None] > 0, q, NEG)
    mx = inv(q.max(axis=-2))                              # (E, 3)
    return mx[..., 0] + mx[..., 1] + 2.0 * mx[..., 2]


def _scan_rollout(policy_params, cost_params, h_pol, h_cost, sizes, cap, key,
                  n_devices, n_episodes, greedy, use_cost, actions_in=None,
                  reward_mode="composed", log_targets=True, tmask=None,
                  dmask=None):
    """Shared core.  If actions_in is given (E, M), replay those actions.

    ``tmask`` (M,) marks valid tables (1.0) vs padding rows (0.0): padded
    steps still run but contribute nothing to the device sums, memory, or
    log-prob/entropy totals, so a task padded to a bucket shape decodes to
    exactly the placement of its unpadded rollout (PlacementSession).  With
    ``tmask=None`` the computation is bit-identical to the unmasked
    original (no extra multiplies are traced).

    ``dmask`` (D,) marks real devices vs padding devices: padding devices
    score NEG in the policy logits (never selected, near-zero probability
    mass), are excluded from the legality fallback, and cannot win the
    device-max in the estimated cost -- so one trace padded to
    ``D_pad = n_devices`` serves any real device count (fused trainer).
    """
    M = h_pol.shape[0]
    H = h_pol.shape[1]
    E, D = n_episodes, n_devices
    replay = actions_in is not None
    masked = tmask is not None
    acts = jnp.swapaxes(actions_in, 0, 1) if replay else jnp.zeros((M, E), jnp.int32)

    def step(carry, xs):
        dev_pol, dev_cost, mem, k = carry
        t, a_replay, valid = xs
        if use_cost:
            q = N.cost_device_heads(cost_params, dev_cost)        # (E,D,3)
            q = jax.lax.stop_gradient(q)
        else:
            q = jnp.zeros((E, D, N.NUM_COST_FEATURES))
        logits = N.policy_logits(policy_params, dev_pol, q, dmask)  # (E,D)
        legal = _legal_mask(mem, sizes[t], cap, dmask)
        logits = jnp.where(legal, logits, NEG)
        logp_all = jax.nn.log_softmax(logits, axis=-1)
        if replay:
            a = a_replay
        elif greedy:
            a = jnp.argmax(logits, axis=-1)
        else:
            k, ks = jax.random.split(k)
            a = jax.random.categorical(ks, logits, axis=-1)
        logp = jnp.take_along_axis(logp_all, a[:, None], axis=-1)[:, 0]
        probs = jax.nn.softmax(logits, axis=-1)
        ent = -jnp.sum(probs * jnp.where(legal, logp_all, 0.0), axis=-1)
        onehot = jax.nn.one_hot(a, D)                             # (E,D)
        if masked:                        # zero padded rows' contributions
            onehot = onehot * valid
            logp = logp * valid
            ent = ent * valid
        dev_pol = dev_pol + onehot[..., None] * h_pol[t][None, None, :]
        dev_cost = dev_cost + onehot[..., None] * h_cost[t][None, None, :]
        mem = mem + onehot * sizes[t]
        return (dev_pol, dev_cost, mem, k), (a, logp, ent)

    init = (jnp.zeros((E, D, H)), jnp.zeros((E, D, H)), jnp.zeros((E, D)), key)
    valid_seq = tmask if masked else jnp.ones((M,), h_pol.dtype)
    xs = (jnp.arange(M), acts, valid_seq)
    (dev_pol, dev_cost, mem, _), (a_seq, logp_seq, ent_seq) = jax.lax.scan(
        step, init, xs)
    actions = jnp.swapaxes(a_seq, 0, 1)                           # (E, M)
    sum_logp = logp_seq.sum(axis=0)
    sum_ent = ent_seq.sum(axis=0)
    if use_cost:
        est_cost = estimate_overall(cost_params, dev_cost, reward_mode,
                                    log_targets, dmask=dmask)
    else:   # no cost network (RNN baseline): no estimate available
        est_cost = jnp.zeros((E,))
    return actions, sum_logp, sum_ent, est_cost


@functools.partial(jax.jit, static_argnames=("n_devices", "n_episodes",
                                             "greedy", "use_cost",
                                             "reward_mode", "log_targets"))
def rollout(policy_params, cost_params, feats, sizes, cap, key, *,
            n_devices: int, n_episodes: int, greedy: bool = False,
            use_cost: bool = True, reward_mode: str = "composed",
            log_targets: bool = True):
    """Sample (or greedily decode) placements on the estimated MDP.

    feats: (M, F) normalized, ALREADY sorted descending by predicted
    single-table cost.  Returns (actions (E,M), est_cost (E,)).
    """
    h_pol = N.policy_table_reprs(policy_params, feats)
    h_cost = N.cost_table_reprs(cost_params, feats)
    actions, _, _, est_cost = _scan_rollout(
        policy_params, cost_params, h_pol, h_cost, sizes, cap, key,
        n_devices, n_episodes, greedy, use_cost, reward_mode=reward_mode,
        log_targets=log_targets)
    return actions, est_cost


def decode_candidates(policy_params, cost_params, feats, sizes, cap, *,
                      n_devices, n_candidates, tmask=None, use_cost=True,
                      reward_mode="composed", log_targets=True):
    """Algorithm-2 inference core: greedy decode + sampled candidates.

    Returns ``(actions (k, M), est_cost (k,))`` -- one greedy episode
    (PRNGKey(0)) followed by ``n_candidates - 1`` sampled episodes
    (PRNGKey(1)), all ranked by the cost network's estimate.  This is the
    ONE decode implementation: ``DreamShard.place_detailed`` jits it
    per-task (via ``decode_candidates_jit``) and ``PlacementSession``
    vmaps it per padded bucket, so the two paths cannot drift apart.
    Unjitted: callers jit/vmap per shape.
    """
    h_pol = N.policy_table_reprs(policy_params, feats)
    h_cost = N.cost_table_reprs(cost_params, feats)
    common = dict(reward_mode=reward_mode, log_targets=log_targets,
                  tmask=tmask)
    a, _, _, est = _scan_rollout(
        policy_params, cost_params, h_pol, h_cost, sizes, cap,
        jax.random.PRNGKey(0), n_devices, 1, True, use_cost, **common)
    if n_candidates > 1:
        a2, _, _, est2 = _scan_rollout(
            policy_params, cost_params, h_pol, h_cost, sizes, cap,
            jax.random.PRNGKey(1), n_devices, n_candidates - 1, False,
            use_cost, **common)
        a = jnp.concatenate([a, a2])
        est = jnp.concatenate([est, est2])
    return a, est


decode_candidates_jit = functools.partial(
    jax.jit, static_argnames=("n_devices", "n_candidates", "use_cost",
                              "reward_mode", "log_targets"))(decode_candidates)


def rollout_with_reprs(policy_params, cost_params, h_pol, feats, sizes, cap,
                       key, *, n_devices, n_episodes, greedy=False,
                       use_cost=True, actions_in=None,
                       reward_mode="composed", log_targets=True,
                       tmask=None, dmask=None):
    """Rollout with externally supplied policy table reprs (RNN baseline).

    ``reward_mode`` / ``log_targets`` configure the estimated-cost head the
    same way as ``rollout`` (they were previously swallowed here, so
    callers always got the defaults); ``tmask`` / ``dmask`` enable padded
    decodes for external-repr policies too.
    """
    h_cost = N.cost_table_reprs(cost_params, feats) if use_cost else \
        jnp.zeros_like(h_pol)
    return _scan_rollout(policy_params, cost_params, h_pol, h_cost, sizes,
                         cap, key, n_devices, n_episodes, greedy, use_cost,
                         actions_in=actions_in, reward_mode=reward_mode,
                         log_targets=log_targets, tmask=tmask, dmask=dmask)


# ---- batched (padded) table sort + collection --------------------------------

def sort_tables(cost_params, feats, sizes, tmask):
    """In-graph descending sort by predicted single-table cost (App. B.4.2).

    Batched: feats (..., M, F), sizes/tmask (..., M).  Padding rows
    (tmask == 0) sort last, so the first m sorted slots are exactly the
    task's real tables.  The stable argsort of the negated costs matches
    the host-side ``np.argsort(-costs, kind="stable")`` order used by the
    per-task path.  Returns (order, feats, sizes, tmask), all sorted.
    """
    costs = N.predict_single_table_costs(cost_params, feats)      # (..., M)
    costs = jnp.where(tmask > 0, costs, -jnp.inf)
    order = jnp.argsort(-costs, axis=-1)
    feats = jnp.take_along_axis(feats, order[..., None], axis=-2)
    sizes = jnp.take_along_axis(sizes, order, axis=-1)
    tmask = jnp.take_along_axis(tmask, order, axis=-1)
    return order, feats, sizes, tmask


@functools.partial(jax.jit, static_argnames=("n_episodes", "greedy",
                                             "use_cost", "reward_mode",
                                             "log_targets"))
def collect_batched(policy_params, cost_params, feats, sizes, tmask, dmask,
                    cap, keys, *, n_episodes: int = 1, greedy: bool = False,
                    use_cost: bool = True, reward_mode: str = "composed",
                    log_targets: bool = True):
    """Sample placements for a whole padded task batch in ONE jitted call.

    feats (B, M_pad, F) normalized but UNSORTED; sizes/tmask (B, M_pad);
    dmask (B, D_pad); keys (B, 2).  Sorting happens in-graph, so the fused
    trainer's collection stage costs one dispatch for all ``n_collect``
    rollouts.  Returns (actions (B, E, M_pad) in sorted space, est (B, E),
    order (B, M_pad)) -- invert with ``assignment[order[b, :m]] =
    actions[b, e, :m]``.
    """
    order, feats, sizes, tmask = sort_tables(cost_params, feats, sizes, tmask)
    n_devices = dmask.shape[-1]

    def one(f, s, tm, dm, k):
        h_pol = N.policy_table_reprs(policy_params, f)
        h_cost = N.cost_table_reprs(cost_params, f)
        a, _, _, est = _scan_rollout(
            policy_params, cost_params, h_pol, h_cost, s, cap, k,
            n_devices, n_episodes, greedy, use_cost,
            reward_mode=reward_mode, log_targets=log_targets,
            tmask=tm, dmask=dm)
        return a, est

    actions, est = jax.vmap(one)(feats, sizes, tmask, dmask, keys)
    return actions, est, order


# ---- REINFORCE on the estimated MDP (Eq. 2) ----------------------------------

def _rl_loss(policy_params, cost_params, feats, sizes, cap, key,
             n_devices, n_episodes, w_entropy, use_cost,
             reward_mode="composed", log_targets=True, tmask=None,
             dmask=None):
    h_pol = N.policy_table_reprs(policy_params, feats)
    h_cost = N.cost_table_reprs(cost_params, feats)
    _, sum_logp, sum_ent, est_cost = _scan_rollout(
        policy_params, cost_params, h_pol, h_cost, sizes, cap, key,
        n_devices, n_episodes, False, use_cost, reward_mode=reward_mode,
        log_targets=log_targets, tmask=tmask, dmask=dmask)
    reward = jax.lax.stop_gradient(-est_cost)                     # (E,)
    baseline = reward.mean()
    adv = reward - baseline
    loss = -jnp.mean(adv * sum_logp) - w_entropy * jnp.mean(sum_ent)
    return loss, reward


def make_rl_update(optimizer, *, n_devices, n_episodes, w_entropy=1e-3,
                   use_cost=True, reward_mode="composed", log_targets=True):
    """Build a jitted REINFORCE update step bound to one (D, E) shape."""

    @jax.jit
    def update(policy_params, opt_state, cost_params, feats, sizes, cap, key):
        (loss, reward), grads = jax.value_and_grad(_rl_loss, has_aux=True)(
            policy_params, cost_params, feats, sizes, cap, key,
            n_devices, n_episodes, w_entropy, use_cost, reward_mode,
            log_targets)
        upd, opt_state = optimizer.update(grads, opt_state, policy_params)
        policy_params = jax.tree.map(lambda p, u: p + u, policy_params, upd)
        return policy_params, opt_state, loss, reward

    return update


def make_fused_rl_update(optimizer, *, n_episodes, w_entropy=1e-3,
                         use_cost=True, reward_mode="composed",
                         log_targets=True):
    """Build ONE jitted REINFORCE trainer covering a whole padded task batch.

    The returned function scans ``n_steps = feats.shape[0]`` sequential
    update steps (one pre-sampled task each) inside a single jit, with
    params/opt-state donated.  Tables are padded to M_pad (tmask) and
    devices to D_pad (dmask -> padding devices illegal in the policy
    logits), so a SINGLE trace serves every task in the training set
    regardless of its (n_tables, n_devices) -- this replaces the per-
    ``(D, E)`` recompile cache of the per-step path.  Tasks are re-sorted
    in-graph by predicted single-table cost (the cost net is frozen during
    the policy stage, so sorting once per batch matches the per-step path).

    ``update.traces[0]`` counts retraces (compile-count guard in tests).
    """
    traces = [0]

    def _update(policy_params, opt_state, cost_params, feats, sizes, tmask,
                dmask, cap, keys):
        traces[0] += 1
        n_devices = dmask.shape[-1]

        def step(carry, xs):
            pp, st = carry
            f, s, tm, dm, k = xs
            _, f, s, tm = sort_tables(cost_params, f, s, tm)
            (loss, reward), grads = jax.value_and_grad(
                _rl_loss, has_aux=True)(
                    pp, cost_params, f, s, cap, k, n_devices, n_episodes,
                    w_entropy, use_cost, reward_mode, log_targets, tm, dm)
            upd, st = optimizer.update(grads, st, pp)
            pp = jax.tree.map(lambda p, u: p + u, pp, upd)
            return (pp, st), (loss, reward.mean())

        (policy_params, opt_state), (losses, rewards) = jax.lax.scan(
            step, (policy_params, opt_state),
            (feats, sizes, tmask, dmask, keys))
        return policy_params, opt_state, losses, rewards

    update = jax.jit(_update, donate_argnums=(0, 1))
    update.traces = traces
    return update


# ---- replayed-actions log-prob (REINFORCE with external rewards) -------------

@functools.partial(jax.jit, static_argnames=("n_devices", "use_cost"))
def replay_logp(policy_params, cost_params, feats, sizes, cap, actions, *,
                n_devices: int, use_cost: bool = True):
    """Sum log pi(a_t|s_t) and entropy for fixed action sequences (E, M)."""
    h_pol = N.policy_table_reprs(policy_params, feats)
    h_cost = N.cost_table_reprs(cost_params, feats)
    _, sum_logp, sum_ent, _ = _scan_rollout(
        policy_params, cost_params, h_pol, h_cost, sizes, cap,
        jax.random.PRNGKey(0), n_devices, actions.shape[0], False, use_cost,
        actions_in=actions)
    return sum_logp, sum_ent
