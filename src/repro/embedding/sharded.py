"""Table-wise model-parallel embedding bags with all-to-all redistribution.

Implements the DLRM distributed embedding pattern of paper App. A.1 in
JAX: tables live on model-axis shards (grouped by a ``PlacementPlan``,
i.e. by DreamShard's placement), each shard performs fused lookups for its
tables over its data-parallel batch slice, and a ``jax.lax.all_to_all``
over the model axis swaps batch-for-tables so the dense (data-parallel)
part of the model sees every table's pooled embedding for its batch rows --
the forward all-to-all of the paper; the transpose in the backward pass is
the backward all-to-all.

Inside the ``shard_map`` the lookup itself is the fused embedding-bag op
(Pallas kernel on TPU, jnp oracle under transforms/CPU).
"""

from __future__ import annotations


import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

try:                                      # jax >= 0.6 top-level export
    _shard_map = jax.shard_map
    _REP_KWARG = "check_vma"
except AttributeError:                    # older jax: experimental module
    from jax.experimental.shard_map import shard_map as _shard_map
    _REP_KWARG = "check_rep"


def shard_map(f, *, mesh, in_specs, out_specs, check_vma=True):
    """Version-robust shard_map (the replication-check kwarg was renamed)."""
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      **{_REP_KWARG: check_vma})

from repro.embedding.plan import PlacementPlan                # noqa: E402
from repro.kernels.embedding_bag.ref import embedding_bag_ref  # noqa: E402


def init_arenas(key, plan: PlacementPlan, dtype=jnp.float32,
                scale: float = 0.01):
    """(n_shards, rows_max, dim) stacked per-shard arenas."""
    arenas = jax.random.normal(
        key, (plan.n_shards, plan.rows_max, plan.dim)) * scale
    # zero rows stay zero via the lookup (padded slots point at row 0)
    return arenas.astype(dtype)


def group_indices(plan: PlacementPlan, indices: np.ndarray) -> np.ndarray:
    """(B, M, P) per-table rows (-1 pad) -> (B, S*K, P) grouped by shard."""
    order = plan.grouped_index_order()
    B, _, Pp = indices.shape
    out = np.full((B, order.shape[0], Pp), -1, indices.dtype)
    live = order >= 0
    out[:, live] = indices[:, order[live]]
    return out


def _local_lookup(arena, bases, idx):
    """arena: (R, D); bases: (K,); idx: (B, K, P) -> (B, K, D)."""
    B, K, Pp = idx.shape
    rebased = jnp.where(idx >= 0, idx + bases[None, :, None], 0)
    out = embedding_bag_ref(arena, rebased.reshape(B * K, Pp))
    return out.reshape(B, K, -1)


def make_sharded_lookup(mesh, plan: PlacementPlan, *,
                        data_axes=("data",), model_axis="model"):
    """Build the shard_mapped distributed lookup.

    fn(arenas (S, R, D), indices (B, S*K, P)) ->
        (B, S*K, D) pooled embeddings, batch sharded over
        (data_axes + model) -- i.e. each device ends with its batch
        sub-slice of EVERY table (post all-to-all), the layout the
        data-parallel dense net consumes.
    """
    S = plan.n_shards
    batch_spec = data_axes if len(data_axes) > 1 else data_axes[0]

    def local_fn(arenas, bases, indices):
        # block shapes: arenas (1, R, D); indices (B_loc, K, P)
        arena = arenas[0]
        idx = indices.reshape(indices.shape[0], S, plan.k_max,
                              indices.shape[-1])
        # this shard's group only (its position along model axis)
        m = jax.lax.axis_index(model_axis)
        own = jax.lax.dynamic_index_in_dim(idx, m, axis=1, keepdims=False)
        out = _local_lookup(arena, bases[0], own)      # (B_loc, K, D)
        # forward all-to-all: trade batch rows for table groups
        out = jax.lax.all_to_all(
            out.reshape(S, out.shape[0] // S, plan.k_max, plan.dim),
            model_axis, split_axis=0, concat_axis=0, tiled=False)
        # (S, B_loc/S, K, D) -> (B_loc/S, S*K, D)
        out = jnp.moveaxis(out, 0, 1).reshape(out.shape[1], S * plan.k_max,
                                              plan.dim)
        return out

    return shard_map(
        local_fn, mesh=mesh,
        in_specs=(P(model_axis, None, None), P(model_axis, None),
                  P(batch_spec, None, None)),
        out_specs=P((*data_axes, model_axis), None, None),
        check_vma=False)


def combine_shard_outputs(plan: PlacementPlan, grouped):
    """Assemble per-slot pooled outputs into per-table embeddings.

    ``grouped`` is ``(B, S*K, D)`` -- the layout ``make_sharded_lookup``
    / ``lookup_unsharded`` produce, one slot per (device, k) cell.  For
    a whole-table plan each live slot IS its table; for a column-sharded
    plan a slot carries its shard's pooled columns in ``[0, width)`` and
    they scatter into the owner's ``[col_start, col_end)`` range (shards
    tile the owner's columns, so the scatter is a disjoint union).
    Returns ``(B, M, D)`` indexed by table id -- slot bookkeeping
    resolved, the layout a dense net consumes regardless of K.
    """
    order = plan.grouped_index_order()
    out = jnp.zeros((grouped.shape[0], plan.n_tables, plan.dim),
                    grouped.dtype)
    cols = None if plan.slot_cols is None else plan.slot_cols.reshape(-1, 2)
    for s in np.flatnonzero(order >= 0):
        t = int(order[s])
        if cols is None:
            out = out.at[:, t, :].set(grouped[:, s, :])
        else:
            c0, c1 = int(cols[s, 0]), int(cols[s, 1])
            out = out.at[:, t, c0:c1].set(grouped[:, s, :c1 - c0])
    return out


def lookup_unsharded(arenas, bases, indices, plan: PlacementPlan):
    """Single-device oracle with identical semantics (tests/CPU examples)."""
    outs = []
    for s in range(plan.n_shards):
        idx = indices[:, s * plan.k_max:(s + 1) * plan.k_max]
        outs.append(_local_lookup(arenas[s], jnp.asarray(bases[s]), idx))
    return jnp.concatenate(outs, axis=1)               # (B, S*K, D)
