"""Placement plans: the bridge from DreamShard's assignment vector to the
physical table layout consumed by the sharded embedding op.

A ``PlacementPlan`` groups tables per shard (padding groups to a uniform
K_max), builds one per-shard arena layout (tables vertically stacked,
row 0 = zero row), and records the permutation needed to regroup the
indices tensor -- everything static/host-side so the device step stays
shape-uniform across shards.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.core import features as F


@dataclasses.dataclass
class PlacementPlan:
    assignment: np.ndarray        # (M,) table -> shard
    n_shards: int
    dim: int                      # padded feature dim (128-lane multiple)
    k_max: int                    # tables per shard (padded)
    rows_max: int                 # arena rows per shard (padded, incl. zero row)
    groups: list[np.ndarray]      # table ids per shard (unpadded)
    base_rows: np.ndarray         # (n_shards, k_max) arena base row per slot
    slot_table: np.ndarray        # (n_shards, k_max) table id or -1 (pad slot)
    table_rows: np.ndarray        # (M,) rows per table

    @property
    def n_tables(self) -> int:
        return self.assignment.shape[0]

    def grouped_index_order(self) -> np.ndarray:
        """(n_shards * k_max,) table id per grouped slot (-1 = padding)."""
        return self.slot_table.reshape(-1)


def build_plan(raw_features: np.ndarray, assignment: np.ndarray,
               n_shards: int, pad_dim_to: int = 128) -> PlacementPlan:
    assignment = np.asarray(assignment)
    rows = raw_features[:, F.HASH_SIZE].astype(np.int64)
    dim = int(raw_features[:, F.DIM].max())
    dimp = int(np.ceil(dim / pad_dim_to) * pad_dim_to)
    groups = [np.flatnonzero(assignment == s) for s in range(n_shards)]
    k_max = max(1, max(len(g) for g in groups))
    rows_max = 1 + max(int(rows[g].sum()) if len(g) else 0 for g in groups)

    base = np.zeros((n_shards, k_max), np.int64)
    slot = np.full((n_shards, k_max), -1, np.int64)
    for s, g in enumerate(groups):
        r = 1                                          # row 0 reserved zero
        for j, t in enumerate(g):
            base[s, j] = r
            slot[s, j] = t
            r += int(rows[t])
    return PlacementPlan(assignment=assignment, n_shards=n_shards, dim=dimp,
                         k_max=k_max, rows_max=rows_max, groups=groups,
                         base_rows=base, slot_table=slot, table_rows=rows)
