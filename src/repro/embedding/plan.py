"""Placement plans: the bridge from DreamShard's assignment vector to the
physical table layout consumed by the sharded embedding op.

A ``PlacementPlan`` groups tables per shard (padding groups to a uniform
K_max), builds one per-shard arena layout (tables vertically stacked,
row 0 = zero row), and records the permutation needed to regroup the
indices tensor -- everything static/host-side so the device step stays
shape-uniform across shards.

With a column ``sharding`` (``repro.sharding.ShardSpec``) the plan's
slots hold *column shards* instead of whole tables: ``assignment`` is
then ``(S,)`` over the spec's shards, each slot still records its
OWNING table id in ``slot_table`` (a column shard consumes its owner's
full index stream, so index grouping is unchanged) plus its column
range in ``slot_cols``, and it occupies the owner's full row count in
the arena.  ``repro.embedding.sharded.combine_shard_outputs`` scatters
the per-slot outputs back into per-table columns.  Plans without a
sharding are bit-for-bit what they were before the field existed.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.core import features as F


@dataclasses.dataclass
class PlacementPlan:
    assignment: np.ndarray        # (M,) table -> shard ((S,) when sharded)
    n_shards: int
    dim: int                      # padded feature dim (128-lane multiple)
    k_max: int                    # tables per shard (padded)
    rows_max: int                 # arena rows per shard (padded, incl. zero row)
    groups: list[np.ndarray]      # table ids per shard (unpadded; column-shard
                                  # ids when sharded)
    base_rows: np.ndarray         # (n_shards, k_max) arena base row per slot
    slot_table: np.ndarray        # (n_shards, k_max) OWNING table id or -1
    table_rows: np.ndarray        # (M,) rows per table
    sharding: object | None = None   # ShardSpec behind a column-sharded plan
    slot_cols: np.ndarray | None = None  # (n_shards, k_max, 2) [start, end)

    @property
    def n_tables(self) -> int:
        if self.sharding is not None:
            return self.sharding.n_tables
        return self.assignment.shape[0]

    @property
    def is_sharded(self) -> bool:
        return self.sharding is not None

    def grouped_index_order(self) -> np.ndarray:
        """(n_shards * k_max,) owning table id per grouped slot (-1 =
        padding).  Column shards repeat their owner: every shard of a
        table routes the SAME index stream."""
        return self.slot_table.reshape(-1)


def build_plan(raw_features: np.ndarray, assignment: np.ndarray,
               n_shards: int, pad_dim_to: int = 128,
               sharding=None) -> PlacementPlan:
    assignment = np.asarray(assignment)
    rows = raw_features[:, F.HASH_SIZE].astype(np.int64)
    dim = int(raw_features[:, F.DIM].max())
    dimp = int(np.ceil(dim / pad_dim_to) * pad_dim_to)
    # owner[i]: the table behind grouped item i (identity when unsharded)
    owner = np.arange(rows.shape[0]) if sharding is None else sharding.table
    if assignment.shape[0] != owner.shape[0]:
        raise ValueError(
            f"assignment covers {assignment.shape[0]} items, expected "
            f"{owner.shape[0]} ({'shards' if sharding is not None else 'tables'})")
    groups = [np.flatnonzero(assignment == s) for s in range(n_shards)]
    k_max = max(1, max(len(g) for g in groups))
    rows_max = 1 + max(int(rows[owner[g]].sum()) if len(g) else 0
                       for g in groups)

    base = np.zeros((n_shards, k_max), np.int64)
    slot = np.full((n_shards, k_max), -1, np.int64)
    cols = None
    if sharding is not None:
        cols = np.zeros((n_shards, k_max, 2), np.int64)
    for s, g in enumerate(groups):
        r = 1                                          # row 0 reserved zero
        for j, i in enumerate(g):
            base[s, j] = r
            slot[s, j] = owner[i]
            if cols is not None:
                cols[s, j] = (sharding.col_start[i], sharding.col_end[i])
            r += int(rows[owner[i]])
    return PlacementPlan(assignment=assignment, n_shards=n_shards, dim=dimp,
                         k_max=k_max, rows_max=rows_max, groups=groups,
                         base_rows=base, slot_table=slot, table_rows=rows,
                         sharding=sharding, slot_cols=cols)
