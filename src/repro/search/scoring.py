"""Budget-tracked batched scoring: the one oracle seam every search
strategy shares.

A ``SearchScorer`` binds a task to a ``CostOracle`` and meters an
**anytime budget** over it: a wall-clock deadline (``budget_ms``), an
oracle-evaluation cap (``max_evals``), or both.  Every candidate batch a
strategy proposes goes through ONE ``evaluate_many`` call (the PR-4
vectorized path, ~1e4-1e5 placements/sec), capped to the remaining eval
budget -- so the cost of a search round is vector width, not Python call
count, and a run with a larger ``max_evals`` scores a strict superset of
the placements a smaller one scores (the anytime-monotonicity guarantee
rests on this).

Budget semantics:

* ``max_evals`` counts candidate ROWS sent to the oracle -- a
  deterministic meter, independent of wall clock and of cache state, so
  eval-budgeted searches reproduce bit-for-bit across hosts.  Wrapping
  the scorer's oracle in a ``CachedOracle`` still pays fewer *hardware*
  measurements (``hardware_evals`` reports the inner count) and less
  wall time; it does not stretch the row budget.
* ``budget_ms`` is a wall-clock deadline checked between rounds (and
  before the first): a strategy never *starts* work past the deadline,
  but an in-flight batch runs to completion -- results already paid for
  are always consumed.
* A scorer with neither bound is infinite; strategies must then bound
  themselves (``SearchConfig.max_rounds`` does).
"""

from __future__ import annotations

import time

import numpy as np

from repro import telemetry as tele
from repro.api.oracle import (ensure_oracle, evaluate_many, evaluate_sharded,
                              legal_batch, legal_sharded)
from repro.data.tasks import Task


class SearchScorer:
    """Meters one task's search budget over a ``CostOracle``.

    With a ``sharding`` (``repro.sharding.ShardSpec``) candidate rows are
    ``(P, S)`` *shard* assignments, scored through ``evaluate_sharded`` /
    ``legal_sharded`` instead of the whole-table paths -- the strategies
    on top propose/dedup/adopt rows identically either way (a shard move
    IS a table move over the expanded items).
    """

    def __init__(self, oracle, task: Task,
                 budget_ms: float | None = None,
                 max_evals: int | None = None, sharding=None):
        self.oracle = ensure_oracle(oracle)
        self.task = task
        self.raw = task.raw_features
        self.n_devices = task.n_devices
        self.sharding = sharding
        self.max_evals = max_evals
        self._deadline = (None if budget_ms is None
                          else time.perf_counter() + budget_ms / 1e3)
        self.evals = 0            # candidate rows sent to the oracle
        self.batches = 0          # evaluate_many calls issued
        self._hardware_evals = 0  # inner-oracle measurements, this scorer
        self._seen: set[bytes] = set()

    # ---- budget -------------------------------------------------------------

    def out_of_budget(self) -> bool:
        """True once either bound is exhausted (checked between rounds)."""
        if self.max_evals is not None and self.evals >= self.max_evals:
            return True
        if self._deadline is not None and \
                time.perf_counter() >= self._deadline:
            return True
        return False

    def remaining_evals(self) -> int | None:
        """Eval-row headroom (``None`` = unmetered)."""
        if self.max_evals is None:
            return None
        return max(0, self.max_evals - self.evals)

    def remaining_ms(self) -> float | None:
        """Wall-clock headroom before the deadline (``None`` =
        undeadlined; clamped at 0 once past it)."""
        if self._deadline is None:
            return None
        return max(0.0, (self._deadline - time.perf_counter()) * 1e3)

    @property
    def hardware_evals(self) -> int:
        """Measurements the oracle actually performed *for this scorer* --
        under a ``CachedOracle`` this is the miss count, i.e. how much of
        the row budget the cache absorbed.

        Accumulated per ``score()`` call (delta of the oracle's
        ``num_evaluations`` across the batched pass), NOT as one delta
        since construction -- a shared oracle may serve other traffic
        (e.g. a benchmark's baseline sweep between searches), and that
        must not be billed to this scorer.
        """
        return self._hardware_evals

    # ---- candidate filtering ------------------------------------------------

    def legal(self, assignments: np.ndarray) -> np.ndarray:
        """Vectorized ``(P,)`` memory-legality -- free, no eval budget."""
        if self.sharding is not None:
            return legal_sharded(self.oracle, self.raw, self.sharding,
                                 assignments, self.n_devices)
        return legal_batch(self.oracle, self.raw, assignments,
                           self.n_devices)

    def filter_new(self, assignments: np.ndarray) -> np.ndarray:
        """Drop rows this scorer has already scored (or queued in this
        very batch) so near-duplicate neighborhoods don't burn budget;
        marks the survivors as seen.  Returns the filtered ``(P', M)``."""
        A = np.asarray(assignments, dtype=np.int64)
        keep = []
        for i, row in enumerate(A):
            key = row.tobytes()
            if key not in self._seen:
                self._seen.add(key)
                keep.append(i)
        return A[keep]

    # ---- scoring ------------------------------------------------------------

    def score(self, assignments: np.ndarray):
        """Measure up to ``remaining_evals`` rows in ONE batched pass.

        Returns ``(costs (P,), results list)``: rows beyond the eval
        budget get ``inf`` cost and ``None`` result (strategies treat
        them as unevaluated, never as cheap).  Row order is preserved, so
        deterministic proposal order + row-capped scoring keeps a larger
        budget's scored set a superset of a smaller one's.
        """
        A = np.asarray(assignments, dtype=np.int64)
        P = A.shape[0]
        costs = np.full(P, np.inf)
        results: list = [None] * P
        cap = P if self.max_evals is None else \
            min(P, max(0, self.max_evals - self.evals))
        if cap == 0:
            return costs, results
        hw0 = self.oracle.num_evaluations
        with tele.span("search.score", rows=cap,
                       n_devices=self.n_devices) as sp:
            if self.sharding is not None:
                res = evaluate_sharded(self.oracle, self.raw, self.sharding,
                                       A[:cap], self.n_devices)
            else:
                res = evaluate_many(self.oracle, self.raw, A[:cap],
                                    self.n_devices)
            sp.set(hardware_evals=self.oracle.num_evaluations - hw0)
        self._hardware_evals += self.oracle.num_evaluations - hw0
        self.evals += cap
        self.batches += 1
        tele.count("search.scored_rows", cap)
        for i, r in enumerate(res):
            costs[i] = r.overall
            results[i] = r
        return costs, results
