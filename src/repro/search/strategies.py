"""The three search strategy families behind ``SearchPlacer``.

All three refine a *seed* placement purely through the scorer's batched
oracle path -- every round proposes one ``(P, M)`` assignment matrix and
pays one ``evaluate_many`` call:

* ``refine_lns``       -- large-neighborhood search: batched single-table
  moves and pairwise swaps around the measured bottleneck device
  (device-imbalance-guided neighborhood selection);
* ``refine_evolution`` -- an evolutionary loop (mutation = k random
  reassignments, crossover = per-table device vote between elites,
  tournament selection) over a population seeded from the proposal;
* ``refine_beam``      -- beam search over the table-by-table MDP
  ordering (``core/mdp.py``'s one-table-per-step environment), scoring
  *partial* placements with the cost network's ``estimate_overall``
  (hardware-free) and finishing only the leaves through the oracle --
  the *Pre-train and Search* recipe.

Strategies only ever improve on the seed: the incumbent is replaced when
a candidate measures strictly cheaper, so the refined cost is <= the
seed cost on every task (``tests/test_search.py`` holds them to it).
Randomness comes exclusively from the caller's ``rng`` stream, consumed
in round order, which makes eval-budgeted runs nested: a larger
``max_evals`` replays the smaller run's rounds exactly and then keeps
going (anytime monotonicity).
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro import telemetry as tele
from repro.search.scoring import SearchScorer

# consecutive rounds allowed to produce zero new admissible candidates
# before a strategy declares its neighborhood exhausted and stops early
# (prevents unmetered spins when the reachable space is tiny)
STALL_LIMIT = 25


@dataclasses.dataclass
class Incumbent:
    """Best placement found so far (assignment in original table order)."""

    assignment: np.ndarray       # (M,)
    cost: float                  # oracle-measured overall ms
    result: object               # SimResult of the incumbent (or None)
    proposed: int = 0            # candidate placements proposed (pre-filter)

    def consider(self, assignments, costs, results) -> bool:
        """Adopt the cheapest strictly-improving row, if any."""
        if len(costs) == 0:
            return False
        i = int(np.argmin(costs))
        if costs[i] < self.cost:
            self.assignment = np.asarray(assignments[i], dtype=np.int64)
            self.cost = float(costs[i])
            self.result = results[i]
            return True
        return False


def _admissible(scorer: SearchScorer, A: np.ndarray,
                enforce_legal: bool) -> np.ndarray:
    """Legality filter (when the seed itself was legal -- refinement must
    never trade memory feasibility for speed) + already-scored dedup."""
    if A.shape[0] and enforce_legal:
        A = A[scorer.legal(A)]
    if A.shape[0]:
        A = scorer.filter_new(A)
    return A


def _device_loads(result, n_devices: int) -> np.ndarray:
    """Per-device busy time of the incumbent -- the neighborhood guide."""
    if result is None:
        return np.ones(n_devices)
    return np.asarray(result.fwd_comp) + np.asarray(result.bwd_comp) \
        + np.asarray(result.bwd_comm)


# ---- large-neighborhood search ----------------------------------------------


def _sample_pairs(rng, n_left: int, n_right: int, k: int):
    """Up to ``k`` distinct (i, j) index pairs from the n_left x n_right
    grid, drawn without replacement."""
    total = n_left * n_right
    if total == 0 or k <= 0:
        return np.empty(0, np.int64), np.empty(0, np.int64)
    if total <= k:
        flat = np.arange(total)
    else:
        flat = rng.choice(total, size=k, replace=False)
    return flat // n_right, flat % n_right


def _lns_neighborhood(incumbent: Incumbent, rng, neighborhood: int,
                      swap_fraction: float, n_devices: int) -> np.ndarray:
    """One round's ``(P, M)`` candidate matrix around the incumbent.

    The source device is sampled proportionally to squared measured load
    (strongly biased toward the bottleneck -- the only device whose
    tables can lower the stage maxima -- but still exploring others so
    repeated rounds don't re-propose one exhausted neighborhood).
    Candidates are single-table moves off the source plus pairwise swaps
    between the source and the rest.
    """
    a = incumbent.assignment
    M, D = a.shape[0], n_devices
    loads = np.maximum(_device_loads(incumbent.result, D), 0.0) ** 2
    p = loads / loads.sum() if loads.sum() > 0 else np.full(D, 1.0 / D)
    src = int(rng.choice(D, p=p))
    on_src = np.flatnonzero(a == src)
    if on_src.size == 0:                      # idle device: nothing to move
        src = int(rng.choice(np.flatnonzero(
            np.bincount(a, minlength=D) > 0)))
        on_src = np.flatnonzero(a == src)
    off_src = np.flatnonzero(a != src)
    targets = np.array([d for d in range(D) if d != src])

    n_swaps = int(round(neighborhood * swap_fraction))
    n_moves = max(0, neighborhood - n_swaps)
    rows = []
    ti, di = _sample_pairs(rng, on_src.size, targets.size, n_moves)
    if ti.size:                                # single-table moves
        A = np.tile(a, (ti.size, 1))
        A[np.arange(ti.size), on_src[ti]] = targets[di]
        rows.append(A)
    ti, ui = _sample_pairs(rng, on_src.size, off_src.size, n_swaps)
    if ti.size:                                # pairwise swaps
        A = np.tile(a, (ti.size, 1))
        t, u = on_src[ti], off_src[ui]
        idx = np.arange(ti.size)
        A[idx, t] = a[u]
        A[idx, u] = src
        rows.append(A)
    if not rows:
        return np.empty((0, M), np.int64)
    return np.concatenate(rows)


def refine_lns(scorer: SearchScorer, rng, cfg, incumbent: Incumbent,
               enforce_legal: bool) -> Incumbent:
    stall = 0
    rounds = 0
    while not scorer.out_of_budget() and stall < STALL_LIMIT:
        if cfg.max_rounds is not None and rounds >= cfg.max_rounds:
            break
        rounds += 1
        with tele.span("search.round", strategy="lns",
                       round=rounds) as sp:
            A = _lns_neighborhood(incumbent, rng, cfg.neighborhood,
                                  cfg.swap_fraction, scorer.n_devices)
            incumbent.proposed += A.shape[0]
            A = _admissible(scorer, A, enforce_legal)
            if A.shape[0] == 0:
                stall += 1
                sp.set(stalled=True)
                continue
            stall = 0
            costs, results = scorer.score(A)
            incumbent.consider(A, costs, results)
            sp.set(incumbent_ms=incumbent.cost,
                   remaining_evals=scorer.remaining_evals(),
                   remaining_ms=scorer.remaining_ms())
    return incumbent


# ---- evolutionary search ----------------------------------------------------


def _mutate(a: np.ndarray, rng, k: int, n_devices: int) -> np.ndarray:
    """k random reassignments, each to a uniformly drawn OTHER device."""
    out = a.copy()
    k = min(max(1, k), a.shape[0])
    tables = rng.choice(a.shape[0], size=k, replace=False)
    out[tables] = (out[tables]
                   + rng.integers(1, n_devices, size=k)) % n_devices
    return out


def _crossover_vote(elites: np.ndarray, rng, n_devices: int) -> np.ndarray:
    """Per-table device vote between elites; ties break uniformly."""
    E, M = elites.shape
    counts = np.zeros((M, n_devices))
    for row in elites:
        counts[np.arange(M), row] += 1.0
    # sub-vote noise perturbs only ties, never a strict majority
    counts += rng.uniform(0.0, 0.5, size=counts.shape)
    return np.argmax(counts, axis=1).astype(np.int64)


def _tournament(rng, costs: np.ndarray, k: int) -> int:
    idx = rng.integers(costs.shape[0], size=max(1, k))
    return int(idx[np.argmin(costs[idx])])


def refine_evolution(scorer: SearchScorer, rng, cfg,
                     incumbent: Incumbent, enforce_legal: bool) -> Incumbent:
    D = scorer.n_devices
    pop_a = [incumbent.assignment]
    pop_c = [incumbent.cost]

    def admit(A):
        incumbent.proposed += A.shape[0]
        A = _admissible(scorer, A, enforce_legal)
        if A.shape[0] == 0:
            return False
        costs, results = scorer.score(A)
        incumbent.consider(A, costs, results)
        ok = np.isfinite(costs)
        pop_a.extend(A[ok])
        pop_c.extend(costs[ok])
        # survival of the fittest: trim back to the population size
        if len(pop_a) > cfg.population:
            order = np.argsort(pop_c, kind="stable")[:cfg.population]
            pop_a[:] = [pop_a[i] for i in order]
            pop_c[:] = [pop_c[i] for i in order]
        return True

    init = np.stack([_mutate(incumbent.assignment, rng, cfg.mutations, D)
                     for _ in range(cfg.population - 1)])
    if not scorer.out_of_budget():
        admit(init)

    stall = 0
    rounds = 0
    while not scorer.out_of_budget() and stall < STALL_LIMIT:
        if cfg.max_rounds is not None and rounds >= cfg.max_rounds:
            break
        rounds += 1
        with tele.span("search.round", strategy="evolution",
                       round=rounds) as sp:
            costs = np.asarray(pop_c)
            order = np.argsort(costs, kind="stable")
            elites = np.stack([pop_a[i]
                               for i in order[:max(1, cfg.elites)]])
            children = []
            for _ in range(cfg.population):
                if elites.shape[0] >= 2 and \
                        rng.random() < cfg.crossover_rate:
                    child = _crossover_vote(elites, rng, D)
                else:
                    child = pop_a[_tournament(rng, costs, cfg.tournament)]
                children.append(_mutate(child, rng, cfg.mutations, D))
            if not admit(np.stack(children)):
                stall += 1
                sp.set(stalled=True)
            else:
                stall = 0
            sp.set(incumbent_ms=incumbent.cost,
                   remaining_evals=scorer.remaining_evals(),
                   remaining_ms=scorer.remaining_ms())
    return incumbent


# ---- beam search over the placement MDP -------------------------------------

# one jitted partial-placement scorer per cost-head configuration; the
# cost params are call arguments, so every agent with the same config
# shares a trace per (beam * devices, devices, hidden) shape
_BEAM_SCORE_FNS: dict = {}


def _beam_score_fn(reward_mode: str, log_targets: bool):
    key = (reward_mode, log_targets)
    fn = _BEAM_SCORE_FNS.get(key)
    if fn is None:
        tele.count("jit.retraces")
        import jax

        from repro.core import rollout as R

        @jax.jit
        def fn(cost_params, dev):          # dev: (B, D, H) device sums
            return R.estimate_overall(cost_params, dev, reward_mode,
                                      log_targets)

        _BEAM_SCORE_FNS[key] = fn
    return fn


def refine_beam(scorer: SearchScorer, rng, cfg, incumbent: Incumbent,
                enforce_legal: bool, agent) -> Incumbent:
    """Beam search over the one-table-per-step MDP, cost-net guided.

    Tables are visited in the agent's descending predicted-cost order
    (the ``core/mdp.py`` / Algorithm-2 ordering).  Each step expands
    every beam entry to all devices, prices the partial placements with
    the cost network's ``estimate_overall`` over running device sums
    (zero oracle budget -- the estimated MDP), applies the memory
    legality mask with the rollout's no-legal-device fallback, breaks
    empty-device symmetry (a table may only open the lowest-indexed
    empty device), and keeps the ``beam_width`` cheapest.  Only the
    surviving leaves are measured through the oracle, best-estimate
    first, so a tiny eval budget still scores the most promising leaf.
    """
    import jax.numpy as jnp

    from repro.core import networks as N

    task = scorer.task
    D = scorer.n_devices
    feats, sizes_gb, order = agent._inference_inputs(task.raw_features)
    feats_s, sizes_s = feats[order], sizes_gb[order]
    h = np.asarray(N.cost_table_reprs(agent.cost_params,
                                      jnp.asarray(feats_s)), np.float32)
    M, H = h.shape
    W = max(1, cfg.beam_width)
    cap = scorer.oracle.mem_capacity_gb
    score_fn = _beam_score_fn(agent.cfg.reward_mode, agent._log_targets)

    assign = np.zeros((W, M), np.int64)
    dev = np.zeros((W, D, H), np.float32)
    mem = np.zeros((W, D), np.float64)
    used = np.zeros((W, D), bool)
    alive = np.zeros(W, bool)
    alive[0] = True
    leaf_est = np.full(W, np.inf)

    rows = np.arange(W)
    with tele.span("search.beam_expand", W=W, M=M, n_devices=D):
        for t in range(M):
            legal = (mem + sizes_s[t]) <= cap                # (W, D)
            none_legal = ~legal.any(axis=1)
            legal[none_legal] = True            # rollout's fallback rule
            # symmetry breaking: empty devices are interchangeable, so
            # only the lowest-indexed one may be opened by this table
            empty = ~used
            first_empty = np.argmax(empty, axis=1)
            allowed = used.copy()
            has_empty = empty.any(axis=1)
            allowed[rows[has_empty], first_empty[has_empty]] = True
            legal &= allowed

            cand = np.repeat(dev[:, None], D, axis=1)        # (W, D, D, H)
            cand[:, np.arange(D), np.arange(D), :] += h[t]
            est = np.asarray(score_fn(
                agent.cost_params,
                jnp.asarray(cand.reshape(W * D, D, H))))
            est = est.reshape(W, D).astype(np.float64)
            est[~legal] = np.inf
            est[~alive] = np.inf
            sel = np.argsort(est, axis=None, kind="stable")[:W]
            w_idx, d_idx = np.unravel_index(sel, (W, D))

            leaf_est = est[w_idx, d_idx]
            new_alive = np.isfinite(leaf_est)
            assign = assign[w_idx]
            assign[new_alive, t] = d_idx[new_alive]
            dev = cand[w_idx, d_idx]
            mem = mem[w_idx]
            mem[new_alive, d_idx[new_alive]] += sizes_s[t]
            used = used[w_idx]
            used[new_alive, d_idx[new_alive]] = True
            alive = new_alive

    if not alive.any():
        return incumbent
    leaves_sorted = assign[alive][np.argsort(leaf_est[alive], kind="stable")]
    # back to original table order: sorted slot t holds table order[t]
    leaves = np.empty_like(leaves_sorted)
    leaves[:, order] = leaves_sorted
    incumbent.proposed += leaves.shape[0]
    leaves = _admissible(scorer, leaves, enforce_legal)
    if leaves.shape[0] and not scorer.out_of_budget():
        with tele.span("search.round", strategy="beam",
                       leaves=int(leaves.shape[0])) as sp:
            costs, results = scorer.score(leaves)
            incumbent.consider(leaves, costs, results)
            sp.set(incumbent_ms=incumbent.cost,
                   remaining_evals=scorer.remaining_evals(),
                   remaining_ms=scorer.remaining_ms())
    return incumbent
